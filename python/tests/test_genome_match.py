"""Kernel-vs-oracle tests for the genome_match Pallas kernel."""

import numpy as np
import pytest

from compile.kernels.genome_match import (
    BASE_A,
    BASE_N,
    BASE_T,
    PAD,
    genome_match,
    make_genome_match,
)
from compile.kernels.ref import genome_match_ref, genome_match_ref_np


def _mk_patterns(rng, seq, n_pat, width, min_len=2):
    """Plant half the patterns in seq, make the other half random."""
    pats = np.full((n_pat, width), PAD, np.int8)
    lens = np.zeros(n_pat, np.int32)
    for p in range(n_pat):
        plen = int(rng.integers(min_len, width + 1))
        lens[p] = plen
        if p % 2 == 0 and len(seq) > width:
            start = int(rng.integers(0, len(seq) - width))
            pats[p, :plen] = seq[start : start + plen]
        else:
            pats[p, :plen] = rng.integers(0, 4, plen).astype(np.int8)
    return pats, lens


@pytest.mark.parametrize("chunk,n_pat,width,p_blk", [
    (64, 4, 5, 2),
    (128, 8, 8, 4),
    (256, 16, 25, 8),
    (1024, 32, 25, 8),
    (333, 6, 7, 3),        # chunk not a power of two
    (64, 4, 25, 4),        # width comparable to chunk
])
def test_kernel_matches_ref(chunk, n_pat, width, p_blk):
    rng = np.random.default_rng(chunk * 31 + n_pat)
    seq = rng.integers(0, 4, chunk).astype(np.int8)
    pats, lens = _mk_patterns(rng, seq, n_pat, width)
    got = np.asarray(genome_match(seq, pats, lens, p_blk=p_blk))
    want = np.asarray(genome_match_ref(seq, pats, lens))
    np.testing.assert_array_equal(got, want)


def test_ref_matches_naive_numpy():
    rng = np.random.default_rng(7)
    seq = rng.integers(0, 4, 200).astype(np.int8)
    pats, lens = _mk_patterns(rng, seq, 10, 9)
    np.testing.assert_array_equal(
        np.asarray(genome_match_ref(seq, pats, lens)),
        genome_match_ref_np(seq, pats, lens),
    )


def test_planted_pattern_found():
    seq = np.zeros(128, np.int8)  # all A
    seq[40:45] = [1, 2, 3, 1, 2]  # CGTCG at 40
    pats = np.full((2, 8), PAD, np.int8)
    pats[0, :5] = [1, 2, 3, 1, 2]
    pats[1, :3] = [3, 3, 3]  # TTT never present
    lens = np.array([5, 3], np.int32)
    mask = np.asarray(genome_match(seq, pats, lens, p_blk=2))
    assert mask[0, 40] == 1
    assert mask[0].sum() == 1
    assert mask[1].sum() == 0


def test_pattern_at_chunk_end_exact_fit():
    seq = np.zeros(32, np.int8)
    seq[29:32] = [3, 3, 3]
    pats = np.full((2, 4), PAD, np.int8)
    pats[0, :3] = [3, 3, 3]
    pats[1, :4] = [3, 3, 3, 3]  # would overrun -> no hit
    lens = np.array([3, 4], np.int32)
    mask = np.asarray(genome_match(seq, pats, lens, p_blk=2))
    assert mask[0, 29] == 1
    assert mask[1].sum() == 0


def test_window_overrun_never_matches():
    """A real-base pattern longer than the remaining chunk never matches."""
    seq = np.array([0, 1, 2, 3], np.int8)
    pats = np.full((2, 6), PAD, np.int8)
    pats[0, :6] = [0, 1, 2, 3, 0, 0]  # prefix matches, tail overruns chunk
    pats[1, :2] = [2, 3]
    lens = np.array([6, 2], np.int32)
    mask = np.asarray(genome_match(seq, pats, lens, p_blk=2))
    assert mask[0].sum() == 0  # overrun positions are N-padding != base A
    assert mask[1, 2] == 1


def test_width_exceeds_chunk():
    """width > chunk (degenerate shift) must not crash and never hit."""
    seq = np.array([0, 1], np.int8)
    pats = np.full((1, 5), PAD, np.int8)
    pats[0, :5] = [0, 1, 0, 1, 0]
    lens = np.array([5], np.int32)
    mask = np.asarray(genome_match(seq, pats, lens, p_blk=1))
    assert mask.sum() == 0


def test_length_one_pattern():
    seq = np.array([0, 1, 0, 1, 0], np.int8)
    pats = np.full((2, 3), PAD, np.int8)
    pats[0, 0] = 0
    pats[1, 0] = 1
    lens = np.array([1, 1], np.int32)
    mask = np.asarray(genome_match(seq, pats, lens, p_blk=2))
    np.testing.assert_array_equal(mask[0], [1, 0, 1, 0, 1])
    np.testing.assert_array_equal(mask[1], [0, 1, 0, 1, 0])


def test_n_bases_never_match():
    seq = np.full(16, BASE_N, np.int8)
    pats = np.full((2, 2), PAD, np.int8)
    pats[0, :2] = [0, 0]
    pats[1, :2] = [BASE_N, BASE_N]  # pattern of Ns: policy = never matches? no:
    lens = np.array([2, 2], np.int32)
    mask = np.asarray(genome_match(seq, pats, lens, p_blk=2))
    assert mask[0].sum() == 0
    # N in pattern DOES equal N in sequence under exact-integer match; the
    # generator never emits N patterns, but the kernel semantics are exact.
    want = np.asarray(genome_match_ref(seq, pats, lens))
    np.testing.assert_array_equal(mask, want)


def test_identical_patterns_identical_rows():
    rng = np.random.default_rng(3)
    seq = rng.integers(0, 4, 256).astype(np.int8)
    pats = np.full((4, 5), PAD, np.int8)
    pats[:, :5] = seq[10:15]  # all four identical
    lens = np.full(4, 5, np.int32)
    mask = np.asarray(genome_match(seq, pats, lens, p_blk=2))
    for p in range(1, 4):
        np.testing.assert_array_equal(mask[0], mask[p])


def test_grid_blocking_invariant():
    """Result must not depend on the dictionary grid block size."""
    rng = np.random.default_rng(11)
    seq = rng.integers(0, 4, 512).astype(np.int8)
    pats, lens = _mk_patterns(rng, seq, 16, 12)
    outs = []
    for p_blk in (1, 2, 4, 8, 16):
        fn = make_genome_match(512, 16, 12, p_blk)
        outs.append(np.asarray(fn(seq, pats, lens)))
    for o in outs[1:]:
        np.testing.assert_array_equal(outs[0], o)


def test_bad_geometry_raises():
    with pytest.raises(ValueError):
        make_genome_match(64, 10, 5, 4)  # 10 % 4 != 0


def test_aot_geometry_smoke():
    """The exact geometry aot.py freezes must execute correctly."""
    from compile import model

    rng = np.random.default_rng(5)
    seq = rng.integers(0, 4, model.CHUNK).astype(np.int8)
    pats, lens = _mk_patterns(rng, seq, model.N_PATTERNS, model.WIDTH, min_len=15)
    fn = make_genome_match(model.CHUNK, model.N_PATTERNS, model.WIDTH, model.P_BLK)
    mask = np.asarray(fn(seq, pats, lens))
    want = np.asarray(genome_match_ref(seq, pats, lens))
    np.testing.assert_array_equal(mask, want)
    # planted patterns must be found at least once
    assert (mask[::2].sum(axis=1) >= 1).all()
