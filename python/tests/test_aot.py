"""AOT emission tests: HLO text artifacts + manifest consistency."""

import os

import numpy as np
import pytest

from compile import aot, model


@pytest.fixture(scope="module")
def emitted(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    lines = aot.emit(str(out))
    return out, lines


def test_all_artifacts_emitted(emitted):
    out, lines = emitted
    names = {l.split("|")[0] for l in lines}
    assert names == {"genome_search", "reduce", "collate"}
    for n in names:
        p = out / f"{n}.hlo.txt"
        assert p.exists() and p.stat().st_size > 100


def test_hlo_text_is_parseable_header(emitted):
    out, _ = emitted
    for n in ("genome_search", "reduce", "collate"):
        text = (out / f"{n}.hlo.txt").read_text()
        assert text.startswith("HloModule"), n
        assert "ROOT" in text
        # must be the text format, not a serialized proto
        assert "\x00" not in text


def test_manifest_shapes_match_model(emitted):
    _, lines = emitted
    m = {l.split("|")[0]: l for l in lines}
    gs = m["genome_search"]
    assert f"int8:{model.CHUNK}" in gs
    assert f"int8:{model.N_PATTERNS}x{model.WIDTH}" in gs
    assert f"int8:{model.N_PATTERNS}x{model.CHUNK}" in gs  # mask output
    rd = m["reduce"]
    assert f"float32:{model.REDUCE_N}" in rd
    assert "float32:scalar" in rd


def test_entry_layout_mentions_tuple_output(emitted):
    """We lower with return_tuple=True; rust unwraps with to_tupleN."""
    out, _ = emitted
    text = (out / "reduce.hlo.txt").read_text()
    first = text.splitlines()[0]
    assert "->(" in first.replace(" ", "")


def test_collate_fn_semantics():
    counts = np.arange(model.COLLATE_NODES * model.N_PATTERNS, dtype=np.int32)
    counts = counts.reshape(model.COLLATE_NODES, model.N_PATTERNS)
    (merged,) = model.collate_fn(counts)
    np.testing.assert_array_equal(np.asarray(merged), counts.sum(axis=0))
