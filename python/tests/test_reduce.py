"""Kernel-vs-oracle tests for the reduce_tree Pallas kernel."""

import numpy as np
import pytest

from compile.kernels.reduce_tree import make_block_reduce, tree_reduce
from compile.kernels.ref import tree_reduce_ref


@pytest.mark.parametrize("n,block", [
    (16, 4), (64, 64), (4096, 256), (1 << 14, 1 << 10), (100, 10), (7, 7),
])
def test_block_reduce_matches_sum(n, block):
    rng = np.random.default_rng(n)
    x = rng.normal(size=n).astype(np.float32)
    partials = np.asarray(make_block_reduce(n, block)(x))
    assert partials.shape == (n // block,)
    np.testing.assert_allclose(partials.sum(), x.sum(), rtol=1e-5)
    # each partial is the sum of its block
    for i in range(n // block):
        np.testing.assert_allclose(
            partials[i], x[i * block : (i + 1) * block].sum(), rtol=1e-5
        )


@pytest.mark.parametrize("n", [1, 2, 9, 100, 1000, 12345, 1 << 16])
def test_tree_reduce_matches_ref(n):
    rng = np.random.default_rng(n)
    x = rng.normal(size=n).astype(np.float32)
    got = float(tree_reduce(x))
    want = float(tree_reduce_ref(x))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_tree_reduce_zeros_and_constants():
    assert float(tree_reduce(np.zeros(128, np.float32))) == 0.0
    np.testing.assert_allclose(float(tree_reduce(np.ones(128, np.float32))), 128.0)


def test_tree_reduce_negative_cancellation():
    x = np.array([1e6, -1e6, 1.0, -1.0, 0.5] * 20, np.float32)
    np.testing.assert_allclose(float(tree_reduce(x)), x.sum(), atol=1e-2)


def test_block_reduce_bad_geometry():
    with pytest.raises(ValueError):
        make_block_reduce(10, 3)


def test_aot_geometry_smoke():
    from compile import model

    rng = np.random.default_rng(0)
    x = rng.normal(size=model.REDUCE_N).astype(np.float32)
    (got,) = model.reduce_fn(x)
    np.testing.assert_allclose(float(got), x.sum(), rtol=1e-3, atol=1e-1)
