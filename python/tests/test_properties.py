"""Hypothesis property sweeps over the Pallas kernels' shape/dtype space."""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels.genome_match import PAD, genome_match
from compile.kernels.ref import genome_match_ref, tree_reduce_ref
from compile.kernels.reduce_tree import tree_reduce


@st.composite
def match_problem(draw):
    chunk = draw(st.integers(8, 300))
    width = draw(st.integers(1, 12))
    n_pat = draw(st.integers(1, 12))
    p_blk = draw(st.sampled_from([1, 2, 4]).filter(lambda b: n_pat % b == 0 or b == 1))
    if n_pat % p_blk != 0:
        p_blk = 1
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    seq = rng.integers(0, 5, chunk).astype(np.int8)  # includes N bases
    pats = np.full((n_pat, width), PAD, np.int8)
    lens = np.zeros(n_pat, np.int32)
    for p in range(n_pat):
        plen = int(rng.integers(1, width + 1))
        lens[p] = plen
        if rng.random() < 0.5 and chunk > width:
            s = int(rng.integers(0, chunk - width))
            pats[p, :plen] = seq[s : s + plen]
        else:
            pats[p, :plen] = rng.integers(0, 4, plen).astype(np.int8)
    return seq, pats, lens, p_blk


@settings(max_examples=40, deadline=None)
@given(match_problem())
def test_match_kernel_equals_oracle(problem):
    seq, pats, lens, p_blk = problem
    got = np.asarray(genome_match(seq, pats, lens, p_blk=p_blk))
    want = np.asarray(genome_match_ref(seq, pats, lens))
    np.testing.assert_array_equal(got, want)


@settings(max_examples=40, deadline=None)
@given(match_problem())
def test_match_planted_window_is_hit(problem):
    """Any window physically present in seq must be reported at that index."""
    seq, pats, lens, p_blk = problem
    mask = np.asarray(genome_match(seq, pats, lens, p_blk=p_blk))
    for p in range(pats.shape[0]):
        plen = int(lens[p])
        pat = pats[p, :plen].astype(np.int64)
        for i in range(len(seq) - plen + 1):
            if np.array_equal(seq[i : i + plen].astype(np.int64), pat):
                assert mask[p, i] == 1, (p, i)


@settings(max_examples=40, deadline=None)
@given(
    st.integers(1, 5000),
    st.integers(0, 2**31 - 1),
    st.sampled_from([np.float32, np.float64, np.int32]),
)
def test_tree_reduce_dtypes_and_sizes(n, seed, dtype):
    rng = np.random.default_rng(seed)
    if dtype is np.int32:
        x = rng.integers(-100, 100, n).astype(dtype)
    else:
        x = rng.normal(size=n).astype(dtype)
    got = float(tree_reduce(np.asarray(x, np.float32)))
    want = float(tree_reduce_ref(x))
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-2)


@settings(max_examples=25, deadline=None)
@given(st.integers(2, 2000), st.integers(0, 2**31 - 1))
def test_tree_reduce_permutation_invariant(n, seed):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=n).astype(np.float32)
    perm = rng.permutation(n)
    a = float(tree_reduce(x))
    b = float(tree_reduce(x[perm]))
    np.testing.assert_allclose(a, b, rtol=1e-3, atol=1e-3)
