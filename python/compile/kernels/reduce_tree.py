"""L1 Pallas kernel: blocked tree reduction (the paper's parallel summation).

The paper's empirical study (Figs. 8-13) uses a generic parallel summation
algorithm: inputs flow leaves -> N1 -> N2 -> N3 with the (+) operator.  A
sub-job is "sum this block of data"; this kernel is that block sum, written
as a two-level tree inside one pallas grid: each program reduces one block
to a partial, the L2 graph (model.py) reduces the partials.

TPU mapping: one block per program resident in VMEM, lane-parallel VPU adds;
the block size is the VMEM tile knob.  interpret=True (see genome_match.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _block_sum_kernel(x_ref, out_ref):
    """Reduce one resident block to a single partial sum."""
    out_ref[...] = jnp.sum(x_ref[...], dtype=jnp.float32).reshape((1,))


def make_block_reduce(n: int, block: int):
    """Build ``f(x[f32 n]) -> partials[f32 n/block]`` with a 1-D grid."""
    if n % block != 0:
        raise ValueError(f"n={n} not divisible by block={block}")
    grid = (n // block,)
    return pl.pallas_call(
        _block_sum_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block,), lambda i: (i,))],
        out_specs=pl.BlockSpec((1,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n // block,), jnp.float32),
        interpret=True,
    )


def tree_reduce(x, *, block: int = 4096):
    """Two-level tree sum: pallas partials + jnp root reduction."""
    n = x.shape[0]
    block = min(block, n)
    while n % block != 0:  # degrade gracefully for awkward sizes (tests)
        block -= 1
    partials = make_block_reduce(n, block)(x)
    return jnp.sum(partials, dtype=jnp.float32)
