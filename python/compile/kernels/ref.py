"""Pure-jnp correctness oracles for the L1 Pallas kernels.

These are the ground truth the kernels are tested against (pytest +
hypothesis).  They use a *different formulation* than the kernels so a shared
bug is unlikely: the oracle gathers full windows and compares them as rows,
the kernel walks shifted columns.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from .genome_match import BASE_N


def genome_match_ref(seq, patterns, lengths):
    """Oracle for genome_match: gather-window formulation.

    Returns int8[P, chunk] with mask[p, i] == 1 iff
    seq[i : i + lengths[p]] == patterns[p, : lengths[p]] (windows that
    overrun the chunk never match).
    """
    seq = jnp.asarray(seq, dtype=jnp.int32)
    patterns = jnp.asarray(patterns, dtype=jnp.int32)
    lengths = jnp.asarray(lengths, dtype=jnp.int32)
    chunk = seq.shape[0]
    n_pat, width = patterns.shape
    # windows[i, w] = seq[i + w], N-padded past the end.
    padded = jnp.concatenate([seq, jnp.full((width,), BASE_N, jnp.int32)])
    idx = jnp.arange(chunk)[:, None] + jnp.arange(width)[None, :]
    windows = padded[idx]  # [chunk, width]
    # eq[p, i, w]
    eq = windows[None, :, :] == patterns[:, None, :]
    active = jnp.arange(width)[None, :] < lengths[:, None]  # [P, width]
    ok = jnp.logical_or(~active[:, None, :], eq)
    return jnp.all(ok, axis=-1).astype(jnp.int8)


def genome_match_ref_np(seq, patterns, lengths):
    """Naive numpy scan — a third, loop-based formulation for hypothesis."""
    seq = np.asarray(seq, dtype=np.int64)
    patterns = np.asarray(patterns, dtype=np.int64)
    lengths = np.asarray(lengths, dtype=np.int64)
    chunk = seq.shape[0]
    n_pat = patterns.shape[0]
    out = np.zeros((n_pat, chunk), dtype=np.int8)
    for p in range(n_pat):
        plen = int(lengths[p])
        pat = patterns[p, :plen]
        for i in range(chunk - plen + 1):
            if np.array_equal(seq[i : i + plen], pat):
                out[p, i] = 1
    return out


def tree_reduce_ref(x):
    """Oracle for tree_reduce."""
    return jnp.sum(jnp.asarray(x, dtype=jnp.float32), dtype=jnp.float32)
