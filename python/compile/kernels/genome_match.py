"""L1 Pallas kernel: windowed genome pattern matching.

The compute hot-spot of the paper's genome-searching job (Results §Genome
Searching): given an encoded nucleotide sequence chunk and a dictionary of
short patterns (15-25 nt), find every position where a pattern matches.

Encoding: A=0, C=1, G=2, T=3, N=4 (int8).  Patterns are padded to width W
with the sentinel PAD=-1; ``lengths`` gives the true length of each pattern.
A window position ``i`` is a hit for pattern ``p`` iff
``seq[i + w] == patterns[p, w]`` for all ``w < lengths[p]``.

Because the end of the chunk is padded logically with N (which never equals a
pattern base), windows that would overrun the chunk can never match; the
caller chunks chromosomes with an overlap of W-1 so no cross-boundary hit is
lost.

TPU mapping (DESIGN.md §Hardware-Adaptation): the dictionary axis is the
grid axis — each program holds one P_BLK-sized block of the dictionary in
VMEM together with the resident sequence tile; the W-deep inner loop is a
statically unrolled VPU compare-and-accumulate (no MXU work in this kernel).
``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, and interpret-mode lowers to plain HLO which XLA:CPU fuses.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Sequence alphabet.
BASE_A, BASE_C, BASE_G, BASE_T, BASE_N = 0, 1, 2, 3, 4
#: Pattern padding sentinel (never equals any encoded base).
PAD = -1


def _match_kernel(seq_ref, pat_ref, len_ref, mask_ref, *, width: int):
    """One grid step: match one dictionary block against the whole chunk.

    seq_ref:  int8[chunk]        resident sequence tile
    pat_ref:  int8[p_blk, width] this program's dictionary block
    len_ref:  int32[p_blk]       true pattern lengths
    mask_ref: int8[p_blk, chunk] output hit mask
    """
    # Compare in int8 throughout: 4x less VPU/lane traffic than widening to
    # int32 and ~4.3x faster on XLA:CPU (EXPERIMENTS.md §Perf L1).
    seq = seq_ref[...]
    chunk = seq.shape[0]
    pats = pat_ref[...]
    lens = len_ref[...]

    acc = jnp.ones((pats.shape[0], chunk), dtype=jnp.bool_)
    # Statically unrolled over the (small) pattern width: each step compares
    # the w-shifted sequence against column w of the dictionary block.
    for w in range(width):
        # seq[i + w] for every window start i; tail padded with N so windows
        # that overrun the chunk can never match a real base.  (w can exceed
        # the chunk when width > chunk; then the whole shift is padding.)
        shifted = jnp.full((chunk,), BASE_N, dtype=jnp.int8)
        s = min(w, chunk)
        shifted = jax.lax.dynamic_update_slice(
            shifted, jax.lax.slice(seq, (s,), (chunk,)), (0,)
        )
        col = pats[:, w]  # [p_blk]
        active = w < lens  # [p_blk]; padded columns don't constrain the match
        hit_w = shifted[None, :] == col[:, None]
        acc = jnp.logical_and(acc, jnp.logical_or(~active[:, None], hit_w))
    mask_ref[...] = acc.astype(jnp.int8)


@functools.partial(jax.jit, static_argnames=("p_blk",))
def _noop(x, p_blk=0):  # pragma: no cover - placeholder to keep jit import hot
    return x


def make_genome_match(chunk: int, n_patterns: int, width: int, p_blk: int):
    """Build the pallas_call for a fixed problem geometry.

    The dictionary axis forms the grid (``n_patterns / p_blk`` programs); the
    sequence chunk is block-resident (index_map pins block 0 for every
    program).  Returns ``f(seq[int8 chunk], patterns[int8 P,W],
    lengths[int32 P]) -> mask[int8 P, chunk]``.
    """
    if n_patterns % p_blk != 0:
        raise ValueError(f"n_patterns={n_patterns} not divisible by p_blk={p_blk}")
    grid = (n_patterns // p_blk,)
    kernel = functools.partial(_match_kernel, width=width)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((chunk,), lambda i: (0,)),          # seq: resident
            pl.BlockSpec((p_blk, width), lambda i: (i, 0)),  # dictionary block
            pl.BlockSpec((p_blk,), lambda i: (i,)),          # lengths block
        ],
        out_specs=pl.BlockSpec((p_blk, chunk), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_patterns, chunk), jnp.int8),
        interpret=True,
    )


def genome_match(seq, patterns, lengths, *, p_blk: int = 64):
    """Convenience wrapper deriving geometry from the operand shapes."""
    n_patterns, width = patterns.shape
    fn = make_genome_match(seq.shape[0], n_patterns, width, min(p_blk, n_patterns))
    return fn(seq, patterns, lengths)
