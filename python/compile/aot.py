"""AOT bridge: lower every L2 function to HLO *text* artifacts.

HLO text (NOT ``lowered.compile()`` / serialized protos) is the interchange
format: jax >= 0.5 emits HloModuleProto with 64-bit instruction ids which the
xla crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text
parser reassigns ids and round-trips cleanly.  See /opt/xla-example/README.md.

Usage:  python -m compile.aot --out ../artifacts
Emits   artifacts/<name>.hlo.txt plus artifacts/manifest.txt with one line
per artifact:  ``name|in=<dtype>:<shape>;...|out=<dtype>:<shape>;...``
(shapes comma-separated, outputs always a tuple because we lower with
return_tuple=True).
"""

from __future__ import annotations

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def artifact_specs():
    """(name, fn, example_args) for every executable the runtime loads."""
    return [
        (
            "genome_search",
            model.genome_search_fn,
            (
                _spec((model.CHUNK,), jnp.int8),
                _spec((model.N_PATTERNS, model.WIDTH), jnp.int8),
                _spec((model.N_PATTERNS,), jnp.int32),
            ),
        ),
        (
            "reduce",
            model.reduce_fn,
            (_spec((model.REDUCE_N,), jnp.float32),),
        ),
        (
            "collate",
            model.collate_fn,
            (_spec((model.COLLATE_NODES, model.N_PATTERNS), jnp.int32),),
        ),
    ]


def _fmt_aval(aval) -> str:
    shape = "x".join(str(d) for d in aval.shape) or "scalar"
    return f"{aval.dtype}:{shape}"


def emit(out_dir: str) -> list[str]:
    os.makedirs(out_dir, exist_ok=True)
    manifest_lines = []
    for name, fn, args in artifact_specs():
        lowered = jax.jit(fn).lower(*args)
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        outs = jax.eval_shape(fn, *args)
        if not isinstance(outs, (tuple, list)):
            outs = (outs,)
        ins = ";".join(_fmt_aval(a) for a in args)
        outs_s = ";".join(_fmt_aval(o) for o in outs)
        manifest_lines.append(f"{name}|in={ins}|out={outs_s}")
        print(f"wrote {path} ({len(text)} chars)")
    with open(os.path.join(out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest_lines) + "\n")
    return manifest_lines


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts", help="artifact directory")
    args = ap.parse_args()
    emit(args.out)


if __name__ == "__main__":
    main()
