"""L2: the JAX compute graphs the rust coordinator executes per sub-job.

Two workloads from the paper:

* ``genome_search_fn`` — the genome-searching sub-job (Results §Genome
  Searching): match a dictionary block against a chromosome chunk and also
  return per-pattern hit counts so the coordinator can collate cheaply.
* ``reduce_fn`` — the parallel-summation sub-job of the empirical study
  (Figs. 8-13): tree-sum one data block.

Both call the L1 Pallas kernels so the whole sub-job lowers into a single
fused HLO module.  These functions are lowered once by aot.py; python never
runs on the request path.
"""

from __future__ import annotations

import jax.numpy as jnp

from .kernels.genome_match import make_genome_match
from .kernels.reduce_tree import make_block_reduce

# Fixed AOT geometry — rust pads operands to these shapes (see
# rust/src/runtime/artifact.rs and artifacts/manifest.txt).
CHUNK = 32_768           # chromosome chunk length (bases)
N_PATTERNS = 512         # dictionary block per executable invocation
WIDTH = 25               # max pattern length (paper: 15-25 nt)
P_BLK = 32               # pallas grid block over the dictionary axis (perf: §Perf L1 sweep)

REDUCE_N = 1 << 20       # elements per summation sub-job
REDUCE_BLK = 1 << 17     # pallas block (VMEM tile; perf: §Perf L1 sweep)


def genome_search_fn(seq, patterns, lengths):
    """Sub-job: search one dictionary block over one chunk.

    Returns ``(mask[int8 N_PATTERNS, CHUNK], counts[int32 N_PATTERNS])``.
    """
    match = make_genome_match(CHUNK, N_PATTERNS, WIDTH, P_BLK)
    mask = match(seq, patterns, lengths)
    counts = jnp.sum(mask.astype(jnp.int32), axis=1)
    return mask, counts


def reduce_fn(x):
    """Sub-job: tree-sum one block of the parallel summation."""
    partials = make_block_reduce(REDUCE_N, REDUCE_BLK)(x)
    return (jnp.sum(partials, dtype=jnp.float32),)


def collate_fn(counts):
    """Combining-node sub-job: merge per-search-node count vectors."""
    return (jnp.sum(counts, axis=0, dtype=jnp.int32),)


# Combining node merges up to this many search-node count vectors at once.
COLLATE_NODES = 16
