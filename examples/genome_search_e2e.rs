//! END-TO-END driver: the full three-layer system on a real workload.
//!
//! * L1/L2: the AOT-compiled Pallas genome-match kernel + JAX graph run via
//!   PJRT (no python anywhere in this process);
//! * L3: the coordinator plays the Placentia genome experiment — worker
//!   threads are the cluster's search nodes, the main thread the combining
//!   node; mid-run a node failure is predicted and the hybrid approach
//!   relocates its work, exactly like the paper's validation study.
//!
//! Reports throughput, the reinstate time, the Fig. 14 hit sample and the
//! Table-1-style penalty accounting. Run after `make artifacts`:
//!
//! ```sh
//! cargo run --release --example genome_search_e2e [bases] [patterns]
//! ```

use std::time::Instant;

use biomaft::cluster::{preset, ClusterPreset};
use biomaft::coordinator::ftmanager::Strategy;
use biomaft::coordinator::run::{measure_reinstate, ExperimentCfg};
use biomaft::genome::{self, encode::PAD, Strand};
use biomaft::net::NodeId;
use biomaft::runtime::client::geom;
use biomaft::runtime::{Manifest, Runtime, SearchPool, SearchTask};
use biomaft::sim::Rng;
use biomaft::util::fmt::{hms, hms_ms};

fn main() -> anyhow::Result<()> {
    let bases: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(400_000);
    let n_patterns: usize =
        std::env::args().nth(2).and_then(|s| s.parse().ok()).unwrap_or(512);
    let dir = Manifest::default_dir();
    anyhow::ensure!(
        dir.join("manifest.txt").exists(),
        "no artifacts at {dir:?} — run `make artifacts` first"
    );

    println!("== biomaft end-to-end genome search (paper validation study) ==");
    println!("genome: {bases} synthetic bases over 7 chromosomes; dictionary: {n_patterns} patterns (15-25 nt)\n");

    // --- the job: 3 search nodes + 1 combining node (paper: Z = 4) ---
    let n_search_nodes = 3;
    let seed = 7u64;
    let mut rng = Rng::new(seed);
    let g = genome::synthesize_genome(bases, seed);
    let spec = genome::PatternSpec { n_patterns, ..Default::default() };
    let dict = genome::PatternDict::build(&spec, &g, &mut rng);
    let chrom_names: Vec<&'static str> = g.iter().map(|c| c.name).collect();

    // --- build the task list: chunks x dictionary blocks x strands ---
    let mut tasks = Vec::new();
    for strand in [Strand::Forward, Strand::Reverse] {
        let eff = match strand {
            Strand::Forward => dict.clone(),
            Strand::Reverse => dict.revcomp(),
        };
        for (ci, chr) in g.iter().enumerate() {
            for (chunk_start, mut seq) in chr.chunks(geom::CHUNK, spec.width - 1) {
                seq.resize(geom::CHUNK, PAD);
                let mut base = 0;
                while base < dict.n {
                    let (patterns, lengths) = eff.block(base, geom::N_PATTERNS);
                    tasks.push((strand, ci, chunk_start, chr.seq.len(), seq.clone(), patterns, lengths, base));
                    base += geom::N_PATTERNS;
                }
            }
        }
    }
    println!("task list: {} (chunk x dict-block x strand) units for {n_search_nodes} search nodes", tasks.len());

    // --- run the search across the worker pool ---
    let t0 = Instant::now();
    let mut pool = SearchPool::spawn(n_search_nodes, dir.clone());
    for (tid, (strand, ci, chunk_start, chrom_len, seq, patterns, lengths, base)) in
        tasks.iter().enumerate()
    {
        pool.submit(SearchTask {
            task_id: tid,
            chrom_idx: *ci,
            chunk_start: *chunk_start,
            chrom_len: *chrom_len,
            seq: seq.clone(),
            patterns: patterns.clone(),
            lengths: lengths.clone(),
            pattern_base: *base,
            n_real: dict.n - base,
            reverse: matches!(strand, Strand::Reverse),
        })?;
    }

    // --- mid-run: a failure is predicted on search node 1 (simulated).
    // The hybrid approach negotiates and relocates; we measure the paper's
    // reinstate time on the calibrated Placentia model alongside the real
    // compute (virtual FT time vs wall compute time are reported separately).
    let cfg = ExperimentCfg { trials: 30, ..ExperimentCfg::table1(preset(ClusterPreset::Placentia)) };
    let mut ft_rng = Rng::new(99);
    let reinstate = measure_reinstate(Strategy::Hybrid, &cfg, &mut ft_rng);
    let predicted_node = NodeId(1);

    // --- combining node: collate masks into hits, merge counts via the
    // AOT `collate` executable ---
    let combiner = Runtime::load(&dir)?;
    let mut hits = Vec::new();
    let mut per_worker = vec![0usize; n_search_nodes];
    let mut count_rows: Vec<Vec<i32>> = Vec::new();
    for _ in 0..tasks.len() {
        let r = pool.recv()?;
        per_worker[r.worker] += 1;
        let strand = if r.task.reverse { Strand::Reverse } else { Strand::Forward };
        genome::hits::collate_hits(
            &r.mask,
            geom::N_PATTERNS,
            geom::CHUNK,
            r.task.chunk_start,
            r.task.chrom_len,
            r.task.pattern_base,
            &r.task.lengths,
            r.task.n_real,
            r.task.chrom_idx,
            strand,
            &mut hits,
        );
        count_rows.push(r.counts);
    }
    pool.shutdown();
    genome::hits::dedup_hits(&mut hits);
    let wall = t0.elapsed().as_secs_f64();

    // merge count rows through the collate executable (batches of 16)
    let mut merged = vec![0i32; geom::N_PATTERNS];
    for batch in count_rows.chunks(geom::COLLATE_NODES) {
        let mut flat = vec![0i32; geom::COLLATE_NODES * geom::N_PATTERNS];
        for (i, row) in batch.iter().enumerate() {
            flat[i * geom::N_PATTERNS..(i + 1) * geom::N_PATTERNS].copy_from_slice(row);
        }
        let part = combiner.collate(&flat)?;
        for (m, p) in merged.iter_mut().zip(part) {
            *m += p;
        }
    }
    let total_counts: i64 = merged.iter().map(|&c| c as i64).sum();

    // --- verify a subsample against the pure-rust oracle ---
    let mut oracle = genome::search_naive(&g, &dict, Strand::Forward);
    oracle.extend(genome::search_naive(&g, &dict, Strand::Reverse));
    genome::hits::dedup_hits(&mut oracle);
    anyhow::ensure!(hits == oracle, "PJRT hits disagree with the pure-rust oracle");

    // --- report ---
    let total_windows = tasks.len() as f64 * geom::CHUNK as f64 * geom::N_PATTERNS as f64;
    println!("\nsearch complete in {wall:.2}s wall ({:.2e} window-comparisons/s)", total_windows / wall);
    println!("worker task distribution: {per_worker:?}");
    println!("hits: {} (oracle-verified), kernel count column total: {total_counts}", hits.len());
    println!("\n-- predicted failure on search node {predicted_node:?} (hybrid FT) --");
    println!(
        "reinstate time: mean {} over {} trials (paper: 0.38 s core / 0.47 s agent at Z=4)",
        hms_ms(reinstate.mean),
        reinstate.n
    );
    let overhead = Strategy::Hybrid.ma_overhead_s(&cfg.cluster.costs, cfg.z, cfg.data_kb);
    let predict = cfg.cluster.costs.predict.predict_time_s;
    println!(
        "per-failure cost: predict {} + reinstate {} + overhead {} = {}",
        hms(predict),
        hms_ms(reinstate.mean),
        hms(overhead),
        hms(predict + reinstate.mean + overhead)
    );
    println!(
        "1 h job with one failure: {} (paper: 01:05:08; +{:.0}% vs no-failure)",
        hms(3600.0 + predict + reinstate.mean + overhead),
        100.0 * (predict + reinstate.mean + overhead) / 3600.0
    );

    println!("\n-- Fig. 14 sample output --");
    println!("{}", genome::format_hits(&hits, &chrom_names, 12));
    Ok(())
}
