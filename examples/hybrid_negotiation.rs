//! Approach 3 deep-dive: sweep the (Z, S_d, S_p) space, show which rule
//! fires where, and verify the hybrid never picks a catastrophically wrong
//! mover.
//!
//! ```sh
//! cargo run --release --example hybrid_negotiation
//! ```

use biomaft::cluster::{preset, ClusterPreset};
use biomaft::hybrid::negotiate::{hybrid_reinstate_s, negotiate};
use biomaft::hybrid::rules::{decide, Mover, RuleInputs};
use biomaft::net::NodeId;
use biomaft::util::fmt::kb_pow2;

fn main() {
    let costs = preset(ClusterPreset::Placentia).costs;
    println!("decision map (Placentia):");
    println!("{:<6} {:>12} {:>12}  {:>8} {:>9} {:>9}  rule", "Z", "S_d", "S_p", "winner", "agent(s)", "core(s)");
    let mut conflicts = 0;
    let mut total = 0;
    for z in [3usize, 8, 10, 11, 20, 40, 63] {
        for exp in [19u32, 22, 24, 25, 28, 31] {
            let kb = 1u64 << exp;
            let inp = RuleInputs { z, data_kb: kb, proc_kb: kb };
            let log = negotiate(&costs, inp, NodeId(1), NodeId(2));
            total += 1;
            if log.conflicted {
                conflicts += 1;
            }
            let (mover, rule) = decide(inp);
            println!(
                "{z:<6} {:>12} {:>12}  {:>8} {:>9.3} {:>9.3}  {rule:?}",
                kb_pow2(kb),
                kb_pow2(kb),
                match mover {
                    Mover::Agent => "agent",
                    Mover::Core => "core",
                },
                log.agent_estimate_s,
                log.core_estimate_s,
            );
            // sanity: hybrid within the best-of envelope + negotiation
            let h = hybrid_reinstate_s(&costs, inp);
            let worst = log.agent_estimate_s.max(log.core_estimate_s);
            assert!(h <= worst + 1e-3);
        }
    }
    println!("\n{conflicts}/{total} scenarios had conflicting proposals (resolved by rules)");
}
