//! Fleet demo: a continuous multi-job cluster lifetime — Poisson job
//! arrivals, node churn with repair, and per-strategy fault tolerance —
//! comparing the proactive hybrid approach against reactive checkpointing
//! on the same seeded cluster story.
//!
//! ```sh
//! cargo run --release --example fleet_demo [seed]
//! ```

use biomaft::checkpoint::CheckpointStrategy;
use biomaft::coordinator::ftmanager::Strategy;
use biomaft::scenario::{run_fleet, FleetOutcome, FleetSpec};

fn report(label: &str, o: &FleetOutcome) {
    println!("-- {label} --");
    println!(
        "  jobs: {} arrived, {} completed, {} still queued at the horizon",
        o.jobs_arrived, o.jobs_completed, o.jobs_waiting
    );
    println!(
        "  slowdown: mean {:.3}, p95 {:.3}  |  goodput {:.3}  |  utilization {:.3}",
        o.mean_slowdown, o.p95_slowdown, o.goodput_ratio, o.utilization
    );
    println!(
        "  migrations {} (peak {} in flight)  rollbacks {} (peak {} concurrent recoveries)",
        o.migrations, o.peak_concurrent_migrations, o.rollbacks, o.peak_concurrent_recoveries
    );
    println!("  {} sub-jobs lost to failures and rolled back, {} DES events\n", o.subs_lost, o.events);
}

fn main() {
    let seed: u64 = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(2014);
    let (nodes, arrival_per_h, churn_per_node_h) = (64, 10.0, 0.5);
    println!(
        "fleet: {nodes} nodes x 2 slots, {arrival_per_h} jobs/h, churn {churn_per_node_h}/node/h, 4 h horizon, seed {seed}\n"
    );

    // The proactive multi-agent fleet: predictions race failures, agents
    // migrate along the ring, and only the unpredicted tail rolls back.
    let hybrid = FleetSpec::placentia_fleet(Strategy::Hybrid, nodes, arrival_per_h, churn_per_node_h);
    report("hybrid intelligence (proactive)", &run_fleet(&hybrid, seed));

    // The reactive baseline: no prediction-driven migration; every failure
    // rolls back through the shared checkpoint server (2 streams), so
    // concurrent recoveries queue on its bandwidth.
    let mut ckpt = FleetSpec::placentia_fleet(
        Strategy::Checkpoint(CheckpointStrategy::CentralSingle),
        nodes,
        arrival_per_h,
        churn_per_node_h,
    );
    ckpt.job.predictable_frac = 0.0;
    report("central checkpointing (reactive)", &run_fleet(&ckpt, seed));

    println!(
        "Same cluster story, two recovery disciplines: the proactive fleet's slowdown\n\
         comes from sub-second migrations, the reactive fleet's from checkpoint\n\
         rollbacks queueing on the server — the paper's 90%-vs-10% headline at fleet\n\
         scale (see EXPERIMENTS.md \u{00a7}Fleet and `biomaft experiment fleet`)."
    );
}
