//! Regenerate Tables 1 and 2: checkpointing baselines vs the multi-agent
//! approaches, plus the headline penalty percentages.
//!
//! ```sh
//! cargo run --release --example checkpoint_comparison
//! ```

use biomaft::coordinator::ftmanager::Strategy;
use biomaft::experiments::tables;
use biomaft::util::fmt::hms;

fn main() {
    let (t1, rows1) = tables::table1();
    println!("{}", t1.render());

    // the paper's headline: checkpointing adds ~90 %, multi-agent ~10 %
    println!("added time vs failure-free execution (one random failure/hour):");
    for r in &rows1 {
        let penalty = 100.0 * (r.total_one_random_s - r.total_nofail_s) / r.total_nofail_s;
        println!("  {:<48} +{penalty:.0}%", r.strategy.name());
    }
    println!();

    let (t2, rows2) = tables::table2();
    println!("{}", t2.render());

    let cold = rows2.iter().find(|r| r.strategy == Strategy::ColdRestart).unwrap();
    println!(
        "cold restart, five random failures/hour: {} ({}x the failure-free 5 h)",
        hms(cold.total_five_random_s),
        (cold.total_five_random_s / cold.total_nofail_s).round()
    );
}
