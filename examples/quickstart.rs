//! Quickstart: predict a core failure and watch the three multi-agent
//! approaches relocate the sub-job.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use biomaft::cluster::{preset, ClusterPreset};
use biomaft::agentft::simulate_agent_migration;
use biomaft::coreft::simulate_core_migration;
use biomaft::hybrid::negotiate::{hybrid_reinstate_s, negotiate};
use biomaft::hybrid::rules::RuleInputs;
use biomaft::net::NodeId;
use biomaft::sim::Rng;
use biomaft::util::fmt::kb_pow2;

fn main() -> anyhow::Result<()> {
    let cluster = preset(ClusterPreset::Placentia);
    let costs = cluster.costs;
    let mut rng = Rng::new(42);

    // The genome experiment's configuration: three searchers + combiner,
    // 512 MB of data per node.
    let (z, data_kb, proc_kb) = (4usize, 1u64 << 19, 1u64 << 19);
    println!(
        "cluster: {}  |  Z = {z}, S_d = {}, S_p = {}\n",
        cluster.name,
        kb_pow2(data_kb),
        kb_pow2(proc_kb)
    );

    // The agent's vicinity: three adjacent cores, one itself predicted to
    // fail (the paper's failure scenario).
    let adjacent = vec![(NodeId(1), false), (NodeId(2), true), (NodeId(3), false)];

    println!("-- Approach 1: agent intelligence (Fig. 3 sequence) --");
    let a = simulate_agent_migration(&costs.agent, z, data_kb, proc_kb, &adjacent, &mut rng, 0.02)
        .expect("a healthy adjacent core exists");
    for s in &a.steps {
        println!("  {:<22} t={:.3}s  (+{:.3}s)", s.step, s.start_s, s.dur_s);
    }
    println!("  moved to node {:?}; reinstated in {:.3}s\n", a.target, a.reinstate_s);

    println!("-- Approach 2: core intelligence (Fig. 5 sequence) --");
    let c = simulate_core_migration(&costs.core, z, data_kb, proc_kb, &adjacent, &mut rng, 0.02)
        .expect("a healthy adjacent core exists");
    for s in &c.steps {
        println!("  {:<22} t={:.3}s  (+{:.3}s)", s.step, s.start_s, s.dur_s);
    }
    println!("  migrated to node {:?}; reinstated in {:.3}s\n", c.target, c.reinstate_s);

    println!("-- Approach 3: hybrid (Fig. 6 negotiation) --");
    let inp = RuleInputs { z, data_kb, proc_kb };
    let log = negotiate(&costs, inp, NodeId(1), NodeId(3));
    println!(
        "  agent proposes node {:?} (est {:.3}s); core proposes node {:?} (est {:.3}s)",
        log.agent_target, log.agent_estimate_s, log.core_target, log.core_estimate_s
    );
    println!(
        "  {:?} fired -> {:?} moves the sub-job to node {:?} ({}conflict)",
        log.rule,
        log.winner,
        log.chosen_target,
        if log.conflicted { "" } else { "no " }
    );
    println!("  hybrid reinstate: {:.3}s", hybrid_reinstate_s(&costs, inp));

    println!("\nnever migrated onto node 2 (predicted to fail): {}",
        a.target != NodeId(2) && c.target != NodeId(2));
    Ok(())
}
