//! The empirical study (Figs. 8-13): parallel-reduction sub-jobs on all
//! four clusters, sweeping dependencies, data size and process size —
//! prints the CSV series behind every figure.
//!
//! ```sh
//! cargo run --release --example reduction_study [trials]
//! ```

use biomaft::experiments::figures;
use biomaft::job::DepGraph;

fn main() {
    let trials: usize =
        std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(30);
    let seed = 2014;

    // The workload: a parallel summation tree (Fig. 7). Show how Z maps to
    // the tree's fan-in, as used by the sweeps.
    println!("parallel reduction trees (Fig. 7): Z = fan_in + 1 at internal nodes");
    for fan_in in [2usize, 9, 62] {
        let g = DepGraph::reduction_tree(fan_in * 2, fan_in);
        let internal = biomaft::net::message::SubJobId(fan_in * 2);
        println!("  fan-in {fan_in:>2}: {} sub-jobs, internal Z = {}", g.len(), g.z(internal));
    }
    println!();

    for (name, fig) in [
        ("fig8", figures::fig8 as fn(usize, u64) -> biomaft::metrics::Series),
        ("fig9", figures::fig9),
        ("fig10", figures::fig10),
        ("fig11", figures::fig11),
        ("fig12", figures::fig12),
        ("fig13", figures::fig13),
    ] {
        let s = fig(trials, seed);
        println!("{}", s.render());
        println!("# CSV ({name})\n{}", s.to_csv());
    }
}
