//! Genome search benchmark: naive oracle vs the packed multi-pattern
//! engine, serial and parallel, at the paper's dictionary scale (5000
//! patterns of 15-25 nt; Results §Genome Searching).
//!
//! Emits a JSON baseline (BENCH_genome.json schema) so the search-path
//! perf trajectory can be tracked across PRs:
//!
//! ```text
//! cd rust && BIOMAFT_BENCH_JSON=../BENCH_genome.json \
//!     cargo bench --bench genome
//! ```
//!
//! Before overwriting, the previous baseline at the target path is read
//! back and compared — and the bench shouts if the committed file is still
//! a placeholder (`"generated": false`) rather than honest measurements.
//!
//! The run also *asserts* the engine's oracle contract — engine hits ==
//! naive hits byte for byte, and thread-count independence — which is what
//! the CI genome bench-smoke step relies on.
//!
//! Environment knobs: `BIOMAFT_BENCH_BASES` (default 2_000_000),
//! `BIOMAFT_BENCH_PATTERNS` (default 5000), `BIOMAFT_BENCH_JSON` (path to
//! write; stdout when unset).

use std::time::Instant;

use biomaft::bench::compare_to_baseline;
use biomaft::genome::{self, Strand};
use biomaft::scenario::default_threads;
use biomaft::sim::Rng;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|s| s.parse().ok()).unwrap_or(default)
}

fn main() {
    let bases = env_usize("BIOMAFT_BENCH_BASES", 2_000_000);
    let n_patterns = env_usize("BIOMAFT_BENCH_PATTERNS", 5000);
    let seed = 2014u64;
    let cores = default_threads();
    println!(
        "=== bench suite: genome (multi-pattern search, {bases} bases x {n_patterns} patterns \
         x 2 strands, {cores} cores) ==="
    );

    let g = genome::synthesize_genome(bases, seed);
    let mut rng = Rng::new(seed ^ 0xf19);
    let spec = genome::PatternSpec { n_patterns, ..Default::default() };
    let dict = genome::PatternDict::build(&spec, &g, &mut rng);
    let total_bases: usize = g.iter().map(|c| c.seq.len()).sum();
    // Work unit: candidate (base, pattern) windows per full two-strand
    // search — what the naive scan actually visits.
    let work = total_bases as f64 * n_patterns as f64 * 2.0;

    let t0 = Instant::now();
    let mut naive = genome::search_naive(&g, &dict, Strand::Forward);
    naive.extend(genome::search_naive(&g, &dict, Strand::Reverse));
    genome::hits::dedup_hits(&mut naive);
    let naive_s = t0.elapsed().as_secs_f64();
    println!(
        "naive:        {naive_s:>10.3} s  ({:>12.3e} base·patterns/s, {} hits)",
        work / naive_s,
        naive.len()
    );

    let t0 = Instant::now();
    let engine1 = genome::search_engine_both(&g, &dict, 1);
    let engine1_s = t0.elapsed().as_secs_f64();
    println!(
        "engine x1:    {engine1_s:>10.3} s  ({:>12.3e} base·patterns/s)",
        work / engine1_s
    );

    let t0 = Instant::now();
    let engine_par = genome::search_engine_both(&g, &dict, 0);
    let engine_par_s = t0.elapsed().as_secs_f64();
    println!(
        "engine x{cores:<4} {engine_par_s:>10.3} s  ({:>12.3e} base·patterns/s)",
        work / engine_par_s
    );

    assert_eq!(
        engine1, engine_par,
        "engine output must be independent of thread count"
    );
    assert_eq!(engine1, naive, "engine must equal the naive oracle hit-for-hit");

    let speedup1 = naive_s / engine1_s.max(1e-12);
    let speedup_par = naive_s / engine_par_s.max(1e-12);
    println!("speedup: {speedup1:>8.2}x serial, {speedup_par:>8.2}x on {cores} cores");

    let json_path = std::env::var("BIOMAFT_BENCH_JSON").ok();
    if let Some(path) = &json_path {
        compare_to_baseline(
            path,
            "engine_par_bp_per_s",
            "base·patterns/s (parallel engine)",
            work / engine_par_s,
        );
    }

    let json = format!(
        "{{\n  \"bench\": \"genome_search\",\n  \"generated\": true,\n  \"machine_cores\": {cores},\n  \"bases\": {total_bases},\n  \"patterns\": {n_patterns},\n  \"strands\": 2,\n  \"hits\": {},\n  \"naive_s\": {naive_s:.4},\n  \"naive_bp_per_s\": {:.1},\n  \"engine1_s\": {engine1_s:.4},\n  \"engine1_bp_per_s\": {:.1},\n  \"engine_par_s\": {engine_par_s:.4},\n  \"engine_par_bp_per_s\": {:.1},\n  \"engine_par_threads\": {cores},\n  \"speedup_engine1_vs_naive\": {speedup1:.2},\n  \"speedup_par_vs_naive\": {speedup_par:.2}\n}}\n",
        naive.len(),
        work / naive_s,
        work / engine1_s,
        work / engine_par_s,
    );
    match json_path {
        Some(path) => {
            std::fs::write(&path, &json).expect("write bench json");
            println!("wrote {path}");
        }
        None => println!("{json}"),
    }
}
