//! Fleet benchmark: continuous multi-job cluster lifetimes through the
//! fused sweep executor, serial vs one thread per core.
//!
//! Emits a JSON baseline (BENCH_fleet.json schema):
//!
//! ```text
//! cd rust && BIOMAFT_BENCH_JSON=../BENCH_fleet.json \
//!     cargo bench --bench fleet
//! ```
//!
//! The grid is the `fleet` figure's shape — (strategy × arrival rate)
//! cells on a 48-node ring under churn — at `BIOMAFT_BENCH_TRIALS`
//! cluster-lifetime trials per cell (default 64). Every run is asserted
//! byte-identical between 1 thread and one per core, so the bench doubles
//! as the CI smoke for the fleet determinism contract.

use biomaft::bench::compare_to_baseline;
use biomaft::checkpoint::CheckpointStrategy;
use biomaft::coordinator::ftmanager::Strategy;
use biomaft::metrics::Summary;
use biomaft::scenario::{
    default_threads, run_sweep, CellSpec, FleetMetric, FleetSpec, SweepSpec,
};
use std::time::Instant;

const SEED: u64 = 2014;

fn grid() -> Vec<CellSpec> {
    let mut cells = Vec::new();
    let strategies = [
        Strategy::Hybrid,
        Strategy::Agent,
        Strategy::Checkpoint(CheckpointStrategy::CentralSingle),
    ];
    for (si, &strategy) in strategies.iter().enumerate() {
        for (ai, arrival) in [4.0, 8.0, 16.0].into_iter().enumerate() {
            let mut spec = FleetSpec::placentia_fleet(strategy, 48, arrival, 0.5);
            if !strategy.is_multi_agent() {
                spec.job.predictable_frac = 0.0;
            }
            // goodput is defined (0) even for a lifetime that completes no
            // job, so the serial≡parallel assert below is NaN-free
            cells.push(CellSpec::fleet(
                spec,
                FleetMetric::Goodput,
                SEED ^ ((si as u64) << 40) ^ ((ai as u64) << 32),
            ));
        }
    }
    cells
}

fn fused(cells: &[CellSpec], trials: usize, threads: usize) -> Vec<Summary> {
    run_sweep(&SweepSpec { threads: Some(threads), ..SweepSpec::new(cells.to_vec(), trials) })
}

fn time<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let v = f();
    (v, t0.elapsed().as_secs_f64())
}

fn main() {
    let cores = default_threads();
    let trials: usize = std::env::var("BIOMAFT_BENCH_TRIALS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(64);
    let cells = grid();
    println!(
        "=== bench suite: fleet ({} cells x {trials} cluster lifetimes, {cores} cores) ===",
        cells.len()
    );
    let (serial, serial_s) = time(|| fused(&cells, trials, 1));
    println!("fleet x1:       {serial_s:>10.4} s");
    let (par, par_s) = time(|| fused(&cells, trials, cores));
    println!("fleet x{cores}:       {par_s:>10.4} s");
    assert_eq!(serial, par, "fleet sweep must be thread-count independent");
    let speedup = serial_s / par_s.max(1e-12);
    let lifetimes_per_s = (cells.len() * trials) as f64 / par_s.max(1e-12);
    println!("speedup x{cores}: {speedup:.2}x  ({lifetimes_per_s:.1} cluster lifetimes/s)");

    let json_path = std::env::var("BIOMAFT_BENCH_JSON").ok();
    if let Some(path) = &json_path {
        compare_to_baseline(path, "fleet_par_s", "fleet parallel s", par_s);
    }
    let json = format!(
        "{{\n  \"bench\": \"fleet\",\n  \"generated\": true,\n  \"machine_cores\": {cores},\n  \"cells\": {},\n  \"trials_per_cell\": {trials},\n  \"fleet_serial_s\": {serial_s:.4},\n  \"fleet_par_s\": {par_s:.4},\n  \"fleet_par_threads\": {cores},\n  \"speedup\": {speedup:.2},\n  \"lifetimes_per_s\": {lifetimes_per_s:.1}\n}}\n",
        cells.len(),
    );
    match json_path {
        Some(path) => {
            std::fs::write(&path, &json).expect("write bench json");
            println!("wrote {path}");
        }
        None => println!("{json}"),
    }
}
