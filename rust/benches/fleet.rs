//! Fleet benchmark: continuous multi-job cluster lifetimes through the
//! fused sweep executor, serial vs one thread per core.
//!
//! Emits a JSON baseline (BENCH_fleet.json schema):
//!
//! ```text
//! cd rust && BIOMAFT_BENCH_JSON=../BENCH_fleet.json \
//!     cargo bench --bench fleet
//! ```
//!
//! The grid is the `fleet` figure's shape — (strategy × arrival rate)
//! cells on a 48-node ring under churn — at `BIOMAFT_BENCH_TRIALS`
//! cluster-lifetime trials per cell (default 64). Every run is asserted
//! byte-identical between 1 thread and one per core, so the bench doubles
//! as the CI smoke for the fleet determinism contract.
//!
//! The second half is the ROADMAP scale target: **one** 10k-node,
//! 1M-arrival lifetime (`FleetSpec::scale_fleet` sizing, ~90 % load)
//! timed end to end through the timer-wheel queue, placement index and
//! job slab. `BIOMAFT_BENCH_FLEET_NODES` / `BIOMAFT_BENCH_FLEET_ARRIVALS`
//! resize it in both directions — CI smokes at 1k nodes × 50k arrivals,
//! and `BIOMAFT_BENCH_FLEET_NODES=100000 BIOMAFT_BENCH_FLEET_ARRIVALS=10000000`
//! is the 100k-node / 10M-job lifetime of EXPERIMENTS.md §fleet-scale. At
//! smoke sizes (≤ 200k arrivals) the lifetime is run twice and asserted
//! bit-identical.
//!
//! The same lifetime is then re-run sharded (`BIOMAFT_BENCH_FLEET_CELLS`
//! cells, default 8) and asserted **byte-identical to the unsharded
//! run** at every size — the sharded-cells determinism contract
//! (DESIGN.md §Sharded cells) smoked at bench scale.

use biomaft::bench::compare_to_baseline;
use biomaft::checkpoint::CheckpointStrategy;
use biomaft::coordinator::ftmanager::Strategy;
use biomaft::metrics::Summary;
use biomaft::scenario::{
    default_threads, run_fleet, run_sweep, CellSpec, FleetMetric, FleetSpec, SweepSpec,
};
use std::num::NonZeroUsize;
use std::time::Instant;

const SEED: u64 = 2014;

fn grid() -> Vec<CellSpec> {
    let mut cells = Vec::new();
    let strategies = [
        Strategy::Hybrid,
        Strategy::Agent,
        Strategy::Checkpoint(CheckpointStrategy::CentralSingle),
    ];
    for (si, &strategy) in strategies.iter().enumerate() {
        for (ai, arrival) in [4.0, 8.0, 16.0].into_iter().enumerate() {
            let mut spec = FleetSpec::placentia_fleet(strategy, 48, arrival, 0.5);
            if !strategy.is_multi_agent() {
                spec.job.predictable_frac = 0.0;
            }
            // goodput is defined (0) even for a lifetime that completes no
            // job, so the serial≡parallel assert below is NaN-free
            cells.push(CellSpec::fleet(
                spec,
                FleetMetric::Goodput,
                SEED ^ ((si as u64) << 40) ^ ((ai as u64) << 32),
            ));
        }
    }
    cells
}

fn fused(cells: &[CellSpec], trials: usize, threads: usize) -> Vec<Summary> {
    run_sweep(&SweepSpec { threads: Some(threads), ..SweepSpec::new(cells.to_vec(), trials) })
}

fn time<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let v = f();
    (v, t0.elapsed().as_secs_f64())
}

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|s| s.parse().ok()).unwrap_or(default)
}

fn main() {
    let cores = default_threads();
    let trials: usize = env_usize("BIOMAFT_BENCH_TRIALS", 64);
    let cells = grid();
    println!(
        "=== bench suite: fleet ({} cells x {trials} cluster lifetimes, {cores} cores) ===",
        cells.len()
    );
    let (serial, serial_s) = time(|| fused(&cells, trials, 1));
    println!("fleet x1:       {serial_s:>10.4} s");
    let (par, par_s) = time(|| fused(&cells, trials, cores));
    println!("fleet x{cores}:       {par_s:>10.4} s");
    assert_eq!(serial, par, "fleet sweep must be thread-count independent");
    let speedup = serial_s / par_s.max(1e-12);
    let lifetimes_per_s = (cells.len() * trials) as f64 / par_s.max(1e-12);
    println!("speedup x{cores}: {speedup:.2}x  ({lifetimes_per_s:.1} cluster lifetimes/s)");

    // --- scale target: one lifetime at 10k nodes / 1M arrivals ---------
    let scale_nodes = env_usize("BIOMAFT_BENCH_FLEET_NODES", 10_000);
    let scale_arrivals = env_usize("BIOMAFT_BENCH_FLEET_ARRIVALS", 1_000_000);
    let scale_spec = FleetSpec::scale_fleet(Strategy::Hybrid, scale_nodes, scale_arrivals, 0.1);
    println!(
        "=== bench: fleet-scale (one lifetime: {scale_nodes} nodes, ~{scale_arrivals} arrivals, \
         horizon {:.1} h) ===",
        scale_spec.horizon_s / 3600.0
    );
    let (scale, scale_s) = time(|| run_fleet(&scale_spec, SEED));
    let scale_events_per_s = scale.events as f64 / scale_s.max(1e-12);
    println!(
        "fleet-scale:    {scale_s:>10.4} s  ({scale_events_per_s:.0} events/s; {} arrived, \
         {} completed, peak {} live jobs)",
        scale.jobs_arrived, scale.jobs_completed, scale.peak_live_jobs
    );
    // The full-size lifetime is single-pass (it is the wall-clock
    // headline); at smoke sizes the run doubles as a determinism check.
    if scale_arrivals <= 200_000 {
        let (again, _) = time(|| run_fleet(&scale_spec, SEED));
        assert_eq!(scale.events, again.events, "fleet-scale lifetime must be deterministic");
        assert_eq!(scale.jobs_arrived, again.jobs_arrived);
        assert_eq!(scale.jobs_completed, again.jobs_completed);
        assert_eq!(scale.mean_slowdown.to_bits(), again.mean_slowdown.to_bits());
        assert_eq!(scale.goodput_ratio.to_bits(), again.goodput_ratio.to_bits());
        println!("fleet-scale determinism re-run: identical");
    }

    // --- sharded cells: the same lifetime, cells > 1 -------------------
    // Timed as its own headline, and asserted byte-identical to the
    // unsharded run at every size: the cell count is a performance knob,
    // never a semantics knob.
    let shard_cells = env_usize("BIOMAFT_BENCH_FLEET_CELLS", 8).max(1);
    let mut shard_spec = scale_spec.clone();
    shard_spec.cells = NonZeroUsize::new(shard_cells).expect("max(1) above");
    let (shard, shard_s) = time(|| run_fleet(&shard_spec, SEED));
    let shard_events_per_s = shard.events as f64 / shard_s.max(1e-12);
    println!(
        "fleet-shard:    {shard_s:>10.4} s  ({shard_events_per_s:.0} events/s across \
         {shard_cells} cells)"
    );
    assert_eq!(scale.events, shard.events, "sharded lifetime must be byte-identical");
    assert_eq!(scale.jobs_arrived, shard.jobs_arrived);
    assert_eq!(scale.jobs_completed, shard.jobs_completed);
    assert_eq!(scale.peak_live_jobs, shard.peak_live_jobs);
    assert_eq!(scale.mean_slowdown.to_bits(), shard.mean_slowdown.to_bits());
    assert_eq!(scale.goodput_ratio.to_bits(), shard.goodput_ratio.to_bits());
    assert_eq!(scale.utilization.to_bits(), shard.utilization.to_bits());
    println!("fleet-shard x{shard_cells} cells vs x1: byte-identical");

    let json_path = std::env::var("BIOMAFT_BENCH_JSON").ok();
    if let Some(path) = &json_path {
        compare_to_baseline(path, "fleet_par_s", "fleet parallel s", par_s);
        compare_to_baseline(path, "fleet_scale_s", "fleet-scale lifetime s", scale_s);
        compare_to_baseline(path, "fleet_shard_s", "fleet-shard lifetime s", shard_s);
    }
    let json = format!(
        "{{\n  \"bench\": \"fleet\",\n  \"generated\": true,\n  \"machine_cores\": {cores},\n  \"cells\": {},\n  \"trials_per_cell\": {trials},\n  \"fleet_serial_s\": {serial_s:.4},\n  \"fleet_par_s\": {par_s:.4},\n  \"fleet_par_threads\": {cores},\n  \"speedup\": {speedup:.2},\n  \"lifetimes_per_s\": {lifetimes_per_s:.1},\n  \"fleet_scale_nodes\": {scale_nodes},\n  \"fleet_scale_arrivals\": {scale_arrivals},\n  \"fleet_scale_s\": {scale_s:.4},\n  \"fleet_scale_events\": {},\n  \"fleet_scale_events_per_s\": {scale_events_per_s:.0},\n  \"fleet_shard_cells\": {shard_cells},\n  \"fleet_shard_s\": {shard_s:.4},\n  \"fleet_shard_events_per_s\": {shard_events_per_s:.0}\n}}\n",
        cells.len(),
        scale.events,
    );
    match json_path {
        Some(path) => {
            std::fs::write(&path, &json).expect("write bench json");
            println!("wrote {path}");
        }
        None => println!("{json}"),
    }
}
