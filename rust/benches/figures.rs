//! Benches regenerating Figs. 8-13 (one bench per figure) — measures the
//! cost of the full sweep at reduced trial counts plus single-point episode
//! costs.

use biomaft::bench::Suite;
use biomaft::experiments::figures;

fn main() {
    std::env::set_var("BIOMAFT_BENCH_SAMPLES", std::env::var("BIOMAFT_BENCH_SAMPLES").unwrap_or_else(|_| "10".into()));
    let mut s = Suite::new("figures (Figs. 8-13 regeneration)");
    let trials = 8;
    s.bench("fig8_deps_agent_sweep", || figures::fig8(trials, 1));
    s.bench("fig9_deps_core_sweep", || figures::fig9(trials, 2));
    s.bench("fig10_datasize_agent_sweep", || figures::fig10(trials, 3));
    s.bench("fig11_datasize_core_sweep", || figures::fig11(trials, 4));
    s.bench("fig12_procsize_agent_sweep", || figures::fig12(trials, 5));
    s.bench("fig13_procsize_core_sweep", || figures::fig13(trials, 6));
    s.finish();
}
