//! PJRT request-path benchmarks: executable invocation latency and
//! throughput for each AOT artifact (skipped when artifacts are absent).

use biomaft::bench::Suite;
use biomaft::runtime::client::geom;
use biomaft::runtime::{Manifest, Runtime};
use biomaft::sim::Rng;

fn main() {
    std::env::set_var("BIOMAFT_BENCH_SAMPLES", std::env::var("BIOMAFT_BENCH_SAMPLES").unwrap_or_else(|_| "10".into()));
    if !cfg!(feature = "pjrt") {
        println!("runtime_exec: built without the `pjrt` feature; skipping");
        return;
    }
    let dir = Manifest::default_dir();
    if !dir.join("manifest.txt").exists() {
        println!("runtime_exec: no artifacts at {dir:?} — run `make artifacts`; skipping");
        return;
    }
    let rt = Runtime::load(&dir).expect("load artifacts");
    let mut s = Suite::new("runtime_exec (PJRT request path)");

    let mut rng = Rng::new(1);
    let seq: Vec<i8> = (0..geom::CHUNK).map(|_| rng.range_u64(0, 4) as i8).collect();
    let mut patterns = vec![-1i8; geom::N_PATTERNS * geom::WIDTH];
    let mut lengths = vec![0i32; geom::N_PATTERNS];
    for p in 0..geom::N_PATTERNS {
        let len = rng.range_usize(15, 26);
        lengths[p] = len as i32;
        for w in 0..len {
            patterns[p * geom::WIDTH + w] = rng.range_u64(0, 4) as i8;
        }
    }
    let windows = (geom::CHUNK * geom::N_PATTERNS) as f64;
    s.bench_throughput("genome_search_chunk_512pat", windows, || {
        rt.genome_search(&seq, &patterns, &lengths).unwrap()
    });

    let x: Vec<f32> = (0..geom::REDUCE_N).map(|_| rng.f64() as f32).collect();
    s.bench_throughput("reduce_1M_f32", geom::REDUCE_N as f64, || rt.reduce(&x).unwrap());

    let counts = vec![3i32; geom::COLLATE_NODES * geom::N_PATTERNS];
    s.bench_throughput(
        "collate_16x512",
        (geom::COLLATE_NODES * geom::N_PATTERNS) as f64,
        || rt.collate(&counts).unwrap(),
    );

    s.finish();
}
