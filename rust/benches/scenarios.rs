//! Scenario batch-runner benchmark: serial vs parallel trial throughput.
//!
//! Emits a JSON baseline (BENCH_scenarios.json schema) so the perf
//! trajectory of the batch runner can be tracked across PRs:
//!
//! ```text
//! cd rust && BIOMAFT_BENCH_JSON=../BENCH_scenarios.json \
//!     cargo bench --bench scenarios
//! ```
//!
//! Before overwriting, the previous baseline at the target path is read
//! back and compared, so a run prints its serial speedup over the last
//! committed numbers — and shouts if the committed file is still a
//! placeholder (`"generated": false`) rather than honest measurements.
//!
//! Environment knobs: `BIOMAFT_BENCH_TRIALS` (default 2000),
//! `BIOMAFT_BENCH_JSON` (path to write; stdout when unset).

use biomaft::bench::compare_to_baseline;
use biomaft::coordinator::ftmanager::Strategy;
use biomaft::scenario::{default_threads, run_batch, BatchCfg, FailureRegime, ScenarioSpec};

fn spec() -> ScenarioSpec {
    ScenarioSpec::placentia_ring16(
        Strategy::Hybrid,
        0.8,
        16,
        FailureRegime::ConcurrentK { k: 3, offset_s: 600.0, spacing_s: 60.0 },
    )
}

fn main() {
    let trials: usize = std::env::var("BIOMAFT_BENCH_TRIALS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(2000);
    let s = spec();
    let cores = default_threads();

    println!("=== bench suite: scenarios (batch runner, {trials} trials, {cores} cores) ===");
    let serial = run_batch(&s, &BatchCfg { trials, base_seed: 1, threads: 1 });
    println!(
        "serial:   {:>10.3} s  ({:>10.1} trials/s)",
        serial.wall_s, serial.trials_per_s
    );
    let parallel = run_batch(&s, &BatchCfg { trials, base_seed: 1, threads: 0 });
    println!(
        "parallel: {:>10.3} s  ({:>10.1} trials/s, {} threads)",
        parallel.wall_s, parallel.trials_per_s, parallel.threads
    );
    let speedup = serial.wall_s / parallel.wall_s.max(1e-12);
    println!("speedup:  {speedup:>10.2}x");
    assert_eq!(
        serial.completed_s, parallel.completed_s,
        "batch results must be independent of thread count"
    );

    let json_path = std::env::var("BIOMAFT_BENCH_JSON").ok();
    if let Some(path) = &json_path {
        compare_to_baseline(path, "serial_trials_per_s", "serial trials/s", serial.trials_per_s);
    }

    let json = format!(
        "{{\n  \"bench\": \"scenario_batch\",\n  \"generated\": true,\n  \"machine_cores\": {cores},\n  \"trials\": {trials},\n  \"events_per_trial\": {:.1},\n  \"serial_s\": {:.4},\n  \"serial_trials_per_s\": {:.1},\n  \"parallel_s\": {:.4},\n  \"parallel_trials_per_s\": {:.1},\n  \"parallel_threads\": {},\n  \"speedup\": {:.2}\n}}\n",
        serial.events as f64 / trials as f64,
        serial.wall_s,
        serial.trials_per_s,
        parallel.wall_s,
        parallel.trials_per_s,
        parallel.threads,
        speedup,
    );
    match json_path {
        Some(path) => {
            std::fs::write(&path, &json).expect("write bench json");
            println!("wrote {path}");
        }
        None => println!("{json}"),
    }
}
