//! Fused grid-sweep benchmark: the historical per-point experiment loop vs
//! the fused `scenario::sweep` executor, on the fig8 grid.
//!
//! Emits a JSON baseline (BENCH_sweep.json schema):
//!
//! ```text
//! cd rust && BIOMAFT_BENCH_JSON=../BENCH_sweep.json \
//!     cargo bench --bench sweep
//! ```
//!
//! Two scales:
//!
//! * **paper scale** — the full fig8 grid (15 Z-points × 4 presets) at 30
//!   trials/cell: the motivating case where the old per-point loop never
//!   crossed the serial threshold and ran the whole figure on one core;
//! * **big cells** — a 2-preset × 4-point slice at
//!   `BIOMAFT_BENCH_TRIALS` trials/cell (default 100 000): the streaming-
//!   accumulator scale, where a per-cell `Vec<f64>` would be megabytes.
//!
//! Every fused run is asserted cell-for-cell equal to the per-point loop
//! (paper scale, exact mode) and thread-count independent — the bench
//! doubles as the CI smoke for the sweep's determinism contract.

use biomaft::bench::compare_to_baseline;
use biomaft::cluster::{preset, ClusterPreset};
use biomaft::coordinator::ftmanager::Strategy;
use biomaft::coordinator::run::{measure_reinstate, ExperimentCfg};
use biomaft::experiments::figures::z_values;
use biomaft::metrics::Summary;
use biomaft::scenario::{default_threads, run_sweep, CellSpec, SweepSpec};
use biomaft::sim::Rng;
use std::time::Instant;

const SEED: u64 = 2014;

fn cell(strategy: Strategy, p: ClusterPreset, z: usize) -> CellSpec {
    let cfg = ExperimentCfg {
        z,
        data_kb: 1 << 24,
        proc_kb: 1 << 24,
        ..ExperimentCfg::table1(preset(p))
    };
    CellSpec::reinstate(strategy, cfg, SEED ^ z as u64)
}

/// The fig8 grid: every preset × every Z point, agent intelligence.
fn fig8_grid(presets: &[ClusterPreset], zs: &[usize]) -> Vec<CellSpec> {
    presets
        .iter()
        .flat_map(|&p| zs.iter().map(move |&z| cell(Strategy::Agent, p, z)))
        .collect()
}

/// The historical per-point loop: one `measure_reinstate` per cell, each
/// with its own thread decision (30-trial cells stay serial) and a barrier
/// between points.
fn per_point(cells: &[CellSpec], trials: usize, threads: usize) -> Vec<Summary> {
    cells
        .iter()
        .map(|c| {
            let biomaft::scenario::CellKind::Reinstate { strategy, cfg } = &c.kind else {
                unreachable!()
            };
            let cfg = ExperimentCfg { trials, threads: Some(threads), ..cfg.clone() };
            measure_reinstate(*strategy, &cfg, &mut Rng::new(c.seed))
        })
        .collect()
}

fn fused(cells: &[CellSpec], trials: usize, threads: usize) -> Vec<Summary> {
    run_sweep(&SweepSpec { threads: Some(threads), ..SweepSpec::new(cells.to_vec(), trials) })
}

fn time<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let v = f();
    (v, t0.elapsed().as_secs_f64())
}

fn main() {
    let cores = default_threads();
    let big_trials: usize = std::env::var("BIOMAFT_BENCH_TRIALS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(100_000);

    // --- paper scale: the full fig8 grid at 30 trials/cell ---
    let zs = z_values();
    let grid = fig8_grid(&ClusterPreset::all(), &zs);
    let trials = 30;
    println!(
        "=== bench suite: sweep (fig8 grid, {} cells x {trials} trials, {cores} cores) ===",
        grid.len()
    );
    let (pp, per_point_s) = time(|| per_point(&grid, trials, 1));
    println!("per-point serial: {per_point_s:>10.4} s");
    let (f1, fused1_s) = time(|| fused(&grid, trials, 1));
    println!("fused x1:         {fused1_s:>10.4} s");
    let (fp, fusedp_s) = time(|| fused(&grid, trials, cores));
    println!("fused x{cores}:         {fusedp_s:>10.4} s");
    assert_eq!(pp, f1, "fused sweep must equal the per-point loop cell-for-cell");
    assert_eq!(f1, fp, "fused sweep must be thread-count independent");
    let speedup = per_point_s / fusedp_s.max(1e-12);
    println!("speedup (fused x{cores} vs per-point serial): {speedup:.2}x");

    // --- big cells: streaming-accumulator scale ---
    let big_grid = fig8_grid(
        &[ClusterPreset::Placentia, ClusterPreset::Acet],
        &[3usize, 10, 25, 63],
    );
    println!(
        "--- big cells: {} cells x {big_trials} trials (O(chunk) memory/worker) ---",
        big_grid.len()
    );
    let (b1, big1_s) = time(|| fused(&big_grid, big_trials, 1));
    println!("fused x1:         {big1_s:>10.4} s");
    let (bp, bigp_s) = time(|| fused(&big_grid, big_trials, cores));
    println!("fused x{cores}:         {bigp_s:>10.4} s");
    assert_eq!(b1, bp, "big-cell sweep must be thread-count independent");
    let big_speedup = big1_s / bigp_s.max(1e-12);
    let big_trials_per_s = (big_grid.len() * big_trials) as f64 / bigp_s.max(1e-12);
    println!("speedup x{cores}: {big_speedup:.2}x  ({big_trials_per_s:.0} trials/s)");

    let json_path = std::env::var("BIOMAFT_BENCH_JSON").ok();
    if let Some(path) = &json_path {
        compare_to_baseline(path, "fused_par_s", "fused parallel s (fig8 grid)", fusedp_s);
    }
    let json = format!(
        "{{\n  \"bench\": \"grid_sweep\",\n  \"generated\": true,\n  \"machine_cores\": {cores},\n  \"paper_cells\": {},\n  \"paper_trials_per_cell\": {trials},\n  \"per_point_serial_s\": {per_point_s:.4},\n  \"fused_serial_s\": {fused1_s:.4},\n  \"fused_par_s\": {fusedp_s:.4},\n  \"fused_par_threads\": {cores},\n  \"speedup_fused_par_vs_per_point\": {speedup:.2},\n  \"big_cells\": {},\n  \"big_trials_per_cell\": {big_trials},\n  \"big_fused_serial_s\": {big1_s:.4},\n  \"big_fused_par_s\": {bigp_s:.4},\n  \"big_speedup\": {big_speedup:.2},\n  \"big_trials_per_s\": {big_trials_per_s:.0}\n}}\n",
        grid.len(),
        big_grid.len(),
    );
    match json_path {
        Some(path) => {
            std::fs::write(&path, &json).expect("write bench json");
            println!("wrote {path}");
        }
        None => println!("{json}"),
    }
}
