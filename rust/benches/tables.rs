//! Benches regenerating Tables 1 and 2 end-to-end, plus the cold-restart
//! survival simulation and the prediction census.

use biomaft::bench::Suite;
use biomaft::checkpoint::cold_restart::{mean_cold_restart, ColdRestartParams};
use biomaft::experiments::{prediction, tables};
use biomaft::sim::Rng;

fn main() {
    std::env::set_var("BIOMAFT_BENCH_SAMPLES", std::env::var("BIOMAFT_BENCH_SAMPLES").unwrap_or_else(|_| "10".into()));
    let mut s = Suite::new("tables (Tables 1-2 regeneration)");
    s.bench("table1_full", || tables::table1());
    s.bench("table2_full", || tables::table2());
    s.bench_throughput("cold_restart_survival_2k_trials", 2000.0, || {
        let mut rng = Rng::new(1);
        mean_cold_restart(&ColdRestartParams::random_5h(5.0 * 3600.0), 2000, &mut rng)
    });
    s.bench_throughput("prediction_census_1k_windows", 1000.0, || {
        let mut rng = Rng::new(2);
        prediction::run_prediction(
            &prediction::PredictionCfg { windows: 1000, ..Default::default() },
            &mut rng,
        )
    });
    s.finish();
}
