//! L3 hot-path micro-benchmarks: the DES engine, migration episodes,
//! predictor scoring, hit collation — the paths the §Perf pass optimizes.

use biomaft::agentft::simulate_agent_migration;
use biomaft::bench::Suite;
use biomaft::cluster::core::{Core, CoreId, HealthSample};
use biomaft::cluster::{preset, ClusterPreset};
use biomaft::coreft::simulate_core_migration;
use biomaft::failure::predictor::Predictor;
use biomaft::genome::{self, Strand};
use biomaft::net::NodeId;
use biomaft::sim::engine::{ActorId, Engine};
use biomaft::sim::{Rng, SimTime};

fn main() {
    std::env::set_var("BIOMAFT_BENCH_SAMPLES", std::env::var("BIOMAFT_BENCH_SAMPLES").unwrap_or_else(|_| "20".into()));
    let mut s = Suite::new("hotpath");

    // DES engine event throughput: self-rescheduling actor, 100k events.
    s.bench_throughput("engine_100k_events", 100_000.0, || {
        let mut eng: Engine<u32> = Engine::new();
        eng.schedule(SimTime::ZERO, ActorId(0), 0u32);
        eng.run(|_me, msg, out| {
            if msg < 100_000 {
                out.send_in(SimTime(1), ActorId(0), msg + 1);
            }
        });
        eng.dispatched()
    });

    // Migration episodes (the Fig. 3 / Fig. 5 protocol simulations).
    let costs = preset(ClusterPreset::Placentia).costs;
    let adjacent: Vec<(NodeId, bool)> = (1..=3).map(|i| (NodeId(i), false)).collect();
    s.bench("agent_migration_episode_z10", || {
        let mut rng = Rng::new(1);
        simulate_agent_migration(&costs.agent, 10, 1 << 24, 1 << 24, &adjacent, &mut rng, 0.025)
    });
    s.bench("core_migration_episode_z10", || {
        let mut rng = Rng::new(2);
        simulate_core_migration(&costs.core, 10, 1 << 24, 1 << 24, &adjacent, &mut rng, 0.025)
    });
    s.bench("agent_migration_episode_z63", || {
        let mut rng = Rng::new(3);
        simulate_agent_migration(&costs.agent, 63, 1 << 24, 1 << 24, &adjacent, &mut rng, 0.025)
    });

    // Predictor scoring over a full health log.
    let mut core = Core::new(CoreId(0), 64);
    for i in 0..64 {
        core.observe(HealthSample {
            at: SimTime::from_secs(i as f64),
            load: 0.5,
            wear: 0.2 + 0.01 * i as f64,
            soft_errors: i % 7 == 0,
        });
    }
    let pred = Predictor::default();
    s.bench_throughput("predictor_score_1k_logs", 1000.0, || {
        let mut acc = 0.0;
        for _ in 0..1000 {
            acc += pred.score(core.log());
        }
        acc
    });

    // Hit collation from a kernel mask (combining-node hot loop).
    let n_pat = 512;
    let chunk = 32_768;
    let mut rng = Rng::new(9);
    let mut mask = vec![0i8; n_pat * chunk];
    for _ in 0..2000 {
        let i = rng.range_usize(0, mask.len());
        mask[i] = 1;
    }
    let lengths = vec![20i32; n_pat];
    s.bench_throughput("collate_hits_16M_mask", (n_pat * chunk) as f64, || {
        let mut hits = Vec::new();
        genome::hits::collate_hits(
            &mask, n_pat, chunk, 0, chunk, 0, &lengths, n_pat, 0, Strand::Forward, &mut hits,
        );
        hits.len()
    });

    // Naive search oracle (for scale comparison with the PJRT path).
    let g = genome::synthesize_genome(100_000, 4);
    let spec = genome::PatternSpec { n_patterns: 64, ..Default::default() };
    let dict = genome::PatternDict::build(&spec, &g, &mut rng);
    let bases: usize = g.iter().map(|c| c.seq.len()).sum();
    s.bench_throughput("naive_search_100kb_64pat", (bases * 64) as f64, || {
        genome::search_naive(&g, &dict, Strand::Forward).len()
    });

    s.finish();
}
