//! Property tests over the fused grid-sweep executor and the streaming
//! accumulator (DESIGN.md §Sweep executor):
//!
//! * the fused sweep equals the historical per-point loop **cell for
//!   cell, byte for byte** — fig8-, multik- and cascade-shaped grids, at
//!   1 and 8 threads;
//! * per-chunk RNG fast-forwarding (`skip_episode`) consumes the cell's
//!   serial stream bit-identically to `draw_episode`;
//! * `Accumulator` in-order chunk merges reproduce the serial fold, and
//!   its Welford moments agree with the naive two-pass formulas to
//!   ulp-scale tolerance at any size;
//! * degraded (above-cap) cells stay thread-count independent.

use biomaft::agentft::migration::{draw_episode, skip_episode};
use biomaft::cluster::{preset, ClusterPreset};
use biomaft::coordinator::ftmanager::Strategy;
use biomaft::coordinator::run::{adjacent3, measure_reinstate, ExperimentCfg};
use biomaft::failure::injector::FailureProcess;
use biomaft::metrics::{Accumulator, Summary};
use biomaft::scenario::{
    run_batch, run_fleet, run_sweep, BatchCfg, CellKind, CellSpec, FailureRegime, ScenarioSpec,
    SweepSpec,
};
use biomaft::sim::Rng;
use biomaft::testkit::forall;

fn reinstate_cells(seed: u64) -> Vec<CellSpec> {
    let mut cells = Vec::new();
    for p in [ClusterPreset::Placentia, ClusterPreset::Glooscap] {
        for z in [3usize, 10, 63] {
            for strategy in [Strategy::Agent, Strategy::Core, Strategy::Hybrid] {
                let cfg = ExperimentCfg {
                    z,
                    data_kb: 1 << 24,
                    proc_kb: 1 << 24,
                    ..ExperimentCfg::table1(preset(p))
                };
                cells.push(CellSpec::reinstate(strategy, cfg, seed ^ z as u64));
            }
        }
    }
    cells
}

fn scenario_cells(seed: u64) -> Vec<CellSpec> {
    let mut cells = Vec::new();
    for k in [1usize, 3, 6] {
        cells.push(CellSpec::scenario(
            ScenarioSpec::placentia_ring16(
                Strategy::Hybrid,
                0.9,
                16,
                FailureRegime::ConcurrentK { k, offset_s: 900.0, spacing_s: 1.0 },
            ),
            seed ^ k as u64,
        ));
    }
    for p_follow in [0.0, 0.5] {
        cells.push(CellSpec::scenario(
            ScenarioSpec::placentia_ring16(
                Strategy::Hybrid,
                0.95,
                16,
                FailureRegime::Cascade {
                    trigger: FailureProcess::RandomUniform,
                    p_follow,
                    lag_s: 5.0,
                },
            ),
            seed,
        ));
    }
    cells
}

/// What the historical code did for one cell, bit for bit.
fn per_point(cell: &CellSpec, trials: usize) -> Summary {
    match &cell.kind {
        CellKind::Reinstate { strategy, cfg } => {
            let cfg = ExperimentCfg { trials, threads: Some(1), ..cfg.clone() };
            measure_reinstate(*strategy, &cfg, &mut Rng::new(cell.seed))
        }
        CellKind::Scenario { spec } => {
            run_batch(spec, &BatchCfg { trials, base_seed: cell.seed, threads: 1 }).completed_s
        }
        CellKind::Fleet { spec, metric } => {
            let xs: Vec<f64> = (0..trials)
                .map(|i| metric.measure(&run_fleet(spec, cell.seed.wrapping_add(i as u64))))
                .collect();
            Summary::of(&xs)
        }
    }
}

#[test]
fn prop_fused_sweep_equals_per_point_loop() {
    forall(6, 4001, |g| {
        let seed = g.u64(0, u64::MAX - 1);
        let trials = g.usize(1, 40);
        let threads = *g.pick(&[1usize, 8]);
        let mut cells = reinstate_cells(seed);
        cells.extend(scenario_cells(seed));
        let fused =
            run_sweep(&SweepSpec { threads: Some(threads), ..SweepSpec::new(cells.clone(), trials) });
        for (cell, got) in cells.iter().zip(&fused) {
            let want = per_point(cell, trials);
            assert_eq!(got.mean.to_bits(), want.mean.to_bits());
            assert_eq!(got.std.to_bits(), want.std.to_bits());
            assert_eq!(got.median.to_bits(), want.median.to_bits());
            assert_eq!(got.p95.to_bits(), want.p95.to_bits());
            assert_eq!(got.min.to_bits(), want.min.to_bits());
            assert_eq!(got.max.to_bits(), want.max.to_bits());
            assert_eq!(got.n, want.n);
        }
    });
}

#[test]
fn prop_skip_episode_matches_draw_episode_stream() {
    forall(40, 4002, |g| {
        let seed = g.u64(0, u64::MAX - 1);
        let n_jitters = g.usize(1, 5);
        let sigma = *g.pick(&[0.0, 0.03, 0.1]);
        let skips = g.usize(0, 20);
        let adjacent = adjacent3();
        // stream A: draw (and discard) `skips` episodes the historical way
        let mut a = Rng::new(seed);
        for _ in 0..skips {
            draw_episode(n_jitters, &adjacent, &mut a, sigma);
        }
        // stream B: fast-forward with skip_episode
        let mut b = Rng::new(seed);
        for _ in 0..skips {
            skip_episode(n_jitters, &adjacent, &mut b, sigma);
        }
        let da = draw_episode(n_jitters, &adjacent, &mut a, sigma).unwrap();
        let db = draw_episode(n_jitters, &adjacent, &mut b, sigma).unwrap();
        assert_eq!(da.target, db.target);
        let ja: Vec<u64> = da.jitter.iter().map(|j| j.to_bits()).collect();
        let jb: Vec<u64> = db.jitter.iter().map(|j| j.to_bits()).collect();
        assert_eq!(ja, jb);
        // and the raw streams stay in lockstep afterwards
        assert_eq!(a.next_u64(), b.next_u64());
    });
}

#[test]
fn prop_accumulator_in_order_merge_equals_serial_fold() {
    forall(30, 4003, |g| {
        let n = g.usize(1, 400);
        let chunk = g.usize(1, 64);
        let xs: Vec<f64> = {
            let mut r = Rng::new(g.u64(0, u64::MAX - 1));
            (0..n).map(|_| r.uniform(-50.0, 150.0)).collect()
        };
        let mut serial = Accumulator::new();
        for &x in &xs {
            serial.push(x);
        }
        let mut merged = Accumulator::new();
        for c in xs.chunks(chunk) {
            let mut part = Accumulator::new();
            for &x in c {
                part.push(x);
            }
            merged.merge(part);
        }
        let (a, b) = (merged.summary(), serial.summary());
        assert_eq!(a.mean.to_bits(), b.mean.to_bits());
        assert_eq!(a.std.to_bits(), b.std.to_bits());
        assert_eq!(a.median.to_bits(), b.median.to_bits());
        // exact mode ⇒ also byte-identical to the historical Vec path
        let c = Summary::of(&xs);
        assert_eq!(a.mean.to_bits(), c.mean.to_bits());
        assert_eq!(a.p95.to_bits(), c.p95.to_bits());
    });
}

#[test]
fn prop_welford_agrees_with_naive_moments() {
    forall(20, 4004, |g| {
        let n = g.usize(2, 5000);
        let scale = *g.pick(&[1.0, 1e4, 1e-4]);
        let xs: Vec<f64> = {
            let mut r = Rng::new(g.u64(0, u64::MAX - 1));
            (0..n).map(|_| r.uniform(1.0, 2.0) * scale).collect()
        };
        // force the streaming (degraded) path with a tiny cap
        let mut acc = Accumulator::with_cap(16);
        for c in xs.chunks(97) {
            let mut part = Accumulator::with_cap(16);
            for &x in c {
                part.push(x);
            }
            acc.merge(part);
        }
        let approx = acc.summary();
        let exact = Summary::of(&xs);
        let mean_rel = (approx.mean - exact.mean).abs() / exact.mean.abs();
        assert!(mean_rel < 1e-12, "mean drift {mean_rel}");
        let std_tol = 1e-9 * exact.std.abs().max(1e-12 * exact.mean.abs());
        assert!(
            (approx.std - exact.std).abs() <= std_tol.max(1e-12 * scale),
            "std {} vs {}",
            approx.std,
            exact.std
        );
        assert_eq!(approx.min, exact.min);
        assert_eq!(approx.max, exact.max);
    });
}

#[test]
fn degraded_sweep_thread_independent_and_vec_free_scale() {
    // a cell well above the quantile cap: the sweep path must stay
    // deterministic across thread counts on the histogram branch too
    let cells = vec![CellSpec::reinstate(
        Strategy::Core,
        ExperimentCfg { z: 8, ..ExperimentCfg::table1(preset(ClusterPreset::Placentia)) },
        77,
    )];
    let spec = SweepSpec { quantile_cap: 128, ..SweepSpec::new(cells, 900) };
    let one = run_sweep(&SweepSpec { threads: Some(1), ..spec.clone() });
    let eight = run_sweep(&SweepSpec { threads: Some(8), ..spec });
    assert_eq!(one, eight);
    assert_eq!(one[0].n, 900);
    // the degraded summary still brackets the exact one
    assert!(one[0].min <= one[0].median && one[0].median <= one[0].max);
}
