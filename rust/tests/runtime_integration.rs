//! Integration tests over the real AOT artifacts (requires `make artifacts`
//! to have run; tests are skipped politely when artifacts are absent so
//! `cargo test` stays green in a fresh checkout).

use biomaft::genome::{self, encode::PAD, Strand};
use biomaft::runtime::client::geom;
use biomaft::runtime::{Manifest, Runtime};
use biomaft::sim::Rng;

fn runtime() -> Option<Runtime> {
    if !cfg!(feature = "pjrt") {
        eprintln!("skipping: built without the `pjrt` feature");
        return None;
    }
    let dir = Manifest::default_dir();
    if !dir.join("manifest.txt").exists() {
        eprintln!("skipping: no artifacts at {dir:?} (run `make artifacts`)");
        return None;
    }
    Some(Runtime::load(&dir).expect("artifacts load"))
}

#[test]
fn reduce_matches_cpu_sum() {
    let Some(rt) = runtime() else { return };
    let mut rng = Rng::new(1);
    let x: Vec<f32> = (0..geom::REDUCE_N).map(|_| rng.normal(0.0, 1.0) as f32).collect();
    let got = rt.reduce(&x).unwrap();
    let want: f64 = x.iter().map(|&v| v as f64).sum();
    assert!(
        (got as f64 - want).abs() < 0.4,
        "pjrt {got} vs cpu {want}"
    );
}

#[test]
fn genome_search_matches_naive_oracle() {
    let Some(rt) = runtime() else { return };
    let mut rng = Rng::new(7);
    // One synthetic chromosome that fits in a single chunk.
    let genome = genome::synthesize_genome(20_000, 5);
    let chr = &genome[4]; // chrV, the longest
    let spec = genome::PatternSpec { n_patterns: 64, ..Default::default() };
    let dict = genome::PatternDict::build(&spec, std::slice::from_ref(chr), &mut rng);

    // pad chunk + dictionary block to AOT geometry
    let mut seq = chr.seq.clone();
    seq.resize(geom::CHUNK, PAD);
    let (patterns, lengths) = dict.block(0, geom::N_PATTERNS);

    let (mask, counts) = rt.genome_search(&seq, &patterns, &lengths).unwrap();

    // collate and compare against the pure-rust naive scan
    let mut hits = Vec::new();
    genome::hits::collate_hits(
        &mask,
        geom::N_PATTERNS,
        geom::CHUNK,
        0,
        chr.seq.len(),
        0,
        &lengths,
        dict.n,
        4,
        Strand::Forward,
        &mut hits,
    );
    genome::hits::dedup_hits(&mut hits);
    let mut want = genome::search_naive(std::slice::from_ref(chr), &dict, Strand::Forward);
    for h in &mut want {
        h.chrom_idx = 4;
    }
    genome::hits::dedup_hits(&mut want);
    assert_eq!(hits, want, "pjrt hits vs naive oracle");
    assert!(!hits.is_empty(), "planted patterns should hit");

    // counts column consistent with the mask
    for p in 0..dict.n {
        let row_hits =
            mask[p * geom::CHUNK..(p + 1) * geom::CHUNK].iter().filter(|&&m| m != 0).count();
        assert_eq!(counts[p] as usize, row_hits, "pattern {p}");
    }
}

#[test]
fn collate_merges_counts() {
    let Some(rt) = runtime() else { return };
    let mut counts = vec![0i32; geom::COLLATE_NODES * geom::N_PATTERNS];
    for (i, c) in counts.iter_mut().enumerate() {
        *c = (i % 7) as i32;
    }
    let merged = rt.collate(&counts).unwrap();
    for p in 0..geom::N_PATTERNS {
        let want: i32 = (0..geom::COLLATE_NODES).map(|n| counts[n * geom::N_PATTERNS + p]).sum();
        assert_eq!(merged[p], want, "pattern {p}");
    }
}

#[test]
fn pool_runs_tasks_across_workers() {
    let dir = Manifest::default_dir();
    if !cfg!(feature = "pjrt") || !dir.join("manifest.txt").exists() {
        return;
    }
    let mut rng = Rng::new(9);
    let genome = genome::synthesize_genome(8_000, 2);
    let chr = &genome[0];
    let spec = genome::PatternSpec { n_patterns: 32, ..Default::default() };
    let dict = genome::PatternDict::build(&spec, std::slice::from_ref(chr), &mut rng);
    let (patterns, lengths) = dict.block(0, geom::N_PATTERNS);
    let mut seq = chr.seq.clone();
    seq.resize(geom::CHUNK, PAD);

    let mut pool = biomaft::runtime::SearchPool::spawn(2, dir);
    for t in 0..4 {
        pool.submit(biomaft::runtime::SearchTask {
            task_id: t,
            chrom_idx: 0,
            chunk_start: 0,
            chrom_len: chr.seq.len(),
            seq: seq.clone(),
            patterns: patterns.clone(),
            lengths: lengths.clone(),
            pattern_base: 0,
            n_real: dict.n,
            reverse: false,
        })
        .unwrap();
    }
    let mut results = Vec::new();
    for _ in 0..4 {
        results.push(pool.recv().unwrap());
    }
    pool.shutdown();
    assert_eq!(results.len(), 4);
    // staged artifacts must actually run via PJRT — a load failure would
    // silently resolve the workers to the CPU engine fallback instead
    assert!(
        results.iter().all(|r| r.via_pjrt),
        "staged artifacts fell back to the CPU path; check the worker load errors"
    );
    // identical tasks → identical counts
    for r in &results[1..] {
        assert_eq!(r.counts, results[0].counts);
    }
}
