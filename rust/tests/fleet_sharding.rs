//! Sharded-cell determinism plane (DESIGN.md §Sharded cells).
//!
//! `FleetSpec::cells` is a *performance* knob, never a semantics knob: a
//! trial must be byte-identical at any cell count, at any thread count,
//! with every fault plane lit up at once. These tests pin that contract
//! on the hardest fixture the repo has — Poisson churn, an imperfect
//! jittery detector with flapping and fail-slow episodes (gray plane),
//! lossy/duplicating/delaying links under retry (net plane), and a
//! starved checkpoint server (contention) — across cells ∈ {1, 2, 7, 64}
//! and sweep thread counts {1, 8}.
//!
//! 64 cells on a 32-node fleet is deliberate over-sharding: more cells
//! than nodes leaves some cells permanently empty, the degenerate layout
//! where routing or merge-order bugs would surface first.

use biomaft::coordinator::ftmanager::Strategy;
use biomaft::failure::DetectorModel;
use biomaft::net::{LinkFaults, RetryPolicy};
use biomaft::scenario::{
    run_fleet, run_sweep, CellSpec, FleetMetric, FleetOutcome, FleetSpec, SweepSpec,
};
use std::num::NonZeroUsize;

/// The kitchen-sink fleet: every plane on, all at once. The imperfect
/// detector (precision < 1) also forces the eager-drain churn mode, so
/// false alarms can precede their doom.
fn hostile_spec() -> FleetSpec {
    let mut spec = FleetSpec::placentia_fleet(Strategy::Hybrid, 32, 8.0, 1.0);
    spec.ckpt_streams = 1; // checkpoint-server contention
    spec.gray.detector =
        Some(DetectorModel { coverage: 0.6, precision: 0.4, lead_jitter_s: 30.0 });
    spec.gray.flapping.rate_per_node_h = 1.0;
    spec.gray.fail_slow.rate_per_node_h = 0.5;
    spec.faults.peer = LinkFaults { loss_p: 0.15, dup_p: 0.1, delay_p: 0.3, delay_mean_s: 0.5 };
    spec.faults.ckpt = LinkFaults { loss_p: 0.1, dup_p: 0.05, delay_p: 0.2, delay_mean_s: 1.0 };
    spec.faults.retry =
        RetryPolicy { timeout_s: 0.4, max_retries: 3, backoff_base_s: 0.2, backoff_mult: 1.8 };
    spec.validate().expect("fixture must validate");
    spec
}

fn with_cells(mut spec: FleetSpec, cells: usize) -> FleetSpec {
    spec.cells = NonZeroUsize::new(cells).expect("cells >= 1");
    spec
}

/// Every outcome field, bit for bit.
fn assert_outcomes_identical(a: &FleetOutcome, b: &FleetOutcome, what: &str) {
    assert_eq!(a.events, b.events, "{what}");
    assert_eq!(a.jobs_arrived, b.jobs_arrived, "{what}");
    assert_eq!(a.jobs_completed, b.jobs_completed, "{what}");
    assert_eq!(a.jobs_waiting, b.jobs_waiting, "{what}");
    assert_eq!(a.peak_live_jobs, b.peak_live_jobs, "{what}");
    assert_eq!(a.mean_slowdown.to_bits(), b.mean_slowdown.to_bits(), "{what}");
    assert_eq!(a.p95_slowdown.to_bits(), b.p95_slowdown.to_bits(), "{what}");
    assert_eq!(a.goodput_ratio.to_bits(), b.goodput_ratio.to_bits(), "{what}");
    assert_eq!(a.utilization.to_bits(), b.utilization.to_bits(), "{what}");
    assert_eq!(a.last_completion_s.to_bits(), b.last_completion_s.to_bits(), "{what}");
    assert_eq!(a.migrations, b.migrations, "{what}");
    assert_eq!(a.rollbacks, b.rollbacks, "{what}");
    assert_eq!(a.subs_lost, b.subs_lost, "{what}");
    assert_eq!(a.absorbed_failures, b.absorbed_failures, "{what}");
    assert_eq!(a.peak_concurrent_migrations, b.peak_concurrent_migrations, "{what}");
    assert_eq!(a.peak_concurrent_recoveries, b.peak_concurrent_recoveries, "{what}");
    assert_eq!(a.net_retries, b.net_retries, "{what}");
    assert_eq!(a.net_timeouts, b.net_timeouts, "{what}");
    assert_eq!(a.fallbacks, b.fallbacks, "{what}");
    assert_eq!(a.dup_suppressed, b.dup_suppressed, "{what}");
    assert_eq!(a.spurious_migrations, b.spurious_migrations, "{what}");
    assert_eq!(a.quarantines, b.quarantines, "{what}");
    assert_eq!(a.quarantine_releases, b.quarantine_releases, "{what}");
    assert_eq!(a.degraded_node_s.to_bits(), b.degraded_node_s.to_bits(), "{what}");
}

#[test]
fn sharded_fleet_byte_identical_across_cells_with_every_plane_on() {
    let base = hostile_spec();
    let reference = run_fleet(&base, 23);
    // the fixture genuinely exercises all four planes at once
    assert!(reference.jobs_completed > 0, "{reference:?}");
    assert!(reference.migrations > 0 || reference.rollbacks > 0, "{reference:?}");
    assert!(
        reference.net_retries > 0 || reference.net_timeouts > 0,
        "net plane drew nothing: {reference:?}"
    );
    assert!(
        reference.spurious_migrations > 0,
        "imperfect detector cried no wolf: {reference:?}"
    );
    assert!(reference.quarantines > 0, "flapping never quarantined: {reference:?}");
    assert!(reference.degraded_node_s > 0.0, "fail-slow sampled nothing: {reference:?}");
    for cells in [2usize, 7, 64] {
        let o = run_fleet(&with_cells(base.clone(), cells), 23);
        assert_outcomes_identical(&reference, &o, &format!("cells={cells}"));
    }
}

#[test]
fn lazy_churn_fleet_byte_identical_across_cells() {
    // No detector ⇒ no false alarms ⇒ the lazy churn pull path (per-node
    // plans materialized window-by-window, never all upfront) — with the
    // net plane still on and heavy churn.
    let mut base = FleetSpec::placentia_fleet(Strategy::Hybrid, 48, 10.0, 2.0);
    base.faults.peer = LinkFaults { loss_p: 0.2, dup_p: 0.05, delay_p: 0.2, delay_mean_s: 0.4 };
    base.validate().expect("fixture must validate");
    let reference = run_fleet(&base, 29);
    assert!(reference.jobs_completed > 0, "{reference:?}");
    assert!(reference.rollbacks > 0, "churny fixture must roll back: {reference:?}");
    for cells in [2usize, 7, 64] {
        let o = run_fleet(&with_cells(base.clone(), cells), 29);
        assert_outcomes_identical(&reference, &o, &format!("cells={cells}"));
    }
}

#[test]
fn sharded_sweep_byte_identical_across_cells_and_thread_counts() {
    // The full grid: cells {1, 2, 7, 64} × threads {1, 8}, all eight
    // sweeps landing on bit-identical summaries of the hostile fixture.
    let base = hostile_spec();
    let trials = 3;
    let sweep = |cells: usize, threads: usize| {
        run_sweep(&SweepSpec {
            threads: Some(threads),
            ..SweepSpec::new(
                vec![CellSpec::fleet(
                    with_cells(base.clone(), cells),
                    FleetMetric::MeanSlowdown,
                    23,
                )],
                trials,
            )
        })
    };
    let reference = sweep(1, 1);
    for cells in [1usize, 2, 7, 64] {
        for threads in [1usize, 8] {
            if (cells, threads) == (1, 1) {
                continue;
            }
            let got = sweep(cells, threads);
            assert_eq!(reference.len(), got.len());
            for (a, b) in reference.iter().zip(&got) {
                let what = format!("cells={cells} threads={threads}");
                assert_eq!(a.n, b.n, "{what}");
                assert_eq!(a.mean.to_bits(), b.mean.to_bits(), "{what}");
                assert_eq!(a.std.to_bits(), b.std.to_bits(), "{what}");
                assert_eq!(a.median.to_bits(), b.median.to_bits(), "{what}");
                assert_eq!(a.p95.to_bits(), b.p95.to_bits(), "{what}");
                assert_eq!(a.min.to_bits(), b.min.to_bits(), "{what}");
                assert_eq!(a.max.to_bits(), b.max.to_bits(), "{what}");
            }
        }
    }
}

#[test]
fn scratch_reuse_stays_bit_identical_when_cell_counts_change_between_trials() {
    // One scratch carried across trials whose cell counts differ — the
    // per-cell wheels, slabs and placement sets must fully re-shape on
    // every reset, never bleed state across layouts.
    let base = hostile_spec();
    let mut scratch = biomaft::scenario::FleetScratch::new();
    for (cells, seed) in [(4usize, 5u64), (1, 5), (64, 7), (3, 5), (1, 7)] {
        let spec = with_cells(base.clone(), cells);
        let fresh = run_fleet(&spec, seed);
        let reused = biomaft::scenario::run_fleet_scratch(&spec, seed, &mut scratch);
        assert_outcomes_identical(&fresh, &reused, &format!("cells={cells} seed={seed}"));
    }
}
