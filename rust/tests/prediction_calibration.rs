//! Calibration bands for the failure-prediction pipeline (Discussion,
//! "Predicting potential failures"): the prober → predictor census must
//! reproduce the paper's operating point — 29 % of real faults predicted
//! at 64 % prediction precision — within tolerance bands wide enough for
//! the simulated census noise.
//!
//! These are contract tests, not unit tests: `DetectorModel::
//! paper_calibrated()` (the gray-failure plane's detector preset, used by
//! the `grayfail` experiment) hard-codes this operating point, so the
//! bands pin the census and the preset to the same numbers.

use biomaft::experiments::prediction::{run_prediction, PredictionCfg, PredictionStats};
use biomaft::failure::DetectorModel;
use biomaft::sim::Rng;

fn stats() -> PredictionStats {
    let mut rng = Rng::new(1234);
    run_prediction(&PredictionCfg::default(), &mut rng)
}

#[test]
fn coverage_matches_paper_band() {
    let s = stats();
    let c = s.coverage();
    assert!((0.23..0.35).contains(&c), "coverage {c} outside the paper band around 0.29");
}

#[test]
fn precision_matches_paper_band() {
    let s = stats();
    let p = s.precision();
    assert!((0.55..0.74).contains(&p), "precision {p} outside the paper band around 0.64");
}

#[test]
fn paper_calibrated_detector_preset_sits_inside_the_measured_bands() {
    // The gray plane's preset and the census must never drift apart: the
    // preset is the census's operating point, frozen as constants.
    let d = DetectorModel::paper_calibrated();
    assert!((0.23..0.35).contains(&d.coverage), "preset coverage {}", d.coverage);
    assert!((0.55..0.74).contains(&d.precision), "preset precision {}", d.precision);
    let s = stats();
    assert!((s.coverage() - d.coverage).abs() < 0.06, "census {} vs preset {}", s.coverage(), d.coverage);
    assert!((s.precision() - d.precision).abs() < 0.10, "census {} vs preset {}", s.precision(), d.precision);
}
