//! Property tests for the packed multi-pattern search engine: the engine's
//! contract is **byte-identical output to `search_naive`** — same hits in
//! the same (chromosome, pattern, position) order — for any input and any
//! thread count. Covers genomes with N-runs, pattern lengths from 1 up to
//! the matrix width (including the > 64-base long tail), both strands,
//! chunk-boundary-spanning hits and chromosomes shorter than a chunk.

use biomaft::genome::engine::CHUNK_OWNED;
use biomaft::genome::{
    encode_seq, hits::dedup_hits, search_block, search_engine, search_engine_both, search_naive,
    synthesize_genome, Chromosome, PatternDict, PatternSpec, Strand, BASE_N, PAD,
};
use biomaft::sim::Rng;
use biomaft::testkit::{forall, Gen};

/// A random chromosome with occasional multi-base N runs (denser than the
/// synthesizer's 0.1 % so the run index is genuinely exercised).
fn random_chrom(g: &mut Gen, name: &'static str, max_len: usize) -> Chromosome {
    let len = g.usize(0, max_len);
    let mut seq = Vec::with_capacity(len);
    while seq.len() < len {
        if g.usize(0, 12) == 0 {
            let run = g.usize(1, 6).min(len - seq.len());
            seq.extend(std::iter::repeat(BASE_N).take(run));
        } else {
            seq.push(g.usize(0, 4) as i8);
        }
    }
    Chromosome { name, seq }
}

/// A random dictionary with lengths 1..=width; about half the patterns are
/// planted genome windows (which may contain N — the engine must treat
/// pattern N == sequence N exactly as the oracle's literal compare does).
fn random_dict(g: &mut Gen, genome: &[Chromosome], n: usize, width: usize) -> PatternDict {
    let mut matrix = vec![PAD; n * width];
    let mut lengths = vec![0i32; n];
    for p in 0..n {
        let len = g.usize(1, width + 1);
        lengths[p] = len as i32;
        let row = &mut matrix[p * width..p * width + len];
        let plantable: Vec<usize> =
            (0..genome.len()).filter(|&c| genome[c].seq.len() >= len).collect();
        if g.bool() && !plantable.is_empty() {
            let c = &genome[plantable[g.usize(0, plantable.len())]];
            let s = g.usize(0, c.seq.len() - len + 1);
            row.copy_from_slice(&c.seq[s..s + len]);
        } else {
            for slot in row.iter_mut() {
                // 0..=4: random patterns occasionally contain N too
                *slot = g.usize(0, 5) as i8;
            }
        }
    }
    PatternDict { matrix, lengths, width, n }
}

#[test]
fn engine_matches_naive_hit_for_hit() {
    forall(25, 0x9e01, |g| {
        let width = *g.pick(&[4usize, 25, 70]); // 70 exercises the >64 long tail
        let genome = vec![
            random_chrom(g, "tA", 2500),
            random_chrom(g, "tB", 600),
            random_chrom(g, "tC", 40), // often shorter than the patterns
        ];
        let n = g.usize(1, 24);
        let dict = random_dict(g, &genome, n, width);
        for strand in [Strand::Forward, Strand::Reverse] {
            let want = search_naive(&genome, &dict, strand);
            for threads in [1usize, 8] {
                let got = search_engine(&genome, &dict, strand, threads);
                assert_eq!(got, want, "strand {strand:?} threads {threads} width {width}");
            }
        }
    });
}

#[test]
fn hits_spanning_chunk_boundaries_found_exactly_once() {
    // One chromosome two chunks long; plant one pattern at every straddle
    // phase of the boundary, so each is owned by exactly one task and its
    // scan must read into the neighbouring chunk.
    let mut rng = Rng::new(99);
    let len = CHUNK_OWNED + 400;
    let seq: Vec<i8> = (0..len).map(|_| rng.range_u64(0, 4) as i8).collect();
    let m = 20;
    let width = 25;
    let starts: Vec<usize> = (CHUNK_OWNED - m + 1..=CHUNK_OWNED).collect();
    let n = starts.len();
    let mut matrix = vec![PAD; n * width];
    let mut lengths = vec![0i32; n];
    for (p, &s) in starts.iter().enumerate() {
        matrix[p * width..p * width + m].copy_from_slice(&seq[s..s + m]);
        lengths[p] = m as i32;
    }
    let dict = PatternDict { matrix, lengths, width, n };
    let genome = vec![Chromosome { name: "tchunk", seq }];

    let want = search_naive(&genome, &dict, Strand::Forward);
    for threads in [1usize, 8] {
        assert_eq!(search_engine(&genome, &dict, Strand::Forward, threads), want, "x{threads}");
    }
    // every planted pattern is found at its planted position, exactly once
    for (p, &s) in starts.iter().enumerate() {
        let at: Vec<_> =
            want.iter().filter(|h| h.pattern_id == p && h.start == s + 1).collect();
        assert_eq!(at.len(), 1, "pattern {p} planted at {s}");
    }
}

#[test]
fn chromosomes_shorter_than_chunk_and_pattern() {
    let genome = vec![
        Chromosome { name: "s1", seq: encode_seq("ACGTACG") },
        Chromosome { name: "s0", seq: vec![] },
        Chromosome { name: "s2", seq: encode_seq("TT") },
    ];
    // full-chromosome match, longer-than-chromosome pattern, 1-base pattern
    let width = 8;
    let rows = [encode_seq("ACGTACG"), encode_seq("ACGTACGT"), encode_seq("T")];
    let mut matrix = vec![PAD; 3 * width];
    let mut lengths = vec![0i32; 3];
    for (p, r) in rows.iter().enumerate() {
        matrix[p * width..p * width + r.len()].copy_from_slice(r);
        lengths[p] = r.len() as i32;
    }
    let dict = PatternDict { matrix, lengths, width, n: 3 };
    for strand in [Strand::Forward, Strand::Reverse] {
        let want = search_naive(&genome, &dict, strand);
        for threads in [1usize, 8] {
            assert_eq!(search_engine(&genome, &dict, strand, threads), want);
        }
    }
    let fwd = search_engine(&genome, &dict, Strand::Forward, 1);
    assert!(fwd.iter().any(|h| h.pattern_id == 0 && h.start == 1 && h.end == 7));
    assert!(fwd.iter().all(|h| h.pattern_id != 1)); // longer than every chromosome
}

#[test]
fn both_strands_single_invocation_matches_two_naive_scans() {
    let g = synthesize_genome(30_000, 21);
    let mut rng = Rng::new(5);
    let spec = PatternSpec { n_patterns: 48, ..Default::default() };
    let dict = PatternDict::build(&spec, &g, &mut rng);
    let mut want = search_naive(&g, &dict, Strand::Forward);
    want.extend(search_naive(&g, &dict, Strand::Reverse));
    dedup_hits(&mut want);
    for threads in [1usize, 8] {
        assert_eq!(search_engine_both(&g, &dict, threads), want, "x{threads}");
    }
}

#[test]
fn search_block_property_matches_literal_reference() {
    forall(20, 0x51ab, |g| {
        let width = *g.pick(&[6usize, 25]);
        let n_real = g.usize(0, 6);
        let n_rows = n_real + g.usize(0, 3); // trailing all-PAD padding rows
        let chunk = g.usize(1, 400);
        let text = random_chrom(g, "blk", chunk + 1);
        let mut seq = text.seq;
        seq.resize(chunk, PAD);
        let dict = random_dict(g, &[Chromosome { name: "blk", seq: seq.clone() }], n_real, width);
        let (patterns, lengths) = dict.block(0, n_rows);
        let (mask, counts) = search_block(&seq, &patterns, &lengths);
        assert_eq!(mask.len(), n_rows * chunk);
        for p in 0..n_rows {
            let m = lengths[p] as usize;
            let pat = &patterns[p * width..p * width + m];
            let mut want_count = 0;
            for i in 0..chunk {
                let want = i + m <= chunk && &seq[i..i + m] == pat;
                assert_eq!(mask[p * chunk + i] != 0, want, "row {p} pos {i}");
                want_count += want as i32;
            }
            assert_eq!(counts[p], want_count, "row {p}");
        }
    });
}
