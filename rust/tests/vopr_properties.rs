//! VOPR-layer property tests (DESIGN.md §VOPR explorer):
//!
//! * every default invariant checker accepts a consistent hand-built
//!   [`FleetView`] and rejects the matching hand-built inconsistency;
//! * 256 random-walked (spec, seed) pairs pass all invariants, and the
//!   explorer's report is identical at thread counts 1 and 8;
//! * repro strings round-trip exactly through the codec.
//!
//! The self-tests that *inject* a fault and watch a checker fire live in
//! `src/scenario/vopr.rs` — the fault hook is a `cfg(test)` field on
//! `FleetSpec`, invisible to integration tests by design.

use biomaft::scenario::vopr::gen_walk;
use biomaft::scenario::{
    decode_walk, default_invariants, encode_walk, explore, FleetEv, FleetView, Invariant, VoprCfg,
};
use biomaft::sim::SimTime;

/// A consistent two-node view: one 2-sub job running on nodes 0 and 1,
/// nothing queued, nothing in flight. Tests mutate one fact at a time.
fn view<'a>(occupancy: &'a [usize], doomed: &'a [bool], hosted: &'a [usize]) -> FleetView<'a> {
    FleetView {
        now: SimTime::from_secs(100.0),
        n_subs: 2,
        capacity: 1,
        arrived: 1,
        completed: 0,
        live_jobs: 1,
        queued: 0,
        running: 2,
        migr_inflight: 0,
        rec_inflight: 0,
        occupancy,
        doomed,
        hosted,
        sub_running: 2,
        sub_migrating: 0,
        distinct_recs: 0,
        remaining_ok: true,
        stale_node_subs: 0,
        abandoned: 0,
        quarantined: &[false, false],
        suspicion: &[0, 0],
        suspicion_threshold: 3,
        quarantines: 0,
        quarantine_releases: 0,
    }
}

fn checker(name: &str) -> Box<dyn Invariant> {
    default_invariants()
        .into_iter()
        .find(|c| c.name() == name)
        .unwrap_or_else(|| panic!("no default checker named {name}"))
}

const EV: FleetEv = FleetEv::Arrival { job: 0 };

#[test]
fn job_conservation_passes_and_fails() {
    let v = view(&[1, 1], &[false, false], &[1, 1]);
    let mut c = checker("job-conservation");
    assert!(c.check(&EV, &v).is_ok());
    assert!(c.at_end(&v, true).is_ok());

    let mut lost = view(&[1, 1], &[false, false], &[1, 1]);
    lost.arrived = 2; // one arrival neither completed nor live
    assert!(c.check(&EV, &lost).is_err());

    let mut phantom = view(&[1, 1], &[false, false], &[1, 1]);
    phantom.queued = 2; // more queued than live
    phantom.live_jobs = 1;
    phantom.arrived = 1;
    assert!(c.check(&EV, &phantom).is_err());
}

#[test]
fn capacity_bound_passes_and_fails() {
    let v = view(&[1, 1], &[false, false], &[1, 1]);
    let mut c = checker("capacity-bound");
    assert!(c.check(&EV, &v).is_ok());

    let over = view(&[2, 0], &[false, false], &[2, 0]); // capacity is 1
    assert!(c.check(&EV, &over).is_err());

    let mut ghost = view(&[1, 1], &[false, false], &[1, 1]);
    ghost.running = 5; // 2 nodes x 1 slot
    assert!(c.check(&EV, &ghost).is_err());
}

#[test]
fn bookkeeping_agreement_passes_and_fails() {
    let v = view(&[1, 1], &[false, false], &[1, 1]);
    let mut c = checker("bookkeeping-agreement");
    assert!(c.check(&EV, &v).is_ok());

    // placement index and per-node lists disagree on node 0
    let leak = view(&[1, 1], &[false, false], &[0, 1]);
    assert!(c.check(&EV, &leak).is_err());

    let mut slab = view(&[1, 1], &[false, false], &[1, 1]);
    slab.sub_running = 1; // slab walk disagrees with the counter
    assert!(c.check(&EV, &slab).is_err());

    let mut rem = view(&[1, 1], &[false, false], &[1, 1]);
    rem.remaining_ok = false;
    assert!(c.check(&EV, &rem).is_err());

    let mut stale = view(&[1, 1], &[false, false], &[1, 1]);
    stale.stale_node_subs = 1;
    assert!(c.check(&EV, &stale).is_err());
}

#[test]
fn queue_progress_fires_only_on_drain_points() {
    let drain = FleetEv::SubDone { slot: 0, sub: 0, job_completed: true };
    let mut c = checker("queue-progress");

    // a queued 2-sub job while both slots are free must fail at a drain
    // point ...
    let mut stuck = view(&[0, 0], &[false, false], &[0, 0]);
    stuck.queued = 1;
    stuck.running = 0;
    stuck.sub_running = 0;
    stuck.live_jobs = 1;
    assert!(c.check(&drain, &stuck).is_err());
    // ... and at quiescence, but never on a non-drain event (other events
    // may free capacity without draining; the next drain point picks it up)
    assert!(c.check(&EV, &stuck).is_ok());
    assert!(c.at_end(&stuck, false).is_err());
    assert!(c.at_end(&stuck, true).is_ok());

    // genuinely insufficient room: one slot down, one occupied
    let mut full = view(&[1, 0], &[false, true], &[1, 0]);
    full.queued = 1;
    full.live_jobs = 2;
    full.arrived = 2;
    full.running = 1;
    full.sub_running = 1;
    assert!(c.check(&drain, &full).is_ok());

    // a quarantined node's free slots don't count toward the queue head
    let mut held = view(&[1, 0], &[false, false], &[1, 0]);
    held.quarantined = &[false, true];
    held.queued = 1;
    held.live_jobs = 2;
    held.arrived = 2;
    held.running = 1;
    held.sub_running = 1;
    assert!(c.check(&drain, &held).is_ok(), "quarantined capacity is not free capacity");
}

#[test]
fn storm_bound_passes_and_fails() {
    let mut c = checker("storm-bound");
    let mut v = view(&[1, 1], &[false, false], &[1, 1]);
    v.suspicion = &[2, 0]; // below the threshold of 3
    assert!(c.check(&EV, &v).is_ok());
    assert!(c.at_end(&v, true).is_ok());

    // at the threshold while quarantined: the policy did its job
    let mut contained = view(&[1, 1], &[false, false], &[1, 1]);
    contained.suspicion = &[3, 0];
    contained.quarantined = &[true, false];
    assert!(c.check(&EV, &contained).is_ok());

    // at the threshold while still placeable: the leak storm-bound exists
    // to catch
    let mut leaked = view(&[1, 1], &[false, false], &[1, 1]);
    leaked.suspicion = &[3, 0];
    assert!(c.check(&EV, &leaked).is_err());
    assert!(c.at_end(&leaked, false).is_err());

    // threshold 0 disables the policy entirely
    let mut off = view(&[1, 1], &[false, false], &[1, 1]);
    off.suspicion = &[9, 9];
    off.suspicion_threshold = 0;
    assert!(c.check(&EV, &off).is_ok());
}

#[test]
fn quarantine_releases_passes_and_fails() {
    let mut c = checker("quarantine-releases");
    let mut v = view(&[1, 1], &[false, false], &[1, 1]);
    v.quarantines = 2;
    v.quarantine_releases = 1;
    assert!(c.check(&EV, &v).is_ok());
    assert!(c.at_end(&v, true).is_ok());

    let mut excess = view(&[1, 1], &[false, false], &[1, 1]);
    excess.quarantines = 1;
    excess.quarantine_releases = 2; // released more than were quarantined
    assert!(c.check(&EV, &excess).is_err());

    // quiescent with a node still quarantined: its release never fired
    let mut stuck = view(&[1, 1], &[false, false], &[1, 1]);
    stuck.quarantined = &[true, false];
    stuck.quarantines = 1;
    assert!(c.at_end(&stuck, false).is_err());
    assert!(c.at_end(&stuck, true).is_ok(), "the horizon may cut a probation off");
}

#[test]
fn no_lost_job_passes_and_fails() {
    let v = view(&[1, 1], &[false, false], &[1, 1]);
    let mut c = checker("no-lost-job");
    assert!(c.check(&EV, &v).is_ok());
    assert!(c.at_end(&v, true).is_ok());

    let mut stranded = view(&[1, 1], &[false, false], &[1, 1]);
    stranded.abandoned = 1; // a sub-job with no scheduled continuation
    assert!(c.check(&EV, &stranded).is_err());
    assert!(c.at_end(&stranded, false).is_err());
}

#[test]
fn monotone_time_passes_and_fails() {
    let mut c = checker("monotone-time");
    let mut v = view(&[1, 1], &[false, false], &[1, 1]);
    v.now = SimTime::from_secs(10.0);
    assert!(c.check(&EV, &v).is_ok());
    v.now = SimTime::from_secs(10.0); // equal times are fine
    assert!(c.check(&EV, &v).is_ok());
    v.now = SimTime::from_secs(20.0);
    assert!(c.check(&EV, &v).is_ok());
    v.now = SimTime::from_secs(15.0); // backwards
    assert!(c.check(&EV, &v).is_err());
}

#[test]
fn termination_passes_and_fails() {
    let mut c = checker("termination");
    let v = view(&[1, 1], &[false, false], &[1, 1]);
    assert!(c.check(&EV, &v).is_ok(), "termination is an end-only check");

    let mut hung = view(&[1, 1], &[false, false], &[1, 1]);
    hung.migr_inflight = 1;
    assert!(c.at_end(&hung, false).is_err(), "quiescent with a migration in flight");
    assert!(c.at_end(&hung, true).is_ok(), "the horizon may cut work off mid-flight");
    let done = view(&[0, 0], &[false, false], &[0, 0]);
    assert!(c.at_end(&done, false).is_ok());
}

#[test]
fn explorer_passes_256_walks_identically_at_threads_1_and_8() {
    let cfg = |threads: usize| VoprCfg {
        walks: 256,
        base_seed: 0xB10F,
        max_nodes: 16,
        max_arrivals: 96,
        threads: Some(threads),
        ..Default::default()
    };
    let one = explore(&cfg(1));
    assert!(one.passed(), "{}", one.render());
    assert!(one.total_events > 0);
    assert_eq!(one.walks, 256);
    assert_eq!(one.fleet_walks + one.episode_walks, 256);
    assert!(one.fleet_walks > 0 && one.episode_walks > 0, "both walk kinds must be sampled");

    let eight = explore(&cfg(8));
    assert!(eight.passed(), "{}", eight.render());
    assert_eq!(one.total_events, eight.total_events, "walks are keyed by index, not thread");
    assert_eq!(one.fleet_walks, eight.fleet_walks);
}

#[test]
fn repro_codec_round_trips_generated_walks() {
    let cfg = VoprCfg {
        walks: 48,
        base_seed: 77,
        max_nodes: 10,
        max_arrivals: 24,
        ..Default::default()
    };
    for i in 0..48 {
        let (spec, _) = gen_walk(&cfg, i);
        let enc = encode_walk(&spec);
        let dec = decode_walk(&enc).unwrap_or_else(|e| panic!("walk {i}: {e}"));
        assert_eq!(enc, encode_walk(&dec), "walk {i} did not round-trip");
    }
    assert!(decode_walk("fleet;nonsense").is_err());
    assert!(decode_walk("who;s=agent").is_err());
}
