//! Smoke tests: every registered experiment runs end-to-end at reduced
//! trial counts and produces non-trivial output.

use biomaft::experiments;

#[test]
fn every_experiment_runs() {
    for e in experiments::list() {
        // fig14 needs artifacts or falls back; either way it must run
        let out = experiments::run_by_id(e.id, 3, 42)
            .unwrap_or_else(|err| panic!("{} failed: {err}", e.id));
        assert!(out.len() > 40, "{} output too small:\n{out}", e.id);
    }
}

#[test]
fn table1_contains_all_strategies() {
    let out = experiments::run_by_id("table1", 3, 1).unwrap();
    for needle in [
        "centralised checkpointing, single server",
        "centralised checkpointing, multiple servers",
        "decentralised checkpointing, multiple servers",
        "agent intelligence",
        "core intelligence",
        "hybrid intelligence",
    ] {
        assert!(out.contains(needle), "missing {needle}");
    }
}

#[test]
fn table2_contains_cold_restart_and_periodicities() {
    let out = experiments::run_by_id("table2", 3, 1).unwrap();
    assert!(out.contains("cold restart"));
    for p in ["(1 h periodicity)", "(2 h periodicity)", "(4 h periodicity)"] {
        assert!(out.contains(p), "missing {p}");
    }
}

#[test]
fn figure_csv_has_four_clusters() {
    let out = experiments::run_by_id("fig9", 3, 1).unwrap();
    for c in ["acet", "brasdor", "glooscap", "placentia"] {
        assert!(out.contains(c), "missing {c}");
    }
}

#[test]
fn rules_experiment_reports_all_three_rules() {
    let out = experiments::run_by_id("rules", 3, 1).unwrap();
    for r in ["Rule 1", "Rule 2", "Rule 3"] {
        assert!(out.contains(r), "missing {r}");
    }
    assert!(!out.contains(" NO "), "a decision rule failed:\n{out}");
}

#[test]
fn prediction_experiment_reports_bands() {
    let out = experiments::run_by_id("prediction", 3, 7).unwrap();
    assert!(out.contains("coverage"));
    assert!(out.contains("precision"));
}

#[test]
fn deterministic_outputs_for_fixed_seed() {
    let a = experiments::run_by_id("fig10", 3, 5).unwrap();
    let b = experiments::run_by_id("fig10", 3, 5).unwrap();
    assert_eq!(a, b);
}
