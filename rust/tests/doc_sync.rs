//! Doc-sync: the experiment registry and the written documentation can
//! never drift apart.
//!
//! * Every `experiments::registry` id must appear as a row of
//!   EXPERIMENTS.md's index tables — a new experiment family (like
//!   `fleet`) cannot ship undocumented.
//! * Every id-looking row of those tables must be a registered experiment
//!   — stale documentation fails too.
//! * README.md must exist and point users at the registry.

use std::collections::BTreeSet;

const EXPERIMENTS_MD: &str = include_str!("../../EXPERIMENTS.md");
const README_MD: &str = include_str!("../../README.md");

/// Ids of EXPERIMENTS.md's index tables: rows shaped `| `id` | … |`.
fn md_index_ids() -> BTreeSet<String> {
    EXPERIMENTS_MD
        .lines()
        .filter_map(|l| {
            let body = l.trim().strip_prefix("| `")?;
            let (id, _) = body.split_once('`')?;
            Some(id.to_string())
        })
        .collect()
}

#[test]
fn registry_and_experiments_md_agree() {
    let registry: BTreeSet<String> =
        biomaft::experiments::list().iter().map(|e| e.id.to_string()).collect();
    let documented = md_index_ids();
    assert!(!documented.is_empty(), "EXPERIMENTS.md index tables not found");
    let undocumented: Vec<&String> = registry.difference(&documented).collect();
    let stale: Vec<&String> = documented.difference(&registry).collect();
    assert!(
        undocumented.is_empty(),
        "registered but missing from EXPERIMENTS.md's index tables: {undocumented:?}"
    );
    assert!(
        stale.is_empty(),
        "documented in EXPERIMENTS.md but not registered: {stale:?}"
    );
}

#[test]
fn fleet_family_is_documented() {
    let documented = md_index_ids();
    for id in ["fleet", "fleet-contention", "fleet-churn", "fleet-scale"] {
        assert!(documented.contains(id), "{id} missing from EXPERIMENTS.md index");
    }
}

#[test]
fn readme_exists_and_points_at_the_registry() {
    assert!(README_MD.contains("biomaft"), "README must name the binary");
    assert!(README_MD.contains("biomaft list"), "README must show the registry entry point");
    assert!(
        README_MD.contains("cargo build --release") && README_MD.contains("cargo test"),
        "README must carry the tier-1 quickstart"
    );
    for doc in ["DESIGN.md", "EXPERIMENTS.md", "ROADMAP.md"] {
        assert!(README_MD.contains(doc), "README must link {doc}");
    }
}
