//! Fleet-layer property tests (DESIGN.md §Fleet simulator, §Determinism
//! inventory):
//!
//! * a fleet trial is a pure function of `(spec, seed)`;
//! * fleet sweep cells are byte-identical at thread counts 1, 3 and 8
//!   (3 covers the non-power-of-two work split);
//! * the degenerate fleet — one traced job at t = 0, an explicit churn
//!   plan, no binding capacity — reduces to `run_live` exactly (completion
//!   time, migrations, rollbacks, lost sub-jobs);
//! * scratch reuse through the sweep path changes nothing.

use biomaft::cluster::{preset, ClusterPreset};
use biomaft::coordinator::ftmanager::Strategy;
use biomaft::coordinator::livesim::{run_live, LiveCfg};
use biomaft::failure::injector::{FailurePlan, FailureProcess};
use biomaft::failure::{DetectorModel, FailSlow, Flapping, GrayPlane, QuarantinePolicy};
use biomaft::net::{FaultPlane, LinkFaults, RetryPolicy, Topology};
use biomaft::scenario::{
    run_fleet, run_fleet_observed, run_sweep, ArrivalSpec, CellSpec, ChurnSpec, FleetEv,
    FleetMetric, FleetScratch, FleetSpec, FleetView, Invariant, InvariantObserver, SweepSpec,
};
use biomaft::sim::Rng;

fn live_cfg(strategy: Strategy, n_subs: usize, seed: u64) -> LiveCfg {
    LiveCfg {
        costs: preset(ClusterPreset::Placentia).costs,
        strategy,
        n_subs,
        z: 4,
        data_kb: 1 << 19,
        proc_kb: 1 << 19,
        compute_s: 3600.0,
        predictable_frac: 0.9,
        ckpt_reinstate_s: 848.0,
        ckpt_overhead_s: 485.0,
        seed,
    }
}

/// The degenerate fleet around one `run_live` trial: a single traced job
/// at t = 0, the trial's explicit failure plan as churn, and capacity far
/// beyond anything the job can pile onto one node. (Built on a preset
/// base rather than a struct literal so the spec stays exhaustive even
/// when `--features vopr-selftest` adds the fault-injection field.)
fn degenerate(cfg: LiveCfg, topo: Topology, plan: FailurePlan) -> FleetSpec {
    let mut spec = FleetSpec::placentia_fleet(cfg.strategy, topo.len(), 0.0, 0.0);
    spec.job = cfg;
    spec.topo = topo;
    spec.capacity = 1 << 20;
    spec.arrivals = ArrivalSpec::Trace { at_s: vec![0.0] };
    spec.churn = ChurnSpec::Plan(plan);
    spec.ckpt_streams = 1 << 20;
    spec.horizon_s = 200_000.0;
    spec
}

/// Run the sweep single-threaded, then at thread counts 3 and 8, and
/// assert every summary statistic is byte-identical. 3 is deliberately
/// not a power of two: it exercises the uneven work split, where an
/// off-by-one in trial partitioning would first show up.
fn assert_sweep_thread_invariant(cells: Vec<CellSpec>, trials: usize) {
    let one =
        run_sweep(&SweepSpec { threads: Some(1), ..SweepSpec::new(cells.clone(), trials) });
    for threads in [3usize, 8] {
        let multi = run_sweep(&SweepSpec {
            threads: Some(threads),
            ..SweepSpec::new(cells.clone(), trials)
        });
        assert_eq!(one.len(), multi.len());
        for (a, b) in one.iter().zip(&multi) {
            assert_eq!(a.n, b.n, "threads {threads}");
            assert_eq!(a.mean.to_bits(), b.mean.to_bits(), "threads {threads}");
            assert_eq!(a.std.to_bits(), b.std.to_bits(), "threads {threads}");
            assert_eq!(a.median.to_bits(), b.median.to_bits(), "threads {threads}");
            assert_eq!(a.p95.to_bits(), b.p95.to_bits(), "threads {threads}");
            assert_eq!(a.min.to_bits(), b.min.to_bits(), "threads {threads}");
            assert_eq!(a.max.to_bits(), b.max.to_bits(), "threads {threads}");
        }
    }
}

#[test]
fn fleet_trial_is_pure_function_of_spec_and_seed() {
    let spec = FleetSpec::placentia_fleet(Strategy::Hybrid, 40, 8.0, 1.0);
    for seed in [0u64, 5, 91] {
        let a = run_fleet(&spec, seed);
        let b = run_fleet(&spec, seed);
        assert_eq!(a.events, b.events, "seed {seed}");
        assert_eq!(a.jobs_arrived, b.jobs_arrived);
        assert_eq!(a.jobs_completed, b.jobs_completed);
        assert_eq!(a.mean_slowdown.to_bits(), b.mean_slowdown.to_bits());
        assert_eq!(a.p95_slowdown.to_bits(), b.p95_slowdown.to_bits());
        assert_eq!(a.goodput_ratio.to_bits(), b.goodput_ratio.to_bits());
        assert_eq!(a.utilization.to_bits(), b.utilization.to_bits());
        assert_eq!(a.last_completion_s.to_bits(), b.last_completion_s.to_bits());
        assert_eq!(a.migrations, b.migrations);
        assert_eq!(a.rollbacks, b.rollbacks);
        assert_eq!(a.subs_lost, b.subs_lost);
        assert_eq!(a.peak_concurrent_migrations, b.peak_concurrent_migrations);
        assert_eq!(a.peak_concurrent_recoveries, b.peak_concurrent_recoveries);
    }
}

#[test]
fn fleet_sweep_byte_identical_across_thread_counts() {
    let mut cells = Vec::new();
    for (i, strategy) in [Strategy::Hybrid, Strategy::Agent].into_iter().enumerate() {
        for (k, arrival) in [4.0, 10.0].into_iter().enumerate() {
            let spec = FleetSpec::placentia_fleet(strategy, 32, arrival, 0.5);
            cells.push(CellSpec::fleet(
                spec,
                FleetMetric::MeanSlowdown,
                7 ^ ((i as u64) << 8) ^ k as u64,
            ));
        }
    }
    // utilization cells exercise the time-weighted accumulator path too
    cells.push(CellSpec::fleet(
        FleetSpec::placentia_fleet(Strategy::Core, 32, 6.0, 1.0),
        FleetMetric::Utilization,
        99,
    ));
    assert_sweep_thread_invariant(cells, 5);
}

#[test]
fn mid_size_scale_fleet_byte_identical_across_thread_counts() {
    // ≥ 500 nodes / ~10k arrivals through the timer-wheel event queue,
    // the (load, node) placement index and the generation-checked job
    // slab — the scale path keeps both fleet contracts: a trial is a pure
    // function of (spec, seed), and sweep cells are byte-identical at any
    // thread count.
    let spec = FleetSpec::scale_fleet(Strategy::Hybrid, 512, 10_000, 0.05);
    let a = run_fleet(&spec, 31);
    assert!(
        a.jobs_arrived >= 9_000,
        "scale sizing must deliver ~10k arrivals, got {}",
        a.jobs_arrived
    );
    assert!(a.jobs_completed > 0, "{a:?}");
    // the slab's footprint is live jobs, far below total arrivals
    assert!(
        a.peak_live_jobs * 4 < a.jobs_arrived,
        "peak live {} should be far below {} arrivals",
        a.peak_live_jobs,
        a.jobs_arrived
    );
    let b = run_fleet(&spec, 31);
    assert_eq!(a.events, b.events);
    assert_eq!(a.jobs_arrived, b.jobs_arrived);
    assert_eq!(a.jobs_completed, b.jobs_completed);
    assert_eq!(a.peak_live_jobs, b.peak_live_jobs);
    assert_eq!(a.mean_slowdown.to_bits(), b.mean_slowdown.to_bits());
    assert_eq!(a.p95_slowdown.to_bits(), b.p95_slowdown.to_bits());
    assert_eq!(a.goodput_ratio.to_bits(), b.goodput_ratio.to_bits());
    assert_eq!(a.utilization.to_bits(), b.utilization.to_bits());
    assert_eq!(a.last_completion_s.to_bits(), b.last_completion_s.to_bits());
    assert_eq!(a.migrations, b.migrations);
    assert_eq!(a.rollbacks, b.rollbacks);

    let cells = vec![CellSpec::fleet(spec, FleetMetric::MeanSlowdown, 31)];
    assert_sweep_thread_invariant(cells, 2);
}

#[test]
fn degenerate_fleet_reduces_to_run_live() {
    let topo = Topology::ring(16, 2);
    for strategy in [Strategy::Agent, Strategy::Core, Strategy::Hybrid] {
        for seed in [3u64, 17, 202] {
            let mut plan_rng = Rng::new(seed ^ 0xBEEF);
            let plan =
                FailureProcess::RandomUniformK { k: 3 }.plan(1, 3600.0, 16, &mut plan_rng);
            let cfg = live_cfg(strategy, 16, seed);
            let direct = run_live(&cfg, &topo, &plan);
            let fleet = degenerate(cfg, topo.clone(), plan);
            let o = run_fleet(&fleet, seed);
            assert_eq!(o.jobs_arrived, 1);
            assert_eq!(o.jobs_completed, 1, "{strategy:?} seed {seed}: {o:?}");
            assert_eq!(
                o.last_completion_s.to_bits(),
                direct.completed_at_s.to_bits(),
                "{strategy:?} seed {seed}: fleet {} vs live {}",
                o.last_completion_s,
                direct.completed_at_s
            );
            assert_eq!(o.migrations, direct.migrations, "{strategy:?} seed {seed}");
            assert_eq!(o.rollbacks, direct.rollbacks, "{strategy:?} seed {seed}");
            assert_eq!(o.subs_lost, direct.lost_then_recovered, "{strategy:?} seed {seed}");
            // the single job's slowdown is its completion over the nominal
            assert_eq!(o.mean_slowdown.to_bits(), (direct.completed_at_s / 3600.0).to_bits());
        }
    }
}

#[test]
fn degenerate_fleet_with_unpredicted_failures_still_matches() {
    // predictable_frac 0 forces the reactive rollback path in both sims
    let topo = Topology::ring(8, 2);
    let mut plan_rng = Rng::new(40);
    let plan = FailureProcess::Periodic { offset_s: 600.0 }.plan(1, 3600.0, 8, &mut plan_rng);
    let mut cfg = live_cfg(Strategy::Hybrid, 8, 11);
    cfg.predictable_frac = 0.0;
    let direct = run_live(&cfg, &topo, &plan);
    assert!(direct.rollbacks >= 1, "fixture must roll back");
    let o = run_fleet(&degenerate(cfg, topo, plan), 11);
    assert_eq!(o.last_completion_s.to_bits(), direct.completed_at_s.to_bits());
    assert_eq!(o.rollbacks, direct.rollbacks);
    assert_eq!(o.subs_lost, direct.lost_then_recovered);
}

#[test]
fn observed_trial_is_bit_identical_to_unobserved() {
    // The vopr invariant observer reads derived views but never touches
    // RNG or scheduling, so a checked trial must equal the plain one on
    // every outcome field, bit for bit — the zero-cost-observer contract
    // (DESIGN.md §VOPR explorer).
    let mut scratch = FleetScratch::new();
    for (nodes, arrival, churn, seed) in
        [(24, 6.0, 1.0, 5u64), (40, 12.0, 0.25, 91), (8, 2.0, 2.0, 7)]
    {
        let spec = FleetSpec::placentia_fleet(Strategy::Hybrid, nodes, arrival, churn);
        let plain = run_fleet(&spec, seed);
        let mut obs = InvariantObserver::new(32);
        let checked = run_fleet_observed(&spec, seed, &mut scratch, &mut obs);
        assert!(obs.violation().is_none(), "clean spec must pass: {:?}", obs.violation());
        assert_eq!(obs.events(), plain.events, "observer must see every event");
        assert_eq!(plain.events, checked.events);
        assert_eq!(plain.jobs_arrived, checked.jobs_arrived);
        assert_eq!(plain.jobs_completed, checked.jobs_completed);
        assert_eq!(plain.jobs_waiting, checked.jobs_waiting);
        assert_eq!(plain.peak_live_jobs, checked.peak_live_jobs);
        assert_eq!(plain.mean_slowdown.to_bits(), checked.mean_slowdown.to_bits());
        assert_eq!(plain.p95_slowdown.to_bits(), checked.p95_slowdown.to_bits());
        assert_eq!(plain.goodput_ratio.to_bits(), checked.goodput_ratio.to_bits());
        assert_eq!(plain.utilization.to_bits(), checked.utilization.to_bits());
        assert_eq!(plain.last_completion_s.to_bits(), checked.last_completion_s.to_bits());
        assert_eq!(plain.migrations, checked.migrations);
        assert_eq!(plain.rollbacks, checked.rollbacks);
        assert_eq!(plain.subs_lost, checked.subs_lost);
        assert_eq!(plain.absorbed_failures, checked.absorbed_failures);
        assert_eq!(plain.peak_concurrent_migrations, checked.peak_concurrent_migrations);
        assert_eq!(plain.peak_concurrent_recoveries, checked.peak_concurrent_recoveries);
    }
}

/// The fleet fixture with a moderately hostile fault plane: lossy,
/// duplicating, delaying links on both classes and a non-default retry
/// policy.
fn faulted_spec() -> FleetSpec {
    let mut spec = FleetSpec::placentia_fleet(Strategy::Hybrid, 24, 6.0, 1.0);
    spec.faults.peer = LinkFaults { loss_p: 0.15, dup_p: 0.1, delay_p: 0.3, delay_mean_s: 0.5 };
    spec.faults.ckpt = LinkFaults { loss_p: 0.1, dup_p: 0.05, delay_p: 0.2, delay_mean_s: 1.0 };
    spec.faults.retry =
        RetryPolicy { timeout_s: 0.4, max_retries: 3, backoff_base_s: 0.2, backoff_mult: 1.8 };
    spec
}

#[test]
fn explicitly_zeroed_plane_is_byte_identical_to_default() {
    // A plane whose every probability is written out as 0.0 — and whose
    // retry policy is nothing like the default — must be indistinguishable
    // from a spec that never mentions faults: `is_off` short-circuits
    // before any draw or retry constant is consulted.
    let mut zeroed = FleetSpec::placentia_fleet(Strategy::Hybrid, 24, 6.0, 1.0);
    zeroed.faults = FaultPlane {
        peer: LinkFaults { loss_p: 0.0, dup_p: 0.0, delay_p: 0.0, delay_mean_s: 5.0 },
        ckpt: LinkFaults { loss_p: 0.0, dup_p: 0.0, delay_p: 0.0, delay_mean_s: 9.0 },
        retry: RetryPolicy {
            timeout_s: 7.0,
            max_retries: 64,
            backoff_base_s: 3.0,
            backoff_mult: 11.0,
        },
        ..FaultPlane::default()
    };
    assert!(zeroed.faults.is_off());
    let plain = FleetSpec::placentia_fleet(Strategy::Hybrid, 24, 6.0, 1.0);
    for seed in [0u64, 5, 91] {
        let a = run_fleet(&zeroed, seed);
        let b = run_fleet(&plain, seed);
        assert_eq!(a.events, b.events, "seed {seed}");
        assert_eq!(a.jobs_completed, b.jobs_completed);
        assert_eq!(a.mean_slowdown.to_bits(), b.mean_slowdown.to_bits());
        assert_eq!(a.goodput_ratio.to_bits(), b.goodput_ratio.to_bits());
        assert_eq!(a.utilization.to_bits(), b.utilization.to_bits());
        assert_eq!(a.last_completion_s.to_bits(), b.last_completion_s.to_bits());
        assert_eq!(a.migrations, b.migrations);
        assert_eq!(a.rollbacks, b.rollbacks);
        assert_eq!((a.net_retries, a.net_timeouts, a.fallbacks, a.dup_suppressed), (0, 0, 0, 0));
        assert_eq!((b.net_retries, b.net_timeouts, b.fallbacks, b.dup_suppressed), (0, 0, 0, 0));
    }

    // ... and byte-identical through the threaded sweep too
    let trials = 4;
    let za = run_sweep(&SweepSpec {
        threads: Some(1),
        ..SweepSpec::new(vec![CellSpec::fleet(zeroed, FleetMetric::MeanSlowdown, 7)], trials)
    });
    let pb = run_sweep(&SweepSpec {
        threads: Some(8),
        ..SweepSpec::new(vec![CellSpec::fleet(plain, FleetMetric::MeanSlowdown, 7)], trials)
    });
    assert_eq!(za[0].mean.to_bits(), pb[0].mean.to_bits());
    assert_eq!(za[0].std.to_bits(), pb[0].std.to_bits());
    assert_eq!(za[0].p95.to_bits(), pb[0].p95.to_bits());
}

#[test]
fn faulted_fleet_is_pure_and_thread_count_invariant() {
    // With the plane on, the trial stays a pure function of (spec, seed):
    // fault draws come from a stateless side-stream keyed by
    // (seed, edge, seq), never from the main RNG streams.
    let spec = faulted_spec();
    for seed in [2u64, 13, 77] {
        let a = run_fleet(&spec, seed);
        let b = run_fleet(&spec, seed);
        assert_eq!(a.events, b.events, "seed {seed}");
        assert_eq!(a.jobs_completed, b.jobs_completed);
        assert_eq!(a.mean_slowdown.to_bits(), b.mean_slowdown.to_bits());
        assert_eq!(a.goodput_ratio.to_bits(), b.goodput_ratio.to_bits());
        assert_eq!(a.last_completion_s.to_bits(), b.last_completion_s.to_bits());
        assert_eq!(a.net_retries, b.net_retries);
        assert_eq!(a.net_timeouts, b.net_timeouts);
        assert_eq!(a.fallbacks, b.fallbacks);
        assert_eq!(a.dup_suppressed, b.dup_suppressed);
    }
    // the fixture actually exercises the plane
    let o = run_fleet(&spec, 2);
    assert!(
        o.net_retries > 0 || o.net_timeouts > 0 || o.dup_suppressed > 0,
        "faulted fixture drew nothing: {o:?}"
    );

    assert_sweep_thread_invariant(vec![CellSpec::fleet(spec, FleetMetric::Goodput, 41)], 5);
}

/// The fleet fixture with a hostile gray plane: an imperfect, jittery
/// detector plus flap bursts and fail-slow episodes, all active at once.
fn gray_spec() -> FleetSpec {
    let mut spec = FleetSpec::placentia_fleet(Strategy::Hybrid, 24, 6.0, 1.0);
    spec.gray.detector =
        Some(DetectorModel { coverage: 0.6, precision: 0.4, lead_jitter_s: 30.0 });
    spec.gray.flapping.rate_per_node_h = 1.0;
    spec.gray.fail_slow.rate_per_node_h = 0.5;
    spec
}

#[test]
fn explicitly_zeroed_gray_plane_is_byte_identical_to_default() {
    // A gray plane whose every rate is written out as 0.0 — and whose
    // inert shape parameters are nothing like the defaults — must be
    // indistinguishable from a spec that never mentions the plane:
    // `is_off` short-circuits before any gray draw is taken.
    let mut zeroed = FleetSpec::placentia_fleet(Strategy::Hybrid, 24, 6.0, 1.0);
    zeroed.gray = GrayPlane {
        detector: None,
        fail_slow: FailSlow { rate_per_node_h: 0.0, mean_duration_s: 5.0, speed_factor: 0.9 },
        flapping: Flapping { rate_per_node_h: 0.0, burst_len: 9, down_s: 1.0, gap_s: 0.0 },
        quarantine: QuarantinePolicy {
            threshold: 1,
            probation_s: 1.0,
            backoff_mult: 9.0,
            max_probation_s: 9.0,
        },
    };
    assert!(zeroed.gray.is_off());
    let plain = FleetSpec::placentia_fleet(Strategy::Hybrid, 24, 6.0, 1.0);
    for seed in [0u64, 5, 91] {
        let a = run_fleet(&zeroed, seed);
        let b = run_fleet(&plain, seed);
        assert_eq!(a.events, b.events, "seed {seed}");
        assert_eq!(a.jobs_completed, b.jobs_completed);
        assert_eq!(a.mean_slowdown.to_bits(), b.mean_slowdown.to_bits());
        assert_eq!(a.goodput_ratio.to_bits(), b.goodput_ratio.to_bits());
        assert_eq!(a.utilization.to_bits(), b.utilization.to_bits());
        assert_eq!(a.last_completion_s.to_bits(), b.last_completion_s.to_bits());
        assert_eq!(a.migrations, b.migrations);
        assert_eq!(a.rollbacks, b.rollbacks);
        assert_eq!((a.spurious_migrations, a.quarantines, a.quarantine_releases), (0, 0, 0));
        assert_eq!(a.degraded_node_s.to_bits(), 0f64.to_bits());
    }

    // ... and byte-identical through the threaded sweep too
    let trials = 4;
    let za = run_sweep(&SweepSpec {
        threads: Some(1),
        ..SweepSpec::new(vec![CellSpec::fleet(zeroed, FleetMetric::MeanSlowdown, 7)], trials)
    });
    let pb = run_sweep(&SweepSpec {
        threads: Some(8),
        ..SweepSpec::new(vec![CellSpec::fleet(plain, FleetMetric::MeanSlowdown, 7)], trials)
    });
    assert_eq!(za[0].mean.to_bits(), pb[0].mean.to_bits());
    assert_eq!(za[0].std.to_bits(), pb[0].std.to_bits());
    assert_eq!(za[0].p95.to_bits(), pb[0].p95.to_bits());
}

#[test]
fn perfect_detector_reproduces_the_legacy_coin_byte_for_byte() {
    // DetectorModel::perfect(pf) is the legacy `predictable_frac` coin:
    // same coverage bits, precision 1 emits no false alarms, zero jitter
    // takes no lead draw — the trial is byte-identical even though the
    // plane reports itself on.
    let plain = FleetSpec::placentia_fleet(Strategy::Hybrid, 24, 6.0, 1.0);
    let mut detected = plain.clone();
    detected.gray.detector = Some(DetectorModel::perfect(plain.job.predictable_frac));
    assert!(!detected.gray.is_off());
    for seed in [0u64, 5, 91] {
        let a = run_fleet(&detected, seed);
        let b = run_fleet(&plain, seed);
        assert_eq!(a.events, b.events, "seed {seed}");
        assert_eq!(a.jobs_completed, b.jobs_completed);
        assert_eq!(a.mean_slowdown.to_bits(), b.mean_slowdown.to_bits());
        assert_eq!(a.utilization.to_bits(), b.utilization.to_bits());
        assert_eq!(a.last_completion_s.to_bits(), b.last_completion_s.to_bits());
        assert_eq!(a.migrations, b.migrations);
        assert_eq!(a.spurious_migrations, 0, "a perfect detector never cries wolf");
    }
}

#[test]
fn gray_fleet_is_pure_and_thread_count_invariant() {
    // With the plane on, the trial stays a pure function of (spec, seed):
    // every gray draw comes from a salted side-stream keyed by
    // (seed, kind, node-or-event), never from the main RNG streams.
    let spec = gray_spec();
    for seed in [2u64, 13, 77] {
        let a = run_fleet(&spec, seed);
        let b = run_fleet(&spec, seed);
        assert_eq!(a.events, b.events, "seed {seed}");
        assert_eq!(a.jobs_completed, b.jobs_completed);
        assert_eq!(a.mean_slowdown.to_bits(), b.mean_slowdown.to_bits());
        assert_eq!(a.last_completion_s.to_bits(), b.last_completion_s.to_bits());
        assert_eq!(a.spurious_migrations, b.spurious_migrations);
        assert_eq!(a.quarantines, b.quarantines);
        assert_eq!(a.quarantine_releases, b.quarantine_releases);
        assert_eq!(a.degraded_node_s.to_bits(), b.degraded_node_s.to_bits());
    }
    // the fixture actually exercises every gray dimension
    let o = run_fleet(&spec, 2);
    assert!(o.spurious_migrations > 0, "imperfect detector drew nothing: {o:?}");
    assert!(o.quarantines > 0, "flap bursts never crossed the threshold: {o:?}");
    assert!(o.degraded_node_s > 0.0, "fail-slow sampled no episodes: {o:?}");

    assert_sweep_thread_invariant(vec![CellSpec::fleet(spec, FleetMetric::Goodput, 41)], 5);
}

#[test]
fn no_job_lost_under_gray_faults() {
    // Degraded, never lost: the full default checker set (including
    // no-lost-job and the storm/quarantine bounds) holds under the
    // hostile gray fixture.
    let mut scratch = FleetScratch::new();
    for seed in [1u64, 42, 1337] {
        let mut obs = InvariantObserver::new(32);
        let o = run_fleet_observed(&gray_spec(), seed, &mut scratch, &mut obs);
        assert!(
            obs.violation().is_none(),
            "gray faults degrade, never lose: {:?}",
            obs.violation()
        );
        assert!(o.jobs_completed > 0, "seed {seed}: {o:?}");
    }
}

/// Occupancy on a quarantined node may only fall: placement, migration
/// targeting and queue drain must all skip it.
#[derive(Default)]
struct NoQuarantinedPlacement {
    prev: Vec<usize>,
}

impl Invariant for NoQuarantinedPlacement {
    fn name(&self) -> &'static str {
        "no-quarantined-placement"
    }
    fn check(&mut self, _ev: &FleetEv, view: &FleetView<'_>) -> Result<(), String> {
        if self.prev.len() == view.occupancy.len() {
            for (v, (&occ, &prev)) in view.occupancy.iter().zip(&self.prev).enumerate() {
                if view.quarantined[v] && occ > prev {
                    return Err(format!(
                        "node {v} gained a sub while quarantined ({prev} -> {occ})"
                    ));
                }
            }
        }
        self.prev.clear();
        self.prev.extend_from_slice(view.occupancy);
        Ok(())
    }
}

#[test]
fn quarantined_nodes_never_receive_placements() {
    let mut scratch = FleetScratch::new();
    for seed in [3u64, 29, 404] {
        let mut obs = InvariantObserver::with_checkers(
            vec![Box::new(NoQuarantinedPlacement::default())],
            16,
        );
        let o = run_fleet_observed(&gray_spec(), seed, &mut scratch, &mut obs);
        assert!(obs.violation().is_none(), "seed {seed}: {:?}", obs.violation());
        assert!(o.quarantines > 0, "fixture must quarantine: {o:?}");
    }
}

#[test]
fn fleet_sweep_scratch_reuse_matches_fresh_trials() {
    // one cell, many trials through the sweep (workers reuse FleetScratch)
    // vs the same trials run fresh
    let spec = FleetSpec::placentia_fleet(Strategy::Hybrid, 24, 6.0, 1.0);
    let trials = 12;
    let cells = vec![CellSpec::fleet(spec.clone(), FleetMetric::MeanSlowdown, 55)];
    let swept = run_sweep(&SweepSpec { threads: Some(3), ..SweepSpec::new(cells, trials) });
    let fresh: Vec<f64> =
        (0..trials).map(|i| run_fleet(&spec, 55 + i as u64).mean_slowdown).collect();
    let want = biomaft::metrics::Summary::of(&fresh);
    assert_eq!(swept[0].n, want.n);
    assert_eq!(swept[0].mean.to_bits(), want.mean.to_bits());
    assert_eq!(swept[0].std.to_bits(), want.std.to_bits());
    assert_eq!(swept[0].median.to_bits(), want.median.to_bits());
    assert_eq!(swept[0].p95.to_bits(), want.p95.to_bits());
}
