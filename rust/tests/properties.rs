//! Property-based tests over coordinator invariants (testkit framework).

use biomaft::agentft::migration::{choose_target, simulate_agent_migration};
use biomaft::cluster::{preset, ClusterPreset};
use biomaft::coordinator::scheduler::Placement;
use biomaft::coreft::simulate_core_migration;
use biomaft::hybrid::negotiate::hybrid_reinstate_s;
use biomaft::hybrid::rules::{decide, RuleInputs};
use biomaft::job::DepGraph;
use biomaft::net::message::SubJobId;
use biomaft::net::{NodeId, Topology};
use biomaft::sim::engine::{pack_key, ActorId, Engine, EventQueue};
use biomaft::sim::{Rng, SimTime};
use biomaft::testkit::{forall, Gen};
use std::cmp::Reverse;
use std::collections::BinaryHeap;

fn any_preset(g: &mut Gen) -> ClusterPreset {
    *g.pick(&ClusterPreset::all())
}

#[test]
fn prop_migration_target_never_doomed() {
    // routing invariant: a sub-job is never relocated onto a core that is
    // itself predicted to fail
    forall(300, 101, |g| {
        let n = g.usize(1, 8);
        let adjacent: Vec<(NodeId, bool)> =
            (0..n).map(|i| (NodeId(i), g.bool())).collect();
        let mut rng = Rng::new(g.u64(0, u64::MAX - 1));
        match choose_target(&adjacent, &mut rng) {
            Some(t) => {
                let entry = adjacent.iter().find(|(id, _)| *id == t).unwrap();
                assert!(!entry.1, "picked doomed target {t:?}");
            }
            None => assert!(adjacent.iter().all(|(_, d)| *d), "None despite healthy option"),
        }
    });
}

#[test]
fn prop_des_episode_equals_closed_form() {
    // the DES protocol and the calibrated closed form are the same model
    forall(200, 102, |g| {
        let p = any_preset(g);
        let costs = preset(p).costs;
        let z = g.usize(0, 64);
        let data_kb = g.size_kb(10.0, 31.0);
        let proc_kb = g.size_kb(10.0, 31.0);
        let adjacent = vec![(NodeId(1), false)];
        let mut rng = Rng::new(g.u64(0, u64::MAX - 1));
        let a = simulate_agent_migration(&costs.agent, z, data_kb, proc_kb, &adjacent, &mut rng, 0.0)
            .unwrap();
        assert!((a.reinstate_s - costs.agent.reinstate_s(z, data_kb, proc_kb)).abs() < 1e-9);
        let c = simulate_core_migration(&costs.core, z, data_kb, proc_kb, &adjacent, &mut rng, 0.0)
            .unwrap();
        assert!((c.reinstate_s - costs.core.reinstate_s(z, data_kb, proc_kb)).abs() < 1e-9);
    });
}

#[test]
fn prop_timer_wheel_pops_exact_binary_heap_sequence() {
    // the hierarchical timer wheel must be order-indistinguishable from
    // the reference BinaryHeap under randomized push/pop interleavings:
    // equal-time ties (seq tie-break), sub-granule clusters, wheel-span
    // deltas and far-future overflow (beyond the ~4.9 h top level) all in
    // one queue. Pushes never precede the last popped time — the engine's
    // send_at clamp guarantees that invariant for the real queue.
    forall(120, 111, |g| {
        let mut wheel: EventQueue<u64> = EventQueue::new();
        let mut heap: BinaryHeap<Reverse<u128>> = BinaryHeap::new();
        let ops = g.usize(1, 400);
        let mut seq = 0u64;
        let mut now_ns = 0u64;
        for _ in 0..ops {
            if g.bool() || wheel.is_empty() {
                let delta_ns = match g.usize(0, 3) {
                    0 => 0,                                 // equal-time tie with `now`
                    1 => g.u64(0, 2_000_000),               // within/near one granule
                    2 => g.u64(0, 4 * 3_600_000_000_000),   // inside the wheel span
                    _ => g.u64(0, 400 * 3_600_000_000_000), // far-future overflow
                };
                let key = pack_key(SimTime(now_ns + delta_ns), seq);
                wheel.push(key, seq);
                heap.push(Reverse(key));
                seq += 1;
            } else {
                let want = heap.pop().unwrap().0;
                assert_eq!(wheel.peek_key(), Some(want), "peek diverged from heap");
                let (got, item) = wheel.pop().unwrap();
                assert_eq!(got, want, "pop order diverged from heap");
                assert_eq!(item as u128, got & u64::MAX as u128, "payload follows its key");
                now_ns = (want >> 64) as u64;
            }
            assert_eq!(wheel.len(), heap.len());
        }
        while let Some(Reverse(want)) = heap.pop() {
            let (got, _) = wheel.pop().unwrap();
            assert_eq!(got, want, "drain order diverged from heap");
        }
        assert!(wheel.pop().is_none());
        assert!(wheel.is_empty());
    });
}

#[test]
fn prop_engine_deterministic_trace() {
    // same seed + same dispatch program => identical event trace
    forall(60, 103, |g| {
        let seed = g.u64(0, u64::MAX - 1);
        let steps = g.usize(1, 200) as u32;
        let run = |seed: u64| {
            let mut eng: Engine<u32> = Engine::new();
            let mut rng = Rng::new(seed);
            eng.capture_log(|m| *m as u64);
            eng.schedule(SimTime::ZERO, ActorId(0), 0);
            eng.run(|_me, msg, out| {
                if msg < steps {
                    let delay = SimTime::from_micros(rng.uniform(1.0, 50.0));
                    out.send_in(delay, ActorId(0), msg + 1);
                }
            });
            eng.take_log()
        };
        assert_eq!(run(seed), run(seed));
    });
}

#[test]
fn prop_engine_time_monotone() {
    forall(50, 104, |g| {
        let seed = g.u64(0, u64::MAX - 1);
        let mut eng: Engine<u32> = Engine::new();
        let mut rng = Rng::new(seed);
        eng.capture_log(|m| *m as u64);
        eng.schedule(SimTime::ZERO, ActorId(0), 0);
        eng.run(|_me, msg, out| {
            if msg < 100 {
                out.send_in(SimTime::from_micros(rng.uniform(0.0, 10.0)), ActorId(0), msg + 1);
            }
        });
        let log = eng.log();
        for w in log.windows(2) {
            assert!(w[0].0 <= w[1].0, "virtual time went backwards");
        }
    });
}

#[test]
fn prop_reduction_tree_is_dag_with_single_root() {
    forall(200, 105, |g| {
        let leaves = g.usize(1, 200);
        let fan_in = g.usize(2, 16);
        let t = DepGraph::reduction_tree(leaves, fan_in);
        // topo_order panics on cycles
        let order = t.topo_order();
        assert_eq!(order.len(), t.len());
        assert_eq!(t.roots().len(), 1);
        assert_eq!(t.leaves().len(), leaves.min(t.len()));
        // every non-root has exactly one output
        for i in 0..t.len() {
            let s = SubJobId(i);
            if !t.roots().contains(&s) {
                assert_eq!(t.outputs(s).len(), 1);
            }
        }
    });
}

#[test]
fn prop_graph_fingerprint_preserved_across_placement() {
    // migration/placement must never mutate the dependency graph
    forall(100, 106, |g| {
        let leaves = g.usize(2, 64);
        let fan_in = g.usize(2, 8);
        let nodes = g.usize(2, 20);
        let t = DepGraph::reduction_tree(leaves, fan_in);
        let before = t.fingerprint();
        let topo = Topology::ring(nodes, 1.max(g.usize(1, 3)));
        let _p1 = Placement::round_robin(t.len(), &topo);
        let _p2 = Placement::spread(&t, &topo);
        assert_eq!(t.fingerprint(), before);
    });
}

#[test]
fn prop_hybrid_bounded_by_envelope() {
    // hybrid never exceeds max(agent, core) + negotiation
    forall(300, 107, |g| {
        let p = any_preset(g);
        let costs = preset(p).costs;
        let inp = RuleInputs {
            z: g.usize(0, 70),
            data_kb: g.size_kb(10.0, 32.0),
            proc_kb: g.size_kb(10.0, 32.0),
        };
        let h = hybrid_reinstate_s(&costs, inp);
        let a = costs.agent.reinstate_s(inp.z, inp.data_kb, inp.proc_kb);
        let c = costs.core.reinstate_s(inp.z, inp.data_kb, inp.proc_kb);
        assert!(h <= a.max(c) + 1e-3, "h={h} a={a} c={c}");
        // and the decision is total
        let _ = decide(inp);
    });
}

#[test]
fn prop_placement_total_and_in_range() {
    // no sub-job lost: every sub-job has exactly one host, in range
    forall(200, 108, |g| {
        let n_subs = g.usize(1, 300);
        let n_nodes = g.usize(1, 50);
        let topo = Topology::mesh(n_nodes);
        let p = Placement::round_robin(n_subs, &topo);
        assert_eq!(p.host.len(), n_subs);
        let mut seen = vec![0usize; n_nodes];
        for i in 0..n_subs {
            let h = p.node_of(SubJobId(i));
            assert!(h.0 < n_nodes);
            seen[h.0] += 1;
        }
        // round robin balance: max-min <= 1
        let max = seen.iter().max().unwrap();
        let min = seen.iter().min().unwrap();
        assert!(max - min <= 1, "imbalance {seen:?}");
        // on_node is the exact inverse
        let total: usize = (0..n_nodes).map(|n| p.on_node(NodeId(n)).len()).sum();
        assert_eq!(total, n_subs);
    });
}

#[test]
fn prop_trial_noise_preserves_ordering_in_the_mean() {
    // core < agent at Z<=10, S=2^24 must survive trial noise (30-trial mean)
    forall(30, 109, |g| {
        let costs = preset(ClusterPreset::Placentia).costs;
        let z = g.usize(3, 11);
        let mut rng = Rng::new(g.u64(0, u64::MAX - 1));
        let adjacent = vec![(NodeId(1), false), (NodeId(2), false)];
        let mean = |agent: bool, rng: &mut Rng| -> f64 {
            (0..30)
                .map(|_| {
                    if agent {
                        simulate_agent_migration(
                            &costs.agent, z, 1 << 24, 1 << 24, &adjacent, rng, 0.025,
                        )
                        .unwrap()
                        .reinstate_s
                    } else {
                        simulate_core_migration(
                            &costs.core, z, 1 << 24, 1 << 24, &adjacent, rng, 0.025,
                        )
                        .unwrap()
                        .reinstate_s
                    }
                })
                .sum::<f64>()
                / 30.0
        };
        let a = mean(true, &mut rng);
        let c = mean(false, &mut rng);
        assert!(c < a + 0.01, "z={z}: core {c} agent {a}");
    });
}

#[test]
fn prop_topologies_symmetric_and_self_free() {
    forall(150, 110, |g| {
        let n = g.usize(2, 60);
        let topo = match g.usize(0, 3) {
            0 => Topology::ring(n, g.usize(1, 4)),
            1 => Topology::star(n),
            _ => Topology::mesh(n),
        };
        for a in topo.nodes() {
            assert!(!topo.neighbours(a).contains(&a), "self-loop at {a:?}");
            for &b in topo.neighbours(a) {
                assert!(topo.are_adjacent(b, a), "asymmetric edge {a:?}-{b:?}");
            }
        }
    });
}
