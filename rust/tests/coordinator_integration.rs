//! Integration: full failure→prediction→migration→completion story on the
//! simulated cluster, composing injector, prober, predictor, scheduler and
//! the migration episodes.

use biomaft::agentft::simulate_agent_migration;
use biomaft::cluster::core::{Core, CoreId, CoreState};
use biomaft::cluster::{preset, ClusterPreset};
use biomaft::coordinator::ftmanager::Strategy;
use biomaft::coordinator::run::{window_row, ExperimentCfg};
use biomaft::coordinator::scheduler::Placement;
use biomaft::failure::injector::FailureProcess;
use biomaft::failure::predictor::Predictor;
use biomaft::failure::prober::Prober;
use biomaft::job::{DepGraph, Job, JobKind};
use biomaft::net::{NodeId, Topology};
use biomaft::sim::{Rng, SimTime};

/// Drive a probing loop on a doomed core until prediction, then migrate.
#[test]
fn failure_predicted_then_job_relocated_and_completed() {
    let cluster = preset(ClusterPreset::Placentia);
    let topo = Topology::ring(8, 2);
    let graph = DepGraph::search_combine(3); // genome job: 3 searchers + combiner
    let mut job = Job::decompose(JobKind::GenomeSearch, graph.len(), 1 << 19, 1 << 19, 3600.0);
    let placement = Placement::round_robin(job.n_subs(), &topo);

    // inject one failure on the node hosting sub-job 1
    let victim_sub = biomaft::net::message::SubJobId(1);
    let victim = placement.node_of(victim_sub);
    let mut rng = Rng::new(5);
    let plan = FailureProcess::Periodic { offset_s: 840.0 }.plan(1, 3600.0, 1, &mut rng);
    let fails_at = plan.events[0].at;

    // probing loop on the victim core
    let mut core = Core::new(CoreId(victim.0), 64);
    core.state = CoreState::Doomed { fails_at };
    let prober = Prober::default();
    let predictor = Predictor::default();
    let mut t = 0.0;
    let mut predicted_at = None;
    while t < fails_at.as_secs() {
        prober.probe(&mut core, SimTime::from_secs(t), &mut rng);
        if let Some(p) = predictor.evaluate(&core, SimTime::from_secs(t)) {
            predicted_at = Some(p.at);
            break;
        }
        t += prober.period_s;
    }
    let predicted_at = predicted_at.expect("drifty failure must be predicted");
    assert!(predicted_at < fails_at, "prediction must precede the failure");

    // migrate: adjacency view marks the victim's neighbours healthy
    let adjacent = placement.adjacency_view(victim_sub, &topo, |_| false);
    job.subs[victim_sub.0].state = biomaft::job::SubJobState::Migrating;
    let out = simulate_agent_migration(
        &cluster.costs.agent,
        graph.z(victim_sub),
        1 << 19,
        1 << 19,
        &adjacent,
        &mut rng,
        0.02,
    )
    .expect("healthy neighbours exist");
    assert!(out.reinstate_s < 1.0, "sub-second reinstatement: {}", out.reinstate_s);
    assert!(topo.are_adjacent(victim, out.target), "moved to an adjacent node");

    // reinstatement completes before the hardware actually fails only if
    // prediction left enough lead; check the timeline composes
    let done_at = predicted_at.as_secs() + out.reinstate_s;
    assert!(done_at < fails_at.as_secs(), "migration completed before the failure struck");

    // job finishes: mark everything done
    for s in &mut job.subs {
        s.state = biomaft::job::SubJobState::Done;
    }
    assert!(job.all_done());
    assert!(!job.any_lost());
}

/// The four-cluster story of the figures composes through the public API.
#[test]
fn cross_cluster_reinstate_orderings() {
    for z in [4usize, 10] {
        let mut times = Vec::new();
        for p in ClusterPreset::all() {
            let cfg = ExperimentCfg {
                z,
                trials: 20,
                ..ExperimentCfg::table1(preset(p))
            };
            let mut rng = Rng::new(77);
            let s = biomaft::coordinator::run::measure_reinstate(Strategy::Agent, &cfg, &mut rng);
            times.push((p.name(), s.mean));
        }
        // acet slowest, placentia fastest
        assert!(times[0].1 > times[3].1, "{times:?}");
    }
}

/// Table rows compose with every strategy without panicking, across
/// periodicities and clusters.
#[test]
fn window_rows_compose_everywhere() {
    for p in [ClusterPreset::Placentia, ClusterPreset::Acet] {
        for period in [1.0, 2.0, 4.0] {
            let cfg = ExperimentCfg::table2(preset(p), period);
            for s in Strategy::all_table2() {
                let r = window_row(s, &cfg);
                assert!(r.total_nofail_s <= r.total_one_periodic_s);
                assert!(r.total_one_periodic_s <= r.total_five_random_s + 1.0);
            }
        }
    }
}

/// Unpredictable failures (no drift) are NOT predicted — the 71 % the paper
/// says the approach misses; they must fall through to checkpointing.
#[test]
fn unpredictable_failure_not_predicted() {
    let mut core = Core::new(CoreId(0), 64);
    // instantaneous failure: doomed with zero lead (state stays healthy-looking)
    let prober = Prober { drift_lead_s: 0.0, ..Default::default() };
    let predictor = Predictor::default();
    let mut rng = Rng::new(3);
    core.state = CoreState::Doomed { fails_at: SimTime::from_secs(500.0) };
    let mut t = 0.0;
    while t < 500.0 {
        prober.probe(&mut core, SimTime::from_secs(t), &mut rng);
        assert!(
            predictor.evaluate(&core, SimTime::from_secs(t)).is_none(),
            "no-drift failure must not be predicted (t={t})"
        );
        t += prober.period_s;
    }
}

/// Agents can survive several failures in sequence (migration storm):
/// state machine stays consistent and the job is never lost.
#[test]
fn migration_storm_preserves_job() {
    let cluster = preset(ClusterPreset::Glooscap);
    let topo = Topology::ring(16, 2);
    let mut agent = biomaft::agentft::Agent::new(
        biomaft::net::message::SubJobId(0),
        1,
        "genome_search",
        1 << 20,
        1 << 20,
        NodeId(0),
        vec![biomaft::net::message::SubJobId(1), biomaft::net::message::SubJobId(2)],
    );
    let mut rng = Rng::new(11);
    for round in 0..10 {
        let adjacent: Vec<(NodeId, bool)> = topo
            .neighbours(agent.home)
            .iter()
            .enumerate()
            .map(|(i, &n)| (n, i == 0 && round % 2 == 0)) // some neighbours doomed
            .collect();
        let out = simulate_agent_migration(
            &cluster.costs.agent,
            agent.z(),
            agent.data_kb,
            agent.proc_kb,
            &adjacent,
            &mut rng,
            0.02,
        )
        .expect("ring always has a healthy neighbour");
        agent.start_move(out.target);
        agent.finish_move();
        assert_eq!(agent.home, out.target);
        // dependencies survive every hop
        assert_eq!(agent.z(), 2);
    }
    assert_eq!(agent.moves, 10);
    assert!(matches!(agent.state, biomaft::agentft::AgentState::Executing));
}
