//! Property tests over the scenario runtime (`sim::harness`) and the
//! scenario layer:
//!
//! * same seed ⇒ byte-identical event traces through the harness;
//! * a single-failure `ScenarioSpec` reproduces `run_live` bit-for-bit for
//!   every multi-agent strategy;
//! * batch results are independent of the thread count — including under
//!   the work-stealing chunk scheduler with skewed (`Cascade`) trial costs;
//! * `TrialScratch`/`LiveScratch` reuse never changes a result.

use biomaft::cluster::{preset, ClusterPreset};
use biomaft::coordinator::ftmanager::Strategy;
use biomaft::coordinator::livesim::run_live;
use biomaft::failure::injector::FailureProcess;
use biomaft::scenario::{
    parallel_map_trials, parallel_map_trials_scratch, run_batch, BatchCfg, FailureRegime,
    LiveScratch, ScenarioSpec,
};
use biomaft::sim::{Ctx, Harness, Rng, Scenario, SimTime};
use biomaft::testkit::forall;

/// A randomly re-arming actor: the harness analogue of the engine-level
/// determinism property, exercising ctx scheduling, rng and jitter.
struct Chatter {
    remaining: u32,
    sigma: f64,
}

impl Scenario for Chatter {
    type Msg = u32;
    fn on_msg(&mut self, ctx: &mut Ctx<'_, '_, u32>, msg: u32) {
        if self.remaining == 0 {
            ctx.finish();
            return;
        }
        self.remaining -= 1;
        ctx.record("hop", 0.0);
        let delay_us = ctx.rng().uniform(1.0, 50.0);
        let j = ctx.jitter(self.sigma);
        ctx.send_self_in_s(delay_us * 1e-6 * j, msg + 1);
    }
}

#[test]
fn prop_harness_same_seed_byte_identical_trace() {
    forall(60, 201, |g| {
        let seed = g.u64(0, u64::MAX - 1);
        let steps = g.usize(1, 200) as u32;
        let sigma = g.f64(0.0, 0.1);
        let run = |seed: u64| {
            let mut h: Harness<Chatter> = Harness::with_seed(seed);
            h.capture_log(|m| *m as u64);
            let id = h.add(Chatter { remaining: steps, sigma });
            h.schedule(SimTime::ZERO, id, 0);
            let fin = h.run();
            (format!("{:?}", fin.log), fin.finished_at, fin.events)
        };
        let (log_a, fin_a, ev_a) = run(seed);
        let (log_b, fin_b, ev_b) = run(seed);
        // byte-identical trace, same finish, same dispatch count
        assert_eq!(log_a.as_bytes(), log_b.as_bytes());
        assert_eq!(fin_a, fin_b);
        assert_eq!(ev_a, ev_b);
    });
}

#[test]
fn prop_single_failure_spec_reproduces_run_live_every_strategy() {
    // The refactor's contract: wrapping the paper's single-failure regime
    // in a ScenarioSpec changes nothing, for every multi-agent strategy.
    forall(40, 202, |g| {
        let strategy = *g.pick(&[Strategy::Agent, Strategy::Core, Strategy::Hybrid]);
        let seed = g.u64(0, u64::MAX - 1);
        let predictable = g.f64(0.0, 1.0);
        let process = if g.bool() {
            FailureProcess::Periodic { offset_s: g.f64(60.0, 3000.0) }
        } else {
            FailureProcess::RandomUniform
        };
        let spec = ScenarioSpec::placentia_ring16(
            strategy,
            predictable,
            8,
            FailureRegime::Single(process),
        );

        let via_spec = spec.run_trial(seed);

        // replicate by hand: same plan stream, then the plain live run
        let mut plan_rng = Rng::new(seed ^ 0x5EED_F00D_0BAD_CAFE);
        let plan = spec.plan(&mut plan_rng);
        let mut cfg = spec.cfg.clone();
        cfg.seed = seed;
        let direct = run_live(&cfg, &spec.topo, &plan);

        assert_eq!(via_spec.completed_at_s.to_bits(), direct.completed_at_s.to_bits());
        assert_eq!(via_spec.events, direct.events);
        assert_eq!(via_spec.migrations, direct.migrations);
        assert_eq!(via_spec.rollbacks, direct.rollbacks);
        assert_eq!(via_spec.lost_then_recovered, direct.lost_then_recovered);
        assert_eq!(via_spec.cascades, 0);
    });
}

#[test]
fn prop_batch_results_independent_of_thread_count() {
    forall(12, 203, |g| {
        let seed = g.u64(0, u64::MAX - 1);
        let trials = g.usize(2, 24);
        let threads_a = g.usize(1, 5);
        let threads_b = g.usize(1, 5);
        let spec = ScenarioSpec::placentia_ring16(
            Strategy::Hybrid,
            0.7,
            8,
            FailureRegime::Single(FailureProcess::RandomUniform),
        );
        let run = |threads: usize| {
            parallel_map_trials(trials, threads, |i| {
                spec.run_trial(seed.wrapping_add(i as u64)).completed_at_s.to_bits()
            })
        };
        assert_eq!(run(threads_a), run(threads_b));
    });
}

/// The skewed-cost fixture: cascade trials vary widely in cost, which is
/// exactly the regime the work-stealing chunk scheduler exists for.
fn cascade_spec() -> ScenarioSpec {
    ScenarioSpec::placentia_ring16(
        Strategy::Hybrid,
        0.8,
        16,
        FailureRegime::Cascade {
            trigger: FailureProcess::RandomUniformK { k: 2 },
            p_follow: 0.7,
            lag_s: 3.0,
        },
    )
}

#[test]
fn prop_workstealing_batch_byte_identical_to_serial_under_cascade() {
    // The scheduler's contract: dynamic chunk claiming changes which worker
    // runs a trial, never the trial itself — byte-identical to threads=1
    // even when trial costs are skewed.
    let spec = cascade_spec();
    forall(8, 205, |g| {
        let seed = g.u64(0, u64::MAX - 1);
        let trials = g.usize(2, 48);
        let threads = g.usize(2, 8);
        let run = |threads: usize| {
            parallel_map_trials(trials, threads, |i| {
                let o = spec.run_trial(seed.wrapping_add(i as u64));
                (o.completed_at_s.to_bits(), o.events, o.migrations, o.rollbacks, o.cascades)
            })
        };
        assert_eq!(run(1), run(threads));
    });
}

#[test]
fn run_batch_thread_count_invariant_under_cascade() {
    let spec = cascade_spec();
    let serial = run_batch(&spec, &BatchCfg { trials: 32, base_seed: 77, threads: 1 });
    let stolen = run_batch(&spec, &BatchCfg { trials: 32, base_seed: 77, threads: 5 });
    assert_eq!(serial.completed_s, stolen.completed_s);
    assert_eq!(serial.migrations, stolen.migrations);
    assert_eq!(serial.rollbacks, stolen.rollbacks);
    assert_eq!(serial.cascades, stolen.cascades);
    assert_eq!(serial.events, stolen.events);
}

#[test]
fn prop_trial_scratch_reuse_leaks_no_state() {
    // A worker's scratch is threaded through many trials; every reused
    // trial must be bit-identical to a fresh-allocation trial — across
    // regimes, so a cheap trial recycled into an expensive one (and vice
    // versa) cannot inherit stale queue/log/state.
    let specs = [
        cascade_spec(),
        ScenarioSpec::placentia_ring16(
            Strategy::Agent,
            0.6,
            8,
            FailureRegime::ConcurrentK { k: 4, offset_s: 600.0, spacing_s: 30.0 },
        ),
        ScenarioSpec::placentia_ring16(
            Strategy::Core,
            0.9,
            8,
            FailureRegime::Single(FailureProcess::RandomUniform),
        ),
    ];
    forall(6, 206, |g| {
        let seed = g.u64(0, u64::MAX - 1);
        let mut scratch = LiveScratch::new();
        for round in 0..3 {
            for (si, spec) in specs.iter().enumerate() {
                let s = seed.wrapping_add((round * specs.len() + si) as u64);
                let fresh = spec.run_trial(s);
                let reused = spec.run_trial_scratch(s, &mut scratch);
                assert_eq!(fresh.completed_at_s.to_bits(), reused.completed_at_s.to_bits());
                assert_eq!(fresh.events, reused.events);
                assert_eq!(fresh.migrations, reused.migrations);
                assert_eq!(fresh.rollbacks, reused.rollbacks);
                assert_eq!(fresh.cascades, reused.cascades);
                assert_eq!(fresh.lost_then_recovered, reused.lost_then_recovered);
            }
        }
    });
}

#[test]
fn scratch_workers_match_stateless_workers() {
    // parallel_map_trials_scratch with a real LiveScratch ≡ the stateless
    // mapping, at every thread count.
    let spec = cascade_spec();
    let stateless: Vec<u64> =
        parallel_map_trials(24, 1, |i| spec.run_trial(1000 + i as u64).events);
    for threads in [1usize, 3, 8] {
        let with_scratch = parallel_map_trials_scratch(24, threads, LiveScratch::new, |sc, i| {
            spec.run_trial_scratch(1000 + i as u64, sc).events
        });
        assert_eq!(stateless, with_scratch, "threads={threads}");
    }
}

#[test]
fn prop_measure_reinstate_stable_under_repeat() {
    // The serial-draw / parallel-execute split in measure_reinstate must be
    // a pure function of the RNG stream.
    use biomaft::coordinator::run::{measure_reinstate, ExperimentCfg};
    forall(20, 204, |g| {
        let strategy = *g.pick(&[Strategy::Agent, Strategy::Core, Strategy::Hybrid]);
        let seed = g.u64(0, u64::MAX - 1);
        let trials = g.usize(1, 80);
        let cfg = ExperimentCfg {
            z: g.usize(0, 20),
            trials,
            ..ExperimentCfg::table1(preset(ClusterPreset::Placentia))
        };
        let a = measure_reinstate(strategy, &cfg, &mut Rng::new(seed));
        let b = measure_reinstate(strategy, &cfg, &mut Rng::new(seed));
        assert_eq!(a.mean.to_bits(), b.mean.to_bits());
        assert_eq!(a.std.to_bits(), b.std.to_bits());
        assert_eq!(a.n, trials.max(1));
    });
}
