//! Property tests over the scenario runtime (`sim::harness`) and the
//! scenario layer:
//!
//! * same seed ⇒ byte-identical event traces through the harness;
//! * a single-failure `ScenarioSpec` reproduces `run_live` bit-for-bit for
//!   every multi-agent strategy;
//! * batch results are independent of the thread count.

use biomaft::cluster::{preset, ClusterPreset};
use biomaft::coordinator::ftmanager::Strategy;
use biomaft::coordinator::livesim::run_live;
use biomaft::failure::injector::FailureProcess;
use biomaft::scenario::{parallel_map_trials, FailureRegime, ScenarioSpec};
use biomaft::sim::{Ctx, Harness, Rng, Scenario, SimTime};
use biomaft::testkit::forall;

/// A randomly re-arming actor: the harness analogue of the engine-level
/// determinism property, exercising ctx scheduling, rng and jitter.
struct Chatter {
    remaining: u32,
    sigma: f64,
}

impl Scenario for Chatter {
    type Msg = u32;
    fn on_msg(&mut self, ctx: &mut Ctx<'_, '_, u32>, msg: u32) {
        if self.remaining == 0 {
            ctx.finish();
            return;
        }
        self.remaining -= 1;
        ctx.record("hop", 0.0);
        let delay_us = ctx.rng().uniform(1.0, 50.0);
        let j = ctx.jitter(self.sigma);
        ctx.send_self_in_s(delay_us * 1e-6 * j, msg + 1);
    }
}

#[test]
fn prop_harness_same_seed_byte_identical_trace() {
    forall(60, 201, |g| {
        let seed = g.u64(0, u64::MAX - 1);
        let steps = g.usize(1, 200) as u32;
        let sigma = g.f64(0.0, 0.1);
        let run = |seed: u64| {
            let mut h: Harness<Chatter> = Harness::with_seed(seed);
            h.capture_log(|m| *m as u64);
            let id = h.add(Chatter { remaining: steps, sigma });
            h.schedule(SimTime::ZERO, id, 0);
            let fin = h.run();
            (format!("{:?}", fin.log), fin.finished_at, fin.events)
        };
        let (log_a, fin_a, ev_a) = run(seed);
        let (log_b, fin_b, ev_b) = run(seed);
        // byte-identical trace, same finish, same dispatch count
        assert_eq!(log_a.as_bytes(), log_b.as_bytes());
        assert_eq!(fin_a, fin_b);
        assert_eq!(ev_a, ev_b);
    });
}

#[test]
fn prop_single_failure_spec_reproduces_run_live_every_strategy() {
    // The refactor's contract: wrapping the paper's single-failure regime
    // in a ScenarioSpec changes nothing, for every multi-agent strategy.
    forall(40, 202, |g| {
        let strategy = *g.pick(&[Strategy::Agent, Strategy::Core, Strategy::Hybrid]);
        let seed = g.u64(0, u64::MAX - 1);
        let predictable = g.f64(0.0, 1.0);
        let process = if g.bool() {
            FailureProcess::Periodic { offset_s: g.f64(60.0, 3000.0) }
        } else {
            FailureProcess::RandomUniform
        };
        let spec = ScenarioSpec::placentia_ring16(
            strategy,
            predictable,
            8,
            FailureRegime::Single(process),
        );

        let via_spec = spec.run_trial(seed);

        // replicate by hand: same plan stream, then the plain live run
        let mut plan_rng = Rng::new(seed ^ 0x5EED_F00D_0BAD_CAFE);
        let plan = spec.plan(&mut plan_rng);
        let mut cfg = spec.cfg.clone();
        cfg.seed = seed;
        let direct = run_live(&cfg, &spec.topo, &plan);

        assert_eq!(via_spec.completed_at_s.to_bits(), direct.completed_at_s.to_bits());
        assert_eq!(via_spec.events, direct.events);
        assert_eq!(via_spec.migrations, direct.migrations);
        assert_eq!(via_spec.rollbacks, direct.rollbacks);
        assert_eq!(via_spec.lost_then_recovered, direct.lost_then_recovered);
        assert_eq!(via_spec.cascades, 0);
    });
}

#[test]
fn prop_batch_results_independent_of_thread_count() {
    forall(12, 203, |g| {
        let seed = g.u64(0, u64::MAX - 1);
        let trials = g.usize(2, 24);
        let threads_a = g.usize(1, 5);
        let threads_b = g.usize(1, 5);
        let spec = ScenarioSpec::placentia_ring16(
            Strategy::Hybrid,
            0.7,
            8,
            FailureRegime::Single(FailureProcess::RandomUniform),
        );
        let run = |threads: usize| {
            parallel_map_trials(trials, threads, |i| {
                spec.run_trial(seed.wrapping_add(i as u64)).completed_at_s.to_bits()
            })
        };
        assert_eq!(run(threads_a), run(threads_b));
    });
}

#[test]
fn prop_measure_reinstate_stable_under_repeat() {
    // The serial-draw / parallel-execute split in measure_reinstate must be
    // a pure function of the RNG stream.
    use biomaft::coordinator::run::{measure_reinstate, ExperimentCfg};
    forall(20, 204, |g| {
        let strategy = *g.pick(&[Strategy::Agent, Strategy::Core, Strategy::Hybrid]);
        let seed = g.u64(0, u64::MAX - 1);
        let trials = g.usize(1, 80);
        let cfg = ExperimentCfg {
            z: g.usize(0, 20),
            trials,
            ..ExperimentCfg::table1(preset(ClusterPreset::Placentia))
        };
        let a = measure_reinstate(strategy, &cfg, &mut Rng::new(seed));
        let b = measure_reinstate(strategy, &cfg, &mut Rng::new(seed));
        assert_eq!(a.mean.to_bits(), b.mean.to_bits());
        assert_eq!(a.std.to_bits(), b.std.to_bits());
        assert_eq!(a.n, trials.max(1));
    });
}
