//! Jobs, sub-jobs and their dependency structure.
//!
//! A job `J` is decomposed into sub-jobs `J_1..J_n` (paper, Methods Step 1);
//! the dependency graph for the empirical study is the parallel-reduction
//! tree of Fig. 7, and for the genome study a search/combine star.

pub mod graph;
pub mod molecular;
pub mod spec;

pub use graph::{DepGraph, GraphKind};
pub use molecular::{Decomposition, MdConfig};
pub use spec::{Job, JobKind, SubJob, SubJobState};
