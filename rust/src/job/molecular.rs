//! Molecular-dynamics decompositions — the paper's motivating workload for
//! the decision rules (Decision Making Rules section).
//!
//! The paper describes three ways to parallelise an MD simulation and ties
//! each to the dependency/data/process-size profile that drives the
//! agent-vs-core choice:
//!
//! * **atom decomposition** — a group of atoms per core; interactions are
//!   global, so dependencies are high and grow with the core count;
//! * **force decomposition** — a block of the force matrix per core; scales
//!   better, dependencies along matrix rows/columns;
//! * **spatial decomposition** — a 3-D region per core; interactions are
//!   local to adjacent regions, so Z is the region's neighbour count.
//!
//! `md_profile` maps a simulation configuration to the `(Z, S_d, S_p)`
//! inputs of [`crate::hybrid::rules::decide`], and `md_job` builds the
//! dependency graph for the simulation's halo exchanges.

use super::graph::{DepGraph, GraphKind};
use crate::hybrid::rules::{decide, Mover, RuleInputs};
use crate::net::message::SubJobId;

/// The three MD parallelisation strategies of the paper.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Decomposition {
    Atom,
    Force,
    Spatial,
}

/// An MD simulation configuration.
#[derive(Debug, Clone, Copy)]
pub struct MdConfig {
    pub decomposition: Decomposition,
    /// Number of cores the simulation is decomposed over.
    pub n_cores: usize,
    /// Total atoms simulated.
    pub n_atoms: usize,
    /// Bytes of state per atom (positions, velocities, forces, history).
    pub bytes_per_atom: u64,
    /// Simulated steps between checkpoints (drives accumulated state).
    pub steps_per_window: u64,
}

impl MdConfig {
    /// Atoms handled per core.
    pub fn atoms_per_core(&self) -> usize {
        self.n_atoms.div_ceil(self.n_cores)
    }

    /// The paper's `Z` for a sub-job of this decomposition.
    ///
    /// * atom: interactions are global — every other core is a dependency;
    /// * force: row + column blocks of the force matrix (2·(√P − 1));
    /// * spatial: the face-neighbour stencil of a 3-D region (6 under the
    ///   periodic face-exchange of `spatial_stencil`).
    pub fn z(&self) -> usize {
        match self.decomposition {
            Decomposition::Atom => self.n_cores.saturating_sub(1),
            Decomposition::Force => {
                let side = (self.n_cores as f64).sqrt().round().max(1.0) as usize;
                2 * side.saturating_sub(1)
            }
            Decomposition::Spatial => 6.min(self.n_cores.saturating_sub(1)),
        }
    }

    /// Data size per core in KB (the paper's S_d): the atoms a core owns
    /// plus the halo it needs.
    pub fn data_kb(&self) -> u64 {
        let own = self.atoms_per_core() as u64 * self.bytes_per_atom;
        let halo_factor = match self.decomposition {
            Decomposition::Atom => 2.0,    // global exchange buffers
            Decomposition::Force => 1.5,   // row/col blocks
            Decomposition::Spatial => 1.2, // thin shells
        };
        ((own as f64 * halo_factor) / 1024.0).ceil() as u64
    }

    /// Process size per core in KB (the paper's S_p): working state grows
    /// with the trajectory history accumulated between checkpoints.
    pub fn proc_kb(&self) -> u64 {
        let history = self.atoms_per_core() as u64
            * self.bytes_per_atom
            * (self.steps_per_window / 100).max(1);
        (history / 1024).max(1)
    }

    pub fn rule_inputs(&self) -> RuleInputs {
        RuleInputs { z: self.z(), data_kb: self.data_kb(), proc_kb: self.proc_kb() }
    }

    /// Which approach the rules select for this simulation.
    pub fn recommended(&self) -> Mover {
        decide(self.rule_inputs()).0
    }
}

/// Build the halo-exchange dependency graph of a spatial decomposition over
/// a `nx × ny × nz` region grid (periodic boundaries): each region depends
/// on its face neighbours. Atom/force decompositions reduce over all-to-all
/// and block rows which the reduction-tree/search-combine builders already
/// model; spatial needs its own stencil.
pub fn spatial_stencil(nx: usize, ny: usize, nz: usize) -> DepGraph {
    assert!(nx > 0 && ny > 0 && nz > 0);
    let n = nx * ny * nz;
    let idx = |x: usize, y: usize, z: usize| -> usize { (z * ny + y) * nx + x };
    let mut g = DepGraph::raw(GraphKind::Stencil { nx, ny, nz }, n);
    for z in 0..nz {
        for y in 0..ny {
            for x in 0..nx {
                let me = idx(x, y, z);
                // +x, +y, +z face neighbours (periodic); the reverse edges
                // come from the neighbours' own loops.
                for (dx, dy, dz) in [(1usize, 0usize, 0usize), (0, 1, 0), (0, 0, 1)] {
                    let nb = idx((x + dx) % nx, (y + dy) % ny, (z + dz) % nz);
                    if nb != me {
                        g.add_edge_pub(SubJobId(me), SubJobId(nb));
                    }
                }
            }
        }
    }
    g
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(d: Decomposition, cores: usize) -> MdConfig {
        MdConfig {
            decomposition: d,
            n_cores: cores,
            n_atoms: 1_000_000,
            bytes_per_atom: 512,
            steps_per_window: 1000,
        }
    }

    #[test]
    fn z_profiles_match_paper_narrative() {
        // atom: global interactions — highest Z; spatial: local — lowest
        let atom = cfg(Decomposition::Atom, 64).z();
        let force = cfg(Decomposition::Force, 64).z();
        let spatial = cfg(Decomposition::Spatial, 64).z();
        assert!(atom > force && force > spatial, "{atom} {force} {spatial}");
        assert_eq!(atom, 63);
        assert_eq!(force, 14);
        assert_eq!(spatial, 6);
    }

    #[test]
    fn spatial_small_cluster_caps_z() {
        assert_eq!(cfg(Decomposition::Spatial, 8).z(), 6);
        assert_eq!(cfg(Decomposition::Spatial, 4).z(), 3);
    }

    #[test]
    fn rules_pick_core_for_spatial_small_sim() {
        // small spatial sim on few cores: Z <= 10 → core intelligence
        let c = MdConfig {
            decomposition: Decomposition::Spatial,
            n_cores: 8,
            n_atoms: 100_000,
            bytes_per_atom: 256,
            steps_per_window: 100,
        };
        assert!(c.z() <= 10);
        assert_eq!(c.recommended(), Mover::Core);
    }

    #[test]
    fn rules_pick_agent_for_atom_decomposition_small_data() {
        // atom decomposition on many cores: Z > 10; with modest data the
        // rules fall to Rule 2 → agent
        let c = cfg(Decomposition::Atom, 64);
        assert!(c.z() > 10);
        assert!(c.data_kb() <= 1 << 24);
        assert_eq!(c.recommended(), Mover::Agent);
    }

    #[test]
    fn long_windows_inflate_process_size() {
        let short = cfg(Decomposition::Spatial, 64);
        let long = MdConfig { steps_per_window: 100_000, ..short };
        assert!(long.proc_kb() >= 100 * short.proc_kb());
    }

    #[test]
    fn stencil_shape() {
        let g = spatial_stencil(4, 4, 4);
        assert_eq!(g.len(), 64);
        // periodic 3-face stencil: undirected degree 6 → z = 6 (3 in, 3 out)
        for i in 0..64 {
            assert_eq!(g.z(SubJobId(i)), 6, "region {i}");
        }
    }

    #[test]
    fn stencil_degenerate_axes() {
        let g = spatial_stencil(1, 1, 4); // a ring in z
        assert_eq!(g.len(), 4);
        for i in 0..4 {
            assert_eq!(g.z(SubJobId(i)), 2);
        }
    }

    #[test]
    fn data_kb_ordering_by_halo() {
        let a = cfg(Decomposition::Atom, 64).data_kb();
        let s = cfg(Decomposition::Spatial, 64).data_kb();
        assert!(a > s);
    }
}
