//! Job and sub-job descriptions.

use crate::net::message::SubJobId;

/// What the sub-jobs compute (selects the AOT executable on the real path).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobKind {
    /// Parallel summation (Fig. 7) — the empirical-study workload.
    Reduction,
    /// Genome pattern search + combine — the validation workload.
    GenomeSearch,
}

/// One sub-job: the unit carried by an agent / placed on a virtual core.
#[derive(Debug, Clone)]
pub struct SubJob {
    pub id: SubJobId,
    /// Input data size in KB (the paper's `S_d`).
    pub data_kb: u64,
    /// Process image size in KB (the paper's `S_p`).
    pub proc_kb: u64,
    /// Nominal compute duration in seconds of virtual time.
    pub compute_s: f64,
    pub state: SubJobState,
}

/// Lifecycle of a sub-job in the coordinator.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SubJobState {
    Pending,
    Running,
    /// Being relocated after a predicted failure.
    Migrating,
    Done,
    /// Lost to an unpredicted failure (must be recovered by a baseline).
    Lost,
}

/// A whole job.
#[derive(Debug, Clone)]
pub struct Job {
    pub kind: JobKind,
    pub subs: Vec<SubJob>,
    /// Nominal failure-free execution time in seconds (1 h and 5 h in the
    /// paper's tables).
    pub nominal_s: f64,
}

impl Job {
    /// Decompose a job into `n` identical sub-jobs (Methods, Step 1-2).
    pub fn decompose(kind: JobKind, n: usize, data_kb: u64, proc_kb: u64, nominal_s: f64) -> Self {
        assert!(n > 0, "job must have at least one sub-job");
        let subs = (0..n)
            .map(|i| SubJob {
                id: SubJobId(i),
                data_kb,
                proc_kb,
                compute_s: nominal_s,
                state: SubJobState::Pending,
            })
            .collect();
        Self { kind, subs, nominal_s }
    }

    pub fn n_subs(&self) -> usize {
        self.subs.len()
    }

    pub fn all_done(&self) -> bool {
        self.subs.iter().all(|s| s.state == SubJobState::Done)
    }

    pub fn any_lost(&self) -> bool {
        self.subs.iter().any(|s| s.state == SubJobState::Lost)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decompose_counts() {
        let j = Job::decompose(JobKind::Reduction, 8, 1 << 19, 1 << 19, 3600.0);
        assert_eq!(j.n_subs(), 8);
        assert!(j.subs.iter().all(|s| s.state == SubJobState::Pending));
        assert!(!j.all_done());
    }

    #[test]
    fn ids_are_dense() {
        let j = Job::decompose(JobKind::GenomeSearch, 4, 1, 1, 10.0);
        for (i, s) in j.subs.iter().enumerate() {
            assert_eq!(s.id.0, i);
        }
    }

    #[test]
    fn all_done_and_lost_flags() {
        let mut j = Job::decompose(JobKind::Reduction, 2, 1, 1, 1.0);
        j.subs[0].state = SubJobState::Done;
        assert!(!j.all_done());
        j.subs[1].state = SubJobState::Done;
        assert!(j.all_done());
        j.subs[0].state = SubJobState::Lost;
        assert!(j.any_lost());
    }

    #[test]
    #[should_panic]
    fn zero_subjobs_panics() {
        Job::decompose(JobKind::Reduction, 0, 1, 1, 1.0);
    }
}
