//! Dependency graphs: reduction trees (Fig. 7) and search/combine stars.
//!
//! `Z = d_i + d_o` — the paper's dependency count for a sub-job is its input
//! plus output degree; the experiments vary `Z` from 3 to 63 by widening the
//! fan-in of a node.

use crate::net::message::SubJobId;

/// How the graph was built (for reporting).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GraphKind {
    /// k-ary reduction tree with the given fan-in.
    ReductionTree { fan_in: usize },
    /// `n-1` searchers feeding one combiner (the genome job).
    SearchCombine,
    /// 3-D halo-exchange stencil (molecular-dynamics spatial decomposition).
    Stencil { nx: usize, ny: usize, nz: usize },
}

/// A DAG over sub-jobs: edges point from producer to consumer.
#[derive(Debug, Clone)]
pub struct DepGraph {
    pub kind: GraphKind,
    n: usize,
    /// children[i] = sub-jobs consuming i's output.
    children: Vec<Vec<SubJobId>>,
    /// parents[i] = sub-jobs whose output i consumes.
    parents: Vec<Vec<SubJobId>>,
}

impl DepGraph {
    fn empty(kind: GraphKind, n: usize) -> Self {
        Self { kind, n, children: vec![Vec::new(); n], parents: vec![Vec::new(); n] }
    }

    fn add_edge(&mut self, from: SubJobId, to: SubJobId) {
        self.children[from.0].push(to);
        self.parents[to.0].push(from);
    }

    /// Construct an empty graph for external builders (e.g.
    /// [`crate::job::molecular::spatial_stencil`]).
    pub fn raw(kind: GraphKind, n: usize) -> Self {
        Self::empty(kind, n)
    }

    /// Public edge insertion for external builders.
    pub fn add_edge_pub(&mut self, from: SubJobId, to: SubJobId) {
        self.add_edge(from, to);
    }

    /// Build a reduction tree over `leaves` leaf sub-jobs with fan-in `k`.
    /// Internal nodes are appended after the leaves; the root is the last
    /// sub-job. Total node count is returned by `len()`.
    pub fn reduction_tree(leaves: usize, fan_in: usize) -> Self {
        assert!(leaves > 0 && fan_in >= 2, "need leaves>0, fan_in>=2");
        // Compute total nodes first: levels of ceil(n/k).
        let mut counts = vec![leaves];
        while *counts.last().unwrap() > 1 {
            let prev = *counts.last().unwrap();
            counts.push(prev.div_ceil(fan_in));
        }
        let total: usize = counts.iter().sum();
        let mut g = Self::empty(GraphKind::ReductionTree { fan_in }, total);
        // Wire level l (offset) to level l+1.
        let mut offset = 0;
        for w in counts.windows(2) {
            let (cur, next) = (w[0], w[1]);
            for i in 0..cur {
                let parent = offset + cur + i / fan_in;
                debug_assert!(parent < offset + cur + next);
                g.add_edge(SubJobId(offset + i), SubJobId(parent));
            }
            offset += cur;
        }
        g
    }

    /// `searchers` nodes all feeding one combiner (paper: genome searching
    /// with `Z = searchers + 1` at the combiner... `Z` of a *searcher* is its
    /// 1 output; the experiments' `Z` counts the combiner's dependencies).
    pub fn search_combine(searchers: usize) -> Self {
        assert!(searchers > 0);
        let mut g = Self::empty(GraphKind::SearchCombine, searchers + 1);
        let combiner = SubJobId(searchers);
        for i in 0..searchers {
            g.add_edge(SubJobId(i), combiner);
        }
        g
    }

    pub fn len(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    pub fn inputs(&self, s: SubJobId) -> &[SubJobId] {
        &self.parents[s.0]
    }

    pub fn outputs(&self, s: SubJobId) -> &[SubJobId] {
        &self.children[s.0]
    }

    /// The paper's dependency count for a sub-job: `Z = d_i + d_o`.
    pub fn z(&self, s: SubJobId) -> usize {
        self.parents[s.0].len() + self.children[s.0].len()
    }

    /// Leaves (no inputs).
    pub fn leaves(&self) -> Vec<SubJobId> {
        (0..self.n).filter(|&i| self.parents[i].is_empty()).map(SubJobId).collect()
    }

    /// Root(s) (no outputs).
    pub fn roots(&self) -> Vec<SubJobId> {
        (0..self.n).filter(|&i| self.children[i].is_empty()).map(SubJobId).collect()
    }

    /// Topological order (Kahn). Panics if cyclic — construction APIs can't
    /// produce cycles, but property tests verify this for all builders.
    pub fn topo_order(&self) -> Vec<SubJobId> {
        let mut indeg: Vec<usize> = (0..self.n).map(|i| self.parents[i].len()).collect();
        let mut ready: Vec<usize> = (0..self.n).filter(|&i| indeg[i] == 0).collect();
        let mut out = Vec::with_capacity(self.n);
        while let Some(i) = ready.pop() {
            out.push(SubJobId(i));
            for &c in &self.children[i] {
                indeg[c.0] -= 1;
                if indeg[c.0] == 0 {
                    ready.push(c.0);
                }
            }
        }
        assert_eq!(out.len(), self.n, "dependency graph has a cycle");
        out
    }

    /// Structural fingerprint for isomorphism checks across migrations:
    /// sorted edge list (migration relocates sub-jobs across cores but must
    /// never change the graph).
    pub fn fingerprint(&self) -> Vec<(usize, usize)> {
        let mut edges = Vec::new();
        for i in 0..self.n {
            for &c in &self.children[i] {
                edges.push((i, c.0));
            }
        }
        edges.sort_unstable();
        edges
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn binary_tree_shape() {
        // 4 leaves, fan-in 2: 4 + 2 + 1 = 7 nodes; internal z = 3 (paper's
        // binary-tree example: two inputs + one output).
        let g = DepGraph::reduction_tree(4, 2);
        assert_eq!(g.len(), 7);
        assert_eq!(g.leaves().len(), 4);
        assert_eq!(g.roots(), vec![SubJobId(6)]);
        assert_eq!(g.z(SubJobId(4)), 3);
        assert_eq!(g.z(SubJobId(6)), 2); // root: two inputs, no output
        assert_eq!(g.z(SubJobId(0)), 1); // leaf: one output
    }

    #[test]
    fn fan_in_controls_z() {
        // paper varies Z by changing input dependencies: fan-in k gives an
        // internal node z = k + 1.
        for k in [2usize, 5, 9, 31, 62] {
            let g = DepGraph::reduction_tree(k * 2, k);
            // first internal node has k inputs and 1 output
            let internal = SubJobId(k * 2);
            assert_eq!(g.z(internal), k + 1, "k={k}");
        }
    }

    #[test]
    fn uneven_leaves_still_reduce() {
        let g = DepGraph::reduction_tree(5, 2); // 5+3+2+1 = 11
        assert_eq!(g.len(), 11);
        assert_eq!(g.roots().len(), 1);
        let order = g.topo_order();
        assert_eq!(order.len(), 11);
    }

    #[test]
    fn search_combine_star() {
        let g = DepGraph::search_combine(3);
        assert_eq!(g.len(), 4);
        let comb = SubJobId(3);
        assert_eq!(g.inputs(comb).len(), 3);
        assert_eq!(g.z(comb), 3);
        for i in 0..3 {
            assert_eq!(g.z(SubJobId(i)), 1);
        }
    }

    #[test]
    fn topo_order_respects_edges() {
        let g = DepGraph::reduction_tree(8, 2);
        let order = g.topo_order();
        let pos: std::collections::HashMap<usize, usize> =
            order.iter().enumerate().map(|(i, s)| (s.0, i)).collect();
        for (a, b) in g.fingerprint() {
            assert!(pos[&a] < pos[&b], "edge {a}->{b} violated");
        }
    }

    #[test]
    fn fingerprint_stable() {
        let a = DepGraph::reduction_tree(6, 3).fingerprint();
        let b = DepGraph::reduction_tree(6, 3).fingerprint();
        assert_eq!(a, b);
        let c = DepGraph::reduction_tree(6, 2).fingerprint();
        assert_ne!(a, c);
    }

    #[test]
    fn single_leaf_tree() {
        let g = DepGraph::reduction_tree(1, 2);
        assert_eq!(g.len(), 1);
        assert_eq!(g.leaves(), g.roots());
    }
}
