//! Artifact manifest parsing.
//!
//! `aot.py` writes `manifest.txt` with one line per executable:
//! `name|in=<dtype>:<shape>;...|out=<dtype>:<shape>;...` where shape is
//! `d0xd1x...` or `scalar`.

use std::path::{Path, PathBuf};

/// Tensor spec: dtype name + dims (empty = scalar).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorSpec {
    pub dtype: String,
    pub dims: Vec<usize>,
}

impl TensorSpec {
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        let (dtype, shape) =
            s.split_once(':').ok_or_else(|| anyhow::anyhow!("bad tensor spec `{s}`"))?;
        let dims = if shape == "scalar" {
            Vec::new()
        } else {
            shape
                .split('x')
                .map(|d| d.parse::<usize>().map_err(|e| anyhow::anyhow!("dim `{d}`: {e}")))
                .collect::<anyhow::Result<Vec<_>>>()?
        };
        Ok(Self { dtype: dtype.to_string(), dims })
    }

    pub fn elements(&self) -> usize {
        self.dims.iter().product::<usize>().max(1)
    }
}

/// One executable's interface.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    pub hlo_path: PathBuf,
}

/// The whole artifact directory.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: Vec<ArtifactSpec>,
}

impl Manifest {
    pub fn parse(dir: &Path, text: &str) -> anyhow::Result<Self> {
        let mut artifacts = Vec::new();
        for (i, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            let mut parts = line.split('|');
            let name = parts
                .next()
                .filter(|s| !s.is_empty())
                .ok_or_else(|| anyhow::anyhow!("manifest line {}: missing name", i + 1))?;
            let mut inputs = Vec::new();
            let mut outputs = Vec::new();
            for part in parts {
                if let Some(body) = part.strip_prefix("in=") {
                    inputs = body.split(';').map(TensorSpec::parse).collect::<anyhow::Result<_>>()?;
                } else if let Some(body) = part.strip_prefix("out=") {
                    outputs =
                        body.split(';').map(TensorSpec::parse).collect::<anyhow::Result<_>>()?;
                } else {
                    anyhow::bail!("manifest line {}: unknown section `{part}`", i + 1);
                }
            }
            artifacts.push(ArtifactSpec {
                name: name.to_string(),
                inputs,
                outputs,
                hlo_path: dir.join(format!("{name}.hlo.txt")),
            });
        }
        Ok(Self { dir: dir.to_path_buf(), artifacts })
    }

    pub fn load(dir: &Path) -> anyhow::Result<Self> {
        let text = std::fs::read_to_string(dir.join("manifest.txt"))
            .map_err(|e| anyhow::anyhow!("reading manifest in {}: {e}", dir.display()))?;
        Self::parse(dir, &text)
    }

    pub fn get(&self, name: &str) -> Option<&ArtifactSpec> {
        self.artifacts.iter().find(|a| a.name == name)
    }

    /// Default artifact directory: `$BIOMAFT_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var_os("BIOMAFT_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("artifacts"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
genome_search|in=int8:32768;int8:512x25;int32:512|out=int8:512x32768;int32:512
reduce|in=float32:1048576|out=float32:scalar
";

    #[test]
    fn parses_specs() {
        let m = Manifest::parse(Path::new("/tmp/a"), SAMPLE).unwrap();
        assert_eq!(m.artifacts.len(), 2);
        let gs = m.get("genome_search").unwrap();
        assert_eq!(gs.inputs.len(), 3);
        assert_eq!(gs.inputs[1].dims, vec![512, 25]);
        assert_eq!(gs.outputs[0].elements(), 512 * 32768);
        assert_eq!(gs.hlo_path, Path::new("/tmp/a/genome_search.hlo.txt"));
    }

    #[test]
    fn scalar_shape() {
        let t = TensorSpec::parse("float32:scalar").unwrap();
        assert!(t.dims.is_empty());
        assert_eq!(t.elements(), 1);
    }

    #[test]
    fn rejects_garbage() {
        assert!(TensorSpec::parse("float32").is_err());
        assert!(TensorSpec::parse("int8:axb").is_err());
        assert!(Manifest::parse(Path::new("."), "name|zap=1").is_err());
    }

    #[test]
    fn missing_name_rejected() {
        assert!(Manifest::parse(Path::new("."), "|in=int8:4|out=int8:4").is_err());
    }

    #[test]
    fn real_artifacts_manifest_if_present() {
        // integration smoke when `make artifacts` has run
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.txt").exists() {
            let m = Manifest::load(&dir).unwrap();
            assert!(m.get("genome_search").is_some());
            assert!(m.get("reduce").is_some());
            assert!(m.get("collate").is_some());
            for a in &m.artifacts {
                assert!(a.hlo_path.exists(), "{:?}", a.hlo_path);
            }
        }
    }
}
