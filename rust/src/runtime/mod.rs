//! PJRT runtime: loads the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them on the request path.
//!
//! Python never runs here — the artifacts are compiled once per process by
//! the `xla` crate's PJRT CPU client and then executed from the coordinator
//! (and from the worker pool that plays the cluster's "search nodes" in the
//! end-to-end example).

pub mod artifact;
pub mod client;
pub mod pool;

pub use artifact::{ArtifactSpec, Manifest};
pub use client::Runtime;
pub use pool::{SearchPool, SearchResult, SearchTask};
