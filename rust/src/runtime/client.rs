//! The PJRT client wrapper: compile HLO-text artifacts once, execute many
//! times.
//!
//! Interchange is HLO *text* — jax ≥ 0.5 emits protos with 64-bit
//! instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids (see DESIGN.md and /opt/xla-example/README.md).
//!
//! The real implementation needs the `xla` bindings crate, which is only
//! available as a vendored path dependency; it is compiled behind the
//! off-by-default `pjrt` cargo feature (see DESIGN.md §Runtime). Without
//! the feature a stub with the same API surface is compiled instead: every
//! entry point returns an error, and callers (fig14, the worker pool, the
//! runtime benches) fall back to the pure-Rust reference paths.

/// Geometry constants frozen by `python/compile/model.py` (checked against
/// the manifest at load time).
pub mod geom {
    pub const CHUNK: usize = 32_768;
    pub const N_PATTERNS: usize = 512;
    pub const WIDTH: usize = 25;
    pub const REDUCE_N: usize = 1 << 20;
    pub const COLLATE_NODES: usize = 16;
}

#[cfg(feature = "pjrt")]
mod imp {
    use super::geom;
    use crate::runtime::artifact::Manifest;
    use std::collections::HashMap;
    use std::path::Path;
    use xla::{ElementType, Literal, PjRtClient, PjRtLoadedExecutable};

    /// A loaded runtime: PJRT client + compiled executables.
    pub struct Runtime {
        #[allow(dead_code)]
        client: PjRtClient,
        execs: HashMap<String, PjRtLoadedExecutable>,
        pub manifest: Manifest,
    }

    impl Runtime {
        /// Load every artifact in `dir` and compile it on the CPU PJRT client.
        pub fn load(dir: &Path) -> anyhow::Result<Self> {
            let manifest = Manifest::load(dir)?;
            let client =
                PjRtClient::cpu().map_err(|e| anyhow::anyhow!("pjrt cpu client: {e:?}"))?;
            let mut execs = HashMap::new();
            for art in &manifest.artifacts {
                let proto = xla::HloModuleProto::from_text_file(&art.hlo_path)
                    .map_err(|e| anyhow::anyhow!("loading {}: {e:?}", art.hlo_path.display()))?;
                let comp = xla::XlaComputation::from_proto(&proto);
                let exe = client
                    .compile(&comp)
                    .map_err(|e| anyhow::anyhow!("compiling {}: {e:?}", art.name))?;
                execs.insert(art.name.clone(), exe);
            }
            Ok(Self { client, execs, manifest })
        }

        /// Load from the default artifact directory.
        pub fn load_default() -> anyhow::Result<Self> {
            Self::load(&Manifest::default_dir())
        }

        fn exec(&self, name: &str) -> anyhow::Result<&PjRtLoadedExecutable> {
            self.execs.get(name).ok_or_else(|| anyhow::anyhow!("no artifact `{name}`"))
        }

        /// Run the genome-search executable on one chunk against one
        /// dictionary block.
        ///
        /// * `seq` — int8[CHUNK]; * `patterns` — row-major
        ///   int8[N_PATTERNS x WIDTH]; * `lengths` — int32[N_PATTERNS].
        ///
        /// Returns `(mask, counts)`: mask is row-major
        /// int8[N_PATTERNS x CHUNK], counts int32[N_PATTERNS].
        pub fn genome_search(
            &self,
            seq: &[i8],
            patterns: &[i8],
            lengths: &[i32],
        ) -> anyhow::Result<(Vec<i8>, Vec<i32>)> {
            anyhow::ensure!(seq.len() == geom::CHUNK, "seq len {}", seq.len());
            anyhow::ensure!(patterns.len() == geom::N_PATTERNS * geom::WIDTH);
            anyhow::ensure!(lengths.len() == geom::N_PATTERNS);
            let seq_l = lit_i8(seq, &[geom::CHUNK])?;
            let pat_l = lit_i8(patterns, &[geom::N_PATTERNS, geom::WIDTH])?;
            let len_l = lit_i32(lengths, &[geom::N_PATTERNS])?;
            let result = self
                .exec("genome_search")?
                .execute::<Literal>(&[seq_l, pat_l, len_l])
                .map_err(|e| anyhow::anyhow!("genome_search exec: {e:?}"))?[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow::anyhow!("genome_search sync: {e:?}"))?;
            let (mask_l, counts_l) =
                result.to_tuple2().map_err(|e| anyhow::anyhow!("tuple2: {e:?}"))?;
            let mask = mask_l.to_vec::<i8>().map_err(|e| anyhow::anyhow!("mask: {e:?}"))?;
            let counts = counts_l.to_vec::<i32>().map_err(|e| anyhow::anyhow!("counts: {e:?}"))?;
            Ok((mask, counts))
        }

        /// Run the parallel-summation sub-job on one block of `REDUCE_N` f32s.
        pub fn reduce(&self, x: &[f32]) -> anyhow::Result<f32> {
            anyhow::ensure!(x.len() == geom::REDUCE_N, "reduce len {}", x.len());
            let xl = Literal::vec1(x)
                .reshape(&[geom::REDUCE_N as i64])
                .map_err(|e| anyhow::anyhow!("reshape: {e:?}"))?;
            let result = self
                .exec("reduce")?
                .execute::<Literal>(&[xl])
                .map_err(|e| anyhow::anyhow!("reduce exec: {e:?}"))?[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow::anyhow!("reduce sync: {e:?}"))?;
            let out = result.to_tuple1().map_err(|e| anyhow::anyhow!("tuple1: {e:?}"))?;
            let v = out.to_vec::<f32>().map_err(|e| anyhow::anyhow!("to_vec: {e:?}"))?;
            Ok(v[0])
        }

        /// Run the combining-node executable: merge per-node count vectors.
        /// `counts` is row-major int32[COLLATE_NODES x N_PATTERNS].
        pub fn collate(&self, counts: &[i32]) -> anyhow::Result<Vec<i32>> {
            anyhow::ensure!(counts.len() == geom::COLLATE_NODES * geom::N_PATTERNS);
            let cl = lit_i32(counts, &[geom::COLLATE_NODES, geom::N_PATTERNS])?;
            let result = self
                .exec("collate")?
                .execute::<Literal>(&[cl])
                .map_err(|e| anyhow::anyhow!("collate exec: {e:?}"))?[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow::anyhow!("collate sync: {e:?}"))?;
            let out = result.to_tuple1().map_err(|e| anyhow::anyhow!("tuple1: {e:?}"))?;
            out.to_vec::<i32>().map_err(|e| anyhow::anyhow!("to_vec: {e:?}"))
        }
    }

    fn lit_i8(data: &[i8], dims: &[usize]) -> anyhow::Result<Literal> {
        let bytes: &[u8] =
            unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len()) };
        Literal::create_from_shape_and_untyped_data(ElementType::S8, dims, bytes)
            .map_err(|e| anyhow::anyhow!("i8 literal: {e:?}"))
    }

    fn lit_i32(data: &[i32], dims: &[usize]) -> anyhow::Result<Literal> {
        let bytes: &[u8] =
            unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4) };
        Literal::create_from_shape_and_untyped_data(ElementType::S32, dims, bytes)
            .map_err(|e| anyhow::anyhow!("i32 literal: {e:?}"))
    }

    #[cfg(test)]
    mod tests {
        // Exercised by `rust/tests/runtime_integration.rs` (requires
        // artifacts); unit-level literal helpers tested here.
        use super::*;

        #[test]
        fn i8_literal_roundtrip() {
            let data: Vec<i8> = vec![-1, 0, 1, 2, 3, 4];
            let l = lit_i8(&data, &[2, 3]).unwrap();
            assert_eq!(l.to_vec::<i8>().unwrap(), data);
        }

        #[test]
        fn i32_literal_roundtrip() {
            let data: Vec<i32> = vec![1, -2, 3, 4];
            let l = lit_i32(&data, &[4]).unwrap();
            assert_eq!(l.to_vec::<i32>().unwrap(), data);
        }

        #[test]
        fn wrong_byte_count_rejected() {
            assert!(lit_i32(&[1, 2, 3], &[4]).is_err());
        }
    }
}

#[cfg(not(feature = "pjrt"))]
mod imp {
    use crate::runtime::artifact::Manifest;
    use std::path::Path;

    const UNAVAILABLE: &str =
        "biomaft was built without the `pjrt` feature; the PJRT compute path is unavailable \
         (pure-Rust fallbacks cover the experiments — see DESIGN.md §Runtime)";

    /// Stub runtime with the real API surface; every entry point errors.
    pub struct Runtime {
        pub manifest: Manifest,
    }

    impl Runtime {
        pub fn load(_dir: &Path) -> anyhow::Result<Self> {
            anyhow::bail!(UNAVAILABLE)
        }

        pub fn load_default() -> anyhow::Result<Self> {
            anyhow::bail!(UNAVAILABLE)
        }

        pub fn genome_search(
            &self,
            _seq: &[i8],
            _patterns: &[i8],
            _lengths: &[i32],
        ) -> anyhow::Result<(Vec<i8>, Vec<i32>)> {
            anyhow::bail!(UNAVAILABLE)
        }

        pub fn reduce(&self, _x: &[f32]) -> anyhow::Result<f32> {
            anyhow::bail!(UNAVAILABLE)
        }

        pub fn collate(&self, _counts: &[i32]) -> anyhow::Result<Vec<i32>> {
            anyhow::bail!(UNAVAILABLE)
        }
    }

    #[cfg(test)]
    mod tests {
        use super::*;

        #[test]
        fn stub_load_reports_missing_feature() {
            let err = Runtime::load(Path::new("/nonexistent")).unwrap_err().to_string();
            assert!(err.contains("pjrt"), "{err}");
        }
    }
}

pub use imp::Runtime;
