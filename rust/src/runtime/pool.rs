//! The search-node worker pool: plays the cluster's search nodes in the
//! end-to-end genome example.
//!
//! PJRT executables hold raw pointers (`!Send`), so each worker thread
//! builds its *own* `Runtime` (own CPU client + compiled executables) —
//! exactly the process-per-node shape of the real cluster. Work and results
//! flow over channels; the coordinator thread plays the combining node.

use std::path::PathBuf;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use super::client::Runtime;

/// One unit of search work: a chromosome chunk against a dictionary block.
#[derive(Debug, Clone)]
pub struct SearchTask {
    pub task_id: usize,
    pub chrom_idx: usize,
    pub chunk_start: usize,
    pub chrom_len: usize,
    pub seq: Vec<i8>,
    /// Row-major [N_PATTERNS x WIDTH] dictionary block.
    pub patterns: Vec<i8>,
    pub lengths: Vec<i32>,
    /// Dictionary index of row 0 and number of real rows in this block.
    pub pattern_base: usize,
    pub n_real: usize,
    /// Reverse strand flag (the block is already reverse-complemented).
    pub reverse: bool,
}

/// Result of one task.
#[derive(Debug)]
pub struct SearchResult {
    pub task_id: usize,
    pub worker: usize,
    pub task: SearchTask,
    pub mask: Vec<i8>,
    pub counts: Vec<i32>,
}

/// A pool of search-node workers.
pub struct SearchPool {
    tx: Sender<SearchTask>,
    rx: Receiver<anyhow::Result<SearchResult>>,
    handles: Vec<JoinHandle<()>>,
    in_flight: usize,
}

impl SearchPool {
    /// Spawn `n_workers` threads, each loading its own runtime from
    /// `artifact_dir`.
    pub fn spawn(n_workers: usize, artifact_dir: PathBuf) -> Self {
        assert!(n_workers > 0);
        let (task_tx, task_rx) = channel::<SearchTask>();
        let task_rx = Arc::new(Mutex::new(task_rx));
        let (res_tx, res_rx) = channel::<anyhow::Result<SearchResult>>();
        let mut handles = Vec::new();
        for w in 0..n_workers {
            let rx = task_rx.clone();
            let tx = res_tx.clone();
            let dir = artifact_dir.clone();
            handles.push(std::thread::spawn(move || {
                let rt = match Runtime::load(&dir) {
                    Ok(rt) => rt,
                    Err(e) => {
                        let _ = tx.send(Err(anyhow::anyhow!("worker {w}: {e}")));
                        return;
                    }
                };
                loop {
                    let task = {
                        let guard = rx.lock().expect("task queue poisoned");
                        match guard.recv() {
                            Ok(t) => t,
                            Err(_) => break, // pool dropped
                        }
                    };
                    let res = rt
                        .genome_search(&task.seq, &task.patterns, &task.lengths)
                        .map(|(mask, counts)| SearchResult {
                            task_id: task.task_id,
                            worker: w,
                            task,
                            mask,
                            counts,
                        });
                    if tx.send(res).is_err() {
                        break;
                    }
                }
            }));
        }
        Self { tx: task_tx, rx: res_rx, handles, in_flight: 0 }
    }

    /// Submit a task.
    pub fn submit(&mut self, task: SearchTask) -> anyhow::Result<()> {
        self.tx.send(task).map_err(|_| anyhow::anyhow!("pool closed"))?;
        self.in_flight += 1;
        Ok(())
    }

    /// Receive the next completed result (blocking).
    pub fn recv(&mut self) -> anyhow::Result<SearchResult> {
        anyhow::ensure!(self.in_flight > 0, "no work in flight");
        self.in_flight -= 1;
        self.rx.recv().map_err(|_| anyhow::anyhow!("pool workers gone"))?
    }

    pub fn in_flight(&self) -> usize {
        self.in_flight
    }

    /// Close the queue and join the workers.
    pub fn shutdown(self) {
        drop(self.tx);
        for h in self.handles {
            let _ = h.join();
        }
    }
}

// Integration-tested in rust/tests/runtime_integration.rs (needs artifacts).
