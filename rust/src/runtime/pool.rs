//! The search-node worker pool: plays the cluster's search nodes in the
//! end-to-end genome example.
//!
//! PJRT executables hold raw pointers (`!Send`), so each worker thread
//! builds its *own* `Runtime` (own CPU client + compiled executables) —
//! exactly the process-per-node shape of the real cluster. Work and results
//! flow over channels; the coordinator thread plays the combining node.
//!
//! When a worker cannot load the PJRT runtime (the crate was built without
//! the `pjrt` feature, or no artifacts are staged) it falls back to the
//! pure-Rust packed engine
//! ([`genome::search_block`](crate::genome::search_block)), which
//! reproduces the kernel's `(mask, counts)` semantics bit for bit — so the
//! pool is usable, and testable, on any machine; `SearchResult::via_pjrt`
//! records which path computed each result.

use std::path::PathBuf;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use super::client::Runtime;
use crate::genome::SearchEngine;

/// The compute path one worker resolved at spawn time.
enum Backend {
    Pjrt(Runtime),
    /// Pure-Rust packed engine (no runtime loadable at the artifact dir).
    Cpu,
}

/// One unit of search work: a chromosome chunk against a dictionary block.
#[derive(Debug, Clone)]
pub struct SearchTask {
    pub task_id: usize,
    pub chrom_idx: usize,
    pub chunk_start: usize,
    pub chrom_len: usize,
    pub seq: Vec<i8>,
    /// Row-major [N_PATTERNS x WIDTH] dictionary block.
    pub patterns: Vec<i8>,
    pub lengths: Vec<i32>,
    /// Dictionary index of row 0 and number of real rows in this block.
    pub pattern_base: usize,
    pub n_real: usize,
    /// Reverse strand flag (the block is already reverse-complemented).
    pub reverse: bool,
}

/// Result of one task.
#[derive(Debug)]
pub struct SearchResult {
    pub task_id: usize,
    pub worker: usize,
    pub task: SearchTask,
    pub mask: Vec<i8>,
    pub counts: Vec<i32>,
    /// Which compute path produced this result: the AOT PJRT executable or
    /// the pure-Rust engine fallback.
    pub via_pjrt: bool,
}

/// A pool of search-node workers.
pub struct SearchPool {
    tx: Sender<SearchTask>,
    rx: Receiver<anyhow::Result<SearchResult>>,
    handles: Vec<JoinHandle<()>>,
    in_flight: usize,
}

impl SearchPool {
    /// Spawn `n_workers` threads, each loading its own runtime from
    /// `artifact_dir` — or resolving to the pure-Rust engine fallback when
    /// no runtime is loadable there.
    pub fn spawn(n_workers: usize, artifact_dir: PathBuf) -> Self {
        assert!(n_workers > 0);
        let (task_tx, task_rx) = channel::<SearchTask>();
        let task_rx = Arc::new(Mutex::new(task_rx));
        let (res_tx, res_rx) = channel::<anyhow::Result<SearchResult>>();
        let mut handles = Vec::new();
        for w in 0..n_workers {
            let rx = task_rx.clone();
            let tx = res_tx.clone();
            let dir = artifact_dir.clone();
            handles.push(std::thread::spawn(move || {
                let backend = match Runtime::load(&dir) {
                    Ok(rt) => Backend::Pjrt(rt),
                    Err(e) => {
                        // Expected when nothing is staged (or no `pjrt`
                        // feature); loud when artifacts ARE staged but
                        // broken, so a degraded run is never silent.
                        if dir.join("manifest.txt").exists() {
                            eprintln!(
                                "worker {w}: staged artifacts failed to load ({e}); \
                                 falling back to the pure-Rust engine"
                            );
                        }
                        Backend::Cpu
                    }
                };
                // CPU path: the compiled dictionary block is cached across
                // tasks (runs share one block), so the task loop only scans
                // — mirroring the PJRT path's compile-once-at-spawn shape.
                let mut cached: Option<(Vec<i8>, Vec<i32>, SearchEngine)> = None;
                loop {
                    let task = {
                        let guard = rx.lock().expect("task queue poisoned");
                        match guard.recv() {
                            Ok(t) => t,
                            Err(_) => break, // pool dropped
                        }
                    };
                    let computed = match &backend {
                        Backend::Pjrt(rt) => rt
                            .genome_search(&task.seq, &task.patterns, &task.lengths)
                            .map(|mc| (mc, true)),
                        Backend::Cpu => {
                            let fresh = matches!(&cached, Some((p, l, _))
                                if *p == task.patterns && *l == task.lengths);
                            if !fresh {
                                let width = if task.lengths.is_empty() {
                                    0
                                } else {
                                    task.patterns.len() / task.lengths.len()
                                };
                                let eng = SearchEngine::from_rows(
                                    &task.patterns,
                                    &task.lengths,
                                    width,
                                );
                                cached =
                                    Some((task.patterns.clone(), task.lengths.clone(), eng));
                            }
                            let (_, _, eng) = cached.as_ref().expect("block just compiled");
                            Ok((eng.run_block(&task.seq), false))
                        }
                    };
                    let res = computed.map(|((mask, counts), via_pjrt)| SearchResult {
                        task_id: task.task_id,
                        worker: w,
                        task,
                        mask,
                        counts,
                        via_pjrt,
                    });
                    if tx.send(res).is_err() {
                        break;
                    }
                }
            }));
        }
        Self { tx: task_tx, rx: res_rx, handles, in_flight: 0 }
    }

    /// Submit a task.
    pub fn submit(&mut self, task: SearchTask) -> anyhow::Result<()> {
        self.tx.send(task).map_err(|_| anyhow::anyhow!("pool closed"))?;
        self.in_flight += 1;
        Ok(())
    }

    /// Receive the next completed result (blocking).
    pub fn recv(&mut self) -> anyhow::Result<SearchResult> {
        anyhow::ensure!(self.in_flight > 0, "no work in flight");
        self.in_flight -= 1;
        self.rx.recv().map_err(|_| anyhow::anyhow!("pool workers gone"))?
    }

    pub fn in_flight(&self) -> usize {
        self.in_flight
    }

    /// Close the queue and join the workers.
    pub fn shutdown(self) {
        drop(self.tx);
        for h in self.handles {
            let _ = h.join();
        }
    }
}

// The PJRT path is integration-tested in rust/tests/runtime_integration.rs
// (needs artifacts); the CPU fallback path is tested right here.

#[cfg(test)]
mod tests {
    use super::*;
    use crate::genome::{self, Strand};
    use crate::sim::Rng;

    /// Loading from a directory with no artifacts resolves every worker to
    /// the engine fallback; the collated result must equal the naive
    /// oracle, and identical tasks must produce identical bytes.
    #[test]
    fn cpu_fallback_matches_naive_oracle() {
        let g = genome::synthesize_genome(6_000, 8);
        let chr = &g[0];
        let mut rng = Rng::new(4);
        let spec = genome::PatternSpec { n_patterns: 12, ..Default::default() };
        let dict = genome::PatternDict::build(&spec, std::slice::from_ref(chr), &mut rng);
        let (patterns, lengths) = dict.block(0, 16); // 12 real + 4 padding rows
        let chunk = chr.seq.len() + 64;
        let mut seq = chr.seq.clone();
        seq.resize(chunk, genome::PAD);

        let mut pool = SearchPool::spawn(2, PathBuf::from("/nonexistent-artifacts"));
        for t in 0..3 {
            pool.submit(SearchTask {
                task_id: t,
                chrom_idx: 0,
                chunk_start: 0,
                chrom_len: chr.seq.len(),
                seq: seq.clone(),
                patterns: patterns.clone(),
                lengths: lengths.clone(),
                pattern_base: 0,
                n_real: dict.n,
                reverse: false,
            })
            .unwrap();
        }
        let mut results = Vec::new();
        for _ in 0..3 {
            results.push(pool.recv().unwrap());
        }
        pool.shutdown();
        assert!(results.iter().all(|r| !r.via_pjrt));

        let r = &results[0];
        let mut hits = Vec::new();
        genome::collate_hits(
            &r.mask,
            16,
            chunk,
            0,
            chr.seq.len(),
            0,
            &lengths,
            dict.n,
            0,
            Strand::Forward,
            &mut hits,
        );
        genome::hits::dedup_hits(&mut hits);
        let mut want = genome::search_naive(std::slice::from_ref(chr), &dict, Strand::Forward);
        genome::hits::dedup_hits(&mut want);
        assert_eq!(hits, want, "pool CPU fallback vs naive oracle");
        assert!(!hits.is_empty(), "planted patterns should hit");
        for r in &results[1..] {
            assert_eq!(r.mask, results[0].mask);
            assert_eq!(r.counts, results[0].counts);
        }
    }

    /// The fallback is geometry-free: any chunk / block shape works, not
    /// just the AOT `geom` constants.
    #[test]
    fn cpu_fallback_accepts_arbitrary_geometry() {
        let seq = genome::encode_seq("ACGTACGTTTACGT");
        let dict = {
            let width = 6;
            let mut matrix = vec![genome::PAD; 2 * width];
            matrix[..4].copy_from_slice(&genome::encode_seq("CGTA"));
            matrix[width..width + 3].copy_from_slice(&genome::encode_seq("TTT"));
            genome::PatternDict { matrix, lengths: vec![4, 3], width, n: 2 }
        };
        let mut pool = SearchPool::spawn(1, PathBuf::from("/nonexistent-artifacts"));
        pool.submit(SearchTask {
            task_id: 0,
            chrom_idx: 0,
            chunk_start: 0,
            chrom_len: seq.len(),
            seq: seq.clone(),
            patterns: dict.matrix.clone(),
            lengths: dict.lengths.clone(),
            pattern_base: 0,
            n_real: 2,
            reverse: false,
        })
        .unwrap();
        let r = pool.recv().unwrap();
        pool.shutdown();
        assert_eq!(r.counts, vec![1, 1]); // CGTA at 0-based 1, TTT at 0-based 7
        assert_eq!(r.mask[1], 1);
        assert_eq!(r.mask[seq.len() + 7], 1);
    }
}
