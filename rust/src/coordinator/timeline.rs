//! Execution timelines — the schematic content of Figs. 16 and 17 rendered
//! from actual simulated runs.

use crate::sim::SimTime;

/// A labelled event on a job timeline.
#[derive(Debug, Clone, PartialEq)]
pub struct TimelineEvent {
    pub at: SimTime,
    pub label: String,
}

/// Build the checkpoint/failure timeline of one window configuration.
///
/// * `job_h` — nominal job hours; * `period_h` — checkpoint periodicity;
/// * `failure_offsets_s` — failure times (absolute seconds from start).
pub fn build_timeline(job_h: f64, period_h: f64, failure_offsets_s: &[f64]) -> Vec<TimelineEvent> {
    let mut ev = vec![TimelineEvent { at: SimTime::ZERO, label: "start".into() }];
    let mut t = period_h * 3600.0;
    let mut i = 1;
    while t < job_h * 3600.0 - 1.0 {
        ev.push(TimelineEvent { at: SimTime::from_secs(t), label: format!("C{i}") });
        t += period_h * 3600.0;
        i += 1;
    }
    for (k, &f) in failure_offsets_s.iter().enumerate() {
        ev.push(TimelineEvent { at: SimTime::from_secs(f), label: format!("F{}", k + 1) });
    }
    ev.push(TimelineEvent { at: SimTime::from_secs(job_h * 3600.0), label: "complete".into() });
    ev.sort_by_key(|e| e.at);
    ev
}

/// Render a timeline as a single ASCII lane.
pub fn render_timeline(events: &[TimelineEvent]) -> String {
    if events.is_empty() {
        return String::new();
    }
    let end = events.last().unwrap().at.as_secs().max(1.0);
    const W: usize = 72;
    let mut lane: Vec<char> = "-".repeat(W).chars().collect();
    let mut labels = Vec::new();
    for e in events {
        let pos = ((e.at.as_secs() / end) * (W - 1) as f64).round() as usize;
        lane[pos.min(W - 1)] = '|';
        labels.push(format!("{}@{}", e.label, crate::util::fmt::hms(e.at.as_secs())));
    }
    format!("{}\n{}\n", lane.iter().collect::<String>(), labels.join("  "))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig17b_one_hour_periodicity_has_four_checkpoints() {
        // 5 h job, 1 h periodicity: C1..C4 (Fig. 17(b)).
        let tl = build_timeline(5.0, 1.0, &[]);
        let cs: Vec<_> = tl.iter().filter(|e| e.label.starts_with('C')).collect();
        assert_eq!(cs.len(), 4);
        assert_eq!(cs[0].at, SimTime::from_secs(3600.0));
    }

    #[test]
    fn fig17c_two_hour_periodicity_has_two() {
        let tl = build_timeline(5.0, 2.0, &[]);
        let cs: Vec<_> = tl.iter().filter(|e| e.label.starts_with('C')).collect();
        assert_eq!(cs.len(), 2);
    }

    #[test]
    fn fig17d_four_hour_periodicity_has_one() {
        let tl = build_timeline(5.0, 4.0, &[]);
        let cs: Vec<_> = tl.iter().filter(|e| e.label.starts_with('C')).collect();
        assert_eq!(cs.len(), 1);
        assert_eq!(cs[0].at, SimTime::from_secs(4.0 * 3600.0));
    }

    #[test]
    fn failures_interleave_sorted() {
        let tl = build_timeline(2.0, 1.0, &[840.0, 4440.0]);
        for w in tl.windows(2) {
            assert!(w[0].at <= w[1].at);
        }
        assert!(tl.iter().any(|e| e.label == "F1"));
        assert!(tl.iter().any(|e| e.label == "F2"));
    }

    #[test]
    fn render_marks_events() {
        let tl = build_timeline(1.0, 1.0, &[900.0]);
        let r = render_timeline(&tl);
        assert!(r.contains('|'));
        assert!(r.contains("F1@00:15:00"));
        assert!(r.contains("complete@01:00:00"));
    }
}
