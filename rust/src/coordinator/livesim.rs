//! Live full-system simulation: an entire job executed in virtual time with
//! probing, failure injection, prediction, migration and checkpoint
//! recovery composed as discrete events.
//!
//! Where [`run`](super::run) computes the paper's tables with per-failure
//! accounting, this module *plays the whole story out* on the DES: sub-jobs
//! progress, probers tick, the injector dooms cores, predictions race
//! failures, agents migrate (or the checkpoint baseline rolls back), and
//! the job completes. The two views must agree — that agreement is the
//! strongest integration test the crate has.

use crate::cluster::spec::FtCosts;
use crate::coordinator::ftmanager::Strategy;
use crate::failure::injector::FailurePlan;
use crate::hybrid::rules::{decide, Mover, RuleInputs};
use crate::net::message::SubJobId;
use crate::net::{NodeId, Topology};
use crate::sim::engine::{ActorId, Engine, Outbox};
use crate::sim::{Rng, SimTime};
use std::cell::RefCell;
use std::rc::Rc;

/// Events of the live simulation.
#[derive(Debug, Clone)]
enum Ev {
    /// A core is doomed: the prediction (if the failure is predictable)
    /// will fire `predict_lead_s` before the failure.
    Doom { node: NodeId, predictable: bool },
    /// A prediction fires for a node.
    Prediction { node: NodeId },
    /// The hardware actually fails.
    Failure { node: NodeId },
    /// A migration episode completes; the sub-job resumes on `to`.
    MigrationDone { sub: SubJobId, to: NodeId },
    /// Checkpoint recovery completes; lost sub-jobs resume.
    RecoveryDone { _node: NodeId },
    /// A sub-job finishes its compute.
    SubJobDone { sub: SubJobId },
}

/// Per-sub-job live state.
#[derive(Debug, Clone, Copy, PartialEq)]
enum LiveState {
    Running { done_at: SimTime },
    Migrating { resume_remaining_s: f64 },
    Recovering { resume_remaining_s: f64 },
    Done,
}

/// Result of a live run.
#[derive(Debug, Clone)]
pub struct LiveOutcome {
    pub completed_at_s: f64,
    pub migrations: usize,
    pub rollbacks: usize,
    pub lost_then_recovered: usize,
    /// Virtual-time event trace length (for determinism checks).
    pub events: u64,
}

/// Configuration of a live run.
#[derive(Debug, Clone)]
pub struct LiveCfg {
    pub costs: FtCosts,
    pub strategy: Strategy,
    pub n_subs: usize,
    pub z: usize,
    pub data_kb: u64,
    pub proc_kb: u64,
    /// Per-sub-job compute seconds (virtual).
    pub compute_s: f64,
    /// Fraction of injected failures that are predictable.
    pub predictable_frac: f64,
    /// Checkpoint recovery parameters (reactive path).
    pub ckpt_reinstate_s: f64,
    pub ckpt_overhead_s: f64,
    pub seed: u64,
}

struct System {
    cfg: LiveCfg,
    topo: Topology,
    host: Vec<NodeId>,
    state: Vec<LiveState>,
    doomed: Vec<bool>,
    rng: Rng,
    outcome: Rc<RefCell<LiveOutcome>>,
}

impl System {
    fn subs_on(&self, node: NodeId) -> Vec<SubJobId> {
        (0..self.host.len()).filter(|&i| self.host[i] == node).map(SubJobId).collect()
    }

    fn all_done(&self) -> bool {
        self.state.iter().all(|s| matches!(s, LiveState::Done))
    }

    fn reinstate_s(&mut self, z: usize) -> f64 {
        let inp = RuleInputs { z, data_kb: self.cfg.data_kb, proc_kb: self.cfg.proc_kb };
        let base = match self.cfg.strategy {
            Strategy::Agent => self.cfg.costs.agent.reinstate_s(z, inp.data_kb, inp.proc_kb),
            Strategy::Core => self.cfg.costs.core.reinstate_s(z, inp.data_kb, inp.proc_kb),
            Strategy::Hybrid => match decide(inp).0 {
                Mover::Agent => self.cfg.costs.agent.reinstate_s(z, inp.data_kb, inp.proc_kb),
                Mover::Core => self.cfg.costs.core.reinstate_s(z, inp.data_kb, inp.proc_kb),
            },
            _ => panic!("livesim supports multi-agent strategies + checkpoint recovery"),
        };
        base * self.rng.jitter(self.cfg.costs.noise_sigma)
    }

    fn pick_target(&mut self, from: NodeId) -> Option<NodeId> {
        let healthy: Vec<NodeId> = self
            .topo
            .neighbours(from)
            .iter()
            .copied()
            .filter(|n| !self.doomed[n.0])
            .collect();
        if healthy.is_empty() {
            None
        } else {
            Some(*self.rng.pick(&healthy))
        }
    }
}

impl crate::sim::engine::Actor<Ev> for System {
    fn on_msg(&mut self, me: ActorId, ev: Ev, out: &mut Outbox<'_, Ev>) {
        let now = out.now();
        match ev {
            Ev::Doom { node, predictable } => {
                self.doomed[node.0] = true;
                let lead = self.cfg.costs.predict.predict_time_s + 20.0;
                if predictable {
                    out.send_in(SimTime::from_secs(0.0), me, Ev::Prediction { node });
                }
                out.send_in(SimTime::from_secs(lead), me, Ev::Failure { node });
            }
            Ev::Prediction { node } => {
                // proactive path: migrate every sub-job on the node
                for sub in self.subs_on(node) {
                    if let LiveState::Running { done_at } = self.state[sub.0] {
                        let remaining = (done_at.saturating_sub(now)).as_secs();
                        let dur = self.reinstate_s(self.cfg.z);
                        if let Some(target) = self.pick_target(node) {
                            self.state[sub.0] =
                                LiveState::Migrating { resume_remaining_s: remaining };
                            self.host[sub.0] = target;
                            out.send_in(
                                SimTime::from_secs(dur),
                                me,
                                Ev::MigrationDone { sub, to: target },
                            );
                        }
                        // no healthy neighbour: stay put; the failure path
                        // will trigger rollback.
                    }
                }
            }
            Ev::Failure { node } => {
                // any sub-job still on the failed node is lost → reactive
                // rollback (the combined design's second line)
                let lost = self
                    .subs_on(node)
                    .into_iter()
                    .filter(|s| matches!(self.state[s.0], LiveState::Running { .. }))
                    .collect::<Vec<_>>();
                if !lost.is_empty() {
                    for sub in &lost {
                        if let LiveState::Running { done_at } = self.state[sub.0] {
                            let remaining = (done_at.saturating_sub(now)).as_secs();
                            self.state[sub.0] =
                                LiveState::Recovering { resume_remaining_s: remaining };
                            // move it off the dead node for the resume
                            if let Some(t) = self.pick_target(node) {
                                self.host[sub.0] = t;
                            }
                        }
                    }
                    let dur = self.cfg.ckpt_reinstate_s + self.cfg.ckpt_overhead_s;
                    self.outcome.borrow_mut().rollbacks += 1;
                    self.outcome.borrow_mut().lost_then_recovered += lost.len();
                    out.send_in(SimTime::from_secs(dur), me, Ev::RecoveryDone { _node: node });
                }
            }
            Ev::MigrationDone { sub, to } => {
                if let LiveState::Migrating { resume_remaining_s } = self.state[sub.0] {
                    debug_assert_eq!(self.host[sub.0], to);
                    debug_assert!(!self.doomed[to.0], "migrated onto a doomed node");
                    let done_at = now + SimTime::from_secs(resume_remaining_s);
                    self.state[sub.0] = LiveState::Running { done_at };
                    self.outcome.borrow_mut().migrations += 1;
                    out.send_at(done_at, me, Ev::SubJobDone { sub });
                }
            }
            Ev::RecoveryDone { .. } => {
                for i in 0..self.state.len() {
                    if let LiveState::Recovering { resume_remaining_s } = self.state[i] {
                        let done_at = now + SimTime::from_secs(resume_remaining_s);
                        self.state[i] = LiveState::Running { done_at };
                        out.send_at(done_at, me, Ev::SubJobDone { sub: SubJobId(i) });
                    }
                }
            }
            Ev::SubJobDone { sub } => {
                if let LiveState::Running { done_at } = self.state[sub.0] {
                    if done_at == now {
                        self.state[sub.0] = LiveState::Done;
                    }
                    // else: a stale completion from before a migration —
                    // ignored because done_at moved.
                }
                if self.all_done() {
                    let mut o = self.outcome.borrow_mut();
                    o.completed_at_s = now.as_secs();
                    out.stop = true;
                }
            }
        }
    }
}

/// Run a live simulation of `cfg` under a failure plan.
pub fn run_live(cfg: &LiveCfg, topo: &Topology, plan: &FailurePlan) -> LiveOutcome {
    let mut rng = Rng::new(cfg.seed);
    let outcome = Rc::new(RefCell::new(LiveOutcome {
        completed_at_s: 0.0,
        migrations: 0,
        rollbacks: 0,
        lost_then_recovered: 0,
        events: 0,
    }));
    let host: Vec<NodeId> = (0..cfg.n_subs).map(|i| NodeId(i % topo.len())).collect();
    let state: Vec<LiveState> = (0..cfg.n_subs)
        .map(|_| LiveState::Running { done_at: SimTime::from_secs(cfg.compute_s) })
        .collect();
    let predictable_frac = cfg.predictable_frac;
    let system = System {
        cfg: cfg.clone(),
        topo: topo.clone(),
        host,
        state,
        doomed: vec![false; topo.len()],
        rng: rng.fork(1),
        outcome: outcome.clone(),
    };
    let mut eng: Engine<Ev> = Engine::new();
    let sys = eng.add_actor(Box::new(system));
    for i in 0..cfg.n_subs {
        eng.schedule(SimTime::from_secs(cfg.compute_s), sys, Ev::SubJobDone { sub: SubJobId(i) });
    }
    let lead = cfg.costs.predict.predict_time_s + 20.0;
    for e in &plan.events {
        let predictable = rng.chance(predictable_frac);
        let doom_at = e.at.saturating_sub(SimTime::from_secs(lead));
        eng.schedule(doom_at, sys, Ev::Doom { node: e.node, predictable });
    }
    eng.run();
    let mut o = outcome.borrow().clone();
    o.events = eng.dispatched();
    o
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{preset, ClusterPreset};
    use crate::failure::injector::FailureProcess;

    fn cfg(strategy: Strategy, predictable_frac: f64) -> LiveCfg {
        LiveCfg {
            costs: preset(ClusterPreset::Placentia).costs,
            strategy,
            n_subs: 4,
            z: 4,
            data_kb: 1 << 19,
            proc_kb: 1 << 19,
            compute_s: 3600.0,
            predictable_frac,
            ckpt_reinstate_s: 848.0,
            ckpt_overhead_s: 485.0,
            seed: 1,
        }
    }

    fn topo() -> Topology {
        Topology::ring(8, 2)
    }

    #[test]
    fn no_failures_completes_at_nominal() {
        let plan = FailurePlan { events: vec![] };
        let o = run_live(&cfg(Strategy::Core, 1.0), &topo(), &plan);
        assert_eq!(o.completed_at_s, 3600.0);
        assert_eq!(o.migrations, 0);
        assert_eq!(o.rollbacks, 0);
    }

    #[test]
    fn predicted_failure_adds_only_reinstate() {
        let mut rng = Rng::new(3);
        let plan = FailureProcess::Periodic { offset_s: 900.0 }.plan(1, 3600.0, 8, &mut rng);
        let o = run_live(&cfg(Strategy::Core, 1.0), &topo(), &plan);
        // the sub-job on the failed node migrated; total inflates only by
        // the sub-second reinstate (if any sub-job was on that node)
        assert_eq!(o.rollbacks, 0);
        assert!(o.completed_at_s < 3600.0 + 2.0, "{}", o.completed_at_s);
        if o.migrations > 0 {
            assert!(o.completed_at_s > 3600.0);
        }
    }

    #[test]
    fn unpredicted_failure_forces_rollback() {
        let mut rng = Rng::new(4);
        // strike node 0 (hosts sub-job 0) with an unpredictable failure
        let plan = FailureProcess::Periodic { offset_s: 600.0 }.plan(1, 3600.0, 1, &mut rng);
        let o = run_live(&cfg(Strategy::Hybrid, 0.0), &topo(), &plan);
        assert_eq!(o.rollbacks, 1);
        assert!(o.lost_then_recovered >= 1);
        // recovery adds reinstate + overhead
        assert!(
            o.completed_at_s >= 3600.0 + 848.0 + 485.0 - 1.0,
            "{}",
            o.completed_at_s
        );
    }

    #[test]
    fn live_total_matches_accounting_for_one_predicted_failure() {
        // the DES story and the window_row accounting agree on the
        // proactive path's added time (reinstate only, since overhead is
        // background and prediction lead is pre-failure)
        let mut rng = Rng::new(5);
        let plan = FailureProcess::Periodic { offset_s: 900.0 }.plan(1, 3600.0, 1, &mut rng);
        let c = cfg(Strategy::Core, 1.0);
        let o = run_live(&c, &topo(), &plan);
        assert_eq!(o.migrations, 1);
        let added = o.completed_at_s - 3600.0;
        let expected = c.costs.core.reinstate_s(4, 1 << 19, 1 << 19);
        assert!((added - expected).abs() < 0.1, "added {added} expected {expected}");
    }

    #[test]
    fn migration_storm_many_failures_job_still_completes() {
        let mut rng = Rng::new(6);
        let plan = FailureProcess::RandomUniformK { k: 6 }.plan(1, 3600.0, 8, &mut rng);
        let o = run_live(&cfg(Strategy::Hybrid, 0.8), &topo(), &plan);
        assert!(o.completed_at_s >= 3600.0);
        assert!(o.completed_at_s < 3600.0 * 3.0, "runaway: {}", o.completed_at_s);
    }

    #[test]
    fn deterministic_per_seed() {
        let mut rng = Rng::new(7);
        let plan = FailureProcess::RandomUniformK { k: 3 }.plan(1, 3600.0, 8, &mut rng);
        let a = run_live(&cfg(Strategy::Agent, 0.5), &topo(), &plan);
        let b = run_live(&cfg(Strategy::Agent, 0.5), &topo(), &plan);
        assert_eq!(a.completed_at_s, b.completed_at_s);
        assert_eq!(a.events, b.events);
    }
}
