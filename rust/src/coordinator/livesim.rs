//! Live full-system simulation: an entire job executed in virtual time with
//! probing, failure injection, prediction, migration and checkpoint
//! recovery composed as discrete events.
//!
//! Where [`run`](super::run) computes the paper's tables with per-failure
//! accounting, this module *plays the whole story out* on the DES: sub-jobs
//! progress, probers tick, the injector dooms cores, predictions race
//! failures, agents migrate (or the checkpoint baseline rolls back), and
//! the job completes. The two views must agree — that agreement is the
//! strongest integration test the crate has.
//!
//! The system runs as one [`Scenario`] on the [`sim::harness`] runtime, and
//! is the shared engine behind both the paper's single-failure experiments
//! ([`run_live`]) and the multi-failure regimes of [`crate::scenario`]
//! ([`run_live_with`]): concurrent and correlated failures arrive through a
//! denser [`FailurePlan`], while cascades — a migration target itself
//! failing mid-reinstate — are injected at migration time via
//! [`CascadeSpec`].
//!
//! ## Hot path (see DESIGN.md §Hot path)
//!
//! The [`System`] scenario *borrows* its `LiveCfg` and `Topology` (no
//! per-trial clones), victim scans iterate the host table in place instead
//! of collecting `Vec`s per event, and target picks count-then-select over
//! the neighbour slice instead of building a filtered `Vec` — the per-event
//! path performs no allocation. [`LiveScratch`] additionally carries the
//! engine buffers and the per-sub/per-node state vectors across trials, so
//! a batch worker's steady-state trials allocate nothing but the failure
//! plan. The RNG draw order of every replaced loop is unchanged, keeping
//! traces bit-identical to the pre-redesign code.
//!
//! [`sim::harness`]: crate::sim::harness

use crate::cluster::spec::FtCosts;
use crate::coordinator::ftmanager::Strategy;
use crate::failure::injector::FailurePlan;
use crate::hybrid::rules::{decide, Mover, RuleInputs};
use crate::net::faults::{self, FaultPlane};
use crate::net::message::SubJobId;
use crate::net::{NetCost, NodeId, Topology};
use crate::sim::{Ctx, Harness, Rng, Scenario, SimTime, TrialScratch};

/// Sentinel `from` marker for [`LiveState::Recovering`] entries created by
/// the *fallback* ladder (a migration whose message sequence exhausted its
/// retries) rather than by a node failure. Never a real node id, so the
/// node-keyed [`Ev::RecoveryDone`] scan can never cross-resume a fallback
/// sub-job; fallbacks resume through their own [`Ev::FallbackDone`].
const FALLBACK_FROM: NodeId = NodeId(usize::MAX);

/// Network cost of one migration's full message sequence under `cfg`'s
/// strategy: the Fig. 3 agent handshakes, the Fig. 5 object migration, or
/// the hybrid negotiation followed by the winner's sequence. The single
/// dispatch point shared by the live simulator and the fleet simulator, so
/// both price a migration's wire traffic identically. Draws come only from
/// the fault plane's salted side-stream via `(seed, edge_key, seq)`.
pub fn migration_net_cost(
    cfg: &LiveCfg,
    faults: &FaultPlane,
    seed: u64,
    edge_key: u64,
    seq: &mut u64,
    cut: bool,
) -> NetCost {
    match cfg.strategy {
        Strategy::Agent => crate::agentft::migration::sequence_net_cost(
            faults, seed, edge_key, seq, cut, cfg.data_kb, cfg.proc_kb,
        ),
        Strategy::Hybrid => crate::hybrid::negotiate::sequence_net_cost(
            faults, seed, edge_key, seq, cut, cfg.z, cfg.data_kb, cfg.proc_kb,
        ),
        // Core — and any other strategy that migrates in a fleet context —
        // moves the job object the Fig. 5 way.
        _ => crate::coreft::migration::sequence_net_cost(
            faults, seed, edge_key, seq, cut, cfg.data_kb,
        ),
    }
}

/// Events of the live simulation.
#[derive(Debug, Clone)]
enum Ev {
    /// A core is doomed: the prediction (if the failure is predictable)
    /// fires immediately and the hardware fails `fail_in_s` later (the
    /// prediction lead for planned failures; the cascade lag for follow-on
    /// dooms). `cascade` marks a follow-on doom injected at migration time.
    Doom { node: NodeId, predictable: bool, cascade: bool, fail_in_s: f64 },
    /// A prediction fires for a node.
    Prediction { node: NodeId },
    /// The hardware actually fails.
    Failure { node: NodeId },
    /// A migration episode completes; the sub-job resumes on `to`.
    MigrationDone { sub: SubJobId, to: NodeId },
    /// Checkpoint recovery for `node`'s failure completes; the sub-jobs
    /// lost to *that* failure resume.
    RecoveryDone { node: NodeId },
    /// Fallback checkpoint recovery for one sub-job completes: its
    /// migration's message sequence exhausted its retries under the fault
    /// plane and the sub-job rolled back instead of migrating.
    FallbackDone { sub: SubJobId },
    /// A sub-job finishes its compute.
    SubJobDone { sub: SubJobId },
}

/// Per-sub-job live state.
#[derive(Debug, Clone, Copy, PartialEq)]
enum LiveState {
    Running { done_at: SimTime },
    Migrating { resume_remaining_s: f64 },
    /// Lost to `from`'s failure; resumes when that failure's recovery ends.
    Recovering { resume_remaining_s: f64, from: NodeId },
    Done,
}

/// Result of a live run.
#[derive(Debug, Clone, Default)]
pub struct LiveOutcome {
    pub completed_at_s: f64,
    pub migrations: usize,
    pub rollbacks: usize,
    pub lost_then_recovered: usize,
    /// Follow-on failures injected on migration targets (cascade regimes).
    pub cascades: usize,
    /// Retransmissions spent across every exchange under the fault plane.
    pub net_retries: u64,
    /// Attempts that timed out (lost request or ack, or a partition).
    pub net_timeouts: u64,
    /// Recoveries taken one rung down the ladder: migrations that fell
    /// back to checkpoint recovery, plus restores degraded to cold.
    pub fallbacks: u64,
    /// Duplicate deliveries suppressed by receivers (counted, free).
    pub dup_suppressed: u64,
    /// Virtual-time event trace length (for determinism checks).
    pub events: u64,
}

/// Configuration of a live run.
#[derive(Debug, Clone)]
pub struct LiveCfg {
    pub costs: FtCosts,
    pub strategy: Strategy,
    pub n_subs: usize,
    pub z: usize,
    pub data_kb: u64,
    pub proc_kb: u64,
    /// Per-sub-job compute seconds (virtual).
    pub compute_s: f64,
    /// Fraction of injected failures that are predictable.
    pub predictable_frac: f64,
    /// Checkpoint recovery parameters (reactive path).
    pub ckpt_reinstate_s: f64,
    pub ckpt_overhead_s: f64,
    pub seed: u64,
}

/// Cascade regime: every migration's target node itself fails with
/// probability `p_follow`, the hardware failure striking `lag_s` after the
/// migration *starts* — the "the core we just moved to dies too" scenario
/// the paper's single-failure model cannot express. A `lag_s` below the
/// reinstate time kills the migration in flight (the sub-job is lost
/// mid-reinstate and rolls back); a larger `lag_s` lets the agent land,
/// learn of the standing prediction, and flee again.
#[derive(Debug, Clone, Copy)]
pub struct CascadeSpec {
    pub p_follow: f64,
    pub lag_s: f64,
}

/// Reusable per-trial allocations for live runs: the harness scratch
/// (engine queue, staging buffer) plus the system's per-sub-job and
/// per-node state vectors. One scratch per batch worker; reuse never
/// changes a result (tested in `tests/harness_properties.rs`).
pub struct LiveScratch {
    sim: TrialScratch<Ev>,
    host: Vec<NodeId>,
    state: Vec<LiveState>,
    doomed: Vec<bool>,
    predicted: Vec<bool>,
}

impl LiveScratch {
    pub fn new() -> Self {
        Self {
            sim: TrialScratch::new(),
            host: Vec::new(),
            state: Vec::new(),
            doomed: Vec::new(),
            predicted: Vec::new(),
        }
    }
}

impl Default for LiveScratch {
    fn default() -> Self {
        Self::new()
    }
}

struct System<'a> {
    cfg: &'a LiveCfg,
    topo: &'a Topology,
    faults: &'a FaultPlane,
    /// Side-stream sequence counter for fault draws; advances per message
    /// whether or not it survives, so replays are exact.
    fault_seq: u64,
    host: Vec<NodeId>,
    state: Vec<LiveState>,
    doomed: Vec<bool>,
    /// Nodes with a standing (predictable) failure prediction.
    predicted: Vec<bool>,
    cascade: Option<CascadeSpec>,
    outcome: LiveOutcome,
}

impl System<'_> {
    fn all_done(&self) -> bool {
        self.state.iter().all(|s| matches!(s, LiveState::Done))
    }

    fn reinstate_s(&self, z: usize, ctx: &mut Ctx<'_, '_, Ev>) -> f64 {
        let inp = RuleInputs { z, data_kb: self.cfg.data_kb, proc_kb: self.cfg.proc_kb };
        let base = match self.cfg.strategy {
            Strategy::Agent => self.cfg.costs.agent.reinstate_s(z, inp.data_kb, inp.proc_kb),
            Strategy::Core => self.cfg.costs.core.reinstate_s(z, inp.data_kb, inp.proc_kb),
            Strategy::Hybrid => match decide(inp).0 {
                Mover::Agent => self.cfg.costs.agent.reinstate_s(z, inp.data_kb, inp.proc_kb),
                Mover::Core => self.cfg.costs.core.reinstate_s(z, inp.data_kb, inp.proc_kb),
            },
            _ => panic!("livesim supports multi-agent strategies + checkpoint recovery"),
        };
        base * ctx.rng().jitter(self.cfg.costs.noise_sigma)
    }

    /// Pick a healthy neighbour of `from`, uniformly. Count-then-select
    /// over the adjacency slice: one RNG draw when any healthy neighbour
    /// exists (exactly like the old collect-then-`pick`, so the stream is
    /// unchanged) and no allocation.
    fn pick_target(&self, from: NodeId, ctx: &mut Ctx<'_, '_, Ev>) -> Option<NodeId> {
        let nbrs = self.topo.neighbours(from);
        let healthy = nbrs.iter().filter(|n| !self.doomed[n.0]).count();
        if healthy == 0 {
            return None;
        }
        let k = ctx.rng().range_usize(0, healthy);
        nbrs.iter().filter(|n| !self.doomed[n.0]).nth(k).copied()
    }
}

impl Scenario for System<'_> {
    type Msg = Ev;

    fn on_msg(&mut self, ctx: &mut Ctx<'_, '_, Ev>, ev: Ev) {
        let now = ctx.now();
        let me = ctx.me();
        match ev {
            Ev::Doom { node, predictable, cascade, fail_in_s } => {
                if self.doomed[node.0] {
                    // Already doomed (duplicate plan entry or a cascade onto
                    // a node another cascade reached first): a node fails
                    // once.
                    return;
                }
                self.doomed[node.0] = true;
                if cascade {
                    // counted here, after the dedup guard, so the tally is
                    // follow-on failures that actually happened
                    self.outcome.cascades += 1;
                }
                if predictable {
                    self.predicted[node.0] = true;
                    ctx.send_in(SimTime::from_secs(0.0), me, Ev::Prediction { node });
                }
                ctx.send_in(SimTime::from_secs(fail_in_s), me, Ev::Failure { node });
            }
            Ev::Prediction { node } => {
                // proactive path: migrate every sub-job on the node. The
                // in-place scan is victim-equivalent to the old snapshot
                // Vec: migrations only move subs *off* `node` (targets are
                // never doomed, and `node` is), so no sub joins the set
                // mid-scan.
                for i in 0..self.host.len() {
                    if self.host[i] != node {
                        continue;
                    }
                    let sub = SubJobId(i);
                    if let LiveState::Running { done_at } = self.state[i] {
                        let remaining = (done_at.saturating_sub(now)).as_secs();
                        let dur = self.reinstate_s(self.cfg.z, ctx);
                        if let Some(target) = self.pick_target(node, ctx) {
                            // Price the migration's message sequence on the
                            // fault plane's side-stream. Off plane: no draw,
                            // no cost — byte-identical to the unfaulted run.
                            let mut extra_s = 0.0;
                            let mut delivered = true;
                            if !self.faults.is_off() {
                                let cut = self.faults.cut_peer(node, target, now.as_secs());
                                let cost = migration_net_cost(
                                    self.cfg,
                                    self.faults,
                                    self.cfg.seed,
                                    faults::edge(node, target),
                                    &mut self.fault_seq,
                                    cut,
                                );
                                self.outcome.net_retries += cost.retries;
                                self.outcome.net_timeouts += cost.timeouts;
                                self.outcome.dup_suppressed += cost.dup_deliveries;
                                extra_s = cost.penalty_s;
                                delivered = cost.delivered;
                            }
                            if delivered {
                                self.state[i] =
                                    LiveState::Migrating { resume_remaining_s: remaining };
                                self.host[i] = target;
                                ctx.send_in(
                                    SimTime::from_secs(dur + extra_s),
                                    me,
                                    Ev::MigrationDone { sub, to: target },
                                );
                                // Cascade regimes: the chosen target is doomed
                                // right as the migration starts and fails
                                // `lag_s` later — possibly mid-reinstate.
                                if let Some(c) = self.cascade {
                                    if ctx.rng().chance(c.p_follow) {
                                        let predictable =
                                            ctx.rng().chance(self.cfg.predictable_frac);
                                        ctx.send_in(
                                            SimTime::from_secs(0.0),
                                            me,
                                            Ev::Doom {
                                                node: target,
                                                predictable,
                                                cascade: true,
                                                fail_in_s: c.lag_s,
                                            },
                                        );
                                    }
                                }
                            } else {
                                // The sequence exhausted its retries: fall
                                // back to reactive checkpoint recovery —
                                // one rung down the ladder, never a lost
                                // sub-job. The sub stays on the doomed node
                                // until FallbackDone re-homes it.
                                self.state[i] = LiveState::Recovering {
                                    resume_remaining_s: remaining,
                                    from: FALLBACK_FROM,
                                };
                                self.outcome.rollbacks += 1;
                                self.outcome.lost_then_recovered += 1;
                                self.outcome.fallbacks += 1;
                                let rdur =
                                    self.cfg.ckpt_reinstate_s + self.cfg.ckpt_overhead_s;
                                ctx.send_in(
                                    SimTime::from_secs(extra_s + rdur),
                                    me,
                                    Ev::FallbackDone { sub },
                                );
                            }
                        }
                        // no healthy neighbour: stay put; the failure path
                        // will trigger rollback.
                    }
                }
            }
            Ev::Failure { node } => {
                // Any sub-job still on the failed node is lost → reactive
                // rollback (the combined design's second line). A sub-job
                // caught *mid-migration onto* the failed node (possible only
                // in multi-failure regimes) loses its in-flight move too.
                // In-place scan; re-homed subs leave `node` (pick_target
                // never returns the doomed `node`), so the victim set and
                // draw order match the old snapshot Vec exactly.
                let mut lost = 0usize;
                for i in 0..self.state.len() {
                    if self.host[i] != node {
                        continue;
                    }
                    match self.state[i] {
                        LiveState::Running { done_at } => {
                            let remaining = (done_at.saturating_sub(now)).as_secs();
                            self.state[i] = LiveState::Recovering {
                                resume_remaining_s: remaining,
                                from: node,
                            };
                        }
                        LiveState::Migrating { resume_remaining_s } => {
                            // the migration aborts; its MigrationDone
                            // event will find a non-Migrating state and
                            // be ignored
                            self.state[i] = LiveState::Recovering {
                                resume_remaining_s,
                                from: node,
                            };
                        }
                        _ => continue,
                    }
                    // move it off the dead node for the resume
                    if let Some(t) = self.pick_target(node, ctx) {
                        self.host[i] = t;
                    }
                    lost += 1;
                }
                if lost > 0 {
                    let mut dur = self.cfg.ckpt_reinstate_s + self.cfg.ckpt_overhead_s;
                    // The restore itself crosses the network: price the
                    // RestoreRequest/RestoreData exchange against the
                    // checkpoint server on the side-stream. An exchange
                    // that exhausts its retries degrades to a cold restore
                    // (bottom rung of the ladder) — slower, never lost.
                    if !self.faults.is_off() {
                        let cost = self.faults.restore_exchange(
                            self.cfg.seed,
                            node,
                            &mut self.fault_seq,
                            now.as_secs(),
                            self.cfg.data_kb,
                        );
                        self.outcome.net_retries += cost.retries;
                        self.outcome.net_timeouts += cost.timeouts;
                        self.outcome.dup_suppressed += cost.dup_deliveries;
                        if cost.delivered {
                            dur += cost.penalty_s;
                        } else {
                            dur = dur * self.faults.cold_restore_factor + cost.penalty_s;
                            self.outcome.fallbacks += 1;
                        }
                    }
                    self.outcome.rollbacks += 1;
                    self.outcome.lost_then_recovered += lost;
                    ctx.send_in(SimTime::from_secs(dur), me, Ev::RecoveryDone { node });
                }
            }
            Ev::MigrationDone { sub, to } => {
                if let LiveState::Migrating { resume_remaining_s } = self.state[sub.0] {
                    debug_assert_eq!(self.host[sub.0], to);
                    // NB: `to` *can* be doomed here under multi-failure
                    // regimes — the sub-job lands and its loss is the
                    // target's pending Failure event's business.
                    let done_at = now + SimTime::from_secs(resume_remaining_s);
                    self.state[sub.0] = LiveState::Running { done_at };
                    self.outcome.migrations += 1;
                    ctx.send_at(done_at, me, Ev::SubJobDone { sub });
                    // The landed agent gathers predictions on arrival
                    // (Fig. 3 step 1): a standing prediction for this very
                    // node sends it fleeing again — the proactive escape
                    // down a cascade's doom chain.
                    if self.predicted[to.0] {
                        ctx.send_in(SimTime::from_secs(0.0), me, Ev::Prediction { node: to });
                    }
                }
            }
            Ev::RecoveryDone { node } => {
                // Only this failure's casualties resume; sub-jobs lost to a
                // later, still-running recovery keep waiting for their own
                // (multi-failure regimes can have overlapping rollbacks).
                for i in 0..self.state.len() {
                    if let LiveState::Recovering { resume_remaining_s, from } = self.state[i] {
                        if from == node {
                            // the resume host chosen at loss time may itself
                            // have been doomed while the rollback ran
                            // (multi-failure regimes): re-home before
                            // resuming rather than running on a dead node
                            if self.doomed[self.host[i].0] {
                                if let Some(t) = self.pick_target(self.host[i], ctx) {
                                    self.host[i] = t;
                                }
                            }
                            let done_at = now + SimTime::from_secs(resume_remaining_s);
                            self.state[i] = LiveState::Running { done_at };
                            ctx.send_at(done_at, me, Ev::SubJobDone { sub: SubJobId(i) });
                        }
                    }
                }
            }
            Ev::FallbackDone { sub } => {
                if let LiveState::Recovering { resume_remaining_s, from } = self.state[sub.0] {
                    if from == FALLBACK_FROM {
                        // the sub waited out its fallback on the doomed
                        // node; re-home before resuming, exactly like the
                        // node-failure recovery path
                        if self.doomed[self.host[sub.0].0] {
                            if let Some(t) = self.pick_target(self.host[sub.0], ctx) {
                                self.host[sub.0] = t;
                            }
                        }
                        let done_at = now + SimTime::from_secs(resume_remaining_s);
                        self.state[sub.0] = LiveState::Running { done_at };
                        ctx.send_at(done_at, me, Ev::SubJobDone { sub });
                    }
                }
            }
            Ev::SubJobDone { sub } => {
                if let LiveState::Running { done_at } = self.state[sub.0] {
                    if done_at == now {
                        self.state[sub.0] = LiveState::Done;
                    }
                    // else: a stale completion from before a migration —
                    // ignored because done_at moved.
                }
                if self.all_done() {
                    self.outcome.completed_at_s = now.as_secs();
                    ctx.stop();
                }
            }
        }
    }
}

/// Run a live simulation of `cfg` under a failure plan (the paper's
/// single-failure regimes; no cascades).
pub fn run_live(cfg: &LiveCfg, topo: &Topology, plan: &FailurePlan) -> LiveOutcome {
    run_live_with(cfg, topo, plan, None)
}

/// Run a live simulation with an optional cascade regime layered on top of
/// the plan. With `cascade = None` this is bit-identical to [`run_live`].
pub fn run_live_with(
    cfg: &LiveCfg,
    topo: &Topology,
    plan: &FailurePlan,
    cascade: Option<CascadeSpec>,
) -> LiveOutcome {
    run_live_scratch(cfg, topo, plan, cascade, &mut LiveScratch::new())
}

/// [`run_live_with`] on recycled trial allocations. Bit-identical results;
/// a batch worker threads one [`LiveScratch`] through consecutive trials so
/// steady-state trials allocate nothing but the plan.
pub fn run_live_scratch(
    cfg: &LiveCfg,
    topo: &Topology,
    plan: &FailurePlan,
    cascade: Option<CascadeSpec>,
    scratch: &mut LiveScratch,
) -> LiveOutcome {
    run_live_faulted_scratch(cfg, topo, plan, cascade, &FaultPlane::default(), scratch)
}

/// Live run under a network fault plane: migrations pay (and may lose)
/// their message sequences, restores pay the checkpoint-server exchange,
/// and every exhausted exchange falls back one rung instead of losing the
/// sub-job. With `faults` off this is byte-identical to
/// [`run_live_with`] — the plane is never consulted.
pub fn run_live_faulted(
    cfg: &LiveCfg,
    topo: &Topology,
    plan: &FailurePlan,
    cascade: Option<CascadeSpec>,
    faults: &FaultPlane,
) -> LiveOutcome {
    run_live_faulted_scratch(cfg, topo, plan, cascade, faults, &mut LiveScratch::new())
}

/// [`run_live_faulted`] on recycled trial allocations.
pub fn run_live_faulted_scratch(
    cfg: &LiveCfg,
    topo: &Topology,
    plan: &FailurePlan,
    cascade: Option<CascadeSpec>,
    faults: &FaultPlane,
    scratch: &mut LiveScratch,
) -> LiveOutcome {
    let mut rng = Rng::new(cfg.seed);
    let mut host = std::mem::take(&mut scratch.host);
    host.clear();
    host.extend((0..cfg.n_subs).map(|i| NodeId(i % topo.len())));
    let mut state = std::mem::take(&mut scratch.state);
    state.clear();
    state.extend(
        (0..cfg.n_subs).map(|_| LiveState::Running { done_at: SimTime::from_secs(cfg.compute_s) }),
    );
    let mut doomed = std::mem::take(&mut scratch.doomed);
    doomed.clear();
    doomed.resize(topo.len(), false);
    let mut predicted = std::mem::take(&mut scratch.predicted);
    predicted.clear();
    predicted.resize(topo.len(), false);
    let system = System {
        cfg,
        topo,
        faults,
        fault_seq: 0,
        host,
        state,
        doomed,
        predicted,
        cascade,
        outcome: LiveOutcome::default(),
    };
    let mut h = Harness::from_scratch(rng.fork(1), std::mem::take(&mut scratch.sim));
    let sys = h.add(system);
    for i in 0..cfg.n_subs {
        h.schedule(SimTime::from_secs(cfg.compute_s), sys, Ev::SubJobDone { sub: SubJobId(i) });
    }
    let lead = cfg.costs.predict.predict_time_s + 20.0;
    for e in &plan.events {
        let predictable = rng.chance(cfg.predictable_frac);
        let doom_at = e.at.saturating_sub(SimTime::from_secs(lead));
        h.schedule(
            doom_at,
            sys,
            Ev::Doom { node: e.node, predictable, cascade: false, fail_in_s: lead },
        );
    }
    let (fin, sim) = h.run_until_reclaim(SimTime(u64::MAX));
    scratch.sim = sim;
    let events = fin.events;
    let mut system = fin.into_scenario();
    let mut outcome = std::mem::take(&mut system.outcome);
    outcome.events = events;
    // hand the state vectors back for the next trial
    scratch.host = system.host;
    scratch.state = system.state;
    scratch.doomed = system.doomed;
    scratch.predicted = system.predicted;
    outcome
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{preset, ClusterPreset};
    use crate::failure::injector::FailureProcess;

    fn cfg(strategy: Strategy, predictable_frac: f64) -> LiveCfg {
        LiveCfg {
            costs: preset(ClusterPreset::Placentia).costs,
            strategy,
            n_subs: 4,
            z: 4,
            data_kb: 1 << 19,
            proc_kb: 1 << 19,
            compute_s: 3600.0,
            predictable_frac,
            ckpt_reinstate_s: 848.0,
            ckpt_overhead_s: 485.0,
            seed: 1,
        }
    }

    fn topo() -> Topology {
        Topology::ring(8, 2)
    }

    #[test]
    fn no_failures_completes_at_nominal() {
        let plan = FailurePlan { events: vec![] };
        let o = run_live(&cfg(Strategy::Core, 1.0), &topo(), &plan);
        assert_eq!(o.completed_at_s, 3600.0);
        assert_eq!(o.migrations, 0);
        assert_eq!(o.rollbacks, 0);
    }

    #[test]
    fn predicted_failure_adds_only_reinstate() {
        let mut rng = Rng::new(3);
        let plan = FailureProcess::Periodic { offset_s: 900.0 }.plan(1, 3600.0, 8, &mut rng);
        let o = run_live(&cfg(Strategy::Core, 1.0), &topo(), &plan);
        // the sub-job on the failed node migrated; total inflates only by
        // the sub-second reinstate (if any sub-job was on that node)
        assert_eq!(o.rollbacks, 0);
        assert!(o.completed_at_s < 3600.0 + 2.0, "{}", o.completed_at_s);
        if o.migrations > 0 {
            assert!(o.completed_at_s > 3600.0);
        }
    }

    #[test]
    fn unpredicted_failure_forces_rollback() {
        let mut rng = Rng::new(4);
        // strike node 0 (hosts sub-job 0) with an unpredictable failure
        let plan = FailureProcess::Periodic { offset_s: 600.0 }.plan(1, 3600.0, 1, &mut rng);
        let o = run_live(&cfg(Strategy::Hybrid, 0.0), &topo(), &plan);
        assert_eq!(o.rollbacks, 1);
        assert!(o.lost_then_recovered >= 1);
        // recovery adds reinstate + overhead
        assert!(
            o.completed_at_s >= 3600.0 + 848.0 + 485.0 - 1.0,
            "{}",
            o.completed_at_s
        );
    }

    #[test]
    fn live_total_matches_accounting_for_one_predicted_failure() {
        // the DES story and the window_row accounting agree on the
        // proactive path's added time (reinstate only, since overhead is
        // background and prediction lead is pre-failure)
        let mut rng = Rng::new(5);
        let plan = FailureProcess::Periodic { offset_s: 900.0 }.plan(1, 3600.0, 1, &mut rng);
        let c = cfg(Strategy::Core, 1.0);
        let o = run_live(&c, &topo(), &plan);
        assert_eq!(o.migrations, 1);
        let added = o.completed_at_s - 3600.0;
        let expected = c.costs.core.reinstate_s(4, 1 << 19, 1 << 19);
        assert!((added - expected).abs() < 0.1, "added {added} expected {expected}");
    }

    #[test]
    fn migration_storm_many_failures_job_still_completes() {
        let mut rng = Rng::new(6);
        let plan = FailureProcess::RandomUniformK { k: 6 }.plan(1, 3600.0, 8, &mut rng);
        let o = run_live(&cfg(Strategy::Hybrid, 0.8), &topo(), &plan);
        assert!(o.completed_at_s >= 3600.0);
        assert!(o.completed_at_s < 3600.0 * 3.0, "runaway: {}", o.completed_at_s);
    }

    #[test]
    fn deterministic_per_seed() {
        let mut rng = Rng::new(7);
        let plan = FailureProcess::RandomUniformK { k: 3 }.plan(1, 3600.0, 8, &mut rng);
        let a = run_live(&cfg(Strategy::Agent, 0.5), &topo(), &plan);
        let b = run_live(&cfg(Strategy::Agent, 0.5), &topo(), &plan);
        assert_eq!(a.completed_at_s, b.completed_at_s);
        assert_eq!(a.events, b.events);
    }

    #[test]
    fn cascade_none_is_bit_identical_to_run_live() {
        let mut rng = Rng::new(8);
        let plan = FailureProcess::RandomUniformK { k: 4 }.plan(1, 3600.0, 8, &mut rng);
        let c = cfg(Strategy::Hybrid, 0.7);
        let a = run_live(&c, &topo(), &plan);
        let b = run_live_with(&c, &topo(), &plan, None);
        assert_eq!(a.completed_at_s, b.completed_at_s);
        assert_eq!(a.events, b.events);
        assert_eq!(a.migrations, b.migrations);
        assert_eq!(a.rollbacks, b.rollbacks);
        assert_eq!(a.cascades, 0);
    }

    #[test]
    fn scratch_reuse_is_bit_identical() {
        let mut rng = Rng::new(12);
        let plan = FailureProcess::RandomUniformK { k: 4 }.plan(1, 3600.0, 8, &mut rng);
        let c = cfg(Strategy::Hybrid, 0.6);
        let cascade = Some(CascadeSpec { p_follow: 0.5, lag_s: 2.0 });
        let mut scratch = LiveScratch::new();
        for _ in 0..4 {
            let fresh = run_live_with(&c, &topo(), &plan, cascade);
            let reused = run_live_scratch(&c, &topo(), &plan, cascade, &mut scratch);
            assert_eq!(fresh.completed_at_s.to_bits(), reused.completed_at_s.to_bits());
            assert_eq!(fresh.events, reused.events);
            assert_eq!(fresh.migrations, reused.migrations);
            assert_eq!(fresh.rollbacks, reused.rollbacks);
            assert_eq!(fresh.cascades, reused.cascades);
        }
    }

    #[test]
    fn cascades_trigger_followon_failures() {
        let mut rng = Rng::new(9);
        let plan = FailureProcess::Periodic { offset_s: 900.0 }.plan(1, 3600.0, 1, &mut rng);
        let c = cfg(Strategy::Core, 1.0);
        // lag well above the sub-second reinstate: the agent lands, learns
        // of the standing prediction, and flees down the doom chain
        let cascade = CascadeSpec { p_follow: 1.0, lag_s: 5.0 };
        let o = run_live_with(&c, &topo(), &plan, Some(cascade));
        // the first migration's target is always doomed in turn
        assert!(o.cascades >= 1, "{o:?}");
        // the job still completes (predictable cascade ⇒ chain of migrations)
        assert!(o.completed_at_s >= 3600.0);
        assert!(o.migrations >= 2 || o.rollbacks >= 1, "{o:?}");
    }

    #[test]
    fn cascade_below_reinstate_kills_migration_in_flight() {
        let mut rng = Rng::new(11);
        let plan = FailureProcess::Periodic { offset_s: 900.0 }.plan(1, 3600.0, 1, &mut rng);
        let c = cfg(Strategy::Core, 1.0);
        // the target fails 0.1 s after the migration starts — well inside
        // the ~0.38 s reinstate, so the in-flight move is lost and the
        // sub-job falls back to checkpoint rollback
        let cascade = CascadeSpec { p_follow: 1.0, lag_s: 0.1 };
        let o = run_live_with(&c, &topo(), &plan, Some(cascade));
        assert!(o.cascades >= 1, "{o:?}");
        assert!(o.rollbacks >= 1, "mid-reinstate loss must roll back: {o:?}");
        assert!(o.lost_then_recovered >= 1, "{o:?}");
        assert!(
            o.completed_at_s >= 3600.0 + 848.0 + 485.0 - 1.0,
            "rollback cost must show: {}",
            o.completed_at_s
        );
    }

    #[test]
    fn default_plane_is_byte_identical_to_run_live() {
        let mut rng = Rng::new(13);
        let plan = FailureProcess::RandomUniformK { k: 4 }.plan(1, 3600.0, 8, &mut rng);
        let c = cfg(Strategy::Hybrid, 0.7);
        let a = run_live(&c, &topo(), &plan);
        let b = run_live_faulted(&c, &topo(), &plan, None, &FaultPlane::default());
        assert_eq!(a.completed_at_s.to_bits(), b.completed_at_s.to_bits());
        assert_eq!(a.events, b.events);
        assert_eq!(a.migrations, b.migrations);
        assert_eq!(a.rollbacks, b.rollbacks);
        assert_eq!(b.net_retries, 0);
        assert_eq!(b.net_timeouts, 0);
        assert_eq!(b.fallbacks, 0);
        assert_eq!(b.dup_suppressed, 0);
    }

    #[test]
    fn total_peer_loss_falls_back_instead_of_migrating() {
        use crate::net::LinkFaults;
        let mut rng = Rng::new(3);
        let plan = FailureProcess::Periodic { offset_s: 900.0 }.plan(1, 3600.0, 8, &mut rng);
        let c = cfg(Strategy::Core, 1.0);
        let p = FaultPlane {
            peer: LinkFaults { loss_p: 1.0, ..LinkFaults::off() },
            ..FaultPlane::default()
        };
        let clean = run_live(&c, &topo(), &plan);
        let o = run_live_faulted(&c, &topo(), &plan, None, &p);
        assert_eq!(o.migrations, 0, "no sequence can complete: {o:?}");
        assert_eq!(o.fallbacks as usize, clean.migrations, "every migration fell back");
        assert_eq!(o.rollbacks as u64, o.fallbacks, "{o:?}");
        assert!(o.net_timeouts > 0 && o.net_retries > 0, "{o:?}");
        // the job still completes, paying the checkpoint recovery instead
        if clean.migrations > 0 {
            assert!(
                o.completed_at_s >= 3600.0 + 848.0 + 485.0 - 1.0,
                "{}",
                o.completed_at_s
            );
        }
    }

    #[test]
    fn severed_checkpoint_server_degrades_the_restore() {
        use crate::net::{CutSet, Partition};
        let mut rng = Rng::new(4);
        let plan = FailureProcess::Periodic { offset_s: 600.0 }.plan(1, 3600.0, 1, &mut rng);
        let c = cfg(Strategy::Hybrid, 0.0); // unpredicted → reactive restore
        let p = FaultPlane {
            partitions: vec![Partition {
                start_s: 0.0,
                end_s: 8.0 * 3600.0,
                cut: CutSet::Checkpoint,
            }],
            ..FaultPlane::default()
        };
        let clean = run_live(&c, &topo(), &plan);
        let o = run_live_faulted(&c, &topo(), &plan, None, &p);
        assert_eq!(o.rollbacks, clean.rollbacks);
        assert!(o.fallbacks >= 1, "restore exchange must exhaust: {o:?}");
        // cold restore at factor 2 plus timeout/backoff penalties
        assert!(
            o.completed_at_s > clean.completed_at_s + (848.0 + 485.0) - 1.0,
            "degraded {} vs clean {}",
            o.completed_at_s,
            clean.completed_at_s
        );
        // degraded, never lost: the run terminated with every sub done
        assert!(o.completed_at_s.is_finite());
    }

    #[test]
    fn faulted_run_is_deterministic_per_seed() {
        use crate::net::LinkFaults;
        let mut rng = Rng::new(21);
        let plan = FailureProcess::RandomUniformK { k: 5 }.plan(1, 3600.0, 8, &mut rng);
        let c = cfg(Strategy::Hybrid, 0.6);
        let p = FaultPlane {
            peer: LinkFaults { loss_p: 0.5, dup_p: 0.2, delay_p: 0.4, delay_mean_s: 1.0 },
            ckpt: LinkFaults { loss_p: 0.3, ..LinkFaults::off() },
            ..FaultPlane::default()
        };
        let a = run_live_faulted(&c, &topo(), &plan, None, &p);
        let b = run_live_faulted(&c, &topo(), &plan, None, &p);
        assert_eq!(a.completed_at_s.to_bits(), b.completed_at_s.to_bits());
        assert_eq!(a.events, b.events);
        assert_eq!(a.net_retries, b.net_retries);
        assert_eq!(a.net_timeouts, b.net_timeouts);
        assert_eq!(a.fallbacks, b.fallbacks);
        assert_eq!(a.dup_suppressed, b.dup_suppressed);
    }

    #[test]
    fn cascade_costs_more_than_single_failure() {
        let mut rng = Rng::new(10);
        let plan = FailureProcess::Periodic { offset_s: 600.0 }.plan(1, 3600.0, 1, &mut rng);
        let c = cfg(Strategy::Hybrid, 1.0);
        let single = run_live(&c, &topo(), &plan);
        let casc =
            run_live_with(&c, &topo(), &plan, Some(CascadeSpec { p_follow: 1.0, lag_s: 5.0 }));
        assert!(casc.completed_at_s >= single.completed_at_s, "{casc:?} vs {single:?}");
    }
}
