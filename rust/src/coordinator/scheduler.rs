//! Sub-job placement onto cluster nodes.
//!
//! The experiments place one sub-job per node (the paper's genome runs:
//! "three nodes of the cluster performed the search operation while the
//! fourth node combined the results"). The scheduler also exposes the
//! adjacency view a protocol episode needs (which neighbours exist and
//! which are predicted to fail).

use crate::job::graph::DepGraph;
use crate::net::message::SubJobId;
use crate::net::{NodeId, Topology};

/// A placement of sub-jobs onto nodes.
#[derive(Debug, Clone)]
pub struct Placement {
    /// node hosting each sub-job, indexed by SubJobId.
    pub host: Vec<NodeId>,
}

impl Placement {
    /// Round-robin placement of `n_subs` sub-jobs over the topology's nodes.
    pub fn round_robin(n_subs: usize, topo: &Topology) -> Self {
        let n = topo.len();
        Self { host: (0..n_subs).map(|i| NodeId(i % n)).collect() }
    }

    /// Place a dependency graph so that adjacent graph levels land on
    /// distinct nodes where possible (reduces co-failure of producer and
    /// consumer).
    pub fn spread(graph: &DepGraph, topo: &Topology) -> Self {
        let order = graph.topo_order();
        let n = topo.len();
        let mut host = vec![NodeId(0); graph.len()];
        for (i, s) in order.iter().enumerate() {
            host[s.0] = NodeId(i % n);
        }
        Self { host }
    }

    pub fn node_of(&self, s: SubJobId) -> NodeId {
        self.host[s.0]
    }

    /// Sub-jobs hosted on `node`.
    pub fn on_node(&self, node: NodeId) -> Vec<SubJobId> {
        self.host
            .iter()
            .enumerate()
            .filter(|(_, &h)| h == node)
            .map(|(i, _)| SubJobId(i))
            .collect()
    }

    /// The adjacency view used by a migration episode for `s`: every
    /// neighbour of its host, flagged with the given predicate ("is this
    /// neighbour predicted to fail?").
    pub fn adjacency_view(
        &self,
        s: SubJobId,
        topo: &Topology,
        doomed: impl Fn(NodeId) -> bool,
    ) -> Vec<(NodeId, bool)> {
        topo.neighbours(self.node_of(s)).iter().map(|&n| (n, doomed(n))).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_robin_wraps() {
        let topo = Topology::mesh(3);
        let p = Placement::round_robin(7, &topo);
        assert_eq!(p.host.len(), 7);
        assert_eq!(p.node_of(SubJobId(0)), NodeId(0));
        assert_eq!(p.node_of(SubJobId(3)), NodeId(0));
        assert_eq!(p.node_of(SubJobId(5)), NodeId(2));
    }

    #[test]
    fn on_node_inverse_of_host() {
        let topo = Topology::mesh(2);
        let p = Placement::round_robin(4, &topo);
        assert_eq!(p.on_node(NodeId(0)), vec![SubJobId(0), SubJobId(2)]);
        assert_eq!(p.on_node(NodeId(1)), vec![SubJobId(1), SubJobId(3)]);
    }

    #[test]
    fn spread_covers_all_subjobs() {
        let g = DepGraph::reduction_tree(8, 2);
        let topo = Topology::ring(5, 1);
        let p = Placement::spread(&g, &topo);
        assert_eq!(p.host.len(), g.len());
    }

    #[test]
    fn adjacency_view_flags_doomed() {
        let topo = Topology::ring(5, 1);
        let p = Placement::round_robin(5, &topo);
        let view = p.adjacency_view(SubJobId(2), &topo, |n| n == NodeId(3));
        // node 2's ring neighbours: 1 and 3
        assert_eq!(view.len(), 2);
        assert!(view.contains(&(NodeId(1), false)));
        assert!(view.contains(&(NodeId(3), true)));
    }
}
