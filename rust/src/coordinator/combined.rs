//! The paper's proposed **combined** strategy (Discussion, "Overcoming the
//! problems of Checkpointing"): the multi-agent approaches as a first line
//! of anticipatory response, backed by checkpointing as the reactive second
//! line for the failures prediction cannot catch.
//!
//! Expected per-failure cost:
//!
//! * with probability `coverage` the failure is predicted → proactive path
//!   (`predict + reinstate_ma + overhead_ma`), nothing lost;
//! * otherwise → reactive rollback (`elapsed + reinstate_ckpt +
//!   overhead_ckpt`);
//! * false alarms (precision < 1) add instability: each prediction that is
//!   not followed by a failure costs one pointless migration
//!   (`reinstate_ma`), at a rate of `coverage·(1/precision − 1)` per real
//!   failure.

use super::ftmanager::Strategy;
use super::run::{charged_failures, mean_random_elapsed_s, measure_reinstate, ExperimentCfg, WindowRow};
use crate::checkpoint::{periodicity_factors, CheckpointStrategy};
use crate::sim::Rng;

/// Which checkpoint baseline backs the combined strategy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Combined {
    pub agent: Strategy,
    pub backstop: CheckpointStrategy,
}

impl Combined {
    pub fn name(&self) -> String {
        format!("{} + {} (combined)", self.agent.name(), self.backstop.name())
    }

    /// Expected per-failure cost given prediction quality.
    pub fn per_failure_s(&self, cfg: &ExperimentCfg, elapsed_s: f64, reinstate_ma: f64) -> f64 {
        let costs = &cfg.cluster.costs;
        let p = costs.predict;
        let (ovf, _) = periodicity_factors(cfg.period_h);
        let ma_overhead = self.agent.ma_overhead_s(costs, cfg.z, cfg.data_kb) * ovf;
        let proactive = p.predict_time_s + reinstate_ma + ma_overhead;
        let ck_re = self.backstop.reinstate_s(&costs.ckpt, cfg.n_nodes, cfg.data_kb, cfg.period_h);
        let ck_ov = self.backstop.overhead_s(&costs.ckpt, cfg.n_nodes, cfg.data_kb, cfg.period_h);
        let reactive = elapsed_s + ck_re + ck_ov;
        // instability: false alarms per real failure
        let fa_rate = p.coverage * (1.0 / p.precision - 1.0);
        let instability = fa_rate * reinstate_ma;
        p.coverage * proactive + (1.0 - p.coverage) * reactive + instability
    }

    /// Build the Table-row for the combined strategy.
    pub fn window_row(&self, cfg: &ExperimentCfg) -> WindowRow {
        let mut rng = Rng::new(cfg.seed ^ 0xC0B1);
        let reinstate_ma = measure_reinstate(self.agent, cfg, &mut rng).mean;
        let elapsed_periodic = cfg.periodic_offset_min * 60.0;
        let elapsed_random = mean_random_elapsed_s(cfg.period_h, 5000, &mut rng);
        let job_s = cfg.job_h * 3600.0;
        let n1 = charged_failures(1.0, cfg.job_h, cfg.period_h);
        let n5 = charged_failures(5.0, cfg.job_h, cfg.period_h);
        let per_p = self.per_failure_s(cfg, elapsed_periodic, reinstate_ma);
        let per_r = self.per_failure_s(cfg, elapsed_random, reinstate_ma);
        let costs = &cfg.cluster.costs;
        let (ovf, _) = periodicity_factors(cfg.period_h);
        WindowRow {
            strategy: self.agent,
            period_h: cfg.period_h,
            predict_s: Some(costs.predict.predict_time_s),
            reinstate_periodic_s: reinstate_ma,
            reinstate_random_s: reinstate_ma,
            overhead_periodic_s: self.agent.ma_overhead_s(costs, cfg.z, cfg.data_kb) * ovf,
            overhead_random_s: self.agent.ma_overhead_s(costs, cfg.z, cfg.data_kb) * ovf,
            total_nofail_s: job_s,
            total_one_periodic_s: job_s + n1 * per_p,
            total_one_random_s: job_s + n1 * per_r,
            total_five_random_s: job_s + n5 * per_r,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{preset, ClusterPreset};
    use crate::coordinator::run::window_row;

    fn cfg() -> ExperimentCfg {
        ExperimentCfg::table1(preset(ClusterPreset::Placentia))
    }

    fn combined() -> Combined {
        Combined { agent: Strategy::Core, backstop: CheckpointStrategy::CentralSingle }
    }

    #[test]
    fn combined_between_pure_strategies() {
        // combined must beat pure checkpointing (proactive catches 29%) but
        // lose to the idealised pure multi-agent row (which assumes every
        // failure is caught).
        let c = cfg();
        let comb = combined().window_row(&c);
        let ck = window_row(Strategy::Checkpoint(CheckpointStrategy::CentralSingle), &c);
        let ma = window_row(Strategy::Core, &c);
        assert!(comb.total_one_random_s < ck.total_one_random_s);
        assert!(comb.total_one_random_s > ma.total_one_random_s);
    }

    #[test]
    fn coverage_gain_matches_expectation() {
        // penalty reduction vs pure checkpointing ≈ coverage fraction of
        // (reactive - proactive) cost
        let c = cfg();
        let comb = combined().window_row(&c);
        let ck = window_row(Strategy::Checkpoint(CheckpointStrategy::CentralSingle), &c);
        let saved = ck.total_one_random_s - comb.total_one_random_s;
        let reactive_penalty = ck.total_one_random_s - ck.total_nofail_s;
        // saved should be roughly coverage × reactive penalty (instability
        // and proactive costs eat a little)
        let frac = saved / reactive_penalty;
        assert!((0.18..0.32).contains(&frac), "saved fraction {frac}");
    }

    #[test]
    fn instability_costs_nonzero() {
        let c = cfg();
        let comb = combined();
        let mut rng = Rng::new(1);
        let re = measure_reinstate(Strategy::Core, &c, &mut rng).mean;
        let with = comb.per_failure_s(&c, 1800.0, re);
        // a perfect-precision clone for comparison
        let mut perfect = c.clone();
        perfect.cluster.costs.predict.precision = 1.0;
        let without = comb.per_failure_s(&perfect, 1800.0, re);
        assert!(with > without);
        assert!(with - without < 1.0, "instability is sub-second per failure");
    }

    #[test]
    fn name_mentions_both_lines() {
        let n = combined().name();
        assert!(n.contains("core intelligence") && n.contains("single server"));
    }
}
