//! The experiment executor: produces the rows of Tables 1 and 2 and the
//! reinstate-time measurements behind Figs. 8-13.
//!
//! ## Accounting model (documented deviations in EXPERIMENTS.md)
//!
//! For checkpointing strategies, every *charged* failure costs
//! `elapsed-since-checkpoint + reinstate + overhead`; the job then resumes
//! from the checkpoint. With periodicity `p` hours over a `H`-hour job and
//! `k` failures/hour, the number of charged failures is
//! `k · max(1, floor(H / p))` — failures striking already re-executed work
//! are absorbed into the same rollback (this reproduces the paper's
//! per-row arithmetic for Table 1 exactly and Table 2 to within its own
//! internal inconsistencies).
//!
//! For the proactive multi-agent strategies nothing is lost on a predicted
//! failure, so each failure costs `predict + reinstate + overhead`.
//! Cold restart uses the survival simulation of
//! [`crate::checkpoint::cold_restart`].

use super::ftmanager::Strategy;
use crate::agentft::migration::{
    draw_episode, EpisodeDraws, EpisodeScratch as AgentScratch, AGENT_JITTERS,
};
use crate::agentft::simulate_agent_migration_drawn_scratch;
use crate::checkpoint::cold_restart::{mean_cold_restart, ColdRestartParams};
use crate::checkpoint::{periodicity_factors, CheckpointStrategy};
use crate::cluster::{ClusterSpec, FtCosts};
use crate::coreft::migration::{EpisodeScratch as CoreScratch, CORE_JITTERS};
use crate::coreft::simulate_core_migration_drawn_scratch;
use crate::hybrid::rules::{decide, Mover, RuleInputs};
use crate::metrics::Summary;
use crate::net::NodeId;
use crate::scenario::batch;
use crate::sim::Rng;

/// Configuration of a window experiment (one Table-1/Table-2 cell group).
#[derive(Debug, Clone)]
pub struct ExperimentCfg {
    pub cluster: ClusterSpec,
    /// Nodes participating in the job (searchers + combiner).
    pub n_nodes: usize,
    /// Dependencies of the sub-job being failed (the paper's Z).
    pub z: usize,
    pub data_kb: u64,
    pub proc_kb: u64,
    /// Nominal job duration in hours (1 for Table 1, 5 for Table 2).
    pub job_h: f64,
    /// Checkpoint periodicity in hours.
    pub period_h: f64,
    /// Offset of the periodic failure after a checkpoint, minutes
    /// (15 in Table 1, 14 in Table 2 / Fig. 16).
    pub periodic_offset_min: f64,
    pub trials: usize,
    pub seed: u64,
    /// Worker threads for trial sweeps: `Some(n)` forces `n` (`Some(0)` ⇒
    /// one per core); `None` defers to the `BIOMAFT_THREADS` env var and
    /// then the trial-count default — see [`batch::thread_policy`] and
    /// EXPERIMENTS.md §Perf.
    pub threads: Option<usize>,
}

impl ExperimentCfg {
    /// Table 1's configuration on a given cluster.
    pub fn table1(cluster: ClusterSpec) -> Self {
        Self {
            cluster,
            n_nodes: 4,
            z: 4,
            data_kb: 1 << 19,
            proc_kb: 1 << 19,
            job_h: 1.0,
            period_h: 1.0,
            periodic_offset_min: 15.0,
            trials: 30,
            seed: 2014,
            threads: None,
        }
    }

    /// Table 2's configuration (5-hour job) at a given periodicity.
    pub fn table2(cluster: ClusterSpec, period_h: f64) -> Self {
        Self { job_h: 5.0, period_h, periodic_offset_min: 14.0, ..Self::table1(cluster) }
    }
}

/// The paper's reinstate scenario: three healthy adjacent cores.
pub fn adjacent3() -> Vec<(NodeId, bool)> {
    (1..=3).map(|i| (NodeId(i), false)).collect()
}

/// A fully resolved reinstate measurement point: the hybrid decision
/// hoisted, costs and sizes fixed — everything one trial needs except its
/// [`EpisodeDraws`]. Shared by [`measure_reinstate`] (one point at a time)
/// and the fused sweep executor
/// ([`scenario::sweep`](crate::scenario::sweep), which runs whole grids of
/// these as one task list).
#[derive(Debug, Clone)]
pub struct ReinstatePoint {
    pub costs: FtCosts,
    pub mover: Mover,
    /// Fixed per-trial addition (the hybrid negotiation exchange).
    pub extra_s: f64,
    /// Jitter draws per trial for this mover.
    pub n_jitters: usize,
    pub z: usize,
    pub data_kb: u64,
    pub proc_kb: u64,
}

impl ReinstatePoint {
    /// Resolve a (strategy, configuration) pair. The hybrid decision is a
    /// pure function of the (fixed) trial inputs, so the per-trial
    /// `decide` of the historical loop is hoisted here. Panics on
    /// non-multi-agent strategies, like `measure_reinstate` always has.
    pub fn new(strategy: Strategy, cfg: &ExperimentCfg) -> Self {
        const NEGOTIATION_S: f64 = 0.4e-3;
        let (mover, extra_s) = match strategy {
            Strategy::Agent => (Mover::Agent, 0.0),
            Strategy::Core => (Mover::Core, 0.0),
            Strategy::Hybrid => {
                let inp = RuleInputs { z: cfg.z, data_kb: cfg.data_kb, proc_kb: cfg.proc_kb };
                (decide(inp).0, NEGOTIATION_S)
            }
            _ => panic!("measure_reinstate is for multi-agent strategies"),
        };
        let n_jitters = match mover {
            Mover::Agent => AGENT_JITTERS,
            Mover::Core => CORE_JITTERS,
        };
        Self {
            costs: cfg.cluster.costs,
            mover,
            extra_s,
            n_jitters,
            z: cfg.z,
            data_kb: cfg.data_kb,
            proc_kb: cfg.proc_kb,
        }
    }

    /// Run one deterministic episode from its pre-sampled draws and return
    /// the trial's measurement (`extra_s` + reinstate time).
    pub fn run_episode(&self, draws: &EpisodeDraws, sc: &mut ReinstateScratch) -> f64 {
        self.extra_s
            + match self.mover {
                Mover::Agent => simulate_agent_migration_drawn_scratch(
                    &self.costs.agent,
                    self.z,
                    self.data_kb,
                    self.proc_kb,
                    draws,
                    &mut sc.agent,
                )
                .reinstate_s,
                Mover::Core => simulate_core_migration_drawn_scratch(
                    &self.costs.core,
                    self.z,
                    self.data_kb,
                    self.proc_kb,
                    draws,
                    &mut sc.core,
                )
                .reinstate_s,
            }
    }
}

/// Per-worker episode allocations for either mover (the cells of one sweep
/// mix agent and core points, so workers carry both — each is a handful of
/// reusable `Vec`s).
pub struct ReinstateScratch {
    agent: AgentScratch,
    core: CoreScratch,
}

impl ReinstateScratch {
    pub fn new() -> Self {
        Self { agent: AgentScratch::new(), core: CoreScratch::new() }
    }
}

impl Default for ReinstateScratch {
    fn default() -> Self {
        Self::new()
    }
}

/// Measure the mean reinstate time of a multi-agent strategy over `trials`
/// DES episodes with trial noise (the paper's 30-trial means, ΔT_A2/ΔT_C2).
///
/// Each trial's randomness is drawn *serially* from `rng` — bit-compatible
/// with the historical serial trial loop, so Tables 1–2 and Figs. 8–13
/// reproduce exactly — and the deterministic episodes then execute through
/// the batch runner. The thread count follows [`batch::thread_policy`]:
/// `cfg.threads`, then `BIOMAFT_THREADS`, then serial below the small-sweep
/// threshold. (Grid experiments no longer loop over this — they flatten
/// into [`scenario::sweep`](crate::scenario::sweep) so even 30-trial cells
/// run in parallel across the grid.)
pub fn measure_reinstate(
    strategy: Strategy,
    cfg: &ExperimentCfg,
    rng: &mut Rng,
) -> Summary {
    let point = ReinstatePoint::new(strategy, cfg);
    let adjacent = adjacent3();
    let sigma = point.costs.noise_sigma;
    let trials = cfg.trials.max(1);
    let draws: Vec<EpisodeDraws> = (0..trials)
        .map(|_| {
            draw_episode(point.n_jitters, &adjacent, rng, sigma).expect("healthy adjacent exists")
        })
        .collect();
    let threads = batch::thread_policy(cfg.threads, trials);
    // Workers carry an episode scratch across their trials (engine queue /
    // staging / log allocations), so steady-state episodes only allocate
    // their step trace.
    let xs = batch::parallel_map_trials_scratch(trials, threads, ReinstateScratch::new, |sc, i| {
        point.run_episode(&draws[i], sc)
    });
    Summary::of(&xs)
}

/// One row of Table 1 / Table 2 (all times in seconds).
#[derive(Debug, Clone)]
pub struct WindowRow {
    pub strategy: Strategy,
    pub period_h: f64,
    /// Time to predict one failure (multi-agent strategies only).
    pub predict_s: Option<f64>,
    pub reinstate_periodic_s: f64,
    pub reinstate_random_s: f64,
    pub overhead_periodic_s: f64,
    pub overhead_random_s: f64,
    pub total_nofail_s: f64,
    pub total_one_periodic_s: f64,
    pub total_one_random_s: f64,
    pub total_five_random_s: f64,
}

/// Mean elapsed time from the last checkpoint to a random failure within a
/// `period_h` window (the paper reports 31 m 14 s over 5000 trials of a 1 h
/// window — a hair above the exact mean, as sampling noise would give).
pub fn mean_random_elapsed_s(period_h: f64, trials: usize, rng: &mut Rng) -> f64 {
    let w = period_h * 3600.0;
    (0..trials).map(|_| rng.uniform(0.0, w)).sum::<f64>() / trials as f64
}

/// Number of charged failures (see module docs).
pub fn charged_failures(per_hour: f64, job_h: f64, period_h: f64) -> f64 {
    per_hour * (job_h / period_h).floor().max(1.0)
}

/// Compute one strategy's row.
pub fn window_row(strategy: Strategy, cfg: &ExperimentCfg) -> WindowRow {
    let mut rng = Rng::new(cfg.seed ^ strategy_tag(strategy));
    let costs = &cfg.cluster.costs;
    let job_s = cfg.job_h * 3600.0;
    let elapsed_periodic = cfg.periodic_offset_min * 60.0;
    let elapsed_random = mean_random_elapsed_s(cfg.period_h, 5000, &mut rng);
    let n1 = charged_failures(1.0, cfg.job_h, cfg.period_h);
    let n5 = charged_failures(5.0, cfg.job_h, cfg.period_h);

    match strategy {
        Strategy::Checkpoint(ck) => {
            let reinstate = ck.reinstate_s(&costs.ckpt, cfg.n_nodes, cfg.data_kb, cfg.period_h);
            let overhead = ck.overhead_s(&costs.ckpt, cfg.n_nodes, cfg.data_kb, cfg.period_h);
            let per_fail_p = elapsed_periodic + reinstate + overhead;
            let per_fail_r = elapsed_random + reinstate + overhead;
            WindowRow {
                strategy,
                period_h: cfg.period_h,
                predict_s: None,
                reinstate_periodic_s: reinstate,
                reinstate_random_s: reinstate,
                overhead_periodic_s: overhead,
                overhead_random_s: overhead,
                total_nofail_s: job_s,
                total_one_periodic_s: job_s + n1 * per_fail_p,
                total_one_random_s: job_s + n1 * per_fail_r,
                total_five_random_s: job_s + n5 * per_fail_r,
            }
        }
        Strategy::Agent | Strategy::Core | Strategy::Hybrid => {
            let reinstate = measure_reinstate(strategy, cfg, &mut rng).mean;
            let (ovf, _) = periodicity_factors(cfg.period_h);
            let overhead = strategy.ma_overhead_s(costs, cfg.z, cfg.data_kb) * ovf;
            let predict = costs.predict.predict_time_s;
            let per_fail = predict + reinstate + overhead;
            WindowRow {
                strategy,
                period_h: cfg.period_h,
                predict_s: Some(predict),
                reinstate_periodic_s: reinstate,
                reinstate_random_s: reinstate,
                overhead_periodic_s: overhead,
                overhead_random_s: overhead,
                total_nofail_s: job_s,
                total_one_periodic_s: job_s + n1 * per_fail,
                total_one_random_s: job_s + n1 * per_fail,
                total_five_random_s: job_s + n5 * per_fail,
            }
        }
        Strategy::ColdRestart => {
            let admin = costs.ckpt.cold_restart_admin_s;
            let trials = 2000;
            let p1 = ColdRestartParams { admin_s: admin, ..ColdRestartParams::periodic_1h(job_s) };
            let r1 = ColdRestartParams { admin_s: admin, ..ColdRestartParams::random_1h(job_s) };
            let r5 = ColdRestartParams { admin_s: admin, ..ColdRestartParams::random_5h(job_s) };
            WindowRow {
                strategy,
                period_h: cfg.period_h,
                predict_s: None,
                reinstate_periodic_s: admin,
                reinstate_random_s: admin,
                overhead_periodic_s: 0.0,
                overhead_random_s: 0.0,
                total_nofail_s: job_s,
                total_one_periodic_s: mean_cold_restart(&p1, trials, &mut rng).total_s,
                total_one_random_s: mean_cold_restart(&r1, trials, &mut rng).total_s,
                total_five_random_s: mean_cold_restart(&r5, trials, &mut rng).total_s,
            }
        }
    }
}

fn strategy_tag(s: Strategy) -> u64 {
    match s {
        Strategy::ColdRestart => 0x1,
        Strategy::Checkpoint(CheckpointStrategy::CentralSingle) => 0x2,
        Strategy::Checkpoint(CheckpointStrategy::CentralMulti) => 0x3,
        Strategy::Checkpoint(CheckpointStrategy::Decentral) => 0x4,
        Strategy::Agent => 0x5,
        Strategy::Core => 0x6,
        Strategy::Hybrid => 0x7,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{preset, ClusterPreset};
    use crate::util::fmt::hms;

    fn cfg() -> ExperimentCfg {
        ExperimentCfg::table1(preset(ClusterPreset::Placentia))
    }

    #[test]
    fn charged_failure_counts() {
        assert_eq!(charged_failures(1.0, 1.0, 1.0), 1.0);
        assert_eq!(charged_failures(5.0, 1.0, 1.0), 5.0);
        assert_eq!(charged_failures(1.0, 5.0, 1.0), 5.0);
        assert_eq!(charged_failures(1.0, 5.0, 2.0), 2.0);
        assert_eq!(charged_failures(1.0, 5.0, 4.0), 1.0);
        assert_eq!(charged_failures(5.0, 5.0, 2.0), 10.0);
        assert_eq!(charged_failures(5.0, 5.0, 4.0), 5.0);
    }

    #[test]
    fn random_elapsed_near_half_window() {
        let mut rng = Rng::new(1);
        let m = mean_random_elapsed_s(1.0, 5000, &mut rng);
        assert!((m - 1800.0).abs() < 40.0, "{m}");
    }

    #[test]
    fn table1_central_single_row_matches_paper() {
        let row = window_row(Strategy::Checkpoint(CheckpointStrategy::CentralSingle), &cfg());
        // Paper: 01:37:13 / 01:53:27 / 05:27:15
        assert_eq!(hms(row.total_nofail_s), "01:00:00");
        let p = row.total_one_periodic_s;
        assert!((p - 5833.0).abs() < 30.0, "periodic {} = {}", p, hms(p));
        let r = row.total_one_random_s;
        assert!((r - 6807.0).abs() < 60.0, "random {} = {}", r, hms(r));
        let f = row.total_five_random_s;
        assert!((f - 19635.0).abs() < 300.0, "five {} = {}", f, hms(f));
    }

    #[test]
    fn table1_core_row_matches_paper() {
        let row = window_row(Strategy::Core, &cfg());
        // Paper: reinstate 0.38 s, overhead 4:27, total 1:05:08
        assert!((row.reinstate_periodic_s - 0.38).abs() < 0.01);
        assert!((row.overhead_periodic_s - 267.0).abs() < 10.0);
        assert!((row.total_one_periodic_s - 3913.0).abs() < 15.0,
            "{}", hms(row.total_one_periodic_s));
    }

    #[test]
    fn multi_agent_one_fifth_of_checkpointing() {
        // headline: multi-agent ≈ 10% added vs ≈ 90% added for checkpointing
        let c = cfg();
        let ck = window_row(Strategy::Checkpoint(CheckpointStrategy::CentralSingle), &c);
        let ag = window_row(Strategy::Agent, &c);
        let job = 3600.0;
        let ck_penalty = ck.total_one_random_s - job;
        let ag_penalty = ag.total_one_random_s - job;
        assert!(ck_penalty / job > 0.80, "ck penalty {:.2}", ck_penalty / job);
        assert!(ag_penalty / job < 0.15, "ag penalty {:.2}", ag_penalty / job);
        assert!(ag_penalty < ck_penalty / 4.0);
    }

    #[test]
    fn hybrid_equals_core_in_table1() {
        let c = cfg();
        let hy = window_row(Strategy::Hybrid, &c);
        let co = window_row(Strategy::Core, &c);
        assert!((hy.total_one_periodic_s - co.total_one_periodic_s).abs() < 2.0);
    }

    #[test]
    fn rows_deterministic() {
        let c = cfg();
        let a = window_row(Strategy::Agent, &c);
        let b = window_row(Strategy::Agent, &c);
        assert_eq!(a.total_five_random_s, b.total_five_random_s);
    }

    #[test]
    fn table2_periodicity_reduces_checkpoint_total() {
        let cl = preset(ClusterPreset::Placentia);
        let t1 = window_row(
            Strategy::Checkpoint(CheckpointStrategy::CentralSingle),
            &ExperimentCfg::table2(cl.clone(), 1.0),
        );
        let t4 = window_row(
            Strategy::Checkpoint(CheckpointStrategy::CentralSingle),
            &ExperimentCfg::table2(cl, 4.0),
        );
        assert!(t4.total_five_random_s < t1.total_five_random_s);
    }

    #[test]
    fn cold_restart_dominates_everything() {
        let cl = preset(ClusterPreset::Placentia);
        let c2 = ExperimentCfg::table2(cl, 1.0);
        let cold = window_row(Strategy::ColdRestart, &c2);
        let ck = window_row(Strategy::Checkpoint(CheckpointStrategy::CentralSingle), &c2);
        assert!(cold.total_five_random_s > ck.total_five_random_s);
        // ~16x nominal at five random failures/hour (paper: 80:31 for 5 h)
        let ratio = cold.total_five_random_s / cold.total_nofail_s;
        assert!((10.0..23.0).contains(&ratio), "ratio {ratio}");
    }
}
