//! Config-file driven experiments (`biomaft run --config <file>`).
//!
//! Example (TOML subset, see `configs/`):
//! ```text
//! cluster = "placentia"
//! strategy = "hybrid"     # agent|core|hybrid|ckpt-single|ckpt-multi|ckpt-decentral|cold-restart
//! z = 4
//! data_kb = 524_288
//! proc_kb = 524_288
//! job_h = 1.0
//! period_h = 1.0
//! periodic_offset_min = 15.0
//! trials = 30
//! seed = 2014
//! threads = 0             # optional: worker threads (0 = one per core)
//! ```

use super::ftmanager::Strategy;
use super::run::ExperimentCfg;
use crate::checkpoint::CheckpointStrategy;
use crate::cluster::{preset, ClusterPreset};
use crate::util::conf::{Conf, Value};

/// Parse a strategy name (CLI + config share this).
pub fn parse_strategy(s: &str) -> anyhow::Result<Strategy> {
    Ok(match s {
        "agent" => Strategy::Agent,
        "core" => Strategy::Core,
        "hybrid" => Strategy::Hybrid,
        "ckpt-single" => Strategy::Checkpoint(CheckpointStrategy::CentralSingle),
        "ckpt-multi" => Strategy::Checkpoint(CheckpointStrategy::CentralMulti),
        "ckpt-decentral" => Strategy::Checkpoint(CheckpointStrategy::Decentral),
        "cold-restart" => Strategy::ColdRestart,
        other => anyhow::bail!(
            "unknown strategy `{other}` (agent|core|hybrid|ckpt-single|ckpt-multi|ckpt-decentral|cold-restart)"
        ),
    })
}

/// A full run description from a config document.
#[derive(Debug, Clone)]
pub struct RunConfig {
    pub cfg: ExperimentCfg,
    pub strategy: Strategy,
}

impl RunConfig {
    pub fn from_conf(c: &Conf) -> anyhow::Result<Self> {
        let cluster_name = c.str_or("cluster", "placentia");
        let cluster = ClusterPreset::from_name(&cluster_name)
            .ok_or_else(|| anyhow::anyhow!("unknown cluster `{cluster_name}`"))?;
        let strategy = parse_strategy(&c.str_or("strategy", "hybrid"))?;
        let base = ExperimentCfg::table1(preset(cluster));
        let cfg = ExperimentCfg {
            n_nodes: c.int_or("n_nodes", base.n_nodes as i64) as usize,
            z: c.int_or("z", base.z as i64) as usize,
            data_kb: c.int_or("data_kb", base.data_kb as i64) as u64,
            proc_kb: c.int_or("proc_kb", base.proc_kb as i64) as u64,
            job_h: c.float_or("job_h", base.job_h),
            period_h: c.float_or("period_h", base.period_h),
            periodic_offset_min: c.float_or("periodic_offset_min", base.periodic_offset_min),
            trials: c.int_or("trials", base.trials as i64) as usize,
            seed: c.int_or("seed", base.seed as i64) as u64,
            // `threads = 0` in a config file means one per core; absent
            // defers to the BIOMAFT_THREADS / trial-count policy.
            threads: match c.get("threads").and_then(Value::as_int) {
                Some(t) => {
                    anyhow::ensure!(t >= 0, "threads must be >= 0, got {t}");
                    Some(t as usize)
                }
                None => None,
            },
            cluster: base.cluster,
        };
        anyhow::ensure!(cfg.job_h > 0.0 && cfg.period_h > 0.0, "durations must be positive");
        anyhow::ensure!(cfg.n_nodes >= 1, "need at least one node");
        Ok(Self { cfg, strategy })
    }

    pub fn load(path: &std::path::Path) -> anyhow::Result<Self> {
        Self::from_conf(&Conf::load(path)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_config_parses() {
        let doc = r#"
cluster = "acet"
strategy = "agent"
z = 12
data_kb = 1_048_576
job_h = 5.0
period_h = 2.0
trials = 10
"#;
        let rc = RunConfig::from_conf(&Conf::parse(doc).unwrap()).unwrap();
        assert_eq!(rc.cfg.cluster.name, "acet");
        assert_eq!(rc.strategy, Strategy::Agent);
        assert_eq!(rc.cfg.z, 12);
        assert_eq!(rc.cfg.data_kb, 1 << 20);
        assert_eq!(rc.cfg.period_h, 2.0);
        assert_eq!(rc.cfg.trials, 10);
    }

    #[test]
    fn defaults_fill_in() {
        let rc = RunConfig::from_conf(&Conf::parse("").unwrap()).unwrap();
        assert_eq!(rc.cfg.cluster.name, "placentia");
        assert_eq!(rc.strategy, Strategy::Hybrid);
        assert_eq!(rc.cfg.z, 4);
    }

    #[test]
    fn bad_values_rejected() {
        assert!(RunConfig::from_conf(&Conf::parse("cluster = \"nowhere\"").unwrap()).is_err());
        assert!(RunConfig::from_conf(&Conf::parse("strategy = \"magic\"").unwrap()).is_err());
        assert!(RunConfig::from_conf(&Conf::parse("job_h = -1").unwrap()).is_err());
    }

    #[test]
    fn every_strategy_name_parses() {
        for s in [
            "agent", "core", "hybrid", "ckpt-single", "ckpt-multi", "ckpt-decentral",
            "cold-restart",
        ] {
            assert!(parse_strategy(s).is_ok(), "{s}");
        }
    }
}
