//! The coordinator: strategy dispatch, sub-job placement, experiment
//! execution and execution timelines.
//!
//! This is the leader-side glue that the paper's tables measure: given a
//! cluster, a job decomposition, a failure process and a fault-tolerance
//! strategy, produce reinstate / overhead / total-execution times.

pub mod combined;
pub mod config;
pub mod ftmanager;
pub mod livesim;
pub mod run;
pub mod scheduler;
pub mod timeline;

pub use combined::Combined;
pub use config::RunConfig;
pub use ftmanager::Strategy;
pub use run::{measure_reinstate, window_row, ExperimentCfg, WindowRow};
pub use scheduler::Placement;
pub use timeline::{render_timeline, TimelineEvent};
