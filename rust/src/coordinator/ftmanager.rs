//! Strategy dispatch: the seven fault-tolerance strategies the paper
//! compares, behind one enum.

use crate::checkpoint::CheckpointStrategy;
use crate::cluster::spec::FtCosts;
use crate::hybrid::rules::{decide, Mover, RuleInputs};

/// Every strategy of Tables 1 and 2.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Strategy {
    /// Cold restart with a human administrator (Table 2 only).
    ColdRestart,
    Checkpoint(CheckpointStrategy),
    /// Approach 1 — agent intelligence.
    Agent,
    /// Approach 2 — core intelligence.
    Core,
    /// Approach 3 — hybrid (rules + negotiation).
    Hybrid,
}

impl Strategy {
    pub fn all_table1() -> Vec<Strategy> {
        vec![
            Strategy::Checkpoint(CheckpointStrategy::CentralSingle),
            Strategy::Checkpoint(CheckpointStrategy::CentralMulti),
            Strategy::Checkpoint(CheckpointStrategy::Decentral),
            Strategy::Agent,
            Strategy::Core,
            Strategy::Hybrid,
        ]
    }

    pub fn all_table2() -> Vec<Strategy> {
        let mut v = vec![Strategy::ColdRestart];
        v.extend(Self::all_table1());
        v
    }

    pub fn name(self) -> &'static str {
        match self {
            Strategy::ColdRestart => "cold restart (no fault tolerance)",
            Strategy::Checkpoint(c) => c.name(),
            Strategy::Agent => "agent intelligence",
            Strategy::Core => "core intelligence",
            Strategy::Hybrid => "hybrid intelligence",
        }
    }

    /// Is this one of the proactive multi-agent approaches?
    pub fn is_multi_agent(self) -> bool {
        matches!(self, Strategy::Agent | Strategy::Core | Strategy::Hybrid)
    }

    /// Closed-form reinstate time for one predicted failure (multi-agent
    /// strategies only; checkpoint strategies go through
    /// `CheckpointStrategy::reinstate_s`, cold restart through the survival
    /// model).
    pub fn ma_reinstate_s(self, costs: &FtCosts, z: usize, data_kb: u64, proc_kb: u64) -> f64 {
        match self {
            Strategy::Agent => costs.agent.reinstate_s(z, data_kb, proc_kb),
            Strategy::Core => costs.core.reinstate_s(z, data_kb, proc_kb),
            Strategy::Hybrid => crate::hybrid::negotiate::hybrid_reinstate_s(
                costs,
                RuleInputs { z, data_kb, proc_kb },
            ),
            _ => panic!("ma_reinstate_s on non-multi-agent strategy"),
        }
    }

    /// Per-failure background overhead (multi-agent strategies).
    pub fn ma_overhead_s(self, costs: &FtCosts, z: usize, data_kb: u64) -> f64 {
        match self {
            Strategy::Agent => costs.agent_overhead.overhead_s(z, data_kb),
            Strategy::Core => costs.core_overhead.overhead_s(z, data_kb),
            Strategy::Hybrid => {
                // the winner's machinery carries the background work
                match decide(RuleInputs { z, data_kb, proc_kb: data_kb }).0 {
                    Mover::Agent => costs.agent_overhead.overhead_s(z, data_kb),
                    Mover::Core => costs.core_overhead.overhead_s(z, data_kb),
                }
            }
            _ => panic!("ma_overhead_s on non-multi-agent strategy"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{preset, ClusterPreset};

    #[test]
    fn table_rosters() {
        assert_eq!(Strategy::all_table1().len(), 6);
        assert_eq!(Strategy::all_table2().len(), 7);
        assert_eq!(Strategy::all_table2()[0], Strategy::ColdRestart);
    }

    #[test]
    fn multi_agent_flag() {
        assert!(Strategy::Agent.is_multi_agent());
        assert!(Strategy::Hybrid.is_multi_agent());
        assert!(!Strategy::ColdRestart.is_multi_agent());
        assert!(!Strategy::Checkpoint(CheckpointStrategy::Decentral).is_multi_agent());
    }

    #[test]
    fn hybrid_tracks_core_at_table1_point() {
        let costs = preset(ClusterPreset::Placentia).costs;
        let h = Strategy::Hybrid.ma_reinstate_s(&costs, 4, 1 << 19, 1 << 19);
        let c = Strategy::Core.ma_reinstate_s(&costs, 4, 1 << 19, 1 << 19);
        assert!((h - c).abs() < 1e-3);
        let ho = Strategy::Hybrid.ma_overhead_s(&costs, 4, 1 << 19);
        let co = Strategy::Core.ma_overhead_s(&costs, 4, 1 << 19);
        assert_eq!(ho, co);
    }

    #[test]
    fn overhead_anchors() {
        // Table 1: agent overhead ≈ 5:14 (314 s), core ≈ 4:27 (267 s).
        let costs = preset(ClusterPreset::Placentia).costs;
        let a = Strategy::Agent.ma_overhead_s(&costs, 4, 1 << 19);
        let c = Strategy::Core.ma_overhead_s(&costs, 4, 1 << 19);
        assert!((a - 314.0).abs() < 10.0, "agent overhead {a}");
        assert!((c - 267.0).abs() < 10.0, "core overhead {c}");
    }

    #[test]
    #[should_panic]
    fn checkpoint_reinstate_via_ma_panics() {
        let costs = preset(ClusterPreset::Placentia).costs;
        Strategy::ColdRestart.ma_reinstate_s(&costs, 1, 1, 1);
    }
}
