//! Figure series: (x, per-cluster y) data behind Figs. 8-13, with a CSV
//! emitter and a crude ASCII sparkline for terminal inspection.

/// One plotted figure: an x-axis plus one named series per cluster.
#[derive(Debug, Clone)]
pub struct Series {
    pub title: String,
    pub x_label: String,
    pub y_label: String,
    pub x: Vec<f64>,
    /// (cluster name, y values — same length as x)
    pub series: Vec<(String, Vec<f64>)>,
}

impl Series {
    pub fn new(title: &str, x_label: &str, y_label: &str, x: Vec<f64>) -> Self {
        Self {
            title: title.to_string(),
            x_label: x_label.to_string(),
            y_label: y_label.to_string(),
            x,
            series: Vec::new(),
        }
    }

    pub fn push(&mut self, name: &str, y: Vec<f64>) {
        assert_eq!(y.len(), self.x.len(), "series length mismatch");
        self.series.push((name.to_string(), y));
    }

    pub fn to_csv(&self) -> String {
        let mut out = String::from(&self.x_label);
        for (name, _) in &self.series {
            out.push(',');
            out.push_str(name);
        }
        out.push('\n');
        for (i, x) in self.x.iter().enumerate() {
            out.push_str(&format!("{x}"));
            for (_, y) in &self.series {
                out.push_str(&format!(",{:.6}", y[i]));
            }
            out.push('\n');
        }
        out
    }

    /// Terminal rendering: per-series min/max plus a sparkline.
    pub fn render(&self) -> String {
        const BARS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
        let mut out = format!("## {} — y: {}, x: {}\n", self.title, self.y_label, self.x_label);
        let lo = self
            .series
            .iter()
            .flat_map(|(_, y)| y.iter())
            .cloned()
            .fold(f64::MAX, f64::min);
        let hi = self
            .series
            .iter()
            .flat_map(|(_, y)| y.iter())
            .cloned()
            .fold(f64::MIN, f64::max);
        for (name, y) in &self.series {
            let line: String = y
                .iter()
                .map(|&v| {
                    let t = if hi > lo { (v - lo) / (hi - lo) } else { 0.0 };
                    BARS[((t * 7.0).round() as usize).min(7)]
                })
                .collect();
            out.push_str(&format!(
                "{name:>12} {line}  [{:.3} .. {:.3}]\n",
                y.iter().cloned().fold(f64::MAX, f64::min),
                y.iter().cloned().fold(f64::MIN, f64::max),
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fig() -> Series {
        let mut s = Series::new("Fig X", "Z", "seconds", vec![3.0, 10.0, 63.0]);
        s.push("acet", vec![0.6, 0.7, 0.8]);
        s.push("placentia", vec![0.45, 0.5, 0.55]);
        s
    }

    #[test]
    fn csv_has_header_and_rows() {
        let csv = fig().to_csv();
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines[0], "Z,acet,placentia");
        assert_eq!(lines.len(), 4);
        assert!(lines[1].starts_with("3,0.6"));
    }

    #[test]
    fn render_contains_all_series() {
        let r = fig().render();
        assert!(r.contains("acet"));
        assert!(r.contains("placentia"));
        assert!(r.contains("Fig X"));
    }

    #[test]
    #[should_panic]
    fn mismatched_length_panics() {
        let mut s = Series::new("t", "x", "y", vec![1.0, 2.0]);
        s.push("bad", vec![1.0]);
    }
}
