//! Measurement utilities: summary statistics, ASCII tables and CSV series
//! emitters used by the experiment harness.

pub mod figure;
pub mod stats;
pub mod table;

pub use figure::Series;
pub use stats::Summary;
pub use table::Table;
