//! Measurement utilities: summary statistics, ASCII tables and CSV series
//! emitters used by the experiment harness.

pub mod accumulator;
pub mod figure;
pub mod stats;
pub mod table;

pub use accumulator::{Accumulator, DEFAULT_QUANTILE_CAP};
pub use figure::Series;
pub use stats::Summary;
pub use table::Table;
