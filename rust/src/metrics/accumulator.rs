//! [`Accumulator`]: a mergeable streaming reducer over trial outcomes —
//! the O(1)-memory replacement for materialising a `Vec<f64>` per sweep
//! cell (DESIGN.md §Sweep executor).
//!
//! Three layers, all updated per push:
//!
//! * **Welford moments** — count, running mean and M2 (sum of squared
//!   deviations), merged across accumulators with Chan's parallel update.
//!   Used for `mean`/`std` only once the quantile buffer has degraded.
//! * **min / max** — exact at any scale (NaN values never become the
//!   min/max; they do poison the mean, see [`Summary::of`]).
//! * **quantile buffer** — up to `cap` values are kept *exactly, in push
//!   order*; while the buffer is exact, [`Accumulator::summary`] computes
//!   the whole [`Summary`] by delegating to [`Summary::of`] on the buffer,
//!   so a small cell's summary is **byte-identical** to the historical
//!   `Vec<f64>` path. The first push (or in-order merge) that would exceed
//!   `cap` degrades the buffer to a fixed-width histogram
//!   ([`HIST_BINS`] bins over the min/max seen at that moment; later
//!   values clamp into the edge bins), after which `median`/`p95` are
//!   bin-interpolated approximations and `mean`/`std` come from the
//!   Welford state.
//!
//! A fourth, independent layer is the **time-weighted mode**
//! ([`Accumulator::push_weighted`]): weighted Welford moments (West's
//! update) for duration-weighted observations — the fleet simulator's
//! time-weighted node utilization ([`scenario::fleet`]) integrates
//! `utilization × interval` samples through it. Zero-duration samples
//! (`w ≤ 0`, or a NaN weight) are ignored — they carry no mass — and an
//! accumulator that never saw positive weight reports
//! [`weighted_mean`](Accumulator::weighted_mean)` = NaN` instead of
//! dividing by zero, matching the `total_cmp` NaN-propagation contract of
//! [`Summary::of`]. The weighted state shares nothing with the unweighted
//! push path, whose arithmetic stays byte-identical.
//!
//! [`scenario::fleet`]: crate::scenario::fleet
//!
//! ## Determinism
//!
//! Every operation is a deterministic function of the *sequence* of
//! `push`/`merge` calls. The sweep executor therefore merges per-chunk
//! accumulators **in chunk-index order** — the chunk layout depends only
//! on the cell's trial count, never on the thread count, so a cell's
//! summary is identical on 1 thread and on 64 (property-tested in
//! `tests/sweep_properties.rs`).

use super::stats::Summary;

/// Default exact-quantile buffer capacity. Cells at or below this many
/// trials report summaries byte-identical to `Summary::of` on the full
/// sample; larger cells degrade to the histogram.
pub const DEFAULT_QUANTILE_CAP: usize = 4096;

/// Bins of the degraded fixed-width histogram.
pub const HIST_BINS: usize = 512;

/// Quantile state: exact buffer (push order preserved) until `cap` is
/// exceeded, then a fixed-width histogram.
#[derive(Debug, Clone)]
enum Quantiles {
    Exact { xs: Vec<f64>, cap: usize },
    Hist(Histogram),
}

/// Fixed-width histogram over `[lo, hi]`; out-of-range values clamp into
/// the edge bins (exact min/max are tracked by the accumulator itself).
#[derive(Debug, Clone)]
pub struct Histogram {
    lo: f64,
    hi: f64,
    counts: Vec<u64>,
}

impl Histogram {
    fn new(lo: f64, hi: f64) -> Self {
        Self { lo, hi, counts: vec![0; HIST_BINS] }
    }

    fn bin_of(&self, x: f64) -> usize {
        if self.hi <= self.lo {
            return 0;
        }
        let frac = (x - self.lo) / (self.hi - self.lo);
        // NaN casts to 0; +inf saturates — both land in an edge bin.
        ((frac * HIST_BINS as f64) as usize).min(HIST_BINS - 1)
    }

    fn insert(&mut self, x: f64) {
        self.counts[self.bin_of(x)] += 1;
    }

    /// Approximate percentile: find the bin holding the target rank (the
    /// same `p/100 · (n−1)` rank convention as
    /// [`percentile_sorted`](super::stats::percentile_sorted)) and
    /// interpolate linearly inside it; the result clamps to `[min, max]`.
    fn percentile(&self, p: f64, n: u64, min: f64, max: f64) -> f64 {
        let rank = p / 100.0 * (n - 1) as f64;
        let mut before = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            if c > 0 && rank < (before + c) as f64 {
                let within = (rank - before as f64) / c as f64;
                let width = (self.hi - self.lo) / HIST_BINS as f64;
                let v = self.lo + (i as f64 + within) * width;
                return v.clamp(min, max);
            }
            before += c;
        }
        max
    }
}

/// Mergeable streaming statistics over one cell's trial outcomes.
#[derive(Debug, Clone)]
pub struct Accumulator {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
    quant: Quantiles,
    /// Time-weighted mode (independent of the fields above): total weight,
    /// weighted mean and weighted M2 of `push_weighted` observations.
    wsum: f64,
    wmean: f64,
    wm2: f64,
}

impl Default for Accumulator {
    fn default() -> Self {
        Self::new()
    }
}

impl Accumulator {
    /// An empty accumulator with the default quantile cap.
    pub fn new() -> Self {
        Self::with_cap(DEFAULT_QUANTILE_CAP)
    }

    /// An empty accumulator whose exact-quantile buffer degrades to a
    /// histogram beyond `cap` values (`cap == 0` degrades on first push).
    pub fn with_cap(cap: usize) -> Self {
        Self {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
            quant: Quantiles::Exact { xs: Vec::new(), cap },
            wsum: 0.0,
            wmean: 0.0,
            wm2: 0.0,
        }
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    /// Is the quantile buffer still exact (summary byte-identical to
    /// `Summary::of` on the pushed sequence)?
    pub fn is_exact(&self) -> bool {
        matches!(self.quant, Quantiles::Exact { .. })
    }

    /// Fold one observation in.
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
        if x < self.min {
            self.min = x;
        }
        if x > self.max {
            self.max = x;
        }
        let needs_degrade = match &mut self.quant {
            Quantiles::Exact { xs, cap } => {
                xs.push(x);
                xs.len() > *cap
            }
            Quantiles::Hist(h) => {
                h.insert(x);
                false
            }
        };
        if needs_degrade {
            self.degrade();
        }
    }

    /// Time-weighted mode: fold in observation `x` carrying weight `w`
    /// (e.g. a utilization level held for `w` seconds of virtual time).
    /// Weighted moments use West's incremental update; they share no state
    /// with the unweighted [`push`](Accumulator::push) path.
    ///
    /// Edge contract (unit-tested): a zero-duration sample (`w == 0`), a
    /// negative weight or a NaN weight carries no mass and is ignored — no
    /// division by zero ever happens here. A NaN *value* with positive
    /// weight poisons the weighted mean, exactly like a NaN trial poisons
    /// [`Summary::of`].
    pub fn push_weighted(&mut self, x: f64, w: f64) {
        if !(w > 0.0) {
            return;
        }
        self.wsum += w;
        let delta = x - self.wmean;
        self.wmean += delta * (w / self.wsum);
        self.wm2 += w * delta * (x - self.wmean);
    }

    /// Total weight folded in by [`push_weighted`](Accumulator::push_weighted).
    pub fn weighted_total(&self) -> f64 {
        self.wsum
    }

    /// Weighted mean of the time-weighted mode; NaN when no positive-weight
    /// sample has been pushed (the documented empty-fleet contract — NaN
    /// propagates, nothing divides by zero or panics).
    pub fn weighted_mean(&self) -> f64 {
        if self.wsum > 0.0 {
            self.wmean
        } else {
            f64::NAN
        }
    }

    /// Weighted population standard deviation; NaN when empty.
    pub fn weighted_std(&self) -> f64 {
        if self.wsum > 0.0 {
            (self.wm2 / self.wsum).sqrt()
        } else {
            f64::NAN
        }
    }

    /// Convert the exact buffer into a histogram over the value range seen
    /// so far (the documented degradation rule: bounds freeze here; later
    /// out-of-range values clamp into the edge bins).
    fn degrade(&mut self) {
        if let Quantiles::Exact { xs, .. } = &self.quant {
            let mut h = Histogram::new(self.min, self.max);
            for &x in xs {
                h.insert(x);
            }
            self.quant = Quantiles::Hist(h);
        }
    }

    /// Merge `other` into `self`. The combined state is exactly what a
    /// single accumulator would hold after `self`'s pushes followed by
    /// `other`'s — bit-for-bit while both buffers are exact and the
    /// combined count fits the cap — so merging per-chunk accumulators in
    /// chunk-index order reproduces the serial fold. The time-weighted
    /// state merges the same way (weighted Chan update), independently of
    /// the unweighted fields.
    pub fn merge(&mut self, other: Accumulator) {
        // Weighted state first: it must survive the empty-count adoption
        // below (other.n == 0 does not imply other.wsum == 0).
        if other.wsum > 0.0 {
            if self.wsum > 0.0 {
                let w = self.wsum + other.wsum;
                let delta = other.wmean - self.wmean;
                self.wmean += delta * (other.wsum / w);
                self.wm2 += other.wm2 + delta * delta * (self.wsum * other.wsum / w);
                self.wsum = w;
            } else {
                self.wsum = other.wsum;
                self.wmean = other.wmean;
                self.wm2 = other.wm2;
            }
        }
        let (wsum, wmean, wm2) = (self.wsum, self.wmean, self.wm2);
        self.merge_counts(other);
        self.wsum = wsum;
        self.wmean = wmean;
        self.wm2 = wm2;
    }

    /// The unweighted half of [`merge`](Accumulator::merge) (count-keyed
    /// moments, min/max, quantile state). May overwrite `self` wholesale on
    /// the empty-adoption path; the caller restores the weighted fields.
    fn merge_counts(&mut self, other: Accumulator) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            // adopt other's state, but keep our own cap
            let keep_cap = match &self.quant {
                Quantiles::Exact { cap, .. } => Some(*cap),
                Quantiles::Hist(_) => None,
            };
            *self = other;
            let mut needs_degrade = false;
            if let (Some(cap_a), Quantiles::Exact { cap, xs }) = (keep_cap, &mut self.quant) {
                *cap = cap_a;
                needs_degrade = xs.len() > cap_a;
            }
            if needs_degrade {
                self.degrade();
            }
            return;
        }
        // Chan et al. parallel moment update.
        let (na, nb) = (self.n as f64, other.n as f64);
        let delta = other.mean - self.mean;
        let n = na + nb;
        self.mean += delta * (nb / n);
        self.m2 += other.m2 + delta * delta * (na * nb / n);
        self.n += other.n;
        if other.min < self.min {
            self.min = other.min;
        }
        if other.max > self.max {
            self.max = other.max;
        }
        match other.quant {
            Quantiles::Exact { xs: bxs, .. } => {
                let needs_degrade = match &mut self.quant {
                    Quantiles::Exact { xs, cap } => {
                        xs.extend_from_slice(&bxs);
                        xs.len() > *cap
                    }
                    Quantiles::Hist(h) => {
                        for &x in &bxs {
                            h.insert(x);
                        }
                        false
                    }
                };
                if needs_degrade {
                    self.degrade();
                }
            }
            // With the default cap (≥ the sweep's chunk size) chunk
            // accumulators stay exact and this arm is unreachable from the
            // sweep; a smaller cap degrades chunks individually, landing
            // here — lossier (midpoint re-binning) but still a
            // deterministic function of the merge sequence.
            Quantiles::Hist(bh) => {
                self.degrade();
                let Quantiles::Hist(h) = &mut self.quant else { unreachable!() };
                merge_hist(h, &bh);
            }
        }
    }

    /// The cell's [`Summary`]. Exact mode delegates to [`Summary::of`] on
    /// the buffered sequence (byte-identical to the historical `Vec<f64>`
    /// path); degraded mode reports Welford mean/std and histogram
    /// quantiles. Panics on an empty accumulator, like `Summary::of`.
    pub fn summary(&self) -> Summary {
        assert!(self.n > 0, "empty sample");
        match &self.quant {
            Quantiles::Exact { xs, .. } => Summary::of(xs),
            Quantiles::Hist(h) => Summary {
                n: self.n as usize,
                mean: self.mean,
                std: (self.m2 / self.n as f64).sqrt(),
                min: self.min,
                max: self.max,
                median: h.percentile(50.0, self.n, self.min, self.max),
                p95: h.percentile(95.0, self.n, self.min, self.max),
            },
        }
    }
}

/// Fold histogram `b` into `a`: matching bounds add counts directly;
/// mismatched bounds re-bin `b`'s mass at each source bin's midpoint
/// (documented lossy fallback — not reachable from the sweep executor).
fn merge_hist(a: &mut Histogram, b: &Histogram) {
    if a.lo == b.lo && a.hi == b.hi {
        for (ca, cb) in a.counts.iter_mut().zip(&b.counts) {
            *ca += cb;
        }
        return;
    }
    let width = (b.hi - b.lo) / HIST_BINS as f64;
    for (i, &c) in b.counts.iter().enumerate() {
        if c > 0 {
            let mid = b.lo + (i as f64 + 0.5) * width;
            a.counts[a.bin_of(mid)] += c;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn seq(n: usize) -> Vec<f64> {
        // deterministic non-monotone sample with spread
        (0..n).map(|i| ((i * 2654435761) % 1000) as f64 / 10.0).collect()
    }

    #[test]
    fn exact_mode_matches_summary_of_bytewise() {
        let xs = seq(100);
        let mut acc = Accumulator::new();
        for &x in &xs {
            acc.push(x);
        }
        assert!(acc.is_exact());
        let a = acc.summary();
        let b = Summary::of(&xs);
        assert_eq!(a.mean.to_bits(), b.mean.to_bits());
        assert_eq!(a.std.to_bits(), b.std.to_bits());
        assert_eq!(a.median.to_bits(), b.median.to_bits());
        assert_eq!(a.p95.to_bits(), b.p95.to_bits());
        assert_eq!(a.min.to_bits(), b.min.to_bits());
        assert_eq!(a.max.to_bits(), b.max.to_bits());
    }

    #[test]
    fn chunked_in_order_merge_equals_serial_fold() {
        let xs = seq(500);
        let mut serial = Accumulator::new();
        for &x in &xs {
            serial.push(x);
        }
        for chunk in [7usize, 64, 200] {
            let mut merged = Accumulator::new();
            for c in xs.chunks(chunk) {
                let mut part = Accumulator::new();
                for &x in c {
                    part.push(x);
                }
                merged.merge(part);
            }
            assert!(merged.is_exact());
            assert_eq!(merged.summary(), serial.summary(), "chunk {chunk}");
        }
    }

    #[test]
    fn degrades_past_cap_and_stays_close() {
        let xs = seq(3000);
        let mut acc = Accumulator::with_cap(256);
        for &x in &xs {
            acc.push(x);
        }
        assert!(!acc.is_exact());
        let approx = acc.summary();
        let exact = Summary::of(&xs);
        assert_eq!(approx.n, exact.n);
        assert_eq!(approx.min, exact.min);
        assert_eq!(approx.max, exact.max);
        // Welford mean/std agree with the two-pass formula to fp noise
        assert!((approx.mean - exact.mean).abs() <= 1e-9 * exact.mean.abs().max(1.0));
        assert!((approx.std - exact.std).abs() <= 1e-9 * exact.std.abs().max(1.0));
        // histogram quantiles land within a few bin widths (bounds froze
        // at degradation time, so bins may be slightly offset)
        let bin = (exact.max - exact.min) / HIST_BINS as f64;
        assert!((approx.median - exact.median).abs() <= 4.0 * bin + 1e-9);
        assert!((approx.p95 - exact.p95).abs() <= 4.0 * bin + 1e-9);
    }

    #[test]
    fn degraded_merge_is_deterministic() {
        let xs = seq(2000);
        let run = || {
            let mut cell = Accumulator::with_cap(128);
            for c in xs.chunks(100) {
                let mut part = Accumulator::with_cap(128);
                for &x in c {
                    part.push(x);
                }
                cell.merge(part);
            }
            cell.summary()
        };
        let (a, b) = (run(), run());
        assert_eq!(a.mean.to_bits(), b.mean.to_bits());
        assert_eq!(a.std.to_bits(), b.std.to_bits());
        assert_eq!(a.median.to_bits(), b.median.to_bits());
        assert_eq!(a.p95.to_bits(), b.p95.to_bits());
    }

    #[test]
    fn merge_into_empty_adopts_and_respects_cap() {
        let mut part = Accumulator::with_cap(8);
        for x in [3.0, 1.0, 2.0] {
            part.push(x);
        }
        let mut cell = Accumulator::with_cap(2); // tighter than the chunk's
        cell.merge(part);
        assert!(!cell.is_exact(), "adopted buffer must respect the cell cap");
        assert_eq!(cell.count(), 3);
        assert_eq!(cell.summary().min, 1.0);
        let mut roomy = Accumulator::with_cap(64);
        let mut p2 = Accumulator::with_cap(8);
        p2.push(5.0);
        roomy.merge(p2);
        assert!(roomy.is_exact());
        assert_eq!(roomy.summary().mean, 5.0);
    }

    #[test]
    fn merge_with_empty_is_identity() {
        let mut a = Accumulator::new();
        a.push(4.0);
        let before = a.summary();
        a.merge(Accumulator::new());
        assert_eq!(a.summary(), before);
    }

    #[test]
    fn constant_sample_degraded() {
        let mut acc = Accumulator::with_cap(4);
        for _ in 0..100 {
            acc.push(7.0);
        }
        let s = acc.summary();
        assert_eq!(s.min, 7.0);
        assert_eq!(s.max, 7.0);
        assert_eq!(s.median, 7.0);
        assert!((s.mean - 7.0).abs() < 1e-12);
        assert!(s.std.abs() < 1e-9);
    }

    #[test]
    fn hist_hist_merge_total() {
        // not reachable from the sweep, but merge must stay total
        let mut a = Accumulator::with_cap(4);
        let mut b = Accumulator::with_cap(4);
        for i in 0..50 {
            a.push(i as f64);
            b.push(100.0 + i as f64);
        }
        a.merge(b);
        let s = a.summary();
        assert_eq!(s.n, 100);
        assert_eq!(s.min, 0.0);
        assert_eq!(s.max, 149.0);
    }

    #[test]
    #[should_panic]
    fn empty_summary_panics() {
        Accumulator::new().summary();
    }

    #[test]
    fn weighted_mean_matches_closed_form() {
        // 0.5 held for 10 s, 1.0 for 30 s ⇒ (5 + 30) / 40 = 0.875
        let mut acc = Accumulator::new();
        acc.push_weighted(0.5, 10.0);
        acc.push_weighted(1.0, 30.0);
        assert!((acc.weighted_mean() - 0.875).abs() < 1e-12);
        assert_eq!(acc.weighted_total(), 40.0);
        // population std of the weighted sample: values 0.5/1.0 with
        // weights 10/30 ⇒ var = .25·(.375²·1 + .125²·3)… compute directly
        let mean = 0.875;
        let var = (10.0 * (0.5f64 - mean).powi(2) + 30.0 * (1.0f64 - mean).powi(2)) / 40.0;
        assert!((acc.weighted_std() - var.sqrt()).abs() < 1e-12);
    }

    #[test]
    fn weighted_zero_duration_and_bad_weights_ignored() {
        let mut acc = Accumulator::new();
        acc.push_weighted(123.0, 0.0); // zero-duration interval: no mass
        acc.push_weighted(456.0, -1.0); // negative weight: ignored
        acc.push_weighted(789.0, f64::NAN); // NaN weight: ignored
        assert_eq!(acc.weighted_total(), 0.0);
        assert!(acc.weighted_mean().is_nan(), "empty weighted mode is NaN, never ÷0");
        assert!(acc.weighted_std().is_nan());
        acc.push_weighted(2.0, 5.0);
        assert_eq!(acc.weighted_mean(), 2.0);
        assert_eq!(acc.weighted_std(), 0.0);
    }

    #[test]
    fn weighted_empty_fleet_is_nan_not_panic() {
        // the empty-fleet contract: no samples at all ⇒ NaN out, no panic
        let acc = Accumulator::new();
        assert!(acc.weighted_mean().is_nan());
        assert!(acc.weighted_std().is_nan());
        assert_eq!(acc.weighted_total(), 0.0);
    }

    #[test]
    fn weighted_nan_value_poisons_like_summary() {
        let mut acc = Accumulator::new();
        acc.push_weighted(1.0, 1.0);
        acc.push_weighted(f64::NAN, 1.0);
        assert!(acc.weighted_mean().is_nan());
    }

    #[test]
    fn weighted_merge_equals_serial_fold() {
        let xs: Vec<(f64, f64)> =
            (0..100).map(|i| ((i % 7) as f64, 0.5 + (i % 3) as f64)).collect();
        let mut serial = Accumulator::new();
        for &(x, w) in &xs {
            serial.push_weighted(x, w);
        }
        let mut merged = Accumulator::new();
        for c in xs.chunks(13) {
            let mut part = Accumulator::new();
            for &(x, w) in c {
                part.push_weighted(x, w);
            }
            merged.merge(part);
        }
        assert!((merged.weighted_mean() - serial.weighted_mean()).abs() < 1e-12);
        assert!((merged.weighted_std() - serial.weighted_std()).abs() < 1e-12);
        assert!((merged.weighted_total() - serial.weighted_total()).abs() < 1e-12);
    }

    #[test]
    fn weighted_survives_empty_count_adoption() {
        // self has weighted mass but zero count; other has counts. The
        // adoption path (*self = other) must not clobber the weighted state.
        let mut acc = Accumulator::new();
        acc.push_weighted(3.0, 2.0);
        let mut part = Accumulator::new();
        part.push(10.0);
        part.push_weighted(5.0, 2.0);
        acc.merge(part);
        assert_eq!(acc.count(), 1);
        assert_eq!(acc.summary().mean, 10.0);
        assert!((acc.weighted_mean() - 4.0).abs() < 1e-12);
        assert_eq!(acc.weighted_total(), 4.0);
    }

    #[test]
    fn weighted_and_unweighted_modes_are_independent() {
        let mut acc = Accumulator::new();
        acc.push(100.0);
        acc.push_weighted(0.25, 8.0);
        acc.push(200.0);
        assert_eq!(acc.count(), 2);
        assert_eq!(acc.summary().mean, 150.0);
        assert_eq!(acc.weighted_mean(), 0.25);
    }

    #[test]
    fn nan_poisons_mean_not_minmax() {
        let mut acc = Accumulator::new();
        acc.push(1.0);
        acc.push(f64::NAN);
        acc.push(3.0);
        assert_eq!(acc.min, 1.0);
        assert_eq!(acc.max, 3.0);
        assert!(acc.summary().mean.is_nan());
    }
}
