//! Plain-text table emitter (the paper-table renderer for the harness).

/// A simple column-aligned table.
#[derive(Debug, Clone, Default)]
pub struct Table {
    pub title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row width mismatch");
        self.rows.push(cells.to_vec());
        self
    }

    pub fn row_strs(&mut self, cells: &[&str]) -> &mut Self {
        let owned: Vec<String> = cells.iter().map(|s| s.to_string()).collect();
        self.row(&owned)
    }

    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    /// Render with column alignment.
    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let sep: String = widths
            .iter()
            .map(|w| "-".repeat(w + 2))
            .collect::<Vec<_>>()
            .join("+");
        let fmt_row = |cells: &[String]| -> String {
            (0..ncol)
                .map(|i| format!(" {:<w$} ", cells[i], w = widths[i]))
                .collect::<Vec<_>>()
                .join("|")
        };
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("## {}\n", self.title));
        }
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for r in &self.rows {
            out.push_str(&fmt_row(r));
            out.push('\n');
        }
        out
    }

    /// CSV form (for piping into plotting tools).
    pub fn to_csv(&self) -> String {
        let mut out = self.header.join(",");
        out.push('\n');
        for r in &self.rows {
            out.push_str(&r.join(","));
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn table() -> Table {
        let mut t = Table::new("Demo", &["approach", "time"]);
        t.row_strs(&["agent", "0.47"]);
        t.row_strs(&["core", "0.38"]);
        t
    }

    #[test]
    fn renders_aligned() {
        let r = table().render();
        assert!(r.contains("## Demo"));
        assert!(r.contains("approach"));
        let lines: Vec<&str> = r.lines().collect();
        // header + sep + 2 rows + title
        assert_eq!(lines.len(), 5);
        // all data lines same width
        assert_eq!(lines[1].len(), lines[3].len() + (lines[1].len() - lines[3].len()));
        assert!(lines[3].contains("agent"));
    }

    #[test]
    fn csv_roundtrip_shape() {
        let csv = table().to_csv();
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines.len(), 3);
        assert_eq!(lines[0], "approach,time");
        assert_eq!(lines[1], "agent,0.47");
    }

    #[test]
    #[should_panic]
    fn row_width_mismatch_panics() {
        Table::new("x", &["a", "b"]).row_strs(&["only-one"]);
    }
}
