//! Summary statistics over trial measurements (the paper reports means over
//! 30 trials; we additionally report spread).

/// Summary of a sample.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub median: f64,
    pub p95: f64,
}

impl Summary {
    /// Compute a summary; panics on an empty sample.
    pub fn of(xs: &[f64]) -> Self {
        assert!(!xs.is_empty(), "empty sample");
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        let mut sorted = xs.to_vec();
        // total_cmp: a NaN trial must propagate into the summary (it sorts
        // to an end and poisons mean/std), never panic the whole sweep.
        sorted.sort_by(f64::total_cmp);
        Self {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            median: percentile_sorted(&sorted, 50.0),
            p95: percentile_sorted(&sorted, 95.0),
        }
    }

    /// 95 % confidence half-width of the mean (normal approximation).
    pub fn ci95(&self) -> f64 {
        1.96 * self.std / (self.n as f64).sqrt()
    }
}

/// Percentile by linear interpolation over a pre-sorted sample.
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=100.0).contains(&p));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constant_sample() {
        let s = Summary::of(&[2.0; 10]);
        assert_eq!(s.mean, 2.0);
        assert_eq!(s.std, 0.0);
        assert_eq!(s.median, 2.0);
        assert_eq!(s.ci95(), 0.0);
    }

    #[test]
    fn known_values() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.mean, 3.0);
        assert_eq!(s.median, 3.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.std - (2.0f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let sorted = [0.0, 10.0];
        assert_eq!(percentile_sorted(&sorted, 0.0), 0.0);
        assert_eq!(percentile_sorted(&sorted, 50.0), 5.0);
        assert_eq!(percentile_sorted(&sorted, 100.0), 10.0);
    }

    #[test]
    fn p95_between_median_and_max() {
        let xs: Vec<f64> = (0..100).map(|i| i as f64).collect();
        let s = Summary::of(&xs);
        assert!(s.median < s.p95 && s.p95 <= s.max);
    }

    #[test]
    #[should_panic]
    fn empty_panics() {
        Summary::of(&[]);
    }

    #[test]
    fn nan_propagates_instead_of_panicking() {
        // a single NaN trial used to panic the whole run via
        // partial_cmp().unwrap(); now it flows through the summary
        let s = Summary::of(&[1.0, f64::NAN, 3.0]);
        assert_eq!(s.n, 3);
        assert!(s.mean.is_nan());
        assert!(s.std.is_nan());
        // positive NaN sorts after +inf under total_cmp
        assert_eq!(s.min, 1.0);
        assert!(s.max.is_nan());
    }

    #[test]
    fn unsorted_input_ok() {
        let s = Summary::of(&[5.0, 1.0, 3.0]);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.median, 3.0);
    }
}
