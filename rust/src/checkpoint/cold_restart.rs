//! Cold restart with a human administrator — the paper's "no fault
//! tolerance" baseline (Table 2).
//!
//! On every node failure the only option is to restart the job from the
//! beginning after ~10 minutes of administrator reaction. The paper's
//! totals (≈21 h for a 5 h job under one periodic failure/hour, ≈80 h under
//! five random failures/hour) are only reachable if failures keep striking
//! *during re-execution*; a deterministic one-per-hour process would never
//! let the job finish at all. We therefore model each hourly failure slot as
//! striking with a survival probability, simulate the restart process to
//! completion, and calibrate the strike probabilities to the paper's
//! magnitudes (documented in EXPERIMENTS.md):
//!
//! * 1 periodic/hour → strike prob 0.33 at minute 14 of each running hour;
//! * 1 random/hour  → strike prob 0.33 at a uniform minute;
//! * 5 random/hour  → 5 slots/hour, strike prob 0.15 each.

use crate::sim::Rng;

/// Parameters of a cold-restart simulation.
#[derive(Debug, Clone, Copy)]
pub struct ColdRestartParams {
    /// Nominal failure-free job duration, seconds.
    pub job_s: f64,
    /// Administrator reaction + resubmission time per failure.
    pub admin_s: f64,
    /// Failure slots per running hour.
    pub slots_per_hour: usize,
    /// Probability a slot strikes.
    pub strike_p: f64,
    /// Fixed offset into the hour for periodic mode (`None` = uniform).
    pub periodic_offset_s: Option<f64>,
    /// Safety cap on simulated wall-clock (avoid unbounded runs at p→1).
    pub max_wall_s: f64,
}

impl ColdRestartParams {
    /// Table 2's "one periodic failure per hour" configuration.
    pub fn periodic_1h(job_s: f64) -> Self {
        Self {
            job_s,
            admin_s: 600.0,
            slots_per_hour: 1,
            strike_p: 0.33,
            periodic_offset_s: Some(14.0 * 60.0),
            max_wall_s: 400.0 * 3600.0,
        }
    }

    /// Table 2's "one random failure per hour".
    pub fn random_1h(job_s: f64) -> Self {
        Self { periodic_offset_s: None, ..Self::periodic_1h(job_s) }
    }

    /// Table 2's "five random failures per hour".
    pub fn random_5h(job_s: f64) -> Self {
        Self {
            slots_per_hour: 5,
            strike_p: 0.15,
            periodic_offset_s: None,
            ..Self::periodic_1h(job_s)
        }
    }
}

/// Outcome of one cold-restart trial.
#[derive(Debug, Clone, Copy)]
pub struct ColdRestartOutcome {
    pub total_s: f64,
    pub failures: usize,
}

/// Simulate one trial: run the job; in each running hour the failure slots
/// may strike; a strike restarts the job from zero after `admin_s`.
pub fn simulate_cold_restart(p: &ColdRestartParams, rng: &mut Rng) -> ColdRestartOutcome {
    let mut wall = 0.0;
    let mut failures = 0;
    'attempt: loop {
        // progress through the job hour by hour
        let mut progressed = 0.0;
        while progressed < p.job_s {
            let hour_len = (p.job_s - progressed).min(3600.0);
            // strike times within this running hour
            let mut strikes: Vec<f64> = Vec::new();
            for s in 0..p.slots_per_hour {
                if rng.chance(p.strike_p) {
                    let at = match p.periodic_offset_s {
                        Some(off) => off * (s as f64 + 1.0) / p.slots_per_hour as f64,
                        None => rng.uniform(0.0, 3600.0),
                    };
                    if at < hour_len {
                        strikes.push(at);
                    }
                }
            }
            if let Some(&first) = strikes.iter().min_by(|a, b| a.partial_cmp(b).unwrap()) {
                wall += first + p.admin_s;
                failures += 1;
                if wall > p.max_wall_s {
                    // cap reached — report the cap (documented limitation)
                    return ColdRestartOutcome { total_s: wall, failures };
                }
                continue 'attempt; // restart from zero
            }
            progressed += hour_len;
            wall += hour_len;
        }
        return ColdRestartOutcome { total_s: wall, failures };
    }
}

/// Mean over `trials` independent trials (the paper uses 5000).
pub fn mean_cold_restart(p: &ColdRestartParams, trials: usize, rng: &mut Rng) -> ColdRestartOutcome {
    let mut total = 0.0;
    let mut fails = 0usize;
    for t in 0..trials {
        let mut trial_rng = rng.fork(t as u64);
        let o = simulate_cold_restart(p, &mut trial_rng);
        total += o.total_s;
        fails += o.failures;
    }
    ColdRestartOutcome {
        total_s: total / trials as f64,
        failures: (fails as f64 / trials as f64).round() as usize,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const H5: f64 = 5.0 * 3600.0;

    #[test]
    fn no_failures_means_nominal_time() {
        let mut rng = Rng::new(1);
        let p = ColdRestartParams { strike_p: 0.0, ..ColdRestartParams::periodic_1h(H5) };
        let o = simulate_cold_restart(&p, &mut rng);
        assert_eq!(o.total_s, H5);
        assert_eq!(o.failures, 0);
    }

    #[test]
    fn periodic_band_matches_paper_magnitude() {
        // Paper: > 21 h for the 5 h job with one periodic failure/hour.
        let mut rng = Rng::new(2);
        let o = mean_cold_restart(&ColdRestartParams::periodic_1h(H5), 3000, &mut rng);
        let hours = o.total_s / 3600.0;
        assert!((15.0..30.0).contains(&hours), "mean {hours} h");
    }

    #[test]
    fn random_band_matches_paper_magnitude() {
        // Paper: > 23 h with one random failure/hour.
        let mut rng = Rng::new(3);
        let o = mean_cold_restart(&ColdRestartParams::random_1h(H5), 3000, &mut rng);
        let hours = o.total_s / 3600.0;
        assert!((15.0..32.0).contains(&hours), "mean {hours} h");
    }

    #[test]
    fn five_random_band_matches_paper_magnitude() {
        // Paper: > 80 h (≈16× nominal) with five random failures/hour.
        let mut rng = Rng::new(4);
        let o = mean_cold_restart(&ColdRestartParams::random_5h(H5), 1500, &mut rng);
        let hours = o.total_s / 3600.0;
        assert!((55.0..115.0).contains(&hours), "mean {hours} h");
    }

    #[test]
    fn ordering_periodic_random_five() {
        let mut rng = Rng::new(5);
        let p1 = mean_cold_restart(&ColdRestartParams::periodic_1h(H5), 1500, &mut rng).total_s;
        let r5 = mean_cold_restart(&ColdRestartParams::random_5h(H5), 1500, &mut rng).total_s;
        assert!(r5 > 2.0 * p1, "five-random {r5} vs periodic {p1}");
    }

    #[test]
    fn deterministic_per_seed() {
        let p = ColdRestartParams::random_1h(H5);
        let a = mean_cold_restart(&p, 100, &mut Rng::new(7)).total_s;
        let b = mean_cold_restart(&p, 100, &mut Rng::new(7)).total_s;
        assert_eq!(a, b);
    }

    #[test]
    fn wall_cap_respected() {
        let mut rng = Rng::new(8);
        let p = ColdRestartParams {
            strike_p: 1.0,
            max_wall_s: 3600.0 * 3.0,
            ..ColdRestartParams::periodic_1h(H5)
        };
        let o = simulate_cold_restart(&p, &mut rng);
        assert!(o.total_s <= 3600.0 * 3.0 + 600.0 + 840.0);
    }
}
