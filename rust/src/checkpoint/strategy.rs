//! Checkpoint strategies: reinstate and overhead cost models.
//!
//! Reinstate = detect + restore all nodes' state from the server(s) +
//! resync; overhead = epoch coordination + write all nodes' state. The
//! effective bandwidths are *shared-storage* figures (the paper's point:
//! checkpoint traffic saturates the path to stable storage).
//!
//! Longer checkpoint periodicity accumulates more mutated state per epoch,
//! growing both columns; the growth factors are calibrated to Table 2's
//! anchors (1 h → 2 h → 4 h) and log-interpolated elsewhere.

use crate::cluster::spec::CheckpointCosts;

/// The three checkpointing baselines of the paper.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CheckpointStrategy {
    CentralSingle,
    CentralMulti,
    Decentral,
}

impl CheckpointStrategy {
    pub fn all() -> [CheckpointStrategy; 3] {
        [Self::CentralSingle, Self::CentralMulti, Self::Decentral]
    }

    pub fn name(self) -> &'static str {
        match self {
            Self::CentralSingle => "centralised checkpointing, single server",
            Self::CentralMulti => "centralised checkpointing, multiple servers",
            Self::Decentral => "decentralised checkpointing, multiple servers",
        }
    }
}

/// (overhead factor, reinstate factor) for a checkpoint periodicity in
/// hours. Anchors from Table 2: 1 h → (1.0, 1.0); 2 h → (1.27, 1.108);
/// 4 h → (1.465, 1.164); log2-interpolated/extrapolated elsewhere.
pub fn periodicity_factors(period_h: f64) -> (f64, f64) {
    assert!(period_h > 0.0);
    let anchors = [(0.0_f64, 1.0_f64, 1.0_f64), (1.0, 1.27, 1.108), (2.0, 1.465, 1.164)];
    let x = period_h.log2();
    // clamp below the first anchor
    if x <= anchors[0].0 {
        return (anchors[0].1, anchors[0].2);
    }
    for w in anchors.windows(2) {
        let (x0, o0, r0) = w[0];
        let (x1, o1, r1) = w[1];
        if x <= x1 {
            let t = (x - x0) / (x1 - x0);
            return (o0 + t * (o1 - o0), r0 + t * (r1 - r0));
        }
    }
    // extrapolate past 4 h with the last slope
    let (x0, o0, r0) = anchors[1];
    let (x1, o1, r1) = anchors[2];
    let t = (x - x0) / (x1 - x0);
    (o0 + t * (o1 - o0), r0 + t * (r1 - r0))
}

impl CheckpointStrategy {
    /// Time to reinstate execution after one failure (Table 1 column b/c —
    /// identical for periodic and random failures: the rollback restores the
    /// same checkpoint either way).
    pub fn reinstate_s(
        self,
        c: &CheckpointCosts,
        n_nodes: usize,
        data_kb_per_node: u64,
        period_h: f64,
    ) -> f64 {
        let (_, rf) = periodicity_factors(period_h);
        let total_bytes = n_nodes as f64 * data_kb_per_node as f64 * 1024.0;
        let restore = total_bytes / c.restore_bw_bps;
        let discovery = match self {
            CheckpointStrategy::Decentral => c.discovery_s,
            _ => 0.0,
        };
        (c.detect_s + discovery + restore + c.resync_s) * rf
    }

    /// Per-failure overhead: creating the checkpoint + transferring it to
    /// the server(s) (Table 1 column d/e).
    pub fn overhead_s(
        self,
        c: &CheckpointCosts,
        n_nodes: usize,
        data_kb_per_node: u64,
        period_h: f64,
    ) -> f64 {
        let (of, _) = periodicity_factors(period_h);
        let total_bytes = n_nodes as f64 * data_kb_per_node as f64 * 1024.0;
        let (coord, write) = match self {
            CheckpointStrategy::CentralSingle => {
                (c.coord_single_s, total_bytes / c.ckpt_bw_bps)
            }
            CheckpointStrategy::CentralMulti => {
                (c.coord_multi_s, total_bytes * c.multi_write_factor / c.ckpt_bw_bps)
            }
            CheckpointStrategy::Decentral => {
                (c.coord_decentral_s, total_bytes / (c.ckpt_bw_bps * c.decentral_bw_factor))
            }
        };
        (coord + write) * of
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{preset, ClusterPreset};

    const KB19: u64 = 1 << 19;

    fn costs() -> crate::cluster::spec::CheckpointCosts {
        preset(ClusterPreset::Placentia).costs.ckpt
    }

    #[test]
    fn table1_anchor_central_single() {
        let c = costs();
        let r = CheckpointStrategy::CentralSingle.reinstate_s(&c, 4, KB19, 1.0);
        let o = CheckpointStrategy::CentralSingle.overhead_s(&c, 4, KB19, 1.0);
        assert!((r - 848.0).abs() < 6.0, "reinstate {r}"); // 00:14:08
        assert!((o - 485.0).abs() < 6.0, "overhead {o}"); // 00:08:05
    }

    #[test]
    fn table1_anchor_central_multi() {
        let c = costs();
        let r = CheckpointStrategy::CentralMulti.reinstate_s(&c, 4, KB19, 1.0);
        let o = CheckpointStrategy::CentralMulti.overhead_s(&c, 4, KB19, 1.0);
        assert!((r - 848.0).abs() < 6.0, "reinstate {r}"); // same restore path
        assert!((o - 554.0).abs() < 8.0, "overhead {o}"); // 00:09:14
    }

    #[test]
    fn table1_anchor_decentral() {
        let c = costs();
        let r = CheckpointStrategy::Decentral.reinstate_s(&c, 4, KB19, 1.0);
        let o = CheckpointStrategy::Decentral.overhead_s(&c, 4, KB19, 1.0);
        assert!((r - 927.0).abs() < 8.0, "reinstate {r}"); // 00:15:27
        assert!((o - 404.0).abs() < 8.0, "overhead {o}"); // 00:06:44
    }

    #[test]
    fn periodicity_factor_anchors() {
        let (o1, r1) = periodicity_factors(1.0);
        assert_eq!((o1, r1), (1.0, 1.0));
        let (o2, r2) = periodicity_factors(2.0);
        assert!((o2 - 1.27).abs() < 1e-9 && (r2 - 1.108).abs() < 1e-9);
        let (o4, r4) = periodicity_factors(4.0);
        assert!((o4 - 1.465).abs() < 1e-9 && (r4 - 1.164).abs() < 1e-9);
    }

    #[test]
    fn periodicity_interpolates_monotone() {
        let mut prev = 0.0;
        for p in [0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 6.0] {
            let (o, r) = periodicity_factors(p);
            assert!(o >= prev, "p={p}");
            assert!(r >= 1.0 || p < 1.0);
            prev = o;
        }
    }

    #[test]
    fn table2_anchor_2h_central_single() {
        let c = costs();
        let r = CheckpointStrategy::CentralSingle.reinstate_s(&c, 4, KB19, 2.0);
        let o = CheckpointStrategy::CentralSingle.overhead_s(&c, 4, KB19, 2.0);
        assert!((r - 940.0).abs() < 10.0, "reinstate {r}"); // 00:15:40
        assert!((o - 617.0).abs() < 10.0, "overhead {o}"); // 00:10:17
    }

    #[test]
    fn table2_anchor_4h_central_single() {
        let c = costs();
        let r = CheckpointStrategy::CentralSingle.reinstate_s(&c, 4, KB19, 4.0);
        let o = CheckpointStrategy::CentralSingle.overhead_s(&c, 4, KB19, 4.0);
        assert!((r - 987.0).abs() < 10.0, "reinstate {r}"); // 00:16:27
        assert!((o - 713.0).abs() < 10.0, "overhead {o}"); // 00:11:53
    }

    #[test]
    fn overhead_scales_with_nodes_and_data() {
        let c = costs();
        let s = CheckpointStrategy::CentralSingle;
        assert!(s.overhead_s(&c, 8, KB19, 1.0) > s.overhead_s(&c, 4, KB19, 1.0));
        assert!(s.overhead_s(&c, 4, KB19 * 2, 1.0) > s.overhead_s(&c, 4, KB19, 1.0));
    }

    #[test]
    fn multi_overhead_exceeds_single_decentral_lowest() {
        let c = costs();
        let single = CheckpointStrategy::CentralSingle.overhead_s(&c, 4, KB19, 1.0);
        let multi = CheckpointStrategy::CentralMulti.overhead_s(&c, 4, KB19, 1.0);
        let dec = CheckpointStrategy::Decentral.overhead_s(&c, 4, KB19, 1.0);
        assert!(multi > single && dec < single);
    }
}
