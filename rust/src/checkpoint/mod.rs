//! Checkpointing baselines and the cold-restart (manual) baseline.
//!
//! These are the comparators of Tables 1 and 2: centralised checkpointing
//! on a single server, centralised on multiple servers, decentralised on
//! multiple servers, and cold restart with a human administrator.

pub mod cold_restart;
pub mod strategy;

pub use cold_restart::{simulate_cold_restart, ColdRestartParams};
pub use strategy::{periodicity_factors, CheckpointStrategy};
