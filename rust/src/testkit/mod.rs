//! Property-based testing mini-framework (proptest substitute — the
//! vendored crate set has no proptest).
//!
//! Usage:
//! ```no_run
//! use biomaft::testkit::{forall, Gen};
//! forall(100, 42, |g| {
//!     let z = g.usize(0, 64);
//!     let kb = g.u64(1, 1 << 32);
//!     // property body: panic/assert on violation
//!     assert!(z <= 64 && kb >= 1);
//! });
//! ```
//!
//! On failure the harness re-raises the panic with the failing case number
//! and the seed to reproduce it. Shrinking is by case replay: the failing
//! case's draws are reported through the `Gen` trace.

use crate::sim::Rng;

/// A generator handle for one property case.
pub struct Gen {
    rng: Rng,
    /// Draw trace for failure reports.
    trace: Vec<String>,
}

impl Gen {
    pub fn new(seed: u64) -> Self {
        Self { rng: Rng::new(seed), trace: Vec::new() }
    }

    pub fn usize(&mut self, lo: usize, hi: usize) -> usize {
        let v = if lo >= hi { lo } else { self.rng.range_usize(lo, hi) };
        self.trace.push(format!("usize[{lo},{hi})={v}"));
        v
    }

    pub fn u64(&mut self, lo: u64, hi: u64) -> u64 {
        let v = if lo >= hi { lo } else { self.rng.range_u64(lo, hi) };
        self.trace.push(format!("u64[{lo},{hi})={v}"));
        v
    }

    pub fn f64(&mut self, lo: f64, hi: f64) -> f64 {
        let v = self.rng.uniform(lo, hi);
        self.trace.push(format!("f64[{lo},{hi})={v}"));
        v
    }

    pub fn bool(&mut self) -> bool {
        let v = self.rng.chance(0.5);
        self.trace.push(format!("bool={v}"));
        v
    }

    /// Power-of-two-ish size in KB, log-uniform over [2^lo, 2^hi] — the
    /// paper's size axes are log scale.
    pub fn size_kb(&mut self, lo_exp: f64, hi_exp: f64) -> u64 {
        let n = self.rng.uniform(lo_exp, hi_exp);
        let v = 2f64.powf(n).round() as u64;
        self.trace.push(format!("size_kb(2^{n:.2})={v}"));
        v
    }

    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        let i = if xs.len() <= 1 { 0 } else { self.rng.range_usize(0, xs.len()) };
        self.trace.push(format!("pick#{i}"));
        &xs[i]
    }

    pub fn vec_i8(&mut self, len: usize, lo: i8, hi: i8) -> Vec<i8> {
        let v: Vec<i8> =
            (0..len).map(|_| self.rng.range_u64(lo as u64, hi as u64 + 1) as i8).collect();
        self.trace.push(format!("vec_i8[{len}]"));
        v
    }

    /// Access the underlying RNG for domain-specific draws.
    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }
}

/// Run `prop` for `cases` cases derived from `seed`. Panics with the case
/// seed and draw trace on the first failure.
pub fn forall(cases: usize, seed: u64, prop: impl Fn(&mut Gen) + std::panic::RefUnwindSafe) {
    for case in 0..cases {
        let case_seed = seed
            .wrapping_mul(0x9E37_79B9_7F4A_7C15)
            .wrapping_add(case as u64);
        let result = std::panic::catch_unwind(|| {
            let mut g = Gen::new(case_seed);
            prop(&mut g);
            g.trace
        });
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            // Re-run to collect the trace (deterministic), tolerating the
            // re-panic.
            let trace = std::panic::catch_unwind(|| {
                let mut g = Gen::new(case_seed);
                let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(&mut g)));
                g.trace
            })
            .unwrap_or_default();
            panic!(
                "property failed at case {case}/{cases} (case_seed={case_seed}):\n  {msg}\n  draws: {}",
                trace.join(", ")
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        forall(50, 1, |g| {
            let a = g.usize(0, 10);
            assert!(a < 10);
        });
    }

    #[test]
    fn failing_property_reports_seed_and_trace() {
        let r = std::panic::catch_unwind(|| {
            forall(100, 2, |g| {
                let v = g.usize(0, 100);
                assert!(v < 95, "v too big: {v}");
            });
        });
        let msg = match r {
            Err(p) => p.downcast_ref::<String>().cloned().unwrap_or_default(),
            Ok(()) => panic!("property should have failed"),
        };
        assert!(msg.contains("case_seed="), "{msg}");
        assert!(msg.contains("draws:"), "{msg}");
    }

    #[test]
    fn size_kb_in_range() {
        forall(100, 3, |g| {
            let kb = g.size_kb(19.0, 31.0);
            assert!(kb >= (1 << 19) - 1 && kb <= (1u64 << 31) + (1 << 30));
        });
    }

    #[test]
    fn deterministic_cases() {
        let mut a = Gen::new(7);
        let mut b = Gen::new(7);
        for _ in 0..20 {
            assert_eq!(a.u64(0, 1000), b.u64(0, 1000));
        }
    }
}
