//! `biomaft` — the leader binary.
//!
//! Subcommands:
//! * `list` — show the experiment registry (one entry per paper artifact);
//! * `experiment <id>` — regenerate a table/figure;
//! * `genome-search` — run the real AOT genome search end-to-end;
//! * `reinstate` — one-off reinstate measurement (cluster, approach, Z, sizes);
//! * `fleet` — one continuous multi-job fleet trial (arrivals, churn, contention);
//! * `vopr` — chaos-explore spec/seed space with continuous invariant
//!   checking and automatic shrinking (exits non-zero on violation);
//! * `clusters` — show the cluster presets.

use biomaft::checkpoint::CheckpointStrategy;
use biomaft::cluster::{preset, ClusterPreset};
use biomaft::coordinator::ftmanager::Strategy;
use biomaft::coordinator::run::{measure_reinstate, ExperimentCfg};
use biomaft::experiments;
use biomaft::failure::DetectorModel;
use biomaft::scenario::{explore, run_fleet, run_repro, ChurnSpec, FleetSpec, VoprCfg};
use biomaft::sim::Rng;
use biomaft::util::cli::Command;
use biomaft::util::fmt::{hms_ms, kb_pow2};

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

/// `--threads` feeds the sweep thread policy
/// ([`biomaft::scenario::thread_policy`]) by setting `BIOMAFT_THREADS`:
/// `auto` leaves the trial-count default (serial below 64 trials per
/// point — the fused sweeps parallelise regardless), `N` forces N worker
/// threads everywhere, `0` forces one per core.
fn set_thread_policy(threads: &str) -> anyhow::Result<()> {
    if threads == "auto" {
        return Ok(());
    }
    let n: usize = threads
        .parse()
        .map_err(|_| anyhow::anyhow!("--threads takes `auto` or a number, got `{threads}`"))?;
    std::env::set_var("BIOMAFT_THREADS", n.to_string());
    Ok(())
}

fn usage() -> String {
    let mut s = String::from(
        "biomaft — multi-agent fault tolerance for HPC computational biology jobs\n\n\
         usage: biomaft <subcommand> [options]\n\nsubcommands:\n",
    );
    for c in commands() {
        s.push_str(&format!("\n{}", c.help()));
    }
    s
}

fn commands() -> Vec<Command> {
    vec![
        Command::new("list", "list all experiments (paper tables/figures)"),
        Command::new("experiment", "regenerate a paper table/figure: experiment <id>")
            .opt("trials", "30", "trials per measured point")
            .opt("seed", "2014", "experiment seed")
            .opt("threads", "auto", "worker threads: auto | N | 0 = one per core"),
        Command::new("genome-search", "run the real AOT genome search (PJRT)")
            .opt("bases", "200000", "synthetic genome size in bases")
            .opt("patterns", "128", "dictionary size")
            .opt("seed", "7", "genome/dictionary seed")
            .opt("limit", "20", "hits to print"),
        Command::new("reinstate", "measure reinstate time for one configuration")
            .opt("cluster", "placentia", "acet|brasdor|glooscap|placentia")
            .opt("approach", "core", "agent|core|hybrid")
            .opt("z", "4", "dependencies")
            .opt("data-kb", "524288", "S_d in KB")
            .opt("proc-kb", "524288", "S_p in KB")
            .opt("trials", "30", "trials")
            .opt("seed", "1", "seed")
            .opt("threads", "auto", "worker threads: auto | N | 0 = one per core"),
        Command::new("fleet", "run one continuous multi-job fleet trial")
            .opt("strategy", "hybrid", "agent|core|hybrid|checkpoint")
            .opt("nodes", "128", "cluster size >= 1 (ring-of-2 neighbourhood)")
            .opt("capacity", "2", "concurrent sub-job slots per node (>= 1)")
            .opt("arrival-per-h", "8", "Poisson job arrivals per hour")
            .opt("churn-per-h", "0.5", "expected failures per node per hour")
            .opt("repair-s", "900", "node repair time in seconds")
            .opt("streams", "2", "checkpoint-server parallel recovery streams (>= 1)")
            .opt("horizon-h", "4", "virtual-time horizon in hours (> 0)")
            .opt(
                "arrivals",
                "0",
                "scale sizing: target this many arrivals at ~90% load \
                 (sets arrival rate to 0.9*nodes/2 jobs/h and stretches the \
                 horizon to fit, overriding arrival-per-h and horizon-h; \
                 0 = off)",
            )
            .opt(
                "loss-p",
                "0",
                "per-message loss probability on both link classes \
                 (migration handshakes and checkpoint-server exchanges pay \
                 timeout/retry/backoff and degrade gracefully; 0 = pristine \
                 network, byte-identical to a build without the fault plane)",
            )
            .opt(
                "flap-rate",
                "0",
                "flap bursts per node-hour: nodes fail and rejoin in short \
                 unpredicted bursts; repeat offenders are quarantined with \
                 exponential probation backoff (0 = no flapping, \
                 byte-identical to a build without the gray plane)",
            )
            .opt(
                "detector-precision",
                "1",
                "failure-detector precision in (0, 1]: below 1, each \
                 predicted failure is accompanied by (1-p)/p false alarms \
                 on healthy nodes, each paying a spurious migration sweep \
                 (1 = oracle detector, no false alarms)",
            )
            .opt(
                "cells",
                "1",
                "shard the fleet into N loosely-coupled cells exchanging \
                 cross-cell traffic at epoch boundaries — a pure \
                 performance knob: any N is byte-identical to 1",
            )
            .opt("seed", "2014", "trial seed"),
        Command::new("vopr", "chaos-explore spec/seed space with invariant checking")
            .opt("walks", "1000", "random (spec, seed) walks to explore")
            .opt("seed", "2014", "root seed (or trial seed with --repro)")
            .opt("max-nodes", "64", "largest generated fleet")
            .opt("max-arrivals", "2000", "cap on expected arrivals per fleet lifetime")
            .opt("trace-window", "32", "events kept before a violation")
            .opt("threads", "auto", "worker threads: auto | N | 0 = one per core")
            .opt("repro", "", "replay one encoded spec instead of exploring"),
        Command::new("clusters", "print the cluster presets"),
        Command::new("run", "run a config-file experiment: run --config <file>")
            .opt_req("config", "path to a TOML-subset config (see configs/)"),
    ]
}

fn run() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(sub) = args.first() else {
        println!("{}", usage());
        return Ok(());
    };
    let rest = &args[1..];
    let cmds = commands();
    let find = |name: &str| cmds.iter().find(|c| c.name == name).unwrap();
    match sub.as_str() {
        "list" => {
            println!("{:<12} description", "id");
            println!("{}", "-".repeat(60));
            for e in experiments::list() {
                println!("{:<12} {}", e.id, e.what);
            }
        }
        "experiment" => {
            let p = find("experiment").parse(rest)?;
            let id = p
                .positional
                .first()
                .ok_or_else(|| anyhow::anyhow!("usage: biomaft experiment <id>"))?;
            let trials: usize = p.req("trials")?;
            let seed: u64 = p.req("seed")?;
            set_thread_policy(&p.req::<String>("threads")?)?;
            println!("{}", experiments::run_by_id(id, trials, seed)?);
        }
        "genome-search" => {
            let p = find("genome-search").parse(rest)?;
            let f = experiments::fig14::run(p.req("bases")?, p.req("patterns")?, p.req("seed")?)?;
            println!("{}", experiments::fig14::render(&f, p.req("limit")?));
        }
        "reinstate" => {
            let p = find("reinstate").parse(rest)?;
            let cluster = ClusterPreset::from_name(&p.req::<String>("cluster")?)
                .ok_or_else(|| anyhow::anyhow!("unknown cluster"))?;
            let strategy = match p.req::<String>("approach")?.as_str() {
                "agent" => Strategy::Agent,
                "core" => Strategy::Core,
                "hybrid" => Strategy::Hybrid,
                other => anyhow::bail!("unknown approach `{other}`"),
            };
            set_thread_policy(&p.req::<String>("threads")?)?;
            let cfg = ExperimentCfg {
                z: p.req("z")?,
                data_kb: p.req("data-kb")?,
                proc_kb: p.req("proc-kb")?,
                trials: p.req("trials")?,
                ..ExperimentCfg::table1(preset(cluster))
            };
            let mut rng = Rng::new(p.req("seed")?);
            let s = measure_reinstate(strategy, &cfg, &mut rng);
            println!(
                "{} on {}: Z={} S_d={} S_p={}",
                strategy.name(),
                cluster.name(),
                cfg.z,
                kb_pow2(cfg.data_kb),
                kb_pow2(cfg.proc_kb)
            );
            println!(
                "reinstate: mean {} (±{:.1} ms over {} trials, min {} max {})",
                hms_ms(s.mean),
                s.ci95() * 1e3,
                s.n,
                hms_ms(s.min),
                hms_ms(s.max)
            );
        }
        "fleet" => {
            let p = find("fleet").parse(rest)?;
            let strategy = match p.req::<String>("strategy")?.as_str() {
                "agent" => Strategy::Agent,
                "core" => Strategy::Core,
                "hybrid" => Strategy::Hybrid,
                "checkpoint" => Strategy::Checkpoint(CheckpointStrategy::CentralSingle),
                other => anyhow::bail!("unknown strategy `{other}`"),
            };
            let nodes: usize = p.req("nodes")?;
            let arrivals: usize = p.req("arrivals")?;
            let arrival_per_h: f64 = p.req("arrival-per-h")?;
            let churn_per_h: f64 = p.req("churn-per-h")?;
            let horizon_h: f64 = p.req("horizon-h")?;
            if nodes == 0 {
                // everything else goes through FleetSpec::validate, but
                // the ring topology can't even be built with zero nodes
                anyhow::bail!("--nodes must be at least 1");
            }
            // --arrivals N switches to scale sizing: rate 0.9*nodes/2
            // jobs/h (~90% load on 2-slot nodes) with the horizon
            // stretched until the expected arrival count reaches N.
            let mut spec = if arrivals > 0 {
                FleetSpec::scale_fleet(strategy, nodes, arrivals, churn_per_h)
            } else {
                let mut s = FleetSpec::placentia_fleet(strategy, nodes, arrival_per_h, churn_per_h);
                s.horizon_s = horizon_h * 3600.0;
                s
            };
            spec.capacity = p.req("capacity")?;
            spec.ckpt_streams = p.req("streams")?;
            if let ChurnSpec::PerNode { repair_s, .. } = &mut spec.churn {
                *repair_s = p.req("repair-s")?;
            }
            if !strategy.is_multi_agent() {
                // checkpoint baselines are reactive only
                spec.job.predictable_frac = 0.0;
            }
            let loss_p: f64 = p.req("loss-p")?;
            spec.faults.peer.loss_p = loss_p;
            spec.faults.ckpt.loss_p = loss_p;
            spec.gray.flapping.rate_per_node_h = p.req("flap-rate")?;
            let precision: f64 = p.req("detector-precision")?;
            if precision < 1.0 {
                // an imperfect detector keeps the legacy coverage but cries
                // wolf: (1-p)/p false alarms per predicted failure
                spec.gray.detector = Some(DetectorModel {
                    coverage: spec.job.predictable_frac,
                    precision,
                    lead_jitter_s: 0.0,
                });
            }
            spec.cells = std::num::NonZeroUsize::new(p.req("cells")?)
                .ok_or_else(|| anyhow::anyhow!("--cells must be at least 1"))?;
            spec.validate().map_err(|e| anyhow::anyhow!("invalid fleet spec: {e}"))?;
            let o = run_fleet(&spec, p.req("seed")?);
            let rate_per_h = match &spec.arrivals {
                biomaft::scenario::ArrivalSpec::Poisson { rate_per_h } => *rate_per_h,
                biomaft::scenario::ArrivalSpec::Trace { at_s } => {
                    at_s.len() as f64 / (spec.horizon_s / 3600.0)
                }
            };
            println!(
                "fleet: {} on {} nodes × {} slots, {:.2} jobs/h{}, churn {}/node/h, horizon {:.2} h",
                strategy.name(),
                spec.topo.len(),
                spec.capacity,
                rate_per_h,
                if arrivals > 0 {
                    format!(" (scale-sized for {arrivals} arrivals at ~90% load)")
                } else {
                    String::new()
                },
                churn_per_h,
                spec.horizon_s / 3600.0
            );
            println!(
                "  jobs: {} arrived, {} completed, {} still queued, {} peak live",
                o.jobs_arrived, o.jobs_completed, o.jobs_waiting, o.peak_live_jobs
            );
            println!(
                "  slowdown: mean {:.3}, p95 {:.3}   goodput {:.3}   utilization {:.3}",
                o.mean_slowdown, o.p95_slowdown, o.goodput_ratio, o.utilization
            );
            println!(
                "  migrations {} (peak {} in flight)   rollbacks {} (peak {} concurrent), {} sub-jobs lost",
                o.migrations,
                o.peak_concurrent_migrations,
                o.rollbacks,
                o.peak_concurrent_recoveries,
                o.subs_lost
            );
            println!(
                "  network: {} retries, {} timeouts, {} fallbacks to checkpoint recovery, {} duplicates suppressed",
                o.net_retries, o.net_timeouts, o.fallbacks, o.dup_suppressed
            );
            if !spec.gray.is_off() {
                println!(
                    "  gray: {} spurious migrations, {} quarantines ({} released), {:.0} degraded node-seconds",
                    o.spurious_migrations, o.quarantines, o.quarantine_releases, o.degraded_node_s
                );
            }
            println!("  events {}   last completion {}", o.events, hms_ms(o.last_completion_s));
        }
        "vopr" => {
            let p = find("vopr").parse(rest)?;
            let seed: u64 = p.req("seed")?;
            let trace_window: usize = p.req("trace-window")?;
            let repro: String = p.req("repro")?;
            if !repro.is_empty() {
                let (report, violated) =
                    run_repro(&repro, seed, trace_window).map_err(|e| anyhow::anyhow!(e))?;
                print!("{report}");
                if violated {
                    anyhow::bail!("invariant violation reproduced");
                }
                return Ok(());
            }
            let threads = match p.req::<String>("threads")?.as_str() {
                "auto" => None,
                t => Some(t.parse::<usize>().map_err(|_| {
                    anyhow::anyhow!("--threads takes `auto` or a number, got `{t}`")
                })?),
            };
            let cfg = VoprCfg {
                walks: p.req("walks")?,
                base_seed: seed,
                max_nodes: p.req("max-nodes")?,
                max_arrivals: p.req("max-arrivals")?,
                trace_window,
                threads,
                // cfg(test) is never consistent between lib and bin, so
                // the self-test hook is feature-gated only here
                #[cfg(feature = "vopr-selftest")]
                fault: None,
            };
            let report = explore(&cfg);
            print!("{}", report.render());
            if !report.passed() {
                anyhow::bail!("invariant violation found");
            }
        }
        "clusters" => {
            for p in ClusterPreset::all() {
                let c = preset(p);
                println!(
                    "{:<10} {:>4} nodes {:>5} cores  link: {:.0} µs / {:.0} MB/s",
                    c.name,
                    c.n_nodes,
                    c.total_cores,
                    c.link.latency_s * 1e6,
                    c.link.bandwidth_bps / 1e6
                );
            }
        }
        "run" => {
            let p = find("run").parse(rest)?;
            let path: String = p.req("config")?;
            let rc = biomaft::coordinator::RunConfig::load(std::path::Path::new(&path))?;
            let row = biomaft::coordinator::run::window_row(rc.strategy, &rc.cfg);
            println!(
                "{} on {} (Z={}, S_d={}, period {} h)",
                rc.strategy.name(),
                rc.cfg.cluster.name,
                rc.cfg.z,
                kb_pow2(rc.cfg.data_kb),
                rc.cfg.period_h
            );
            println!("  reinstate:   {}", hms_ms(row.reinstate_periodic_s));
            println!("  overhead:    {}", hms_ms(row.overhead_periodic_s));
            println!("  no failures: {}", biomaft::util::fmt::hms(row.total_nofail_s));
            println!("  1 periodic/h: {}", biomaft::util::fmt::hms(row.total_one_periodic_s));
            println!("  1 random/h:  {}", biomaft::util::fmt::hms(row.total_one_random_s));
            println!("  5 random/h:  {}", biomaft::util::fmt::hms(row.total_five_random_s));
        }
        "--help" | "-h" | "help" => println!("{}", usage()),
        other => anyhow::bail!("unknown subcommand `{other}`\n\n{}", usage()),
    }
    Ok(())
}
