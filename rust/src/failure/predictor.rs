//! Log-based failure prediction.
//!
//! The paper's predictor is "a machine learning approach ... constantly
//! evaluating the state of the system against the log it maintains". We
//! implement it as an online scorer over the core's health log: a weighted
//! blend of recent wear level, wear slope and soft-error density, firing
//! when the score crosses a threshold. Two mechanisms bound its quality to
//! the paper's observed figures:
//!
//! * **coverage ≈ 29 %** — only failures whose drift lead time exceeds the
//!   probing horizon are *predictable* at all; the injector marks the rest
//!   (deadlocks, power loss, instantaneous faults) as undetectable.
//! * **precision ≈ 64 %** — log noise produces false positives; the
//!   threshold is calibrated so ~36 % of firings are spurious
//!   (`experiments::prediction` measures both and asserts the bands).

use crate::cluster::core::{Core, HealthSample};
use crate::sim::SimTime;

/// A positive prediction for a core.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Prediction {
    pub at: SimTime,
    /// Score at firing time (0..1-ish).
    pub score: f64,
}

/// Online health-log scorer.
#[derive(Debug, Clone)]
pub struct Predictor {
    /// Firing threshold on the blended score.
    pub threshold: f64,
    /// Samples considered for the slope estimate.
    pub window: usize,
    /// Time from the first anomalous sample to a positive prediction; the
    /// paper measured ≈38 s for this ramp.
    pub predict_time_s: f64,
}

impl Default for Predictor {
    fn default() -> Self {
        Self { threshold: 0.55, window: 8, predict_time_s: 38.0 }
    }
}

impl Predictor {
    /// Blended anomaly score over the most recent window of the log.
    pub fn score(&self, log: &[HealthSample]) -> f64 {
        if log.is_empty() {
            return 0.0;
        }
        let tail = &log[log.len().saturating_sub(self.window)..];
        let latest = tail.last().unwrap();
        let wear_level = latest.wear;
        // slope of wear across the window (per sample)
        let slope = if tail.len() >= 2 {
            let d = tail.last().unwrap().wear - tail.first().unwrap().wear;
            (d / (tail.len() - 1) as f64).max(0.0)
        } else {
            0.0
        };
        let soft_density =
            tail.iter().filter(|s| s.soft_errors).count() as f64 / tail.len() as f64;
        0.55 * wear_level + 2.5 * slope + 0.25 * soft_density
    }

    /// Evaluate a core's log; returns a prediction if the score crosses the
    /// threshold.
    pub fn evaluate(&self, core: &Core, now: SimTime) -> Option<Prediction> {
        let s = self.score(core.log());
        (s >= self.threshold).then_some(Prediction { at: now, score: s })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::core::{Core, CoreId, CoreState};
    use crate::failure::prober::Prober;
    use crate::sim::Rng;

    fn run_probes(core: &mut Core, t0: f64, t1: f64, step: f64, seed: u64) {
        let p = Prober::default();
        let mut rng = Rng::new(seed);
        let mut t = t0;
        while t < t1 {
            p.probe(core, SimTime::from_secs(t), &mut rng);
            t += step;
        }
    }

    #[test]
    fn empty_log_scores_zero() {
        let p = Predictor::default();
        assert_eq!(p.score(&[]), 0.0);
    }

    #[test]
    fn healthy_core_not_predicted() {
        let mut core = Core::new(CoreId(0), 64);
        run_probes(&mut core, 0.0, 500.0, 5.0, 1);
        let p = Predictor::default();
        assert!(p.evaluate(&core, SimTime::from_secs(500.0)).is_none());
    }

    #[test]
    fn doomed_core_predicted_before_failure() {
        let mut core = Core::new(CoreId(1), 64);
        core.state = CoreState::Doomed { fails_at: SimTime::from_secs(600.0) };
        // probe right through the drift window
        run_probes(&mut core, 0.0, 595.0, 5.0, 2);
        let p = Predictor::default();
        let pred = p.evaluate(&core, SimTime::from_secs(595.0));
        assert!(pred.is_some(), "score={}", p.score(core.log()));
    }

    #[test]
    fn prediction_fires_only_near_failure() {
        let mut core = Core::new(CoreId(2), 64);
        core.state = CoreState::Doomed { fails_at: SimTime::from_secs(10_000.0) };
        run_probes(&mut core, 0.0, 500.0, 5.0, 3);
        let p = Predictor::default();
        assert!(p.evaluate(&core, SimTime::from_secs(500.0)).is_none());
    }

    #[test]
    fn score_monotone_in_wear() {
        let p = Predictor::default();
        let mk = |wear: f64| HealthSample {
            at: SimTime::ZERO,
            load: 0.5,
            wear,
            soft_errors: false,
        };
        let low: Vec<_> = (0..8).map(|_| mk(0.2)).collect();
        let high: Vec<_> = (0..8).map(|_| mk(0.9)).collect();
        assert!(p.score(&high) > p.score(&low));
    }

    #[test]
    fn slope_contributes() {
        let p = Predictor::default();
        let ramp: Vec<_> = (0..8)
            .map(|i| HealthSample {
                at: SimTime::from_secs(i as f64),
                load: 0.5,
                wear: 0.1 + 0.1 * i as f64,
                soft_errors: false,
            })
            .collect();
        let flat: Vec<_> = (0..8)
            .map(|i| HealthSample {
                at: SimTime::from_secs(i as f64),
                load: 0.5,
                wear: ramp.last().unwrap().wear,
                soft_errors: false,
            })
            .collect();
        assert!(p.score(&ramp) > p.score(&flat) - 1e-12);
    }
}
