//! Failure injection, hardware probing and failure prediction.
//!
//! The paper simulates two single-node failure classes (periodic at a fixed
//! offset from a checkpoint, and random uniform within the window — Fig. 16)
//! and predicts failures with a log-based learner achieving 29 % coverage at
//! 64 % precision (Discussion, "Predicting potential failures").

pub mod gray;
pub mod injector;
pub mod predictor;
pub mod prober;
pub mod states;

pub use gray::{DetectorModel, FailSlow, Flapping, GrayPlane, QuarantinePolicy};
pub use injector::{FailureEvent, FailurePlan, FailureProcess};
pub use predictor::{Prediction, Predictor};
pub use prober::Prober;
pub use states::{classify, OutcomeClass};
