//! Failure injection: the two single-node failure processes of Fig. 16 plus
//! a Poisson process and trace replay for extensions.

use crate::net::NodeId;
use crate::sim::{Rng, SimTime};

/// One injected failure.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FailureEvent {
    pub at: SimTime,
    pub node: NodeId,
}

/// The failure process driving an experiment window.
#[derive(Debug, Clone)]
pub enum FailureProcess {
    /// One failure per window at a fixed offset (paper: 14 or 15 minutes
    /// after the checkpoint, depending on the experiment).
    Periodic { offset_s: f64 },
    /// One failure per window, uniform over the window (paper: mean lands at
    /// ~31 m 14 s over 5000 trials of a 1 h window).
    RandomUniform,
    /// `k` failures per window, each uniform over the window.
    RandomUniformK { k: usize },
    /// Poisson arrivals with the given rate (failures per window).
    Poisson { rate_per_window: f64 },
    /// Replay an explicit trace of offsets (seconds into the window).
    Trace { offsets_s: Vec<f64> },
}

/// A concrete plan: which node fails when, for each window of a run.
#[derive(Debug, Clone)]
pub struct FailurePlan {
    pub events: Vec<FailureEvent>,
}

impl FailureProcess {
    /// Sample the failure offsets (seconds) within one window.
    pub fn sample_offsets(&self, window_s: f64, rng: &mut Rng) -> Vec<f64> {
        match self {
            FailureProcess::Periodic { offset_s } => {
                if *offset_s <= window_s {
                    vec![*offset_s]
                } else {
                    vec![]
                }
            }
            FailureProcess::RandomUniform => vec![rng.uniform(0.0, window_s)],
            FailureProcess::RandomUniformK { k } => {
                let mut v: Vec<f64> = (0..*k).map(|_| rng.uniform(0.0, window_s)).collect();
                v.sort_by(|a, b| a.partial_cmp(b).unwrap());
                v
            }
            FailureProcess::Poisson { rate_per_window } => {
                let mut t = 0.0;
                let mean_gap = window_s / rate_per_window.max(1e-12);
                let mut v = Vec::new();
                loop {
                    t += rng.exponential(mean_gap);
                    if t >= window_s {
                        break;
                    }
                    v.push(t);
                }
                v
            }
            FailureProcess::Trace { offsets_s } => {
                offsets_s.iter().copied().filter(|&o| o <= window_s).collect()
            }
        }
    }

    /// Sample one window's failure events, appending them to `out` in draw
    /// order: all offsets for the window first, then one victim draw per
    /// event. This is exactly the per-window draw sequence of [`plan`], so a
    /// lazy caller that materializes windows one at a time (in order, from
    /// the same rng) consumes a stream identical to an eager `plan` call.
    ///
    /// [`plan`]: FailureProcess::plan
    pub fn window_events(
        &self,
        window: usize,
        window_s: f64,
        n_nodes: usize,
        rng: &mut Rng,
        out: &mut Vec<FailureEvent>,
    ) {
        assert!(n_nodes > 0);
        let base = window as f64 * window_s;
        for off in self.sample_offsets(window_s, rng) {
            out.push(FailureEvent {
                at: SimTime::from_secs(base + off),
                node: NodeId(rng.range_usize(0, n_nodes)),
            });
        }
    }

    /// Build a plan over `windows` consecutive windows, picking a victim
    /// node uniformly among `n_nodes` for each failure.
    pub fn plan(&self, windows: usize, window_s: f64, n_nodes: usize, rng: &mut Rng) -> FailurePlan {
        assert!(n_nodes > 0);
        let mut events = Vec::new();
        for w in 0..windows {
            self.window_events(w, window_s, n_nodes, rng, &mut events);
        }
        events.sort_by_key(|e| e.at);
        FailurePlan { events }
    }
}

impl FailurePlan {
    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn periodic_single_offset() {
        let mut rng = Rng::new(1);
        let p = FailureProcess::Periodic { offset_s: 14.0 * 60.0 };
        let offs = p.sample_offsets(3600.0, &mut rng);
        assert_eq!(offs, vec![840.0]);
    }

    #[test]
    fn periodic_beyond_window_dropped() {
        let mut rng = Rng::new(1);
        let p = FailureProcess::Periodic { offset_s: 4000.0 };
        assert!(p.sample_offsets(3600.0, &mut rng).is_empty());
    }

    #[test]
    fn random_uniform_mean_matches_paper() {
        // Paper: over 5000 trials of a 1 h window the mean failure time is
        // ~31 m 14 s (i.e. ~the window midpoint).
        let mut rng = Rng::new(42);
        let p = FailureProcess::RandomUniform;
        let n = 5000;
        let mean: f64 =
            (0..n).map(|_| p.sample_offsets(3600.0, &mut rng)[0]).sum::<f64>() / n as f64;
        assert!((mean - 1800.0).abs() < 40.0, "mean={mean}");
    }

    #[test]
    fn random_k_sorted_and_counted() {
        let mut rng = Rng::new(3);
        let p = FailureProcess::RandomUniformK { k: 5 };
        let offs = p.sample_offsets(3600.0, &mut rng);
        assert_eq!(offs.len(), 5);
        assert!(offs.windows(2).all(|w| w[0] <= w[1]));
        assert!(offs.iter().all(|&o| (0.0..3600.0).contains(&o)));
    }

    #[test]
    fn poisson_rate_approximate() {
        let mut rng = Rng::new(4);
        let p = FailureProcess::Poisson { rate_per_window: 3.0 };
        let total: usize = (0..2000).map(|_| p.sample_offsets(3600.0, &mut rng).len()).sum();
        let rate = total as f64 / 2000.0;
        assert!((rate - 3.0).abs() < 0.15, "rate={rate}");
    }

    #[test]
    fn trace_replay_filters() {
        let mut rng = Rng::new(5);
        let p = FailureProcess::Trace { offsets_s: vec![10.0, 20.0, 9999.0] };
        assert_eq!(p.sample_offsets(100.0, &mut rng), vec![10.0, 20.0]);
    }

    #[test]
    fn plan_spans_windows_sorted() {
        let mut rng = Rng::new(6);
        let p = FailureProcess::Periodic { offset_s: 840.0 };
        let plan = p.plan(5, 3600.0, 4, &mut rng);
        assert_eq!(plan.len(), 5);
        for (w, e) in plan.events.iter().enumerate() {
            assert_eq!(e.at, SimTime::from_secs(w as f64 * 3600.0 + 840.0));
            assert!(e.node.0 < 4);
        }
    }

    #[test]
    fn window_events_lockstep_with_plan() {
        // Walking windows one at a time through `window_events` consumes the
        // exact draw sequence of an eager `plan` call: same events (before
        // the final sort, in identical push order) and an identically
        // positioned rng afterwards.
        let procs = [
            FailureProcess::Periodic { offset_s: 840.0 },
            FailureProcess::RandomUniform,
            FailureProcess::RandomUniformK { k: 3 },
            FailureProcess::Poisson { rate_per_window: 2.5 },
            FailureProcess::Trace { offsets_s: vec![5.0, 1.0, 3600.0, 9999.0] },
        ];
        for (i, p) in procs.iter().enumerate() {
            let mut eager_rng = Rng::new(100 + i as u64);
            let mut lazy_rng = Rng::new(100 + i as u64);
            let eager = p.plan(6, 3600.0, 4, &mut eager_rng);
            let mut lazy = Vec::new();
            for w in 0..6 {
                p.window_events(w, 3600.0, 4, &mut lazy_rng, &mut lazy);
            }
            lazy.sort_by_key(|e| e.at);
            assert_eq!(eager.events, lazy, "process {i}");
            assert_eq!(eager_rng.next_u64(), lazy_rng.next_u64(), "process {i}");
        }
    }

    #[test]
    fn plan_deterministic_per_seed() {
        let p = FailureProcess::RandomUniformK { k: 3 };
        let a = p.plan(4, 3600.0, 8, &mut Rng::new(9));
        let b = p.plan(4, 3600.0, 8, &mut Rng::new(9));
        assert_eq!(a.events, b.events);
    }
}
