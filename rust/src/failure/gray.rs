//! Deterministic gray-failure plane: imperfect detectors, fail-slow
//! episodes, flapping nodes and the suspicion/quarantine placement policy
//! (DESIGN.md §Gray-failure plane).
//!
//! The fleet simulator's stock failure model is fail-stop with a perfect
//! oracle: a node is either up or doomed, and a doomed node is predicted
//! with probability `predictable_frac` — every prediction is correct and
//! every lead time is exact. Real detectors are nothing like that
//! (coverage ≈29 %, precision ≈64 % in the paper's log-based learner, and
//! the fault-tolerance literature's "gray failures" — degraded-but-alive
//! nodes, flapping links — sit entirely outside the fail-stop model). The
//! [`GrayPlane`] closes that gap along four axes:
//!
//! * [`DetectorModel`] — replaces the raw coin with `(coverage, precision,
//!   lead_jitter)`: coverage is the fraction of real failures predicted,
//!   sub-unit precision emits *false-positive* predictions on healthy
//!   nodes (paying full spurious-migration cost), and lead jitter smears
//!   the warning time. `detector: None` reproduces the legacy coin
//!   byte-for-byte.
//! * [`FailSlow`] — degraded-but-alive episodes: resident sub-jobs execute
//!   at `speed_factor` instead of fail-stopping.
//! * [`Flapping`] — fail/recover bursts: short unpredicted downs with fast
//!   repairs, the classic migration-storm trigger.
//! * [`QuarantinePolicy`] — the defence: nodes that flap or attract false
//!   alarms accrue suspicion and are excluded from placement with
//!   exponential probation backoff, bounding the storm.
//!
//! The determinism discipline is the same salted side-stream contract as
//! the network [`FaultPlane`](crate::net::FaultPlane): every gray draw
//! comes from a throwaway RNG keyed by `(trial seed, tag, node-or-event)`
//! — never from the simulation's main stream — so trials stay pure
//! functions of `(spec, seed)` at any thread count, and with the plane off
//! ([`GrayPlane::is_off`]) no draw is taken at all.

use crate::scenario::fleet::SpecError;
use crate::sim::Rng;

/// Salt for the gray side-streams. Draw keys are
/// `seed ^ GRAY_SALT ^ mix(tag + mix(key))`, disjoint by construction from
/// the arrival (`ARRIVAL_SALT`), churn (`CHURN_SALT`) and network fault
/// (`FAULT_SALT`) streams.
pub const GRAY_SALT: u64 = 0x6A4F_A170_DE7E_C7ED;

const TAG_JITTER: u64 = 1;
const TAG_FALSE_POS: u64 = 2;
const TAG_FLAP: u64 = 3;
const TAG_SLOW: u64 = 4;

/// splitmix64 finalizer: decorrelates adjacent `(tag, key)` pairs.
fn mix(mut x: u64) -> u64 {
    x ^= x >> 30;
    x = x.wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x ^= x >> 27;
    x = x.wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^= x >> 31;
    x
}

fn side_stream(seed: u64, tag: u64, key: u64) -> Rng {
    Rng::new(seed ^ GRAY_SALT ^ mix(tag.wrapping_add(mix(key))))
}

/// Expected-count rounding shared by every gray schedule: `floor(expect)`
/// events plus one more with probability `fract(expect)`, so the mean
/// count equals the configured rate exactly while each node's count stays
/// a pure function of its side-stream.
fn round_count(rng: &mut Rng, expect: f64) -> usize {
    let mut n = expect.floor() as usize;
    if rng.chance(expect.fract()) {
        n += 1;
    }
    n
}

/// An imperfect failure detector. `coverage` is the probability a real
/// (plan-churn) failure is predicted at all; `precision` is the fraction
/// of emitted predictions that point at a real failure — each covered
/// failure drags `(1 - precision) / precision` expected false alarms on
/// *healthy* nodes along with it, so the prediction census matches the
/// configured precision in expectation; `lead_jitter_s` smears the warning
/// lead uniformly by `±lead_jitter_s` (clamped at zero lead).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DetectorModel {
    pub coverage: f64,
    pub precision: f64,
    pub lead_jitter_s: f64,
}

impl DetectorModel {
    /// A perfect detector with the given coverage: reproduces the legacy
    /// `predictable_frac` coin byte-for-byte (property-tested).
    pub const fn perfect(coverage: f64) -> Self {
        Self { coverage, precision: 1.0, lead_jitter_s: 0.0 }
    }

    /// The paper-calibrated operating point: 29 % coverage at 64 %
    /// precision (Discussion, "Predicting potential failures"), with a
    /// ±10 s lead smear. This is what the `grayfail` experiment runs —
    /// the fleet default `predictable_frac = 0.9` is a deliberately
    /// optimistic oracle (DESIGN.md §Gray-failure plane).
    pub const fn paper_calibrated() -> Self {
        Self { coverage: 0.29, precision: 0.64, lead_jitter_s: 10.0 }
    }

    fn validate(&self) -> Result<(), SpecError> {
        let ok = self.coverage.is_finite()
            && (0.0..=1.0).contains(&self.coverage)
            && self.precision.is_finite()
            && self.precision > 0.0
            && self.precision <= 1.0
            && self.lead_jitter_s.is_finite()
            && self.lead_jitter_s >= 0.0;
        if ok {
            Ok(())
        } else {
            Err(SpecError::BadDetector)
        }
    }
}

/// Fail-slow episodes: the node stays up but resident sub-jobs execute at
/// `speed_factor` (< 1) for the episode's duration. Episodes never lose
/// work — they stretch it — which is exactly what makes them invisible to
/// a fail-stop detector.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FailSlow {
    /// Expected episodes per node per hour (0 = none).
    pub rate_per_node_h: f64,
    /// Mean episode length, seconds (exponential).
    pub mean_duration_s: f64,
    /// Execution speed inside an episode, in (0, 1]. Overlapping episodes
    /// merge — degradation clamps at this factor, it never stacks.
    pub speed_factor: f64,
}

impl Default for FailSlow {
    fn default() -> Self {
        Self { rate_per_node_h: 0.0, mean_duration_s: 600.0, speed_factor: 0.25 }
    }
}

impl FailSlow {
    fn validate(&self) -> Result<(), SpecError> {
        let ok = self.rate_per_node_h.is_finite()
            && self.rate_per_node_h >= 0.0
            && self.mean_duration_s.is_finite()
            && self.mean_duration_s >= 0.0
            && self.speed_factor.is_finite()
            && self.speed_factor > 0.0
            && self.speed_factor <= 1.0;
        if ok {
            Ok(())
        } else {
            Err(SpecError::BadFailSlow)
        }
    }
}

/// Flapping churn: bursts of short, *unpredicted* fail/recover cycles.
/// Each burst is `burst_len` downs of `down_s` seconds separated by
/// `gap_s` seconds of uptime — the node keeps coming back just long
/// enough to attract placements, the classic migration-storm shape the
/// quarantine policy exists to bound.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Flapping {
    /// Expected bursts per node per hour (0 = none).
    pub rate_per_node_h: f64,
    /// Downs per burst.
    pub burst_len: u32,
    /// Seconds each flap-down lasts (fast repair, distinct from the plan
    /// churn's `repair_s`).
    pub down_s: f64,
    /// Uptime seconds between consecutive downs in a burst.
    pub gap_s: f64,
}

impl Default for Flapping {
    fn default() -> Self {
        Self { rate_per_node_h: 0.0, burst_len: 3, down_s: 60.0, gap_s: 120.0 }
    }
}

impl Flapping {
    fn validate(&self) -> Result<(), SpecError> {
        let ok = self.rate_per_node_h.is_finite()
            && self.rate_per_node_h >= 0.0
            && (1..=64).contains(&self.burst_len)
            && self.down_s.is_finite()
            && self.down_s > 0.0
            && self.gap_s.is_finite()
            && self.gap_s >= 0.0;
        if ok {
            Ok(())
        } else {
            Err(SpecError::BadFlapping)
        }
    }
}

/// The suspicion/quarantine placement policy. Gray events (false alarms,
/// flap-downs) accrue suspicion; at `threshold` the node is quarantined —
/// excluded from [`PlacementIndex`](crate::scenario::fleet) — for a
/// probation that backs off exponentially per repeat offence, then
/// released. Quarantine never evicts resident sub-jobs; it only stops new
/// placements, bounding misprediction/flap-induced migration storms.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct QuarantinePolicy {
    /// Suspicion events before quarantine (0 disables the policy).
    pub threshold: u32,
    /// First probation length, seconds.
    pub probation_s: f64,
    /// Geometric probation multiplier per repeat offence (≥ 1).
    pub backoff_mult: f64,
    /// Probation ceiling, seconds.
    pub max_probation_s: f64,
}

impl Default for QuarantinePolicy {
    fn default() -> Self {
        Self { threshold: 3, probation_s: 600.0, backoff_mult: 2.0, max_probation_s: 7200.0 }
    }
}

impl QuarantinePolicy {
    /// Probation for offence number `offense` (0-based), seconds.
    pub fn probation(&self, offense: u32) -> f64 {
        (self.probation_s * self.backoff_mult.powi(offense.min(64) as i32))
            .min(self.max_probation_s)
    }

    fn validate(&self) -> Result<(), SpecError> {
        let ok = self.probation_s.is_finite()
            && self.probation_s > 0.0
            && self.backoff_mult.is_finite()
            && self.backoff_mult >= 1.0
            && self.max_probation_s.is_finite()
            && self.max_probation_s >= self.probation_s;
        if ok {
            Ok(())
        } else {
            Err(SpecError::BadQuarantine)
        }
    }
}

/// The whole gray-failure plane. `GrayPlane::default()` is **off**: no
/// detector override, no fail-slow, no flapping — no gray draw is ever
/// taken and the simulation is byte-identical to a build without the
/// plane. The quarantine policy defaults *on* (threshold 3) but suspicion
/// only ever accrues from gray events, so it is inert when the plane is
/// off.
#[derive(Debug, Clone, PartialEq)]
pub struct GrayPlane {
    /// `None` = the legacy `predictable_frac` coin, byte-for-byte.
    pub detector: Option<DetectorModel>,
    pub fail_slow: FailSlow,
    pub flapping: Flapping,
    pub quarantine: QuarantinePolicy,
}

impl Default for GrayPlane {
    fn default() -> Self {
        Self {
            detector: None,
            fail_slow: FailSlow::default(),
            flapping: Flapping::default(),
            quarantine: QuarantinePolicy::default(),
        }
    }
}

impl GrayPlane {
    /// True when the plane cannot perturb anything: no detector override
    /// and both episode rates zero. Suspicion sources vanish with the
    /// gray events, so the quarantine policy is irrelevant then.
    pub fn is_off(&self) -> bool {
        self.detector.is_none()
            && self.fail_slow.rate_per_node_h == 0.0
            && self.flapping.rate_per_node_h == 0.0
    }

    /// Structured validation, surfaced through `FleetSpec::validate` and
    /// the vopr generator.
    pub fn validate(&self) -> Result<(), SpecError> {
        if let Some(d) = &self.detector {
            d.validate()?;
        }
        self.fail_slow.validate()?;
        self.flapping.validate()?;
        self.quarantine.validate()?;
        Ok(())
    }

    /// Detection probability for one plan-churn failure: the detector's
    /// coverage, or the legacy coin when no detector is configured.
    pub fn coverage(&self, legacy_frac: f64) -> f64 {
        self.detector.as_ref().map_or(legacy_frac, |d| d.coverage)
    }

    /// The (possibly jittered) warning lead for plan-churn event `k`.
    /// Without a detector — or with `lead_jitter_s = 0` — this returns
    /// `base_lead_s` untouched and takes **no draw**, preserving the
    /// legacy path bit-for-bit.
    pub fn lead_s(&self, seed: u64, k: u64, base_lead_s: f64) -> f64 {
        match &self.detector {
            Some(d) if d.lead_jitter_s > 0.0 => {
                let mut rng = side_stream(seed, TAG_JITTER, k);
                (base_lead_s + rng.uniform(-d.lead_jitter_s, d.lead_jitter_s)).max(0.0)
            }
            _ => base_lead_s,
        }
    }

    /// Upper bound on [`lead_s`](GrayPlane::lead_s) over every event `k`:
    /// the base lead plus the detector's jitter half-width. The sharded
    /// fleet's lazy churn pull uses this as its look-ahead margin — a churn
    /// event failing at wall time `t` can schedule its doom no earlier than
    /// `t - max_lead_s(base)`, so events whose failure time lies beyond
    /// `now + max_lead_s(base)` can safely stay unmaterialized.
    pub fn max_lead_s(&self, base_lead_s: f64) -> f64 {
        base_lead_s + self.detector.as_ref().map_or(0.0, |d| d.lead_jitter_s)
    }

    /// True when [`false_alarms`](GrayPlane::false_alarms) can ever be
    /// non-empty: a configured detector with sub-unit precision. False
    /// alarms fire uniformly over the *whole* horizon, so when this holds
    /// the fleet must drain its churn stream eagerly at setup (scheduling
    /// each covered event's alarms alongside its doom) instead of lazily
    /// ahead of the clock.
    pub fn emits_false_alarms(&self) -> bool {
        self.detector.as_ref().is_some_and(|d| d.precision < 1.0)
    }

    /// False alarms dragged along by one *covered* plan-churn event `k`:
    /// `(node, fire time)` pairs on the side-stream, expected count
    /// `(1 - precision) / precision` so the overall prediction census
    /// matches the configured precision. Empty without a detector or at
    /// precision 1.
    pub fn false_alarms(
        &self,
        seed: u64,
        k: u64,
        n_nodes: usize,
        horizon_s: f64,
    ) -> Vec<(usize, f64)> {
        let Some(d) = &self.detector else { return Vec::new() };
        if d.precision >= 1.0 {
            return Vec::new();
        }
        let mut rng = side_stream(seed, TAG_FALSE_POS, k);
        let n = round_count(&mut rng, (1.0 - d.precision) / d.precision);
        (0..n).map(|_| (rng.range_usize(0, n_nodes), rng.uniform(0.0, horizon_s))).collect()
    }

    /// Flap-down times for `node`, sorted. Each burst start is uniform on
    /// the horizon; downs inside a burst are `down_s + gap_s` apart and
    /// clipped to the horizon.
    pub fn flap_downs(&self, seed: u64, node: usize, horizon_s: f64) -> Vec<f64> {
        let f = &self.flapping;
        if f.rate_per_node_h == 0.0 {
            return Vec::new();
        }
        let mut rng = side_stream(seed, TAG_FLAP, node as u64);
        let bursts = round_count(&mut rng, f.rate_per_node_h * horizon_s / 3600.0);
        let mut out = Vec::new();
        for _ in 0..bursts {
            let start = rng.uniform(0.0, horizon_s);
            for j in 0..f.burst_len {
                let t = start + j as f64 * (f.down_s + f.gap_s);
                if t < horizon_s {
                    out.push(t);
                }
            }
        }
        out.sort_by(|a, b| a.partial_cmp(b).unwrap());
        out
    }

    /// Fail-slow windows for `node`: sorted, merged (degradation clamps
    /// at `speed_factor`, it never stacks) and clipped to the horizon.
    pub fn slow_windows(&self, seed: u64, node: usize, horizon_s: f64) -> Vec<(f64, f64)> {
        let fs = &self.fail_slow;
        if fs.rate_per_node_h == 0.0 {
            return Vec::new();
        }
        let mut rng = side_stream(seed, TAG_SLOW, node as u64);
        let n = round_count(&mut rng, fs.rate_per_node_h * horizon_s / 3600.0);
        let mut raw: Vec<(f64, f64)> = (0..n)
            .map(|_| {
                let a = rng.uniform(0.0, horizon_s);
                let len = rng.exponential(fs.mean_duration_s);
                (a, (a + len).min(horizon_s))
            })
            .collect();
        raw.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mut merged: Vec<(f64, f64)> = Vec::new();
        for (a, b) in raw {
            match merged.last_mut() {
                Some(m) if a <= m.1 => m.1 = m.1.max(b),
                _ => merged.push((a, b)),
            }
        }
        merged
    }
}

/// Work seconds accrued on the wall-clock interval `[from, to]` given a
/// node's merged fail-slow `windows`: full speed outside a window,
/// `speed` inside. With no windows this is exactly `to - from`.
pub fn wall_to_work(windows: &[(f64, f64)], speed: f64, from: f64, to: f64) -> f64 {
    let mut work = to - from;
    for &(a, b) in windows {
        let lo = a.max(from);
        let hi = b.min(to);
        if hi > lo {
            work -= (1.0 - speed) * (hi - lo);
        }
    }
    work.max(0.0)
}

/// Wall seconds needed to accrue `work_s` work seconds starting at wall
/// time `start`, the inverse of [`wall_to_work`]. Past the last window the
/// node runs at full speed. With no windows this is `work_s` (callers on
/// the byte-identity path early-out before calling, so the off path never
/// even pays the float round-trip).
pub fn work_to_wall(windows: &[(f64, f64)], speed: f64, start: f64, work_s: f64) -> f64 {
    let mut t = start;
    let mut left = work_s;
    for &(a, b) in windows {
        if b <= t {
            continue;
        }
        if a > t {
            let span = a - t;
            if left <= span {
                return t + left - start;
            }
            left -= span;
            t = a;
        }
        let avail = (b - t) * speed;
        if left <= avail {
            return t + left / speed - start;
        }
        left -= avail;
        t = b;
    }
    t + left - start
}

#[cfg(test)]
mod tests {
    use super::*;

    fn active() -> GrayPlane {
        GrayPlane {
            detector: Some(DetectorModel::paper_calibrated()),
            fail_slow: FailSlow { rate_per_node_h: 0.5, ..FailSlow::default() },
            flapping: Flapping { rate_per_node_h: 0.5, ..Flapping::default() },
            quarantine: QuarantinePolicy::default(),
        }
    }

    #[test]
    fn default_plane_is_off_and_validates() {
        let p = GrayPlane::default();
        assert!(p.is_off());
        p.validate().unwrap();
    }

    #[test]
    fn paper_calibrated_preset_validates_and_is_on() {
        let p = GrayPlane { detector: Some(DetectorModel::paper_calibrated()), ..Default::default() };
        assert!(!p.is_off());
        p.validate().unwrap();
        let d = DetectorModel::paper_calibrated();
        assert!((d.coverage - 0.29).abs() < 1e-12);
        assert!((d.precision - 0.64).abs() < 1e-12);
    }

    #[test]
    fn perfect_detector_takes_no_jitter_draw_and_emits_no_false_alarms() {
        let p = GrayPlane { detector: Some(DetectorModel::perfect(0.9)), ..Default::default() };
        assert_eq!(p.lead_s(7, 0, 41.0).to_bits(), 41.0f64.to_bits());
        assert!(p.false_alarms(7, 0, 16, 3600.0).is_empty());
        assert!((p.coverage(0.5) - 0.9).abs() < 1e-12, "detector overrides the coin");
        assert_eq!(GrayPlane::default().coverage(0.5).to_bits(), 0.5f64.to_bits());
    }

    #[test]
    fn schedules_are_pure_functions_of_their_key() {
        let p = active();
        for node in 0..8 {
            assert_eq!(p.flap_downs(42, node, 14400.0), p.flap_downs(42, node, 14400.0));
            assert_eq!(p.slow_windows(42, node, 14400.0), p.slow_windows(42, node, 14400.0));
        }
        for k in 0..8 {
            assert_eq!(p.false_alarms(42, k, 16, 14400.0), p.false_alarms(42, k, 16, 14400.0));
        }
        // a different seed decorrelates
        let a: usize = (0..32).map(|n| p.flap_downs(1, n, 14400.0).len()).sum();
        let b: usize = (0..32).map(|n| p.flap_downs(2, n, 14400.0).len()).sum();
        let _ = (a, b); // counts may coincide; purity above is the contract
    }

    #[test]
    fn max_lead_bounds_every_jittered_lead() {
        let p = active();
        let bound = p.max_lead_s(41.0);
        assert!((bound - 51.0).abs() < 1e-12, "base 41 + jitter 10");
        for k in 0..512 {
            assert!(p.lead_s(42, k, 41.0) <= bound);
        }
        assert_eq!(GrayPlane::default().max_lead_s(41.0).to_bits(), 41.0f64.to_bits());
    }

    #[test]
    fn emits_false_alarms_matches_the_emptiness_contract() {
        assert!(!GrayPlane::default().emits_false_alarms());
        let perfect =
            GrayPlane { detector: Some(DetectorModel::perfect(0.9)), ..Default::default() };
        assert!(!perfect.emits_false_alarms());
        let imperfect = active();
        assert!(imperfect.emits_false_alarms());
        // predicate ⇔ some event somewhere can carry alarms
        let any: usize = (0..64).map(|k| imperfect.false_alarms(3, k, 8, 3600.0).len()).sum();
        assert!(any > 0);
        let none: usize = (0..64).map(|k| perfect.false_alarms(3, k, 8, 3600.0).len()).sum();
        assert_eq!(none, 0);
    }

    #[test]
    fn false_alarm_ratio_matches_precision_in_expectation() {
        let p = GrayPlane {
            detector: Some(DetectorModel { coverage: 1.0, precision: 0.5, lead_jitter_s: 0.0 }),
            ..Default::default()
        };
        // ratio (1-p)/p = 1 exactly: every covered event drags exactly one
        // false alarm (fract = 0 never rounds up)
        let total: usize = (0..256).map(|k| p.false_alarms(9, k, 32, 3600.0).len()).sum();
        assert_eq!(total, 256);
        for k in 0..32 {
            for (node, t) in p.false_alarms(9, k, 32, 3600.0) {
                assert!(node < 32);
                assert!((0.0..3600.0).contains(&t));
            }
        }
    }

    #[test]
    fn flap_bursts_have_the_configured_shape() {
        let p = GrayPlane {
            flapping: Flapping { rate_per_node_h: 1.0, burst_len: 3, down_s: 60.0, gap_s: 120.0 },
            ..Default::default()
        };
        let mut shaped = 0;
        for node in 0..64 {
            let downs = p.flap_downs(5, node, 3600.0);
            assert!(downs.windows(2).all(|w| w[0] <= w[1]), "sorted");
            assert!(downs.iter().all(|&t| (0.0..3600.0).contains(&t)));
            // a full mid-horizon burst spaces its downs by down_s + gap_s
            for w in downs.windows(2) {
                if (w[1] - w[0] - 180.0).abs() < 1e-9 {
                    shaped += 1;
                }
            }
        }
        assert!(shaped > 0, "at least one full burst should fit the horizon");
    }

    #[test]
    fn slow_windows_are_sorted_disjoint_and_clipped() {
        let p = GrayPlane {
            fail_slow: FailSlow { rate_per_node_h: 4.0, mean_duration_s: 900.0, speed_factor: 0.25 },
            ..Default::default()
        };
        let mut any = false;
        for node in 0..32 {
            let w = p.slow_windows(11, node, 7200.0);
            any |= !w.is_empty();
            for pair in w.windows(2) {
                assert!(pair[0].1 < pair[1].0, "merged windows must be disjoint: {pair:?}");
            }
            for &(a, b) in &w {
                assert!(0.0 <= a && a < b && b <= 7200.0, "clipped: ({a}, {b})");
            }
        }
        assert!(any, "rate 4/h over 2 h should produce windows somewhere");
    }

    #[test]
    fn wall_work_conversions_invert_each_other() {
        let windows = [(100.0, 400.0), (1000.0, 1600.0)];
        let speed = 0.25;
        for &(start, work) in
            &[(0.0, 50.0), (0.0, 500.0), (50.0, 1000.0), (350.0, 10.0), (2000.0, 300.0)]
        {
            let wall = work_to_wall(&windows, speed, start, work);
            let back = wall_to_work(&windows, speed, start, start + wall);
            assert!((back - work).abs() < 1e-9, "start {start} work {work}: {back}");
        }
        // inside a window, work accrues at speed
        assert!((wall_to_work(&windows, speed, 100.0, 200.0) - 25.0).abs() < 1e-12);
        // no windows: identity
        assert_eq!(wall_to_work(&[], speed, 10.0, 70.0).to_bits(), 60.0f64.to_bits());
    }

    #[test]
    fn probation_backs_off_geometrically_to_the_ceiling() {
        let q = QuarantinePolicy::default();
        assert!((q.probation(0) - 600.0).abs() < 1e-12);
        assert!((q.probation(1) - 1200.0).abs() < 1e-12);
        assert!((q.probation(2) - 2400.0).abs() < 1e-12);
        assert!((q.probation(10) - 7200.0).abs() < 1e-12, "clamped at max_probation_s");
    }

    #[test]
    fn validate_rejects_each_bad_dimension() {
        let mut p = GrayPlane::default();
        p.detector = Some(DetectorModel { coverage: 1.5, precision: 1.0, lead_jitter_s: 0.0 });
        assert_eq!(p.validate(), Err(SpecError::BadDetector));

        let mut p = GrayPlane::default();
        p.detector = Some(DetectorModel { coverage: 0.5, precision: 0.0, lead_jitter_s: 0.0 });
        assert_eq!(p.validate(), Err(SpecError::BadDetector), "precision 0 would be all noise");

        let mut p = GrayPlane::default();
        p.detector = Some(DetectorModel { coverage: 0.5, precision: 1.0, lead_jitter_s: -1.0 });
        assert_eq!(p.validate(), Err(SpecError::BadDetector));

        let mut p = GrayPlane::default();
        p.fail_slow.speed_factor = 0.0;
        assert_eq!(p.validate(), Err(SpecError::BadFailSlow), "fail-slow is not fail-stop");

        let mut p = GrayPlane::default();
        p.fail_slow.rate_per_node_h = f64::NAN;
        assert_eq!(p.validate(), Err(SpecError::BadFailSlow));

        let mut p = GrayPlane::default();
        p.flapping.burst_len = 0;
        assert_eq!(p.validate(), Err(SpecError::BadFlapping));

        let mut p = GrayPlane::default();
        p.flapping.down_s = 0.0;
        assert_eq!(p.validate(), Err(SpecError::BadFlapping));

        let mut p = GrayPlane::default();
        p.quarantine.backoff_mult = 0.5;
        assert_eq!(p.validate(), Err(SpecError::BadQuarantine));

        let mut p = GrayPlane::default();
        p.quarantine.max_probation_s = 1.0;
        assert_eq!(p.validate(), Err(SpecError::BadQuarantine), "ceiling below the floor");
    }
}
