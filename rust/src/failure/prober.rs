//! The hardware probing process: periodic local health sampling plus
//! "are you alive?" exchanges with adjacent nodes.
//!
//! Before a real failure strikes, the victim core's health indicators drift
//! (wear ramps, soft errors appear). The prober records those samples into
//! the core's log; the [`crate::failure::predictor`] reads the log.

use crate::cluster::core::{Core, CoreState, HealthSample};
use crate::sim::{Rng, SimTime};

/// Generates health samples for a core, with pre-failure drift.
#[derive(Debug, Clone)]
pub struct Prober {
    /// Probe period in seconds (high frequency, tiny payload — the paper's
    /// point about probing traffic vs checkpoint traffic).
    pub period_s: f64,
    /// How long before an injected failure the drift becomes visible.
    /// Failures with shorter lead time are unpredictable (deadlocks, power
    /// loss) — this is what caps coverage at ~29 %.
    pub drift_lead_s: f64,
}

impl Default for Prober {
    fn default() -> Self {
        Self { period_s: 5.0, drift_lead_s: 60.0 }
    }
}

impl Prober {
    /// Sample the core at `now`, appending to its health log.
    pub fn probe(&self, core: &mut Core, now: SimTime, rng: &mut Rng) -> HealthSample {
        let base_load = 0.45 + 0.1 * rng.normal(0.0, 1.0).clamp(-3.0, 3.0);
        let (wear, soft) = match core.state {
            CoreState::Doomed { fails_at } if fails_at > now => {
                let lead = (fails_at.as_secs() - now.as_secs()).max(0.0);
                if lead <= self.drift_lead_s {
                    // ramp from 0.3 → 0.95 as the failure approaches
                    let frac = 1.0 - lead / self.drift_lead_s;
                    (0.3 + 0.65 * frac, rng.chance(0.3 + 0.6 * frac))
                } else {
                    (0.15 + 0.1 * rng.f64(), rng.chance(0.02))
                }
            }
            _ => (0.15 + 0.1 * rng.f64(), rng.chance(0.02)),
        };
        let s = HealthSample { at: now, load: base_load.clamp(0.0, 1.0), wear, soft_errors: soft };
        core.observe(s);
        s
    }

    /// Cost of one probe exchange in seconds of virtual time (tiny —
    /// contrast with checkpoint traffic).
    pub fn probe_cost_s(&self, rtt_s: f64) -> f64 {
        rtt_s + 1e-4
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::core::CoreId;

    #[test]
    fn healthy_core_low_wear() {
        let mut core = Core::new(CoreId(0), 64);
        let p = Prober::default();
        let mut rng = Rng::new(1);
        for i in 0..50 {
            p.probe(&mut core, SimTime::from_secs(i as f64 * 5.0), &mut rng);
        }
        let avg: f64 =
            core.log().iter().map(|s| s.wear).sum::<f64>() / core.log().len() as f64;
        assert!(avg < 0.3, "avg wear {avg}");
    }

    #[test]
    fn doomed_core_wear_ramps_near_failure() {
        let mut core = Core::new(CoreId(1), 64);
        core.state = CoreState::Doomed { fails_at: SimTime::from_secs(300.0) };
        let p = Prober::default();
        let mut rng = Rng::new(2);
        let early = p.probe(&mut core, SimTime::from_secs(100.0), &mut rng);
        let late = p.probe(&mut core, SimTime::from_secs(295.0), &mut rng);
        assert!(early.wear < 0.3, "early {}", early.wear);
        assert!(late.wear > 0.8, "late {}", late.wear);
    }

    #[test]
    fn drift_invisible_before_lead() {
        let mut core = Core::new(CoreId(2), 64);
        core.state = CoreState::Doomed { fails_at: SimTime::from_secs(10_000.0) };
        let p = Prober::default();
        let mut rng = Rng::new(3);
        let s = p.probe(&mut core, SimTime::from_secs(100.0), &mut rng);
        assert!(s.wear < 0.3);
    }

    #[test]
    fn probe_cost_small() {
        let p = Prober::default();
        assert!(p.probe_cost_s(16e-6) < 1e-3);
    }
}
