//! Fig. 15 outcome classification: the four prediction/failure states of a
//! job between two checkpoints.

use crate::sim::SimTime;

/// The four cases of Fig. 15.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OutcomeClass {
    /// (a) no prediction, no failure — ideal quiet state.
    Ideal,
    /// (b) failure occurred but was not predicted — the system fails if the
    /// multi-agent approaches are employed alone.
    UnpredictedFailure,
    /// (c) a prediction fired but no failure followed — unstable state
    /// (sub-job shuffled for nothing).
    FalseAlarm,
    /// (d) a prediction fired and the failure followed — ideal prediction.
    IdealPrediction,
}

/// Classify a window given the prediction and failure times observed in it.
pub fn classify(prediction: Option<SimTime>, failure: Option<SimTime>) -> OutcomeClass {
    match (prediction, failure) {
        (None, None) => OutcomeClass::Ideal,
        (None, Some(_)) => OutcomeClass::UnpredictedFailure,
        (Some(_), None) => OutcomeClass::FalseAlarm,
        (Some(p), Some(f)) => {
            if p <= f {
                OutcomeClass::IdealPrediction
            } else {
                // Prediction after the fact is useless: the failure was
                // effectively unpredicted.
                OutcomeClass::UnpredictedFailure
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(s: f64) -> Option<SimTime> {
        Some(SimTime::from_secs(s))
    }

    #[test]
    fn four_quadrants() {
        assert_eq!(classify(None, None), OutcomeClass::Ideal);
        assert_eq!(classify(None, t(10.0)), OutcomeClass::UnpredictedFailure);
        assert_eq!(classify(t(10.0), None), OutcomeClass::FalseAlarm);
        assert_eq!(classify(t(5.0), t(10.0)), OutcomeClass::IdealPrediction);
    }

    #[test]
    fn late_prediction_counts_as_unpredicted() {
        assert_eq!(classify(t(20.0), t(10.0)), OutcomeClass::UnpredictedFailure);
    }

    #[test]
    fn simultaneous_counts_as_predicted() {
        assert_eq!(classify(t(10.0), t(10.0)), OutcomeClass::IdealPrediction);
    }
}
