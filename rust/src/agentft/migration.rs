//! The Fig. 3 failure-scenario episode as a discrete-event simulation.
//!
//! The episode starts when the hardware probing process on `C_PF` notifies
//! the agent process `P_PF` of a predicted failure and ends when the new
//! agent process has re-established its last dependency. Each protocol step
//! runs in virtual time derived from the cluster's calibrated
//! [`AgentCosts`]; per-step lognormal jitter models trial-to-trial
//! variation. With jitter disabled the episode total equals
//! `AgentCosts::reinstate_s` exactly (asserted in tests) — the DES and the
//! closed form are two views of the same model.

use crate::cluster::spec::{size_log_factor, AgentCosts};
use crate::net::NodeId;
use crate::sim::engine::{ActorId, Engine, Outbox};
use crate::sim::{Rng, SimTime};
use std::cell::RefCell;
use std::rc::Rc;

/// One recorded protocol step (name, start, duration).
#[derive(Debug, Clone, PartialEq)]
pub struct StepTrace {
    pub step: &'static str,
    pub start_s: f64,
    pub dur_s: f64,
}

/// Result of a migration episode.
#[derive(Debug, Clone)]
pub struct MigrationOutcome {
    /// Total time to reinstate execution (the paper's ΔT_A2).
    pub reinstate_s: f64,
    /// The adjacent core the agent moved to.
    pub target: NodeId,
    /// Step-by-step trace (Fig. 3 sequence).
    pub steps: Vec<StepTrace>,
}

/// Episode messages the state machine sends itself.
#[derive(Debug, Clone)]
enum Ep {
    PredictionNotified,
    PredictionsGathered,
    Spawned,
    StateTransferred,
    DependencyDone { _idx: usize },
}

struct EpisodeActor {
    costs: AgentCosts,
    z: usize,
    data_kb: u64,
    proc_kb: u64,
    jitter: Vec<f64>,
    deps_done: usize,
    trace: Rc<RefCell<Vec<StepTrace>>>,
    finished: Rc<RefCell<Option<f64>>>,
}

impl EpisodeActor {
    fn record(&self, step: &'static str, start: SimTime, dur: f64) {
        self.trace.borrow_mut().push(StepTrace { step, start_s: start.as_secs(), dur_s: dur });
    }
}

impl crate::sim::engine::Actor<Ep> for EpisodeActor {
    fn on_msg(&mut self, me: ActorId, msg: Ep, out: &mut Outbox<'_, Ep>) {
        let now = out.now();
        match msg {
            // P_PF learns of the prediction; request predictions from the
            // probing processes on all adjacent cores (parallel RTTs).
            Ep::PredictionNotified => {
                let dur = self.costs.probe_gather_s * self.jitter[0];
                self.record("gather_predictions", now, dur);
                out.send_in(SimTime::from_secs(dur), me, Ep::PredictionsGathered);
            }
            // Create the replacement process on the chosen adjacent core.
            Ep::PredictionsGathered => {
                let dur = self.costs.spawn_s * self.jitter[1];
                self.record("spawn_process", now, dur);
                out.send_in(SimTime::from_secs(dur), me, Ep::Spawned);
            }
            // Transfer the agent's working state: handle/segment
            // registration scales with log2 of the payload sizes, plus the
            // fixed agent-layer cost.
            Ep::Spawned => {
                let dur = (self.costs.layer_s
                    + self.costs.data_log_coef_s * size_log_factor(self.data_kb)
                    + self.costs.proc_log_coef_s * size_log_factor(self.proc_kb))
                    * self.jitter[2];
                self.record("transfer_state", now, dur);
                out.send_in(SimTime::from_secs(dur), me, Ep::StateTransferred);
            }
            // Notify dependents and re-establish each dependency. The
            // handshakes pipeline through a window of `dep_window` parallel
            // channels; beyond the window each extra handshake only adds the
            // overlap tail, and past the NIC queue depth retransmissions add
            // congestion cost. Completion times follow that schedule.
            Ep::StateTransferred => {
                if self.z == 0 {
                    self.finished.borrow_mut().replace(now.as_secs());
                    out.stop = true;
                    return;
                }
                let j = self.jitter[3];
                for i in 0..self.z {
                    let within = (i + 1).min(self.costs.dep_window) as f64;
                    let beyond = (i + 1).saturating_sub(self.costs.dep_window) as f64;
                    let mut off = self.costs.dep_handshake_s * (within + self.costs.dep_tail * beyond);
                    let over = (i + 1).saturating_sub(self.costs.congestion_threshold) as f64;
                    off += self.costs.congestion_s * over;
                    out.send_in(SimTime::from_secs(off * j), me, Ep::DependencyDone { _idx: i });
                }
                self.record("dependency_phase", now, self.costs.dep_phase_s(self.z) * j);
            }
            Ep::DependencyDone { .. } => {
                self.deps_done += 1;
                if self.deps_done == self.z {
                    // Old agent process terminated; new process fully wired.
                    self.finished.borrow_mut().replace(now.as_secs());
                    out.stop = true;
                }
            }
        }
    }
}

/// Choose the migration target among adjacent cores, skipping any that are
/// themselves predicted to fail (the paper's scenario: "any adjacent core
/// onto which the job needs to be reallocated can also fail").
///
/// Returns `None` when every adjacent core is predicted to fail — the
/// caller must then fall back to checkpoint recovery.
pub fn choose_target(adjacent: &[(NodeId, bool)], rng: &mut Rng) -> Option<NodeId> {
    let healthy: Vec<NodeId> =
        adjacent.iter().filter(|(_, doomed)| !doomed).map(|(n, _)| *n).collect();
    if healthy.is_empty() {
        None
    } else {
        Some(*rng.pick(&healthy))
    }
}

/// Run one agent-intelligence migration episode.
///
/// * `adjacent` — the agent's vicinity with per-core failure predictions.
/// * `noise_sigma` — per-step lognormal jitter (0 ⇒ deterministic; the
///   episode then equals `costs.reinstate_s(z, data_kb, proc_kb)` exactly).
pub fn simulate_agent_migration(
    costs: &AgentCosts,
    z: usize,
    data_kb: u64,
    proc_kb: u64,
    adjacent: &[(NodeId, bool)],
    rng: &mut Rng,
    noise_sigma: f64,
) -> Option<MigrationOutcome> {
    let target = choose_target(adjacent, rng)?;
    let jitter: Vec<f64> = (0..4)
        .map(|_| if noise_sigma > 0.0 { rng.jitter(noise_sigma) } else { 1.0 })
        .collect();
    let trace = Rc::new(RefCell::new(Vec::new()));
    let finished = Rc::new(RefCell::new(None));
    let mut eng: Engine<Ep> = Engine::new();
    let actor = EpisodeActor {
        costs: *costs,
        z,
        data_kb,
        proc_kb,
        jitter,
        deps_done: 0,
        trace: trace.clone(),
        finished: finished.clone(),
    };
    let id = eng.add_actor(Box::new(actor));
    eng.schedule(SimTime::ZERO, id, Ep::PredictionNotified);
    eng.run();
    let reinstate_s = finished.borrow().expect("episode did not finish");
    let steps = trace.borrow().clone();
    Some(MigrationOutcome { reinstate_s, target, steps })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{preset, ClusterPreset};

    fn adj(n: usize) -> Vec<(NodeId, bool)> {
        (0..n).map(|i| (NodeId(i + 100), false)).collect()
    }

    #[test]
    fn deterministic_episode_matches_closed_form() {
        let costs = preset(ClusterPreset::Placentia).costs.agent;
        let mut rng = Rng::new(1);
        for z in [1usize, 3, 10, 25, 63] {
            for kb in [1u64 << 19, 1 << 24, 1 << 31] {
                let out =
                    simulate_agent_migration(&costs, z, kb, kb, &adj(4), &mut rng, 0.0).unwrap();
                let want = costs.reinstate_s(z, kb, kb);
                assert!(
                    (out.reinstate_s - want).abs() < 1e-9,
                    "z={z} kb={kb}: DES {} vs closed {want}",
                    out.reinstate_s
                );
            }
        }
    }

    #[test]
    fn zero_deps_episode_finishes() {
        let costs = preset(ClusterPreset::Placentia).costs.agent;
        let mut rng = Rng::new(2);
        let out = simulate_agent_migration(&costs, 0, 1, 1, &adj(2), &mut rng, 0.0).unwrap();
        assert!(out.reinstate_s > 0.0);
        assert_eq!(out.steps.len(), 3); // no dependency phase
    }

    #[test]
    fn steps_follow_fig3_order() {
        let costs = preset(ClusterPreset::Placentia).costs.agent;
        let mut rng = Rng::new(3);
        let out = simulate_agent_migration(&costs, 5, 1 << 20, 1 << 20, &adj(3), &mut rng, 0.0)
            .unwrap();
        let names: Vec<_> = out.steps.iter().map(|s| s.step).collect();
        assert_eq!(
            names,
            vec!["gather_predictions", "spawn_process", "transfer_state", "dependency_phase"]
        );
        // contiguous, ordered in time
        for w in out.steps.windows(2) {
            assert!(w[1].start_s >= w[0].start_s + w[0].dur_s - 1e-9);
        }
    }

    #[test]
    fn target_never_predicted_to_fail() {
        let mut rng = Rng::new(4);
        let adjacent = vec![
            (NodeId(1), true),
            (NodeId(2), false),
            (NodeId(3), true),
            (NodeId(4), false),
        ];
        for _ in 0..200 {
            let t = choose_target(&adjacent, &mut rng).unwrap();
            assert!(t == NodeId(2) || t == NodeId(4));
        }
    }

    #[test]
    fn all_adjacent_doomed_returns_none() {
        let mut rng = Rng::new(5);
        let adjacent = vec![(NodeId(1), true), (NodeId(2), true)];
        assert!(choose_target(&adjacent, &mut rng).is_none());
        let costs = preset(ClusterPreset::Placentia).costs.agent;
        assert!(simulate_agent_migration(&costs, 3, 1, 1, &adjacent, &mut rng, 0.0).is_none());
    }

    #[test]
    fn jitter_produces_spread_with_median_near_model() {
        let costs = preset(ClusterPreset::Placentia).costs.agent;
        let mut rng = Rng::new(6);
        let want = costs.reinstate_s(4, 1 << 19, 1 << 19);
        let xs: Vec<f64> = (0..200)
            .map(|_| {
                simulate_agent_migration(&costs, 4, 1 << 19, 1 << 19, &adj(3), &mut rng, 0.025)
                    .unwrap()
                    .reinstate_s
            })
            .collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        assert!((mean - want).abs() / want < 0.02, "mean {mean} want {want}");
        let min = xs.iter().cloned().fold(f64::MAX, f64::min);
        let max = xs.iter().cloned().fold(f64::MIN, f64::max);
        assert!(max > min, "no spread");
    }

    #[test]
    fn trials_deterministic_for_same_seed() {
        let costs = preset(ClusterPreset::Acet).costs.agent;
        let run = |seed| {
            let mut rng = Rng::new(seed);
            simulate_agent_migration(&costs, 7, 1 << 22, 1 << 22, &adj(4), &mut rng, 0.025)
                .unwrap()
                .reinstate_s
        };
        assert_eq!(run(99), run(99));
        assert_ne!(run(99), run(100));
    }
}
