//! The Fig. 3 failure-scenario episode as a discrete-event simulation.
//!
//! The episode starts when the hardware probing process on `C_PF` notifies
//! the agent process `P_PF` of a predicted failure and ends when the new
//! agent process has re-established its last dependency. Each protocol step
//! runs in virtual time derived from the cluster's calibrated
//! [`AgentCosts`]; per-step lognormal jitter models trial-to-trial
//! variation. With jitter disabled the episode total equals
//! `AgentCosts::reinstate_s` exactly (asserted in tests) — the DES and the
//! closed form are two views of the same model.
//!
//! The episode runs on the generic [`sim::harness`](crate::sim::harness)
//! scenario runtime. Randomness is split out of the simulation: a trial's
//! draws ([`EpisodeDraws`]) are sampled *serially* from the caller's RNG
//! (bit-compatible with the historical serial trial loop) and the episode
//! itself is then fully deterministic — which is what lets
//! `scenario::batch` fan trials across threads without changing a single
//! result.

use crate::cluster::spec::{size_log_factor, AgentCosts};
use crate::net::faults::FaultPlane;
use crate::net::message::SubJobId;
use crate::net::{LinkClass, MsgKind, NetCost, NodeId};
use crate::sim::{Ctx, Harness, Rng, Scenario, SimTime, TrialScratch};

pub use crate::sim::harness::StepTrace;

/// Result of a migration episode.
#[derive(Debug, Clone)]
pub struct MigrationOutcome {
    /// Total time to reinstate execution (the paper's ΔT_A2).
    pub reinstate_s: f64,
    /// The adjacent core the agent moved to.
    pub target: NodeId,
    /// Step-by-step trace (Fig. 3 sequence).
    pub steps: Vec<StepTrace>,
}

/// Episode messages the state machine sends itself.
#[derive(Debug, Clone)]
enum Ep {
    PredictionNotified,
    PredictionsGathered,
    Spawned,
    StateTransferred,
    DependencyDone { _idx: usize },
}

struct EpisodeActor<'a> {
    costs: AgentCosts,
    z: usize,
    data_kb: u64,
    proc_kb: u64,
    /// Borrowed from the trial's [`EpisodeDraws`] — no per-episode clone.
    jitter: &'a [f64],
    deps_done: usize,
}

impl Scenario for EpisodeActor<'_> {
    type Msg = Ep;

    fn on_msg(&mut self, ctx: &mut Ctx<'_, '_, Ep>, msg: Ep) {
        match msg {
            // P_PF learns of the prediction; request predictions from the
            // probing processes on all adjacent cores (parallel RTTs).
            Ep::PredictionNotified => {
                let dur = self.costs.probe_gather_s * self.jitter[0];
                ctx.record("gather_predictions", dur);
                ctx.send_self_in_s(dur, Ep::PredictionsGathered);
            }
            // Create the replacement process on the chosen adjacent core.
            Ep::PredictionsGathered => {
                let dur = self.costs.spawn_s * self.jitter[1];
                ctx.record("spawn_process", dur);
                ctx.send_self_in_s(dur, Ep::Spawned);
            }
            // Transfer the agent's working state: handle/segment
            // registration scales with log2 of the payload sizes, plus the
            // fixed agent-layer cost.
            Ep::Spawned => {
                let dur = (self.costs.layer_s
                    + self.costs.data_log_coef_s * size_log_factor(self.data_kb)
                    + self.costs.proc_log_coef_s * size_log_factor(self.proc_kb))
                    * self.jitter[2];
                ctx.record("transfer_state", dur);
                ctx.send_self_in_s(dur, Ep::StateTransferred);
            }
            // Notify dependents and re-establish each dependency. The
            // handshakes pipeline through a window of `dep_window` parallel
            // channels; beyond the window each extra handshake only adds the
            // overlap tail, and past the NIC queue depth retransmissions add
            // congestion cost. Completion times follow that schedule.
            Ep::StateTransferred => {
                if self.z == 0 {
                    ctx.finish();
                    return;
                }
                let j = self.jitter[3];
                for i in 0..self.z {
                    let within = (i + 1).min(self.costs.dep_window) as f64;
                    let beyond = (i + 1).saturating_sub(self.costs.dep_window) as f64;
                    let mut off = self.costs.dep_handshake_s * (within + self.costs.dep_tail * beyond);
                    let over = (i + 1).saturating_sub(self.costs.congestion_threshold) as f64;
                    off += self.costs.congestion_s * over;
                    ctx.send_self_in_s(off * j, Ep::DependencyDone { _idx: i });
                }
                ctx.record("dependency_phase", self.costs.dep_phase_s(self.z) * j);
            }
            Ep::DependencyDone { .. } => {
                self.deps_done += 1;
                if self.deps_done == self.z {
                    // Old agent process terminated; new process fully wired.
                    ctx.finish();
                }
            }
        }
    }
}

/// Choose the migration target among adjacent cores, skipping any that are
/// themselves predicted to fail (the paper's scenario: "any adjacent core
/// onto which the job needs to be reallocated can also fail").
///
/// Returns `None` when every adjacent core is predicted to fail — the
/// caller must then fall back to checkpoint recovery.
pub fn choose_target(adjacent: &[(NodeId, bool)], rng: &mut Rng) -> Option<NodeId> {
    let healthy: Vec<NodeId> =
        adjacent.iter().filter(|(_, doomed)| !doomed).map(|(n, _)| *n).collect();
    if healthy.is_empty() {
        None
    } else {
        Some(*rng.pick(&healthy))
    }
}

/// One trial's randomness for a migration episode, drawn serially from the
/// caller's stream so the (deterministic) episode itself can run on any
/// thread. The draw order — target pick, then per-step jitters — is
/// bit-compatible with the historical in-episode draws.
#[derive(Debug, Clone)]
pub struct EpisodeDraws {
    pub target: NodeId,
    pub jitter: Vec<f64>,
}

/// Sample one episode's draws: the migration target plus `n_jitters`
/// per-step factors (`noise_sigma <= 0` draws nothing and yields exact 1.0
/// factors). `None` when every adjacent core is doomed.
pub fn draw_episode(
    n_jitters: usize,
    adjacent: &[(NodeId, bool)],
    rng: &mut Rng,
    noise_sigma: f64,
) -> Option<EpisodeDraws> {
    let target = choose_target(adjacent, rng)?;
    let jitter: Vec<f64> = (0..n_jitters)
        .map(|_| if noise_sigma > 0.0 { rng.jitter(noise_sigma) } else { 1.0 })
        .collect();
    Some(EpisodeDraws { target, jitter })
}

/// [`draw_episode`] into a caller-owned [`EpisodeDraws`], reusing its
/// jitter buffer — the sweep executor's chunk loop draws one trial at a
/// time without a per-trial allocation. Same RNG consumption, same values.
pub fn draw_episode_into(
    n_jitters: usize,
    adjacent: &[(NodeId, bool)],
    rng: &mut Rng,
    noise_sigma: f64,
    out: &mut EpisodeDraws,
) -> bool {
    let Some(target) = choose_target(adjacent, rng) else {
        return false;
    };
    out.target = target;
    out.jitter.clear();
    out.jitter
        .extend((0..n_jitters).map(|_| if noise_sigma > 0.0 { rng.jitter(noise_sigma) } else { 1.0 }));
    true
}

/// Advance `rng` past exactly one episode's draws without materialising
/// them. The consumption is bit-identical to [`draw_episode`] — one target
/// pick (when any adjacent core is healthy) plus `n_jitters` jitters — so
/// a sweep chunk can fast-forward a cell's serial stream to its own trial
/// range and stay bit-compatible with the historical serial loop
/// (property-tested in `tests/sweep_properties.rs`).
pub fn skip_episode(
    n_jitters: usize,
    adjacent: &[(NodeId, bool)],
    rng: &mut Rng,
    noise_sigma: f64,
) {
    if choose_target(adjacent, rng).is_some() && noise_sigma > 0.0 {
        for _ in 0..n_jitters {
            rng.jitter(noise_sigma);
        }
    }
}

/// Number of jittered steps in the agent episode (Fig. 3).
pub const AGENT_JITTERS: usize = 4;

/// Total network cost of the Fig. 3 message sequence under a fault plane:
/// the `SpawnProcess`/`SpawnAck` handshake, the `TransferState`/
/// `TransferDone` payload transfer (data + process image), and the
/// `NotifyDependent`/`NotifyAck` round. Each phase is one
/// [`FaultPlane::exchange`] under the plane's shared timeout/retry/backoff
/// policy; a phase that exhausts its retries aborts the sequence (delivery
/// is conjunctive — later phases are never attempted) and the caller falls
/// back to reactive checkpoint recovery. Draws come only from the salted
/// side-stream keyed by `(seed, edge_key, seq)`, so calling this never
/// perturbs an episode's own jitter draws: with the plane off it returns
/// [`NetCost::CLEAN`] after zero-probability draws and the simulation is
/// byte-identical to one that never calls it.
pub fn sequence_net_cost(
    faults: &FaultPlane,
    seed: u64,
    edge_key: u64,
    seq: &mut u64,
    cut: bool,
    data_kb: u64,
    proc_kb: u64,
) -> NetCost {
    let phases = [
        MsgKind::SpawnProcess { sub_job: SubJobId(0) }.wire_bytes(),
        MsgKind::TransferState { bytes: (data_kb + proc_kb) * 1024 }.wire_bytes(),
        MsgKind::NotifyDependent { sub_job: SubJobId(0) }.wire_bytes(),
    ];
    let mut total = NetCost::CLEAN;
    for bytes in phases {
        let c = faults.exchange(LinkClass::Peer, seed, edge_key, seq, cut, bytes);
        let failed = !c.delivered;
        total.absorb(c);
        if failed {
            break;
        }
    }
    total
}

/// Reusable engine allocations for agent episodes; batch workers thread
/// one through consecutive trials (reuse never changes a result).
pub struct EpisodeScratch(TrialScratch<Ep>);

impl EpisodeScratch {
    pub fn new() -> Self {
        Self(TrialScratch::new())
    }
}

impl Default for EpisodeScratch {
    fn default() -> Self {
        Self::new()
    }
}

/// Run one agent-intelligence migration episode from pre-sampled draws.
/// Fully deterministic: same draws ⇒ same outcome, on any thread.
pub fn simulate_agent_migration_drawn(
    costs: &AgentCosts,
    z: usize,
    data_kb: u64,
    proc_kb: u64,
    draws: &EpisodeDraws,
) -> MigrationOutcome {
    let mut scratch = EpisodeScratch::new();
    simulate_agent_migration_drawn_scratch(costs, z, data_kb, proc_kb, draws, &mut scratch)
}

/// [`simulate_agent_migration_drawn`] on recycled engine allocations.
pub fn simulate_agent_migration_drawn_scratch(
    costs: &AgentCosts,
    z: usize,
    data_kb: u64,
    proc_kb: u64,
    draws: &EpisodeDraws,
    scratch: &mut EpisodeScratch,
) -> MigrationOutcome {
    assert!(draws.jitter.len() >= AGENT_JITTERS, "agent episode needs {AGENT_JITTERS} jitters");
    let mut h = Harness::from_scratch(Rng::new(0), std::mem::take(&mut scratch.0));
    let id = h.add(EpisodeActor {
        costs: *costs,
        z,
        data_kb,
        proc_kb,
        jitter: &draws.jitter,
        deps_done: 0,
    });
    h.schedule(SimTime::ZERO, id, Ep::PredictionNotified);
    let (fin, sim) = h.run_until_reclaim(SimTime(u64::MAX));
    scratch.0 = sim;
    MigrationOutcome {
        reinstate_s: fin.finished_at.expect("episode did not finish").as_secs(),
        target: draws.target,
        steps: fin.trace,
    }
}

/// Run one agent-intelligence migration episode.
///
/// * `adjacent` — the agent's vicinity with per-core failure predictions.
/// * `noise_sigma` — per-step lognormal jitter (0 ⇒ deterministic; the
///   episode then equals `costs.reinstate_s(z, data_kb, proc_kb)` exactly).
pub fn simulate_agent_migration(
    costs: &AgentCosts,
    z: usize,
    data_kb: u64,
    proc_kb: u64,
    adjacent: &[(NodeId, bool)],
    rng: &mut Rng,
    noise_sigma: f64,
) -> Option<MigrationOutcome> {
    let draws = draw_episode(AGENT_JITTERS, adjacent, rng, noise_sigma)?;
    Some(simulate_agent_migration_drawn(costs, z, data_kb, proc_kb, &draws))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{preset, ClusterPreset};

    fn adj(n: usize) -> Vec<(NodeId, bool)> {
        (0..n).map(|i| (NodeId(i + 100), false)).collect()
    }

    #[test]
    fn deterministic_episode_matches_closed_form() {
        let costs = preset(ClusterPreset::Placentia).costs.agent;
        let mut rng = Rng::new(1);
        for z in [1usize, 3, 10, 25, 63] {
            for kb in [1u64 << 19, 1 << 24, 1 << 31] {
                let out =
                    simulate_agent_migration(&costs, z, kb, kb, &adj(4), &mut rng, 0.0).unwrap();
                let want = costs.reinstate_s(z, kb, kb);
                assert!(
                    (out.reinstate_s - want).abs() < 1e-9,
                    "z={z} kb={kb}: DES {} vs closed {want}",
                    out.reinstate_s
                );
            }
        }
    }

    #[test]
    fn zero_deps_episode_finishes() {
        let costs = preset(ClusterPreset::Placentia).costs.agent;
        let mut rng = Rng::new(2);
        let out = simulate_agent_migration(&costs, 0, 1, 1, &adj(2), &mut rng, 0.0).unwrap();
        assert!(out.reinstate_s > 0.0);
        assert_eq!(out.steps.len(), 3); // no dependency phase
    }

    #[test]
    fn steps_follow_fig3_order() {
        let costs = preset(ClusterPreset::Placentia).costs.agent;
        let mut rng = Rng::new(3);
        let out = simulate_agent_migration(&costs, 5, 1 << 20, 1 << 20, &adj(3), &mut rng, 0.0)
            .unwrap();
        let names: Vec<_> = out.steps.iter().map(|s| s.step).collect();
        assert_eq!(
            names,
            vec!["gather_predictions", "spawn_process", "transfer_state", "dependency_phase"]
        );
        // contiguous, ordered in time
        for w in out.steps.windows(2) {
            assert!(w[1].start_s >= w[0].start_s + w[0].dur_s - 1e-9);
        }
    }

    #[test]
    fn target_never_predicted_to_fail() {
        let mut rng = Rng::new(4);
        let adjacent = vec![
            (NodeId(1), true),
            (NodeId(2), false),
            (NodeId(3), true),
            (NodeId(4), false),
        ];
        for _ in 0..200 {
            let t = choose_target(&adjacent, &mut rng).unwrap();
            assert!(t == NodeId(2) || t == NodeId(4));
        }
    }

    #[test]
    fn all_adjacent_doomed_returns_none() {
        let mut rng = Rng::new(5);
        let adjacent = vec![(NodeId(1), true), (NodeId(2), true)];
        assert!(choose_target(&adjacent, &mut rng).is_none());
        let costs = preset(ClusterPreset::Placentia).costs.agent;
        assert!(simulate_agent_migration(&costs, 3, 1, 1, &adjacent, &mut rng, 0.0).is_none());
    }

    #[test]
    fn jitter_produces_spread_with_median_near_model() {
        let costs = preset(ClusterPreset::Placentia).costs.agent;
        let mut rng = Rng::new(6);
        let want = costs.reinstate_s(4, 1 << 19, 1 << 19);
        let xs: Vec<f64> = (0..200)
            .map(|_| {
                simulate_agent_migration(&costs, 4, 1 << 19, 1 << 19, &adj(3), &mut rng, 0.025)
                    .unwrap()
                    .reinstate_s
            })
            .collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        assert!((mean - want).abs() / want < 0.02, "mean {mean} want {want}");
        let min = xs.iter().cloned().fold(f64::MAX, f64::min);
        let max = xs.iter().cloned().fold(f64::MIN, f64::max);
        assert!(max > min, "no spread");
    }

    #[test]
    fn trials_deterministic_for_same_seed() {
        let costs = preset(ClusterPreset::Acet).costs.agent;
        let run = |seed| {
            let mut rng = Rng::new(seed);
            simulate_agent_migration(&costs, 7, 1 << 22, 1 << 22, &adj(4), &mut rng, 0.025)
                .unwrap()
                .reinstate_s
        };
        assert_eq!(run(99), run(99));
        assert_ne!(run(99), run(100));
    }

    #[test]
    fn off_plane_sequence_is_clean() {
        let p = FaultPlane::default();
        let mut seq = 0;
        let c = sequence_net_cost(&p, 1, 42, &mut seq, false, 1 << 19, 1 << 19);
        assert_eq!(c, NetCost::CLEAN);
        assert_eq!(seq, 6, "three phases consume two draws each");
    }

    #[test]
    fn certain_loss_aborts_the_sequence_on_phase_one() {
        use crate::net::LinkFaults;
        let p = FaultPlane {
            peer: LinkFaults { loss_p: 1.0, ..LinkFaults::off() },
            ..FaultPlane::default()
        };
        let mut seq = 0;
        let c = sequence_net_cost(&p, 1, 42, &mut seq, false, 1 << 19, 1 << 19);
        assert!(!c.delivered, "loss_p = 1 can never complete the handshake");
        let attempts = p.retry.max_retries as u64 + 1;
        assert_eq!(c.timeouts, attempts, "later phases must never start");
        assert_eq!(seq, 2 * attempts);
        assert!(c.penalty_s > 0.0);
    }

    #[test]
    fn sequence_cost_is_pure_in_its_key() {
        use crate::net::LinkFaults;
        let p = FaultPlane {
            peer: LinkFaults { loss_p: 0.4, dup_p: 0.2, delay_p: 0.3, delay_mean_s: 0.2 },
            ..FaultPlane::default()
        };
        let (mut s1, mut s2) = (0u64, 0u64);
        let a = sequence_net_cost(&p, 9, 77, &mut s1, false, 1 << 20, 1 << 18);
        let b = sequence_net_cost(&p, 9, 77, &mut s2, false, 1 << 20, 1 << 18);
        assert_eq!(a, b, "same (seed, edge, seq) must mean same cost");
        assert_eq!(s1, s2);
    }

    #[test]
    fn drawn_episode_equals_inline_episode() {
        // the serial-draw / deterministic-execute split is the same model
        let costs = preset(ClusterPreset::Glooscap).costs.agent;
        let inline = {
            let mut rng = Rng::new(21);
            simulate_agent_migration(&costs, 9, 1 << 23, 1 << 21, &adj(3), &mut rng, 0.03).unwrap()
        };
        let split = {
            let mut rng = Rng::new(21);
            let d = draw_episode(AGENT_JITTERS, &adj(3), &mut rng, 0.03).unwrap();
            simulate_agent_migration_drawn(&costs, 9, 1 << 23, 1 << 21, &d)
        };
        assert_eq!(inline.reinstate_s, split.reinstate_s);
        assert_eq!(inline.target, split.target);
        assert_eq!(inline.steps, split.steps);
    }
}
