//! Approach 1 — fault tolerance incorporating **agent intelligence**.
//!
//! Sub-jobs are payloads of mobile agents situated on computing cores. The
//! agent probes its core; when a failure is predicted it executes the
//! Fig. 3 communication sequence: gather adjacent predictions, spawn a
//! replacement process on a healthy adjacent core, transfer its working
//! state, notify every input/output-dependent agent, terminate the old
//! process, and re-establish each dependency *individually* (the structural
//! difference from Approach 2, where re-binding is automatic).

pub mod agent;
pub mod migration;

pub use agent::{Agent, AgentState};
pub use migration::{
    draw_episode, simulate_agent_migration, simulate_agent_migration_drawn,
    simulate_agent_migration_drawn_scratch, EpisodeDraws, MigrationOutcome, StepTrace,
};
