//! The mobile agent: a wrapper that situates a sub-job on a core.
//!
//! "The agents and the sub-job are independent of each other; in other
//! words, an agent acts as a wrapper around a sub-job to situate the
//! sub-job on a core." — Methods, Approach 1.

use crate::net::message::SubJobId;
use crate::net::NodeId;

/// Lifecycle of an agent.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AgentState {
    /// Executing its payload on `home`.
    Executing,
    /// Mid-migration to the embedded target.
    Moving { to: NodeId },
    /// Payload finished; results handed to the collator.
    Finished,
    /// The core failed before the agent could move (unpredicted failure).
    Dead,
}

/// An agent carrying one sub-job as payload.
///
/// The three computational requirements of the paper (knowledge of the
/// overall job, access to the payload's data, knowledge of the operation)
/// map to `job_tag`, `data_kb` and the executable named by `op`.
#[derive(Debug, Clone)]
pub struct Agent {
    pub sub_job: SubJobId,
    /// Which overall job this agent participates in.
    pub job_tag: u64,
    /// Name of the AOT executable implementing the payload operation
    /// (resolved by `runtime::artifact`).
    pub op: &'static str,
    pub data_kb: u64,
    pub proc_kb: u64,
    pub home: NodeId,
    pub state: AgentState,
    /// Dependency endpoints the agent must carry and re-establish on move.
    pub deps: Vec<SubJobId>,
    /// Number of completed migrations (for instability accounting).
    pub moves: usize,
}

impl Agent {
    pub fn new(
        sub_job: SubJobId,
        job_tag: u64,
        op: &'static str,
        data_kb: u64,
        proc_kb: u64,
        home: NodeId,
        deps: Vec<SubJobId>,
    ) -> Self {
        Self {
            sub_job,
            job_tag,
            op,
            data_kb,
            proc_kb,
            home,
            state: AgentState::Executing,
            deps,
            moves: 0,
        }
    }

    /// The paper's Z for this agent.
    pub fn z(&self) -> usize {
        self.deps.len()
    }

    /// Begin moving to `target`.
    pub fn start_move(&mut self, target: NodeId) {
        debug_assert!(matches!(self.state, AgentState::Executing));
        self.state = AgentState::Moving { to: target };
    }

    /// Complete the move: the agent is now executing on the target.
    pub fn finish_move(&mut self) {
        if let AgentState::Moving { to } = self.state {
            self.home = to;
            self.state = AgentState::Executing;
            self.moves += 1;
        } else {
            panic!("finish_move while not moving");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn agent() -> Agent {
        Agent::new(
            SubJobId(3),
            7,
            "genome_search",
            1 << 19,
            1 << 19,
            NodeId(2),
            vec![SubJobId(0), SubJobId(1), SubJobId(9)],
        )
    }

    #[test]
    fn z_counts_deps() {
        assert_eq!(agent().z(), 3);
    }

    #[test]
    fn move_lifecycle() {
        let mut a = agent();
        a.start_move(NodeId(5));
        assert_eq!(a.state, AgentState::Moving { to: NodeId(5) });
        a.finish_move();
        assert_eq!(a.home, NodeId(5));
        assert_eq!(a.state, AgentState::Executing);
        assert_eq!(a.moves, 1);
    }

    #[test]
    #[should_panic]
    fn finish_without_start_panics() {
        agent().finish_move();
    }
}
