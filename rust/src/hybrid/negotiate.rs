//! Fig. 6 — conflict negotiation between an agent and its virtual core.
//!
//! When a failure is predicted, both the agent (Approach 1 reflex) and the
//! virtual core (Approach 2 reflex) want to initiate a move, possibly to
//! *different* adjacent cores. The negotiation protocol:
//!
//! 1. both parties propose (mover, estimated reinstate time, target);
//! 2. the decision rules pick the mover;
//! 3. the chosen mover's target wins; the other party yields and its
//!    in-flight proposal is cancelled.
//!
//! The estimates come from the same calibrated cost model the episodes use,
//! so the negotiation is consistent with what would actually happen — the
//! consistency is asserted in tests.

use super::rules::{decide, Mover, RuleInputs, RuleTrace};
use crate::cluster::spec::FtCosts;
use crate::net::faults::FaultPlane;
use crate::net::{LinkClass, MsgKind, NetCost, NodeId};

/// Record of one negotiation (for reporting and tests).
#[derive(Debug, Clone)]
pub struct NegotiationLog {
    pub agent_estimate_s: f64,
    pub core_estimate_s: f64,
    pub agent_target: NodeId,
    pub core_target: NodeId,
    pub winner: Mover,
    pub rule: RuleTrace,
    /// Target the sub-job will actually move to.
    pub chosen_target: NodeId,
    /// True when both parties proposed different targets (a real conflict).
    pub conflicted: bool,
}

/// Run the negotiation.
pub fn negotiate(
    costs: &FtCosts,
    inp: RuleInputs,
    agent_target: NodeId,
    core_target: NodeId,
) -> NegotiationLog {
    let agent_estimate_s = costs.agent.reinstate_s(inp.z, inp.data_kb, inp.proc_kb);
    let core_estimate_s = costs.core.reinstate_s(inp.z, inp.data_kb, inp.proc_kb);
    let (winner, rule) = decide(inp);
    let chosen_target = match winner {
        Mover::Agent => agent_target,
        Mover::Core => core_target,
    };
    NegotiationLog {
        agent_estimate_s,
        core_estimate_s,
        agent_target,
        core_target,
        winner,
        rule,
        chosen_target,
        conflicted: agent_target != core_target,
    }
}

/// The hybrid reinstate time: the winner's episode cost plus a fixed
/// negotiation exchange (one local round-trip between agent and vcore —
/// sub-millisecond, which is why Table 1's hybrid row equals the core row).
pub fn hybrid_reinstate_s(costs: &FtCosts, inp: RuleInputs) -> f64 {
    const NEGOTIATION_S: f64 = 0.4e-3;
    let (winner, _) = decide(inp);
    let episode = match winner {
        Mover::Agent => costs.agent.reinstate_s(inp.z, inp.data_kb, inp.proc_kb),
        Mover::Core => costs.core.reinstate_s(inp.z, inp.data_kb, inp.proc_kb),
    };
    episode + NEGOTIATION_S
}

/// Total network cost of the hybrid sequence under a fault plane: the
/// `PredictionRequest`/`PredictionReply` negotiation exchange between the
/// conflicting parties, then the *winner's* full message sequence — the
/// Fig. 3 agent handshakes or the Fig. 5 object migration, chosen by the
/// same [`decide`] rules the timing model uses. Delivery is conjunctive: a
/// negotiation that exhausts its retries aborts before either mover
/// starts, and the caller falls back to reactive checkpoint recovery.
/// Draws come only from the salted side-stream keyed by
/// `(seed, edge_key, seq)`; an off plane returns [`NetCost::CLEAN`].
#[allow(clippy::too_many_arguments)]
pub fn sequence_net_cost(
    faults: &FaultPlane,
    seed: u64,
    edge_key: u64,
    seq: &mut u64,
    cut: bool,
    z: usize,
    data_kb: u64,
    proc_kb: u64,
) -> NetCost {
    let mut total = faults.exchange(
        LinkClass::Peer,
        seed,
        edge_key,
        seq,
        cut,
        MsgKind::PredictionRequest.wire_bytes(),
    );
    if !total.delivered {
        return total;
    }
    let rest = match decide(RuleInputs { z, data_kb, proc_kb }).0 {
        Mover::Agent => crate::agentft::migration::sequence_net_cost(
            faults, seed, edge_key, seq, cut, data_kb, proc_kb,
        ),
        Mover::Core => {
            crate::coreft::migration::sequence_net_cost(faults, seed, edge_key, seq, cut, data_kb)
        }
    };
    total.absorb(rest);
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{preset, ClusterPreset};

    fn inp(z: usize, d: u64, p: u64) -> RuleInputs {
        RuleInputs { z, data_kb: d, proc_kb: p }
    }

    #[test]
    fn off_plane_sequence_is_clean_and_follows_the_winner() {
        let p = FaultPlane::default();
        // Core wins at the Table 1 point: negotiation + 2 core phases.
        let mut seq = 0;
        let c = sequence_net_cost(&p, 1, 9, &mut seq, false, 4, 1 << 19, 1 << 19);
        assert_eq!(c, NetCost::CLEAN);
        assert_eq!(seq, 6, "negotiation + MigrateObject + RebindRound, two draws each");
        // Agent wins at Z > 10 with small data: negotiation + 3 agent phases.
        let mut seq = 0;
        let c = sequence_net_cost(&p, 1, 9, &mut seq, false, 12, 1 << 19, 1 << 19);
        assert_eq!(c, NetCost::CLEAN);
        assert_eq!(seq, 8, "negotiation + Spawn + Transfer + Notify, two draws each");
    }

    #[test]
    fn lost_negotiation_aborts_before_any_mover_starts() {
        use crate::net::LinkFaults;
        let p = FaultPlane {
            peer: LinkFaults { loss_p: 1.0, ..LinkFaults::off() },
            ..FaultPlane::default()
        };
        let mut seq = 0;
        let c = sequence_net_cost(&p, 1, 9, &mut seq, false, 4, 1 << 19, 1 << 19);
        assert!(!c.delivered);
        let attempts = p.retry.max_retries as u64 + 1;
        assert_eq!(c.timeouts, attempts, "the winner's sequence must never start");
        assert_eq!(seq, 2 * attempts);
    }

    #[test]
    fn sequence_cost_is_pure_in_its_key() {
        use crate::net::LinkFaults;
        let p = FaultPlane {
            peer: LinkFaults { loss_p: 0.25, dup_p: 0.25, delay_p: 0.25, delay_mean_s: 0.05 },
            ..FaultPlane::default()
        };
        let (mut s1, mut s2) = (0u64, 0u64);
        let a = sequence_net_cost(&p, 4, 21, &mut s1, false, 4, 1 << 19, 1 << 19);
        let b = sequence_net_cost(&p, 4, 21, &mut s2, false, 4, 1 << 19, 1 << 19);
        assert_eq!(a, b);
        assert_eq!(s1, s2);
    }

    #[test]
    fn winner_target_chosen() {
        let costs = preset(ClusterPreset::Placentia).costs;
        let log = negotiate(&costs, inp(4, 1 << 19, 1 << 19), NodeId(7), NodeId(9));
        assert_eq!(log.winner, Mover::Core);
        assert_eq!(log.chosen_target, NodeId(9));
        assert!(log.conflicted);
    }

    #[test]
    fn no_conflict_when_targets_agree() {
        let costs = preset(ClusterPreset::Placentia).costs;
        let log = negotiate(&costs, inp(12, 1 << 19, 1 << 19), NodeId(7), NodeId(7));
        assert_eq!(log.winner, Mover::Agent); // Rule 2
        assert!(!log.conflicted);
        assert_eq!(log.chosen_target, NodeId(7));
    }

    #[test]
    fn rules_agree_with_cost_model_in_their_regions() {
        // Where a rule decides, the winner should be no slower than the
        // loser under the calibrated model (the rules were derived from the
        // same experiments).
        let costs = preset(ClusterPreset::Placentia).costs;
        // Rule 1 region: Z <= 10, large data
        let l = negotiate(&costs, inp(6, 1 << 24, 1 << 24), NodeId(1), NodeId(2));
        assert_eq!(l.winner, Mover::Core);
        assert!(l.core_estimate_s <= l.agent_estimate_s + 1e-9);
        // Rule 2 region: Z > 10, small data
        let l = negotiate(&costs, inp(11, 1 << 20, 1 << 20), NodeId(1), NodeId(2));
        assert_eq!(l.winner, Mover::Agent);
        assert!(l.agent_estimate_s <= l.core_estimate_s + 1e-9);
    }

    #[test]
    fn hybrid_matches_core_row_in_table1_setting() {
        // Table 1: Z = 4, S_d = 2^19 — hybrid row equals core row (0.38 s).
        let costs = preset(ClusterPreset::Placentia).costs;
        let h = hybrid_reinstate_s(&costs, inp(4, 1 << 19, 1 << 19));
        let c = costs.core.reinstate_s(4, 1 << 19, 1 << 19);
        assert!((h - c) < 1e-3, "hybrid {h} core {c}");
        assert!(h >= c); // negotiation adds a hair
    }

    #[test]
    fn hybrid_never_catastrophically_wrong() {
        // Hybrid should never exceed the best single approach by more than
        // the small negotiation overhead + model mismatch near boundaries.
        let costs = preset(ClusterPreset::Acet).costs;
        for z in [3usize, 10, 11, 40] {
            for kb in [1u64 << 19, 1 << 24, 1 << 28] {
                let h = hybrid_reinstate_s(&costs, inp(z, kb, kb));
                let best = costs
                    .agent
                    .reinstate_s(z, kb, kb)
                    .min(costs.core.reinstate_s(z, kb, kb));
                let worst = costs
                    .agent
                    .reinstate_s(z, kb, kb)
                    .max(costs.core.reinstate_s(z, kb, kb));
                assert!(h <= worst + 1e-3, "z={z} kb={kb}");
                // within 25% of the best even at rule boundaries
                assert!(h <= best * 1.25 + 0.01, "z={z} kb={kb}: h={h} best={best}");
            }
        }
    }
}
