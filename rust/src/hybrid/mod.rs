//! Approach 3 — **hybrid** fault tolerance: agents on virtual cores.
//!
//! When a failure is predicted both the agent and the virtual core can
//! respond; they negotiate (Fig. 6) using the empirically derived decision
//! rules of the paper's "Decision Making Rules" section.

pub mod negotiate;
pub mod rules;

pub use negotiate::{negotiate, NegotiationLog};
pub use rules::{decide, Mover, RuleInputs, RuleTrace};
