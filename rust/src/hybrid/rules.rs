//! The paper's three decision rules (Decision Making Rules section):
//!
//! * **Rule 1** — if `Z <= 10` use core intelligence, else either.
//! * **Rule 2** — if `S_d <= 2^24 KB` use agent intelligence, else either.
//! * **Rule 3** — if `S_p <= 2^24 KB` use agent intelligence, else either.
//!
//! Rules are ordered: dependency structure dominates (it is what Table 1's
//! hybrid row keys on — with `Z = 4 <= 10` the hybrid behaves exactly like
//! core intelligence). When no rule is decisive the approaches are
//! comparable and the tie-break prefers core intelligence (the paper's
//! observation that "the approach incorporating core intelligence takes
//! lesser time").

/// Who moves the sub-job.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mover {
    /// The agent moves itself (Approach 1 path).
    Agent,
    /// The virtual core migrates the agent (Approach 2 path).
    Core,
}

/// Inputs to the decision.
#[derive(Debug, Clone, Copy)]
pub struct RuleInputs {
    pub z: usize,
    pub data_kb: u64,
    pub proc_kb: u64,
}

/// Rule-1/2/3 thresholds (KB) — `2^24 KB` in the paper.
pub const DATA_THRESHOLD_KB: u64 = 1 << 24;
pub const PROC_THRESHOLD_KB: u64 = 1 << 24;
pub const Z_THRESHOLD: usize = 10;

/// Which rule fired, for reporting.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RuleTrace {
    Rule1Core,
    Rule2Agent,
    Rule3Agent,
    TieBreakCore,
}

/// Apply the decision rules.
pub fn decide(inp: RuleInputs) -> (Mover, RuleTrace) {
    if inp.z <= Z_THRESHOLD {
        return (Mover::Core, RuleTrace::Rule1Core);
    }
    if inp.data_kb <= DATA_THRESHOLD_KB {
        return (Mover::Agent, RuleTrace::Rule2Agent);
    }
    if inp.proc_kb <= PROC_THRESHOLD_KB {
        return (Mover::Agent, RuleTrace::Rule3Agent);
    }
    (Mover::Core, RuleTrace::TieBreakCore)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn inputs(z: usize, d: u64, p: u64) -> RuleInputs {
        RuleInputs { z, data_kb: d, proc_kb: p }
    }

    #[test]
    fn rule1_small_z_core() {
        // Table 1 hybrid row: Z = 4 ⇒ behaves like core intelligence.
        let (m, t) = decide(inputs(4, 1 << 19, 1 << 19));
        assert_eq!(m, Mover::Core);
        assert_eq!(t, RuleTrace::Rule1Core);
        // boundary inclusive
        assert_eq!(decide(inputs(10, 1 << 30, 1 << 30)).0, Mover::Core);
    }

    #[test]
    fn rule2_small_data_agent() {
        let (m, t) = decide(inputs(12, 1 << 20, 1 << 30));
        assert_eq!(m, Mover::Agent);
        assert_eq!(t, RuleTrace::Rule2Agent);
        // boundary inclusive
        assert_eq!(decide(inputs(12, 1 << 24, 1 << 30)).0, Mover::Agent);
    }

    #[test]
    fn rule3_small_proc_agent() {
        let (m, t) = decide(inputs(12, 1 << 30, 1 << 22));
        assert_eq!(m, Mover::Agent);
        assert_eq!(t, RuleTrace::Rule3Agent);
    }

    #[test]
    fn tiebreak_everything_large_core() {
        let (m, t) = decide(inputs(50, 1 << 30, 1 << 30));
        assert_eq!(m, Mover::Core);
        assert_eq!(t, RuleTrace::TieBreakCore);
    }

    #[test]
    fn decision_total_over_grid() {
        // totality: every input yields a decision (no panics)
        for z in [0usize, 1, 10, 11, 63] {
            for d in [0u64, 1 << 19, 1 << 24, (1 << 24) + 1, 1 << 31] {
                for p in [0u64, 1 << 19, 1 << 24, (1 << 24) + 1, 1 << 31] {
                    let _ = decide(inputs(z, d, p));
                }
            }
        }
    }
}
