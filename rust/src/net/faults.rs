//! Deterministic network fault plane: message loss, duplication, extra
//! delay and timed partitions, plus the bounded timeout/retry/backoff
//! machinery every recovery protocol runs its message exchanges through
//! (DESIGN.md §Network fault plane).
//!
//! The central discipline is the **salted side-stream**: every fault draw
//! comes from a throwaway RNG keyed by `(trial seed, edge, message seq)` —
//! never from the simulation's main stream. Consuming a draw therefore
//! cannot perturb arrival times, churn plans, jitters or placement, so
//! `run_live`/`run_fleet` stay pure functions of `(spec, seed)` with the
//! plane on, and with the plane off ([`FaultPlane::is_off`]) no draw is
//! taken at all — the off path is byte-identical to a build without the
//! plane, the same zero-cost contract the vopr
//! [`FleetObserver`](crate::scenario::fleet::FleetObserver) keeps.
//!
//! [`FaultPlane::exchange`] is the one retry loop every protocol shares: a
//! request/ack round-trip that retries on loss or partition with a
//! per-phase timeout and deterministic exponential backoff
//! ([`RetryPolicy`]), pricing each retransmission at the message's real
//! wire size ([`MsgKind::wire_bytes`](crate::net::MsgKind::wire_bytes) ×
//! [`LinkParams::transfer_time`]). It returns a [`NetCost`]: whether the
//! exchange ultimately delivered, the retries/timeouts/duplicates spent,
//! and the total extra seconds the caller must add to its phase. A caller
//! whose exchange exhausts its retries falls back one rung on the recovery
//! ladder (migration → reactive checkpoint recovery → degraded cold
//! restore) instead of losing the job — the fallback bookkeeping lives in
//! `coordinator::livesim` and `scenario::fleet`.

use crate::net::link::LinkParams;
use crate::net::message::MsgKind;
use crate::net::topology::NodeId;
use crate::scenario::fleet::SpecError;
use crate::sim::Rng;

/// Salt for the fault side-stream. Draw keys are
/// `seed ^ FAULT_SALT ^ mix(edge, seq)`, so fault draws can never collide
/// with the arrival (`ARRIVAL_SALT`), churn (`CHURN_SALT`) or plan
/// (`PLAN_SALT`) streams.
pub const FAULT_SALT: u64 = 0xFA17_5EED_DE11_FE77;

/// splitmix64 finalizer: decorrelates adjacent `(edge, seq)` keys.
fn mix(mut x: u64) -> u64 {
    x ^= x >> 30;
    x = x.wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x ^= x >> 27;
    x = x.wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^= x >> 31;
    x
}

/// Stable key for the directed peer link `a → b`.
pub fn edge(a: NodeId, b: NodeId) -> u64 {
    ((a.0 as u64) << 32) | (b.0 as u64 & 0xFFFF_FFFF)
}

/// Stable key for the link from node `a` to the checkpoint server.
pub fn ckpt_edge(a: NodeId) -> u64 {
    (1 << 63) | a.0 as u64
}

/// Which link class an exchange crosses: node↔node or node↔checkpoint
/// server. The two classes carry independent fault parameters — a flaky
/// interconnect and a healthy storage network (or vice versa) are distinct
/// scenarios.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LinkClass {
    Peer,
    Ckpt,
}

/// Per-link-class fault parameters. All probabilities are per message
/// (request and ack are drawn independently).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LinkFaults {
    /// Probability a message is silently dropped.
    pub loss_p: f64,
    /// Probability a delivered message arrives twice (the receiver must
    /// suppress the duplicate; suppression is counted, and free).
    pub dup_p: f64,
    /// Probability a delivered message is delayed beyond the link's
    /// nominal transfer time.
    pub delay_p: f64,
    /// Mean of the exponential extra-delay distribution, seconds.
    pub delay_mean_s: f64,
}

impl LinkFaults {
    /// No loss, no duplication, no extra delay.
    pub const fn off() -> Self {
        Self { loss_p: 0.0, dup_p: 0.0, delay_p: 0.0, delay_mean_s: 0.0 }
    }

    /// True when this link class can never perturb a delivery.
    pub fn is_off(&self) -> bool {
        self.loss_p == 0.0 && self.dup_p == 0.0 && self.delay_p == 0.0
    }

    fn validate(&self) -> Result<(), SpecError> {
        for p in [self.loss_p, self.dup_p, self.delay_p] {
            if !(p.is_finite() && (0.0..=1.0).contains(&p)) {
                return Err(SpecError::BadFaultProbability);
            }
        }
        if !(self.delay_mean_s.is_finite() && self.delay_mean_s >= 0.0) {
            return Err(SpecError::BadFaultDelay);
        }
        Ok(())
    }
}

impl Default for LinkFaults {
    fn default() -> Self {
        Self::off()
    }
}

/// Which links a timed [`Partition`] severs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CutSet {
    /// Split the ring into `[0, at)` vs `[at, n)`: peer messages crossing
    /// the boundary are cut, intra-side traffic is unaffected.
    Split { at: usize },
    /// Sever every node from the checkpoint server (restores and
    /// checkpoint writes time out; peer traffic is unaffected).
    Checkpoint,
}

/// A timed network partition, active on `[start_s, end_s)` of virtual
/// time. Partitions are deterministic — no draws — so they compose freely
/// with the probabilistic faults.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Partition {
    pub start_s: f64,
    pub end_s: f64,
    pub cut: CutSet,
}

impl Partition {
    fn active(&self, t_s: f64) -> bool {
        self.start_s <= t_s && t_s < self.end_s
    }

    fn validate(&self) -> Result<(), SpecError> {
        if !(self.start_s.is_finite() && self.end_s.is_finite())
            || self.start_s < 0.0
            || self.end_s <= self.start_s
        {
            return Err(SpecError::BadPartitionWindow);
        }
        if let CutSet::Split { at } = self.cut {
            if at == 0 {
                return Err(SpecError::BadPartitionCut);
            }
        }
        Ok(())
    }
}

/// Timeout/retry/backoff constants for one request/ack exchange — spec
/// data, never hardcoded in a protocol. The retransmit schedule is a pure
/// function of these four numbers: attempt `i ≥ 1` is sent
/// `timeout_s + backoff_s(i - 1)` after attempt `i - 1`, and after
/// `max_retries` retransmissions the exchange gives up and the caller
/// falls back.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RetryPolicy {
    /// Seconds a sender waits for the ack before declaring the attempt
    /// lost.
    pub timeout_s: f64,
    /// Retransmissions after the first attempt (total attempts =
    /// `max_retries + 1`).
    pub max_retries: u32,
    /// First backoff, seconds.
    pub backoff_base_s: f64,
    /// Geometric backoff multiplier (≥ 1).
    pub backoff_mult: f64,
}

impl RetryPolicy {
    /// Deterministic exponential backoff before retransmission
    /// `attempt + 1`: `backoff_base_s * backoff_mult^attempt`.
    pub fn backoff_s(&self, attempt: u32) -> f64 {
        self.backoff_base_s * self.backoff_mult.powi(attempt as i32)
    }

    fn validate(&self) -> Result<(), SpecError> {
        let ok = self.timeout_s.is_finite()
            && self.timeout_s > 0.0
            && self.backoff_base_s.is_finite()
            && self.backoff_base_s >= 0.0
            && self.backoff_mult.is_finite()
            && self.backoff_mult >= 1.0
            && self.max_retries <= 64;
        if ok {
            Ok(())
        } else {
            Err(SpecError::BadRetryPolicy)
        }
    }
}

impl Default for RetryPolicy {
    fn default() -> Self {
        Self { timeout_s: 0.5, max_retries: 4, backoff_base_s: 0.25, backoff_mult: 2.0 }
    }
}

/// The whole fault plane: per-class probabilistic faults, timed
/// partitions, the shared retry policy, the link model that prices
/// retransmissions, and the degradation factor for recoveries whose
/// checkpoint-server exchange exhausts its retries.
/// `FaultPlane::default()` is **off**: no draw is ever taken and every
/// simulation is byte-identical to one without the plane.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultPlane {
    /// Faults on node↔node links (migration traffic).
    pub peer: LinkFaults,
    /// Faults on node↔checkpoint-server links (write/restore traffic).
    pub ckpt: LinkFaults,
    /// Timed partitions, checked deterministically at exchange start.
    pub partitions: Vec<Partition>,
    /// Timeout/retry/backoff constants shared by every protocol phase.
    pub retry: RetryPolicy,
    /// Link model pricing each retransmission (`wire_bytes` ×
    /// `transfer_time`).
    pub link: LinkParams,
    /// Multiplier on the reactive recovery time when the checkpoint
    /// restore exchange itself exhausts its retries — the bottom rung of
    /// the fallback ladder (degraded cold restore), never a lost job.
    pub cold_restore_factor: f64,
}

impl Default for FaultPlane {
    fn default() -> Self {
        Self {
            peer: LinkFaults::off(),
            ckpt: LinkFaults::off(),
            partitions: Vec::new(),
            retry: RetryPolicy::default(),
            link: LinkParams::gige(),
            cold_restore_factor: 2.0,
        }
    }
}

impl FaultPlane {
    /// True when no delivery can ever be perturbed: both link classes off
    /// and no partitions. The retry policy is irrelevant then — no
    /// exchange is attempted — so the hot path skips the plane entirely.
    pub fn is_off(&self) -> bool {
        self.peer.is_off() && self.ckpt.is_off() && self.partitions.is_empty()
    }

    /// Structured validation, surfaced through `FleetSpec::validate` and
    /// the vopr generator.
    pub fn validate(&self) -> Result<(), SpecError> {
        self.peer.validate()?;
        self.ckpt.validate()?;
        for p in &self.partitions {
            p.validate()?;
        }
        self.retry.validate()?;
        self.link.validate()?;
        if !(self.cold_restore_factor.is_finite() && self.cold_restore_factor >= 1.0) {
            return Err(SpecError::BadColdRestoreFactor);
        }
        Ok(())
    }

    /// Is the peer link `a ↔ b` severed at virtual time `t_s`?
    pub fn cut_peer(&self, a: NodeId, b: NodeId, t_s: f64) -> bool {
        self.partitions.iter().any(|p| {
            p.active(t_s)
                && matches!(p.cut, CutSet::Split { at } if (a.0 < at) != (b.0 < at))
        })
    }

    /// Is node `a` severed from the checkpoint server at virtual time
    /// `t_s`?
    pub fn cut_ckpt(&self, _a: NodeId, t_s: f64) -> bool {
        self.partitions
            .iter()
            .any(|p| p.active(t_s) && matches!(p.cut, CutSet::Checkpoint))
    }

    /// One message's fate, a pure function of `(seed, edge, seq)`: the
    /// salted side-stream discipline. Same key, same fate — replays are
    /// exact — and no draw touches the simulation's main RNG.
    pub fn delivery(&self, class: LinkClass, seed: u64, edge: u64, seq: u64) -> Delivery {
        let lf = match class {
            LinkClass::Peer => &self.peer,
            LinkClass::Ckpt => &self.ckpt,
        };
        let mut rng = Rng::new(seed ^ FAULT_SALT ^ mix(edge.wrapping_add(mix(seq))));
        let lost = rng.chance(lf.loss_p);
        let duplicate = rng.chance(lf.dup_p);
        let extra_delay_s =
            if rng.chance(lf.delay_p) { rng.exponential(lf.delay_mean_s) } else { 0.0 };
        Delivery { lost, duplicate, extra_delay_s }
    }

    /// One request/ack exchange under the retry policy. `cut` is the
    /// partition verdict at exchange start (a partitioned exchange times
    /// out every attempt); `bytes` is the request's wire size, pricing
    /// each retransmission at `link.transfer_time(bytes)`. Consumes two
    /// side-stream draws (request, ack) per attempt — `seq` advances
    /// identically whether or not the messages survive, so downstream
    /// draws never shift.
    pub fn exchange(
        &self,
        class: LinkClass,
        seed: u64,
        edge_key: u64,
        seq: &mut u64,
        cut: bool,
        bytes: u64,
    ) -> NetCost {
        let resend_s = self.link.transfer_time(bytes);
        let mut out = NetCost {
            delivered: false,
            retries: 0,
            timeouts: 0,
            dup_deliveries: 0,
            penalty_s: 0.0,
        };
        for attempt in 0..=self.retry.max_retries {
            if attempt > 0 {
                out.retries += 1;
                out.penalty_s += self.retry.backoff_s(attempt - 1) + resend_s;
            }
            let req = self.take(class, seed, edge_key, seq);
            let ack = self.take(class, seed, edge_key, seq);
            if cut || req.lost || ack.lost {
                out.timeouts += 1;
                out.penalty_s += self.retry.timeout_s;
                continue;
            }
            out.dup_deliveries += u64::from(req.duplicate) + u64::from(ack.duplicate);
            out.penalty_s += req.extra_delay_s + ack.extra_delay_s;
            out.delivered = true;
            break;
        }
        out
    }

    /// The checkpoint-restore exchange: `RestoreRequest`/`RestoreData`
    /// against the checkpoint server, partition-checked at `t_s`.
    pub fn restore_exchange(
        &self,
        seed: u64,
        node: NodeId,
        seq: &mut u64,
        t_s: f64,
        data_kb: u64,
    ) -> NetCost {
        let bytes = MsgKind::RestoreRequest { bytes: data_kb * 1024 }.wire_bytes();
        let cut = self.cut_ckpt(node, t_s);
        self.exchange(LinkClass::Ckpt, seed, ckpt_edge(node), seq, cut, bytes)
    }

    fn take(&self, class: LinkClass, seed: u64, edge_key: u64, seq: &mut u64) -> Delivery {
        let d = self.delivery(class, seed, edge_key, *seq);
        *seq += 1;
        d
    }
}

/// One message's fate on the wire.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Delivery {
    pub lost: bool,
    pub duplicate: bool,
    pub extra_delay_s: f64,
}

/// What a message exchange (or a whole protocol's worth of exchanges)
/// cost: delivery verdict, retries/timeouts/duplicate-suppressions spent,
/// and the extra seconds the calling phase must absorb. The penalty is
/// *additive* — the nominal phase cost is the protocol's closed form, and
/// an off plane contributes exactly [`NetCost::CLEAN`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NetCost {
    /// False when the final attempt also timed out: the caller must fall
    /// back, never silently drop the work.
    pub delivered: bool,
    pub retries: u64,
    pub timeouts: u64,
    pub dup_deliveries: u64,
    pub penalty_s: f64,
}

impl NetCost {
    /// The off-plane outcome: delivered, nothing spent.
    pub const CLEAN: NetCost =
        NetCost { delivered: true, retries: 0, timeouts: 0, dup_deliveries: 0, penalty_s: 0.0 };

    /// Fold a later exchange into a running protocol total. Delivery is
    /// conjunctive: one exhausted phase fails the protocol.
    pub fn absorb(&mut self, o: NetCost) {
        self.delivered = self.delivered && o.delivered;
        self.retries += o.retries;
        self.timeouts += o.timeouts;
        self.dup_deliveries += o.dup_deliveries;
        self.penalty_s += o.penalty_s;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lossy(loss_p: f64) -> FaultPlane {
        FaultPlane {
            peer: LinkFaults { loss_p, ..LinkFaults::off() },
            ckpt: LinkFaults { loss_p, ..LinkFaults::off() },
            ..FaultPlane::default()
        }
    }

    #[test]
    fn default_plane_is_off_and_validates() {
        let p = FaultPlane::default();
        assert!(p.is_off());
        p.validate().unwrap();
    }

    #[test]
    fn delivery_is_pure_in_its_key() {
        let p = lossy(0.5);
        let mut lost = 0;
        for seq in 0..256 {
            let a = p.delivery(LinkClass::Peer, 7, edge(NodeId(0), NodeId(1)), seq);
            let b = p.delivery(LinkClass::Peer, 7, edge(NodeId(0), NodeId(1)), seq);
            assert_eq!(a, b, "same key must mean same fate");
            lost += a.lost as usize;
        }
        assert!(lost > 64 && lost < 192, "p=0.5 loss should land near half: {lost}");
        // a different edge sees an independent stream
        let a = p.delivery(LinkClass::Peer, 7, edge(NodeId(0), NodeId(1)), 0);
        let c = p.delivery(LinkClass::Peer, 7, edge(NodeId(1), NodeId(0)), 0);
        let _ = (a, c); // may coincide on one draw; purity is the contract
    }

    #[test]
    fn clean_link_delivers_first_attempt_for_free() {
        let p = FaultPlane::default();
        let mut seq = 0;
        let c = p.exchange(LinkClass::Peer, 3, edge(NodeId(0), NodeId(1)), &mut seq, false, 256);
        assert!(c.delivered);
        assert_eq!(c.retries, 0);
        assert_eq!(c.timeouts, 0);
        assert_eq!(c.penalty_s.to_bits(), 0f64.to_bits());
        assert_eq!(seq, 2, "one attempt consumes exactly two draws");
    }

    #[test]
    fn certain_loss_exhausts_on_the_closed_form_schedule() {
        let p = lossy(1.0);
        let mut seq = 0;
        let bytes = 256;
        let c = p.exchange(LinkClass::Peer, 9, edge(NodeId(2), NodeId(5)), &mut seq, false, bytes);
        assert!(!c.delivered, "loss_p = 1 can never deliver");
        let attempts = p.retry.max_retries as u64 + 1;
        assert_eq!(c.retries, attempts - 1);
        assert_eq!(c.timeouts, attempts);
        assert_eq!(seq, 2 * attempts, "draws advance on every attempt");
        // penalty = every timeout + every backoff + every retransmission
        let mut want = 0.0;
        for attempt in 0..p.retry.max_retries {
            want += p.retry.backoff_s(attempt) + p.link.transfer_time(bytes);
        }
        want += attempts as f64 * p.retry.timeout_s;
        assert!((c.penalty_s - want).abs() < 1e-12, "{} vs {}", c.penalty_s, want);
    }

    #[test]
    fn partitioned_exchange_times_out_without_loss() {
        let p = FaultPlane {
            partitions: vec![Partition {
                start_s: 100.0,
                end_s: 200.0,
                cut: CutSet::Split { at: 2 },
            }],
            ..FaultPlane::default()
        };
        assert!(!p.is_off());
        assert!(p.cut_peer(NodeId(0), NodeId(3), 150.0), "cross-boundary link is cut");
        assert!(!p.cut_peer(NodeId(0), NodeId(1), 150.0), "intra-side link survives");
        assert!(!p.cut_peer(NodeId(0), NodeId(3), 250.0), "partition heals");
        assert!(!p.cut_ckpt(NodeId(0), 150.0), "split does not touch the server");
        let cut = p.cut_peer(NodeId(0), NodeId(3), 150.0);
        let mut seq = 0;
        let c = p.exchange(LinkClass::Peer, 1, edge(NodeId(0), NodeId(3)), &mut seq, cut, 256);
        assert!(!c.delivered);
        assert_eq!(c.timeouts, p.retry.max_retries as u64 + 1);
    }

    #[test]
    fn checkpoint_cut_severs_only_the_server() {
        let p = FaultPlane {
            partitions: vec![Partition { start_s: 0.0, end_s: 50.0, cut: CutSet::Checkpoint }],
            ..FaultPlane::default()
        };
        assert!(p.cut_ckpt(NodeId(4), 10.0));
        assert!(!p.cut_ckpt(NodeId(4), 60.0));
        assert!(!p.cut_peer(NodeId(0), NodeId(4), 10.0));
        let mut seq = 0;
        let c = p.restore_exchange(11, NodeId(4), &mut seq, 10.0, 512);
        assert!(!c.delivered, "restore during the cut must exhaust");
        let healed = p.restore_exchange(11, NodeId(4), &mut seq, 60.0, 512);
        assert!(healed.delivered);
    }

    #[test]
    fn duplicates_and_delays_are_counted_not_fatal() {
        let p = FaultPlane {
            peer: LinkFaults { loss_p: 0.0, dup_p: 1.0, delay_p: 1.0, delay_mean_s: 0.1 },
            ..FaultPlane::default()
        };
        let mut seq = 0;
        let c = p.exchange(LinkClass::Peer, 5, edge(NodeId(1), NodeId(2)), &mut seq, false, 256);
        assert!(c.delivered);
        assert_eq!(c.dup_deliveries, 2, "request and ack both duplicated");
        assert!(c.penalty_s > 0.0, "extra delay must cost time");
        assert_eq!(c.retries, 0);
    }

    #[test]
    fn backoff_schedule_is_deterministic_and_bounded() {
        let r = RetryPolicy { timeout_s: 1.0, max_retries: 5, backoff_base_s: 0.5, backoff_mult: 2.0 };
        let widths: Vec<f64> = (0..r.max_retries).map(|i| r.backoff_s(i)).collect();
        assert_eq!(widths, vec![0.5, 1.0, 2.0, 4.0, 8.0]);
        let again: Vec<f64> = (0..r.max_retries).map(|i| r.backoff_s(i)).collect();
        assert_eq!(widths, again);
    }

    #[test]
    fn netcost_absorb_is_conjunctive_on_delivery() {
        let mut total = NetCost::CLEAN;
        total.absorb(NetCost { delivered: true, retries: 2, timeouts: 2, dup_deliveries: 1, penalty_s: 1.5 });
        assert!(total.delivered);
        total.absorb(NetCost { delivered: false, retries: 4, timeouts: 5, dup_deliveries: 0, penalty_s: 9.0 });
        assert!(!total.delivered);
        assert_eq!(total.retries, 6);
        assert_eq!(total.timeouts, 7);
        assert_eq!(total.dup_deliveries, 1);
        assert!((total.penalty_s - 10.5).abs() < 1e-12);
    }

    #[test]
    fn validate_rejects_each_bad_dimension() {
        let mut p = FaultPlane::default();
        p.peer.loss_p = 1.5;
        assert_eq!(p.validate(), Err(SpecError::BadFaultProbability));

        let mut p = FaultPlane::default();
        p.ckpt.delay_mean_s = -1.0;
        assert_eq!(p.validate(), Err(SpecError::BadFaultDelay));

        let mut p = FaultPlane::default();
        p.retry.timeout_s = 0.0;
        assert_eq!(p.validate(), Err(SpecError::BadRetryPolicy));

        let mut p = FaultPlane::default();
        p.retry.backoff_mult = 0.5;
        assert_eq!(p.validate(), Err(SpecError::BadRetryPolicy));

        let mut p = FaultPlane::default();
        p.partitions.push(Partition { start_s: 10.0, end_s: 5.0, cut: CutSet::Checkpoint });
        assert_eq!(p.validate(), Err(SpecError::BadPartitionWindow));

        let mut p = FaultPlane::default();
        p.partitions.push(Partition { start_s: 0.0, end_s: 5.0, cut: CutSet::Split { at: 0 } });
        assert_eq!(p.validate(), Err(SpecError::BadPartitionCut));

        let mut p = FaultPlane::default();
        p.cold_restore_factor = 0.5;
        assert_eq!(p.validate(), Err(SpecError::BadColdRestoreFactor));

        let mut p = FaultPlane::default();
        p.link.bandwidth_bps = 0.0;
        assert_eq!(p.validate(), Err(SpecError::BadLinkBandwidth));
    }
}
