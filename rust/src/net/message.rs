//! Typed messages exchanged by the fault-tolerance protocols.
//!
//! One shared enum keeps the DES engine monomorphic across protocols; the
//! variants follow the communication sequences of Fig. 3 (agent
//! intelligence), Fig. 5 (core intelligence) and the checkpointing
//! baselines.

use super::topology::NodeId;
use crate::sim::SimTime;

/// Identifies a sub-job (and hence its agent / virtual core binding).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct SubJobId(pub usize);

/// Payload-free protocol message kinds; sizes are carried alongside so the
/// transport can compute timing.
#[derive(Debug, Clone, PartialEq)]
pub enum MsgKind {
    // --- probing / prediction (both approaches) ---
    /// Hardware probing process tick on a core.
    ProbeTick,
    /// "Are you alive?" query to an adjacent node.
    AliveQuery,
    /// Response carrying the responder's health estimate.
    AliveReply { healthy: bool },
    /// The probing process notifies the local agent/core of a prediction.
    FailurePredicted { node: NodeId },

    // --- Fig. 3: agent intelligence failure scenario ---
    /// P_PF requests predictions from adjacent probing processes.
    PredictionRequest,
    PredictionReply { will_fail: bool },
    /// Agent creates a replacement process on the chosen adjacent core.
    SpawnProcess { sub_job: SubJobId },
    SpawnAck,
    /// Agent streams its working data to the new process.
    TransferState { bytes: u64 },
    TransferDone,
    /// Notify one input/output-dependent agent of the relocation.
    NotifyDependent { sub_job: SubJobId },
    NotifyAck,
    /// New process re-establishes one dependency channel.
    EstablishDependency { sub_job: SubJobId },
    DependencyReady,
    /// Old agent process terminates.
    Terminate,

    // --- Fig. 5: core intelligence failure scenario ---
    /// Virtual core migrates the job object to an adjacent virtual core.
    MigrateObject { sub_job: SubJobId, bytes: u64 },
    MigrateAck,
    /// Runtime-level dependency table update (automatic re-binding).
    RebindRound { remaining: usize },

    // --- checkpointing baselines ---
    CheckpointBegin,
    CheckpointWrite { bytes: u64 },
    CheckpointAck,
    RestoreRequest { bytes: u64 },
    RestoreData,
    /// Decentralised variant: locate the nearest checkpoint server.
    ServerDiscovery,

    // --- failure injection / job lifecycle ---
    InjectFailure { node: NodeId },
    SubJobDone { sub_job: SubJobId },
    CollateResults,
}

/// A message in flight.
#[derive(Debug, Clone)]
pub struct Message {
    pub from: NodeId,
    pub to: NodeId,
    pub kind: MsgKind,
    /// Time the message was sent (for in-flight accounting / tracing).
    pub sent_at: SimTime,
}

/// Wire size of every payload-free protocol message: headers, ids and
/// flags fit one envelope. The single source of truth for control-message
/// timing — call sites must go through [`MsgKind::wire_bytes`] rather than
/// repeating the number.
pub const CONTROL_ENVELOPE_BYTES: u64 = 256;

impl MsgKind {
    /// Stable tag for determinism traces.
    pub fn tag(&self) -> u64 {
        match self {
            MsgKind::ProbeTick => 1,
            MsgKind::AliveQuery => 2,
            MsgKind::AliveReply { .. } => 3,
            MsgKind::FailurePredicted { .. } => 4,
            MsgKind::PredictionRequest => 5,
            MsgKind::PredictionReply { .. } => 6,
            MsgKind::SpawnProcess { .. } => 7,
            MsgKind::SpawnAck => 8,
            MsgKind::TransferState { .. } => 9,
            MsgKind::TransferDone => 10,
            MsgKind::NotifyDependent { .. } => 11,
            MsgKind::NotifyAck => 12,
            MsgKind::EstablishDependency { .. } => 13,
            MsgKind::DependencyReady => 14,
            MsgKind::Terminate => 15,
            MsgKind::MigrateObject { .. } => 16,
            MsgKind::MigrateAck => 17,
            MsgKind::RebindRound { .. } => 18,
            MsgKind::CheckpointBegin => 19,
            MsgKind::CheckpointWrite { .. } => 20,
            MsgKind::CheckpointAck => 21,
            MsgKind::RestoreRequest { .. } => 22,
            MsgKind::RestoreData => 23,
            MsgKind::ServerDiscovery => 24,
            MsgKind::InjectFailure { .. } => 25,
            MsgKind::SubJobDone { .. } => 26,
            MsgKind::CollateResults => 27,
        }
    }

    /// Wire size in bytes for transport timing: control messages are small;
    /// state transfers carry their payload size.
    pub fn wire_bytes(&self) -> u64 {
        match self {
            MsgKind::TransferState { bytes }
            | MsgKind::MigrateObject { bytes, .. }
            | MsgKind::CheckpointWrite { bytes }
            | MsgKind::RestoreRequest { bytes } => *bytes,
            _ => CONTROL_ENVELOPE_BYTES,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tags_unique() {
        let kinds = [
            MsgKind::ProbeTick,
            MsgKind::AliveQuery,
            MsgKind::AliveReply { healthy: true },
            MsgKind::FailurePredicted { node: NodeId(0) },
            MsgKind::PredictionRequest,
            MsgKind::PredictionReply { will_fail: false },
            MsgKind::SpawnProcess { sub_job: SubJobId(0) },
            MsgKind::SpawnAck,
            MsgKind::TransferState { bytes: 1 },
            MsgKind::TransferDone,
            MsgKind::NotifyDependent { sub_job: SubJobId(0) },
            MsgKind::NotifyAck,
            MsgKind::EstablishDependency { sub_job: SubJobId(0) },
            MsgKind::DependencyReady,
            MsgKind::Terminate,
            MsgKind::MigrateObject { sub_job: SubJobId(0), bytes: 1 },
            MsgKind::MigrateAck,
            MsgKind::RebindRound { remaining: 1 },
            MsgKind::CheckpointBegin,
            MsgKind::CheckpointWrite { bytes: 1 },
            MsgKind::CheckpointAck,
            MsgKind::RestoreRequest { bytes: 1 },
            MsgKind::RestoreData,
            MsgKind::ServerDiscovery,
            MsgKind::InjectFailure { node: NodeId(0) },
            MsgKind::SubJobDone { sub_job: SubJobId(0) },
            MsgKind::CollateResults,
        ];
        let mut tags: Vec<u64> = kinds.iter().map(|k| k.tag()).collect();
        tags.sort();
        tags.dedup();
        assert_eq!(tags.len(), kinds.len());
    }

    #[test]
    fn payload_sizes_flow_through() {
        assert_eq!(MsgKind::TransferState { bytes: 12345 }.wire_bytes(), 12345);
        assert_eq!(MsgKind::AliveQuery.wire_bytes(), 256);
        assert_eq!(
            MsgKind::MigrateObject { sub_job: SubJobId(1), bytes: 99 }.wire_bytes(),
            99
        );
    }

    /// Pins the wire size of every variant. Timing-affecting constants
    /// must never drift silently between protocols: a change here is a
    /// deliberate, reviewed change to every simulated transfer time.
    #[test]
    fn wire_bytes_pinned_for_every_variant() {
        let payload = 7_654_321u64;
        let sized: [(MsgKind, u64); 4] = [
            (MsgKind::TransferState { bytes: payload }, payload),
            (MsgKind::MigrateObject { sub_job: SubJobId(3), bytes: payload }, payload),
            (MsgKind::CheckpointWrite { bytes: payload }, payload),
            (MsgKind::RestoreRequest { bytes: payload }, payload),
        ];
        for (kind, want) in sized {
            assert_eq!(kind.wire_bytes(), want, "{kind:?}");
        }
        let control = [
            MsgKind::ProbeTick,
            MsgKind::AliveQuery,
            MsgKind::AliveReply { healthy: true },
            MsgKind::FailurePredicted { node: NodeId(0) },
            MsgKind::PredictionRequest,
            MsgKind::PredictionReply { will_fail: false },
            MsgKind::SpawnProcess { sub_job: SubJobId(0) },
            MsgKind::SpawnAck,
            MsgKind::TransferDone,
            MsgKind::NotifyDependent { sub_job: SubJobId(0) },
            MsgKind::NotifyAck,
            MsgKind::EstablishDependency { sub_job: SubJobId(0) },
            MsgKind::DependencyReady,
            MsgKind::Terminate,
            MsgKind::MigrateAck,
            MsgKind::RebindRound { remaining: 1 },
            MsgKind::CheckpointBegin,
            MsgKind::CheckpointAck,
            MsgKind::RestoreData,
            MsgKind::ServerDiscovery,
            MsgKind::InjectFailure { node: NodeId(0) },
            MsgKind::SubJobDone { sub_job: SubJobId(0) },
            MsgKind::CollateResults,
        ];
        for kind in control {
            assert_eq!(kind.wire_bytes(), CONTROL_ENVELOPE_BYTES, "{kind:?}");
        }
    }
}
