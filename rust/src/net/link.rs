//! Link timing model: per-message latency plus bandwidth-limited payload.

use crate::scenario::fleet::SpecError;
use crate::sim::SimTime;

/// Parameters of one interconnect class (GigE vs InfiniBand in the paper's
/// clusters).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LinkParams {
    /// One-way small-message latency, seconds.
    pub latency_s: f64,
    /// Sustained point-to-point bandwidth, bytes/second.
    pub bandwidth_bps: f64,
    /// Per-message software overhead (MPI stack), seconds.
    pub sw_overhead_s: f64,
}

impl LinkParams {
    pub const fn new(latency_s: f64, bandwidth_bps: f64, sw_overhead_s: f64) -> Self {
        Self { latency_s, bandwidth_bps, sw_overhead_s }
    }

    /// Gigabit Ethernet (ACET, Brasdor).
    pub const fn gige() -> Self {
        Self::new(80e-6, 110e6, 25e-6)
    }

    /// InfiniBand (Glooscap, Placentia).
    pub const fn infiniband() -> Self {
        Self::new(8e-6, 1_200e6, 5e-6)
    }

    /// Time to move `bytes` in one message.
    pub fn transfer_time(&self, bytes: u64) -> f64 {
        self.latency_s + self.sw_overhead_s + bytes as f64 / self.bandwidth_bps
    }

    /// Round-trip time of a small control message (e.g. "are you alive?").
    pub fn rtt(&self) -> f64 {
        2.0 * (self.latency_s + self.sw_overhead_s)
    }

    pub fn transfer(&self, bytes: u64) -> SimTime {
        SimTime::from_secs(self.transfer_time(bytes))
    }

    /// Structured validation: negative or non-finite latency/overhead and
    /// non-positive bandwidth are rejected (zero `bandwidth_bps` would
    /// make every [`transfer_time`](Self::transfer_time) infinite).
    /// Called from `FleetSpec::validate` and the vopr generator so no
    /// simulated link can silently carry a nonsensical timing model.
    pub fn validate(&self) -> Result<(), SpecError> {
        if !(self.latency_s.is_finite() && self.latency_s >= 0.0) {
            return Err(SpecError::BadLinkLatency);
        }
        if !(self.bandwidth_bps.is_finite() && self.bandwidth_bps > 0.0) {
            return Err(SpecError::BadLinkBandwidth);
        }
        if !(self.sw_overhead_s.is_finite() && self.sw_overhead_s >= 0.0) {
            return Err(SpecError::BadLinkOverhead);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_dominates_small_messages() {
        let l = LinkParams::gige();
        let t = l.transfer_time(64);
        assert!(t < 2.0 * (l.latency_s + l.sw_overhead_s));
    }

    #[test]
    fn bandwidth_dominates_large_messages() {
        let l = LinkParams::gige();
        let bytes = 1u64 << 30; // 1 GiB
        let t = l.transfer_time(bytes);
        let bw_term = bytes as f64 / l.bandwidth_bps;
        assert!((t - bw_term) / t < 0.01);
    }

    #[test]
    fn infiniband_faster_than_gige() {
        let g = LinkParams::gige();
        let i = LinkParams::infiniband();
        assert!(i.rtt() < g.rtt());
        assert!(i.transfer_time(1 << 20) < g.transfer_time(1 << 20));
    }

    #[test]
    fn transfer_monotone_in_bytes() {
        let l = LinkParams::infiniband();
        let mut prev = 0.0;
        for sh in 0..30 {
            let t = l.transfer_time(1 << sh);
            assert!(t >= prev);
            prev = t;
        }
    }

    #[test]
    fn simtime_conversion() {
        let l = LinkParams::gige();
        assert_eq!(l.transfer(0), SimTime::from_secs(l.latency_s + l.sw_overhead_s));
    }

    #[test]
    fn presets_validate() {
        LinkParams::gige().validate().unwrap();
        LinkParams::infiniband().validate().unwrap();
    }

    #[test]
    fn validate_rejects_degenerate_links() {
        let mut l = LinkParams::gige();
        l.latency_s = -1e-6;
        assert_eq!(l.validate(), Err(SpecError::BadLinkLatency));
        let mut l = LinkParams::gige();
        l.latency_s = f64::NAN;
        assert_eq!(l.validate(), Err(SpecError::BadLinkLatency));

        let mut l = LinkParams::gige();
        l.bandwidth_bps = 0.0; // transfer_time would be infinite
        assert_eq!(l.validate(), Err(SpecError::BadLinkBandwidth));
        let mut l = LinkParams::gige();
        l.bandwidth_bps = -110e6;
        assert_eq!(l.validate(), Err(SpecError::BadLinkBandwidth));
        let mut l = LinkParams::gige();
        l.bandwidth_bps = f64::INFINITY;
        assert_eq!(l.validate(), Err(SpecError::BadLinkBandwidth));

        let mut l = LinkParams::gige();
        l.sw_overhead_s = f64::NEG_INFINITY;
        assert_eq!(l.validate(), Err(SpecError::BadLinkOverhead));
    }
}
