//! Node graph with adjacency — "the landscape" in the paper's terms.
//!
//! Agents gather predictions from *adjacent* cores and migrate to adjacent
//! cores (Methods, Approach 1); virtual cores monitor their *neighbours*
//! (Approach 2). Adjacency here is the communication neighbourhood, built
//! as a ring-of-switches / star / full mesh depending on the cluster.

/// Index of a compute node in the cluster.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct NodeId(pub usize);

/// Undirected adjacency over nodes.
#[derive(Debug, Clone)]
pub struct Topology {
    n: usize,
    adj: Vec<Vec<NodeId>>,
}

impl Topology {
    /// Ring topology with `k` neighbours on each side (the "vicinity" used
    /// by the probing processes).
    pub fn ring(n: usize, k: usize) -> Self {
        assert!(n > 0, "empty topology");
        let mut adj = vec![Vec::new(); n];
        for i in 0..n {
            for d in 1..=k {
                let a = (i + d) % n;
                let b = (i + n - d % n) % n;
                if a != i && !adj[i].contains(&NodeId(a)) {
                    adj[i].push(NodeId(a));
                }
                if b != i && !adj[i].contains(&NodeId(b)) {
                    adj[i].push(NodeId(b));
                }
            }
            adj[i].sort();
        }
        Self { n, adj }
    }

    /// Star: node 0 is the head (checkpoint server / combiner host).
    pub fn star(n: usize) -> Self {
        assert!(n > 1, "star needs >= 2 nodes");
        let mut adj = vec![Vec::new(); n];
        for i in 1..n {
            adj[0].push(NodeId(i));
            adj[i].push(NodeId(0));
        }
        Self { n, adj }
    }

    /// Full mesh (small experiments, every core in every core's vicinity).
    pub fn mesh(n: usize) -> Self {
        assert!(n > 0, "empty topology");
        let mut adj = vec![Vec::new(); n];
        for i in 0..n {
            for j in 0..n {
                if i != j {
                    adj[i].push(NodeId(j));
                }
            }
        }
        Self { n, adj }
    }

    pub fn len(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    pub fn neighbours(&self, node: NodeId) -> &[NodeId] {
        &self.adj[node.0]
    }

    pub fn are_adjacent(&self, a: NodeId, b: NodeId) -> bool {
        self.adj[a.0].contains(&b)
    }

    /// All nodes, useful for schedulers.
    pub fn nodes(&self) -> impl Iterator<Item = NodeId> + '_ {
        (0..self.n).map(NodeId)
    }

    /// Degree of a node.
    pub fn degree(&self, node: NodeId) -> usize {
        self.adj[node.0].len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_adjacency_symmetric() {
        let t = Topology::ring(10, 2);
        for i in t.nodes() {
            for &j in t.neighbours(i) {
                assert!(t.are_adjacent(j, i), "{i:?} {j:?}");
            }
        }
    }

    #[test]
    fn ring_degree() {
        let t = Topology::ring(10, 2);
        for i in t.nodes() {
            assert_eq!(t.degree(i), 4);
        }
        let t1 = Topology::ring(10, 1);
        for i in t1.nodes() {
            assert_eq!(t1.degree(i), 2);
        }
    }

    #[test]
    fn tiny_ring_no_self_or_dup() {
        let t = Topology::ring(3, 2); // k >= n/2: neighbours must dedup
        for i in t.nodes() {
            let nb = t.neighbours(i);
            assert!(!nb.contains(&i));
            let mut d = nb.to_vec();
            d.dedup();
            assert_eq!(d.len(), nb.len());
        }
    }

    #[test]
    fn star_shape() {
        let t = Topology::star(5);
        assert_eq!(t.degree(NodeId(0)), 4);
        for i in 1..5 {
            assert_eq!(t.degree(NodeId(i)), 1);
            assert!(t.are_adjacent(NodeId(i), NodeId(0)));
        }
    }

    #[test]
    fn mesh_complete() {
        let t = Topology::mesh(6);
        for i in t.nodes() {
            assert_eq!(t.degree(i), 5);
        }
    }

    #[test]
    fn single_node_mesh() {
        let t = Topology::mesh(1);
        assert_eq!(t.len(), 1);
        assert_eq!(t.degree(NodeId(0)), 0);
    }
}
