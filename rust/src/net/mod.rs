//! Cluster interconnect model: topology, link timing, typed messages.
//!
//! The paper's measurements are dominated by message latency, handshake
//! counts and bytes moved; this module provides those primitives for the
//! protocol layers ([`crate::agentft`], [`crate::coreft`],
//! [`crate::checkpoint`]) running on the DES.

pub mod faults;
pub mod link;
pub mod message;
pub mod topology;

pub use faults::{
    CutSet, Delivery, FaultPlane, LinkClass, LinkFaults, NetCost, Partition, RetryPolicy,
    FAULT_SALT,
};
pub use link::LinkParams;
pub use message::{Message, MsgKind};
pub use topology::{NodeId, Topology};
