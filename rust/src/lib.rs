//! # biomaft — Multi-agent fault tolerance for HPC computational biology jobs
//!
//! A reproduction of Varghese, McKee & Alexandrov, *"Automating Fault
//! Tolerance in High-Performance Computational Biological Jobs Using
//! Multi-Agent Approaches"* (Computers in Biology and Medicine, 2014).
//!
//! The crate is organised as the L3 coordinator of a three-layer stack:
//!
//! * **L3 (this crate)** — the paper's coordination contribution: mobile-agent
//!   fault tolerance ([`agentft`]), virtual-core fault tolerance ([`coreft`]),
//!   the hybrid approach ([`hybrid`]), checkpointing baselines
//!   ([`checkpoint`]), all running over a deterministic discrete-event
//!   cluster simulator ([`sim`], [`net`], [`cluster`], [`failure`]) via the
//!   generic scenario runtime ([`sim::harness`](sim::harness)) and the
//!   multi-failure scenario layer + parallel batch runner ([`scenario`]).
//! * **L2/L1 (python, build-time only)** — the genome-search and parallel
//!   reduction compute graphs (JAX + Pallas), AOT-lowered to HLO text and
//!   executed from [`runtime`] via the PJRT CPU client. Python never runs on
//!   the request path.
//!
//! # Module map
//!
//! Each module's rustdoc carries the detail; the corresponding DESIGN.md
//! section holds the design rationale.
//!
//! | Module | What it is | DESIGN.md |
//! |---|---|---|
//! | [`sim`] | DES engine, RNG, generic scenario runtime | §Simulation core, §Hot path |
//! | [`agentft`] / [`coreft`] / [`hybrid`] | the paper's three approaches (Figs. 3, 5, 6) | §Protocols |
//! | [`checkpoint`] | checkpointing baselines + cold restart | §Protocols |
//! | [`failure`] | probing, prediction, hardware states, injector | §Protocols |
//! | [`net`] / [`cluster`] / [`job`] | landscape, presets + calibrated costs, workloads | §Protocols |
//! | [`coordinator`] | accounting runs, live full-system simulation, configs | §Scenario layer, §Coordination & experiments |
//! | [`scenario`] | multi-failure regimes, batch runner, fused sweep executor, **fleet simulator** | §Scenario layer, §Sweep executor, §Fleet simulator |
//! | [`metrics`] | summaries, streaming accumulator (incl. time-weighted mode), tables, series | §Sweep executor |
//! | [`experiments`] | the registry: one runner per table/figure/extension | §Coordination & experiments |
//! | [`genome`] | synthetic genomes + packed multi-pattern search engine | §Genome search engine |
//! | [`runtime`] | PJRT client, artifact manifest, worker pool (pure-Rust fallback) | §Runtime |
//! | [`bench`] / [`testkit`] / [`util`] | in-crate bench harness, test helpers, CLI/conf/fmt | — |
//!
//! # Determinism
//!
//! Every stochastic draw flows through [`sim::rng::Rng`], every simulated
//! story through the DES — a seed fully determines an experiment, batches
//! and sweeps are byte-identical at any thread count, and a fleet trial is
//! a pure function of `(spec, seed)`. The full guarantee table lives in
//! DESIGN.md §Determinism inventory.
//!
//! See `README.md` for the quickstart, `DESIGN.md` for the system
//! inventory, `EXPERIMENTS.md` for the experiment index (kept in lock-step
//! with [`experiments::registry`] by `tests/doc_sync.rs`) and `ROADMAP.md`
//! for direction.

pub mod agentft;
pub mod bench;
pub mod checkpoint;
pub mod cluster;
pub mod coordinator;
pub mod coreft;
pub mod experiments;
pub mod failure;
pub mod genome;
pub mod hybrid;
pub mod job;
pub mod metrics;
pub mod net;
pub mod runtime;
pub mod scenario;
pub mod sim;
pub mod testkit;
pub mod util;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
