//! # biomaft — Multi-agent fault tolerance for HPC computational biology jobs
//!
//! A reproduction of Varghese, McKee & Alexandrov, *"Automating Fault
//! Tolerance in High-Performance Computational Biological Jobs Using
//! Multi-Agent Approaches"* (Computers in Biology and Medicine, 2014).
//!
//! The crate is organised as the L3 coordinator of a three-layer stack:
//!
//! * **L3 (this crate)** — the paper's coordination contribution: mobile-agent
//!   fault tolerance ([`agentft`]), virtual-core fault tolerance ([`coreft`]),
//!   the hybrid approach ([`hybrid`]), checkpointing baselines
//!   ([`checkpoint`]), all running over a deterministic discrete-event
//!   cluster simulator ([`sim`], [`net`], [`cluster`], [`failure`]) via the
//!   generic scenario runtime ([`sim::harness`](sim::harness)) and the
//!   multi-failure scenario layer + parallel batch runner ([`scenario`]).
//! * **L2/L1 (python, build-time only)** — the genome-search and parallel
//!   reduction compute graphs (JAX + Pallas), AOT-lowered to HLO text and
//!   executed from [`runtime`] via the PJRT CPU client. Python never runs on
//!   the request path.
//!
//! See `DESIGN.md` for the full system inventory and experiment index.

pub mod agentft;
pub mod bench;
pub mod checkpoint;
pub mod cluster;
pub mod coordinator;
pub mod coreft;
pub mod experiments;
pub mod failure;
pub mod genome;
pub mod hybrid;
pub mod job;
pub mod metrics;
pub mod net;
pub mod runtime;
pub mod scenario;
pub mod sim;
pub mod testkit;
pub mod util;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
