//! The virtual core: a logical core bound to a hardware core, able to
//! migrate the job object it hosts.

use crate::net::message::SubJobId;
use crate::net::NodeId;

/// Lifecycle of a virtual core.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum VCoreState {
    /// Bound to its hardware core, executing its sub-job.
    Bound,
    /// Migrating its sub-job to the embedded target virtual core.
    Migrating { to: NodeId },
    /// Its sub-job finished.
    Drained,
    /// The hardware core failed under it before migration completed.
    Dead,
}

/// A virtual core hosting at most one sub-job.
#[derive(Debug, Clone)]
pub struct VCore {
    /// The hardware node this virtual core is currently mapped onto.
    pub hw: NodeId,
    pub sub_job: Option<SubJobId>,
    pub state: VCoreState,
    /// Dependency table maintained by the runtime — re-bound automatically
    /// on migration (difference (iv) in the paper's comparison).
    pub dep_table: Vec<SubJobId>,
    pub migrations: usize,
}

impl VCore {
    pub fn new(hw: NodeId, sub_job: SubJobId, deps: Vec<SubJobId>) -> Self {
        Self { hw, sub_job: Some(sub_job), state: VCoreState::Bound, dep_table: deps, migrations: 0 }
    }

    pub fn z(&self) -> usize {
        self.dep_table.len()
    }

    pub fn start_migration(&mut self, to: NodeId) {
        debug_assert!(matches!(self.state, VCoreState::Bound));
        self.state = VCoreState::Migrating { to };
    }

    /// Complete migration: the virtual core is re-bound onto the target
    /// hardware core; the dependency table survives untouched.
    pub fn finish_migration(&mut self) {
        if let VCoreState::Migrating { to } = self.state {
            self.hw = to;
            self.state = VCoreState::Bound;
            self.migrations += 1;
        } else {
            panic!("finish_migration while not migrating");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn migration_preserves_dep_table() {
        let deps = vec![SubJobId(1), SubJobId(2)];
        let mut v = VCore::new(NodeId(0), SubJobId(9), deps.clone());
        v.start_migration(NodeId(3));
        v.finish_migration();
        assert_eq!(v.hw, NodeId(3));
        assert_eq!(v.dep_table, deps);
        assert_eq!(v.migrations, 1);
        assert_eq!(v.state, VCoreState::Bound);
    }

    #[test]
    fn z_counts_table() {
        let v = VCore::new(NodeId(0), SubJobId(0), vec![SubJobId(1); 5]);
        assert_eq!(v.z(), 5);
    }

    #[test]
    #[should_panic]
    fn finish_without_start_panics() {
        VCore::new(NodeId(0), SubJobId(0), vec![]).finish_migration();
    }
}
