//! The Fig. 5 core-intelligence episode as a discrete-event simulation.
//!
//! Sequence: the hardware probing process on `C_PF` notifies the virtual
//! core; predictions are gathered from adjacent probing processes; the job
//! object migrates to the chosen adjacent virtual core; the runtime updates
//! the dependency tables (automatic re-binding — no per-dependency
//! handshake by the job itself, but the runtime's rebind rounds still cost
//! time and diverge across clusters beyond the window, Fig. 9).
//!
//! Like the agent episode, this runs on the [`sim::harness`] scenario
//! runtime with its randomness pre-sampled into [`EpisodeDraws`], so trials
//! draw serially but execute deterministically (and therefore in parallel).
//!
//! [`sim::harness`]: crate::sim::harness

use crate::agentft::migration::{draw_episode, EpisodeDraws, StepTrace};
use crate::cluster::spec::{size_log_factor, CoreCosts};
use crate::net::faults::FaultPlane;
use crate::net::message::SubJobId;
use crate::net::{LinkClass, MsgKind, NetCost, NodeId};
use crate::sim::{Ctx, Harness, Rng, Scenario, SimTime, TrialScratch};

/// Result of a core-intelligence migration episode.
#[derive(Debug, Clone)]
pub struct CoreMigrationOutcome {
    /// Total time to reinstate execution (the paper's ΔT_C2).
    pub reinstate_s: f64,
    pub target: NodeId,
    pub steps: Vec<StepTrace>,
}

#[derive(Debug, Clone)]
enum Ep {
    PredictionNotified,
    PredictionsGathered,
    ObjectMigrated,
    RebindDone { _idx: usize },
}

struct EpisodeActor<'a> {
    costs: CoreCosts,
    z: usize,
    data_kb: u64,
    proc_kb: u64,
    /// Borrowed from the trial's [`EpisodeDraws`] — no per-episode clone.
    jitter: &'a [f64],
    rebinds_done: usize,
}

impl EpisodeActor<'_> {
    fn data_term_s(&self) -> f64 {
        let u = size_log_factor(self.data_kb);
        let over = (u - self.costs.data_overflow_threshold).max(0.0);
        self.costs.data_log_coef_s * u
            + self.costs.data_overflow_coef_s * over
            + self.costs.proc_log_coef_s * size_log_factor(self.proc_kb)
    }
}

impl Scenario for EpisodeActor<'_> {
    type Msg = Ep;

    fn on_msg(&mut self, ctx: &mut Ctx<'_, '_, Ep>, msg: Ep) {
        match msg {
            Ep::PredictionNotified => {
                let dur = self.costs.probe_gather_s * self.jitter[0];
                ctx.record("gather_predictions", dur);
                ctx.send_self_in_s(dur, Ep::PredictionsGathered);
            }
            // Object migration: serialization machinery setup plus the
            // handle/segment registration for data + process image.
            Ep::PredictionsGathered => {
                let dur = (self.costs.migrate_setup_s + self.data_term_s()) * self.jitter[1];
                ctx.record("migrate_object", dur);
                ctx.send_self_in_s(dur, Ep::ObjectMigrated);
            }
            // Runtime dependency-table rebind rounds: windowed like the
            // agent handshakes but owned by the runtime, with a
            // cluster-specific overlap tail (Fig. 9 divergence).
            Ep::ObjectMigrated => {
                if self.z == 0 {
                    ctx.finish();
                    return;
                }
                let j = self.jitter[2];
                for i in 0..self.z {
                    let within = (i + 1).min(self.costs.rebind_window) as f64;
                    let beyond = (i + 1).saturating_sub(self.costs.rebind_window) as f64;
                    let off = self.costs.rebind_round_s * (within + self.costs.rebind_tail * beyond);
                    ctx.send_self_in_s(off * j, Ep::RebindDone { _idx: i });
                }
                ctx.record("rebind_phase", self.costs.rebind_phase_s(self.z) * j);
            }
            Ep::RebindDone { .. } => {
                self.rebinds_done += 1;
                if self.rebinds_done == self.z {
                    ctx.finish();
                }
            }
        }
    }
}

/// Number of jittered steps in the core episode (Fig. 5).
pub const CORE_JITTERS: usize = 3;

/// Total network cost of the Fig. 5 message sequence under a fault plane:
/// the `MigrateObject`/`MigrateAck` payload exchange (data + handle
/// registration, priced at the object's wire size) followed by the
/// runtime's `RebindRound` control exchange. Same contract as
/// [`crate::agentft::migration::sequence_net_cost`]: per-phase
/// timeout/retry/backoff from the plane's shared
/// [`crate::net::RetryPolicy`], conjunctive delivery with early abort, and
/// draws only from the salted side-stream so episode jitters never shift.
pub fn sequence_net_cost(
    faults: &FaultPlane,
    seed: u64,
    edge_key: u64,
    seq: &mut u64,
    cut: bool,
    data_kb: u64,
) -> NetCost {
    let phases = [
        MsgKind::MigrateObject { sub_job: SubJobId(0), bytes: data_kb * 1024 }.wire_bytes(),
        MsgKind::RebindRound { remaining: 0 }.wire_bytes(),
    ];
    let mut total = NetCost::CLEAN;
    for bytes in phases {
        let c = faults.exchange(LinkClass::Peer, seed, edge_key, seq, cut, bytes);
        let failed = !c.delivered;
        total.absorb(c);
        if failed {
            break;
        }
    }
    total
}

/// Reusable engine allocations for core episodes; batch workers thread
/// one through consecutive trials (reuse never changes a result).
pub struct EpisodeScratch(TrialScratch<Ep>);

impl EpisodeScratch {
    pub fn new() -> Self {
        Self(TrialScratch::new())
    }
}

impl Default for EpisodeScratch {
    fn default() -> Self {
        Self::new()
    }
}

/// Run one core-intelligence migration episode from pre-sampled draws.
/// Fully deterministic: same draws ⇒ same outcome, on any thread.
pub fn simulate_core_migration_drawn(
    costs: &CoreCosts,
    z: usize,
    data_kb: u64,
    proc_kb: u64,
    draws: &EpisodeDraws,
) -> CoreMigrationOutcome {
    let mut scratch = EpisodeScratch::new();
    simulate_core_migration_drawn_scratch(costs, z, data_kb, proc_kb, draws, &mut scratch)
}

/// [`simulate_core_migration_drawn`] on recycled engine allocations.
pub fn simulate_core_migration_drawn_scratch(
    costs: &CoreCosts,
    z: usize,
    data_kb: u64,
    proc_kb: u64,
    draws: &EpisodeDraws,
    scratch: &mut EpisodeScratch,
) -> CoreMigrationOutcome {
    assert!(draws.jitter.len() >= CORE_JITTERS, "core episode needs {CORE_JITTERS} jitters");
    let mut h = Harness::from_scratch(Rng::new(0), std::mem::take(&mut scratch.0));
    let id = h.add(EpisodeActor {
        costs: *costs,
        z,
        data_kb,
        proc_kb,
        jitter: &draws.jitter,
        rebinds_done: 0,
    });
    h.schedule(SimTime::ZERO, id, Ep::PredictionNotified);
    let (fin, sim) = h.run_until_reclaim(SimTime(u64::MAX));
    scratch.0 = sim;
    CoreMigrationOutcome {
        reinstate_s: fin.finished_at.expect("episode did not finish").as_secs(),
        target: draws.target,
        steps: fin.trace,
    }
}

/// Run one core-intelligence migration episode (Fig. 5).
pub fn simulate_core_migration(
    costs: &CoreCosts,
    z: usize,
    data_kb: u64,
    proc_kb: u64,
    adjacent: &[(NodeId, bool)],
    rng: &mut Rng,
    noise_sigma: f64,
) -> Option<CoreMigrationOutcome> {
    let draws = draw_episode(CORE_JITTERS, adjacent, rng, noise_sigma)?;
    Some(simulate_core_migration_drawn(costs, z, data_kb, proc_kb, &draws))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{preset, ClusterPreset};

    fn adj(n: usize) -> Vec<(NodeId, bool)> {
        (0..n).map(|i| (NodeId(i + 200), false)).collect()
    }

    #[test]
    fn off_plane_sequence_is_clean() {
        let p = FaultPlane::default();
        let mut seq = 0;
        let c = sequence_net_cost(&p, 3, 17, &mut seq, false, 1 << 19);
        assert_eq!(c, NetCost::CLEAN);
        assert_eq!(seq, 4, "two phases consume two draws each");
    }

    #[test]
    fn certain_loss_never_delivers_and_is_bounded() {
        use crate::net::LinkFaults;
        let p = FaultPlane {
            peer: LinkFaults { loss_p: 1.0, ..LinkFaults::off() },
            ..FaultPlane::default()
        };
        let mut seq = 0;
        let c = sequence_net_cost(&p, 3, 17, &mut seq, false, 1 << 19);
        assert!(!c.delivered);
        let attempts = p.retry.max_retries as u64 + 1;
        assert_eq!(c.timeouts, attempts, "phase two must never start");
        assert_eq!(c.retries, attempts - 1, "retries are bounded by the policy");
        assert_eq!(seq, 2 * attempts);
    }

    #[test]
    fn sequence_cost_is_pure_in_its_key() {
        use crate::net::LinkFaults;
        let p = FaultPlane {
            peer: LinkFaults { loss_p: 0.3, dup_p: 0.3, delay_p: 0.3, delay_mean_s: 0.1 },
            ..FaultPlane::default()
        };
        let (mut s1, mut s2) = (0u64, 0u64);
        let a = sequence_net_cost(&p, 5, 31, &mut s1, false, 1 << 25);
        let b = sequence_net_cost(&p, 5, 31, &mut s2, false, 1 << 25);
        assert_eq!(a, b);
        assert_eq!(s1, s2);
    }

    #[test]
    fn deterministic_episode_matches_closed_form() {
        let mut rng = Rng::new(1);
        for p in ClusterPreset::all() {
            let costs = preset(p).costs.core;
            for z in [1usize, 4, 10, 40] {
                for kb in [1u64 << 19, 1 << 25, 1 << 31] {
                    let out = simulate_core_migration(&costs, z, kb, kb, &adj(4), &mut rng, 0.0)
                        .unwrap();
                    let want = costs.reinstate_s(z, kb, kb);
                    assert!(
                        (out.reinstate_s - want).abs() < 1e-9,
                        "{p:?} z={z} kb={kb}: {} vs {want}",
                        out.reinstate_s
                    );
                }
            }
        }
    }

    #[test]
    fn steps_follow_fig5_order() {
        let costs = preset(ClusterPreset::Glooscap).costs.core;
        let mut rng = Rng::new(2);
        let out =
            simulate_core_migration(&costs, 6, 1 << 20, 1 << 20, &adj(3), &mut rng, 0.0).unwrap();
        let names: Vec<_> = out.steps.iter().map(|s| s.step).collect();
        assert_eq!(names, vec!["gather_predictions", "migrate_object", "rebind_phase"]);
    }

    #[test]
    fn core_beats_agent_at_genome_anchor() {
        // Z = 4, S_d = 2^19 KB on Placentia: core 0.38 s vs agent 0.47 s.
        let costs = preset(ClusterPreset::Placentia).costs;
        let mut rng = Rng::new(3);
        let core = simulate_core_migration(&costs.core, 4, 1 << 19, 1 << 19, &adj(3), &mut rng, 0.0)
            .unwrap();
        let agent = crate::agentft::simulate_agent_migration(
            &costs.agent,
            4,
            1 << 19,
            1 << 19,
            &adj(3),
            &mut rng,
            0.0,
        )
        .unwrap();
        assert!((core.reinstate_s - 0.38).abs() < 0.01, "{}", core.reinstate_s);
        assert!((agent.reinstate_s - 0.47).abs() < 0.01, "{}", agent.reinstate_s);
        assert!(core.reinstate_s < agent.reinstate_s);
    }

    #[test]
    fn all_doomed_returns_none() {
        let costs = preset(ClusterPreset::Placentia).costs.core;
        let mut rng = Rng::new(4);
        let adjacent = vec![(NodeId(1), true)];
        assert!(simulate_core_migration(&costs, 3, 1, 1, &adjacent, &mut rng, 0.0).is_none());
    }

    #[test]
    fn zero_deps_finishes() {
        let costs = preset(ClusterPreset::Brasdor).costs.core;
        let mut rng = Rng::new(5);
        let out = simulate_core_migration(&costs, 0, 1, 1, &adj(1), &mut rng, 0.0).unwrap();
        assert!(out.reinstate_s > 0.0);
        assert_eq!(out.steps.len(), 2);
    }

    #[test]
    fn drawn_episode_equals_inline_episode() {
        let costs = preset(ClusterPreset::Acet).costs.core;
        let inline = {
            let mut rng = Rng::new(31);
            simulate_core_migration(&costs, 12, 1 << 25, 1 << 20, &adj(4), &mut rng, 0.03).unwrap()
        };
        let split = {
            let mut rng = Rng::new(31);
            let d = draw_episode(CORE_JITTERS, &adj(4), &mut rng, 0.03).unwrap();
            simulate_core_migration_drawn(&costs, 12, 1 << 25, 1 << 20, &d)
        };
        assert_eq!(inline.reinstate_s, split.reinstate_s);
        assert_eq!(inline.target, split.target);
        assert_eq!(inline.steps, split.steps);
    }
}
