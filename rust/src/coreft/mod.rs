//! Approach 2 — fault tolerance incorporating **core intelligence**.
//!
//! Sub-jobs sit on *virtual cores*, an abstraction over the hardware cores
//! (the paper implements this on AMPI/Charm++ object migration). The
//! virtual core probes its hardware core and, on a predicted failure,
//! executes the Fig. 5 sequence: gather adjacent predictions, migrate the
//! job object to an adjacent virtual core, and let the runtime re-bind
//! dependencies automatically.

pub mod migration;
pub mod vcore;

pub use migration::{
    simulate_core_migration, simulate_core_migration_drawn,
    simulate_core_migration_drawn_scratch, CoreMigrationOutcome,
};
pub use vcore::{VCore, VCoreState};
