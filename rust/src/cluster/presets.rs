//! The four cluster presets of the paper's platform section, with their
//! calibrated cost models.
//!
//! | Cluster   | Nodes | Cores | Interconnect | Character                     |
//! |-----------|-------|-------|--------------|-------------------------------|
//! | ACET      | 33    | 33    | GigE         | P-IV, slowest, small NIC bufs |
//! | Brasdor   | 306   | 932   | GigE         | mid                           |
//! | Glooscap  | 97    | 852   | InfiniBand   | fast                          |
//! | Placentia | 338   | 3740  | InfiniBand   | fastest (validation cluster)  |
//!
//! Placentia carries the reference calibration (see `spec.rs`); the other
//! clusters scale it with the multipliers below, chosen so the cross-cluster
//! orderings of Figs. 8-13 hold (asserted by experiment tests).

use super::spec::*;
use crate::net::LinkParams;

/// Enumerates the available presets.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ClusterPreset {
    Acet,
    Brasdor,
    Glooscap,
    Placentia,
}

impl ClusterPreset {
    pub fn all() -> [ClusterPreset; 4] {
        [Self::Acet, Self::Brasdor, Self::Glooscap, Self::Placentia]
    }

    pub fn name(self) -> &'static str {
        match self {
            Self::Acet => "acet",
            Self::Brasdor => "brasdor",
            Self::Glooscap => "glooscap",
            Self::Placentia => "placentia",
        }
    }

    pub fn from_name(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "acet" => Some(Self::Acet),
            "brasdor" => Some(Self::Brasdor),
            "glooscap" => Some(Self::Glooscap),
            "placentia" => Some(Self::Placentia),
            _ => None,
        }
    }
}

/// Names accepted by `preset` / the CLI.
pub fn preset_names() -> &'static [&'static str] {
    &["acet", "brasdor", "glooscap", "placentia"]
}

struct Mults {
    agent_base: f64,
    agent_slope: f64,
    data: f64,
    core_base: f64,
    core_slope: f64,
    core_beta: f64,
    congestion_threshold: usize,
    congestion_s: f64,
    core_overflow_coef: f64,
    overhead: f64,
}

fn mults(p: ClusterPreset) -> Mults {
    match p {
        // Pentium-IV nodes on GigE with shallow NIC queues: slowest overall,
        // visible congestion knee after Z≈25 (Fig. 8) and a storage-path
        // penalty for very large data (Fig. 11, n > 24).
        ClusterPreset::Acet => Mults {
            agent_base: 1.30,
            agent_slope: 1.8,
            data: 1.8,
            core_base: 1.10,
            core_slope: 1.05,
            core_beta: 0.30,
            congestion_threshold: 25,
            congestion_s: 0.006,
            core_overflow_coef: 0.012,
            overhead: 1.35,
        },
        ClusterPreset::Brasdor => Mults {
            agent_base: 1.15,
            agent_slope: 1.45,
            data: 1.4,
            core_base: 1.06,
            core_slope: 1.03,
            core_beta: 0.20,
            congestion_threshold: usize::MAX,
            congestion_s: 0.0,
            core_overflow_coef: 0.0,
            overhead: 1.2,
        },
        ClusterPreset::Glooscap => Mults {
            agent_base: 1.04,
            agent_slope: 1.12,
            data: 1.1,
            core_base: 1.02,
            core_slope: 1.01,
            core_beta: 0.06,
            congestion_threshold: usize::MAX,
            congestion_s: 0.0,
            core_overflow_coef: 0.0,
            overhead: 1.05,
        },
        ClusterPreset::Placentia => Mults {
            agent_base: 1.0,
            agent_slope: 1.0,
            data: 1.0,
            core_base: 1.0,
            core_slope: 1.0,
            core_beta: 0.02,
            congestion_threshold: usize::MAX,
            congestion_s: 0.0,
            core_overflow_coef: 0.0,
            overhead: 1.0,
        },
    }
}

/// Build a cluster spec from a preset.
pub fn preset(p: ClusterPreset) -> ClusterSpec {
    let m = mults(p);
    // Reference (Placentia) agent calibration: base 0.45 = 0.05 + 0.28 + 0.12,
    // slope 0.004/dep (window 10, tail 0.15), data+proc 0.002/u each.
    let agent = AgentCosts {
        probe_gather_s: 0.05 * m.agent_base,
        spawn_s: 0.28 * m.agent_base,
        layer_s: 0.12 * m.agent_base,
        dep_handshake_s: 0.004 * m.agent_slope,
        dep_window: 10,
        dep_tail: 0.15,
        congestion_threshold: m.congestion_threshold,
        congestion_s: m.congestion_s,
        data_log_coef_s: 0.002 * m.data,
        proc_log_coef_s: 0.002 * m.data,
    };
    // Reference core calibration: base 0.2944 = 0.05 + 0.2444, rebind round
    // 0.021/dep (window 10), data+proc 0.0008/u each.
    let core = CoreCosts {
        probe_gather_s: 0.05 * m.core_base,
        migrate_setup_s: 0.2444 * m.core_base,
        rebind_round_s: 0.021 * m.core_slope,
        rebind_window: 10,
        rebind_tail: m.core_beta,
        data_log_coef_s: 0.0008 * m.data,
        proc_log_coef_s: 0.0008 * m.data,
        data_overflow_threshold: 6.0,
        data_overflow_coef_s: m.core_overflow_coef,
    };
    // Overheads per failure: agent 108 + 3·Z + S_d/2.7 MBps ≈ 5:14 at the
    // genome anchor; core 90 + 2·Z + S_d/3.0 MBps ≈ 4:27.
    let agent_overhead = AgentOverheadCosts {
        base_s: 108.0 * m.overhead,
        per_dep_s: 3.0 * m.overhead,
        restage_bw_bps: 2.7e6 / m.overhead,
    };
    let core_overhead = AgentOverheadCosts {
        base_s: 90.0 * m.overhead,
        per_dep_s: 2.0 * m.overhead,
        restage_bw_bps: 3.05e6 / m.overhead,
    };
    // Checkpointing (Table 1 anchors, shared-storage effective bandwidths):
    // reinstate_single = 30 + 2 GiB / 2.684 MB/s + 18 ≈ 848 s (00:14:08)
    // overhead_single  = 60 + 2 GiB / 5.05 MB/s       ≈ 485 s (00:08:05)
    let ckpt = CheckpointCosts {
        detect_s: 30.0,
        resync_s: 18.0,
        restore_bw_bps: 2.684e6,
        ckpt_bw_bps: 5.052e6,
        coord_single_s: 60.0,
        coord_multi_s: 75.0,
        coord_decentral_s: 45.0,
        multi_write_factor: 1.127,
        decentral_bw_factor: 1.184,
        discovery_s: 79.0,
        cold_restart_admin_s: 600.0,
    };
    let predict = PredictCosts { predict_time_s: 38.0, coverage: 0.29, precision: 0.64 };
    let (name, n_nodes, total_cores, ram_min, ram_max, link) = match p {
        ClusterPreset::Acet => ("acet", 33, 33, 512, 2048, LinkParams::gige()),
        ClusterPreset::Brasdor => ("brasdor", 306, 932, 1024, 2048, LinkParams::gige()),
        ClusterPreset::Glooscap => ("glooscap", 97, 852, 1024, 8192, LinkParams::infiniband()),
        ClusterPreset::Placentia => ("placentia", 338, 3740, 2048, 16384, LinkParams::infiniband()),
    };
    ClusterSpec {
        name,
        n_nodes,
        total_cores,
        ram_mib_min: ram_min,
        ram_mib_max: ram_max,
        link,
        costs: FtCosts {
            agent,
            core,
            agent_overhead,
            core_overhead,
            ckpt,
            predict,
            noise_sigma: 0.025,
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const KB19: u64 = 1 << 19;
    const KB24: u64 = 1 << 24;

    #[test]
    fn names_roundtrip() {
        for p in ClusterPreset::all() {
            assert_eq!(ClusterPreset::from_name(p.name()), Some(p));
        }
        assert_eq!(ClusterPreset::from_name("PLACENTIA"), Some(ClusterPreset::Placentia));
        assert!(ClusterPreset::from_name("nope").is_none());
    }

    #[test]
    fn genome_anchor_placentia() {
        let c = preset(ClusterPreset::Placentia).costs;
        let a = c.agent.reinstate_s(4, KB19, KB19);
        let k = c.core.reinstate_s(4, KB19, KB19);
        assert!((a - 0.47).abs() < 0.01, "agent reinstate {a}");
        assert!((k - 0.38).abs() < 0.01, "core reinstate {k}");
    }

    #[test]
    fn rule1_core_wins_small_z_at_2p24() {
        let c = preset(ClusterPreset::Placentia).costs;
        for z in [3, 5, 8, 10] {
            let a = c.agent.reinstate_s(z, KB24, KB24);
            let k = c.core.reinstate_s(z, KB24, KB24);
            assert!(k <= a + 1e-9, "z={z}: core {k} vs agent {a}");
        }
    }

    #[test]
    fn rule2_agent_wins_small_data_at_z10() {
        let c = preset(ClusterPreset::Placentia).costs;
        for kb in [1u64 << 19, 1 << 20, 1 << 22, 1 << 23] {
            let a = c.agent.reinstate_s(10, kb, kb);
            let k = c.core.reinstate_s(10, kb, kb);
            assert!(a <= k + 1e-9, "kb=2^{}: agent {a} vs core {k}", (kb as f64).log2());
        }
    }

    #[test]
    fn boundary_equality_z10_2p24() {
        let c = preset(ClusterPreset::Placentia).costs;
        let a = c.agent.reinstate_s(10, KB24, KB24);
        let k = c.core.reinstate_s(10, KB24, KB24);
        assert!((a - k).abs() < 0.02, "agent {a} core {k}");
    }

    #[test]
    fn fig8_bounds() {
        let c = preset(ClusterPreset::Placentia).costs;
        for z in [3, 10, 25, 50, 63] {
            let a = c.agent.reinstate_s(z, KB24, KB24);
            assert!(a < 0.56, "z={z}: {a}");
        }
        // over 50 dependencies: < 0.55 s (paper, Decision Making Rules)
        assert!(c.agent.reinstate_s(55, KB24, KB24) < 0.55);
    }

    #[test]
    fn acet_slowest_placentia_fastest_agent() {
        for z in [3, 10, 30, 63] {
            let times: Vec<f64> = ClusterPreset::all()
                .iter()
                .map(|&p| preset(p).costs.agent.reinstate_s(z, KB24, KB24))
                .collect();
            // order: acet, brasdor, glooscap, placentia
            assert!(times[0] > times[1], "z={z} {times:?}");
            assert!(times[1] > times[2], "z={z} {times:?}");
            assert!(times[2] > times[3], "z={z} {times:?}");
        }
    }

    #[test]
    fn core_similar_across_clusters_until_z10_then_diverges() {
        let at = |z: usize| -> Vec<f64> {
            ClusterPreset::all()
                .iter()
                .map(|&p| preset(p).costs.core.reinstate_s(z, KB24, KB24))
                .collect()
        };
        let z5 = at(5);
        let spread5 = z5.iter().cloned().fold(f64::MIN, f64::max)
            - z5.iter().cloned().fold(f64::MAX, f64::min);
        assert!(spread5 < 0.06, "spread at z=5: {spread5} {z5:?}");
        let z40 = at(40);
        let spread40 = z40.iter().cloned().fold(f64::MIN, f64::max)
            - z40.iter().cloned().fold(f64::MAX, f64::min);
        assert!(spread40 > 2.0 * spread5, "z40 {z40:?} z5 {z5:?}");
    }

    #[test]
    fn acet_congestion_knee_after_25() {
        let a = preset(ClusterPreset::Acet).costs.agent;
        let before = a.reinstate_s(25, KB24, KB24) - a.reinstate_s(20, KB24, KB24);
        let after = a.reinstate_s(35, KB24, KB24) - a.reinstate_s(30, KB24, KB24);
        assert!(after > 2.0 * before, "before {before} after {after}");
    }

    #[test]
    fn acet_core_data_overflow_after_2p24() {
        let c = preset(ClusterPreset::Acet).costs.core;
        let p = preset(ClusterPreset::Placentia).costs.core;
        let below = c.reinstate_s(10, 1 << 22, 1 << 22) - p.reinstate_s(10, 1 << 22, 1 << 22);
        let above = c.reinstate_s(10, 1 << 28, 1 << 28) - p.reinstate_s(10, 1 << 28, 1 << 28);
        assert!(above > below + 0.03, "below {below} above {above}");
    }

    #[test]
    fn checkpoint_anchor_times() {
        let c = preset(ClusterPreset::Placentia).costs.ckpt;
        let total_bytes = 4.0 * (1u64 << 19) as f64 * 1024.0; // 4 nodes x 512 MiB
        let reinstate = c.detect_s + total_bytes / c.restore_bw_bps + c.resync_s;
        assert!((reinstate - 848.0).abs() < 5.0, "reinstate {reinstate}"); // 00:14:08
        let overhead = c.coord_single_s + total_bytes / c.ckpt_bw_bps;
        assert!((overhead - 485.0).abs() < 5.0, "overhead {overhead}"); // 00:08:05
    }

    #[test]
    fn platform_facts_match_paper() {
        let p = preset(ClusterPreset::Placentia);
        assert_eq!(p.n_nodes, 338);
        assert_eq!(p.total_cores, 3740);
        let b = preset(ClusterPreset::Brasdor);
        assert_eq!(b.n_nodes, 306);
        assert_eq!(b.total_cores, 932);
        let g = preset(ClusterPreset::Glooscap);
        assert_eq!(g.n_nodes, 97);
        let a = preset(ClusterPreset::Acet);
        assert_eq!(a.n_nodes, 33);
    }

    #[test]
    fn prediction_quality_constants() {
        let c = preset(ClusterPreset::Placentia).costs.predict;
        assert_eq!(c.coverage, 0.29);
        assert_eq!(c.precision, 0.64);
        assert_eq!(c.predict_time_s, 38.0);
    }
}
