//! Cluster specification + calibrated fault-tolerance cost parameters.
//!
//! ## Calibration
//!
//! The paper measures *time to reinstate execution* after a predicted
//! failure. Those times are sub-second even for multi-terabyte `S_d`
//! (Figs. 10-13), which tells us reinstatement moves *handles and
//! metadata*, not payload bytes (payload re-staging happens in the
//! background and is accounted in the paper's separate "overhead" column).
//! We therefore model the data/process-size contribution as logarithmic
//! (`u(S) = max(log2(S_KB) - 18, 0)`, i.e. zero below 2^18 KB) — the number
//! of segment/handle registrations grows with log of size.
//!
//! Constants below are calibrated (on the Placentia preset) to the paper's
//! anchors, and the calibration is enforced by tests in
//! `experiments::rules_validation`:
//!
//! * agent reinstate ≈ 0.47 s and core ≈ 0.38 s at `Z = 4, S_d = 2^19 KB`
//!   (genome experiment, Results);
//! * core beats agent for `Z <= 10` at `S_d = 2^24 KB` (Rule 1 / Figs. 8-9);
//! * agent beats core for `S_d <= 2^24 KB` at `Z = 10` (Rule 2 / Figs. 10-11),
//!   with equality at the `(Z = 10, S_d = 2^24)` boundary;
//! * agent reinstate stays ≤ 0.56 s up to `Z = 63` (Fig. 8);
//! * ACET slowest / Placentia fastest for the agent approach, ACET re-rising
//!   after `Z ≈ 25` (NIC queue congestion), core times near-uniform across
//!   clusters until `Z = 10` then diverging (Figs. 8-9).
//!
//! Note: the paper's *narrative* says core-side dependency re-binding is
//! automatic and therefore cheap, yet its *data* (Rule 1 holding only up to
//! Z = 10, Fig. 9's divergence) show core time growing faster in Z than the
//! agent approach. We calibrate to the data and discuss the tension in
//! EXPERIMENTS.md.

use crate::net::LinkParams;

/// Log-scale size factor: `max(log2(kb) - 18, 0)`; zero below 2^18 KB.
pub fn size_log_factor(kb: u64) -> f64 {
    if kb == 0 {
        return 0.0;
    }
    ((kb as f64).log2() - 18.0).max(0.0)
}

/// Agent-intelligence (Approach 1) protocol step costs.
#[derive(Debug, Clone, Copy)]
pub struct AgentCosts {
    /// Gathering predictions from adjacent probing processes (parallel RTTs).
    pub probe_gather_s: f64,
    /// `MPI_COMM_SPAWN`-style replacement-process creation.
    pub spawn_s: f64,
    /// Fixed cost of the agent software layer (the paper's "virtualised
    /// layer in the communication stack").
    pub layer_s: f64,
    /// One dependency notify + re-establish handshake.
    pub dep_handshake_s: f64,
    /// Handshakes proceed in parallel windows of this size...
    pub dep_window: usize,
    /// ...and overlap beyond the window at this fractional cost.
    pub dep_tail: f64,
    /// NIC queue depth: beyond this many dependents, retransmissions kick in
    /// (`usize::MAX` = never; ACET's small buffers set 25).
    pub congestion_threshold: usize,
    /// Extra per-dependent cost past the congestion threshold.
    pub congestion_s: f64,
    /// Per-`u(S_d)` handle-registration cost for the carried data.
    pub data_log_coef_s: f64,
    /// Per-`u(S_p)` cost for the process image.
    pub proc_log_coef_s: f64,
}

impl AgentCosts {
    /// Effective dependency phase duration for `z` dependencies.
    pub fn dep_phase_s(&self, z: usize) -> f64 {
        let w = self.dep_window.min(z);
        let tail = z.saturating_sub(self.dep_window);
        let mut t = self.dep_handshake_s * (w as f64 + self.dep_tail * tail as f64);
        let over = z.saturating_sub(self.congestion_threshold);
        t += self.congestion_s * over as f64;
        t
    }

    /// Closed-form reinstate time (the DES protocol reproduces this sum
    /// step-by-step; equality is asserted in agentft tests).
    pub fn reinstate_s(&self, z: usize, data_kb: u64, proc_kb: u64) -> f64 {
        self.probe_gather_s
            + self.spawn_s
            + self.layer_s
            + self.dep_phase_s(z)
            + self.data_log_coef_s * size_log_factor(data_kb)
            + self.proc_log_coef_s * size_log_factor(proc_kb)
    }
}

/// Core-intelligence (Approach 2) protocol step costs.
#[derive(Debug, Clone, Copy)]
pub struct CoreCosts {
    pub probe_gather_s: f64,
    /// AMPI/Charm++-style object-migration machinery setup.
    pub migrate_setup_s: f64,
    /// One runtime dependency-table rebind round.
    pub rebind_round_s: f64,
    pub rebind_window: usize,
    /// Post-window overlap factor (the clusters diverge here: Fig. 9).
    pub rebind_tail: f64,
    pub data_log_coef_s: f64,
    pub proc_log_coef_s: f64,
    /// Extra data cost past this `u` threshold (ACET's slower storage path
    /// shows for n > 24 in Fig. 11).
    pub data_overflow_threshold: f64,
    pub data_overflow_coef_s: f64,
}

impl CoreCosts {
    pub fn rebind_phase_s(&self, z: usize) -> f64 {
        let w = self.rebind_window.min(z);
        let tail = z.saturating_sub(self.rebind_window);
        self.rebind_round_s * (w as f64 + self.rebind_tail * tail as f64)
    }

    fn data_term_s(&self, data_kb: u64) -> f64 {
        let u = size_log_factor(data_kb);
        let over = (u - self.data_overflow_threshold).max(0.0);
        self.data_log_coef_s * u + self.data_overflow_coef_s * over
    }

    pub fn reinstate_s(&self, z: usize, data_kb: u64, proc_kb: u64) -> f64 {
        self.probe_gather_s
            + self.migrate_setup_s
            + self.rebind_phase_s(z)
            + self.data_term_s(data_kb)
            + self.proc_log_coef_s * size_log_factor(proc_kb)
    }
}

/// Checkpointing baseline costs (shared-storage dominated — the point the
/// paper makes about checkpoint overheads).
#[derive(Debug, Clone, Copy)]
pub struct CheckpointCosts {
    /// Failure detection by the monitoring process.
    pub detect_s: f64,
    /// Post-restore barrier/resync across the job's nodes.
    pub resync_s: f64,
    /// Effective restore bandwidth from a checkpoint server (contended).
    pub restore_bw_bps: f64,
    /// Effective checkpoint-write bandwidth to a server (contended).
    pub ckpt_bw_bps: f64,
    /// Coordination time to open a checkpoint epoch (single server).
    pub coord_single_s: f64,
    pub coord_multi_s: f64,
    pub coord_decentral_s: f64,
    /// Multi-server replication write amplification.
    pub multi_write_factor: f64,
    /// Decentralised: nearest-server write speedup.
    pub decentral_bw_factor: f64,
    /// Decentralised restore: time to discover the server nearest the
    /// failed node.
    pub discovery_s: f64,
    /// Cold restart: human administrator reaction + resubmission.
    pub cold_restart_admin_s: f64,
}

/// Failure-prediction quality (Discussion: 29 % of faults predicted, 64 %
/// of predictions correct, ≈38 s from anomaly to positive prediction).
#[derive(Debug, Clone, Copy)]
pub struct PredictCosts {
    pub predict_time_s: f64,
    /// Fraction of real faults that are predicted (recall).
    pub coverage: f64,
    /// Fraction of predictions that are followed by a real fault.
    pub precision: f64,
}

/// Per-failure background overhead of the multi-agent approaches (probing,
/// relocation logistics, background data re-staging) — the paper's
/// "overheads related to one failure" column.
#[derive(Debug, Clone, Copy)]
pub struct AgentOverheadCosts {
    pub base_s: f64,
    pub per_dep_s: f64,
    /// Background re-staging of the sub-job's data.
    pub restage_bw_bps: f64,
}

impl AgentOverheadCosts {
    pub fn overhead_s(&self, z: usize, data_kb: u64) -> f64 {
        self.base_s + self.per_dep_s * z as f64 + (data_kb as f64 * 1024.0) / self.restage_bw_bps
    }
}

/// All calibrated FT costs of one cluster.
#[derive(Debug, Clone, Copy)]
pub struct FtCosts {
    pub agent: AgentCosts,
    pub core: CoreCosts,
    pub agent_overhead: AgentOverheadCosts,
    pub core_overhead: AgentOverheadCosts,
    pub ckpt: CheckpointCosts,
    pub predict: PredictCosts,
    /// Lognormal sigma of trial-to-trial measurement noise.
    pub noise_sigma: f64,
}

/// A cluster: platform facts (paper, Results §Platform) + cost model.
#[derive(Debug, Clone)]
pub struct ClusterSpec {
    pub name: &'static str,
    pub n_nodes: usize,
    pub total_cores: usize,
    pub ram_mib_min: u64,
    pub ram_mib_max: u64,
    pub link: LinkParams,
    pub costs: FtCosts,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn agent() -> AgentCosts {
        AgentCosts {
            probe_gather_s: 0.05,
            spawn_s: 0.28,
            layer_s: 0.12,
            dep_handshake_s: 0.004,
            dep_window: 10,
            dep_tail: 0.15,
            congestion_threshold: usize::MAX,
            congestion_s: 0.0,
            data_log_coef_s: 0.002,
            proc_log_coef_s: 0.002,
        }
    }

    #[test]
    fn size_log_factor_anchors() {
        assert_eq!(size_log_factor(0), 0.0);
        assert_eq!(size_log_factor(1), 0.0); // far below 2^18
        assert_eq!(size_log_factor(1 << 18), 0.0);
        assert_eq!(size_log_factor(1 << 19), 1.0);
        assert_eq!(size_log_factor(1 << 24), 6.0);
        assert_eq!(size_log_factor(1 << 31), 13.0);
    }

    #[test]
    fn dep_phase_saturates_at_window() {
        let a = agent();
        let t10 = a.dep_phase_s(10);
        let t11 = a.dep_phase_s(11);
        let t3 = a.dep_phase_s(3);
        // steep region below the window, shallow beyond
        assert!((t10 - 0.04).abs() < 1e-12);
        assert!((t11 - t10) < (t10 - t3) / 7.0 + 1e-12);
        assert!((t11 - t10 - 0.004 * 0.15).abs() < 1e-12);
    }

    #[test]
    fn congestion_kicks_in_past_threshold() {
        let mut a = agent();
        a.congestion_threshold = 25;
        a.congestion_s = 0.006;
        let below = a.dep_phase_s(25);
        let above = a.dep_phase_s(26);
        assert!((above - below - (0.004 * 0.15 + 0.006)).abs() < 1e-12);
    }

    #[test]
    fn agent_reinstate_monotone_in_everything() {
        let a = agent();
        assert!(a.reinstate_s(4, 1 << 19, 1 << 19) < a.reinstate_s(10, 1 << 19, 1 << 19));
        assert!(a.reinstate_s(4, 1 << 19, 1 << 19) < a.reinstate_s(4, 1 << 24, 1 << 19));
        assert!(a.reinstate_s(4, 1 << 19, 1 << 19) < a.reinstate_s(4, 1 << 19, 1 << 24));
    }

    #[test]
    fn core_overflow_term() {
        let c = CoreCosts {
            probe_gather_s: 0.05,
            migrate_setup_s: 0.24,
            rebind_round_s: 0.021,
            rebind_window: 10,
            rebind_tail: 0.02,
            data_log_coef_s: 0.0008,
            proc_log_coef_s: 0.0008,
            data_overflow_threshold: 6.0,
            data_overflow_coef_s: 0.01,
        };
        let at_thresh = c.reinstate_s(4, 1 << 24, 1 << 19);
        let above = c.reinstate_s(4, 1 << 25, 1 << 19);
        assert!((above - at_thresh - (0.0008 + 0.01)).abs() < 1e-9);
    }

    #[test]
    fn overhead_grows_with_deps_and_data() {
        let o = AgentOverheadCosts { base_s: 108.0, per_dep_s: 3.0, restage_bw_bps: 2.7e6 };
        let base = o.overhead_s(4, 1 << 19);
        assert!(base > 300.0 && base < 330.0, "{base}");
        assert!(o.overhead_s(12, 1 << 19) > base);
        assert!(o.overhead_s(4, 1 << 20) > base);
    }
}
