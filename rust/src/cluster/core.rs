//! Hardware computing-core state and its health log.
//!
//! Each core runs a *hardware probing process* (paper, Methods): it samples
//! local health indicators and maintains the log the failure predictor
//! learns from.

use crate::sim::SimTime;

/// Identifies a hardware core within a cluster.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct CoreId(pub usize);

/// One probe observation appended to the core's health log.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct HealthSample {
    pub at: SimTime,
    /// Normalised load (0..1).
    pub load: f64,
    /// Temperature-like wear indicator (0..1); drifts up before failure.
    pub wear: f64,
    /// Whether correctable-error counters ticked since the last probe.
    pub soft_errors: bool,
}

/// Lifecycle of a core as seen by the simulator.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CoreState {
    Healthy,
    /// A failure has been injected and will strike at the embedded time.
    Doomed { fails_at: SimTime },
    Failed,
}

/// A hardware core: state + bounded health log.
#[derive(Debug, Clone)]
pub struct Core {
    pub id: CoreId,
    pub state: CoreState,
    log: Vec<HealthSample>,
    cap: usize,
}

impl Core {
    pub fn new(id: CoreId, log_capacity: usize) -> Self {
        Self { id, state: CoreState::Healthy, log: Vec::new(), cap: log_capacity.max(1) }
    }

    /// Append a sample, evicting the oldest past capacity.
    pub fn observe(&mut self, s: HealthSample) {
        if self.log.len() == self.cap {
            self.log.remove(0);
        }
        self.log.push(s);
    }

    pub fn log(&self) -> &[HealthSample] {
        &self.log
    }

    pub fn is_failed(&self) -> bool {
        matches!(self.state, CoreState::Failed)
    }

    /// True once the injected failure time has passed.
    pub fn tick(&mut self, now: SimTime) -> bool {
        if let CoreState::Doomed { fails_at } = self.state {
            if now >= fails_at {
                self.state = CoreState::Failed;
                return true;
            }
        }
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample(t: f64, wear: f64) -> HealthSample {
        HealthSample { at: SimTime::from_secs(t), load: 0.5, wear, soft_errors: false }
    }

    #[test]
    fn log_bounded() {
        let mut c = Core::new(CoreId(0), 3);
        for i in 0..10 {
            c.observe(sample(i as f64, 0.1));
        }
        assert_eq!(c.log().len(), 3);
        assert_eq!(c.log()[0].at, SimTime::from_secs(7.0));
    }

    #[test]
    fn doomed_core_fails_at_time() {
        let mut c = Core::new(CoreId(1), 4);
        c.state = CoreState::Doomed { fails_at: SimTime::from_secs(100.0) };
        assert!(!c.tick(SimTime::from_secs(99.0)));
        assert!(!c.is_failed());
        assert!(c.tick(SimTime::from_secs(100.0)));
        assert!(c.is_failed());
        // Subsequent ticks report no *new* failure.
        assert!(!c.tick(SimTime::from_secs(101.0)));
    }

    #[test]
    fn healthy_never_fails_on_tick() {
        let mut c = Core::new(CoreId(2), 4);
        assert!(!c.tick(SimTime::from_secs(1e9)));
        assert_eq!(c.state, CoreState::Healthy);
    }
}
