//! A compute node: a set of hardware cores behind one NIC.

use super::core::{Core, CoreId};
use crate::net::NodeId;
use crate::sim::SimTime;

/// One cluster node.
#[derive(Debug, Clone)]
pub struct Node {
    pub id: NodeId,
    pub cores: Vec<Core>,
    /// RAM in MiB (from the paper's platform table; used for sanity checks
    /// against process sizes).
    pub ram_mib: u64,
}

impl Node {
    pub fn new(id: NodeId, n_cores: usize, ram_mib: u64, log_capacity: usize) -> Self {
        let cores = (0..n_cores)
            .map(|c| Core::new(CoreId(id.0 * 1024 + c), log_capacity))
            .collect();
        Self { id, cores, ram_mib }
    }

    /// Node fails when all its cores failed (single-core nodes in the
    /// experiments: node failure == core failure, as in the paper's
    /// "single node failure" scenarios).
    pub fn is_failed(&self) -> bool {
        self.cores.iter().all(|c| c.is_failed())
    }

    /// Advance injected failures; returns true if the node newly failed.
    pub fn tick(&mut self, now: SimTime) -> bool {
        let was = self.is_failed();
        for c in &mut self.cores {
            c.tick(now);
        }
        !was && self.is_failed()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::core::CoreState;

    #[test]
    fn node_fails_when_all_cores_fail() {
        let mut n = Node::new(NodeId(0), 2, 1024, 8);
        n.cores[0].state = CoreState::Failed;
        assert!(!n.is_failed());
        n.cores[1].state = CoreState::Failed;
        assert!(n.is_failed());
    }

    #[test]
    fn tick_reports_transition_once() {
        let mut n = Node::new(NodeId(1), 1, 512, 8);
        n.cores[0].state = CoreState::Doomed { fails_at: SimTime::from_secs(5.0) };
        assert!(!n.tick(SimTime::from_secs(4.0)));
        assert!(n.tick(SimTime::from_secs(5.0)));
        assert!(!n.tick(SimTime::from_secs(6.0)));
    }

    #[test]
    fn core_ids_unique_across_nodes() {
        let a = Node::new(NodeId(0), 4, 1024, 8);
        let b = Node::new(NodeId(1), 4, 1024, 8);
        for ca in &a.cores {
            for cb in &b.cores {
                assert_ne!(ca.id, cb.id);
            }
        }
    }
}
