//! Cluster descriptions: nodes, cores, interconnects, and the calibrated
//! per-cluster protocol cost parameters.
//!
//! Four presets mirror the paper's platform section: ACET (Reading) and the
//! three ACEnet clusters Brasdor, Glooscap, Placentia.

pub mod core;
pub mod node;
pub mod presets;
pub mod spec;

pub use core::{CoreId, CoreState, HealthSample};
pub use node::Node;
pub use presets::{preset, preset_names, ClusterPreset};
pub use spec::{ClusterSpec, FtCosts};
