//! VOPR-style chaos explorer: randomized spec/seed walks over the fleet
//! simulator with continuous invariant checking and automatic shrinking.
//!
//! `run_fleet(spec, seed)` is a pure function of its arguments, which is
//! exactly the precondition for FoundationDB/TigerBeetle-style
//! deterministic simulation testing (SNIPPETS.md §kimberlite-sim): pick a
//! seed, sample a whole cluster scenario from it, run it, and check
//! invariants *continuously* — after every dispatched event, not just at
//! trial end. A violation yields a perfectly reproducible `(spec, seed)`
//! pair, which the shrinker then minimizes dimension-by-dimension (nodes,
//! arrivals, horizon, churn, capacity, sub-jobs) into a small repro,
//! printed as a copy-pasteable `biomaft vopr --repro ...` command plus the
//! last-N-events trace window before the violation.
//!
//! The pieces:
//!
//! * **Generator** — [`gen_walk`] samples a [`WalkSpec`] (a full
//!   [`FleetSpec`] lifetime, or a single-job [`ScenarioSpec`] episode
//!   under one of the multi-failure regimes) from a per-walk seeded
//!   stream. Every generated fleet passes [`FleetSpec::validate`] — the
//!   same validation layer the `biomaft fleet` CLI uses, so walks can
//!   never be vacuously invalid.
//! * **Invariants** — the [`Invariant`] trait plus the default checkers
//!   ([`default_invariants`]): job conservation, no-lost-job (graceful
//!   degradation under the network fault plane), capacity bounds,
//!   placement-index/slab/per-node-list agreement, wait-queue progress,
//!   monotone virtual time, and termination of in-flight recovery work.
//!   They ride the [`FleetObserver`] hook, which is compiled out of the
//!   unobserved path entirely — the byte-identical determinism contract
//!   and the hot-path performance of `run_fleet` are untouched.
//! * **Shrinker** — [`shrink_fleet`] greedily re-runs the deterministic
//!   failure while shrinking one dimension at a time (Poisson arrivals are
//!   first materialized into an explicit trace via
//!   [`sample_arrivals`], a bit-identical substitution), accepting a step
//!   only when the *same* invariant still fails, until no tried move
//!   shrinks further (a greedy local minimum) or the rerun budget is
//!   spent.
//! * **Codec** — [`encode_walk`]/[`decode_walk`] round-trip a walk spec
//!   through a one-line string with `f64`s as exact bit patterns, so a
//!   repro pasted from CI replays the identical trajectory.
//! * **Self-test** — `FleetSpec::fault` (an `InjectedFault`, which exists
//!   only under `cfg(any(test, feature = "vopr-selftest"))`) deliberately
//!   corrupts one transition; tests prove each checker actually fires and
//!   the shrinker converges to a small repro.
//!
//! Episodes have no shrinker: a [`ScenarioSpec`] runs exactly one job, so
//! a failing episode is already minimal — the repro command replays it
//! as-is.

use crate::checkpoint::CheckpointStrategy;
use crate::coordinator::ftmanager::Strategy;
use crate::failure::gray::{DetectorModel, FailSlow, Flapping, GrayPlane, QuarantinePolicy};
use crate::failure::injector::{FailureEvent, FailurePlan, FailureProcess};
use crate::net::{CutSet, FaultPlane, LinkFaults, NodeId, Partition, RetryPolicy, Topology};
use crate::scenario::batch::{parallel_map_trials_scratch, thread_policy};
use crate::scenario::fleet::{
    run_fleet_observed, sample_arrivals, ArrivalSpec, ChurnSpec, FleetEv, FleetObserver,
    FleetOutcome, FleetScratch, FleetSpec, FleetView,
};
use crate::scenario::spec::{FailureRegime, ScenarioSpec};
use crate::sim::{Rng, SimTime};
use std::collections::VecDeque;
use std::fmt::Write as _;
use std::num::NonZeroUsize;

#[cfg(any(test, feature = "vopr-selftest"))]
use crate::scenario::fleet::InjectedFault;

/// Hard ceiling on shrinker reruns per failure.
const MAX_RERUNS: usize = 500;

/// Absolute slack for floating-point outcome bounds.
const EPS: f64 = 1e-9;

/// Configuration of one exploration run.
#[derive(Debug, Clone)]
pub struct VoprCfg {
    /// Number of independent (spec, seed) walks.
    pub walks: usize,
    /// Root seed; every walk derives its own generator and trial seed from
    /// `(base_seed, walk index)`, so runs are reproducible and walk
    /// results are independent of the thread count.
    pub base_seed: u64,
    /// Largest generated fleet (nodes).
    pub max_nodes: usize,
    /// Cap on expected arrivals per generated fleet lifetime.
    pub max_arrivals: usize,
    /// Events kept in the pre-violation trace window.
    pub trace_window: usize,
    /// Worker threads (`None` ⇒ all cores); output is identical at any
    /// value.
    pub threads: Option<usize>,
    /// Arm the deliberate corruption on every generated fleet — the
    /// self-test hook proving the checkers fire and the shrinker
    /// converges. Compiled out of normal builds.
    #[cfg(any(test, feature = "vopr-selftest"))]
    pub fault: Option<InjectedFault>,
}

impl Default for VoprCfg {
    fn default() -> Self {
        Self {
            walks: 1000,
            base_seed: 2014,
            max_nodes: 64,
            max_arrivals: 2000,
            trace_window: 32,
            threads: None,
            #[cfg(any(test, feature = "vopr-selftest"))]
            fault: None,
        }
    }
}

/// One sampled point in spec space: a whole fleet lifetime or a single-job
/// scenario episode.
#[derive(Debug, Clone)]
pub enum WalkSpec {
    Fleet(FleetSpec),
    Episode(ScenarioSpec),
}

/// One entry of the pre-violation trace window.
#[derive(Debug, Clone, Copy)]
pub struct TraceEntry {
    /// 1-based dispatch index of the event within the trial.
    pub index: u64,
    /// Virtual time of the event in seconds.
    pub at_s: f64,
    pub ev: FleetEv,
}

/// A checked invariant that failed, with the window of events leading up
/// to it.
#[derive(Debug, Clone)]
pub struct Violation {
    /// Name of the failed checker (stable; the shrinker matches on it).
    pub invariant: &'static str,
    /// Human-readable account of the disagreement.
    pub detail: String,
    /// Virtual time of the violating event in seconds.
    pub at_s: f64,
    /// Dispatch index of the violating event (0 for outcome-level checks
    /// of an episode).
    pub event_index: u64,
    /// Last events before (and including) the violation, oldest first.
    pub trace: Vec<TraceEntry>,
}

// ---------------------------------------------------------------------------
// Invariants

/// A continuously-checked fleet invariant. `check` runs after every
/// dispatched event with the post-state [`FleetView`]; `at_end` runs once
/// after the final tick. Checkers are cheap pure reads — they see the
/// view, never the system — so a passing trial is bit-identical with and
/// without them.
pub trait Invariant {
    /// Stable name, used in reports and by the shrinker's oracle.
    fn name(&self) -> &'static str;
    /// Check the post-state of one event.
    fn check(&mut self, ev: &FleetEv, view: &FleetView<'_>) -> Result<(), String>;
    /// Check the final state. `hit_horizon` is false when the event queue
    /// drained (quiescence) before the horizon.
    fn at_end(&mut self, view: &FleetView<'_>, hit_horizon: bool) -> Result<(), String> {
        let _ = (view, hit_horizon);
        Ok(())
    }
}

/// No job is ever lost or double-counted: every arrival is either
/// completed or live (placed or queued), at all times.
#[derive(Debug, Default, Clone, Copy)]
pub struct JobConservation;

impl JobConservation {
    fn check_view(view: &FleetView<'_>) -> Result<(), String> {
        if view.arrived != view.completed + view.live_jobs {
            return Err(format!(
                "arrived {} != completed {} + live {}",
                view.arrived, view.completed, view.live_jobs
            ));
        }
        if view.queued > view.live_jobs {
            return Err(format!("queued {} > live jobs {}", view.queued, view.live_jobs));
        }
        Ok(())
    }
}

impl Invariant for JobConservation {
    fn name(&self) -> &'static str {
        "job-conservation"
    }
    fn check(&mut self, _ev: &FleetEv, view: &FleetView<'_>) -> Result<(), String> {
        Self::check_view(view)
    }
    fn at_end(&mut self, view: &FleetView<'_>, hit_horizon: bool) -> Result<(), String> {
        Self::check_view(view)?;
        // Quiescence clause: the event queue drained before the horizon,
        // so no live job can still be *placed* — a placed sub-job always
        // has a scheduled continuation event. Any live job beyond the
        // waiting queue lost its continuation somewhere (the signature of
        // a cross-cell message leaking at an epoch boundary; see
        // `InjectedFault::EpochLeak`).
        if !hit_horizon && view.live_jobs > view.queued {
            return Err(format!(
                "quiescent with {} live jobs but only {} queued: a placed job \
                 lost its scheduled continuation",
                view.live_jobs, view.queued
            ));
        }
        Ok(())
    }
}

/// Graceful degradation, never silent loss: no transition may strand a
/// sub-job without a scheduled continuation. The fleet counts such
/// abandonments ([`FleetView::abandoned`]); a correct protocol keeps the
/// count at zero forever — a migration whose message sequence exhausts its
/// retries under the network fault plane must fall back to reactive
/// checkpoint recovery, not drop the work.
#[derive(Debug, Default, Clone, Copy)]
pub struct NoLostJob;

impl NoLostJob {
    fn check_view(view: &FleetView<'_>) -> Result<(), String> {
        if view.abandoned > 0 {
            return Err(format!(
                "{} sub-jobs abandoned with no scheduled continuation",
                view.abandoned
            ));
        }
        Ok(())
    }
}

impl Invariant for NoLostJob {
    fn name(&self) -> &'static str {
        "no-lost-job"
    }
    fn check(&mut self, _ev: &FleetEv, view: &FleetView<'_>) -> Result<(), String> {
        Self::check_view(view)
    }
    fn at_end(&mut self, view: &FleetView<'_>, _hit_horizon: bool) -> Result<(), String> {
        Self::check_view(view)
    }
}

/// Placement never overfills a node and the cluster never runs more subs
/// than it has slots — goodput ≤ capacity at the bookkeeping level.
#[derive(Debug, Default, Clone, Copy)]
pub struct CapacityBound;

impl CapacityBound {
    fn check_view(view: &FleetView<'_>) -> Result<(), String> {
        for (v, &o) in view.occupancy.iter().enumerate() {
            if o > view.capacity {
                return Err(format!(
                    "node {v} occupancy {o} > capacity {}",
                    view.capacity
                ));
            }
        }
        let slots = view.occupancy.len() * view.capacity;
        if view.running > slots {
            return Err(format!("running subs {} > cluster slots {slots}", view.running));
        }
        Ok(())
    }
}

impl Invariant for CapacityBound {
    fn name(&self) -> &'static str {
        "capacity-bound"
    }
    fn check(&mut self, _ev: &FleetEv, view: &FleetView<'_>) -> Result<(), String> {
        Self::check_view(view)
    }
    fn at_end(&mut self, view: &FleetView<'_>, _hit_horizon: bool) -> Result<(), String> {
        Self::check_view(view)
    }
}

/// The three independent bookkeeping structures — placement index, job
/// slab, per-node sub lists — agree on every fact they share.
#[derive(Debug, Default, Clone, Copy)]
pub struct BookkeepingAgreement;

impl BookkeepingAgreement {
    fn check_view(view: &FleetView<'_>) -> Result<(), String> {
        for (v, (&occ, &hosted)) in view.occupancy.iter().zip(view.hosted).enumerate() {
            if occ != hosted {
                return Err(format!(
                    "node {v}: placement index says {occ} occupied, per-node list says {hosted}"
                ));
            }
        }
        if view.sub_running != view.running {
            return Err(format!(
                "slab counts {} running subs, counter says {}",
                view.sub_running, view.running
            ));
        }
        if view.sub_migrating != view.migr_inflight {
            return Err(format!(
                "slab counts {} migrating subs, counter says {}",
                view.sub_migrating, view.migr_inflight
            ));
        }
        if view.distinct_recs != view.rec_inflight {
            return Err(format!(
                "slab holds {} distinct recovery groups, counter says {}",
                view.distinct_recs, view.rec_inflight
            ));
        }
        if !view.remaining_ok {
            return Err("a live job's `remaining` disagrees with its non-Done sub count".into());
        }
        if view.stale_node_subs > 0 {
            return Err(format!(
                "{} per-node list entries point at dead or moved subs",
                view.stale_node_subs
            ));
        }
        Ok(())
    }
}

impl Invariant for BookkeepingAgreement {
    fn name(&self) -> &'static str {
        "bookkeeping-agreement"
    }
    fn check(&mut self, _ev: &FleetEv, view: &FleetView<'_>) -> Result<(), String> {
        Self::check_view(view)
    }
    fn at_end(&mut self, view: &FleetView<'_>, _hit_horizon: bool) -> Result<(), String> {
        Self::check_view(view)
    }
}

/// The wait queue makes progress: immediately after the events that drain
/// it (a job-completing `SubDone`, a `Repair`), a non-empty queue implies
/// its all-or-nothing head genuinely does not fit the free healthy slots.
/// (Other events may legitimately free capacity without a drain — the next
/// drain point picks it up — so only drain points are checked, plus
/// quiescence.)
#[derive(Debug, Default, Clone, Copy)]
pub struct QueueProgress;

impl QueueProgress {
    fn head_must_not_fit(view: &FleetView<'_>) -> Result<(), String> {
        if view.queued == 0 {
            return Ok(());
        }
        let free: usize = view
            .occupancy
            .iter()
            .enumerate()
            .filter(|&(v, _)| !view.doomed[v] && !view.quarantined[v])
            .map(|(_, &o)| view.capacity.saturating_sub(o))
            .sum();
        if free >= view.n_subs {
            return Err(format!(
                "{} jobs queued but {free} free healthy slots fit a {}-sub job",
                view.queued, view.n_subs
            ));
        }
        Ok(())
    }
}

impl Invariant for QueueProgress {
    fn name(&self) -> &'static str {
        "queue-progress"
    }
    fn check(&mut self, ev: &FleetEv, view: &FleetView<'_>) -> Result<(), String> {
        let drain_point = matches!(
            ev,
            FleetEv::SubDone { job_completed: true, .. } | FleetEv::Repair { .. }
        );
        if !drain_point {
            return Ok(());
        }
        Self::head_must_not_fit(view)
    }
    fn at_end(&mut self, view: &FleetView<'_>, hit_horizon: bool) -> Result<(), String> {
        if hit_horizon {
            return Ok(());
        }
        Self::head_must_not_fit(view)
    }
}

/// Misprediction/flap storms stay bounded: after any event, no
/// un-quarantined node's suspicion may sit at or above the quarantine
/// threshold — crossing the threshold must quarantine the node and reset
/// its count in the same transition. This is the checker the cfg-gated
/// [`InjectedFault::QuarantineLeak`] self-test proves fires.
#[derive(Debug, Default, Clone, Copy)]
pub struct StormBound;

impl StormBound {
    fn check_view(view: &FleetView<'_>) -> Result<(), String> {
        if view.suspicion_threshold == 0 {
            return Ok(()); // policy disabled: suspicion never accrues
        }
        for (v, &s) in view.suspicion.iter().enumerate() {
            if s >= view.suspicion_threshold && !view.quarantined[v] {
                return Err(format!(
                    "node {v} suspicion {s} at/past threshold {} without quarantine",
                    view.suspicion_threshold
                ));
            }
        }
        Ok(())
    }
}

impl Invariant for StormBound {
    fn name(&self) -> &'static str {
        "storm-bound"
    }
    fn check(&mut self, _ev: &FleetEv, view: &FleetView<'_>) -> Result<(), String> {
        Self::check_view(view)
    }
    fn at_end(&mut self, view: &FleetView<'_>, _hit_horizon: bool) -> Result<(), String> {
        Self::check_view(view)
    }
}

/// Quarantine bookkeeping balances: releases never exceed quarantines,
/// and a fleet that went quiescent before the horizon holds no node in
/// quarantine — every probation scheduled a release and every release
/// fired.
#[derive(Debug, Default, Clone, Copy)]
pub struct QuarantineReleases;

impl Invariant for QuarantineReleases {
    fn name(&self) -> &'static str {
        "quarantine-releases"
    }
    fn check(&mut self, _ev: &FleetEv, view: &FleetView<'_>) -> Result<(), String> {
        if view.quarantine_releases > view.quarantines {
            return Err(format!(
                "{} releases > {} quarantines",
                view.quarantine_releases, view.quarantines
            ));
        }
        Ok(())
    }
    fn at_end(&mut self, view: &FleetView<'_>, hit_horizon: bool) -> Result<(), String> {
        if view.quarantine_releases > view.quarantines {
            return Err(format!(
                "{} releases > {} quarantines",
                view.quarantine_releases, view.quarantines
            ));
        }
        if !hit_horizon {
            if let Some(v) = view.quarantined.iter().position(|&q| q) {
                return Err(format!("quiescent before the horizon with node {v} quarantined"));
            }
        }
        Ok(())
    }
}

/// Virtual time never runs backwards across dispatched events.
#[derive(Debug, Default)]
pub struct MonotoneTime {
    last_ns: u64,
}

impl Invariant for MonotoneTime {
    fn name(&self) -> &'static str {
        "monotone-time"
    }
    fn check(&mut self, _ev: &FleetEv, view: &FleetView<'_>) -> Result<(), String> {
        let now = view.now.0;
        if now < self.last_ns {
            return Err(format!(
                "time ran backwards: {} ns after {} ns",
                now, self.last_ns
            ));
        }
        self.last_ns = now;
        Ok(())
    }
}

/// Every migration and rollback recovery terminates: if the event queue
/// drains before the horizon, nothing may still be in flight.
#[derive(Debug, Default, Clone, Copy)]
pub struct Termination;

impl Invariant for Termination {
    fn name(&self) -> &'static str {
        "termination"
    }
    fn check(&mut self, _ev: &FleetEv, _view: &FleetView<'_>) -> Result<(), String> {
        Ok(())
    }
    fn at_end(&mut self, view: &FleetView<'_>, hit_horizon: bool) -> Result<(), String> {
        if !hit_horizon && (view.migr_inflight > 0 || view.rec_inflight > 0) {
            return Err(format!(
                "quiescent before the horizon with {} migrations and {} recoveries in flight",
                view.migr_inflight, view.rec_inflight
            ));
        }
        Ok(())
    }
}

/// The full default checker set, fresh state per trial. Order matters
/// mildly: structural checkers run before derived ones so the first
/// reported violation is the most primitive disagreement.
pub fn default_invariants() -> Vec<Box<dyn Invariant>> {
    vec![
        Box::new(MonotoneTime::default()),
        Box::new(JobConservation),
        Box::new(NoLostJob),
        Box::new(CapacityBound),
        Box::new(BookkeepingAgreement),
        Box::new(QueueProgress),
        Box::new(StormBound),
        Box::new(QuarantineReleases),
        Box::new(Termination),
    ]
}

/// The [`FleetObserver`] that drives a checker set and keeps the rolling
/// pre-violation trace window. Records the *first* violation only — once
/// one checker disagrees the derived state is suspect, so later reports
/// would be noise.
pub struct InvariantObserver {
    checkers: Vec<Box<dyn Invariant>>,
    window: usize,
    ring: VecDeque<TraceEntry>,
    events: u64,
    violation: Option<Violation>,
}

impl InvariantObserver {
    /// The default checker set with a trace window of `window` events
    /// (clamped to ≥ 1).
    pub fn new(window: usize) -> Self {
        Self::with_checkers(default_invariants(), window)
    }

    pub fn with_checkers(checkers: Vec<Box<dyn Invariant>>, window: usize) -> Self {
        Self {
            checkers,
            window: window.max(1),
            ring: VecDeque::new(),
            events: 0,
            violation: None,
        }
    }

    /// The first violation, if any checker fired so far.
    pub fn violation(&self) -> Option<&Violation> {
        self.violation.as_ref()
    }

    /// Events observed so far.
    pub fn events(&self) -> u64 {
        self.events
    }

    /// Tear down into the violation (if any) and the final trace window.
    pub fn finish(self) -> (Option<Violation>, Vec<TraceEntry>) {
        (self.violation, self.ring.into_iter().collect())
    }

    fn record(&mut self, invariant: &'static str, detail: String, at_s: f64) {
        self.violation = Some(Violation {
            invariant,
            detail,
            at_s,
            event_index: self.events,
            trace: self.ring.iter().copied().collect(),
        });
    }
}

impl FleetObserver for InvariantObserver {
    fn after_event(&mut self, ev: FleetEv, view: &FleetView<'_>) {
        self.events += 1;
        if self.ring.len() == self.window {
            self.ring.pop_front();
        }
        self.ring.push_back(TraceEntry { index: self.events, at_s: view.now.as_secs(), ev });
        if self.violation.is_some() {
            return;
        }
        let hit = self.checkers.iter_mut().find_map(|c| match c.check(&ev, view) {
            Err(detail) => Some((c.name(), detail)),
            Ok(()) => None,
        });
        if let Some((name, detail)) = hit {
            self.record(name, detail, view.now.as_secs());
        }
    }

    fn at_end(&mut self, view: &FleetView<'_>, hit_horizon: bool) {
        if self.violation.is_some() {
            return;
        }
        let hit = self.checkers.iter_mut().find_map(|c| match c.at_end(view, hit_horizon) {
            Err(detail) => Some((c.name(), detail)),
            Ok(()) => None,
        });
        if let Some((name, detail)) = hit {
            self.record(name, detail, view.now.as_secs());
        }
    }
}

/// Outcome-level sanity bounds checked after a clean event loop: the
/// aggregate metrics must respect their own definitions.
fn check_fleet_outcome(
    spec: &FleetSpec,
    o: &FleetOutcome,
) -> Result<(), (&'static str, String)> {
    if !(o.goodput_ratio.is_nan() || o.goodput_ratio <= 1.0 + EPS) {
        return Err((
            "goodput-bound",
            format!("goodput ratio {} exceeds cluster capacity", o.goodput_ratio),
        ));
    }
    if !(o.utilization.is_nan() || (-EPS..=1.0 + EPS).contains(&o.utilization)) {
        return Err((
            "utilization-bound",
            format!("utilization {} outside [0, 1]", o.utilization),
        ));
    }
    if !(o.mean_slowdown.is_nan() || o.mean_slowdown >= 1.0 - EPS) {
        return Err((
            "slowdown-floor",
            format!("mean slowdown {} below 1 (faster than nominal)", o.mean_slowdown),
        ));
    }
    if o.last_completion_s > spec.horizon_s + EPS {
        return Err((
            "completion-past-horizon",
            format!(
                "last completion at {} s past the {} s horizon",
                o.last_completion_s, spec.horizon_s
            ),
        ));
    }
    if o.jobs_completed > o.jobs_arrived {
        return Err((
            "outcome-conservation",
            format!("completed {} > arrived {}", o.jobs_completed, o.jobs_arrived),
        ));
    }
    if o.jobs_waiting > o.jobs_arrived - o.jobs_completed {
        return Err((
            "outcome-conservation",
            format!(
                "waiting {} > arrived {} - completed {}",
                o.jobs_waiting, o.jobs_arrived, o.jobs_completed
            ),
        ));
    }
    if o.peak_live_jobs > o.jobs_arrived {
        return Err((
            "outcome-conservation",
            format!("peak live {} > arrived {}", o.peak_live_jobs, o.jobs_arrived),
        ));
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Generator

fn walk_rng(base_seed: u64, walk: u64) -> Rng {
    Rng::new(base_seed ^ walk.wrapping_mul(0x9E37_79B9_7F4A_7C15).rotate_left(17))
}

/// Sample walk `walk`'s spec and trial seed. Pure in `(cfg, walk)`: the
/// explorer calls this from worker threads, so walk results are keyed by
/// index and independent of the thread count.
pub fn gen_walk(cfg: &VoprCfg, walk: u64) -> (WalkSpec, u64) {
    let mut rng = walk_rng(cfg.base_seed, walk);
    let seed = rng.next_u64();
    let spec = if rng.chance(0.25) {
        WalkSpec::Episode(gen_episode(&mut rng))
    } else {
        WalkSpec::Fleet(gen_fleet(&mut rng, cfg))
    };
    (spec, seed)
}

const STRATEGIES: [Strategy; 4] = [
    Strategy::Agent,
    Strategy::Core,
    Strategy::Hybrid,
    Strategy::Checkpoint(CheckpointStrategy::CentralSingle),
];

fn gen_fleet(rng: &mut Rng, cfg: &VoprCfg) -> FleetSpec {
    let strategy = *rng.pick(&STRATEGIES);
    let nodes = 1 + rng.range_usize(0, cfg.max_nodes.max(1));
    let mut spec = FleetSpec::placentia_fleet(strategy, nodes, 0.0, 0.0);
    spec.capacity = 1 + rng.range_usize(0, 4);
    spec.ckpt_streams = 1 + rng.range_usize(0, 4);
    spec.job.n_subs = 1 + rng.range_usize(0, 8);
    spec.job.z = 1 + rng.range_usize(0, 8);
    spec.job.compute_s = rng.uniform(300.0, 3600.0);
    spec.job.predictable_frac =
        if strategy.is_multi_agent() { rng.f64() } else { 0.0 };
    spec.horizon_s = rng.uniform(1800.0, 4.0 * 3600.0);
    // Arrival rate scaled against what the cluster can clear, so a good
    // share of walks saturate — queues are where placement bugs live —
    // capped so the expected arrival count stays within `max_arrivals`.
    spec.arrivals = if rng.chance(0.5) {
        let slots = (nodes * spec.capacity) as f64;
        let clear_per_h = slots * 3600.0 / (spec.job.n_subs as f64 * spec.job.compute_s);
        let cap_rate = cfg.max_arrivals.max(1) as f64 / (spec.horizon_s / 3600.0);
        ArrivalSpec::Poisson { rate_per_h: rng.uniform(0.0, (2.0 * clear_per_h).min(cap_rate)) }
    } else {
        let n = rng.range_usize(0, cfg.max_arrivals.max(1) + 1);
        ArrivalSpec::Trace { at_s: (0..n).map(|_| rng.uniform(0.0, spec.horizon_s)).collect() }
    };
    spec.churn = if rng.chance(0.6) {
        ChurnSpec::PerNode {
            process: FailureProcess::Poisson { rate_per_window: rng.uniform(0.0, 2.0) },
            window_s: rng.uniform(600.0, 3600.0),
            repair_s: rng.uniform(60.0, 1800.0),
        }
    } else {
        // A planned multi-failure regime, built through the same plan
        // builder the scenario layer uses — concurrent-k, rack-correlated,
        // or a k-per-window burst. Planned nodes never repair.
        let windows = (spec.horizon_s / 3600.0).ceil() as usize;
        let regime = match rng.range_usize(0, 3) {
            0 => FailureRegime::Single(FailureProcess::RandomUniformK {
                k: 1 + rng.range_usize(0, 3),
            }),
            1 => FailureRegime::ConcurrentK {
                k: 1 + rng.range_usize(0, 4),
                offset_s: rng.uniform(0.0, 1800.0),
                spacing_s: rng.uniform(0.0, 120.0),
            },
            _ => FailureRegime::Correlated {
                primary: FailureProcess::Periodic { offset_s: rng.uniform(0.0, 3600.0) },
                rack_size: 1 + rng.range_usize(0, 8),
                p_spread: rng.f64(),
                lag_s: rng.uniform(0.0, 300.0),
            },
        };
        let probe = ScenarioSpec {
            cfg: spec.job.clone(),
            topo: spec.topo.clone(),
            regime,
            windows,
            window_s: 3600.0,
            faults: FaultPlane::default(),
        };
        ChurnSpec::Plan(probe.plan(&mut rng.fork(0xC4A0)))
    };
    // Network fault plane: half the walks run under a sampled plane. The
    // plane draws from a forked stream, after every other dimension, so
    // earlier dims sample exactly as they would without it.
    if rng.chance(0.5) {
        spec.faults = sample_fault_plane(&mut rng.fork(0xFA17), nodes);
    }
    // Gray-failure plane: half the walks run under a sampled plane, drawn
    // from its own forked stream after every other dimension so earlier
    // dims sample exactly as they would without it.
    if rng.chance(0.5) {
        spec.gray = sample_gray_plane(&mut rng.fork(0x64AF));
    }
    // Sharded cells: half the walks run the sharded layout (a pure
    // performance knob — byte-identity to cells = 1 is the contract under
    // test), drawn from a forked stream after every other dimension so
    // earlier dims sample exactly as they would without it.
    if rng.chance(0.5) {
        let cells = 2 + rng.fork(0xCE11).range_usize(0, 7);
        spec.cells = NonZeroUsize::new(cells).expect("cells >= 2");
    }
    #[cfg(any(test, feature = "vopr-selftest"))]
    {
        spec.fault = cfg.fault;
    }
    debug_assert!(spec.validate().is_ok());
    spec
}

/// Sample a gray plane for a generated fleet: an imperfect detector about
/// half the time, mild flapping and fail-slow episodes, and a quarantine
/// policy drawn around the default (threshold 0 disables it — those walks
/// cover the policy-off path). The result may still be off (no detector,
/// both rates zero) — those walks double as is-off fast-path coverage.
fn sample_gray_plane(rng: &mut Rng) -> GrayPlane {
    let detector = if rng.chance(0.5) {
        Some(DetectorModel {
            coverage: rng.f64(),
            precision: rng.uniform(0.2, 1.0),
            lead_jitter_s: rng.uniform(0.0, 60.0),
        })
    } else {
        None
    };
    let fail_slow = if rng.chance(0.5) {
        FailSlow {
            rate_per_node_h: rng.uniform(0.0, 1.0),
            mean_duration_s: rng.uniform(60.0, 1800.0),
            speed_factor: rng.uniform(0.1, 1.0),
        }
    } else {
        FailSlow::default()
    };
    let flapping = if rng.chance(0.5) {
        Flapping {
            rate_per_node_h: rng.uniform(0.0, 2.0),
            burst_len: 1 + rng.range_usize(0, 6) as u32,
            down_s: rng.uniform(10.0, 300.0),
            gap_s: rng.uniform(0.0, 600.0),
        }
    } else {
        Flapping::default()
    };
    let probation_s = rng.uniform(60.0, 1800.0);
    let quarantine = QuarantinePolicy {
        threshold: rng.range_usize(0, 6) as u32,
        probation_s,
        backoff_mult: rng.uniform(1.0, 3.0),
        max_probation_s: probation_s * rng.uniform(1.0, 8.0),
    };
    GrayPlane { detector, fail_slow, flapping, quarantine }
}

fn sample_link_faults(rng: &mut Rng) -> LinkFaults {
    if rng.chance(0.5) {
        LinkFaults::off()
    } else {
        LinkFaults {
            loss_p: rng.uniform(0.0, 0.3),
            dup_p: rng.uniform(0.0, 0.2),
            delay_p: rng.uniform(0.0, 0.5),
            delay_mean_s: rng.uniform(0.0, 2.0),
        }
    }
}

/// Sample a fault plane for a generated fleet: mild-to-moderate loss,
/// duplication and delay on either link class, sometimes a timed
/// partition, and a retry policy drawn around the default. The result may
/// still be off (both links clean, no partition) — those walks double as
/// is-off fast-path coverage.
fn sample_fault_plane(rng: &mut Rng, nodes: usize) -> FaultPlane {
    let peer = sample_link_faults(rng);
    let ckpt = sample_link_faults(rng);
    let mut partitions = Vec::new();
    if rng.chance(0.3) {
        let cut = if nodes >= 2 && rng.chance(0.5) {
            CutSet::Split { at: 1 + rng.range_usize(0, nodes - 1) }
        } else {
            CutSet::Checkpoint
        };
        let start_s = rng.uniform(0.0, 3600.0);
        partitions.push(Partition {
            start_s,
            end_s: start_s + rng.uniform(60.0, 1800.0),
            cut,
        });
    }
    let retry = RetryPolicy {
        timeout_s: rng.uniform(0.1, 2.0),
        max_retries: 1 + rng.range_usize(0, 6) as u32,
        backoff_base_s: rng.uniform(0.0, 1.0),
        backoff_mult: rng.uniform(1.0, 3.0),
    };
    FaultPlane { peer, ckpt, partitions, retry, ..FaultPlane::default() }
}

fn gen_episode(rng: &mut Rng) -> ScenarioSpec {
    let strategy = *rng.pick(&STRATEGIES);
    let predictable_frac = if strategy.is_multi_agent() { rng.f64() } else { 0.0 };
    let n_subs = 1 + rng.range_usize(0, 16);
    let regime = match rng.range_usize(0, 6) {
        0 => FailureRegime::Single(FailureProcess::Periodic {
            offset_s: rng.uniform(0.0, 3000.0),
        }),
        1 => FailureRegime::Single(FailureProcess::RandomUniform),
        2 => FailureRegime::Single(FailureProcess::RandomUniformK {
            k: 1 + rng.range_usize(0, 4),
        }),
        3 => FailureRegime::ConcurrentK {
            k: 1 + rng.range_usize(0, 4),
            offset_s: rng.uniform(0.0, 1800.0),
            spacing_s: rng.uniform(0.0, 120.0),
        },
        4 => FailureRegime::Correlated {
            primary: FailureProcess::Periodic { offset_s: rng.uniform(0.0, 3600.0) },
            rack_size: 1 + rng.range_usize(0, 8),
            p_spread: rng.f64(),
            lag_s: rng.uniform(0.0, 300.0),
        },
        _ => FailureRegime::Cascade {
            trigger: FailureProcess::Periodic { offset_s: rng.uniform(0.0, 3600.0) },
            p_follow: rng.f64(),
            lag_s: rng.uniform(0.0, 60.0),
        },
    };
    let mut spec = ScenarioSpec::placentia_ring16(strategy, predictable_frac, n_subs, regime);
    spec.topo = Topology::ring(2 + rng.range_usize(0, 31), 2);
    spec.windows = 1 + rng.range_usize(0, 3);
    spec
}

// ---------------------------------------------------------------------------
// Walk execution

/// Run one walk under the full checker set. Returns the dispatched event
/// count and the first violation, if any.
pub fn run_walk(
    spec: &WalkSpec,
    seed: u64,
    window: usize,
    scratch: &mut FleetScratch,
) -> (u64, Option<Violation>) {
    match spec {
        WalkSpec::Fleet(f) => {
            let mut obs = InvariantObserver::new(window);
            let out = run_fleet_observed(f, seed, scratch, &mut obs);
            let (violation, ring) = obs.finish();
            if let Some(v) = violation {
                return (out.events, Some(v));
            }
            if let Err((name, detail)) = check_fleet_outcome(f, &out) {
                let v = Violation {
                    invariant: name,
                    detail,
                    at_s: f.horizon_s,
                    event_index: out.events,
                    trace: ring,
                };
                return (out.events, Some(v));
            }
            (out.events, None)
        }
        WalkSpec::Episode(e) => run_episode(e, seed),
    }
}

/// Episode walks: run the single-job scenario twice on the same seed and
/// hold it to determinism plus basic physics (the job completes, taking at
/// least its nominal compute time, in a non-empty event trace).
fn run_episode(spec: &ScenarioSpec, seed: u64) -> (u64, Option<Violation>) {
    let a = spec.run_trial(seed);
    let b = spec.run_trial(seed);
    let mk = |invariant: &'static str, detail: String| Violation {
        invariant,
        detail,
        at_s: a.completed_at_s,
        event_index: a.events,
        trace: Vec::new(),
    };
    let same = a.events == b.events
        && a.completed_at_s.to_bits() == b.completed_at_s.to_bits()
        && a.migrations == b.migrations
        && a.rollbacks == b.rollbacks
        && a.lost_then_recovered == b.lost_then_recovered
        && a.cascades == b.cascades;
    if !same {
        let v = mk(
            "episode-determinism",
            format!(
                "two runs of the same (spec, seed) diverged: \
                 {} vs {} events, completion {} vs {}",
                a.events, b.events, a.completed_at_s, b.completed_at_s
            ),
        );
        return (a.events, Some(v));
    }
    if a.events == 0 {
        return (a.events, Some(mk("episode-sanity", "trial dispatched no events".into())));
    }
    if !(a.completed_at_s.is_finite() && a.completed_at_s >= spec.cfg.compute_s - 1e-6) {
        let v = mk(
            "episode-sanity",
            format!(
                "completion at {} s beats the {} s nominal compute time",
                a.completed_at_s, spec.cfg.compute_s
            ),
        );
        return (a.events, Some(v));
    }
    (a.events, None)
}

// ---------------------------------------------------------------------------
// Explorer

/// A failing walk: the original spec and violation, plus the shrunk repro
/// when the walk was a fleet (episodes are already minimal).
#[derive(Debug, Clone)]
pub struct WalkFailure {
    /// Index of the first failing walk.
    pub walk: usize,
    /// Its trial seed (pass to `--seed` with the repro string).
    pub seed: u64,
    pub spec: WalkSpec,
    pub violation: Violation,
    pub shrunk: Option<Shrunk>,
}

/// Result of shrinking a failing fleet spec.
#[derive(Debug, Clone)]
pub struct Shrunk {
    pub spec: FleetSpec,
    /// The violation as it fires on the shrunk spec (same invariant).
    pub violation: Violation,
    /// Deterministic reruns the shrinker spent.
    pub reruns: usize,
}

/// Aggregate of one exploration run.
#[derive(Debug, Clone)]
pub struct ExploreReport {
    pub walks: usize,
    pub fleet_walks: usize,
    pub episode_walks: usize,
    /// Dispatched events across all walks.
    pub total_events: u64,
    pub threads: usize,
    /// The first failing walk (lowest index), shrunk if possible.
    pub failure: Option<WalkFailure>,
}

impl ExploreReport {
    pub fn passed(&self) -> bool {
        self.failure.is_none()
    }

    /// Render the human-readable report, including the repro command and
    /// the pre-violation trace window on failure.
    pub fn render(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(
            s,
            "vopr: {} walks ({} fleet, {} episode) on {} threads, {} events dispatched",
            self.walks, self.fleet_walks, self.episode_walks, self.threads, self.total_events
        );
        let Some(f) = &self.failure else {
            let _ = writeln!(s, "all invariants held");
            return s;
        };
        let _ = writeln!(
            s,
            "walk {} (trial seed {:#018x}) violated `{}`:",
            f.walk, f.seed, f.violation.invariant
        );
        let _ = writeln!(s, "  {}", f.violation.detail);
        let _ = writeln!(s, "  original spec: {}", walk_dims(&f.spec));
        let (repro_spec, trace_violation) = match &f.shrunk {
            Some(sh) => {
                let _ = writeln!(
                    s,
                    "  shrunk after {} reruns: {}",
                    sh.reruns,
                    fleet_dims(&sh.spec)
                );
                let _ = writeln!(s, "  {}", sh.violation.detail);
                (WalkSpec::Fleet(sh.spec.clone()), &sh.violation)
            }
            None => {
                if matches!(f.spec, WalkSpec::Episode(_)) {
                    let _ = writeln!(s, "  (episode specs run one job; already minimal)");
                }
                (f.spec.clone(), &f.violation)
            }
        };
        render_trace(&mut s, trace_violation);
        let _ = writeln!(
            s,
            "  repro: biomaft vopr --seed {} --repro '{}'",
            f.seed,
            encode_walk(&repro_spec)
        );
        s
    }
}

fn render_trace(s: &mut String, v: &Violation) {
    if v.trace.is_empty() {
        return;
    }
    let _ = writeln!(
        s,
        "  trace (last {} events up to the violation at t={:.3}s, event #{}):",
        v.trace.len(),
        v.at_s,
        v.event_index
    );
    for t in &v.trace {
        let _ = writeln!(s, "    [{:>7}] {:>12.3}s  {}", t.index, t.at_s, t.ev);
    }
}

/// One-line dimensional summary of a fleet spec.
pub fn fleet_dims(spec: &FleetSpec) -> String {
    let arrivals = match &spec.arrivals {
        ArrivalSpec::Poisson { rate_per_h } => format!("poisson {rate_per_h:.2}/h"),
        ArrivalSpec::Trace { at_s } => format!("{} arrivals", at_s.len()),
    };
    let churn = match &spec.churn {
        ChurnSpec::Plan(p) => format!("{} planned failures", p.events.len()),
        ChurnSpec::PerNode { process: FailureProcess::Poisson { rate_per_window }, .. } => {
            format!("per-node churn {rate_per_window:.2}/window")
        }
        ChurnSpec::PerNode { .. } => "per-node churn".into(),
    };
    format!(
        "{} nodes x {} slots, {}-sub jobs, {arrivals}, {churn}, horizon {:.0}s",
        spec.topo.len(),
        spec.capacity,
        spec.job.n_subs,
        spec.horizon_s
    )
}

/// One-line dimensional summary of a walk spec.
pub fn walk_dims(spec: &WalkSpec) -> String {
    match spec {
        WalkSpec::Fleet(f) => fleet_dims(f),
        WalkSpec::Episode(e) => {
            let regime = match &e.regime {
                FailureRegime::Single(_) => "single",
                FailureRegime::ConcurrentK { .. } => "concurrent-k",
                FailureRegime::Correlated { .. } => "correlated",
                FailureRegime::Cascade { .. } => "cascade",
            };
            format!(
                "episode ({regime}): {} nodes, {}-sub job, {} windows x {:.0}s",
                e.topo.len(),
                e.cfg.n_subs,
                e.windows,
                e.window_s
            )
        }
    }
}

/// Random-walk `cfg.walks` (spec, seed) pairs under continuous invariant
/// checking, shrink the first failure, and report. Deterministic in
/// `cfg`: walks are keyed by index (not by thread), so the report —
/// counts, event totals, first failure, shrunk repro — is identical at
/// any thread count.
pub fn explore(cfg: &VoprCfg) -> ExploreReport {
    let threads = thread_policy(cfg.threads, cfg.walks);
    let walks = parallel_map_trials_scratch(cfg.walks, threads, FleetScratch::new, |scratch, i| {
        let (spec, seed) = gen_walk(cfg, i as u64);
        let (events, violation) = run_walk(&spec, seed, cfg.trace_window, scratch);
        (matches!(spec, WalkSpec::Fleet(_)), events, violation.map(|v| (spec, seed, v)))
    });
    let fleet_walks = walks.iter().filter(|w| w.0).count();
    let total_events: u64 = walks.iter().map(|w| w.1).sum();
    let first = walks
        .into_iter()
        .enumerate()
        .find_map(|(walk, (_, _, failed))| failed.map(|f| (walk, f)));
    let failure = first.map(|(walk, (spec, seed, violation))| {
        let shrunk = match &spec {
            WalkSpec::Fleet(f) => shrink_fleet(f, seed, cfg.trace_window, violation.invariant),
            WalkSpec::Episode(_) => None,
        };
        WalkFailure { walk, seed, spec, violation, shrunk }
    });
    ExploreReport {
        walks: cfg.walks,
        fleet_walks,
        episode_walks: cfg.walks - fleet_walks,
        total_events,
        threads,
        failure,
    }
}

/// Decode and replay a repro string against the full checker set; returns
/// the rendered report and whether the invariant violation reproduced.
pub fn run_repro(encoded: &str, seed: u64, window: usize) -> Result<(String, bool), String> {
    let spec = decode_walk(encoded)?;
    let mut scratch = FleetScratch::new();
    let (events, violation) = run_walk(&spec, seed, window, &mut scratch);
    let mut s = String::new();
    let _ = writeln!(s, "repro: {}", walk_dims(&spec));
    match &violation {
        None => {
            let _ = writeln!(s, "ran clean: {events} events, all invariants held");
        }
        Some(v) => {
            let _ = writeln!(s, "violated `{}`: {}", v.invariant, v.detail);
            render_trace(&mut s, v);
        }
    }
    Ok((s, violation.is_some()))
}

// ---------------------------------------------------------------------------
// Shrinker

struct ShrinkCtx<'a> {
    seed: u64,
    window: usize,
    /// Only steps that reproduce this same invariant are accepted.
    target: &'a str,
    scratch: FleetScratch,
    reruns: usize,
}

impl ShrinkCtx<'_> {
    /// Deterministic oracle: does `spec` still violate the target
    /// invariant on this seed?
    fn refails(&mut self, spec: &FleetSpec) -> Option<Violation> {
        if self.reruns >= MAX_RERUNS {
            return None;
        }
        self.reruns += 1;
        let mut obs = InvariantObserver::new(self.window);
        let out = run_fleet_observed(spec, self.seed, &mut self.scratch, &mut obs);
        let (violation, ring) = obs.finish();
        let violation = violation.or_else(|| match check_fleet_outcome(spec, &out) {
            Err((name, detail)) => Some(Violation {
                invariant: name,
                detail,
                at_s: spec.horizon_s,
                event_index: out.events,
                trace: ring,
            }),
            Ok(()) => None,
        });
        violation.filter(|v| v.invariant == self.target)
    }
}

fn trace_arrivals(spec: &FleetSpec) -> &[f64] {
    match &spec.arrivals {
        ArrivalSpec::Trace { at_s } => at_s,
        ArrivalSpec::Poisson { .. } => &[],
    }
}

/// Shrink one integer dimension: try `n/2` then `n-1`, keep stepping while
/// the target invariant still fires.
fn shrink_scalar(
    ctx: &mut ShrinkCtx<'_>,
    cur: &mut FleetSpec,
    best: &mut Violation,
    changed: &mut bool,
    get: impl Fn(&FleetSpec) -> usize,
    set: impl Fn(&mut FleetSpec, usize),
) {
    while get(cur) > 1 && ctx.reruns < MAX_RERUNS {
        let n = get(cur);
        let mut cands = vec![n / 2, n - 1];
        cands.retain(|&t| t >= 1 && t < n);
        cands.dedup();
        let mut stepped = false;
        for t in cands {
            let mut c = cur.clone();
            set(&mut c, t);
            if let Some(v) = ctx.refails(&c) {
                *cur = c;
                *best = v;
                *changed = true;
                stepped = true;
                break;
            }
        }
        if !stepped {
            break;
        }
    }
}

/// Greedily minimize a failing `(spec, seed)` pair dimension-by-dimension
/// — churn, nodes, arrivals, horizon, capacity, checkpoint streams,
/// sub-jobs — re-running deterministically and accepting a step only when
/// the *same* invariant still fails, until no tried move shrinks further
/// (a greedy local minimum) or the rerun budget is spent. Poisson
/// arrivals are first materialized into an explicit trace (bit-identical
/// substitution — `run_fleet` materializes them through
/// [`sample_arrivals`] itself) so the arrival list can shrink
/// element-by-element. Returns `None` only if the failure does not
/// reproduce at all (impossible for a deterministic violation).
pub fn shrink_fleet(
    spec: &FleetSpec,
    seed: u64,
    window: usize,
    target: &str,
) -> Option<Shrunk> {
    let mut ctx = ShrinkCtx { seed, window, target, scratch: FleetScratch::new(), reruns: 0 };
    let mut cur = spec.clone();
    cur.arrivals = ArrivalSpec::Trace { at_s: sample_arrivals(spec, seed) };
    let mut best = match ctx.refails(&cur) {
        Some(v) => v,
        None => {
            // The substitution is bit-identical by construction, but stay
            // honest: fall back to the original spec.
            cur = spec.clone();
            ctx.refails(&cur)?
        }
    };
    let mut changed = true;
    while changed && ctx.reruns < MAX_RERUNS {
        changed = false;

        // Churn: try dropping it entirely first — the biggest single cut.
        let has_churn = !matches!(&cur.churn, ChurnSpec::Plan(p) if p.events.is_empty());
        if has_churn {
            let mut c = cur.clone();
            c.churn = ChurnSpec::Plan(FailurePlan { events: Vec::new() });
            if let Some(v) = ctx.refails(&c) {
                cur = c;
                best = v;
                changed = true;
            }
        }

        // Fault plane: try turning it off entirely — when the violation
        // survives without network faults, the repro reads much simpler.
        if !cur.faults.is_off() {
            let mut c = cur.clone();
            c.faults = FaultPlane::default();
            if let Some(v) = ctx.refails(&c) {
                cur = c;
                best = v;
                changed = true;
            }
        }

        // Gray plane: same move — a repro without detectors, flapping and
        // fail-slow episodes is the one worth reading first.
        if !cur.gray.is_off() {
            let mut c = cur.clone();
            c.gray = GrayPlane::default();
            if let Some(v) = ctx.refails(&c) {
                cur = c;
                best = v;
                changed = true;
            }
        }

        // Nodes: halve, then decrement; planned failures on dropped nodes
        // go with them.
        shrink_scalar(
            &mut ctx,
            &mut cur,
            &mut best,
            &mut changed,
            |s| s.topo.len(),
            |s, n| {
                s.topo = Topology::ring(n, 2);
                if let ChurnSpec::Plan(p) = &mut s.churn {
                    p.events.retain(|e| e.node.0 < n);
                }
            },
        );

        // Arrivals: binary chunk removal (keep either half) ...
        while ctx.reruns < MAX_RERUNS {
            let at = trace_arrivals(&cur).to_vec();
            if at.len() <= 1 {
                break;
            }
            let half = at.len() / 2;
            let mut stepped = false;
            for cand in [at[..half].to_vec(), at[half..].to_vec()] {
                let mut c = cur.clone();
                c.arrivals = ArrivalSpec::Trace { at_s: cand };
                if let Some(v) = ctx.refails(&c) {
                    cur = c;
                    best = v;
                    changed = true;
                    stepped = true;
                    break;
                }
            }
            if !stepped {
                break;
            }
        }
        // ... then single-arrival removal once the list is small.
        let mut i = 0;
        while ctx.reruns < MAX_RERUNS {
            let at = trace_arrivals(&cur);
            if at.len() <= 1 || at.len() > 64 || i >= at.len() {
                break;
            }
            let mut cand = at.to_vec();
            cand.remove(i);
            let mut c = cur.clone();
            c.arrivals = ArrivalSpec::Trace { at_s: cand };
            if let Some(v) = ctx.refails(&c) {
                cur = c;
                best = v;
                changed = true;
                // the next element shifted into position i — retry it
            } else {
                i += 1;
            }
        }

        // Horizon: halve while the violation still fires.
        while ctx.reruns < MAX_RERUNS {
            let h = cur.horizon_s / 2.0;
            if h < 60.0 {
                break;
            }
            let mut c = cur.clone();
            c.horizon_s = h;
            match ctx.refails(&c) {
                Some(v) => {
                    cur = c;
                    best = v;
                    changed = true;
                }
                None => break,
            }
        }

        shrink_scalar(&mut ctx, &mut cur, &mut best, &mut changed, |s| s.capacity, |s, n| {
            s.capacity = n;
        });
        shrink_scalar(&mut ctx, &mut cur, &mut best, &mut changed, |s| s.ckpt_streams, |s, n| {
            s.ckpt_streams = n;
        });
        shrink_scalar(&mut ctx, &mut cur, &mut best, &mut changed, |s| s.job.n_subs, |s, n| {
            s.job.n_subs = n;
        });
        // Sharded cells: halve toward the unsharded layout. A violation
        // that survives at `cells = 1` is not a sharding bug at all; one
        // that needs cross-cell traffic bottoms out at the smallest cell
        // count whose routing still crosses.
        shrink_scalar(&mut ctx, &mut cur, &mut best, &mut changed, |s| s.cells.get(), |s, n| {
            s.cells = NonZeroUsize::new(n).expect("shrink_scalar keeps n >= 1");
        });

        // Per-node churn: halve the rate toward quiet.
        while ctx.reruns < MAX_RERUNS {
            let rate = match &cur.churn {
                ChurnSpec::PerNode {
                    process: FailureProcess::Poisson { rate_per_window },
                    ..
                } if *rate_per_window > 1e-3 => *rate_per_window,
                _ => break,
            };
            let mut c = cur.clone();
            if let ChurnSpec::PerNode {
                process: FailureProcess::Poisson { rate_per_window },
                ..
            } = &mut c.churn
            {
                *rate_per_window = rate / 2.0;
            }
            match ctx.refails(&c) {
                Some(v) => {
                    cur = c;
                    best = v;
                    changed = true;
                }
                None => break,
            }
        }

        // Planned churn: binary chunk removal over the event list.
        while ctx.reruns < MAX_RERUNS {
            let events = match &cur.churn {
                ChurnSpec::Plan(p) if !p.events.is_empty() => p.events.clone(),
                _ => break,
            };
            let half = events.len() / 2;
            let mut stepped = false;
            for cand in [events[..half].to_vec(), events[half..].to_vec()] {
                if cand.len() == events.len() {
                    continue;
                }
                let mut c = cur.clone();
                c.churn = ChurnSpec::Plan(FailurePlan { events: cand });
                if let Some(v) = ctx.refails(&c) {
                    cur = c;
                    best = v;
                    changed = true;
                    stepped = true;
                    break;
                }
            }
            if !stepped {
                break;
            }
        }
    }
    Some(Shrunk { spec: cur, violation: best, reruns: ctx.reruns })
}

// ---------------------------------------------------------------------------
// Repro codec
//
// One-line `key=value;` strings. Every f64 is its exact bit pattern in
// hex, so a pasted repro replays the identical trajectory. The codec
// covers the generator/shrinker dialect: Placentia costs, ring(n, 2)
// topologies, Poisson per-node churn or explicit plans.

fn fhex(v: f64) -> String {
    format!("{:016x}", v.to_bits())
}

fn unfhex(s: &str) -> Result<f64, String> {
    u64::from_str_radix(s, 16)
        .map(f64::from_bits)
        .map_err(|e| format!("bad f64 hex {s:?}: {e}"))
}

fn uint<T: std::str::FromStr>(s: &str) -> Result<T, String>
where
    T::Err: std::fmt::Display,
{
    s.parse().map_err(|e| format!("bad integer {s:?}: {e}"))
}

fn strat_str(s: Strategy) -> &'static str {
    match s {
        Strategy::Agent => "agent",
        Strategy::Core => "core",
        Strategy::Hybrid => "hybrid",
        Strategy::ColdRestart => "cold",
        Strategy::Checkpoint(CheckpointStrategy::CentralSingle) => "ckpt",
        Strategy::Checkpoint(CheckpointStrategy::CentralMulti) => "ckpt-multi",
        Strategy::Checkpoint(CheckpointStrategy::Decentral) => "ckpt-decentral",
    }
}

fn dec_strat(s: &str) -> Result<Strategy, String> {
    Ok(match s {
        "agent" => Strategy::Agent,
        "core" => Strategy::Core,
        "hybrid" => Strategy::Hybrid,
        "cold" => Strategy::ColdRestart,
        "ckpt" => Strategy::Checkpoint(CheckpointStrategy::CentralSingle),
        "ckpt-multi" => Strategy::Checkpoint(CheckpointStrategy::CentralMulti),
        "ckpt-decentral" => Strategy::Checkpoint(CheckpointStrategy::Decentral),
        _ => return Err(format!("unknown strategy {s:?}")),
    })
}

fn enc_process(p: &FailureProcess) -> String {
    match p {
        FailureProcess::Periodic { offset_s } => format!("per:{}", fhex(*offset_s)),
        FailureProcess::RandomUniform => "uni".into(),
        FailureProcess::RandomUniformK { k } => format!("unik:{k}"),
        FailureProcess::Poisson { rate_per_window } => format!("poi:{}", fhex(*rate_per_window)),
        FailureProcess::Trace { offsets_s } => {
            let offs: Vec<String> = offsets_s.iter().map(|t| fhex(*t)).collect();
            format!("tr:{}", offs.join("+"))
        }
    }
}

fn dec_process(s: &str) -> Result<FailureProcess, String> {
    if s == "uni" {
        return Ok(FailureProcess::RandomUniform);
    }
    let (tag, rest) = s.split_once(':').ok_or_else(|| format!("bad process {s:?}"))?;
    Ok(match tag {
        "per" => FailureProcess::Periodic { offset_s: unfhex(rest)? },
        "unik" => FailureProcess::RandomUniformK { k: uint(rest)? },
        "poi" => FailureProcess::Poisson { rate_per_window: unfhex(rest)? },
        "tr" => {
            let offsets_s = if rest.is_empty() {
                Vec::new()
            } else {
                rest.split('+').map(unfhex).collect::<Result<_, _>>()?
            };
            FailureProcess::Trace { offsets_s }
        }
        _ => return Err(format!("unknown process {tag:?}")),
    })
}

fn enc_regime(r: &FailureRegime) -> String {
    match r {
        FailureRegime::Single(p) => format!("sg|{}", enc_process(p)),
        FailureRegime::ConcurrentK { k, offset_s, spacing_s } => {
            format!("ck|{k}|{}|{}", fhex(*offset_s), fhex(*spacing_s))
        }
        FailureRegime::Correlated { primary, rack_size, p_spread, lag_s } => format!(
            "co|{}|{rack_size}|{}|{}",
            enc_process(primary),
            fhex(*p_spread),
            fhex(*lag_s)
        ),
        FailureRegime::Cascade { trigger, p_follow, lag_s } => {
            format!("ca|{}|{}|{}", enc_process(trigger), fhex(*p_follow), fhex(*lag_s))
        }
    }
}

fn dec_regime(s: &str) -> Result<FailureRegime, String> {
    let mut it = s.split('|');
    let tag = it.next().ok_or("empty regime")?;
    let mut next = |what: &str| {
        it.next().map(str::to_owned).ok_or_else(|| format!("regime {tag}: missing {what}"))
    };
    Ok(match tag {
        "sg" => FailureRegime::Single(dec_process(&next("process")?)?),
        "ck" => FailureRegime::ConcurrentK {
            k: uint(&next("k")?)?,
            offset_s: unfhex(&next("offset")?)?,
            spacing_s: unfhex(&next("spacing")?)?,
        },
        "co" => FailureRegime::Correlated {
            primary: dec_process(&next("primary")?)?,
            rack_size: uint(&next("rack size")?)?,
            p_spread: unfhex(&next("p_spread")?)?,
            lag_s: unfhex(&next("lag")?)?,
        },
        "ca" => FailureRegime::Cascade {
            trigger: dec_process(&next("trigger")?)?,
            p_follow: unfhex(&next("p_follow")?)?,
            lag_s: unfhex(&next("lag")?)?,
        },
        _ => return Err(format!("unknown regime {tag:?}")),
    })
}

/// Encode a walk spec as a one-line repro string (exact: every f64 is its
/// bit pattern).
pub fn encode_walk(spec: &WalkSpec) -> String {
    match spec {
        WalkSpec::Fleet(f) => {
            let mut s = format!(
                "fleet;s={};n={};cap={};st={};sub={};z={};dkb={};pkb={};cs={};pf={};crs={};cos={};hz={}",
                strat_str(f.job.strategy),
                f.topo.len(),
                f.capacity,
                f.ckpt_streams,
                f.job.n_subs,
                f.job.z,
                f.job.data_kb,
                f.job.proc_kb,
                fhex(f.job.compute_s),
                fhex(f.job.predictable_frac),
                fhex(f.job.ckpt_reinstate_s),
                fhex(f.job.ckpt_overhead_s),
                fhex(f.horizon_s),
            );
            match &f.arrivals {
                ArrivalSpec::Poisson { rate_per_h } => {
                    let _ = write!(s, ";arr=p{}", fhex(*rate_per_h));
                }
                ArrivalSpec::Trace { at_s } => {
                    let ts: Vec<String> = at_s.iter().map(|t| fhex(*t)).collect();
                    let _ = write!(s, ";arr=t{}", ts.join(","));
                }
            }
            match &f.churn {
                ChurnSpec::PerNode { process, window_s, repair_s } => {
                    let _ = write!(
                        s,
                        ";ch=pn|{}|{}|{}",
                        enc_process(process),
                        fhex(*window_s),
                        fhex(*repair_s)
                    );
                }
                ChurnSpec::Plan(p) => {
                    let evs: Vec<String> =
                        p.events.iter().map(|e| format!("{}@{}", e.at.0, e.node.0)).collect();
                    let _ = write!(s, ";ch=pl|{}", evs.join(","));
                }
            }
            // Sharded cells, only when sharded — the unsharded layout
            // (including every pre-shard repro string) omits the key, so
            // old strings keep decoding and re-encode unchanged.
            if f.cells.get() > 1 {
                let _ = write!(s, ";ce={}", f.cells);
            }
            // Fault plane, only when it can perturb a delivery — off planes
            // (including every pre-plane repro string) omit both keys, so
            // old strings keep decoding and re-encode unchanged.
            if !f.faults.is_off() {
                let p = &f.faults;
                let _ = write!(
                    s,
                    ";nf={}+{}+{}+{}+{}+{}+{}+{}+{}+{}+{}+{}+{}",
                    fhex(p.peer.loss_p),
                    fhex(p.peer.dup_p),
                    fhex(p.peer.delay_p),
                    fhex(p.peer.delay_mean_s),
                    fhex(p.ckpt.loss_p),
                    fhex(p.ckpt.dup_p),
                    fhex(p.ckpt.delay_p),
                    fhex(p.ckpt.delay_mean_s),
                    fhex(p.retry.timeout_s),
                    p.retry.max_retries,
                    fhex(p.retry.backoff_base_s),
                    fhex(p.retry.backoff_mult),
                    fhex(p.cold_restore_factor),
                );
                if !p.partitions.is_empty() {
                    let ps: Vec<String> = p
                        .partitions
                        .iter()
                        .map(|q| {
                            let cut = match q.cut {
                                CutSet::Split { at } => format!("s{at}"),
                                CutSet::Checkpoint => "c".into(),
                            };
                            format!("{}@{}@{cut}", fhex(q.start_s), fhex(q.end_s))
                        })
                        .collect();
                    let _ = write!(s, ";np={}", ps.join(","));
                }
            }
            // Gray plane, only when it can perturb the run — off planes
            // (including every pre-gray repro string) omit all four keys,
            // so old strings keep decoding and re-encode unchanged. `gd`
            // additionally requires a detector override.
            if !f.gray.is_off() {
                let g = &f.gray;
                if let Some(d) = &g.detector {
                    let _ = write!(
                        s,
                        ";gd={}+{}+{}",
                        fhex(d.coverage),
                        fhex(d.precision),
                        fhex(d.lead_jitter_s),
                    );
                }
                let _ = write!(
                    s,
                    ";gs={}+{}+{}",
                    fhex(g.fail_slow.rate_per_node_h),
                    fhex(g.fail_slow.mean_duration_s),
                    fhex(g.fail_slow.speed_factor),
                );
                let _ = write!(
                    s,
                    ";gf={}+{}+{}+{}",
                    fhex(g.flapping.rate_per_node_h),
                    g.flapping.burst_len,
                    fhex(g.flapping.down_s),
                    fhex(g.flapping.gap_s),
                );
                let _ = write!(
                    s,
                    ";gq={}+{}+{}+{}",
                    g.quarantine.threshold,
                    fhex(g.quarantine.probation_s),
                    fhex(g.quarantine.backoff_mult),
                    fhex(g.quarantine.max_probation_s),
                );
            }
            s
        }
        WalkSpec::Episode(e) => {
            format!(
                "ep;s={};n={};sub={};z={};dkb={};pkb={};cs={};pf={};crs={};cos={};w={};ws={};rg={}",
                strat_str(e.cfg.strategy),
                e.topo.len(),
                e.cfg.n_subs,
                e.cfg.z,
                e.cfg.data_kb,
                e.cfg.proc_kb,
                fhex(e.cfg.compute_s),
                fhex(e.cfg.predictable_frac),
                fhex(e.cfg.ckpt_reinstate_s),
                fhex(e.cfg.ckpt_overhead_s),
                e.windows,
                fhex(e.window_s),
                enc_regime(&e.regime),
            )
        }
    }
}

/// Decode a repro string produced by [`encode_walk`]. Fleet specs are
/// validated through [`FleetSpec::validate`]; a decoded spec re-encodes to
/// the same string.
pub fn decode_walk(s: &str) -> Result<WalkSpec, String> {
    let mut parts = s.trim().split(';');
    let kind = parts.next().ok_or("empty repro string")?;
    let mut kv: Vec<(&str, &str)> = Vec::new();
    for p in parts {
        if p.is_empty() {
            continue;
        }
        let (k, v) = p.split_once('=').ok_or_else(|| format!("bad field {p:?}"))?;
        kv.push((k, v));
    }
    let get = |k: &str| -> Result<&str, String> {
        kv.iter()
            .find(|(key, _)| *key == k)
            .map(|(_, v)| *v)
            .ok_or_else(|| format!("missing field `{k}`"))
    };
    match kind {
        "fleet" => {
            let n: usize = uint(get("n")?)?;
            if n == 0 {
                return Err("fleet needs at least one node".into());
            }
            let mut f = FleetSpec::placentia_fleet(dec_strat(get("s")?)?, n, 0.0, 0.0);
            f.capacity = uint(get("cap")?)?;
            f.ckpt_streams = uint(get("st")?)?;
            f.job.n_subs = uint(get("sub")?)?;
            f.job.z = uint(get("z")?)?;
            f.job.data_kb = uint(get("dkb")?)?;
            f.job.proc_kb = uint(get("pkb")?)?;
            f.job.compute_s = unfhex(get("cs")?)?;
            f.job.predictable_frac = unfhex(get("pf")?)?;
            f.job.ckpt_reinstate_s = unfhex(get("crs")?)?;
            f.job.ckpt_overhead_s = unfhex(get("cos")?)?;
            f.horizon_s = unfhex(get("hz")?)?;
            let arr = get("arr")?;
            f.arrivals = if let Some(rest) = arr.strip_prefix('p') {
                ArrivalSpec::Poisson { rate_per_h: unfhex(rest)? }
            } else if let Some(rest) = arr.strip_prefix('t') {
                let at_s = if rest.is_empty() {
                    Vec::new()
                } else {
                    rest.split(',').map(unfhex).collect::<Result<_, _>>()?
                };
                ArrivalSpec::Trace { at_s }
            } else {
                return Err(format!("bad arrivals {arr:?}"));
            };
            let ch = get("ch")?;
            f.churn = if let Some(rest) = ch.strip_prefix("pn|") {
                let mut it = rest.split('|');
                let mut next = |what: &str| {
                    it.next().map(str::to_owned).ok_or_else(|| format!("pn churn: missing {what}"))
                };
                ChurnSpec::PerNode {
                    process: dec_process(&next("process")?)?,
                    window_s: unfhex(&next("window")?)?,
                    repair_s: unfhex(&next("repair")?)?,
                }
            } else if let Some(rest) = ch.strip_prefix("pl|") {
                let events = if rest.is_empty() {
                    Vec::new()
                } else {
                    rest.split(',')
                        .map(|e| {
                            let (ns, node) = e
                                .split_once('@')
                                .ok_or_else(|| format!("bad plan event {e:?}"))?;
                            Ok(FailureEvent {
                                at: SimTime(uint(ns)?),
                                node: NodeId(uint(node)?),
                            })
                        })
                        .collect::<Result<Vec<_>, String>>()?
                };
                ChurnSpec::Plan(FailurePlan { events })
            } else {
                return Err(format!("bad churn {ch:?}"));
            };
            // Optional fault-plane keys — absent in every pre-plane repro
            // string, which therefore decodes to the default (off) plane.
            let opt = |k: &str| kv.iter().find(|(key, _)| *key == k).map(|(_, v)| *v);
            if let Some(nf) = opt("nf") {
                let fields: Vec<&str> = nf.split('+').collect();
                if fields.len() != 13 {
                    return Err(format!("nf needs 13 `+`-joined fields, got {}", fields.len()));
                }
                f.faults.peer = LinkFaults {
                    loss_p: unfhex(fields[0])?,
                    dup_p: unfhex(fields[1])?,
                    delay_p: unfhex(fields[2])?,
                    delay_mean_s: unfhex(fields[3])?,
                };
                f.faults.ckpt = LinkFaults {
                    loss_p: unfhex(fields[4])?,
                    dup_p: unfhex(fields[5])?,
                    delay_p: unfhex(fields[6])?,
                    delay_mean_s: unfhex(fields[7])?,
                };
                f.faults.retry = RetryPolicy {
                    timeout_s: unfhex(fields[8])?,
                    max_retries: uint(fields[9])?,
                    backoff_base_s: unfhex(fields[10])?,
                    backoff_mult: unfhex(fields[11])?,
                };
                f.faults.cold_restore_factor = unfhex(fields[12])?;
            }
            if let Some(np) = opt("np") {
                for p in np.split(',') {
                    let mut it = p.split('@');
                    let mut next = |what: &str| {
                        it.next().ok_or_else(|| format!("np partition: missing {what}"))
                    };
                    let start_s = unfhex(next("start")?)?;
                    let end_s = unfhex(next("end")?)?;
                    let cut = next("cut")?;
                    let cut = if let Some(at) = cut.strip_prefix('s') {
                        CutSet::Split { at: uint(at)? }
                    } else if cut == "c" {
                        CutSet::Checkpoint
                    } else {
                        return Err(format!("bad partition cut {cut:?}"));
                    };
                    f.faults.partitions.push(Partition { start_s, end_s, cut });
                }
            }
            // Optional gray-plane keys — absent in every pre-gray repro
            // string, which therefore decodes to the default (off) plane.
            let fields = |v: &str, n: usize, key: &str| -> Result<Vec<String>, String> {
                let fs: Vec<String> = v.split('+').map(str::to_owned).collect();
                if fs.len() != n {
                    return Err(format!("{key} needs {n} `+`-joined fields, got {}", fs.len()));
                }
                Ok(fs)
            };
            if let Some(gd) = opt("gd") {
                let fs = fields(gd, 3, "gd")?;
                f.gray.detector = Some(DetectorModel {
                    coverage: unfhex(&fs[0])?,
                    precision: unfhex(&fs[1])?,
                    lead_jitter_s: unfhex(&fs[2])?,
                });
            }
            if let Some(gs) = opt("gs") {
                let fs = fields(gs, 3, "gs")?;
                f.gray.fail_slow = FailSlow {
                    rate_per_node_h: unfhex(&fs[0])?,
                    mean_duration_s: unfhex(&fs[1])?,
                    speed_factor: unfhex(&fs[2])?,
                };
            }
            if let Some(gf) = opt("gf") {
                let fs = fields(gf, 4, "gf")?;
                f.gray.flapping = Flapping {
                    rate_per_node_h: unfhex(&fs[0])?,
                    burst_len: uint(&fs[1])?,
                    down_s: unfhex(&fs[2])?,
                    gap_s: unfhex(&fs[3])?,
                };
            }
            if let Some(gq) = opt("gq") {
                let fs = fields(gq, 4, "gq")?;
                f.gray.quarantine = QuarantinePolicy {
                    threshold: uint(&fs[0])?,
                    probation_s: unfhex(&fs[1])?,
                    backoff_mult: unfhex(&fs[2])?,
                    max_probation_s: unfhex(&fs[3])?,
                };
            }
            // Optional cell count — absent in every pre-shard repro
            // string, which therefore decodes to the unsharded layout.
            if let Some(ce) = opt("ce") {
                let cells: usize = uint(ce)?;
                f.cells =
                    NonZeroUsize::new(cells).ok_or("cell count must be at least 1")?;
            }
            f.validate().map_err(|e| e.to_string())?;
            Ok(WalkSpec::Fleet(f))
        }
        "ep" => {
            let n: usize = uint(get("n")?)?;
            if n == 0 {
                return Err("episode needs at least one node".into());
            }
            let strategy = dec_strat(get("s")?)?;
            let predictable_frac = unfhex(get("pf")?)?;
            let n_subs: usize = uint(get("sub")?)?;
            if n_subs == 0 {
                return Err("episode needs at least one sub-job".into());
            }
            let regime = dec_regime(get("rg")?)?;
            let mut e = ScenarioSpec::placentia_ring16(strategy, predictable_frac, n_subs, regime);
            e.topo = Topology::ring(n, 2);
            e.windows = uint(get("w")?)?;
            e.window_s = unfhex(get("ws")?)?;
            e.cfg.z = uint(get("z")?)?;
            e.cfg.data_kb = uint(get("dkb")?)?;
            e.cfg.proc_kb = uint(get("pkb")?)?;
            e.cfg.compute_s = unfhex(get("cs")?)?;
            e.cfg.ckpt_reinstate_s = unfhex(get("crs")?)?;
            e.cfg.ckpt_overhead_s = unfhex(get("cos")?)?;
            if e.windows == 0 || !(e.window_s.is_finite() && e.window_s > 0.0) {
                return Err("episode needs positive windows".into());
            }
            Ok(WalkSpec::Episode(e))
        }
        _ => Err(format!("unknown walk kind {kind:?} (expected `fleet` or `ep`)")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn selftest_cfg(fault: InjectedFault) -> VoprCfg {
        VoprCfg {
            walks: 96,
            base_seed: 11,
            max_nodes: 16,
            max_arrivals: 32,
            trace_window: 16,
            threads: Some(1),
            fault: Some(fault),
        }
    }

    /// A hand-built spec where the skipped requeue must fire: two 1-slot
    /// nodes, four 1-sub jobs arriving up front, no churn. Jobs 0 and 1
    /// place; at the first completion the freed slot fits the queue head,
    /// but the corrupted transition never offers it.
    fn skip_requeue_spec() -> FleetSpec {
        let mut spec = FleetSpec::placentia_fleet(Strategy::Hybrid, 2, 0.0, 0.0);
        spec.capacity = 1;
        spec.job.n_subs = 1;
        spec.job.compute_s = 600.0;
        spec.horizon_s = 10_000.0;
        spec.arrivals = ArrivalSpec::Trace { at_s: vec![0.0, 1.0, 2.0, 3.0] };
        spec.churn = ChurnSpec::Plan(FailurePlan { events: Vec::new() });
        spec.fault = Some(InjectedFault::SkipRequeue);
        spec
    }

    #[test]
    fn generated_fleets_always_validate() {
        let cfg = VoprCfg { walks: 512, ..Default::default() };
        for i in 0..512 {
            let (spec, _) = gen_walk(&cfg, i);
            match spec {
                WalkSpec::Fleet(f) => f.validate().unwrap(),
                WalkSpec::Episode(e) => {
                    assert!(e.topo.len() >= 2 && e.cfg.n_subs >= 1 && e.windows >= 1);
                }
            }
        }
    }

    #[test]
    fn codec_round_trips_generated_specs() {
        let cfg = VoprCfg { max_nodes: 12, max_arrivals: 24, ..Default::default() };
        for i in 0..64 {
            let (spec, _) = gen_walk(&cfg, i);
            let enc = encode_walk(&spec);
            let dec = decode_walk(&enc).unwrap();
            assert_eq!(enc, encode_walk(&dec), "walk {i} did not round-trip");
        }
    }

    #[test]
    fn decoded_fleet_replays_identically() {
        let cfg = VoprCfg { max_nodes: 8, max_arrivals: 16, ..Default::default() };
        let mut scratch = FleetScratch::new();
        let mut checked = 0;
        for i in 0..32 {
            let (spec, seed) = gen_walk(&cfg, i);
            let WalkSpec::Fleet(f) = &spec else { continue };
            let dec = decode_walk(&encode_walk(&spec)).unwrap();
            let WalkSpec::Fleet(g) = &dec else { panic!("kind changed") };
            let a = crate::scenario::fleet::run_fleet_scratch(f, seed, &mut scratch);
            let b = crate::scenario::fleet::run_fleet_scratch(g, seed, &mut scratch);
            assert_eq!(a.events, b.events, "walk {i} diverged after decode");
            assert_eq!(a.jobs_completed, b.jobs_completed);
            checked += 1;
        }
        assert!(checked > 4, "too few fleet walks sampled");
    }

    #[test]
    fn decode_rejects_garbage() {
        assert!(decode_walk("").is_err());
        assert!(decode_walk("nonsense;n=4").is_err());
        assert!(decode_walk("fleet;n=4").is_err()); // missing fields
        // structurally complete but invalid (zero capacity)
        let mut spec = skip_requeue_spec();
        spec.capacity = 0;
        assert!(decode_walk(&encode_walk(&WalkSpec::Fleet(spec))).is_err());
    }

    #[test]
    fn skipped_requeue_is_detected() {
        let spec = skip_requeue_spec();
        let mut scratch = FleetScratch::new();
        let (_, v) = run_walk(&WalkSpec::Fleet(spec), 7, 16, &mut scratch);
        let v = v.expect("corrupted requeue must violate an invariant");
        assert_eq!(v.invariant, "queue-progress", "{}", v.detail);
        assert!(!v.trace.is_empty(), "violation must carry a trace window");
    }

    #[test]
    fn leaked_slot_is_detected_on_the_leaking_event() {
        let mut spec = skip_requeue_spec();
        spec.fault = Some(InjectedFault::LeakSlot);
        spec.arrivals = ArrivalSpec::Trace { at_s: vec![0.0] };
        let mut scratch = FleetScratch::new();
        let (_, v) = run_walk(&WalkSpec::Fleet(spec), 7, 16, &mut scratch);
        let v = v.expect("leaked slot must violate an invariant");
        assert_eq!(v.invariant, "bookkeeping-agreement", "{}", v.detail);
        assert!(
            matches!(v.trace.last().unwrap().ev, FleetEv::SubDone { .. }),
            "the violating event should be the completing SubDone"
        );
    }

    #[test]
    fn shrinker_minimizes_the_crafted_repro() {
        let spec = skip_requeue_spec();
        let sh = shrink_fleet(&spec, 7, 16, "queue-progress").expect("must reproduce");
        assert_eq!(sh.violation.invariant, "queue-progress");
        assert!(sh.reruns >= 2, "shrinking must actually re-run");
        assert!(sh.spec.topo.len() <= 2, "nodes did not shrink: {}", fleet_dims(&sh.spec));
        let arrivals = match &sh.spec.arrivals {
            ArrivalSpec::Trace { at_s } => at_s.len(),
            ArrivalSpec::Poisson { .. } => panic!("shrinker must materialize arrivals"),
        };
        assert!(arrivals <= 2, "arrivals did not shrink: {arrivals}");
        // deterministic: a second shrink lands on the identical spec
        let again = shrink_fleet(&spec, 7, 16, "queue-progress").unwrap();
        assert_eq!(
            encode_walk(&WalkSpec::Fleet(sh.spec.clone())),
            encode_walk(&WalkSpec::Fleet(again.spec)),
        );
    }

    #[test]
    fn explorer_finds_and_shrinks_an_injected_fault() {
        let cfg = selftest_cfg(InjectedFault::SkipRequeue);
        let report = explore(&cfg);
        let f = report.failure.as_ref().expect("armed fault must be found");
        assert_eq!(f.violation.invariant, "queue-progress", "{}", report.render());
        let sh = f.shrunk.as_ref().expect("fleet failures must shrink");
        assert!(
            sh.spec.topo.len() <= 8,
            "shrunk repro too big: {}",
            fleet_dims(&sh.spec)
        );
        let arrivals = trace_arrivals(&sh.spec).len();
        assert!(arrivals <= 32, "shrunk repro keeps {arrivals} arrivals");
        // the report carries a copy-pasteable repro that replays the
        // violation
        let enc = encode_walk(&WalkSpec::Fleet(sh.spec.clone()));
        let rendered = report.render();
        assert!(rendered.contains(&enc), "render must embed the repro string");
        // the explorer is deterministic end to end
        let again = explore(&cfg);
        let g = again.failure.as_ref().unwrap();
        assert_eq!(f.walk, g.walk);
        assert_eq!(f.seed, g.seed);
        assert_eq!(enc, encode_walk(&WalkSpec::Fleet(g.shrunk.as_ref().unwrap().spec.clone())));
    }

    #[test]
    fn repro_string_replays_the_injected_violation() {
        let spec = skip_requeue_spec();
        let enc = encode_walk(&WalkSpec::Fleet(spec));
        let (report, violated) = run_repro(&enc, 7, 16).unwrap();
        assert!(violated, "repro must reproduce: {report}");
        assert!(report.contains("queue-progress"));
    }

    /// A hand-built spec where the armed [`InjectedFault::DropSpawnAck`]
    /// must fire: every failure is predicted (`pf = 1.0`), one planned
    /// failure strikes node 0 mid-compute, so node 0's prediction attempts
    /// a migration whose SpawnAck the corrupted transition swallows.
    fn drop_spawn_ack_spec() -> FleetSpec {
        let mut spec = FleetSpec::placentia_fleet(Strategy::Hybrid, 2, 0.0, 0.0);
        spec.capacity = 2;
        spec.job.n_subs = 1;
        spec.job.compute_s = 600.0;
        spec.job.predictable_frac = 1.0;
        spec.horizon_s = 10_000.0;
        spec.arrivals = ArrivalSpec::Trace { at_s: vec![0.0, 1.0] };
        spec.churn = ChurnSpec::Plan(FailurePlan {
            events: vec![FailureEvent { at: SimTime::from_secs(300.0), node: NodeId(0) }],
        });
        spec.fault = Some(InjectedFault::DropSpawnAck);
        spec
    }

    #[test]
    fn dropped_spawn_ack_is_detected_by_no_lost_job() {
        let spec = drop_spawn_ack_spec();
        let mut scratch = FleetScratch::new();
        let (_, v) = run_walk(&WalkSpec::Fleet(spec), 7, 16, &mut scratch);
        let v = v.expect("a swallowed SpawnAck must strand a sub-job");
        assert_eq!(v.invariant, "no-lost-job", "{}", v.detail);
        assert!(!v.trace.is_empty(), "violation must carry a trace window");
    }

    #[test]
    fn shrinker_minimizes_the_dropped_ack_repro() {
        let spec = drop_spawn_ack_spec();
        let sh = shrink_fleet(&spec, 7, 16, "no-lost-job").expect("must reproduce");
        assert_eq!(sh.violation.invariant, "no-lost-job");
        assert!(sh.spec.topo.len() <= 2, "nodes did not shrink: {}", fleet_dims(&sh.spec));
        let arrivals = match &sh.spec.arrivals {
            ArrivalSpec::Trace { at_s } => at_s.len(),
            ArrivalSpec::Poisson { .. } => panic!("shrinker must materialize arrivals"),
        };
        assert!(arrivals <= 2, "arrivals did not shrink: {arrivals}");
    }

    #[test]
    fn explorer_finds_the_dropped_ack_fault() {
        // More walks than the requeue self-test: the drop only fires on
        // walks that sample both a predictable failure mix and churn.
        let cfg = VoprCfg { walks: 256, ..selftest_cfg(InjectedFault::DropSpawnAck) };
        let report = explore(&cfg);
        let f = report.failure.as_ref().expect("armed fault must be found");
        assert_eq!(f.violation.invariant, "no-lost-job", "{}", report.render());
        assert!(f.shrunk.is_some(), "fleet failures must shrink");
    }

    #[test]
    fn pre_fault_plane_repro_strings_still_decode() {
        // Captured verbatim from the encoder *before* the fault plane
        // existed. It must decode to an off plane and re-encode untouched.
        let legacy = "fleet;s=hybrid;n=4;cap=2;st=2;sub=1;z=4;dkb=524288;pkb=524288;\
                      cs=409c200000000000;pf=0000000000000000;crs=408a800000000000;\
                      cos=407e500000000000;hz=40cc200000000000;arr=t0000000000000000;ch=pl|";
        let legacy: String = legacy.split_whitespace().collect();
        let dec = decode_walk(&legacy).unwrap();
        let WalkSpec::Fleet(f) = &dec else { panic!("kind changed") };
        assert!(f.faults.is_off(), "absent keys must decode to the off plane");
        assert_eq!(encode_walk(&dec), legacy, "legacy strings must re-encode unchanged");
    }

    #[test]
    fn fault_plane_codec_round_trips() {
        let mut spec = skip_requeue_spec();
        spec.fault = None;
        spec.faults.peer =
            LinkFaults { loss_p: 0.1, dup_p: 0.05, delay_p: 0.25, delay_mean_s: 0.75 };
        spec.faults.ckpt =
            LinkFaults { loss_p: 0.02, dup_p: 0.0, delay_p: 0.4, delay_mean_s: 1.5 };
        spec.faults.retry = RetryPolicy {
            timeout_s: 0.75,
            max_retries: 3,
            backoff_base_s: 0.5,
            backoff_mult: 1.5,
        };
        spec.faults.partitions = vec![
            Partition { start_s: 100.0, end_s: 400.0, cut: CutSet::Split { at: 1 } },
            Partition { start_s: 900.0, end_s: 1200.0, cut: CutSet::Checkpoint },
        ];
        let enc = encode_walk(&WalkSpec::Fleet(spec.clone()));
        assert!(enc.contains(";nf="), "faulted plane must encode its link/retry block");
        assert!(enc.contains(";np="), "partitions must encode");
        let dec = decode_walk(&enc).unwrap();
        let WalkSpec::Fleet(g) = &dec else { panic!("kind changed") };
        assert_eq!(g.faults, spec.faults, "decoded plane must equal the original");
        assert_eq!(encode_walk(&dec), enc, "codec must round-trip byte-for-byte");
    }

    #[test]
    fn sampled_fault_planes_always_validate() {
        let cfg = VoprCfg { walks: 512, ..Default::default() };
        let mut faulted = 0;
        for i in 0..512 {
            let (spec, _) = gen_walk(&cfg, i);
            if let WalkSpec::Fleet(f) = spec {
                if !f.faults.is_off() {
                    faulted += 1;
                }
            }
        }
        assert!(faulted > 32, "too few faulted planes sampled: {faulted}");
    }

    #[test]
    fn sampled_gray_planes_always_validate() {
        // `gen_walk` debug-asserts validate() on every fleet; here we only
        // need to know the gray dimension actually gets exercised.
        let cfg = VoprCfg { walks: 512, ..Default::default() };
        let mut gray = 0;
        for i in 0..512 {
            let (spec, _) = gen_walk(&cfg, i);
            if let WalkSpec::Fleet(f) = spec {
                f.validate().unwrap();
                if !f.gray.is_off() {
                    gray += 1;
                }
            }
        }
        assert!(gray > 32, "too few gray planes sampled: {gray}");
    }

    #[test]
    fn pre_gray_plane_repro_strings_still_decode() {
        // The same frozen pre-plane literal: absent gray keys must decode
        // to the off plane and re-encode untouched.
        let legacy = "fleet;s=hybrid;n=4;cap=2;st=2;sub=1;z=4;dkb=524288;pkb=524288;\
                      cs=409c200000000000;pf=0000000000000000;crs=408a800000000000;\
                      cos=407e500000000000;hz=40cc200000000000;arr=t0000000000000000;ch=pl|";
        let legacy: String = legacy.split_whitespace().collect();
        let dec = decode_walk(&legacy).unwrap();
        let WalkSpec::Fleet(f) = &dec else { panic!("kind changed") };
        assert!(f.gray.is_off(), "absent keys must decode to the off plane");
        assert_eq!(f.gray, GrayPlane::default());
        assert_eq!(encode_walk(&dec), legacy, "legacy strings must re-encode unchanged");
    }

    #[test]
    fn gray_plane_codec_round_trips() {
        let mut spec = skip_requeue_spec();
        spec.fault = None;
        spec.gray.detector =
            Some(DetectorModel { coverage: 0.29, precision: 0.64, lead_jitter_s: 10.0 });
        spec.gray.fail_slow =
            FailSlow { rate_per_node_h: 0.5, mean_duration_s: 450.0, speed_factor: 0.3 };
        spec.gray.flapping =
            Flapping { rate_per_node_h: 1.25, burst_len: 4, down_s: 45.0, gap_s: 90.0 };
        spec.gray.quarantine = QuarantinePolicy {
            threshold: 2,
            probation_s: 300.0,
            backoff_mult: 1.5,
            max_probation_s: 3600.0,
        };
        let enc = encode_walk(&WalkSpec::Fleet(spec.clone()));
        for key in [";gd=", ";gs=", ";gf=", ";gq="] {
            assert!(enc.contains(key), "active gray plane must encode {key}");
        }
        let dec = decode_walk(&enc).unwrap();
        let WalkSpec::Fleet(g) = &dec else { panic!("kind changed") };
        assert_eq!(g.gray, spec.gray, "decoded plane must equal the original");
        assert_eq!(encode_walk(&dec), enc, "codec must round-trip byte-for-byte");
    }

    /// A hand-built spec where the armed [`InjectedFault::QuarantineLeak`]
    /// must fire: flap bursts of 3 exactly meet the default suspicion
    /// threshold, but the leak never quarantines, so the third unabsorbed
    /// flap-down leaves suspicion at the threshold on a placeable node.
    fn quarantine_leak_spec() -> FleetSpec {
        let mut spec = FleetSpec::placentia_fleet(Strategy::Hybrid, 2, 0.0, 0.0);
        spec.capacity = 2;
        spec.job.n_subs = 1;
        spec.job.compute_s = 600.0;
        spec.horizon_s = 10_000.0;
        spec.arrivals = ArrivalSpec::Trace { at_s: vec![0.0, 1.0] };
        spec.churn = ChurnSpec::Plan(FailurePlan { events: Vec::new() });
        spec.gray.flapping.rate_per_node_h = 2.0;
        spec.fault = Some(InjectedFault::QuarantineLeak);
        spec
    }

    #[test]
    fn quarantine_leak_is_detected_by_storm_bound() {
        let spec = quarantine_leak_spec();
        assert!(!spec.gray.is_off());
        let mut scratch = FleetScratch::new();
        let (_, v) = run_walk(&WalkSpec::Fleet(spec.clone()), 7, 16, &mut scratch);
        let v = v.expect("a leaked quarantine must violate an invariant");
        assert_eq!(v.invariant, "storm-bound", "{}", v.detail);
        assert!(!v.trace.is_empty(), "violation must carry a trace window");
        // the same plane without the leak holds every invariant
        let mut clean = spec;
        clean.fault = None;
        let (_, v) = run_walk(&WalkSpec::Fleet(clean), 7, 16, &mut scratch);
        assert!(v.is_none(), "unleaked quarantine must pass: {v:?}");
    }

    #[test]
    fn shrinker_minimizes_the_quarantine_leak_repro() {
        let spec = quarantine_leak_spec();
        let sh = shrink_fleet(&spec, 7, 16, "storm-bound").expect("must reproduce");
        assert_eq!(sh.violation.invariant, "storm-bound");
        assert!(sh.spec.topo.len() <= 2, "nodes did not shrink: {}", fleet_dims(&sh.spec));
        assert!(
            !sh.spec.gray.is_off(),
            "the zero-gray step must be rejected — the leak needs flapping"
        );
    }

    /// A hand-built spec where the armed [`InjectedFault::EpochLeak`] must
    /// fire: a 2-sub job lands one sub per node, an unpredicted failure
    /// kills node 1's sub, and the recovery's `RecoveryDone` — staged in
    /// node 1's cell, destined for the job's cell 0 — is the first
    /// job-carrying message to cross cells, so the leak swallows it. The
    /// fleet then drains with the job still live: only the
    /// job-conservation quiescence clause can see the loss.
    fn epoch_leak_spec() -> FleetSpec {
        let mut spec = FleetSpec::placentia_fleet(Strategy::Hybrid, 2, 0.0, 0.0);
        spec.capacity = 1;
        spec.job.n_subs = 2;
        spec.job.compute_s = 600.0;
        spec.job.predictable_frac = 0.0; // reactive only: no migrations
        spec.horizon_s = 10_000.0;
        spec.arrivals = ArrivalSpec::Trace { at_s: vec![0.0] };
        spec.churn = ChurnSpec::Plan(FailurePlan {
            events: vec![FailureEvent { at: SimTime::from_secs(300.0), node: NodeId(1) }],
        });
        spec.cells = NonZeroUsize::new(7).unwrap();
        spec.fault = Some(InjectedFault::EpochLeak);
        spec
    }

    #[test]
    fn epoch_leak_is_detected_by_job_conservation() {
        let spec = epoch_leak_spec();
        let mut scratch = FleetScratch::new();
        let (_, v) = run_walk(&WalkSpec::Fleet(spec.clone()), 7, 16, &mut scratch);
        let v = v.expect("a leaked cross-cell message must violate an invariant");
        assert_eq!(v.invariant, "job-conservation", "{}", v.detail);
        assert!(
            v.detail.contains("lost its scheduled continuation"),
            "the quiescence clause must be the one that fires: {}",
            v.detail
        );
        // the same sharded fleet without the leak holds every invariant
        let mut clean = spec;
        clean.fault = None;
        let (_, v) = run_walk(&WalkSpec::Fleet(clean), 7, 16, &mut scratch);
        assert!(v.is_none(), "unleaked sharded run must pass: {v:?}");
    }

    #[test]
    fn shrinker_minimizes_the_epoch_leak_repro() {
        let spec = epoch_leak_spec();
        let sh = shrink_fleet(&spec, 7, 16, "job-conservation").expect("must reproduce");
        assert_eq!(sh.violation.invariant, "job-conservation");
        assert!(sh.spec.topo.len() <= 2, "nodes did not shrink: {}", fleet_dims(&sh.spec));
        // cells = 1 can never cross, so the leak needs at least 2 — and
        // the scalar shrinker must land exactly there from 7
        assert_eq!(
            sh.spec.cells.get(),
            2,
            "cells must shrink to the smallest layout that still crosses"
        );
    }

    #[test]
    fn pre_shard_repro_strings_still_decode() {
        // The same frozen pre-plane literal: an absent `ce` key must
        // decode to the unsharded layout and re-encode untouched.
        let legacy = "fleet;s=hybrid;n=4;cap=2;st=2;sub=1;z=4;dkb=524288;pkb=524288;\
                      cs=409c200000000000;pf=0000000000000000;crs=408a800000000000;\
                      cos=407e500000000000;hz=40cc200000000000;arr=t0000000000000000;ch=pl|";
        let legacy: String = legacy.split_whitespace().collect();
        let dec = decode_walk(&legacy).unwrap();
        let WalkSpec::Fleet(f) = &dec else { panic!("kind changed") };
        assert_eq!(f.cells.get(), 1, "absent `ce` must decode to the unsharded layout");
        assert_eq!(encode_walk(&dec), legacy, "legacy strings must re-encode unchanged");
    }

    #[test]
    fn sharded_cells_codec_round_trips() {
        let mut spec = skip_requeue_spec();
        spec.fault = None;
        spec.cells = NonZeroUsize::new(5).unwrap();
        let enc = encode_walk(&WalkSpec::Fleet(spec.clone()));
        assert!(enc.contains(";ce=5"), "sharded specs must encode the cell count");
        let dec = decode_walk(&enc).unwrap();
        let WalkSpec::Fleet(g) = &dec else { panic!("kind changed") };
        assert_eq!(g.cells, spec.cells);
        assert_eq!(encode_walk(&dec), enc, "codec must round-trip byte-for-byte");
        // the unsharded layout omits the key entirely
        spec.cells = NonZeroUsize::MIN;
        assert!(!encode_walk(&WalkSpec::Fleet(spec)).contains(";ce="));
    }

    #[test]
    fn sampled_cell_counts_exercise_sharding() {
        let cfg = VoprCfg { walks: 512, ..Default::default() };
        let mut sharded = 0;
        for i in 0..512 {
            let (spec, _) = gen_walk(&cfg, i);
            if let WalkSpec::Fleet(f) = spec {
                if f.cells.get() > 1 {
                    assert!((2..=8).contains(&f.cells.get()), "cells {} out of range", f.cells);
                    sharded += 1;
                }
            }
        }
        assert!(sharded > 32, "too few sharded fleets sampled: {sharded}");
    }
}
