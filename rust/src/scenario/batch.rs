//! The parallel batch runner: fan thousands of seeded scenario trials
//! across OS threads and summarise them.
//!
//! Every trial owns its engine, RNG and actor state (see
//! [`ScenarioSpec::run_trial`]), so a batch is embarrassingly parallel.
//! Trials are keyed by **trial index** — trial `i` always runs seed
//! `base_seed + i` and its result always lands in slot `i` — so a batch's
//! output is byte-identical whatever the thread count (including 1). That
//! invariant is what lets `coordinator::run` and the experiment sweeps use
//! this runner while still reproducing the paper's tables exactly.
//!
//! ## Scheduling (see DESIGN.md §Hot path)
//!
//! Work distribution is an atomic-counter chunk scheduler: workers claim
//! the next chunk of trial indices with one `fetch_add` and write results
//! into their disjoint slots. Unlike the old static contiguous partition,
//! a worker that drew cheap trials steals the next chunk instead of going
//! idle — which matters for skewed regimes (`Cascade` trials vary widely in
//! cost) — while results stay keyed by index, so output is still
//! byte-identical for any thread count. Workers carry a
//! [`LiveScratch`](crate::coordinator::livesim::LiveScratch) across their
//! trials, so steady-state trials allocate nothing but the failure plan.

use super::spec::ScenarioSpec;
use crate::coordinator::livesim::LiveScratch;
use crate::metrics::Summary;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::time::Instant;

/// How to run a batch.
#[derive(Debug, Clone)]
pub struct BatchCfg {
    pub trials: usize,
    /// Trial `i` runs with seed `base_seed + i`.
    pub base_seed: u64,
    /// OS threads to fan across; `0` ⇒ one per available core.
    pub threads: usize,
}

/// Aggregate of one batch, ready for tables/figures.
#[derive(Debug, Clone)]
pub struct BatchOutcome {
    pub trials: usize,
    pub threads: usize,
    /// Summary of per-trial completion times (seconds of virtual time).
    pub completed_s: Summary,
    pub migrations: u64,
    pub rollbacks: u64,
    pub cascades: u64,
    pub lost_then_recovered: u64,
    /// Total dispatched events across the batch.
    pub events: u64,
    /// Wall-clock cost of the batch and derived throughput.
    pub wall_s: f64,
    pub trials_per_s: f64,
}

/// One thread per available core (the scheduler's default).
pub fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Below this many trials an *unconfigured* sweep stays serial (thread
/// spawn would cost more than it buys). Both overrides beat it — this is a
/// default, not the silent hard floor the old `PARALLEL_TRIAL_THRESHOLD`
/// constant was.
pub const SERIAL_TRIAL_THRESHOLD: usize = 64;

/// Resolve the worker-thread count for a sweep of `trials` trials
/// (EXPERIMENTS.md §Perf):
///
/// 1. an explicit request wins (`Some(0)` ⇒ one per core);
/// 2. else the `BIOMAFT_THREADS` env var, when set and parsable
///    (`0` ⇒ one per core) — the CLI's `--threads` sets this;
/// 3. else serial below [`SERIAL_TRIAL_THRESHOLD`] trials, one thread per
///    core at or above it.
///
/// Thread count never changes any result (the batch contract), only wall
/// time, so the policy is free to be heuristic.
pub fn thread_policy(requested: Option<usize>, trials: usize) -> usize {
    let resolve = |t: usize| if t == 0 { default_threads() } else { t };
    if let Some(t) = requested {
        return resolve(t);
    }
    if let Some(t) =
        std::env::var("BIOMAFT_THREADS").ok().and_then(|v| v.trim().parse::<usize>().ok())
    {
        return resolve(t);
    }
    if trials >= SERIAL_TRIAL_THRESHOLD {
        default_threads()
    } else {
        1
    }
}

/// Chunk of trial indices claimed per `fetch_add`: small enough that a
/// skewed tail rebalances, large enough to amortise the atomic and keep
/// result writes cache-friendly.
fn steal_chunk(n: usize, threads: usize) -> usize {
    (n / (threads * 8)).clamp(1, 1024)
}

/// A raw, `Send`able pointer to the result slots. Workers write only the
/// indices they claimed from the atomic counter, so all writes are
/// disjoint.
struct Slots<T>(*mut Option<T>);
unsafe impl<T: Send> Send for Slots<T> {}

/// Fan `n` independent trials across `threads` OS threads; trial `i`'s
/// result lands in slot `i`, so the output is independent of thread count
/// and scheduling. `threads == 0` uses [`default_threads`].
pub fn parallel_map_trials<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    parallel_map_trials_scratch(n, threads, || (), |_, i| f(i))
}

/// [`parallel_map_trials`] with per-worker carried state: each worker calls
/// `init()` once and threads the value through every trial it claims — the
/// hook by which batch workers reuse a [`LiveScratch`] (or any other
/// scratch) across trials.
///
/// Results are keyed by trial index; they are independent of the thread
/// count **iff `f(scratch, i)` is a pure function of `i`** — the scratch
/// must only carry allocations, never state that changes an output. Which
/// trials share a worker's scratch depends on chunk claiming, so a
/// result-affecting scratch would silently break the crate's
/// byte-identical-batch contract (`LiveScratch` reuse is property-tested
/// for exactly this in `tests/harness_properties.rs`).
pub fn parallel_map_trials_scratch<T, C, I, F>(n: usize, threads: usize, init: I, f: F) -> Vec<T>
where
    T: Send,
    I: Fn() -> C + Sync,
    F: Fn(&mut C, usize) -> T + Sync,
{
    let threads = if threads == 0 { default_threads() } else { threads };
    if threads <= 1 || n <= 1 {
        let mut scratch = init();
        return (0..n).map(|i| f(&mut scratch, i)).collect();
    }
    let mut results: Vec<Option<T>> = (0..n).map(|_| None).collect();
    let chunk = steal_chunk(n, threads);
    let next = AtomicUsize::new(0);
    let base = results.as_mut_ptr();
    std::thread::scope(|s| {
        for _ in 0..threads.min(n) {
            let next = &next;
            let init = &init;
            let f = &f;
            let slots = Slots(base);
            s.spawn(move || {
                let mut scratch = init();
                loop {
                    let start = next.fetch_add(chunk, Ordering::Relaxed);
                    if start >= n {
                        break;
                    }
                    let end = (start + chunk).min(n);
                    for i in start..end {
                        let v = f(&mut scratch, i);
                        // SAFETY: `fetch_add` hands out disjoint index
                        // ranges, so slot `i` is written by exactly one
                        // worker; the slots vec outlives the scope and
                        // every slot is initialised (`None`), so the
                        // replaced value drops correctly.
                        unsafe { *slots.0.add(i) = Some(v) };
                    }
                }
            });
        }
    });
    results.into_iter().map(|o| o.expect("every trial completed")).collect()
}

fn summarize(
    threads: usize,
    base: &BatchCfg,
    outcomes: &[crate::coordinator::livesim::LiveOutcome],
    wall_s: f64,
) -> BatchOutcome {
    let completed: Vec<f64> = outcomes.iter().map(|o| o.completed_at_s).collect();
    BatchOutcome {
        trials: base.trials,
        threads,
        completed_s: Summary::of(&completed),
        migrations: outcomes.iter().map(|o| o.migrations as u64).sum(),
        rollbacks: outcomes.iter().map(|o| o.rollbacks as u64).sum(),
        cascades: outcomes.iter().map(|o| o.cascades as u64).sum(),
        lost_then_recovered: outcomes.iter().map(|o| o.lost_then_recovered as u64).sum(),
        events: outcomes.iter().map(|o| o.events).sum(),
        wall_s,
        trials_per_s: if wall_s > 0.0 { base.trials as f64 / wall_s } else { f64::INFINITY },
    }
}

/// Run `cfg.trials` seeded trials of `spec` and summarise them.
pub fn run_batch(spec: &ScenarioSpec, cfg: &BatchCfg) -> BatchOutcome {
    assert!(cfg.trials > 0, "empty batch");
    let threads = if cfg.threads == 0 { default_threads() } else { cfg.threads };
    let t0 = Instant::now();
    let outcomes = parallel_map_trials_scratch(cfg.trials, threads, LiveScratch::new, |sc, i| {
        spec.run_trial_scratch(cfg.base_seed.wrapping_add(i as u64), sc)
    });
    let wall_s = t0.elapsed().as_secs_f64();
    summarize(threads, cfg, &outcomes, wall_s)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::ftmanager::Strategy;
    use crate::scenario::spec::FailureRegime;

    fn spec() -> ScenarioSpec {
        ScenarioSpec::placentia_ring16(
            Strategy::Hybrid,
            0.8,
            8,
            FailureRegime::ConcurrentK { k: 3, offset_s: 600.0, spacing_s: 60.0 },
        )
    }

    #[test]
    fn parallel_map_preserves_trial_order() {
        let out = parallel_map_trials(100, 4, |i| i * i);
        assert_eq!(out.len(), 100);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i * i);
        }
    }

    #[test]
    fn parallel_map_serial_fallbacks() {
        assert_eq!(parallel_map_trials(1, 8, |i| i), vec![0]);
        assert_eq!(parallel_map_trials(3, 1, |i| i + 1), vec![1, 2, 3]);
        assert!(parallel_map_trials(0, 4, |i| i).is_empty());
    }

    #[test]
    fn parallel_map_more_threads_than_trials() {
        let out = parallel_map_trials(3, 16, |i| i);
        assert_eq!(out, vec![0, 1, 2]);
    }

    #[test]
    fn parallel_map_scratch_carries_per_worker_state() {
        // every worker's scratch counts the trials it executed; the counts
        // must partition the index set exactly
        let executed = AtomicUsize::new(0);
        let out = parallel_map_trials_scratch(
            200,
            4,
            || 0usize,
            |count, i| {
                *count += 1;
                executed.fetch_add(1, Ordering::Relaxed);
                i
            },
        );
        assert_eq!(out, (0..200).collect::<Vec<_>>());
        assert_eq!(executed.load(Ordering::Relaxed), 200);
    }

    #[test]
    fn steal_chunk_bounds() {
        assert_eq!(steal_chunk(1, 8), 1);
        assert_eq!(steal_chunk(64, 8), 1);
        assert_eq!(steal_chunk(2000, 8), 31);
        assert_eq!(steal_chunk(1_000_000, 2), 1024);
    }

    #[test]
    fn batch_output_independent_of_thread_count() {
        let s = spec();
        let serial = run_batch(&s, &BatchCfg { trials: 24, base_seed: 9, threads: 1 });
        let parallel = run_batch(&s, &BatchCfg { trials: 24, base_seed: 9, threads: 4 });
        assert_eq!(serial.completed_s, parallel.completed_s);
        assert_eq!(serial.migrations, parallel.migrations);
        assert_eq!(serial.rollbacks, parallel.rollbacks);
        assert_eq!(serial.events, parallel.events);
    }

    #[test]
    fn batch_feeds_summary() {
        let s = spec();
        let b = run_batch(&s, &BatchCfg { trials: 16, base_seed: 1, threads: 0 });
        assert_eq!(b.completed_s.n, 16);
        // failures strike: completion can never beat the nominal job time
        assert!(b.completed_s.min >= 3600.0);
        assert!(b.trials_per_s > 0.0);
        assert!(b.events > 0);
    }

    #[test]
    fn default_threads_positive() {
        assert!(default_threads() >= 1);
    }

    #[test]
    fn thread_policy_explicit_beats_everything() {
        assert_eq!(thread_policy(Some(3), 1), 3);
        assert_eq!(thread_policy(Some(0), 1), default_threads());
    }

    #[test]
    fn thread_policy_trial_default() {
        // no explicit request: serial below the threshold, parallel at it
        // (assumes BIOMAFT_THREADS is unset in the test environment; the
        // env arm itself is covered by the explicit-request equivalence)
        if std::env::var("BIOMAFT_THREADS").is_err() {
            assert_eq!(thread_policy(None, SERIAL_TRIAL_THRESHOLD - 1), 1);
            assert_eq!(thread_policy(None, SERIAL_TRIAL_THRESHOLD), default_threads());
        }
    }
}
