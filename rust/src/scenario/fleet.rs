//! Fleet-scale continuous cluster simulation: many concurrent jobs, a job
//! arrival process, online placement, per-strategy fault tolerance and
//! long-horizon node churn — the production case the paper's one-job-one-
//! failure experiments only gesture at (DESIGN.md §Fleet simulator).
//!
//! Where [`ScenarioSpec`](super::spec::ScenarioSpec) runs exactly one job
//! per trial, a [`FleetSpec`] trial plays out a whole *cluster lifetime* on
//! the same [`sim::harness`](crate::sim::harness) DES:
//!
//! * **jobs arrive** by a Poisson process or an explicit trace
//!   ([`ArrivalSpec`]) and are placed by an online least-loaded policy over
//!   the healthy nodes (all-or-wait: a job that does not fit queues FIFO
//!   and is retried whenever a job completes or a node rejoins);
//! * **nodes churn** ([`ChurnSpec`]): each node draws its own
//!   [`failure::injector`](crate::failure::injector) plan over consecutive
//!   windows, fails, repairs after `repair_s` and rejoins — or an explicit
//!   [`FailurePlan`] dooms nodes once, exactly like the single-job runs;
//! * **fault tolerance is per-strategy**
//!   ([`Strategy`](crate::coordinator::ftmanager::Strategy)): multi-agent
//!   jobs migrate proactively under neighbour-*capacity* pressure (a full
//!   or doomed neighbour is not a candidate), falling back to a flat
//!   checkpoint rollback for unpredicted failures; checkpoint-family jobs
//!   recover reactively only, and every concurrent recovery contends for
//!   the shared checkpoint server — conceptually the hub of the star
//!   topology — which sustains [`FleetSpec::ckpt_streams`] full-speed
//!   transfers (a recovery admitted as the `k`-th concurrent stream pays
//!   `⌈k / streams⌉ ×` the base reinstate transfer);
//! * **fleet metrics** stream out per trial ([`FleetOutcome`]): goodput,
//!   the job slowdown distribution, time-weighted node utilization (the
//!   time-weighted mode of
//!   [`metrics::Accumulator`](crate::metrics::Accumulator)), and
//!   rollback/migration storm peaks.
//!
//! ## Scale (DESIGN.md §Fleet simulator, §Event queue)
//!
//! The implementation is sized for 10k-node / 1M-arrival lifetimes
//! (`benches/fleet.rs`):
//!
//! * placement reads the cheapest node from a [`PlacementIndex`] — a
//!   `BTreeSet<(load, node)>` over healthy, non-full nodes maintained
//!   incrementally on place/complete/fail/repair — O(log n) per sub-job
//!   instead of the old O(n) full scan, with the *same* tie-break (lowest
//!   load, then lowest node index);
//! * jobs live in a generation-checked slab ([`JobSlab`]): a completed
//!   job's slot (and its per-sub vectors) is recycled for a later arrival,
//!   so a lifetime allocates O(peak live jobs), not O(total arrivals), and
//!   any stale in-flight event (an aborted migration's `MigrationDone`)
//!   misses on its generation instead of touching the new tenant;
//! * each node keeps the ordered set of non-done sub-jobs it hosts, so the
//!   prediction/failure handlers scan O(subs on the node) instead of the
//!   whole job table — in exactly the old scan order (jobs by arrival
//!   index, subs by index), which keeps the RNG draw sequence identical.
//!
//! ## Determinism
//!
//! A fleet trial is a **pure function of `(spec, seed)`**: arrivals draw
//! from `Rng::new(seed ^ ARRIVAL_SALT)`, churn plans from
//! `Rng::new(seed ^ CHURN_SALT)` (one forked stream per node), and the
//! in-run dynamics from the harness stream `Rng::new(seed).fork(1)` with
//! per-failure predictability flags off the root — and network fault draws
//! from the stateless side-stream keyed by `(seed ^ FAULT_SALT, edge, seq)`
//! ([`net::faults`](crate::net::faults)), which touches no other stream.
//! This is the *same* stream
//! discipline as [`run_live`](crate::coordinator::livesim::run_live), so a
//! degenerate fleet (one traced job at t = 0, an explicit churn plan, no
//! binding capacity) reproduces `run_live`'s completion time, migrations
//! and rollbacks **exactly** (property-tested in
//! `tests/fleet_properties.rs`). Fleet sweep cells are trial-seeded like
//! scenario cells, so `fleet` grids inherit the executor's
//! byte-identical-at-any-thread-count contract. The placement index, the
//! slab and the per-node lists are pure lookup structures: they change no
//! draw and no event, and a mid-size trial is property-tested byte-
//! identical through them at thread counts 1 and 8.
//!
//! ## Sharded cells (DESIGN.md §Sharded cells)
//!
//! At 100k nodes a single timer wheel, placement set and job arena stop
//! scaling, so the cluster is partitioned into [`FleetSpec::cells`]
//! loosely-coupled cells (node `v` → cell `v % cells`, job `j` → cell
//! `j % cells`): each cell owns its own wheel in a
//! [`ShardedQueue`](crate::sim::ShardedQueue), its own availability set in
//! the [`PlacementIndex`] and its own [`JobSlab`] arena. Cross-cell
//! traffic (a migration landing in another cell, a recovery resolving a
//! job homed elsewhere) is exchanged only at event boundaries through the
//! staging buffer, routed and merged in deterministic order. Sequence
//! numbers are *banded* — `(band << 62) | counter` with setup bands for
//! arrivals, churn and flap-downs below the run band — so the global
//! min-(time, seq) pop order is one total order no matter how entries are
//! distributed: `cells = 1` is byte-identical to the pre-shard path and
//! any two cell counts are byte-identical to each other (property-tested
//! in `tests/fleet_sharding.rs`). Per-node churn plans are materialized
//! *lazily*, one window at a time ahead of the clock ([`Rng::fork_key`]
//! keeps the per-node stream position-independent), so setup no longer
//! allocates O(nodes) plans upfront.

use crate::cluster::{preset, ClusterPreset};
use crate::coordinator::ftmanager::Strategy;
use crate::coordinator::livesim::{migration_net_cost, LiveCfg};
use crate::failure::gray::{self, GrayPlane};
use crate::failure::injector::{FailureEvent, FailurePlan, FailureProcess};
use crate::hybrid::rules::{decide, Mover, RuleInputs};
use crate::metrics::Accumulator;
use crate::net::faults::{self, FaultPlane};
use crate::net::{NodeId, Topology};
use crate::sim::engine::pack_key;
use crate::sim::{Rng, ShardedQueue, SimTime};
use std::cmp::Reverse;
use std::collections::{BTreeSet, BinaryHeap, VecDeque};
use std::num::NonZeroUsize;

/// Salt separating the arrival stream from the dynamics stream.
const ARRIVAL_SALT: u64 = 0xA11_1FEE7_0F_A17A;
/// Salt separating the churn-plan stream from the dynamics stream.
const CHURN_SALT: u64 = 0xC0_0C_CC_0C_FA_11_5A_17;

/// The job arrival process of a fleet trial.
#[derive(Debug, Clone)]
pub enum ArrivalSpec {
    /// Poisson arrivals at `rate_per_h` jobs per hour of virtual time.
    Poisson { rate_per_h: f64 },
    /// Explicit arrival times in seconds (arrivals at or past the horizon
    /// are dropped, like the Poisson process; the single-entry `vec![0.0]`
    /// is the degenerate one-job fleet).
    Trace { at_s: Vec<f64> },
}

/// The node churn process of a fleet trial.
#[derive(Debug, Clone)]
pub enum ChurnSpec {
    /// An explicit, pre-built failure plan; struck nodes never repair.
    /// This is the single-job-regime-compatible mode: with the plan of a
    /// `run_live` trial, the degenerate fleet replays it event for event.
    Plan(FailurePlan),
    /// Continuous churn: every node runs its own copy of `process` over
    /// consecutive `window_s` windows (its plan drawn from a per-node
    /// forked stream — `failure::injector` reused node by node), fails,
    /// repairs `repair_s` later and rejoins. A planned failure striking a
    /// node that is still down is absorbed (a node is doomed at most once
    /// per up-period).
    PerNode { process: FailureProcess, window_s: f64, repair_s: f64 },
}

/// What one fleet sweep cell measures per trial (see
/// [`CellKind::Fleet`](super::sweep::CellKind)).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FleetMetric {
    /// Mean job slowdown `(finish − arrival) / nominal` over completed
    /// jobs; NaN when a trial completes no job (NaN propagates through the
    /// cell summary per the [`Summary`](crate::metrics::Summary) contract).
    MeanSlowdown,
    /// Completed nominal compute seconds per cluster slot-second.
    Goodput,
    /// Time-weighted running-slot fraction.
    Utilization,
}

impl FleetMetric {
    /// Extract the measured value from a trial outcome.
    pub fn measure(self, o: &FleetOutcome) -> f64 {
        match self {
            FleetMetric::MeanSlowdown => o.mean_slowdown,
            FleetMetric::Goodput => o.goodput_ratio,
            FleetMetric::Utilization => o.utilization,
        }
    }
}

/// A complete fleet scenario: the job population, the cluster, and how it
/// all fails.
#[derive(Debug, Clone)]
pub struct FleetSpec {
    /// The job template: costs, strategy, sub-job count, sizes, nominal
    /// per-sub compute and the reactive recovery figures. `job.seed` is
    /// ignored — the trial seed governs every stream.
    pub job: LiveCfg,
    /// The migration/probing neighbourhood (agents move along its edges).
    pub topo: Topology,
    /// Concurrent sub-job slots per node; placement and migration never
    /// overfill a node.
    pub capacity: usize,
    pub arrivals: ArrivalSpec,
    pub churn: ChurnSpec,
    /// Concurrent recoveries the shared checkpoint server sustains at full
    /// speed (checkpoint-family jobs only; multi-agent backstop rollbacks
    /// are flat, exactly as in the single-job live simulation).
    pub ckpt_streams: usize,
    /// Virtual-time horizon of one trial in seconds.
    pub horizon_s: f64,
    /// Loosely-coupled cells the cluster is partitioned into (node `v` →
    /// cell `v % cells`, job `j` → cell `j % cells`): each cell owns its
    /// own timer wheel, placement availability set and job arena, and
    /// cross-cell traffic merges in deterministic order at event
    /// boundaries. **Any** value produces byte-identical trials — `cells`
    /// is a performance knob, not a semantics knob (property-tested in
    /// `tests/fleet_sharding.rs`); 1 is the unsharded layout.
    pub cells: NonZeroUsize,
    /// The network fault plane ([`net::faults`](crate::net::faults)):
    /// per-link-class message loss/duplication/extra delay, timed
    /// partitions, and the timeout/retry/backoff constants every recovery
    /// exchange runs under. [`FaultPlane::default`] is **off** and leaves
    /// every trial byte-identical to a build without the plane.
    pub faults: FaultPlane,
    /// The gray-failure plane ([`failure::gray`](crate::failure::gray)):
    /// imperfect detector (coverage/precision/lead jitter, with
    /// false-positive predictions on healthy nodes), fail-slow episodes,
    /// flapping churn, and the suspicion/quarantine placement policy.
    /// [`GrayPlane::default`] is **off** and leaves every trial
    /// byte-identical to a build without the plane (property-tested).
    pub gray: GrayPlane,
    /// Deliberate single-transition corruption for the VOPR self-test
    /// (`scenario::vopr`): proves the invariant checkers fire and the
    /// shrinker converges. Compiled out of normal builds — it exists only
    /// under `cfg(test)` and the `vopr-selftest` feature, so production
    /// code cannot even name it. Carried in the spec (not a thread-local)
    /// so a faulty walk stays deterministic under any thread count.
    #[cfg(any(test, feature = "vopr-selftest"))]
    pub fault: Option<InjectedFault>,
}

/// Which transition the VOPR self-test corrupts (see [`FleetSpec::fault`]).
#[cfg(any(test, feature = "vopr-selftest"))]
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum InjectedFault {
    /// Skip the wait-queue requeue after a job completion: freed slots are
    /// never offered to queued jobs. Caught by the queue-progress checker.
    SkipRequeue,
    /// Leak the completed sub-job's occupancy slot (skip the placement-
    /// index decrement). Caught by the bookkeeping-agreement checker on
    /// the very event that leaks.
    LeakSlot,
    /// Drop every `SpawnAck`: the migration handshake can never complete
    /// *and* the (deliberately broken) protocol abandons the sub-job
    /// instead of falling back to checkpoint recovery — the exact bug the
    /// PR-8 hardening exists to prevent. Caught by the no-lost-job
    /// checker on the abandoning `Prediction` event.
    DropSpawnAck,
    /// Never quarantine: suspicion accrues past the policy threshold but
    /// the node is never excluded from placement — the migration-storm
    /// bound silently evaporates. Caught by the storm-bound checker on the
    /// first event that crosses the threshold.
    QuarantineLeak,
    /// Drop the first job-carrying event routed *across* cells at an
    /// epoch boundary (a migration landing, recovery resolution or
    /// completion whose destination cell differs from the dispatching
    /// cell) — the classic sharding bug where cross-cell traffic leaks at
    /// the exchange. The job's continuation silently vanishes while every
    /// counter stays self-consistent; caught by the job-conservation
    /// checker's quiescence clause at end of trial, and the shrinker
    /// converges to the minimal cell count that still crosses (≤ 2 beyond
    /// the unsharded layout).
    EpochLeak,
}

impl FleetSpec {
    /// The shared fleet fixture (experiments, benches and tests build on
    /// this one): Placentia costs at the Table-1 point, 8-sub half-hour
    /// jobs on a ring(`nodes`, 2) landscape with 2 slots per node, Poisson
    /// arrivals, per-node Poisson churn (1-hour windows, 15-minute
    /// repairs), a 2-stream checkpoint server and a 4-hour horizon.
    pub fn placentia_fleet(
        strategy: Strategy,
        nodes: usize,
        arrival_per_h: f64,
        churn_per_node_h: f64,
    ) -> Self {
        let job = LiveCfg {
            costs: preset(ClusterPreset::Placentia).costs,
            strategy,
            n_subs: 8,
            z: 4,
            data_kb: 1 << 19,
            proc_kb: 1 << 19,
            compute_s: 1800.0,
            predictable_frac: 0.9,
            ckpt_reinstate_s: 848.0,
            ckpt_overhead_s: 485.0,
            seed: 0,
        };
        Self {
            job,
            topo: Topology::ring(nodes, 2),
            capacity: 2,
            arrivals: ArrivalSpec::Poisson { rate_per_h: arrival_per_h },
            churn: ChurnSpec::PerNode {
                process: FailureProcess::Poisson { rate_per_window: churn_per_node_h },
                window_s: 3600.0,
                repair_s: 900.0,
            },
            ckpt_streams: 2,
            horizon_s: 4.0 * 3600.0,
            cells: NonZeroUsize::MIN,
            faults: FaultPlane::default(),
            gray: GrayPlane::default(),
            #[cfg(any(test, feature = "vopr-selftest"))]
            fault: None,
        }
    }

    /// A large-fleet lifetime sized so the cluster runs ~90% loaded: each
    /// 8-sub, 1800 s job consumes 4 slot-hours, a ring(`nodes`, 2) cluster
    /// at 2 slots/node clears `nodes / 2` jobs per hour, so the Poisson
    /// rate is `0.9 × nodes / 2` and the horizon is stretched until the
    /// expected arrival count reaches `arrivals`. This is the shape of the
    /// `fleet-scale` experiment and the 10k-node / 1M-arrival bench target
    /// (ROADMAP "Scale the fleet sim").
    pub fn scale_fleet(
        strategy: Strategy,
        nodes: usize,
        arrivals: usize,
        churn_per_node_h: f64,
    ) -> Self {
        let rate_per_h = 0.9 * nodes as f64 / 2.0;
        let horizon_s = arrivals as f64 / rate_per_h * 3600.0;
        let mut spec = Self::placentia_fleet(strategy, nodes, rate_per_h, churn_per_node_h);
        spec.horizon_s = horizon_s;
        spec
    }

    /// Validate the spec as user/generator input: structural minimums
    /// (nodes, slots, streams, sub-jobs ≥ 1) and finite, sensible numbers
    /// everywhere a rate or duration enters the simulation. This is the
    /// one validation layer shared by the `biomaft fleet` CLI and the
    /// `scenario::vopr` spec generator, so generated specs can never be
    /// vacuously invalid. [`run_fleet`] itself stays more permissive (the
    /// degenerate zero-horizon fleet is well-defined and tested).
    pub fn validate(&self) -> Result<(), SpecError> {
        if self.topo.len() == 0 {
            return Err(SpecError::NoNodes);
        }
        if self.capacity == 0 {
            return Err(SpecError::ZeroCapacity);
        }
        if self.ckpt_streams == 0 {
            return Err(SpecError::ZeroStreams);
        }
        if self.job.n_subs == 0 {
            return Err(SpecError::ZeroSubs);
        }
        if !self.horizon_s.is_finite() || self.horizon_s <= 0.0 {
            return Err(SpecError::BadHorizon(self.horizon_s));
        }
        if !self.job.compute_s.is_finite() || self.job.compute_s <= 0.0 {
            return Err(SpecError::BadComputeTime(self.job.compute_s));
        }
        let pf = self.job.predictable_frac;
        if !pf.is_finite() || !(0.0..=1.0).contains(&pf) {
            return Err(SpecError::BadPredictableFrac(pf));
        }
        for d in [self.job.ckpt_reinstate_s, self.job.ckpt_overhead_s] {
            if !d.is_finite() || d < 0.0 {
                return Err(SpecError::BadRecoveryTime(d));
            }
        }
        match &self.arrivals {
            ArrivalSpec::Poisson { rate_per_h } => {
                if !rate_per_h.is_finite() || *rate_per_h < 0.0 {
                    return Err(SpecError::BadArrivalRate(*rate_per_h));
                }
            }
            ArrivalSpec::Trace { at_s } => {
                for &t in at_s {
                    if !t.is_finite() || t < 0.0 {
                        return Err(SpecError::BadArrivalTime(t));
                    }
                }
            }
        }
        match &self.churn {
            // explicit plans carry integer SimTimes: nothing to reject
            ChurnSpec::Plan(_) => {}
            ChurnSpec::PerNode { process, window_s, repair_s } => {
                if !window_s.is_finite() || *window_s <= 0.0 {
                    return Err(SpecError::BadChurnWindow(*window_s));
                }
                if !repair_s.is_finite() || *repair_s < 0.0 {
                    return Err(SpecError::BadRepairTime(*repair_s));
                }
                validate_process(process)?;
            }
        }
        self.faults.validate()?;
        self.gray.validate()?;
        Ok(())
    }
}

/// Finite-and-sensible check on a churn process's own parameters.
fn validate_process(p: &FailureProcess) -> Result<(), SpecError> {
    match p {
        FailureProcess::Periodic { offset_s } => {
            if !offset_s.is_finite() || *offset_s < 0.0 {
                return Err(SpecError::BadChurnRate(*offset_s));
            }
        }
        FailureProcess::Poisson { rate_per_window } => {
            if !rate_per_window.is_finite() || *rate_per_window < 0.0 {
                return Err(SpecError::BadChurnRate(*rate_per_window));
            }
        }
        FailureProcess::Trace { offsets_s } => {
            for &t in offsets_s {
                if !t.is_finite() || t < 0.0 {
                    return Err(SpecError::BadChurnRate(t));
                }
            }
        }
        FailureProcess::RandomUniform | FailureProcess::RandomUniformK { .. } => {}
    }
    Ok(())
}

/// Structured rejection from [`FleetSpec::validate`] — one variant per
/// checked field, so callers (CLI, vopr generator tests) can match on the
/// exact failure instead of parsing a message.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum SpecError {
    /// The topology has no nodes.
    NoNodes,
    /// `capacity` is 0 — nodes need at least one sub-job slot.
    ZeroCapacity,
    /// `ckpt_streams` is 0 — the checkpoint server needs a stream.
    ZeroStreams,
    /// `job.n_subs` is 0 — jobs need at least one sub-job.
    ZeroSubs,
    /// `horizon_s` is not a finite number > 0.
    BadHorizon(f64),
    /// `job.compute_s` is not a finite number > 0.
    BadComputeTime(f64),
    /// A Poisson arrival rate is not finite and ≥ 0.
    BadArrivalRate(f64),
    /// A traced arrival time is not finite and ≥ 0.
    BadArrivalTime(f64),
    /// A churn-process parameter (rate, offset or traced time) is not
    /// finite and ≥ 0.
    BadChurnRate(f64),
    /// A per-node churn window is not a finite number > 0.
    BadChurnWindow(f64),
    /// `repair_s` is not finite and ≥ 0.
    BadRepairTime(f64),
    /// `job.predictable_frac` is outside `[0, 1]`.
    BadPredictableFrac(f64),
    /// A reactive recovery figure (`ckpt_reinstate_s`/`ckpt_overhead_s`)
    /// is not finite and ≥ 0.
    BadRecoveryTime(f64),
    /// A fault-plane loss/duplication/delay probability is outside `[0, 1]`.
    BadFaultProbability,
    /// A fault-plane extra-delay mean is not finite and ≥ 0.
    BadFaultDelay,
    /// A retry policy is degenerate: non-positive timeout, negative
    /// backoff, multiplier below 1 or more than 64 retransmissions.
    BadRetryPolicy,
    /// A partition window is not a finite `[start, end)` with
    /// `0 ≤ start < end`.
    BadPartitionWindow,
    /// A split partition cuts at node 0 (an empty side is no partition).
    BadPartitionCut,
    /// `cold_restore_factor` is not finite and ≥ 1.
    BadColdRestoreFactor,
    /// A link's one-way latency is not finite and ≥ 0.
    BadLinkLatency,
    /// A link's bandwidth is not finite and > 0 (zero would make every
    /// transfer time infinite).
    BadLinkBandwidth,
    /// A link's per-message software overhead is not finite and ≥ 0.
    BadLinkOverhead,
    /// A detector model is out of range: coverage outside `[0, 1]`,
    /// precision outside `(0, 1]` (0 would mean all noise, unbounded false
    /// alarms) or a non-finite/negative lead jitter.
    BadDetector,
    /// A fail-slow episode spec is out of range: negative rate/duration or
    /// a speed factor outside `(0, 1]` (0 would be fail-stop, not
    /// fail-slow).
    BadFailSlow,
    /// A flapping spec is out of range: negative rate, empty or oversized
    /// burst, non-positive down time or negative gap.
    BadFlapping,
    /// A quarantine policy is degenerate: non-positive probation,
    /// multiplier below 1 or a ceiling below the first probation.
    BadQuarantine,
}

impl std::fmt::Display for SpecError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SpecError::NoNodes => write!(f, "fleet needs at least 1 node"),
            SpecError::ZeroCapacity => write!(f, "capacity must be at least 1 slot per node"),
            SpecError::ZeroStreams => {
                write!(f, "the checkpoint server needs at least 1 recovery stream")
            }
            SpecError::ZeroSubs => write!(f, "jobs need at least 1 sub-job"),
            SpecError::BadHorizon(v) => write!(f, "horizon must be a finite number > 0, got {v}"),
            SpecError::BadComputeTime(v) => {
                write!(f, "compute time must be a finite number > 0, got {v}")
            }
            SpecError::BadArrivalRate(v) => {
                write!(f, "arrival rate must be a finite number >= 0, got {v}")
            }
            SpecError::BadArrivalTime(v) => {
                write!(f, "traced arrival times must be finite and >= 0, got {v}")
            }
            SpecError::BadChurnRate(v) => {
                write!(f, "churn process parameters must be finite and >= 0, got {v}")
            }
            SpecError::BadChurnWindow(v) => {
                write!(f, "churn window must be a finite number > 0, got {v}")
            }
            SpecError::BadRepairTime(v) => {
                write!(f, "repair time must be finite and >= 0, got {v}")
            }
            SpecError::BadPredictableFrac(v) => {
                write!(f, "predictable fraction must be in [0, 1], got {v}")
            }
            SpecError::BadRecoveryTime(v) => {
                write!(f, "recovery figures must be finite and >= 0, got {v}")
            }
            SpecError::BadFaultProbability => {
                write!(f, "fault probabilities must be in [0, 1]")
            }
            SpecError::BadFaultDelay => {
                write!(f, "fault delay mean must be finite and >= 0")
            }
            SpecError::BadRetryPolicy => write!(
                f,
                "retry policy needs timeout > 0, backoff >= 0, multiplier >= 1, retries <= 64"
            ),
            SpecError::BadPartitionWindow => {
                write!(f, "partition windows must satisfy 0 <= start < end, finite")
            }
            SpecError::BadPartitionCut => {
                write!(f, "split partitions must cut at node index >= 1")
            }
            SpecError::BadColdRestoreFactor => {
                write!(f, "cold restore factor must be finite and >= 1")
            }
            SpecError::BadLinkLatency => {
                write!(f, "link latency must be finite and >= 0")
            }
            SpecError::BadLinkBandwidth => {
                write!(f, "link bandwidth must be finite and > 0")
            }
            SpecError::BadLinkOverhead => {
                write!(f, "link software overhead must be finite and >= 0")
            }
            SpecError::BadDetector => {
                write!(f, "detector needs coverage in [0, 1], precision in (0, 1], jitter >= 0")
            }
            SpecError::BadFailSlow => {
                write!(f, "fail-slow needs rate/duration >= 0 and speed factor in (0, 1]")
            }
            SpecError::BadFlapping => {
                write!(f, "flapping needs rate >= 0, 1..=64 downs per burst, down > 0, gap >= 0")
            }
            SpecError::BadQuarantine => write!(
                f,
                "quarantine needs probation > 0, backoff multiplier >= 1, ceiling >= probation"
            ),
        }
    }
}

impl std::error::Error for SpecError {}

/// Aggregate of one fleet trial.
#[derive(Debug, Clone)]
pub struct FleetOutcome {
    /// Jobs whose arrival fell inside the horizon.
    pub jobs_arrived: usize,
    pub jobs_completed: usize,
    /// Jobs still queued for placement when the horizon struck.
    pub jobs_waiting: usize,
    /// Completed nominal compute seconds per cluster slot-second
    /// (`horizon × nodes × capacity`); NaN on a zero-size fleet.
    pub goodput_ratio: f64,
    /// Mean `(finish − arrival) / nominal` over completed jobs; NaN when
    /// none completed.
    pub mean_slowdown: f64,
    /// 95th-percentile slowdown; NaN when none completed.
    pub p95_slowdown: f64,
    /// Virtual time of the last job completion (0 when none completed).
    pub last_completion_s: f64,
    /// Time-weighted fraction of the cluster's total slots
    /// (`nodes × capacity`, down nodes included) running sub-job compute
    /// over `[0, horizon]` ([`Accumulator::push_weighted`]); always in
    /// `[0, 1]`, NaN only for a zero-length horizon.
    pub utilization: f64,
    pub migrations: usize,
    pub rollbacks: usize,
    /// Sub-jobs lost to failures and later recovered from checkpoint.
    pub subs_lost: usize,
    /// Follow-on node failures absorbed because the node was already down.
    pub absorbed_failures: usize,
    /// Peak concurrent in-flight migrations (migration storms).
    pub peak_concurrent_migrations: usize,
    /// Peak concurrent rollback recoveries (rollback storms / checkpoint-
    /// server queueing).
    pub peak_concurrent_recoveries: usize,
    /// Peak simultaneously-live jobs — the slab's actual footprint, which
    /// is what a lifetime allocates for (versus `jobs_arrived` it merely
    /// counts through).
    pub peak_live_jobs: usize,
    /// Message retransmissions spent by recovery exchanges under the fault
    /// plane (0 when the plane is off).
    pub net_retries: u64,
    /// Exchange attempts that timed out (lost request/ack or partition).
    pub net_timeouts: u64,
    /// Recoveries that fell back a rung on the ladder: migrations whose
    /// handshake exhausted its retries (→ reactive checkpoint recovery)
    /// plus restores whose server exchange exhausted (→ degraded cold
    /// restore). Never a lost job.
    pub fallbacks: u64,
    /// Duplicate deliveries suppressed by receivers (counted, free).
    pub dup_suppressed: u64,
    /// Migrations triggered by false-positive predictions on healthy
    /// nodes (full migration cost for nothing; 0 when the gray plane is
    /// off or the detector is perfect).
    pub spurious_migrations: u64,
    /// Nodes quarantined by the suspicion policy (repeat offenders
    /// excluded from placement with exponential probation backoff).
    pub quarantines: u64,
    /// Quarantine probations that expired, returning the node to the
    /// placement pool. Always ≤ `quarantines`; equal at quiescence.
    pub quarantine_releases: u64,
    /// Total node-seconds spent in fail-slow episodes (sum of merged
    /// degraded windows across nodes; 0 when the plane is off).
    pub degraded_node_s: f64,
    /// Dispatched DES events (determinism fingerprint — byte-identical
    /// across cell counts and thread counts).
    pub events: u64,
}

/// Compact, copyable description of one dispatched fleet event, handed to
/// a [`FleetObserver`] after the handler ran. Jobs are named by slab slot
/// (`slot`) or arrival index (`job`) — cheap `u32`s, not handles — because
/// the observer only labels, never dereferences.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FleetEv {
    /// Job `job` (arrival-order index) arrived.
    Arrival { job: u32 },
    /// Node `node` was doomed (`predictable` ⇒ a prediction fired too).
    Doom { node: u32, predictable: bool },
    /// The proactive prediction scan ran on `node`.
    Prediction { node: u32 },
    /// Node `node`'s hardware failed.
    Failure { node: u32 },
    /// Node `node` repaired and rejoined the pool.
    Repair { node: u32 },
    /// A migration of `(slot, sub)` to node `to` resolved; `landed` is
    /// false when the move had been aborted or superseded in flight.
    MigrationDone { slot: u32, sub: u32, to: u32, landed: bool },
    /// Rollback recovery `rec` of job `slot` completed.
    RecoveryDone { slot: u32, rec: u32 },
    /// Sub-job `(slot, sub)` completed; `job_completed` when it was the
    /// job's last (the wait queue is drained on exactly these events).
    SubDone { slot: u32, sub: u32, job_completed: bool },
    /// A false-positive prediction fired on (healthy) node `node`.
    FalseAlarm { node: u32 },
    /// Node `node`'s quarantine probation expired; it rejoined placement.
    QuarantineRelease { node: u32 },
}

impl std::fmt::Display for FleetEv {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FleetEv::Arrival { job } => write!(f, "Arrival job={job}"),
            FleetEv::Doom { node, predictable } => {
                write!(f, "Doom node={node} predictable={predictable}")
            }
            FleetEv::Prediction { node } => write!(f, "Prediction node={node}"),
            FleetEv::Failure { node } => write!(f, "Failure node={node}"),
            FleetEv::Repair { node } => write!(f, "Repair node={node}"),
            FleetEv::MigrationDone { slot, sub, to, landed } => {
                write!(f, "MigrationDone slot={slot} sub={sub} to={to} landed={landed}")
            }
            FleetEv::RecoveryDone { slot, rec } => {
                write!(f, "RecoveryDone slot={slot} rec={rec}")
            }
            FleetEv::SubDone { slot, sub, job_completed } => {
                write!(f, "SubDone slot={slot} sub={sub} job_completed={job_completed}")
            }
            FleetEv::FalseAlarm { node } => write!(f, "FalseAlarm node={node}"),
            FleetEv::QuarantineRelease { node } => {
                write!(f, "QuarantineRelease node={node}")
            }
        }
    }
}

/// A consistent snapshot of the fleet's bookkeeping after one event, built
/// only when an observer is enabled. Counter fields come straight off the
/// system's counters; the `hosted`/`sub_*`/`distinct_recs` fields are re-derived
/// from the slab and the per-node lists, so an invariant checker can
/// compare the two views of the same facts. Plain values and slices — a
/// test can hand-build one.
pub struct FleetView<'a> {
    /// Virtual time of the event just handled.
    pub now: SimTime,
    /// Sub-jobs per job (`spec.job.n_subs`).
    pub n_subs: usize,
    /// Slots per node (`spec.capacity`).
    pub capacity: usize,
    /// Jobs whose `Arrival` has dispatched.
    pub arrived: usize,
    /// Jobs completed (and retired).
    pub completed: usize,
    /// Live jobs in the slab (placed + queued).
    pub live_jobs: usize,
    /// Jobs in the wait queue.
    pub queued: usize,
    /// The system's Running-sub counter (utilization integrand).
    pub running: usize,
    /// The system's in-flight migration counter.
    pub migr_inflight: usize,
    /// The system's in-flight rollback-recovery counter.
    pub rec_inflight: usize,
    /// Per-node occupancy from the placement index.
    pub occupancy: &'a [usize],
    /// Per-node down-flag from the placement index.
    pub doomed: &'a [bool],
    /// Per-node non-done sub-job count from the per-node lists
    /// (independently derived; must agree with `occupancy`).
    pub hosted: &'a [usize],
    /// Running subs counted by slab walk (must equal `running`).
    pub sub_running: usize,
    /// Migrating subs counted by slab walk (must equal `migr_inflight`).
    pub sub_migrating: usize,
    /// Distinct recovery ids among Recovering subs (must equal
    /// `rec_inflight`).
    pub distinct_recs: usize,
    /// Every live job's `remaining` equals its non-Done sub count.
    pub remaining_ok: bool,
    /// Per-node list entries pointing at dead/moved subs (must be 0).
    pub stale_node_subs: usize,
    /// Sub-jobs abandoned with no scheduled resume — a recovery that
    /// neither completed, fell back nor rescheduled. Must always be 0:
    /// the no-lost-job checker fires on the first abandonment.
    pub abandoned: usize,
    /// Per-node quarantine flag from the placement index.
    pub quarantined: &'a [bool],
    /// Per-node suspicion counter — strictly below `suspicion_threshold`
    /// after every event (crossing it triggers quarantine and a reset; the
    /// storm-bound checker fires if the bound silently evaporates).
    pub suspicion: &'a [u32],
    /// The quarantine policy's threshold (0 = policy disabled).
    pub suspicion_threshold: u32,
    /// The system's quarantine counter.
    pub quarantines: u64,
    /// The system's quarantine-release counter (≤ `quarantines`; equal at
    /// quiescence — every probation is scheduled and must fire).
    pub quarantine_releases: u64,
}

/// Observer hook on the fleet event loop. The unit observer `()` is the
/// no-op: its `ENABLED` is false, every view construction is skipped, and
/// the monomorphized [`run_fleet`] body is the pre-observer code — zero
/// cost, and the byte-identical determinism contract is untouched (an
/// observer draws no randomness and schedules no events; it can only
/// read).
pub trait FleetObserver {
    /// Compile-time gate: view derivation is skipped entirely when false.
    const ENABLED: bool = true;
    /// Called after each event's handler ran, with the post-state view.
    fn after_event(&mut self, ev: FleetEv, view: &FleetView<'_>);
    /// Called once after the trial's final tick. `hit_horizon` is false
    /// when the event queue drained (quiescence) before the horizon.
    fn at_end(&mut self, view: &FleetView<'_>, hit_horizon: bool) {
        let _ = (view, hit_horizon);
    }
}

/// The no-op observer: [`run_fleet`] without invariant checking.
impl FleetObserver for () {
    const ENABLED: bool = false;
    fn after_event(&mut self, _ev: FleetEv, _view: &FleetView<'_>) {}
}

/// Reused buffers for the derived half of a [`FleetView`] (slab walk +
/// per-node list lengths). Refreshed per event only when the observer is
/// enabled — O(nodes + live subs) per refresh, irrelevant at vopr scale
/// and never run on the unobserved path.
#[derive(Debug, Default)]
struct Derive {
    hosted: Vec<usize>,
    recs: Vec<usize>,
    sub_running: usize,
    sub_migrating: usize,
    distinct_recs: usize,
    remaining_ok: bool,
    stale_node_subs: usize,
}

impl Derive {
    fn refresh(&mut self, jobs: &JobSlab, node_subs: &[BTreeSet<NodeSub>]) {
        self.hosted.clear();
        self.hosted.extend(node_subs.iter().map(BTreeSet::len));
        self.recs.clear();
        self.sub_running = 0;
        self.sub_migrating = 0;
        self.remaining_ok = true;
        for rec in jobs.cells.iter().flat_map(|c| c.slots.iter()).filter(|r| r.live) {
            let mut not_done = 0;
            for s in &rec.state {
                match s {
                    SubState::Running { .. } => {
                        self.sub_running += 1;
                        not_done += 1;
                    }
                    SubState::Migrating { .. } => {
                        self.sub_migrating += 1;
                        not_done += 1;
                    }
                    SubState::Recovering { rec: r, .. } => {
                        self.recs.push(*r);
                        not_done += 1;
                    }
                    SubState::Done => {}
                }
            }
            // a queued (never-placed) job has no states yet: remaining 0
            if rec.remaining != not_done {
                self.remaining_ok = false;
            }
        }
        self.recs.sort_unstable();
        self.recs.dedup();
        self.distinct_recs = self.recs.len();
        self.stale_node_subs = 0;
        let ncells = jobs.cells.len().max(1);
        for (v, set) in node_subs.iter().enumerate() {
            for &(arrival, sub, slot) in set {
                let cell = arrival as usize % ncells;
                let ok = jobs
                    .cells
                    .get(cell)
                    .and_then(|c| c.slots.get(slot as usize))
                    .is_some_and(|r| {
                        r.live
                            && r.arrival == arrival
                            && r.host.get(sub as usize) == Some(&NodeId(v))
                            && r.state.get(sub as usize) != Some(&SubState::Done)
                    });
                if !ok {
                    self.stale_node_subs += 1;
                }
            }
        }
    }
}

/// Generation-checked handle into the [`JobSlab`]. A slot's generation
/// bumps when its job retires, so an event that outlives its job (an
/// aborted migration's `MigrationDone`) misses instead of touching the
/// slot's next tenant. The cell rides along because slots are per-cell
/// arenas — `(cell, slot)` is the physical address, and an event carrying
/// a `JobId` routes to `cell` without a global lookup.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
struct JobId {
    cell: u32,
    slot: u32,
    gen: u32,
}

/// One job of the fleet (a slab slot).
#[derive(Debug, Default)]
struct JobRec {
    gen: u32,
    live: bool,
    /// Arrival-order index: the per-node scans iterate `(arrival, sub)`
    /// ascending, reproducing the old full-table scan order exactly.
    arrival: u32,
    arrived_at: SimTime,
    /// Host per sub-job; empty until placed.
    host: Vec<NodeId>,
    state: Vec<SubState>,
    /// Sub-jobs not yet done (completion counter; scans stay draw-free).
    remaining: usize,
}

/// One cell's share of the job arena: its slot storage and free list.
#[derive(Debug, Default)]
struct SlabCell {
    slots: Vec<JobRec>,
    free_slots: Vec<u32>,
}

/// Arena of live jobs, one [`SlabCell`] per fleet cell (job `j` lives in
/// cell `j % cells`). Retired slots (and their per-sub vectors) are
/// reused for later arrivals, so a million-arrival lifetime allocates
/// O(peak live jobs) — the slab never grows past the cluster's actual
/// concurrency, and each cell's arena only past its own.
#[derive(Debug, Default)]
struct JobSlab {
    cells: Vec<SlabCell>,
    live: usize,
    peak_live: usize,
}

impl JobSlab {
    /// Start a fresh trial on recycled slot storage, resized to `ncells`
    /// arenas.
    fn reset(&mut self, ncells: usize) {
        self.cells.truncate(ncells);
        for c in &mut self.cells {
            for r in &mut c.slots {
                r.live = false;
                r.gen = 0;
            }
            c.free_slots.clear();
            c.free_slots.extend((0..c.slots.len() as u32).rev());
        }
        if self.cells.len() < ncells {
            self.cells.resize_with(ncells, SlabCell::default);
        }
        self.live = 0;
        self.peak_live = 0;
    }

    fn alloc(&mut self, cell: u32, arrival: u32, arrived_at: SimTime) -> JobId {
        let c = &mut self.cells[cell as usize];
        let slot = match c.free_slots.pop() {
            Some(s) => s,
            None => {
                c.slots.push(JobRec::default());
                (c.slots.len() - 1) as u32
            }
        };
        let r = &mut c.slots[slot as usize];
        r.live = true;
        r.arrival = arrival;
        r.arrived_at = arrived_at;
        r.host.clear();
        r.state.clear();
        r.remaining = 0;
        self.live += 1;
        self.peak_live = self.peak_live.max(self.live);
        JobId { cell, slot, gen: r.gen }
    }

    /// The job behind `id`, or None when the handle is stale (the job
    /// retired and the slot moved on).
    fn get(&self, id: JobId) -> Option<&JobRec> {
        let r = self.cells.get(id.cell as usize)?.slots.get(id.slot as usize)?;
        (r.live && r.gen == id.gen).then_some(r)
    }

    /// Mutable access for a handle already validated by [`get`](Self::get).
    fn rec_mut(&mut self, id: JobId) -> &mut JobRec {
        let r = &mut self.cells[id.cell as usize].slots[id.slot as usize];
        debug_assert!(r.live && r.gen == id.gen, "stale JobId past validation");
        r
    }

    /// Raw slot access by physical `(cell, slot)` address — the per-node
    /// scans carry the address in their [`NodeSub`] entries (cell derived
    /// from the arrival index), already validated by the set's liveness
    /// discipline.
    fn raw(&self, cell: u32, slot: u32) -> &JobRec {
        &self.cells[cell as usize].slots[slot as usize]
    }

    fn raw_mut(&mut self, cell: u32, slot: u32) -> &mut JobRec {
        &mut self.cells[cell as usize].slots[slot as usize]
    }

    /// Retire a completed job: bump the generation (stale handles miss),
    /// keep the sub-job vectors' capacity for the slot's next tenant.
    fn retire(&mut self, id: JobId) {
        let c = &mut self.cells[id.cell as usize];
        let r = &mut c.slots[id.slot as usize];
        debug_assert!(r.live && r.gen == id.gen, "double retire");
        r.live = false;
        r.gen = r.gen.wrapping_add(1);
        self.live -= 1;
        c.free_slots.push(id.slot);
    }
}

/// The O(log n) placement index: per-node load and health plus one
/// `BTreeSet<(load, node)>` of healthy spare-slot nodes *per cell* (node
/// `v` → cell `v % cells`). `best()` compares the cells' minima, so the
/// global choice — least loaded, ties to the lowest node index — is the
/// *same* choice the old single-set (and before it, the O(n) full scan)
/// made, at any cell count. Maintained incrementally on every occupancy
/// and health transition; load/health vectors stay global (they are flat
/// arrays, cheap at any scale — only the ordered set needed sharding).
#[derive(Debug, Default)]
struct PlacementIndex {
    occupancy: Vec<usize>,
    doomed: Vec<bool>,
    /// Suspicion-policy exclusion flag: a quarantined node keeps hosting
    /// its resident sub-jobs but takes no new placements or migrations
    /// until released ([`failure::gray::QuarantinePolicy`]).
    quarantined: Vec<bool>,
    capacity: usize,
    avail: Vec<BTreeSet<(usize, usize)>>,
}

impl PlacementIndex {
    fn reset(&mut self, n: usize, capacity: usize, ncells: usize) {
        self.occupancy.clear();
        self.occupancy.resize(n, 0);
        self.doomed.clear();
        self.doomed.resize(n, false);
        self.quarantined.clear();
        self.quarantined.resize(n, false);
        self.capacity = capacity;
        self.avail.truncate(ncells);
        for s in &mut self.avail {
            s.clear();
        }
        if self.avail.len() < ncells {
            self.avail.resize_with(ncells, BTreeSet::new);
        }
        for i in 0..n {
            self.avail[i % ncells].insert((0, i));
        }
    }

    /// The cell set holding (or due to hold) `node`'s availability entry.
    fn cell_set(&mut self, node: usize) -> &mut BTreeSet<(usize, usize)> {
        let c = node % self.avail.len();
        &mut self.avail[c]
    }

    /// The least-loaded healthy node with a spare slot (ties to the
    /// lowest node index), or None when the cluster is saturated. The
    /// minimum over the per-cell minima — identical to the single-set
    /// minimum because the sets partition the same entries.
    fn best(&self) -> Option<NodeId> {
        self.avail.iter().filter_map(|s| s.iter().next().copied()).min().map(|(_, n)| NodeId(n))
    }

    fn inc(&mut self, node: NodeId) {
        let o = self.occupancy[node.0];
        let capacity = self.capacity;
        if !self.doomed[node.0] && !self.quarantined[node.0] {
            let set = self.cell_set(node.0);
            if o < capacity {
                set.remove(&(o, node.0));
            }
            if o + 1 < capacity {
                set.insert((o + 1, node.0));
            }
        }
        self.occupancy[node.0] = o + 1;
    }

    fn dec(&mut self, node: NodeId) {
        let o = self.occupancy[node.0];
        debug_assert!(o > 0, "occupancy underflow on node {}", node.0);
        let capacity = self.capacity;
        if !self.doomed[node.0] && !self.quarantined[node.0] {
            let set = self.cell_set(node.0);
            if o < capacity {
                set.remove(&(o, node.0));
            }
            if o - 1 < capacity {
                set.insert((o - 1, node.0));
            }
        }
        self.occupancy[node.0] = o - 1;
    }

    /// Take the node out of the placement pool (load bookkeeping
    /// continues while it is down).
    fn doom(&mut self, node: NodeId) {
        debug_assert!(!self.doomed[node.0], "double doom");
        self.doomed[node.0] = true;
        let o = self.occupancy[node.0];
        self.cell_set(node.0).remove(&(o, node.0));
    }

    fn repair(&mut self, node: NodeId) {
        self.doomed[node.0] = false;
        let o = self.occupancy[node.0];
        if !self.quarantined[node.0] && o < self.capacity {
            self.cell_set(node.0).insert((o, node.0));
        }
    }

    /// Exclude a suspicious node from placement. Resident sub-jobs stay;
    /// load bookkeeping continues while it is out. A doomed node may be
    /// quarantined too — the flags are independent (the avail entry is
    /// already absent then, and `remove` on an absent entry is a no-op).
    fn quarantine(&mut self, node: NodeId) {
        debug_assert!(!self.quarantined[node.0], "double quarantine");
        self.quarantined[node.0] = true;
        let o = self.occupancy[node.0];
        self.cell_set(node.0).remove(&(o, node.0));
    }

    /// Probation expired: readmit the node (unless it is down or full).
    fn release(&mut self, node: NodeId) {
        self.quarantined[node.0] = false;
        let o = self.occupancy[node.0];
        if !self.doomed[node.0] && o < self.capacity {
            self.cell_set(node.0).insert((o, node.0));
        }
    }

    fn is_doomed(&self, node: NodeId) -> bool {
        self.doomed[node.0]
    }

    fn is_quarantined(&self, node: NodeId) -> bool {
        self.quarantined[node.0]
    }

    /// Migration-candidate predicate: healthy, unquarantined, spare slot.
    fn has_slot(&self, node: NodeId) -> bool {
        !self.doomed[node.0] && !self.quarantined[node.0] && self.occupancy[node.0] < self.capacity
    }
}

/// Events of the fleet simulation. The failure-path events mirror
/// [`livesim`](crate::coordinator::livesim)'s exactly — same scheduling
/// order, same RNG draw order — which is what makes the degenerate fleet
/// reduce to `run_live` bit for bit.
#[derive(Debug, Clone)]
enum Ev {
    /// Job `job` (arrival-order index) arrives and requests placement.
    Arrival { job: usize },
    /// A node is doomed: the prediction (if predictable) fires immediately
    /// and the hardware fails `fail_in_s` later. `flap` marks a gray-plane
    /// flap-down: always unpredicted, repaired after the flapping spec's
    /// fast `down_s` instead of the churn `repair_s`, and a suspicion
    /// source for the quarantine policy.
    Doom { node: NodeId, predictable: bool, fail_in_s: f64, flap: bool },
    Prediction { node: NodeId },
    Failure { node: NodeId, flap: bool },
    /// A failed node finishes repair and rejoins the pool.
    Repair { node: NodeId },
    /// A false-positive prediction on a healthy node (gray-plane detector
    /// with precision < 1): sub-jobs flee at full migration cost, and the
    /// node accrues suspicion.
    FalseAlarm { node: NodeId },
    /// A quarantined node's probation expired.
    QuarantineRelease { node: NodeId },
    MigrationDone { job: JobId, sub: usize, to: NodeId },
    /// Recovery `rec` (one per job per failure) completes.
    RecoveryDone { job: JobId, rec: usize },
    SubDone { job: JobId, sub: usize },
}

/// Per-sub-job state (mirrors livesim's `LiveState`, with recoveries keyed
/// by a generation id so repaired-then-refailed nodes cannot cross wires).
#[derive(Debug, Clone, Copy, PartialEq)]
enum SubState {
    Running { done_at: SimTime },
    Migrating { resume_remaining_s: f64 },
    Recovering { resume_remaining_s: f64, rec: usize },
    Done,
}

/// A per-node sub-job list entry: `(arrival index, sub index, slab slot)`.
/// Ordered by `(arrival, sub)` — `(arrival, sub)` is unique within a set,
/// the slot rides along as the lookup payload.
type NodeSub = (u32, u32, u32);

/// Reusable per-trial allocations: the per-cell timer wheels and staging
/// buffer of the mesh event loop, the churn-cursor machinery, plus the
/// fleet's slab, placement index, per-node lists and scan buffer. Reuse
/// never changes a result (tested).
pub struct FleetScratch {
    wheels: ShardedQueue<Ev>,
    staging: Vec<(SimTime, Ev)>,
    churn_cursors: Vec<ChurnCursor>,
    churn_heap: BinaryHeap<Reverse<(SimTime, u32)>>,
    churn_tmp: Vec<FailureEvent>,
    jobs: JobSlab,
    queue: VecDeque<JobId>,
    placement: PlacementIndex,
    node_subs: Vec<BTreeSet<NodeSub>>,
    scan: Vec<NodeSub>,
    predicted: Vec<bool>,
    suspicion: Vec<u32>,
    offenses: Vec<u32>,
    slow_windows: Vec<Vec<(f64, f64)>>,
    derive: Derive,
}

impl FleetScratch {
    pub fn new() -> Self {
        Self {
            wheels: ShardedQueue::new(1),
            staging: Vec::new(),
            churn_cursors: Vec::new(),
            churn_heap: BinaryHeap::new(),
            churn_tmp: Vec::new(),
            jobs: JobSlab::default(),
            queue: VecDeque::new(),
            placement: PlacementIndex::default(),
            node_subs: Vec::new(),
            scan: Vec::new(),
            predicted: Vec::new(),
            suspicion: Vec::new(),
            offenses: Vec::new(),
            slow_windows: Vec::new(),
            derive: Derive::default(),
        }
    }
}

impl Default for FleetScratch {
    fn default() -> Self {
        Self::new()
    }
}

/// Sequence-number bands of the mesh. A wheel entry's key is
/// `pack_key(at, (band << 62) | counter)`; bands order the setup
/// categories exactly as the old single-counter harness scheduled them
/// (arrivals, then churn dooms/false alarms, then flap-downs, then
/// everything staged at run time), and counters preserve insertion order
/// within a band — so the global min-(time, seq) pop order is the
/// pre-shard dispatch order verbatim, no matter which cell's wheel an
/// entry sits in.
const BAND_ARRIVAL: u64 = 0;
const BAND_CHURN: u64 = 1;
const BAND_FLAP: u64 = 2;
const BAND_RUN: u64 = 3;

fn band_key(at: SimTime, band: u64, counter: u64) -> u128 {
    debug_assert!(counter < 1 << 62, "band counter overflow");
    pack_key(at, (band << 62) | counter)
}

/// The cell an event is routed to: node events to the node's cell, job
/// events to the job's home cell (`Arrival` derives it from the arrival
/// index; handle-carrying events read it off the [`JobId`]). Routing is
/// *display/partition* only — the banded keys make the pop order
/// routing-independent — but a stable rule is what gives the epoch-leak
/// self-test a meaningful "cross-cell" message to drop.
fn route_ev(ev: &Ev, ncells: usize) -> usize {
    match ev {
        Ev::Arrival { job } => job % ncells,
        Ev::Doom { node, .. }
        | Ev::Prediction { node }
        | Ev::Failure { node, .. }
        | Ev::Repair { node }
        | Ev::FalseAlarm { node }
        | Ev::QuarantineRelease { node } => node.0 % ncells,
        Ev::MigrationDone { to, .. } => to.0 % ncells,
        Ev::RecoveryDone { job, .. } | Ev::SubDone { job, .. } => job.cell as usize,
    }
}

/// The dispatch context handed to the [`System`] handlers by the mesh
/// event loop: virtual now, the dynamics stream, and the staging buffer
/// the handler's sends accumulate in. Same contract as the old actor
/// harness `Ctx` — `send_at` clamps past times to now, and staged events
/// drain in push order after the handler returns, each taking the next
/// run-band sequence number.
struct MeshCtx<'a> {
    now: SimTime,
    rng: &'a mut Rng,
    staging: &'a mut Vec<(SimTime, Ev)>,
}

impl MeshCtx<'_> {
    fn now(&self) -> SimTime {
        self.now
    }

    fn rng(&mut self) -> &mut Rng {
        self.rng
    }

    fn send_at(&mut self, at: SimTime, ev: Ev) {
        self.staging.push((at.max(self.now), ev));
    }

    fn send_in(&mut self, delay: SimTime, ev: Ev) {
        self.staging.push((self.now + delay, ev));
    }
}

/// One node's lazily-materialized churn stream: the per-node rng
/// (reconstructed position-independently from a [`Rng::fork_key`]), the
/// next unmaterialized window, and a small buffer of drawn-but-unemitted
/// events ordered by `(time, draw order)`. A head is *emittable* only
/// once its time is at or below the floor of every unmaterialized window
/// (window `w`'s events never precede `from_secs(w × window_s)`), which
/// reproduces the eager plan's stable time sort exactly — including the
/// float corner where a window's last offset rounds past the next
/// window's floor.
struct ChurnCursor {
    rng: Rng,
    next_window: usize,
    /// `(at, draw_seq)` ascending; `draw_seq` is the per-node draw
    /// counter, the stable-sort tiebreak for equal times.
    buf: VecDeque<(SimTime, u64)>,
    draw_seq: u64,
}

impl ChurnCursor {
    /// The head event's time, materializing windows until the head is
    /// emittable; None when the node's stream is exhausted.
    fn head(
        &mut self,
        process: &FailureProcess,
        window_s: f64,
        windows: usize,
        tmp: &mut Vec<FailureEvent>,
    ) -> Option<SimTime> {
        loop {
            let floor = (self.next_window < windows)
                .then(|| SimTime::from_secs(self.next_window as f64 * window_s));
            match (self.buf.front(), floor) {
                (Some(&(at, _)), Some(f)) if at > f => {} // a later window could still precede
                (Some(&(at, _)), _) => return Some(at),
                (None, Some(_)) => {}
                (None, None) => return None,
            }
            tmp.clear();
            process.window_events(self.next_window, window_s, 1, &mut self.rng, tmp);
            self.next_window += 1;
            for e in tmp.drain(..) {
                let seq = self.draw_seq;
                self.draw_seq += 1;
                // almost always an append; float rounding can briefly
                // overlap the previous window's tail
                let pos = self.buf.partition_point(|&(a, s)| (a, s) <= (e.at, seq));
                self.buf.insert(pos, (e.at, seq));
            }
        }
    }

    fn pop(&mut self) -> SimTime {
        self.buf.pop_front().expect("pop follows a Some(head)").0
    }
}

/// The global churn merge: one [`ChurnCursor`] per node and a heap of
/// head times keyed `(at, node)` — the eager path's global
/// `sort_by_key(|e| (e.at, e.node))` order, emitted one event at a time.
/// `k` is the emission index, the per-event key into the gray plane's
/// side streams (lead jitter, false alarms) and the root predictability
/// coin's position — both identical to the eager path because emission
/// order is.
struct ChurnMerge<'a> {
    process: &'a FailureProcess,
    window_s: f64,
    windows: usize,
    cursors: Vec<ChurnCursor>,
    heap: BinaryHeap<Reverse<(SimTime, u32)>>,
    tmp: Vec<FailureEvent>,
    next_k: u64,
}

impl<'a> ChurnMerge<'a> {
    #[allow(clippy::too_many_arguments)]
    fn new(
        process: &'a FailureProcess,
        window_s: f64,
        horizon_s: f64,
        n: usize,
        seed: u64,
        mut cursors: Vec<ChurnCursor>,
        mut heap: BinaryHeap<Reverse<(SimTime, u32)>>,
        mut tmp: Vec<FailureEvent>,
    ) -> Self {
        assert!(n <= u32::MAX as usize, "node index must fit u32");
        let windows = (horizon_s / window_s).ceil() as usize;
        cursors.clear();
        heap.clear();
        // the fork *keys* are drawn sequentially (preserving the old
        // `crng.fork(node)` stream positions exactly), but each node's
        // plan stream is reconstructed from its key on demand — O(1)
        // setup state per node instead of an O(windows) eager plan
        let mut crng = Rng::new(seed ^ CHURN_SALT);
        for node in 0..n {
            let key = crng.fork_key();
            let mut cur = ChurnCursor {
                rng: Rng::from_fork(key, node as u64),
                next_window: 0,
                buf: VecDeque::new(),
                draw_seq: 0,
            };
            if let Some(at) = cur.head(process, window_s, windows, &mut tmp) {
                heap.push(Reverse((at, node as u32)));
            }
            cursors.push(cur);
        }
        Self { process, window_s, windows, cursors, heap, tmp, next_k: 0 }
    }

    /// Earliest unemitted churn event's failure time.
    fn head_at(&self) -> Option<SimTime> {
        self.heap.peek().map(|&Reverse((at, _))| at)
    }

    /// Emit the next churn event in global `(at, node)` order.
    fn pop(&mut self) -> Option<(SimTime, NodeId, u64)> {
        let Reverse((at, node)) = self.heap.pop()?;
        let cur = &mut self.cursors[node as usize];
        let t = cur.pop();
        debug_assert_eq!(t, at, "heap head out of sync with cursor head");
        if let Some(next) = cur.head(self.process, self.window_s, self.windows, &mut self.tmp) {
            self.heap.push(Reverse((next, node)));
        }
        let k = self.next_k;
        self.next_k += 1;
        Some((at, NodeId(node as usize), k))
    }
}

struct System<'a, O: FleetObserver> {
    spec: &'a FleetSpec,
    /// The observer hook (the unit observer on the unobserved path).
    obs: &'a mut O,
    /// Derived-view buffers; touched only when `O::ENABLED`.
    derive: Derive,
    jobs: JobSlab,
    /// FIFO of jobs awaiting placement (head-of-line blocking by design:
    /// placement order is part of the determinism contract).
    queue: VecDeque<JobId>,
    /// Load/health state and the least-loaded placement index.
    placement: PlacementIndex,
    /// Per node: the non-done sub-jobs it hosts, `(arrival, sub)` ordered
    /// — the prediction/failure scan domain.
    node_subs: Vec<BTreeSet<NodeSub>>,
    /// Reused snapshot buffer for the per-node scans (the handlers mutate
    /// the sets they walk).
    scan: Vec<NodeSub>,
    predicted: Vec<bool>,
    /// Per-node suspicion counters (gray-plane quarantine policy; always
    /// strictly below the threshold after an event completes).
    suspicion: Vec<u32>,
    /// Per-node quarantine offence counts (probation backoff exponent).
    offenses: Vec<u32>,
    /// Per-node merged fail-slow windows, `(start_s, end_s)` sorted and
    /// disjoint; every entry empty when the gray plane is off, which is
    /// the byte-identity early-out for the wall/work conversions.
    slow_windows: Vec<Vec<(f64, f64)>>,
    /// Execution speed inside a fail-slow window.
    slow_speed: f64,
    /// Repair time of a flap-down (the flapping spec's `down_s`).
    flap_down_s: f64,
    repair_s: Option<f64>,
    /// Jobs whose Arrival has dispatched.
    arrived: usize,
    /// Recovery generation counter (one id per job per failure).
    next_rec: usize,
    /// In-flight rollback recoveries (contention + storm peak).
    rec_inflight: usize,
    /// In-flight migrations (storm peak).
    migr_inflight: usize,
    /// Sub-jobs currently Running (utilization integrand).
    running: usize,
    /// Utilization integration state.
    last_t: SimTime,
    util: Accumulator,
    slowdowns: Accumulator,
    completed: usize,
    completed_compute_s: f64,
    last_completion: SimTime,
    migrations: usize,
    rollbacks: usize,
    subs_lost: usize,
    absorbed_failures: usize,
    peak_migr: usize,
    peak_rec: usize,
    /// Trial seed, keying the fault side-stream (never drawn from when
    /// the plane is off).
    seed: u64,
    /// Monotone message-sequence counter for fault-draw keys.
    fault_seq: u64,
    net_retries: u64,
    net_timeouts: u64,
    fallbacks: u64,
    dup_suppressed: u64,
    spurious_migrations: u64,
    quarantines: u64,
    quarantine_releases: u64,
    /// Sub-jobs stranded with no scheduled resume (only an injected
    /// self-test defect can raise this; the no-lost-job checker fires).
    abandoned: usize,
}

impl<O: FleetObserver> System<'_, O> {
    /// Integrate the running-slot fraction over `[last_t, now)` into the
    /// time-weighted accumulator. Zero-duration intervals carry no mass
    /// (the accumulator's documented edge contract). The denominator is
    /// the cluster's *total* slot count — down nodes still count as
    /// provisioned capacity — so the fraction is bounded by 1 (every
    /// Running sub-job holds exactly one occupancy slot).
    fn tick(&mut self, now: SimTime) {
        let dt = now.saturating_sub(self.last_t).as_secs();
        let denom = (self.spec.topo.len() * self.spec.capacity) as f64;
        self.util.push_weighted(self.running as f64 / denom, dt);
        self.last_t = now;
    }

    /// The per-strategy reinstate duration of one proactive migration —
    /// livesim's formula verbatim (same draw: one jitter off the harness
    /// stream), called only for multi-agent strategies.
    fn reinstate_s(&self, ctx: &mut MeshCtx<'_>) -> f64 {
        let cfg = &self.spec.job;
        let inp = RuleInputs { z: cfg.z, data_kb: cfg.data_kb, proc_kb: cfg.proc_kb };
        let base = match cfg.strategy {
            Strategy::Agent => cfg.costs.agent.reinstate_s(cfg.z, inp.data_kb, inp.proc_kb),
            Strategy::Core => cfg.costs.core.reinstate_s(cfg.z, inp.data_kb, inp.proc_kb),
            Strategy::Hybrid => match decide(inp).0 {
                Mover::Agent => cfg.costs.agent.reinstate_s(cfg.z, inp.data_kb, inp.proc_kb),
                Mover::Core => cfg.costs.core.reinstate_s(cfg.z, inp.data_kb, inp.proc_kb),
            },
            _ => unreachable!("proactive path is multi-agent only"),
        };
        base * ctx.rng().jitter(cfg.costs.noise_sigma)
    }

    /// Pick a healthy neighbour of `from` with a spare slot, uniformly —
    /// livesim's count-then-select (one draw iff a candidate exists) plus
    /// the fleet's capacity bound: a full neighbour is not a candidate,
    /// which is the "migrate under neighbour-capacity pressure" regime.
    fn pick_target(&self, from: NodeId, ctx: &mut MeshCtx<'_>) -> Option<NodeId> {
        let nbrs = self.spec.topo.neighbours(from);
        let healthy = nbrs.iter().filter(|n| self.placement.has_slot(**n)).count();
        if healthy == 0 {
            return None;
        }
        let k = ctx.rng().range_usize(0, healthy);
        nbrs.iter().filter(|n| self.placement.has_slot(**n)).nth(k).copied()
    }

    /// The reactive recovery duration for one (job, failure) rollback.
    /// Multi-agent jobs pay the flat single-job figure (their backstop is
    /// rare and local); checkpoint-family jobs contend for the shared
    /// server: admitted as the `k`-th concurrent stream, the reinstate
    /// transfer stretches by `⌈k / streams⌉` (admission-time contention —
    /// deterministic, no draws). `rec_inflight` must already count this
    /// recovery.
    fn recovery_s(&self) -> f64 {
        let cfg = &self.spec.job;
        if cfg.strategy.is_multi_agent() {
            cfg.ckpt_reinstate_s + cfg.ckpt_overhead_s
        } else {
            let streams = self.spec.ckpt_streams.max(1);
            let factor = (self.rec_inflight as f64 / streams as f64).ceil().max(1.0);
            cfg.ckpt_reinstate_s * factor + cfg.ckpt_overhead_s
        }
    }

    /// Least-loaded all-or-wait placement via the [`PlacementIndex`] (a
    /// predicted node is always already doomed, so the index's health
    /// filter is the full health check; ties break to the lowest node
    /// index, so an empty cluster places sub `i` on node `i % nodes` — the
    /// degenerate layout of `run_live`). Returns false (and rolls
    /// occupancy back) when the job does not fit. Draw-free.
    fn try_place(&mut self, id: JobId, ctx: &mut MeshCtx<'_>) -> bool {
        let n_subs = self.spec.job.n_subs;
        for _ in 0..n_subs {
            match self.placement.best() {
                Some(b) => {
                    self.placement.inc(b);
                    self.jobs.rec_mut(id).host.push(b);
                }
                None => {
                    let rec = self.jobs.rec_mut(id);
                    for h in rec.host.drain(..) {
                        self.placement.dec(h);
                    }
                    return false;
                }
            }
        }
        let now = ctx.now();
        let done_at = now + SimTime::from_secs(self.spec.job.compute_s);
        let rec = self.jobs.rec_mut(id);
        rec.state.clear();
        rec.state.extend((0..n_subs).map(|_| SubState::Running { done_at }));
        rec.remaining = n_subs;
        let arrival = rec.arrival;
        self.running += n_subs;
        for sub in 0..n_subs {
            let host = self.jobs.rec_mut(id).host[sub];
            self.node_subs[host.0].insert((arrival, sub as u32, id.slot));
            // a fail-slow host stretches this sub's wall clock; without
            // windows the shared `done_at` is used untouched (the gray-off
            // byte-identity path)
            let d = if self.slow_windows[host.0].is_empty() {
                done_at
            } else {
                let wall = self.work_to_wall(host, now, self.spec.job.compute_s);
                let d = now + SimTime::from_secs(wall);
                self.jobs.rec_mut(id).state[sub] = SubState::Running { done_at: d };
                d
            };
            ctx.send_at(d, Ev::SubDone { job: id, sub });
        }
        true
    }

    /// Retry queued placements in FIFO order, stopping at the first job
    /// that still does not fit (head-of-line blocking keeps the order a
    /// pure function of the event sequence).
    fn drain_queue(&mut self, ctx: &mut MeshCtx<'_>) {
        while let Some(&id) = self.queue.front() {
            if !self.try_place(id, ctx) {
                break;
            }
            self.queue.pop_front();
        }
    }

    /// Work seconds a sub accrues on `node` over the wall interval
    /// `[from, to]`. A node without fail-slow windows takes the early
    /// return — the legacy float arithmetic verbatim, so the gray-off
    /// path stays byte-identical.
    fn wall_to_work(&self, node: NodeId, from: SimTime, to: SimTime) -> f64 {
        let w = &self.slow_windows[node.0];
        if w.is_empty() {
            return to.saturating_sub(from).as_secs();
        }
        gray::wall_to_work(w, self.slow_speed, from.as_secs(), to.as_secs())
    }

    /// Wall seconds `node` needs from `start` to accrue `work_s` work
    /// seconds (inverse of [`wall_to_work`](Self::wall_to_work); same
    /// no-window early return).
    fn work_to_wall(&self, node: NodeId, start: SimTime, work_s: f64) -> f64 {
        let w = &self.slow_windows[node.0];
        if w.is_empty() {
            return work_s;
        }
        gray::work_to_wall(w, self.slow_speed, start.as_secs(), work_s)
    }

    /// One suspicion event (a false alarm or a non-absorbed flap-down) on
    /// `node`. Crossing the policy threshold quarantines the node, resets
    /// its counter, bumps its offence count and schedules the release
    /// after an exponentially backed-off probation. A node already in
    /// quarantine accrues nothing — the counter stays strictly below the
    /// threshold after every event (the storm-bound invariant).
    fn suspicion_accrue(&mut self, node: NodeId, ctx: &mut MeshCtx<'_>) {
        let q = &self.spec.gray.quarantine;
        if q.threshold == 0 || self.placement.is_quarantined(node) {
            return;
        }
        self.suspicion[node.0] += 1;
        if self.suspicion[node.0] < q.threshold {
            return;
        }
        // vopr self-test fault QuarantineLeak: the threshold crossing is
        // ignored, suspicion keeps accruing — the storm-bound checker
        // must fire on this very event
        #[cfg(any(test, feature = "vopr-selftest"))]
        let leak = self.spec.fault == Some(InjectedFault::QuarantineLeak);
        #[cfg(not(any(test, feature = "vopr-selftest")))]
        let leak = false;
        if leak {
            return;
        }
        self.placement.quarantine(node);
        self.quarantines += 1;
        let probation = q.probation(self.offenses[node.0]);
        self.offenses[node.0] = self.offenses[node.0].saturating_add(1);
        self.suspicion[node.0] = 0;
        ctx.send_in(SimTime::from_secs(probation), Ev::QuarantineRelease { node });
    }

    /// The home cell of the job with arrival index `arrival` (allocation
    /// rule: job `j` → cell `j % cells`).
    fn job_cell(&self, arrival: u32) -> u32 {
        (arrival as usize % self.spec.cells.get()) as u32
    }
}

/// Project the private event onto its public observer label. The
/// post-state flags (`job_completed`, `landed`) are patched in afterwards
/// from counter deltas. Slots are labelled `slot × cells + cell` — the
/// raw slot at `cells = 1`, and a stable flat name for a `(cell, slot)`
/// address otherwise (observers only label, never dereference).
fn ev_kind(ev: &Ev, ncells: usize) -> FleetEv {
    let flat = |id: &JobId| (id.slot as u64 * ncells as u64 + id.cell as u64) as u32;
    match ev {
        Ev::Arrival { job } => FleetEv::Arrival { job: *job as u32 },
        Ev::Doom { node, predictable, .. } => {
            FleetEv::Doom { node: node.0 as u32, predictable: *predictable }
        }
        Ev::Prediction { node } => FleetEv::Prediction { node: node.0 as u32 },
        Ev::Failure { node, .. } => FleetEv::Failure { node: node.0 as u32 },
        Ev::Repair { node } => FleetEv::Repair { node: node.0 as u32 },
        Ev::FalseAlarm { node } => FleetEv::FalseAlarm { node: node.0 as u32 },
        Ev::QuarantineRelease { node } => {
            FleetEv::QuarantineRelease { node: node.0 as u32 }
        }
        Ev::MigrationDone { job, sub, to } => FleetEv::MigrationDone {
            slot: flat(job),
            sub: *sub as u32,
            to: to.0 as u32,
            landed: false,
        },
        Ev::RecoveryDone { job, rec } => {
            FleetEv::RecoveryDone { slot: flat(job), rec: *rec as u32 }
        }
        Ev::SubDone { job, sub } => {
            FleetEv::SubDone { slot: flat(job), sub: *sub as u32, job_completed: false }
        }
    }
}

impl<O: FleetObserver> System<'_, O> {
    /// Refresh the derived view and notify the observer (enabled path
    /// only — `observe` is never reached when `O::ENABLED` is false).
    fn observe(&mut self, now: SimTime, ev: FleetEv) {
        self.derive.refresh(&self.jobs, &self.node_subs);
        let view = FleetView {
            now,
            n_subs: self.spec.job.n_subs,
            capacity: self.spec.capacity,
            arrived: self.arrived,
            completed: self.completed,
            live_jobs: self.jobs.live,
            queued: self.queue.len(),
            running: self.running,
            migr_inflight: self.migr_inflight,
            rec_inflight: self.rec_inflight,
            occupancy: &self.placement.occupancy,
            doomed: &self.placement.doomed,
            hosted: &self.derive.hosted,
            sub_running: self.derive.sub_running,
            sub_migrating: self.derive.sub_migrating,
            distinct_recs: self.derive.distinct_recs,
            remaining_ok: self.derive.remaining_ok,
            stale_node_subs: self.derive.stale_node_subs,
            abandoned: self.abandoned,
            quarantined: &self.placement.quarantined,
            suspicion: &self.suspicion,
            suspicion_threshold: self.spec.gray.quarantine.threshold,
            quarantines: self.quarantines,
            quarantine_releases: self.quarantine_releases,
        };
        self.obs.after_event(ev, &view);
    }

    /// Final observer callback after the trial's closing tick.
    fn observe_end(&mut self, now: SimTime, hit_horizon: bool) {
        if !O::ENABLED {
            return;
        }
        self.derive.refresh(&self.jobs, &self.node_subs);
        let view = FleetView {
            now,
            n_subs: self.spec.job.n_subs,
            capacity: self.spec.capacity,
            arrived: self.arrived,
            completed: self.completed,
            live_jobs: self.jobs.live,
            queued: self.queue.len(),
            running: self.running,
            migr_inflight: self.migr_inflight,
            rec_inflight: self.rec_inflight,
            occupancy: &self.placement.occupancy,
            doomed: &self.placement.doomed,
            hosted: &self.derive.hosted,
            sub_running: self.derive.sub_running,
            sub_migrating: self.derive.sub_migrating,
            distinct_recs: self.derive.distinct_recs,
            remaining_ok: self.derive.remaining_ok,
            stale_node_subs: self.derive.stale_node_subs,
            abandoned: self.abandoned,
            quarantined: &self.placement.quarantined,
            suspicion: &self.suspicion,
            suspicion_threshold: self.spec.gray.quarantine.threshold,
            quarantines: self.quarantines,
            quarantine_releases: self.quarantine_releases,
        };
        self.obs.at_end(&view, hit_horizon);
    }

    /// The proactive migration sweep (multi-agent strategies only):
    /// migrate every running sub-job off `node`, jobs in arrival order,
    /// subs in index order — livesim's scan and draw order verbatim for
    /// each job. The node's sub-job set *is* that order; snapshot it
    /// because migrations edit it. Shared by real predictions (`spurious
    /// = false`, the node is doomed) and gray-plane false alarms
    /// (`spurious = true`, the node is healthy and every migration is
    /// pure waste, counted in `spurious_migrations`).
    fn proactive_sweep(&mut self, ctx: &mut MeshCtx<'_>, node: NodeId, spurious: bool) {
        let now = ctx.now();
        self.scan.clear();
        self.scan.extend(self.node_subs[node.0].iter().copied());
        for k in 0..self.scan.len() {
            let (arrival, sub, slot) = self.scan[k];
            let cell = self.job_cell(arrival);
            let i = sub as usize;
            let rec = self.jobs.raw(cell, slot);
            debug_assert!(rec.live && rec.arrival == arrival, "dead entry in node set");
            debug_assert_eq!(rec.host[i], node, "entry strayed off its node");
            if let SubState::Running { done_at } = rec.state[i] {
                let remaining = self.wall_to_work(node, now, done_at);
                let gen = rec.gen;
                let dur = self.reinstate_s(ctx);
                if let Some(target) = self.pick_target(node, ctx) {
                    // Harden the migration handshake against the
                    // fault plane. The exchange draws only from the
                    // salted side-stream, so with the plane off this
                    // whole block is skipped and the trial is
                    // byte-identical to a build without it.
                    #[cfg(any(test, feature = "vopr-selftest"))]
                    let drop_ack = self.spec.fault == Some(InjectedFault::DropSpawnAck);
                    #[cfg(not(any(test, feature = "vopr-selftest")))]
                    let drop_ack = false;
                    let mut extra_s = 0.0;
                    let mut delivered = !drop_ack;
                    if !drop_ack && !self.spec.faults.is_off() {
                        let cut = self.spec.faults.cut_peer(node, target, now.as_secs());
                        let cost = migration_net_cost(
                            &self.spec.job,
                            &self.spec.faults,
                            self.seed,
                            faults::edge(node, target),
                            &mut self.fault_seq,
                            cut,
                        );
                        self.net_retries += cost.retries;
                        self.net_timeouts += cost.timeouts;
                        self.dup_suppressed += cost.dup_deliveries;
                        extra_s = cost.penalty_s;
                        delivered = cost.delivered;
                    }
                    if delivered {
                        let rec = self.jobs.raw_mut(cell, slot);
                        rec.state[i] = SubState::Migrating { resume_remaining_s: remaining };
                        rec.host[i] = target;
                        self.placement.dec(node);
                        self.placement.inc(target);
                        self.node_subs[node.0].remove(&(arrival, sub, slot));
                        self.node_subs[target.0].insert((arrival, sub, slot));
                        self.running -= 1;
                        self.migr_inflight += 1;
                        self.peak_migr = self.peak_migr.max(self.migr_inflight);
                        if spurious {
                            self.spurious_migrations += 1;
                        }
                        ctx.send_in(
                            SimTime::from_secs(dur + extra_s),
                            Ev::MigrationDone { job: JobId { cell, slot, gen }, sub: i, to: target },
                        );
                    } else if drop_ack {
                        // injected self-test defect: the handshake
                        // never completes and the broken protocol
                        // strands the sub — Migrating forever, no
                        // event scheduled, no fallback. Bookkeeping
                        // stays self-consistent so only the
                        // no-lost-job checker fires.
                        let rec = self.jobs.raw_mut(cell, slot);
                        rec.state[i] = SubState::Migrating { resume_remaining_s: remaining };
                        rec.host[i] = target;
                        self.placement.dec(node);
                        self.placement.inc(target);
                        self.node_subs[node.0].remove(&(arrival, sub, slot));
                        self.node_subs[target.0].insert((arrival, sub, slot));
                        self.running -= 1;
                        self.migr_inflight += 1;
                        self.peak_migr = self.peak_migr.max(self.migr_inflight);
                        self.abandoned += 1;
                    } else {
                        // the handshake exhausted its retries (or
                        // the target partitioned away): fall back
                        // one rung to reactive checkpoint recovery —
                        // the Failure-path bookkeeping, never a
                        // lost job. The time spent retrying
                        // (`extra_s`) delays the recovery's start.
                        let rec_id = self.next_rec;
                        self.next_rec += 1;
                        self.jobs.raw_mut(cell, slot).state[i] =
                            SubState::Recovering { resume_remaining_s: remaining, rec: rec_id };
                        self.running -= 1;
                        if let Some(t) = self.pick_target(node, ctx) {
                            self.jobs.raw_mut(cell, slot).host[i] = t;
                            self.placement.dec(node);
                            self.placement.inc(t);
                            self.node_subs[node.0].remove(&(arrival, sub, slot));
                            self.node_subs[t.0].insert((arrival, sub, slot));
                        }
                        self.rec_inflight += 1;
                        self.peak_rec = self.peak_rec.max(self.rec_inflight);
                        let rdur = self.recovery_s();
                        self.rollbacks += 1;
                        self.fallbacks += 1;
                        ctx.send_in(
                            SimTime::from_secs(extra_s + rdur),
                            Ev::RecoveryDone { job: JobId { cell, slot, gen }, rec: rec_id },
                        );
                    }
                }
                // no healthy neighbour with a spare slot: stay
                // put; the failure path will roll back
            }
        }
    }

    /// Dispatch one event — the event-loop body, observer-free. Early
    /// returns here (absorbed strikes, stale handles) still reach the
    /// observer: the mesh loop wraps this call.
    fn handle(&mut self, ctx: &mut MeshCtx<'_>, ev: Ev) {
        let now = ctx.now();
        match ev {
            Ev::Arrival { job } => {
                let cell = self.job_cell(job as u32);
                let id = self.jobs.alloc(cell, job as u32, now);
                self.arrived += 1;
                if !self.try_place(id, ctx) {
                    self.queue.push_back(id);
                }
            }
            Ev::Doom { node, predictable, fail_in_s, flap } => {
                if self.placement.is_doomed(node) {
                    // still down from an earlier failure: the strike is
                    // absorbed (a node is doomed at most once per
                    // up-period), exactly like livesim's dedup guard
                    self.absorbed_failures += 1;
                    return;
                }
                self.placement.doom(node);
                if flap {
                    // a landed flap-down is a suspicion source (the strike
                    // itself is always unpredicted: flaps stress the
                    // reactive path)
                    self.suspicion_accrue(node, ctx);
                }
                if predictable {
                    self.predicted[node.0] = true;
                    ctx.send_in(SimTime::from_secs(0.0), Ev::Prediction { node });
                }
                ctx.send_in(SimTime::from_secs(fail_in_s), Ev::Failure { node, flap });
            }
            Ev::Prediction { node } => {
                // proactive path (multi-agent strategies only): migrate
                // every running sub-job off the node
                if !self.spec.job.strategy.is_multi_agent() {
                    return;
                }
                self.proactive_sweep(ctx, node, false);
            }
            Ev::FalseAlarm { node } => {
                // a false-positive prediction (gray detector, precision
                // < 1) on a node that was never going to fail. If it is
                // down anyway the alarm is moot (absorbed like a doubled
                // doom); otherwise it accrues suspicion and — for the
                // proactive strategies — triggers the full migration
                // sweep at full cost, for nothing.
                if self.placement.is_doomed(node) {
                    return;
                }
                self.suspicion_accrue(node, ctx);
                if self.spec.job.strategy.is_multi_agent() {
                    self.proactive_sweep(ctx, node, true);
                }
            }
            Ev::QuarantineRelease { node } => {
                self.quarantine_releases += 1;
                self.placement.release(node);
                self.drain_queue(ctx);
            }
            Ev::Failure { node, flap } => {
                // every sub-job still on the failed node is lost → reactive
                // rollback, one recovery per affected job (each its own
                // checkpoint-server stream). The node's set is already
                // (arrival, sub) ordered, so walking contiguous same-
                // arrival groups replays the old per-job loop exactly.
                self.scan.clear();
                self.scan.extend(self.node_subs[node.0].iter().copied());
                let mut k = 0;
                while k < self.scan.len() {
                    let (arrival, _, slot) = self.scan[k];
                    let cell = self.job_cell(arrival);
                    let rec_id = self.next_rec;
                    let mut lost = 0usize;
                    while k < self.scan.len() && self.scan[k].0 == arrival {
                        let (_, sub, _) = self.scan[k];
                        k += 1;
                        let i = sub as usize;
                        match self.jobs.raw(cell, slot).state[i] {
                            SubState::Running { done_at } => {
                                let remaining = self.wall_to_work(node, now, done_at);
                                self.jobs.raw_mut(cell, slot).state[i] = SubState::Recovering {
                                    resume_remaining_s: remaining,
                                    rec: rec_id,
                                };
                                self.running -= 1;
                            }
                            SubState::Migrating { resume_remaining_s } => {
                                // the in-flight move (targeting this node)
                                // aborts; its MigrationDone will find a
                                // non-Migrating state and be ignored
                                self.jobs.raw_mut(cell, slot).state[i] = SubState::Recovering {
                                    resume_remaining_s,
                                    rec: rec_id,
                                };
                                self.migr_inflight -= 1;
                            }
                            _ => continue,
                        }
                        // move it off the dead node for the resume
                        if let Some(t) = self.pick_target(node, ctx) {
                            self.jobs.raw_mut(cell, slot).host[i] = t;
                            self.placement.dec(node);
                            self.placement.inc(t);
                            self.node_subs[node.0].remove(&(arrival, sub, slot));
                            self.node_subs[t.0].insert((arrival, sub, slot));
                        }
                        lost += 1;
                    }
                    if lost > 0 {
                        self.next_rec += 1;
                        self.rec_inflight += 1;
                        self.peak_rec = self.peak_rec.max(self.rec_inflight);
                        let mut dur = self.recovery_s();
                        if !self.spec.faults.is_off() {
                            // the rollback's RestoreRequest/RestoreData
                            // exchange rides the node↔server link; an
                            // exhausted exchange degrades to a cold restore
                            // (the ladder's bottom rung) — never a lost job
                            let cost = self.spec.faults.restore_exchange(
                                self.seed,
                                node,
                                &mut self.fault_seq,
                                now.as_secs(),
                                self.spec.job.data_kb,
                            );
                            self.net_retries += cost.retries;
                            self.net_timeouts += cost.timeouts;
                            self.dup_suppressed += cost.dup_deliveries;
                            if cost.delivered {
                                dur += cost.penalty_s;
                            } else {
                                dur = dur * self.spec.faults.cold_restore_factor
                                    + cost.penalty_s;
                                self.fallbacks += 1;
                            }
                        }
                        self.rollbacks += 1;
                        self.subs_lost += lost;
                        let gen = self.jobs.raw(cell, slot).gen;
                        ctx.send_in(
                            SimTime::from_secs(dur),
                            Ev::RecoveryDone { job: JobId { cell, slot, gen }, rec: rec_id },
                        );
                    }
                }
                // a flap-down always repairs — after the flapping spec's
                // fast down_s, not the churn repair_s (a plan failure
                // absorbed during a flap window rides this repair too:
                // the repair belongs to the failure that took the node
                // down, see DESIGN.md §Gray-failure plane)
                let repair = if flap { Some(self.flap_down_s) } else { self.repair_s };
                if let Some(repair_s) = repair {
                    ctx.send_in(SimTime::from_secs(repair_s), Ev::Repair { node });
                }
            }
            Ev::Repair { node } => {
                self.placement.repair(node);
                self.predicted[node.0] = false;
                self.drain_queue(ctx);
            }
            Ev::MigrationDone { job, sub, to } => {
                // a stale handle means the move aborted long ago and the
                // job has since completed (slot retired): nothing to do —
                // same net effect as the old table's non-Migrating check
                let Some(rec) = self.jobs.get(job) else { return };
                if let SubState::Migrating { resume_remaining_s } = rec.state[sub] {
                    debug_assert_eq!(rec.host[sub], to);
                    // `resume_remaining_s` is *work* seconds; a fail-slow
                    // landing node stretches them (identity when the node
                    // has no degraded windows)
                    let done_at =
                        now + SimTime::from_secs(self.work_to_wall(to, now, resume_remaining_s));
                    self.jobs.rec_mut(job).state[sub] = SubState::Running { done_at };
                    self.running += 1;
                    self.migr_inflight -= 1;
                    self.migrations += 1;
                    ctx.send_at(done_at, Ev::SubDone { job, sub });
                    // the landed agent gathers predictions on arrival: a
                    // standing prediction for this very node sends it
                    // fleeing again
                    if self.predicted[to.0] {
                        ctx.send_in(SimTime::from_secs(0.0), Ev::Prediction { node: to });
                    }
                }
            }
            Ev::RecoveryDone { job, rec } => {
                self.rec_inflight -= 1;
                // a job with an in-flight recovery holds Recovering subs,
                // so it cannot retire before this arrives; the guard is
                // belt-and-braces for the handle discipline
                debug_assert!(self.jobs.get(job).is_some(), "recovery outlived its job");
                let Some(rec0) = self.jobs.get(job) else { return };
                let n_state = rec0.state.len();
                let arrival = rec0.arrival;
                for i in 0..n_state {
                    if let SubState::Recovering { resume_remaining_s, rec: r } =
                        self.jobs.raw(job.cell, job.slot).state[i]
                    {
                        if r == rec {
                            // the resume host chosen at loss time may have
                            // been doomed while the rollback ran: re-home
                            // before resuming. When every candidate is full
                            // or doomed the sub resumes in place on the
                            // down node — livesim's best-effort fallback,
                            // kept verbatim because the degenerate fleet
                            // must replay run_live bit for bit; such
                            // compute does count into goodput/utilization
                            // (documented in DESIGN.md §Fleet simulator).
                            let old = self.jobs.raw(job.cell, job.slot).host[i];
                            if self.placement.is_doomed(old) {
                                if let Some(t) = self.pick_target(old, ctx) {
                                    self.jobs.raw_mut(job.cell, job.slot).host[i] = t;
                                    self.placement.dec(old);
                                    self.placement.inc(t);
                                    self.node_subs[old.0].remove(&(
                                        arrival,
                                        i as u32,
                                        job.slot,
                                    ));
                                    self.node_subs[t.0].insert((arrival, i as u32, job.slot));
                                }
                            }
                            let host = self.jobs.raw(job.cell, job.slot).host[i];
                            let done_at = now
                                + SimTime::from_secs(self.work_to_wall(
                                    host,
                                    now,
                                    resume_remaining_s,
                                ));
                            self.jobs.raw_mut(job.cell, job.slot).state[i] =
                                SubState::Running { done_at };
                            self.running += 1;
                            ctx.send_at(done_at, Ev::SubDone { job, sub: i });
                        }
                    }
                }
            }
            Ev::SubDone { job, sub } => {
                // a sub's live completion precedes any retirement of its
                // job, so a miss here can only be a stale (superseded)
                // completion — ignored either way
                let Some(rec) = self.jobs.get(job) else { return };
                if let SubState::Running { done_at } = rec.state[sub] {
                    if done_at == now {
                        let host = rec.host[sub];
                        let arrival = rec.arrival;
                        let rec = self.jobs.rec_mut(job);
                        rec.state[sub] = SubState::Done;
                        rec.remaining -= 1;
                        let remaining = rec.remaining;
                        let arrived_at = rec.arrived_at;
                        self.running -= 1;
                        // vopr self-test fault LeakSlot: keep the freed
                        // slot counted in the placement index — the
                        // bookkeeping-agreement checker must fire on this
                        // very event
                        #[cfg(any(test, feature = "vopr-selftest"))]
                        let leak = self.spec.fault == Some(InjectedFault::LeakSlot);
                        #[cfg(not(any(test, feature = "vopr-selftest")))]
                        let leak = false;
                        if !leak {
                            self.placement.dec(host);
                        }
                        self.node_subs[host.0].remove(&(arrival, sub as u32, job.slot));
                        if remaining == 0 {
                            self.completed += 1;
                            let cfg = &self.spec.job;
                            self.completed_compute_s += cfg.n_subs as f64 * cfg.compute_s;
                            let elapsed = now.saturating_sub(arrived_at).as_secs();
                            self.slowdowns.push(elapsed / cfg.compute_s);
                            self.last_completion = now;
                            self.jobs.retire(job);
                            // vopr self-test fault SkipRequeue: never offer
                            // the freed slots to the wait queue — the
                            // queue-progress checker must fire
                            #[cfg(any(test, feature = "vopr-selftest"))]
                            let skip = self.spec.fault == Some(InjectedFault::SkipRequeue);
                            #[cfg(not(any(test, feature = "vopr-selftest")))]
                            let skip = false;
                            if !skip {
                                self.drain_queue(ctx);
                            }
                        }
                    }
                    // else: a stale completion from before a migration —
                    // ignored because done_at moved
                }
            }
        }
    }
}

/// Run one fleet trial. Deterministic in `(spec, seed)`.
pub fn run_fleet(spec: &FleetSpec, seed: u64) -> FleetOutcome {
    run_fleet_scratch(spec, seed, &mut FleetScratch::new())
}

/// [`run_fleet`] on recycled trial allocations — bit-identical results; a
/// sweep worker threads one [`FleetScratch`] through its chunk of trials.
pub fn run_fleet_scratch(spec: &FleetSpec, seed: u64, scratch: &mut FleetScratch) -> FleetOutcome {
    run_fleet_observed(spec, seed, scratch, &mut ())
}

/// The trial's arrival times, materialized: the exact sorted in-horizon
/// list the run schedules, whether the spec traces them or draws them from
/// the Poisson side stream. Substituting them back as
/// [`ArrivalSpec::Trace`] leaves the trial bit-identical (the arrival
/// stream is salted off to the side and feeds nothing else) — which is how
/// the vopr shrinker turns a rate into a shrinkable list.
pub fn sample_arrivals(spec: &FleetSpec, seed: u64) -> Vec<f64> {
    let mut at_s: Vec<f64> = match &spec.arrivals {
        ArrivalSpec::Trace { at_s } => {
            at_s.iter().copied().filter(|&t| t < spec.horizon_s).collect()
        }
        ArrivalSpec::Poisson { rate_per_h } => {
            let mut arr = Vec::new();
            if *rate_per_h > 0.0 {
                let mut rng = Rng::new(seed ^ ARRIVAL_SALT);
                let mean_gap = 3600.0 / rate_per_h;
                let mut t = rng.exponential(mean_gap);
                while t < spec.horizon_s {
                    arr.push(t);
                    t += rng.exponential(mean_gap);
                }
            }
            arr
        }
    };
    at_s.sort_by(f64::total_cmp);
    at_s
}

/// [`run_fleet_scratch`] with a [`FleetObserver`] wired into the event
/// loop (the vopr invariant checkers ride this). With the unit observer
/// this *is* `run_fleet_scratch` — same monomorphized body, same bytes
/// out; an observer cannot perturb the run (no draws, no events), only
/// watch it.
pub fn run_fleet_observed<O: FleetObserver>(
    spec: &FleetSpec,
    seed: u64,
    scratch: &mut FleetScratch,
    obs: &mut O,
) -> FleetOutcome {
    /// Emit one churn event into the wheels: one root predictability coin
    /// (plan order), the gray-plane lead for covered events, the doom at
    /// `at − lead`, and the covered event's false-alarm batch — the
    /// pre-shard setup loop's body verbatim, shared by the eager paths
    /// (explicit plans, sub-unit-precision detectors) and the lazy pull.
    #[allow(clippy::too_many_arguments)]
    fn schedule_churn(
        spec: &FleetSpec,
        seed: u64,
        n: usize,
        lead: f64,
        coverage: f64,
        root: &mut Rng,
        wheels: &mut ShardedQueue<Ev>,
        churn_seq: &mut u64,
        ncells: usize,
        at: SimTime,
        node: NodeId,
        k: u64,
    ) {
        let predictable = root.chance(coverage);
        let lead_s = if predictable { spec.gray.lead_s(seed, k, lead) } else { lead };
        let doom_at = at.saturating_sub(SimTime::from_secs(lead_s));
        let seq = *churn_seq;
        *churn_seq += 1;
        wheels.push(
            node.0 % ncells,
            band_key(doom_at, BAND_CHURN, seq),
            Ev::Doom { node, predictable, fail_in_s: lead_s, flap: false },
        );
        if predictable {
            // sub-unit precision: every covered failure drags its
            // expected share of false alarms on (a priori healthy) nodes
            for (fp, t) in spec.gray.false_alarms(seed, k, n, spec.horizon_s) {
                let seq = *churn_seq;
                *churn_seq += 1;
                wheels.push(
                    fp % ncells,
                    band_key(SimTime::from_secs(t), BAND_CHURN, seq),
                    Ev::FalseAlarm { node: NodeId(fp) },
                );
            }
        }
    }

    assert!(spec.job.n_subs > 0, "fleet jobs need at least one sub-job");
    assert!(spec.capacity > 0, "fleet nodes need at least one slot");
    let n = spec.topo.len();
    let ncells = spec.cells.get();
    // Stream discipline (the degenerate-equivalence contract): the
    // dynamics stream forks off the root *first*, then the root serves
    // exactly one predictability draw per churn event in plan order —
    // run_live's exact sequence. Arrivals and churn plans use salted side
    // streams that never touch the root. Lazy churn defers the trailing
    // coins past the last pulled event; nothing reads the root after
    // setup, so the prefix actually drawn is identical.
    let mut root = Rng::new(seed);
    let mut hrng = root.fork(1);
    let at_s = sample_arrivals(spec, seed);

    let mut jobs = std::mem::take(&mut scratch.jobs);
    jobs.reset(ncells);
    let mut queue = std::mem::take(&mut scratch.queue);
    queue.clear();
    let mut placement = std::mem::take(&mut scratch.placement);
    placement.reset(n, spec.capacity, ncells);
    let mut node_subs = std::mem::take(&mut scratch.node_subs);
    for s in &mut node_subs {
        s.clear();
    }
    node_subs.resize_with(n, BTreeSet::new);
    let mut scan = std::mem::take(&mut scratch.scan);
    scan.clear();
    let mut predicted = std::mem::take(&mut scratch.predicted);
    predicted.clear();
    predicted.resize(n, false);
    let mut suspicion = std::mem::take(&mut scratch.suspicion);
    suspicion.clear();
    suspicion.resize(n, 0);
    let mut offenses = std::mem::take(&mut scratch.offenses);
    offenses.clear();
    offenses.resize(n, 0);
    // Fail-slow windows are static per trial: drawn from the gray
    // side-stream at build time (one throwaway RNG per node, never the
    // root), merged, and summed into the degraded-node-seconds counter.
    // With the plane off every entry stays empty — the byte-identity
    // early-out of the wall/work conversions.
    let mut slow_windows = std::mem::take(&mut scratch.slow_windows);
    for w in &mut slow_windows {
        w.clear();
    }
    slow_windows.resize_with(n, Vec::new);
    let mut degraded_node_s = 0.0;
    if spec.gray.fail_slow.rate_per_node_h > 0.0 {
        for (node, w) in slow_windows.iter_mut().enumerate() {
            *w = spec.gray.slow_windows(seed, node, spec.horizon_s);
            degraded_node_s += w.iter().map(|(a, b)| b - a).sum::<f64>();
        }
    }
    let derive = std::mem::take(&mut scratch.derive);

    // ---- setup: load the wheels under the banded sequence scheme ----
    let wheels = &mut scratch.wheels;
    wheels.reset(ncells);
    for (j, &t) in at_s.iter().enumerate() {
        wheels.push(
            j % ncells,
            band_key(SimTime::from_secs(t), BAND_ARRIVAL, j as u64),
            Ev::Arrival { job: j },
        );
    }
    let lead = spec.job.costs.predict.predict_time_s + 20.0;
    // The detector model overrides the raw predictable_frac coin with its
    // coverage — still exactly one root draw per churn event in plan
    // order, so the root stream is untouched by the gray plane; jitter
    // and false alarms come from per-event side streams. With `detector:
    // None` (the default) this is the legacy loop byte-for-byte.
    let coverage = spec.gray.coverage(spec.job.predictable_frac);
    let mut churn_seq: u64 = 0;
    let mut churn: Option<ChurnMerge<'_>> = None;
    let (repair_s, margin) = match &spec.churn {
        ChurnSpec::Plan(p) => {
            // explicit plans are a handful of literal events (and the
            // run_live-equivalence mode): schedule them eagerly, in the
            // plan's own order, exactly as before
            for (k, e) in p.events.iter().enumerate() {
                schedule_churn(
                    spec, seed, n, lead, coverage, &mut root, wheels, &mut churn_seq, ncells,
                    e.at, e.node, k as u64,
                );
            }
            (None, SimTime::ZERO)
        }
        ChurnSpec::PerNode { process, window_s, repair_s } => {
            assert!(*window_s > 0.0, "churn window must be positive");
            let mut merge = ChurnMerge::new(
                process,
                *window_s,
                spec.horizon_s,
                n,
                seed,
                std::mem::take(&mut scratch.churn_cursors),
                std::mem::take(&mut scratch.churn_heap),
                std::mem::take(&mut scratch.churn_tmp),
            );
            if spec.gray.emits_false_alarms() {
                // a sub-unit-precision detector batches false alarms at
                // absolute side-stream times that may precede the doom
                // that spawned them — stream the whole merge through
                // setup (still no O(nodes) plan vectors: the cursors
                // walk window by window)
                while let Some((at, node, k)) = merge.pop() {
                    schedule_churn(
                        spec, seed, n, lead, coverage, &mut root, wheels, &mut churn_seq,
                        ncells, at, node, k,
                    );
                }
            }
            // otherwise the merge stays live and the mesh loop pulls
            // events just ahead of the clock; doom times trail failure
            // times by at most this margin, which bounds the look-ahead
            (Some(*repair_s), SimTime::from_secs(spec.gray.max_lead_s(lead)))
        }
    };
    // Flap-downs: unpredicted, zero-lead dooms with the fast flap repair,
    // drawn per node from the gray side stream at build time.
    let mut flap_seq: u64 = 0;
    for node in 0..n {
        for t in spec.gray.flap_downs(seed, node, spec.horizon_s) {
            wheels.push(
                node % ncells,
                band_key(SimTime::from_secs(t), BAND_FLAP, flap_seq),
                Ev::Doom { node: NodeId(node), predictable: false, fail_in_s: 0.0, flap: true },
            );
            flap_seq += 1;
        }
    }

    let mut system = System {
        spec,
        obs,
        derive,
        jobs,
        queue,
        placement,
        node_subs,
        scan,
        predicted,
        suspicion,
        offenses,
        slow_windows,
        slow_speed: spec.gray.fail_slow.speed_factor,
        flap_down_s: spec.gray.flapping.down_s,
        repair_s,
        arrived: 0,
        next_rec: 0,
        rec_inflight: 0,
        migr_inflight: 0,
        running: 0,
        last_t: SimTime::ZERO,
        util: Accumulator::new(),
        slowdowns: Accumulator::new(),
        completed: 0,
        completed_compute_s: 0.0,
        last_completion: SimTime::ZERO,
        migrations: 0,
        rollbacks: 0,
        subs_lost: 0,
        absorbed_failures: 0,
        peak_migr: 0,
        peak_rec: 0,
        seed,
        fault_seq: 0,
        net_retries: 0,
        net_timeouts: 0,
        fallbacks: 0,
        dup_suppressed: 0,
        spurious_migrations: 0,
        quarantines: 0,
        quarantine_releases: 0,
        abandoned: 0,
    };
    // ---- the mesh event loop ----
    //
    // Per-cell wheels + globally unique banded keys: popping the minimum
    // key across cells *is* the single-queue dispatch order, so the loop
    // below is the old harness loop with the queue sharded out from under
    // it. Staged sends drain in push order after each handler (each
    // taking the next run-band seq) and route to their destination cell —
    // the epoch-boundary exchange of DESIGN.md §Sharded cells.
    let horizon = SimTime::from_secs(spec.horizon_s);
    let mut staging = std::mem::take(&mut scratch.staging);
    staging.clear();
    let mut run_seq: u64 = 0;
    let mut dispatched: u64 = 0;
    let mut now = SimTime::ZERO;
    #[cfg(any(test, feature = "vopr-selftest"))]
    let mut leak_armed = spec.fault == Some(InjectedFault::EpochLeak);
    let end;
    loop {
        // Pull churn just ahead of the clock: any unemitted event whose
        // doom could precede (or tie) the next wheel entry — or the
        // horizon, when the wheels are empty — must be scheduled before
        // the next pop decision. Dooms trail their failure time by at
        // most `margin`, so the guard below is exact; pulled dooms are
        // always ≥ the last dispatch time (no past scheduling).
        if let Some(m) = churn.as_mut() {
            while let Some(h) = m.head_at() {
                let cap = match wheels.min_key() {
                    Some(k) => SimTime((k >> 64) as u64).min(horizon),
                    None => horizon,
                };
                if h.saturating_sub(margin) > cap {
                    break;
                }
                let (at, node, k) = m.pop().expect("head_at was Some");
                schedule_churn(
                    spec, seed, n, lead, coverage, &mut root, wheels, &mut churn_seq, ncells,
                    at, node, k,
                );
            }
        }
        let Some(key) = wheels.min_key() else {
            // wheels drained: quiescent — unless unpulled churn remains,
            // which is then strictly post-horizon doom work (the old path
            // had it queued and stopped at the horizon)
            let churn_left = churn.as_ref().is_some_and(|m| m.head_at().is_some());
            end = if churn_left { horizon } else { now };
            break;
        };
        let at = SimTime((key >> 64) as u64);
        if at > horizon {
            end = horizon;
            break;
        }
        let (cell, _, ev) = wheels.pop_min().expect("min_key was Some");
        debug_assert!(cell < ncells, "wheel entry routed out of range");
        now = at;
        dispatched += 1;
        system.tick(now);
        let mut ctx = MeshCtx { now, rng: &mut hrng, staging: &mut staging };
        if O::ENABLED {
            let mut kind = ev_kind(&ev, ncells);
            let (pre_completed, pre_migrations) = (system.completed, system.migrations);
            system.handle(&mut ctx, ev);
            // post-state flags from counter deltas, so `handle` stays
            // verbatim
            match &mut kind {
                FleetEv::SubDone { job_completed, .. } => {
                    *job_completed = system.completed > pre_completed;
                }
                FleetEv::MigrationDone { landed, .. } => {
                    *landed = system.migrations > pre_migrations;
                }
                _ => {}
            }
            system.observe(now, kind);
        } else {
            system.handle(&mut ctx, ev);
        }
        for (t, ev) in staging.drain(..) {
            let dest = route_ev(&ev, ncells);
            // vopr self-test fault EpochLeak: the first job-carrying
            // message crossing cells vanishes at the exchange — the
            // job-conservation checker's quiescence clause must fire
            #[cfg(any(test, feature = "vopr-selftest"))]
            if leak_armed
                && dest != cell
                && matches!(
                    &ev,
                    Ev::SubDone { .. } | Ev::RecoveryDone { .. } | Ev::MigrationDone { .. }
                )
            {
                leak_armed = false;
                continue;
            }
            wheels.push(dest, band_key(t, BAND_RUN, run_seq), ev);
            run_seq += 1;
        }
    }
    let events = dispatched;
    // the queue drained before the horizon ⇔ the trial went quiescent
    let hit_horizon = end == horizon;
    if let Some(m) = churn {
        scratch.churn_cursors = m.cursors;
        scratch.churn_heap = m.heap;
        scratch.churn_tmp = m.tmp;
    }
    scratch.staging = staging;
    // integrate the idle tail so utilization covers the whole horizon
    system.tick(horizon);
    system.observe_end(horizon, hit_horizon);

    let slot_s = spec.horizon_s * (n * spec.capacity) as f64;
    let (mean_slowdown, p95_slowdown) = if system.slowdowns.count() > 0 {
        let s = system.slowdowns.summary();
        (s.mean, s.p95)
    } else {
        (f64::NAN, f64::NAN)
    };
    let outcome = FleetOutcome {
        jobs_arrived: system.arrived,
        jobs_completed: system.completed,
        jobs_waiting: system.queue.len(),
        goodput_ratio: if slot_s > 0.0 { system.completed_compute_s / slot_s } else { f64::NAN },
        mean_slowdown,
        p95_slowdown,
        last_completion_s: system.last_completion.as_secs(),
        utilization: system.util.weighted_mean(),
        migrations: system.migrations,
        rollbacks: system.rollbacks,
        subs_lost: system.subs_lost,
        absorbed_failures: system.absorbed_failures,
        peak_concurrent_migrations: system.peak_migr,
        peak_concurrent_recoveries: system.peak_rec,
        peak_live_jobs: system.jobs.peak_live,
        net_retries: system.net_retries,
        net_timeouts: system.net_timeouts,
        fallbacks: system.fallbacks,
        dup_suppressed: system.dup_suppressed,
        spurious_migrations: system.spurious_migrations,
        quarantines: system.quarantines,
        quarantine_releases: system.quarantine_releases,
        degraded_node_s,
        events,
    };
    // hand the allocations back for the next trial
    scratch.jobs = system.jobs;
    scratch.queue = system.queue;
    scratch.placement = system.placement;
    scratch.node_subs = system.node_subs;
    scratch.scan = system.scan;
    scratch.predicted = system.predicted;
    scratch.suspicion = system.suspicion;
    scratch.offenses = system.offenses;
    scratch.slow_windows = system.slow_windows;
    scratch.derive = system.derive;
    outcome
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::checkpoint::CheckpointStrategy;

    fn quiet(strategy: Strategy) -> FleetSpec {
        // no churn, trace arrivals: fully deterministic skeleton
        FleetSpec {
            arrivals: ArrivalSpec::Trace { at_s: vec![0.0] },
            churn: ChurnSpec::Plan(FailurePlan { events: vec![] }),
            ..FleetSpec::placentia_fleet(strategy, 16, 0.0, 0.0)
        }
    }

    #[test]
    fn single_job_no_churn_completes_at_nominal() {
        let o = run_fleet(&quiet(Strategy::Hybrid), 1);
        assert_eq!(o.jobs_arrived, 1);
        assert_eq!(o.jobs_completed, 1);
        assert_eq!(o.jobs_waiting, 0);
        assert_eq!(o.last_completion_s, 1800.0);
        assert_eq!(o.mean_slowdown, 1.0);
        assert_eq!(o.migrations, 0);
        assert_eq!(o.rollbacks, 0);
        assert_eq!(o.peak_live_jobs, 1);
        // 8 subs × 1800 s over 16 nodes × 2 slots × 4 h
        let want = 8.0 * 1800.0 / (16.0 * 2.0 * 14400.0);
        assert!((o.goodput_ratio - want).abs() < 1e-12);
        // utilization: 8/32 slots busy for 1800 of 14400 s
        assert!((o.utilization - 0.25 * 1800.0 / 14400.0).abs() < 1e-12);
    }

    #[test]
    fn empty_fleet_is_well_defined() {
        let spec = FleetSpec {
            arrivals: ArrivalSpec::Trace { at_s: vec![] },
            ..quiet(Strategy::Agent)
        };
        let o = run_fleet(&spec, 3);
        assert_eq!(o.jobs_arrived, 0);
        assert_eq!(o.jobs_completed, 0);
        assert_eq!(o.peak_live_jobs, 0);
        assert!(o.mean_slowdown.is_nan(), "no completions ⇒ NaN slowdown");
        assert_eq!(o.utilization, 0.0, "idle horizon integrates to zero");
        assert_eq!(o.goodput_ratio, 0.0);
        // a zero-length horizon has no mass at all: NaN, never a panic
        let o0 = run_fleet(&FleetSpec { horizon_s: 0.0, ..spec }, 3);
        assert!(o0.utilization.is_nan());
    }

    #[test]
    fn deterministic_in_seed() {
        let spec = FleetSpec::placentia_fleet(Strategy::Hybrid, 32, 8.0, 0.5);
        let a = run_fleet(&spec, 11);
        let b = run_fleet(&spec, 11);
        assert_eq!(a.events, b.events);
        assert_eq!(a.mean_slowdown.to_bits(), b.mean_slowdown.to_bits());
        assert_eq!(a.utilization.to_bits(), b.utilization.to_bits());
        assert_eq!(a.migrations, b.migrations);
        assert_eq!(a.rollbacks, b.rollbacks);
        let c = run_fleet(&spec, 12);
        assert_ne!(a.events, c.events, "different seeds draw different fleets");
    }

    #[test]
    fn scratch_reuse_is_bit_identical() {
        let spec = FleetSpec::placentia_fleet(Strategy::Core, 24, 6.0, 0.5);
        let mut scratch = FleetScratch::new();
        for seed in [1u64, 2, 3] {
            let fresh = run_fleet(&spec, seed);
            let reused = run_fleet_scratch(&spec, seed, &mut scratch);
            assert_eq!(fresh.events, reused.events);
            assert_eq!(fresh.mean_slowdown.to_bits(), reused.mean_slowdown.to_bits());
            assert_eq!(fresh.utilization.to_bits(), reused.utilization.to_bits());
            assert_eq!(fresh.goodput_ratio.to_bits(), reused.goodput_ratio.to_bits());
            assert_eq!(fresh.migrations, reused.migrations);
            assert_eq!(fresh.rollbacks, reused.rollbacks);
            assert_eq!(fresh.peak_live_jobs, reused.peak_live_jobs);
        }
    }

    #[test]
    fn capacity_queues_then_places() {
        // 4 nodes × 2 slots = 8 slots; two 8-sub jobs: the second waits for
        // the first to finish, then runs — completions 1800 and 3600
        let spec = FleetSpec {
            arrivals: ArrivalSpec::Trace { at_s: vec![0.0, 10.0] },
            topo: Topology::ring(4, 2),
            ..quiet(Strategy::Hybrid)
        };
        let o = run_fleet(&spec, 5);
        assert_eq!(o.jobs_arrived, 2);
        assert_eq!(o.jobs_completed, 2);
        assert_eq!(o.last_completion_s, 3600.0);
        // slowdowns: 1.0 and (3600 − 10)/1800
        assert!((o.mean_slowdown - (1.0 + 3590.0 / 1800.0) / 2.0).abs() < 1e-12);
    }

    #[test]
    fn slab_peaks_at_concurrency_not_arrivals() {
        // 40 non-overlapping jobs: each finishes (1800 s) before the next
        // arrives (every 2000 s), so the slab never holds more than one
        // live job — the arena allocates O(live), not O(arrivals)
        let at_s: Vec<f64> = (0..40).map(|i| i as f64 * 2000.0).collect();
        let spec = FleetSpec {
            arrivals: ArrivalSpec::Trace { at_s },
            horizon_s: 90_000.0,
            ..quiet(Strategy::Hybrid)
        };
        let o = run_fleet(&spec, 9);
        assert_eq!(o.jobs_arrived, 40);
        assert_eq!(o.jobs_completed, 40);
        assert_eq!(o.peak_live_jobs, 1, "{o:?}");
    }

    #[test]
    fn churn_with_repair_keeps_completing_jobs() {
        let spec = FleetSpec::placentia_fleet(Strategy::Hybrid, 32, 6.0, 0.5);
        let o = run_fleet(&spec, 7);
        assert!(o.jobs_arrived > 4, "{o:?}");
        assert!(o.jobs_completed > 0, "{o:?}");
        assert!(o.goodput_ratio > 0.0);
        assert!(o.utilization > 0.0 && o.utilization <= 1.0 + 1e-9, "{o:?}");
        assert!(o.mean_slowdown >= 1.0 - 1e-9, "{o:?}");
        assert!(o.peak_live_jobs >= 1 && o.peak_live_jobs <= o.jobs_arrived, "{o:?}");
    }

    #[test]
    fn checkpoint_contention_hurts() {
        // same fleet, checkpoint recovery: one shared server stream vs
        // effectively unlimited streams — starved recoveries stretch
        let ckpt = Strategy::Checkpoint(CheckpointStrategy::CentralSingle);
        let mut spec = FleetSpec::placentia_fleet(ckpt, 32, 6.0, 1.0);
        spec.job.predictable_frac = 0.0; // reactive only
        let starved = FleetSpec { ckpt_streams: 1, ..spec.clone() };
        let roomy = FleetSpec { ckpt_streams: 1024, ..spec };
        // recovery stretch shifts later event interleavings (and so RNG
        // draws), so the claim is aggregate, not per-seed
        let mut sum_starved = 0.0;
        let mut sum_roomy = 0.0;
        let mut trials = 0;
        for seed in 0..8u64 {
            let a = run_fleet(&starved, seed);
            let b = run_fleet(&roomy, seed);
            if a.jobs_completed == 0 || b.jobs_completed == 0 {
                continue;
            }
            trials += 1;
            sum_starved += a.mean_slowdown;
            sum_roomy += b.mean_slowdown;
        }
        assert!(trials > 0, "no trial completed jobs");
        assert!(
            sum_starved > sum_roomy,
            "contended server slowdown {sum_starved} must exceed uncontended {sum_roomy}"
        );
    }

    #[test]
    fn proactive_beats_reactive_under_churn() {
        // the paper's 90-vs-10 headline at fleet scale: hybrid proactive
        // migration vs checkpoint-only reactive recovery
        let hybrid = FleetSpec::placentia_fleet(Strategy::Hybrid, 32, 6.0, 1.0);
        let mut ckpt = FleetSpec::placentia_fleet(
            Strategy::Checkpoint(CheckpointStrategy::CentralSingle),
            32,
            6.0,
            1.0,
        );
        ckpt.job.predictable_frac = 0.0;
        ckpt.ckpt_streams = 1;
        let mut sum_h = 0.0;
        let mut sum_c = 0.0;
        let mut used = 0;
        for seed in 0..6u64 {
            let h = run_fleet(&hybrid, seed);
            let c = run_fleet(&ckpt, seed);
            if h.jobs_completed > 0 && c.jobs_completed > 0 {
                used += 1;
                sum_h += h.mean_slowdown;
                sum_c += c.mean_slowdown;
            }
        }
        assert!(used > 0, "no seed completed jobs under both strategies");
        assert!(
            sum_h < sum_c,
            "proactive fleet slowdown {sum_h} must beat reactive {sum_c}"
        );
    }

    #[test]
    fn storms_are_observed_under_heavy_churn() {
        let spec = FleetSpec::placentia_fleet(Strategy::Hybrid, 48, 10.0, 2.0);
        let o = run_fleet(&spec, 13);
        assert!(o.migrations > 0, "{o:?}");
        assert!(o.peak_concurrent_migrations >= 1, "{o:?}");
        // unpredicted fraction forces some rollbacks at this churn rate
        assert!(o.rollbacks > 0, "{o:?}");
        assert!(o.peak_concurrent_recoveries >= 1, "{o:?}");
    }

    #[test]
    fn scale_fleet_spec_targets_ninety_percent_load() {
        let spec = FleetSpec::scale_fleet(Strategy::Hybrid, 1000, 10_000, 0.05);
        let ArrivalSpec::Poisson { rate_per_h } = spec.arrivals else {
            panic!("scale fleet must be Poisson");
        };
        assert!((rate_per_h - 450.0).abs() < 1e-9);
        // expected arrivals over the horizon = the requested count
        assert!((spec.horizon_s / 3600.0 * rate_per_h - 10_000.0).abs() < 1e-6);
        assert_eq!(spec.topo.len(), 1000);
    }

    #[test]
    fn placement_index_matches_linear_scan() {
        // the index's best() must equal the old full scan on random
        // load/health states, including saturation
        let mut rng = Rng::new(77);
        for _ in 0..200 {
            let n = 1 + rng.range_usize(0, 40);
            let cap = 1 + rng.range_usize(0, 3);
            // cell count must not change which node best() returns
            let ncells = 1 + rng.range_usize(0, 5);
            let mut idx = PlacementIndex::default();
            idx.reset(n, cap, ncells);
            let mut doomed = vec![false; n];
            let mut quar = vec![false; n];
            let mut occ = vec![0usize; n];
            // random walk of the same transitions the fleet performs
            for _ in 0..120 {
                let node = NodeId(rng.range_usize(0, n));
                match rng.range_usize(0, 6) {
                    0 if !doomed[node.0] && !quar[node.0] && occ[node.0] < cap => {
                        occ[node.0] += 1;
                        idx.inc(node);
                    }
                    1 if occ[node.0] > 0 => {
                        occ[node.0] -= 1;
                        idx.dec(node);
                    }
                    2 if !doomed[node.0] => {
                        doomed[node.0] = true;
                        idx.doom(node);
                    }
                    3 if doomed[node.0] => {
                        doomed[node.0] = false;
                        idx.repair(node);
                    }
                    4 if !quar[node.0] => {
                        quar[node.0] = true;
                        idx.quarantine(node);
                    }
                    5 if quar[node.0] => {
                        quar[node.0] = false;
                        idx.release(node);
                    }
                    _ => {}
                }
                let mut best: Option<NodeId> = None;
                for v in 0..n {
                    if doomed[v] || quar[v] || occ[v] >= cap {
                        continue;
                    }
                    best = match best {
                        Some(b) if occ[v] < occ[b.0] => Some(NodeId(v)),
                        None => Some(NodeId(v)),
                        keep => keep,
                    };
                }
                assert_eq!(idx.best(), best, "index diverged from the linear scan");
            }
        }
    }

    #[test]
    fn fleet_metric_selectors() {
        let o = run_fleet(&quiet(Strategy::Hybrid), 1);
        assert_eq!(FleetMetric::MeanSlowdown.measure(&o), o.mean_slowdown);
        assert_eq!(FleetMetric::Goodput.measure(&o), o.goodput_ratio);
        assert_eq!(FleetMetric::Utilization.measure(&o), o.utilization);
    }

    #[test]
    fn default_plane_reports_zero_net_activity() {
        let spec = FleetSpec::placentia_fleet(Strategy::Hybrid, 32, 8.0, 1.0);
        let o = run_fleet(&spec, 11);
        assert!(o.migrations > 0 || o.rollbacks > 0, "churny fixture must recover: {o:?}");
        assert_eq!(o.net_retries, 0);
        assert_eq!(o.net_timeouts, 0);
        assert_eq!(o.fallbacks, 0);
        assert_eq!(o.dup_suppressed, 0);
    }

    #[test]
    fn total_peer_loss_falls_back_to_checkpoint_recovery() {
        // loss_p = 1 on the peer links: no migration handshake can ever
        // complete, so every proactive migration must fall back to a
        // reactive rollback — and the fleet must keep completing jobs.
        let mut spec = FleetSpec::placentia_fleet(Strategy::Hybrid, 16, 4.0, 1.0);
        spec.faults.peer.loss_p = 1.0;
        let o = run_fleet(&spec, 3);
        assert!(o.net_timeouts > 0, "{o:?}");
        assert!(o.net_retries > 0, "{o:?}");
        assert!(o.fallbacks > 0, "exhausted handshakes must fall back: {o:?}");
        assert_eq!(o.migrations, 0, "loss_p = 1 lets no migration land: {o:?}");
        assert!(o.rollbacks as u64 >= o.fallbacks, "every fallback is a rollback: {o:?}");
        assert!(o.jobs_completed > 0, "{o:?}");
    }

    #[test]
    fn checkpoint_partition_degrades_restores_but_never_loses_jobs() {
        use crate::net::{CutSet, Partition};
        let ckpt = Strategy::Checkpoint(CheckpointStrategy::CentralSingle);
        let mut spec = FleetSpec::placentia_fleet(ckpt, 16, 4.0, 1.0);
        spec.job.predictable_frac = 0.0; // reactive only
        spec.faults.partitions.push(Partition {
            start_s: 0.0,
            end_s: spec.horizon_s,
            cut: CutSet::Checkpoint,
        });
        let o = run_fleet(&spec, 5);
        assert!(o.rollbacks > 0, "{o:?}");
        assert!(o.fallbacks > 0, "a severed server must degrade restores: {o:?}");
        assert_eq!(
            o.fallbacks, o.rollbacks as u64,
            "every rollback's restore exchange hit the cut: {o:?}"
        );
        assert!(o.net_timeouts > 0, "{o:?}");
        assert!(o.jobs_completed > 0, "degraded cold restores still finish: {o:?}");
    }

    #[test]
    fn lossy_plane_is_deterministic_in_seed() {
        use crate::net::LinkFaults;
        let mut spec = FleetSpec::placentia_fleet(Strategy::Hybrid, 24, 6.0, 1.0);
        spec.faults.peer =
            LinkFaults { loss_p: 0.3, dup_p: 0.1, delay_p: 0.2, delay_mean_s: 0.5 };
        spec.faults.ckpt = LinkFaults { loss_p: 0.2, ..LinkFaults::off() };
        let a = run_fleet(&spec, 17);
        let b = run_fleet(&spec, 17);
        assert_eq!(a.events, b.events);
        assert_eq!(a.net_retries, b.net_retries);
        assert_eq!(a.net_timeouts, b.net_timeouts);
        assert_eq!(a.fallbacks, b.fallbacks);
        assert_eq!(a.dup_suppressed, b.dup_suppressed);
        assert_eq!(a.mean_slowdown.to_bits(), b.mean_slowdown.to_bits());
        assert_eq!(a.utilization.to_bits(), b.utilization.to_bits());
    }

    #[test]
    fn validate_surfaces_fault_plane_errors() {
        let mut spec = FleetSpec::placentia_fleet(Strategy::Hybrid, 4, 1.0, 0.0);
        spec.faults.peer.loss_p = 2.0;
        assert_eq!(spec.validate(), Err(SpecError::BadFaultProbability));
        let mut spec = FleetSpec::placentia_fleet(Strategy::Hybrid, 4, 1.0, 0.0);
        spec.faults.link.bandwidth_bps = 0.0;
        assert_eq!(spec.validate(), Err(SpecError::BadLinkBandwidth));
        let mut spec = FleetSpec::placentia_fleet(Strategy::Hybrid, 4, 1.0, 0.0);
        spec.faults.retry.max_retries = 65;
        assert_eq!(spec.validate(), Err(SpecError::BadRetryPolicy));
    }

    #[test]
    fn validate_surfaces_gray_plane_errors() {
        use crate::failure::gray::DetectorModel;
        let mut spec = FleetSpec::placentia_fleet(Strategy::Hybrid, 4, 1.0, 0.0);
        spec.gray.detector =
            Some(DetectorModel { coverage: 1.5, precision: 0.5, lead_jitter_s: 0.0 });
        assert_eq!(spec.validate(), Err(SpecError::BadDetector));
        let mut spec = FleetSpec::placentia_fleet(Strategy::Hybrid, 4, 1.0, 0.0);
        spec.gray.fail_slow.speed_factor = 0.0;
        assert_eq!(spec.validate(), Err(SpecError::BadFailSlow));
        let mut spec = FleetSpec::placentia_fleet(Strategy::Hybrid, 4, 1.0, 0.0);
        spec.gray.flapping.burst_len = 0;
        assert_eq!(spec.validate(), Err(SpecError::BadFlapping));
        let mut spec = FleetSpec::placentia_fleet(Strategy::Hybrid, 4, 1.0, 0.0);
        spec.gray.quarantine.backoff_mult = 0.5;
        assert_eq!(spec.validate(), Err(SpecError::BadQuarantine));
    }

    #[test]
    fn flapping_quarantines_and_releases_repeat_offenders() {
        // 2 bursts/node/h × burst_len 3 ≥ the suspicion threshold: over a
        // 4-hour horizon essentially every node earns a quarantine, and
        // the 10-minute probation releases fit inside the horizon too.
        let mut spec = FleetSpec::placentia_fleet(Strategy::Hybrid, 16, 4.0, 0.0);
        spec.gray.flapping.rate_per_node_h = 2.0;
        spec.validate().unwrap();
        let o = run_fleet(&spec, 9);
        assert!(o.quarantines > 0, "flap bursts must cross the threshold: {o:?}");
        assert!(o.quarantine_releases > 0, "probation must lapse in-horizon: {o:?}");
        assert!(o.quarantine_releases <= o.quarantines, "{o:?}");
        assert!(o.jobs_completed > 0, "{o:?}");
    }

    #[test]
    fn imperfect_detector_pays_spurious_migrations() {
        use crate::failure::gray::DetectorModel;
        // precision 0.25 drags three expected false alarms behind every
        // covered failure; on a busy multi-agent fleet some of them land
        // on nodes with resident sub-jobs and trigger paid-for sweeps.
        let mut spec = FleetSpec::placentia_fleet(Strategy::Hybrid, 32, 12.0, 1.0);
        spec.gray.detector =
            Some(DetectorModel { coverage: 0.9, precision: 0.25, lead_jitter_s: 30.0 });
        spec.validate().unwrap();
        let o = run_fleet(&spec, 7);
        assert!(o.spurious_migrations > 0, "false alarms must cost migrations: {o:?}");
        assert!(o.migrations as u64 >= o.spurious_migrations, "{o:?}");
        assert!(o.jobs_completed > 0, "{o:?}");
    }

    #[test]
    fn fail_slow_degrades_without_losing_work() {
        // saturating fail-slow coverage on a single-job fixture: the job
        // must still finish (degraded, never lost) and strictly later
        // than the clean run.
        let clean = quiet(Strategy::Hybrid);
        let mut slow = quiet(Strategy::Hybrid);
        slow.gray.fail_slow.rate_per_node_h = 30.0;
        let a = run_fleet(&clean, 13);
        let b = run_fleet(&slow, 13);
        assert!(b.degraded_node_s > 0.0, "{b:?}");
        assert_eq!(b.jobs_completed, 1, "{b:?}");
        assert!(
            b.last_completion_s > a.last_completion_s,
            "degraded compute must stretch the completion: {} vs {}",
            b.last_completion_s,
            a.last_completion_s
        );
    }

    #[test]
    fn gray_plane_is_deterministic_in_seed() {
        use crate::failure::gray::DetectorModel;
        let mut spec = FleetSpec::placentia_fleet(Strategy::Hybrid, 24, 6.0, 1.0);
        spec.gray.detector =
            Some(DetectorModel { coverage: 0.5, precision: 0.5, lead_jitter_s: 20.0 });
        spec.gray.flapping.rate_per_node_h = 1.0;
        spec.gray.fail_slow.rate_per_node_h = 0.5;
        let a = run_fleet(&spec, 17);
        let b = run_fleet(&spec, 17);
        assert_eq!(a.events, b.events);
        assert_eq!(a.spurious_migrations, b.spurious_migrations);
        assert_eq!(a.quarantines, b.quarantines);
        assert_eq!(a.quarantine_releases, b.quarantine_releases);
        assert_eq!(a.degraded_node_s.to_bits(), b.degraded_node_s.to_bits());
        assert_eq!(a.mean_slowdown.to_bits(), b.mean_slowdown.to_bits());
        assert_eq!(a.utilization.to_bits(), b.utilization.to_bits());
    }

    #[test]
    fn lazy_churn_merge_matches_eager_plan_sort() {
        // the lazy per-node cursors must emit the exact global stream the
        // eager path produced: sequential forks off seed ^ CHURN_SALT, one
        // plan per node, all events stably sorted by (at, node)
        let procs = [
            FailureProcess::Poisson { rate_per_window: 1.7 },
            FailureProcess::RandomUniformK { k: 2 },
            FailureProcess::Periodic { offset_s: 900.0 },
        ];
        for (pi, process) in procs.iter().enumerate() {
            for seed in [3u64, 19] {
                let (n, window_s, horizon_s) = (6usize, 3600.0, 4.5 * 3600.0);
                let windows = (horizon_s / window_s).ceil() as usize;
                let mut crng = Rng::new(seed ^ CHURN_SALT);
                let mut eager: Vec<(SimTime, usize)> = Vec::new();
                for node in 0..n {
                    let mut prng = crng.fork(node as u64);
                    let plan = process.plan(windows, window_s, 1, &mut prng);
                    eager.extend(plan.events.iter().map(|e| (e.at, node)));
                }
                eager.sort_by_key(|&(at, node)| (at, node));
                let mut merge = ChurnMerge::new(
                    process,
                    window_s,
                    horizon_s,
                    n,
                    seed,
                    Vec::new(),
                    BinaryHeap::new(),
                    Vec::new(),
                );
                let mut lazy: Vec<(SimTime, usize)> = Vec::new();
                while let Some(head) = merge.head_at() {
                    let (at, node, k) = merge.pop().expect("head_at promised an event");
                    assert_eq!(at, head);
                    assert_eq!(k, lazy.len() as u64, "k is the emission index");
                    lazy.push((at, node.0));
                }
                assert_eq!(eager, lazy, "process {pi} seed {seed}");
            }
        }
    }

    #[test]
    fn cells_are_a_pure_performance_knob() {
        // quick in-module smoke; the cross-plane sweep lives in
        // tests/fleet_sharding.rs
        let base = FleetSpec::placentia_fleet(Strategy::Hybrid, 32, 6.0, 1.0);
        let a = run_fleet(&base, 21);
        assert!(a.jobs_completed > 0, "{a:?}");
        for cells in [2usize, 4, 7] {
            let spec =
                FleetSpec { cells: NonZeroUsize::new(cells).unwrap(), ..base.clone() };
            let b = run_fleet(&spec, 21);
            assert_eq!(a.events, b.events, "cells={cells}");
            assert_eq!(a.migrations, b.migrations, "cells={cells}");
            assert_eq!(a.rollbacks, b.rollbacks, "cells={cells}");
            assert_eq!(
                a.mean_slowdown.to_bits(),
                b.mean_slowdown.to_bits(),
                "cells={cells}"
            );
            assert_eq!(a.utilization.to_bits(), b.utilization.to_bits(), "cells={cells}");
            assert_eq!(
                a.goodput_ratio.to_bits(),
                b.goodput_ratio.to_bits(),
                "cells={cells}"
            );
        }
    }
}
