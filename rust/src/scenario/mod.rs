//! Scenario layer: composable failure regimes over the live simulation,
//! plus the parallel batch runner that fans seeded trials across OS
//! threads.
//!
//! The paper evaluates exactly one regime — a single core failing once per
//! checkpoint window. [`ScenarioSpec`] generalises that to the regimes its
//! own discussion (and the fault-tolerance survey literature) point at:
//!
//! * **single** — the paper's processes ([`FailureProcess`]), unchanged;
//! * **concurrent-k** — `k` distinct nodes failing (near-)simultaneously;
//! * **correlated** — rack-adjacency spreading: a primary failure dooms its
//!   rack-mates with some probability;
//! * **cascade** — every migration's target can itself fail mid-reinstate
//!   ([`CascadeSpec`](crate::coordinator::livesim::CascadeSpec)).
//!
//! Each trial owns its engine, so batches are embarrassingly parallel:
//! [`batch`] fans thousands of seeded trials over threads and feeds
//! [`metrics::Summary`](crate::metrics::Summary). Results are keyed by
//! trial seed, never by thread, so a batch's output is independent of the
//! thread count — asserted in tests and in `tests/harness_properties.rs`.
//!
//! [`sweep`] fuses a whole experiment *grid* — every (strategy × preset ×
//! parameter point) cell — into one (cell × trial-chunk) task list over
//! the same scheduler, with streaming per-cell statistics
//! ([`metrics::Accumulator`](crate::metrics::Accumulator)) instead of a
//! `Vec<f64>` per cell; the grid experiments (fig8–fig13, `multik`,
//! `correlated`, `cascade`, `rules`, and the `fleet` family) all run
//! through it.
//!
//! [`fleet`] lifts the whole layer from one job per trial to a *cluster
//! lifetime* per trial: a continuous multi-job simulation with Poisson/
//! trace arrivals, online placement, per-strategy fault tolerance with
//! checkpoint-server contention, and node churn with repair — the
//! production regime the paper's discussion points at (DESIGN.md §Fleet
//! simulator).
//!
//! [`vopr`] closes the loop on correctness: a VOPR-style chaos explorer
//! that random-walks `FleetSpec`/`ScenarioSpec` space across seeds,
//! checks invariants continuously through the zero-cost
//! [`FleetObserver`](fleet::FleetObserver) hook (job conservation,
//! capacity bounds, bookkeeping agreement, queue progress, monotone
//! time, termination), and greedily shrinks any failing `(spec, seed)`
//! pair into a copy-pasteable repro (DESIGN.md §VOPR explorer).
//!
//! [`FailureProcess`]: crate::failure::injector::FailureProcess

pub mod batch;
pub mod fleet;
pub mod spec;
pub mod sweep;
pub mod vopr;

pub use batch::{
    default_threads, parallel_map_trials, parallel_map_trials_scratch, run_batch, thread_policy,
    BatchCfg, BatchOutcome,
};
pub use crate::coordinator::livesim::LiveScratch;
pub use fleet::{
    run_fleet, run_fleet_observed, run_fleet_scratch, sample_arrivals, ArrivalSpec, ChurnSpec,
    FleetEv, FleetMetric, FleetObserver, FleetOutcome, FleetScratch, FleetSpec, FleetView,
    SpecError,
};
pub use spec::{FailureRegime, ScenarioSpec};
pub use sweep::{run_sweep, CellKind, CellSpec, SweepSpec};
pub use vopr::{
    decode_walk, default_invariants, encode_walk, explore, run_repro, shrink_fleet, ExploreReport,
    Invariant, InvariantObserver, Violation, VoprCfg, WalkSpec,
};
