//! The fused grid-sweep executor (DESIGN.md §Sweep executor).
//!
//! Every grid experiment used to walk its grid serially — one
//! `measure_reinstate` / `run_batch` call per point — so a figure of
//! 15 × 4 × 30-trial cells never crossed the parallel-trial threshold and
//! ran on one core, with a hard barrier between points. [`run_sweep`]
//! flattens the whole grid into one global (cell × trial-chunk) task list
//! dispatched through the existing work-stealing
//! [`parallel_map_trials_scratch`](super::batch::parallel_map_trials_scratch)
//! scheduler: the grid is one unit of parallel work, a slow cell no longer
//! serialises behind fast ones, and per-cell memory is bounded by the
//! streaming [`Accumulator`] instead of a `Vec<f64>` of trial outcomes.
//!
//! ## Determinism contract
//!
//! * A cell's chunk layout depends only on `trials_per_cell` (fixed
//!   [`SWEEP_CHUNK`]-trial chunks), never on the thread count.
//! * Chunk accumulators merge **in chunk-index order** (out-of-order
//!   finishers park until their turn; claims come off a monotone atomic
//!   counter, so at most ~`threads` chunks can ever be parked per cell).
//! * Reinstate cells re-derive their serial RNG stream per chunk: a chunk
//!   fast-forwards `Rng::new(cell.seed)` with
//!   [`skip_episode`](crate::agentft::migration::skip_episode) — bit-
//!   identical consumption to [`draw_episode`] — then draws its own trial
//!   range. The values every trial sees are exactly the historical serial
//!   loop's, so Figs. 8–13 / Tables 1–2 reproduce byte-for-byte.
//! * Scenario cells are trial-seeded (`seed + i`) like
//!   [`run_batch`](super::batch::run_batch); no stream to fast-forward.
//! * Fleet cells ([`scenario::fleet`](super::fleet)) are trial-seeded the
//!   same way — each trial is one whole cluster lifetime, so `fleet` grids
//!   (arrival rate × strategy × churn × cluster size) inherit the identical
//!   determinism contract.
//!
//! Cells at or below the quantile cap therefore report summaries
//! byte-identical to the historical per-point loop at **any** thread
//! count; larger cells degrade to histogram quantiles (exact mean-to-
//! Welford-tolerance, exact min/max) with O(chunk) memory per worker —
//! property-tested in `tests/sweep_properties.rs`.

use super::batch::{parallel_map_trials_scratch, thread_policy};
use super::fleet::{run_fleet_scratch, FleetMetric, FleetScratch, FleetSpec};
use super::spec::ScenarioSpec;
use crate::agentft::migration::{draw_episode_into, skip_episode, EpisodeDraws};
use crate::coordinator::ftmanager::Strategy;
use crate::coordinator::livesim::LiveScratch;
use crate::coordinator::run::{adjacent3, ExperimentCfg, ReinstatePoint, ReinstateScratch};
use crate::metrics::{Accumulator, Summary, DEFAULT_QUANTILE_CAP};
use crate::net::NodeId;
use crate::sim::Rng;
use std::sync::Mutex;

/// Trials per chunk task. Small enough that a handful of big cells still
/// spread across every core, large enough to amortise the per-chunk RNG
/// fast-forward and the reduction lock.
pub const SWEEP_CHUNK: usize = 2048;

/// What one cell measures.
#[derive(Debug, Clone)]
pub enum CellKind {
    /// A `measure_reinstate`-compatible episode cell: trial randomness is
    /// one serial stream from `Rng::new(seed)`, episodes are deterministic.
    /// The measured value is `extra_s + reinstate_s`.
    Reinstate { strategy: Strategy, cfg: ExperimentCfg },
    /// A `run_batch`-compatible scenario cell: trial `i` runs
    /// `spec.run_trial(seed + i)`; the measured value is `completed_at_s`.
    Scenario { spec: ScenarioSpec },
    /// A fleet cell: trial `i` runs one whole cluster lifetime
    /// (`run_fleet(spec, seed + i)`); the measured value is
    /// `metric.measure(..)` — NaN trials (e.g. no completed jobs under
    /// `MeanSlowdown`) propagate through the cell summary per the
    /// [`Summary`] NaN contract.
    Fleet { spec: FleetSpec, metric: FleetMetric },
}

/// One grid point: a kind plus its per-cell seed (the `Rng::new` seed for
/// reinstate cells, the base trial seed for scenario cells).
#[derive(Debug, Clone)]
pub struct CellSpec {
    pub seed: u64,
    pub kind: CellKind,
}

impl CellSpec {
    pub fn reinstate(strategy: Strategy, cfg: ExperimentCfg, seed: u64) -> Self {
        Self { seed, kind: CellKind::Reinstate { strategy, cfg } }
    }

    pub fn scenario(spec: ScenarioSpec, base_seed: u64) -> Self {
        Self { seed: base_seed, kind: CellKind::Scenario { spec } }
    }

    pub fn fleet(spec: FleetSpec, metric: FleetMetric, base_seed: u64) -> Self {
        Self { seed: base_seed, kind: CellKind::Fleet { spec, metric } }
    }
}

/// A whole experiment grid as one parallel unit of work.
#[derive(Debug, Clone)]
pub struct SweepSpec {
    pub cells: Vec<CellSpec>,
    /// Trials per cell (clamped to ≥ 1, like `measure_reinstate`).
    pub trials_per_cell: usize,
    /// Worker threads: `Some(n)` forces `n` (`Some(0)` ⇒ one per core,
    /// like every other threads knob in the crate); `None` defers to
    /// [`thread_policy`](super::batch::thread_policy) over the grid's
    /// *total* trial count — the whole point of fusing: 60 cells of 30
    /// trials are 1800 trials of parallel work, not 60 serial sweeps.
    pub threads: Option<usize>,
    /// Per-cell exact-quantile cap (see
    /// [`Accumulator`](crate::metrics::Accumulator)); cells at or below it
    /// report byte-identical summaries to the historical `Vec<f64>` path.
    pub quantile_cap: usize,
}

impl SweepSpec {
    pub fn new(cells: Vec<CellSpec>, trials_per_cell: usize) -> Self {
        Self { cells, trials_per_cell, threads: None, quantile_cap: DEFAULT_QUANTILE_CAP }
    }
}

/// Per-worker reusable state: episode + live-sim scratch, and one
/// [`EpisodeDraws`] the chunk loop draws each trial into (no per-trial
/// allocation on the sweep path).
struct SweepScratch {
    reinstate: ReinstateScratch,
    live: LiveScratch,
    fleet: FleetScratch,
    draws: EpisodeDraws,
    adjacent: Vec<(NodeId, bool)>,
}

impl SweepScratch {
    fn new() -> Self {
        Self {
            reinstate: ReinstateScratch::new(),
            live: LiveScratch::new(),
            fleet: FleetScratch::new(),
            draws: EpisodeDraws { target: NodeId(0), jitter: Vec::new() },
            adjacent: adjacent3(),
        }
    }
}

/// Per-cell ordered reducer: chunk accumulators merge strictly in
/// chunk-index order; early finishers park. Claims off the scheduler's
/// atomic counter are monotone, so `parked` holds at most the in-flight
/// window (≈ threads × claim size) — each entry O(chunk) — never the cell.
struct CellReduce {
    next: usize,
    acc: Accumulator,
    parked: Vec<(usize, Accumulator)>,
}

impl CellReduce {
    fn offer(&mut self, chunk: usize, acc: Accumulator) {
        if chunk != self.next {
            self.parked.push((chunk, acc));
            return;
        }
        self.acc.merge(acc);
        self.next += 1;
        while let Some(i) = self.parked.iter().position(|(c, _)| *c == self.next) {
            let (_, a) = self.parked.swap_remove(i);
            self.acc.merge(a);
            self.next += 1;
        }
    }
}

/// Run one chunk of a cell's trials into a fresh accumulator.
fn run_chunk(
    cell: &CellSpec,
    trials: usize,
    chunk: usize,
    cap: usize,
    sc: &mut SweepScratch,
) -> Accumulator {
    let start = chunk * SWEEP_CHUNK;
    let end = (start + SWEEP_CHUNK).min(trials);
    let mut acc = Accumulator::with_cap(cap);
    match &cell.kind {
        CellKind::Reinstate { strategy, cfg } => {
            let point = ReinstatePoint::new(*strategy, cfg);
            let sigma = point.costs.noise_sigma;
            let mut rng = Rng::new(cell.seed);
            for _ in 0..start {
                skip_episode(point.n_jitters, &sc.adjacent, &mut rng, sigma);
            }
            for _ in start..end {
                let ok = draw_episode_into(
                    point.n_jitters,
                    &sc.adjacent,
                    &mut rng,
                    sigma,
                    &mut sc.draws,
                );
                assert!(ok, "healthy adjacent exists");
                acc.push(point.run_episode(&sc.draws, &mut sc.reinstate));
            }
        }
        CellKind::Scenario { spec } => {
            for i in start..end {
                let o = spec.run_trial_scratch(cell.seed.wrapping_add(i as u64), &mut sc.live);
                acc.push(o.completed_at_s);
            }
        }
        CellKind::Fleet { spec, metric } => {
            for i in start..end {
                let o = run_fleet_scratch(spec, cell.seed.wrapping_add(i as u64), &mut sc.fleet);
                acc.push(metric.measure(&o));
            }
        }
    }
    acc
}

/// Execute the whole grid as one fused task list and return one
/// [`Summary`] per cell, in cell order.
pub fn run_sweep(spec: &SweepSpec) -> Vec<Summary> {
    if spec.cells.is_empty() {
        return Vec::new();
    }
    let trials = spec.trials_per_cell.max(1);
    let chunks_per_cell = trials.div_ceil(SWEEP_CHUNK);
    let n_tasks = spec.cells.len() * chunks_per_cell;
    let total_trials = spec.cells.len().saturating_mul(trials);
    let threads = thread_policy(spec.threads, total_trials);
    let reducers: Vec<Mutex<CellReduce>> = spec
        .cells
        .iter()
        .map(|_| {
            Mutex::new(CellReduce {
                next: 0,
                acc: Accumulator::with_cap(spec.quantile_cap),
                parked: Vec::new(),
            })
        })
        .collect();
    parallel_map_trials_scratch(n_tasks, threads, SweepScratch::new, |sc, task| {
        let (cell, chunk) = (task / chunks_per_cell, task % chunks_per_cell);
        let acc = run_chunk(&spec.cells[cell], trials, chunk, spec.quantile_cap, sc);
        reducers[cell].lock().expect("sweep reducer poisoned").offer(chunk, acc);
    });
    reducers
        .into_iter()
        .map(|m| {
            let r = m.into_inner().expect("sweep reducer poisoned");
            debug_assert!(r.parked.is_empty() && r.next == chunks_per_cell);
            r.acc.summary()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{preset, ClusterPreset};
    use crate::coordinator::run::measure_reinstate;
    use crate::failure::injector::FailureProcess;
    use crate::scenario::spec::FailureRegime;
    use crate::scenario::{run_batch, BatchCfg};

    fn cfg_at(p: ClusterPreset, z: usize) -> ExperimentCfg {
        ExperimentCfg { z, ..ExperimentCfg::table1(preset(p)) }
    }

    fn small_grid() -> Vec<CellSpec> {
        let mut cells = Vec::new();
        for p in [ClusterPreset::Placentia, ClusterPreset::Acet] {
            for z in [3usize, 10, 25] {
                for strategy in [Strategy::Agent, Strategy::Core, Strategy::Hybrid] {
                    cells.push(CellSpec::reinstate(strategy, cfg_at(p, z), 99 ^ z as u64));
                }
            }
        }
        cells
    }

    #[test]
    fn fused_equals_per_point_loop() {
        let cells = small_grid();
        let trials = 12;
        let fused = run_sweep(&SweepSpec { threads: Some(4), ..SweepSpec::new(cells.clone(), trials) });
        for (cell, got) in cells.iter().zip(&fused) {
            let CellKind::Reinstate { strategy, cfg } = &cell.kind else { unreachable!() };
            let cfg = ExperimentCfg { trials, ..cfg.clone() };
            let want = measure_reinstate(*strategy, &cfg, &mut Rng::new(cell.seed));
            assert_eq!(*got, want);
        }
    }

    #[test]
    fn fused_thread_count_independent() {
        let cells = small_grid();
        let one = run_sweep(&SweepSpec { threads: Some(1), ..SweepSpec::new(cells.clone(), 9) });
        let eight = run_sweep(&SweepSpec { threads: Some(8), ..SweepSpec::new(cells, 9) });
        assert_eq!(one, eight);
    }

    #[test]
    fn scenario_cells_equal_run_batch() {
        let spec = ScenarioSpec::placentia_ring16(
            Strategy::Hybrid,
            0.9,
            8,
            FailureRegime::ConcurrentK { k: 2, offset_s: 600.0, spacing_s: 30.0 },
        );
        let cells = vec![CellSpec::scenario(spec.clone(), 41)];
        let got = run_sweep(&SweepSpec { threads: Some(3), ..SweepSpec::new(cells, 16) });
        let want = run_batch(&spec, &BatchCfg { trials: 16, base_seed: 41, threads: 1 });
        assert_eq!(got[0], want.completed_s);
    }

    #[test]
    fn fleet_cells_equal_direct_loop_and_threads() {
        use crate::scenario::fleet::{run_fleet, FleetMetric, FleetSpec};
        let spec = FleetSpec::placentia_fleet(Strategy::Hybrid, 24, 6.0, 0.5);
        let cells = vec![
            CellSpec::fleet(spec.clone(), FleetMetric::MeanSlowdown, 31),
            CellSpec::fleet(spec.clone(), FleetMetric::Goodput, 31),
        ];
        let trials = 6;
        let one = run_sweep(&SweepSpec { threads: Some(1), ..SweepSpec::new(cells.clone(), trials) });
        let four =
            run_sweep(&SweepSpec { threads: Some(4), ..SweepSpec::new(cells, trials) });
        // bitwise: summaries may legitimately carry NaN (a lifetime with no
        // completed job), which PartialEq would treat as unequal
        for (a, b) in one.iter().zip(&four) {
            assert_eq!(a.mean.to_bits(), b.mean.to_bits());
            assert_eq!(a.p95.to_bits(), b.p95.to_bits());
            assert_eq!(a.min.to_bits(), b.min.to_bits());
            assert_eq!(a.max.to_bits(), b.max.to_bits());
        }
        // cell 0 equals the direct trial loop
        let direct: Vec<f64> =
            (0..trials).map(|i| run_fleet(&spec, 31 + i as u64).mean_slowdown).collect();
        let want = crate::metrics::Summary::of(&direct);
        assert_eq!(one[0].mean.to_bits(), want.mean.to_bits());
        assert_eq!(one[0].p95.to_bits(), want.p95.to_bits());
        assert_eq!(one[0].n, want.n);
    }

    #[test]
    fn mixed_kind_grid_runs() {
        let live = ScenarioSpec::placentia_ring16(
            Strategy::Core,
            0.9,
            8,
            FailureRegime::Single(FailureProcess::RandomUniform),
        );
        let cells = vec![
            CellSpec::reinstate(Strategy::Agent, cfg_at(ClusterPreset::Placentia, 4), 7),
            CellSpec::scenario(live, 7),
        ];
        let out = run_sweep(&SweepSpec::new(cells, 5));
        assert_eq!(out.len(), 2);
        assert!(out[0].mean < 2.0, "sub-second reinstate, got {}", out[0].mean);
        assert!(out[1].mean >= 3600.0, "full job time, got {}", out[1].mean);
    }

    #[test]
    fn multi_chunk_cells_stay_exact_below_cap() {
        // trials spanning several chunks but under the cap: Exact buffers
        // concatenate in chunk order, so the summary still equals the
        // historical single-Vec path byte-for-byte
        let cells =
            vec![CellSpec::reinstate(Strategy::Core, cfg_at(ClusterPreset::Placentia, 6), 5)];
        let trials = SWEEP_CHUNK + 100;
        let fused = run_sweep(&SweepSpec { threads: Some(4), ..SweepSpec::new(cells, trials) });
        let cfg = ExperimentCfg { trials, ..cfg_at(ClusterPreset::Placentia, 6) };
        let want = measure_reinstate(Strategy::Core, &cfg, &mut Rng::new(5));
        assert_eq!(fused[0], want);
    }

    #[test]
    fn degraded_cells_deterministic_and_close() {
        let cells =
            vec![CellSpec::reinstate(Strategy::Agent, cfg_at(ClusterPreset::Placentia, 8), 3)];
        let trials = 600;
        let small_cap = SweepSpec {
            threads: Some(4),
            quantile_cap: 64,
            ..SweepSpec::new(cells.clone(), trials)
        };
        let a = run_sweep(&small_cap);
        let b = run_sweep(&SweepSpec { threads: Some(1), ..small_cap.clone() });
        assert_eq!(a, b, "degraded summaries still thread-independent");
        let exact = run_sweep(&SweepSpec { threads: Some(2), ..SweepSpec::new(cells, trials) });
        assert_eq!(a[0].n, exact[0].n);
        assert_eq!(a[0].min, exact[0].min);
        assert_eq!(a[0].max, exact[0].max);
        let rel = (a[0].mean - exact[0].mean).abs() / exact[0].mean;
        assert!(rel < 1e-9, "welford vs naive mean drift {rel}");
    }

    #[test]
    fn empty_sweep_is_empty() {
        assert!(run_sweep(&SweepSpec::new(Vec::new(), 10)).is_empty());
    }

    #[test]
    fn cell_reduce_parks_out_of_order() {
        let mut r = CellReduce { next: 0, acc: Accumulator::new(), parked: Vec::new() };
        let mk = |x: f64| {
            let mut a = Accumulator::new();
            a.push(x);
            a
        };
        r.offer(2, mk(30.0));
        r.offer(0, mk(10.0));
        assert_eq!(r.next, 1);
        assert_eq!(r.parked.len(), 1);
        r.offer(1, mk(20.0));
        assert_eq!(r.next, 3);
        assert!(r.parked.is_empty());
        let s = r.acc.summary();
        assert_eq!(s.n, 3);
        assert_eq!(s.median, 20.0);
    }
}
