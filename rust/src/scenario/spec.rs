//! [`ScenarioSpec`]: a complete, seedable description of one experiment
//! scenario — job + strategy configuration, topology, and a composable
//! multi-failure regime — generalising `failure::injector`'s "one failure
//! per window on one node" to the regimes beyond the paper.

use crate::cluster::{preset, ClusterPreset};
use crate::coordinator::ftmanager::Strategy;
use crate::coordinator::livesim::{
    run_live_faulted_scratch, CascadeSpec, LiveCfg, LiveOutcome, LiveScratch,
};
use crate::failure::injector::{FailureEvent, FailurePlan, FailureProcess};
use crate::net::{FaultPlane, NodeId, Topology};
use crate::sim::{Rng, SimTime};

/// Salt separating a trial's plan stream from its live-run stream.
const PLAN_SALT: u64 = 0x5EED_F00D_0BAD_CAFE;

/// The failure regime driving a scenario.
#[derive(Debug, Clone)]
pub enum FailureRegime {
    /// One of the paper's single-node processes, unchanged.
    Single(FailureProcess),
    /// `k` *distinct* nodes fail per window, the first at `offset_s` and
    /// each subsequent one `spacing_s` later (spacing 0 ⇒ simultaneous).
    /// Failed nodes stay dead: later windows strike only survivors, so a
    /// multi-window plan never re-dooms a node (the live system models a
    /// node failing exactly once).
    ConcurrentK { k: usize, offset_s: f64, spacing_s: f64 },
    /// Rack-correlated spreading: primary failures from `primary`; each
    /// same-rack neighbour (racks are contiguous blocks of `rack_size`
    /// nodes) is dragged down with probability `p_spread`, within `lag_s`.
    Correlated { primary: FailureProcess, rack_size: usize, p_spread: f64, lag_s: f64 },
    /// Trigger failures from `trigger`; additionally every migration's
    /// target node itself fails with probability `p_follow`, doomed `lag_s`
    /// after the migration starts (runtime-driven — these follow-on
    /// failures cannot be planned ahead because the targets are chosen
    /// during the run).
    Cascade { trigger: FailureProcess, p_follow: f64, lag_s: f64 },
}

/// A complete scenario: what runs, where it runs, and how it fails.
#[derive(Debug, Clone)]
pub struct ScenarioSpec {
    pub cfg: LiveCfg,
    pub topo: Topology,
    pub regime: FailureRegime,
    /// Number of consecutive failure windows in one trial.
    pub windows: usize,
    /// Window length in seconds.
    pub window_s: f64,
    /// Network fault plane; `FaultPlane::default()` is off and trials are
    /// byte-identical to builds that predate the plane.
    pub faults: FaultPlane,
}

impl ScenarioSpec {
    /// The paper's regime: a single-failure process over one window.
    pub fn single(cfg: LiveCfg, topo: Topology, process: FailureProcess) -> Self {
        let window_s = cfg.compute_s;
        Self {
            cfg,
            topo,
            regime: FailureRegime::Single(process),
            windows: 1,
            window_s,
            faults: FaultPlane::default(),
        }
    }

    /// The shared demo fixture (tests, benches and the multi-failure
    /// experiments all build on this one so the cost model lives in one
    /// place): Placentia costs, a ring(16, 2) landscape, a one-hour job at
    /// the Table-1 point (Z = 4, 2^19 KB) and the reactive recovery figures
    /// of the combined design (848 + 485 s). One window over the job.
    pub fn placentia_ring16(
        strategy: Strategy,
        predictable_frac: f64,
        n_subs: usize,
        regime: FailureRegime,
    ) -> Self {
        let cfg = LiveCfg {
            costs: preset(ClusterPreset::Placentia).costs,
            strategy,
            n_subs,
            z: 4,
            data_kb: 1 << 19,
            proc_kb: 1 << 19,
            compute_s: 3600.0,
            predictable_frac,
            ckpt_reinstate_s: 848.0,
            ckpt_overhead_s: 485.0,
            seed: 0,
        };
        Self {
            cfg,
            topo: Topology::ring(16, 2),
            regime,
            windows: 1,
            window_s: 3600.0,
            faults: FaultPlane::default(),
        }
    }

    /// Build the (plannable part of the) failure plan for one trial.
    /// Cascade follow-on failures are runtime-driven and not in the plan.
    pub fn plan(&self, rng: &mut Rng) -> FailurePlan {
        let n = self.topo.len();
        match &self.regime {
            FailureRegime::Single(p) => p.plan(self.windows, self.window_s, n, rng),
            FailureRegime::Cascade { trigger, .. } => {
                trigger.plan(self.windows, self.window_s, n, rng)
            }
            FailureRegime::ConcurrentK { k, offset_s, spacing_s } => {
                let mut events = Vec::new();
                // nodes die once: each window's victims come off this list
                let mut alive: Vec<usize> = (0..n).collect();
                for w in 0..self.windows {
                    let base = w as f64 * self.window_s;
                    rng.shuffle(&mut alive);
                    // failure times grow with the victim index, so stop at
                    // the first one past the window; only nodes actually
                    // struck leave the alive list (the rest stay eligible
                    // for later windows)
                    let mut struck = 0;
                    for i in 0..(*k).min(alive.len()) {
                        let at = base + offset_s + i as f64 * spacing_s;
                        if at > base + self.window_s {
                            break;
                        }
                        events.push(FailureEvent {
                            at: SimTime::from_secs(at),
                            node: NodeId(alive[i]),
                        });
                        struck += 1;
                    }
                    alive.drain(..struck);
                }
                events.sort_by_key(|e| e.at);
                FailurePlan { events }
            }
            FailureRegime::Correlated { primary, rack_size, p_spread, lag_s } => {
                let rack = (*rack_size).max(1);
                let base = primary.plan(self.windows, self.window_s, n, rng);
                let mut events = base.events.clone();
                for e in &base.events {
                    let rack_start = (e.node.0 / rack) * rack;
                    for node in rack_start..(rack_start + rack).min(n) {
                        if node != e.node.0 && rng.chance(*p_spread) {
                            events.push(FailureEvent {
                                at: e.at + SimTime::from_secs(rng.uniform(0.0, *lag_s)),
                                node: NodeId(node),
                            });
                        }
                    }
                }
                events.sort_by_key(|e| e.at);
                FailurePlan { events }
            }
        }
    }

    /// The cascade parameters, when the regime has them.
    pub fn cascade(&self) -> Option<CascadeSpec> {
        match &self.regime {
            FailureRegime::Cascade { p_follow, lag_s, .. } => {
                Some(CascadeSpec { p_follow: *p_follow, lag_s: *lag_s })
            }
            _ => None,
        }
    }

    /// Run one seeded trial: build the trial's plan from `seed`'s plan
    /// stream, then play it out live. Deterministic in `seed`.
    pub fn run_trial(&self, seed: u64) -> LiveOutcome {
        self.run_trial_scratch(seed, &mut LiveScratch::new())
    }

    /// [`ScenarioSpec::run_trial`] on recycled trial allocations —
    /// bit-identical results; `scenario::batch` workers thread one
    /// [`LiveScratch`] through their share of a batch.
    pub fn run_trial_scratch(&self, seed: u64, scratch: &mut LiveScratch) -> LiveOutcome {
        let mut cfg = self.cfg.clone();
        cfg.seed = seed;
        let mut plan_rng = Rng::new(seed ^ PLAN_SALT);
        let plan = self.plan(&mut plan_rng);
        run_live_faulted_scratch(&cfg, &self.topo, &plan, self.cascade(), &self.faults, scratch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::livesim::run_live;

    /// The shared fixture at test scale (8 sub-jobs on the 16-node ring).
    fn demo(strategy: Strategy, regime: FailureRegime) -> ScenarioSpec {
        ScenarioSpec::placentia_ring16(strategy, 0.9, 8, regime)
    }

    #[test]
    fn single_spec_reproduces_run_live() {
        let base = demo(Strategy::Core, FailureRegime::Single(FailureProcess::RandomUniform));
        let spec =
            ScenarioSpec::single(base.cfg, Topology::ring(16, 2), FailureProcess::RandomUniform);
        for seed in [1u64, 7, 99] {
            let via_spec = spec.run_trial(seed);
            let mut cfg = spec.cfg.clone();
            cfg.seed = seed;
            let plan = spec.plan(&mut Rng::new(seed ^ PLAN_SALT));
            let direct = run_live(&cfg, &spec.topo, &plan);
            assert_eq!(via_spec.completed_at_s, direct.completed_at_s);
            assert_eq!(via_spec.events, direct.events);
            assert_eq!(via_spec.migrations, direct.migrations);
            assert_eq!(via_spec.rollbacks, direct.rollbacks);
        }
    }

    #[test]
    fn concurrent_k_hits_k_distinct_nodes() {
        let spec = demo(
            Strategy::Hybrid,
            FailureRegime::ConcurrentK { k: 5, offset_s: 900.0, spacing_s: 0.0 },
        );
        let mut rng = Rng::new(3);
        let plan = spec.plan(&mut rng);
        assert_eq!(plan.len(), 5);
        let mut nodes: Vec<usize> = plan.events.iter().map(|e| e.node.0).collect();
        nodes.sort_unstable();
        nodes.dedup();
        assert_eq!(nodes.len(), 5, "victims must be distinct");
        assert!(plan.events.iter().all(|e| e.at == SimTime::from_secs(900.0)));
    }

    #[test]
    fn concurrent_k_capped_and_nodes_die_once() {
        let mut spec = demo(
            Strategy::Core,
            FailureRegime::ConcurrentK { k: 10, offset_s: 100.0, spacing_s: 1.0 },
        );
        spec.topo = Topology::ring(4, 1);
        spec.windows = 2;
        spec.window_s = 1000.0;
        let plan = spec.plan(&mut Rng::new(4));
        // window 1 kills the whole 4-node cluster; window 2 has no
        // survivors left to strike — a node never fails twice
        assert_eq!(plan.len(), 4);
        let mut nodes: Vec<usize> = plan.events.iter().map(|e| e.node.0).collect();
        nodes.sort_unstable();
        assert_eq!(nodes, vec![0, 1, 2, 3]);
    }

    #[test]
    fn concurrent_k_multi_window_strikes_survivors() {
        let mut spec = demo(
            Strategy::Core,
            FailureRegime::ConcurrentK { k: 3, offset_s: 100.0, spacing_s: 1.0 },
        );
        spec.windows = 3;
        spec.window_s = 1000.0;
        let plan = spec.plan(&mut Rng::new(7));
        assert_eq!(plan.len(), 9);
        let mut nodes: Vec<usize> = plan.events.iter().map(|e| e.node.0).collect();
        nodes.sort_unstable();
        nodes.dedup();
        assert_eq!(nodes.len(), 9, "victims distinct across windows: {plan:?}");
    }

    #[test]
    fn correlated_spreads_within_rack_only() {
        let spec = demo(
            Strategy::Core,
            FailureRegime::Correlated {
                primary: FailureProcess::Periodic { offset_s: 600.0 },
                rack_size: 4,
                p_spread: 1.0,
                lag_s: 10.0,
            },
        );
        let plan = spec.plan(&mut Rng::new(5));
        // one primary + its 3 rack-mates
        assert_eq!(plan.len(), 4);
        let rack: Vec<usize> = plan.events.iter().map(|e| e.node.0 / 4).collect();
        assert!(rack.windows(2).all(|w| w[0] == w[1]), "all in one rack: {plan:?}");
        // sorted by time, spread within the lag
        let t0 = plan.events[0].at;
        assert!(plan.events.iter().all(|e| e.at >= t0));
        assert!(plan
            .events
            .iter()
            .all(|e| e.at <= t0 + SimTime::from_secs(10.0)));
    }

    #[test]
    fn correlated_zero_spread_is_primary_only() {
        let spec = demo(
            Strategy::Core,
            FailureRegime::Correlated {
                primary: FailureProcess::Periodic { offset_s: 600.0 },
                rack_size: 4,
                p_spread: 0.0,
                lag_s: 10.0,
            },
        );
        assert_eq!(spec.plan(&mut Rng::new(6)).len(), 1);
    }

    #[test]
    fn cascade_spec_carries_params_and_runs() {
        // one sub-job per node and a fully predictable trigger, so the
        // failure always strikes a hosted sub-job, the proactive migration
        // always runs, and the (p_follow = 1) cascade must fire
        let spec = ScenarioSpec::placentia_ring16(
            Strategy::Hybrid,
            1.0,
            16,
            FailureRegime::Cascade {
                trigger: FailureProcess::Periodic { offset_s: 900.0 },
                p_follow: 1.0,
                lag_s: 5.0,
            },
        );
        let c = spec.cascade().expect("cascade params");
        assert_eq!(c.p_follow, 1.0);
        let o = spec.run_trial(11);
        assert!(o.cascades >= 1, "{o:?}");
        assert!(o.completed_at_s >= 3600.0);
    }

    #[test]
    fn trials_deterministic_and_seed_sensitive() {
        let spec = demo(
            Strategy::Agent,
            FailureRegime::ConcurrentK { k: 3, offset_s: 600.0, spacing_s: 30.0 },
        );
        let a = spec.run_trial(42);
        let b = spec.run_trial(42);
        assert_eq!(a.completed_at_s, b.completed_at_s);
        assert_eq!(a.events, b.events);
        // different seeds draw different plans (victim sets and/or jitters)
        let pa = spec.plan(&mut Rng::new(42 ^ PLAN_SALT));
        let pb = spec.plan(&mut Rng::new(43 ^ PLAN_SALT));
        assert_eq!(pa.events, spec.plan(&mut Rng::new(42 ^ PLAN_SALT)).events);
        assert_eq!(pa.len(), 3);
        assert_eq!(pb.len(), 3);
    }
}
