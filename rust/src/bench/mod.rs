//! Benchmark harness (criterion substitute — the vendored crate set has no
//! criterion). Used by `rust/benches/*.rs` with `harness = false`.
//!
//! Reports min/mean/median/p95 over N timed samples after warmup, plus
//! derived throughput when a unit count is given. Samples use
//! `std::time::Instant` and a `black_box` to defeat dead-code elimination.

use std::hint::black_box;
use std::time::Instant;

/// One benchmark's measurements (seconds per iteration).
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub samples: Vec<f64>,
    pub units_per_iter: Option<f64>,
}

impl BenchResult {
    pub fn summary(&self) -> crate::metrics::Summary {
        crate::metrics::Summary::of(&self.samples)
    }

    /// Render one line, criterion-style.
    pub fn render(&self) -> String {
        let s = self.summary();
        let mut line = format!(
            "{:<44} {:>12}/iter  (median {:>12}, p95 {:>12}, n={})",
            self.name,
            fmt_t(s.mean),
            fmt_t(s.median),
            fmt_t(s.p95),
            s.n
        );
        if let Some(u) = self.units_per_iter {
            line.push_str(&format!("  [{:.2e} units/s]", u / s.mean));
        }
        line
    }
}

fn fmt_t(secs: f64) -> String {
    if secs < 1e-6 {
        format!("{:.1} ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.2} µs", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.2} ms", secs * 1e3)
    } else {
        format!("{:.3} s", secs)
    }
}

/// A suite of benchmarks sharing a header.
pub struct Suite {
    title: String,
    results: Vec<BenchResult>,
    /// Target samples per benchmark (overridable with BIOMAFT_BENCH_SAMPLES).
    samples: usize,
}

impl Suite {
    pub fn new(title: &str) -> Self {
        let samples = std::env::var("BIOMAFT_BENCH_SAMPLES")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(20);
        println!("=== bench suite: {title} ===");
        Self { title: title.to_string(), results: Vec::new(), samples }
    }

    /// Time `f`, which must return something observable (passed through
    /// black_box).
    pub fn bench<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> &BenchResult {
        self.bench_units(name, None, &mut f)
    }

    /// Time `f` and report throughput in `units` per iteration.
    pub fn bench_throughput<T>(
        &mut self,
        name: &str,
        units: f64,
        mut f: impl FnMut() -> T,
    ) -> &BenchResult {
        self.bench_units(name, Some(units), &mut f)
    }

    fn bench_units<T>(
        &mut self,
        name: &str,
        units: Option<f64>,
        f: &mut dyn FnMut() -> T,
    ) -> &BenchResult {
        // warmup
        for _ in 0..3.min(self.samples) {
            black_box(f());
        }
        let mut samples = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t0 = Instant::now();
            black_box(f());
            samples.push(t0.elapsed().as_secs_f64());
        }
        let r = BenchResult { name: name.to_string(), samples, units_per_iter: units };
        println!("{}", r.render());
        self.results.push(r);
        self.results.last().unwrap()
    }

    /// Final summary block.
    pub fn finish(self) {
        println!("=== {}: {} benchmarks ===\n", self.title, self.results.len());
    }
}

/// Pull a numeric field out of a baseline JSON blob without a JSON dep:
/// finds `"key":` and parses the number that follows. Shared by the
/// baseline-emitting benches (`scenarios`, `genome`).
pub fn json_number(src: &str, key: &str) -> Option<f64> {
    let needle = format!("\"{key}\":");
    let at = src.find(&needle)? + needle.len();
    let rest = src[at..].trim_start();
    let end = rest
        .find(|c: char| !(c.is_ascii_digit() || c == '.' || c == '-' || c == '+' || c == 'e'))
        .unwrap_or(rest.len());
    rest[..end].parse().ok()
}

/// Report a bench's `current` figure (the number under `key`, labelled
/// `label`) against the previously committed baseline file at `path` —
/// and shout if that file is still a `"generated": false` placeholder
/// rather than honest measurements.
pub fn compare_to_baseline(path: &str, key: &str, label: &str, current: f64) {
    let Ok(prev) = std::fs::read_to_string(path) else {
        println!("no previous baseline at {path} — first run on this machine");
        return;
    };
    let generated = prev.contains("\"generated\": true") || prev.contains("\"generated\":true");
    if !generated {
        println!();
        println!("!!! =============================================================== !!!");
        println!("!!! WARNING: {path} is a PLACEHOLDER baseline (\"generated\": false). !!!");
        println!("!!! There are no honest pre-change numbers to compare against.      !!!");
        println!("!!! Committing this run's JSON establishes the first real baseline. !!!");
        println!("!!! =============================================================== !!!");
        println!();
        return;
    }
    match json_number(&prev, key) {
        Some(prev_rate) if prev_rate > 0.0 => {
            println!(
                "baseline: {prev_rate:>12.4e} {label} -> {current:>12.4e} ({:.2}x)",
                current / prev_rate
            );
        }
        _ => println!("previous baseline at {path} has no parsable {key}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_collects_samples() {
        std::env::remove_var("BIOMAFT_BENCH_SAMPLES");
        let mut s = Suite::new("t");
        let r = s.bench("noop-ish", || (0..100).sum::<u64>());
        assert_eq!(r.samples.len(), 20);
        assert!(r.summary().mean >= 0.0);
    }

    #[test]
    fn throughput_line_mentions_units() {
        let mut s = Suite::new("t2");
        let r = s.bench_throughput("tp", 1000.0, || (0..1000).sum::<u64>());
        assert!(r.render().contains("units/s"));
    }

    #[test]
    fn time_formatting() {
        assert!(fmt_t(2e-9).contains("ns"));
        assert!(fmt_t(2e-6).contains("µs"));
        assert!(fmt_t(2e-3).contains("ms"));
        assert!(fmt_t(2.0).contains(" s"));
    }

    #[test]
    fn json_number_parses_fields() {
        let src = "{\n  \"a\": 12.5,\n  \"b\":3e4,\n  \"neg\": -2\n}";
        assert_eq!(json_number(src, "a"), Some(12.5));
        assert_eq!(json_number(src, "b"), Some(30_000.0));
        assert_eq!(json_number(src, "neg"), Some(-2.0));
        assert_eq!(json_number(src, "missing"), None);
    }
}
