//! The discrete-event engine: a virtual clock, an ordered event queue, and
//! closure dispatch.
//!
//! Determinism contract: two runs with the same dispatch function, same
//! initial events and same RNG seeds produce *identical* event traces. Ties
//! in delivery time are broken by a monotone sequence number, so insertion
//! order is part of the contract (tested in `testkit` property tests).
//!
//! ## Hot-path design (see DESIGN.md §Hot path, §Event queue)
//!
//! The engine owns no actors: [`Engine::run_until`] takes a *dispatch
//! closure* and hands it each due event. Callers (notably
//! [`crate::sim::harness`]) keep their actor state in a plain `Vec` and
//! index it with the delivered [`ActorId`] — no `Box<dyn>` virtual call, no
//! `Rc<RefCell<…>>` borrow, no allocation on the per-event path. The queue
//! key is packed as `(time, seq)` into one `u128`, so every ordering
//! compare is a single integer compare.
//!
//! The queue itself is a hierarchical timer wheel ([`EventQueue`]) rather
//! than a global `BinaryHeap`: pushes on the fleet simulator's hot path are
//! O(1) slot appends instead of O(log n) sift-ups, while the pop sequence
//! is exactly the heap's total order — same `(time, seq)` key, same
//! tie-break, property-tested event-for-event against a reference heap in
//! `tests/properties.rs`. Far-future events (churn repair timers, doom
//! events scheduled hours out) park in an overflow heap until the wheel
//! rotates into their range.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// Virtual time in integer nanoseconds (u64 ⇒ ~584 years of range).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub struct SimTime(pub u64);

impl SimTime {
    pub const ZERO: SimTime = SimTime(0);

    pub fn from_secs(s: f64) -> Self {
        debug_assert!(s >= 0.0, "negative sim time {s}");
        SimTime((s * 1e9).round() as u64)
    }

    pub fn from_millis(ms: f64) -> Self {
        Self::from_secs(ms * 1e-3)
    }

    pub fn from_micros(us: f64) -> Self {
        Self::from_secs(us * 1e-6)
    }

    pub fn as_secs(self) -> f64 {
        self.0 as f64 * 1e-9
    }

    pub fn saturating_sub(self, other: SimTime) -> SimTime {
        SimTime(self.0.saturating_sub(other.0))
    }
}

impl std::ops::Add for SimTime {
    type Output = SimTime;
    fn add(self, rhs: SimTime) -> SimTime {
        SimTime(self.0 + rhs.0)
    }
}

impl std::ops::AddAssign for SimTime {
    fn add_assign(&mut self, rhs: SimTime) {
        self.0 += rhs.0;
    }
}

impl std::fmt::Display for SimTime {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{:.6}s", self.as_secs())
    }
}

/// Identifies an actor; an index into whatever state store the dispatch
/// closure consults (the harness uses a plain `Vec` of scenario states).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct ActorId(pub usize);

/// Pack `(time, seq)` into one `u128` — `time` in the high 64 bits, `seq`
/// in the low — so ordering is a single integer compare instead of a
/// lexicographic tuple compare. Public so the queue property tests can
/// build keys exactly the way the engine does.
#[inline]
pub fn pack_key(at: SimTime, seq: u64) -> u128 {
    ((at.0 as u128) << 64) | seq as u128
}

/// A queue entry: the packed `(time, seq)` key plus the caller's payload.
struct Entry<T> {
    key: u128,
    item: T,
}

// Order by the packed (time, seq) key — the internal heaps are max-heaps,
// so the queue wraps entries in Reverse.
impl<T> PartialEq for Entry<T> {
    fn eq(&self, other: &Self) -> bool {
        self.key == other.key
    }
}
impl<T> Eq for Entry<T> {}
impl<T> PartialOrd for Entry<T> {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl<T> Ord for Entry<T> {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.key.cmp(&other.key)
    }
}

/// Bits per wheel level: 2^6 = 64 slots, so a level's occupancy is one u64
/// bitmap and "earliest non-empty slot" is a `trailing_zeros`.
const WHEEL_BITS: usize = 6;
const WHEEL_SLOTS: usize = 1 << WHEEL_BITS;
const SLOT_MASK: u64 = WHEEL_SLOTS as u64 - 1;
/// Hierarchy depth: 4 levels × 6 bits = 24 granule bits in-wheel.
const WHEEL_LEVELS: usize = 4;
/// Granule size: 2^20 ns ≈ 1.05 ms. Level spans are then ≈ 67 ms, 4.3 s,
/// 4.6 min and 4.9 h; anything further out goes to the overflow heap.
const GRANULE_BITS: u32 = 20;

#[inline]
fn granule_of(key: u128) -> u64 {
    ((key >> 64) as u64) >> GRANULE_BITS
}

/// A hierarchical timer wheel ordered by a packed `(time, seq)` `u128` key
/// — the engine's event queue (DESIGN.md §Event queue).
///
/// Time is bucketed into *granules* of 2^[`GRANULE_BITS`] ns. The wheel
/// keeps [`WHEEL_LEVELS`] levels of [`WHEEL_SLOTS`] slots; an entry's slot
/// at level `l` is digit `l` of its granule in base 64 (absolute indexing,
/// Linux-kernel style, so cascades only touch entries whose time has
/// arrived). Entries whose granule differs from the cursor above the top
/// level wait in an `overflow` heap until the wheel rotates into range.
///
/// Invariants (the pop-order argument, tested against a reference
/// `BinaryHeap` in `tests/properties.rs`):
///
/// * every entry in `due` has granule **equal to** `cursor`; every entry
///   in the levels or overflow has granule **greater than** `cursor`;
/// * within a granule, `due` is a min-heap on the full key, so equal-time
///   ties pop in `seq` (insertion) order;
/// * levels are filled lowest-first: if level `l` is non-empty, its
///   earliest slot holds the globally earliest pending granule, because
///   any level-`l+1` entry differs from the cursor in a strictly higher
///   base-64 digit and is therefore later.
///
/// Together these give: `pop` always returns the globally minimum key —
/// exactly the `BinaryHeap<Reverse<_>>` sequence it replaced.
pub struct EventQueue<T> {
    /// Current-granule entries, ordered by full key.
    due: BinaryHeap<Reverse<Entry<T>>>,
    /// `levels[l][slot]`: unordered entries due in a future granule whose
    /// base-64 digit `l` is `slot` (and whose higher digits match the
    /// cursor's). Sorted on drain via `due`.
    levels: Vec<Vec<Vec<Entry<T>>>>,
    /// Per-level bitmap of non-empty slots.
    occupied: [u64; WHEEL_LEVELS],
    /// Entries beyond the top level's span (> ~4.9 h of virtual time out).
    overflow: BinaryHeap<Reverse<Entry<T>>>,
    /// The granule currently being drained. Monotone within a run; every
    /// queued entry's granule is ≥ the cursor.
    cursor: u64,
    len: usize,
}

impl<T> Default for EventQueue<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> EventQueue<T> {
    pub fn new() -> Self {
        Self {
            due: BinaryHeap::new(),
            levels: (0..WHEEL_LEVELS)
                .map(|_| (0..WHEEL_SLOTS).map(|_| Vec::new()).collect())
                .collect(),
            occupied: [0; WHEEL_LEVELS],
            overflow: BinaryHeap::new(),
            cursor: 0,
            len: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Empty the queue and rewind the cursor, keeping every slot/heap
    /// allocation — the `recycle()` half of trial-scratch reuse.
    pub fn clear(&mut self) {
        self.due.clear();
        self.overflow.clear();
        for level in &mut self.levels {
            for slot in level {
                slot.clear();
            }
        }
        self.occupied = [0; WHEEL_LEVELS];
        self.cursor = 0;
        self.len = 0;
    }

    /// Insert an entry. Keys must not lie in the already-drained past
    /// (granule < cursor): the engine's clamp-to-now contract guarantees
    /// this, and the queue clamps such an entry into the current granule
    /// as a defensive backstop.
    pub fn push(&mut self, key: u128, item: T) {
        self.len += 1;
        self.place(Entry { key, item });
    }

    /// The minimum pending key, without removing it. `&mut` because the
    /// wheel may rotate to expose it (rotation never reorders anything).
    pub fn peek_key(&mut self) -> Option<u128> {
        self.advance();
        self.due.peek().map(|Reverse(e)| e.key)
    }

    /// Remove and return the minimum-key entry.
    pub fn pop(&mut self) -> Option<(u128, T)> {
        self.advance();
        let Reverse(e) = self.due.pop()?;
        self.len -= 1;
        Some((e.key, e.item))
    }

    /// Route one entry to `due`, a wheel slot, or overflow (no len change).
    fn place(&mut self, e: Entry<T>) {
        let granule = granule_of(e.key);
        debug_assert!(granule >= self.cursor, "event scheduled into the drained past");
        if granule <= self.cursor {
            self.due.push(Reverse(e));
            return;
        }
        let diff = granule ^ self.cursor;
        let level = (63 - diff.leading_zeros()) as usize / WHEEL_BITS;
        if level >= WHEEL_LEVELS {
            self.overflow.push(Reverse(e));
            return;
        }
        let slot = ((granule >> (level * WHEEL_BITS)) & SLOT_MASK) as usize;
        self.levels[level][slot].push(e);
        self.occupied[level] |= 1 << slot;
    }

    /// Rotate until `due` holds the earliest pending granule (or the queue
    /// is empty): drain the earliest slot of the lowest non-empty level,
    /// re-basing the cursor so drained entries cascade into lower levels
    /// and, ultimately, `due`. When the whole wheel is empty, jump the
    /// cursor to the earliest overflow entry and pull in everything that
    /// now fits under the top level's span.
    fn advance(&mut self) {
        while self.due.is_empty() && self.len > 0 {
            if let Some(level) = (0..WHEEL_LEVELS).find(|&l| self.occupied[l] != 0) {
                let slot = self.occupied[level].trailing_zeros() as usize;
                self.occupied[level] &= !(1u64 << slot);
                let shift = level * WHEEL_BITS;
                let kept = (self.cursor >> (shift + WHEEL_BITS)) << (shift + WHEEL_BITS);
                self.cursor = kept | ((slot as u64) << shift);
                let mut batch = std::mem::take(&mut self.levels[level][slot]);
                for e in batch.drain(..) {
                    self.place(e);
                }
                // drained entries re-place strictly below this level, so
                // the slot's allocation is free to hand back
                self.levels[level][slot] = batch;
            } else {
                let Reverse(first) = self.overflow.pop().expect("len > 0 with empty wheel");
                self.cursor = granule_of(first.key);
                self.place(first);
                while let Some(Reverse(e)) = self.overflow.peek() {
                    if (granule_of(e.key) ^ self.cursor) >> (WHEEL_BITS * WHEEL_LEVELS) != 0 {
                        // overflow pops in ascending key order, so the
                        // first out-of-span entry ends the in-span run
                        break;
                    }
                    let Reverse(e) = self.overflow.pop().expect("peeked entry");
                    self.place(e);
                }
            }
        }
    }
}

/// A bank of per-cell [`EventQueue`] wheels presenting a single global
/// min-key pop order — the queue layer of the sharded fleet (DESIGN.md
/// §Sharded cells).
///
/// Each cell owns its own timer wheel; the bank caches every cell's exact
/// minimum pending key, so the global minimum is an O(cells) scan over a
/// dense array of `u128`s rather than a touch of every wheel. Because the
/// packed `(time, seq)` keys of one simulation are globally unique (the
/// caller hands out `seq` from shared counters), popping the cached global
/// minimum yields *exactly* the sequence a single [`EventQueue`] holding
/// every entry would produce — for any cell count and any entry→cell
/// routing. That identity is what makes sharding invisible to the
/// determinism contract, and it is property-tested against a single wheel
/// in the engine tests and end-to-end in `tests/fleet_sharding.rs`.
pub struct ShardedQueue<T> {
    cells: Vec<EventQueue<T>>,
    /// Exact minimum pending key per cell (`None` ⇔ that cell is empty).
    /// Maintained on push (min with the new key) and pop (re-peek).
    mins: Vec<Option<u128>>,
    len: usize,
}

impl<T> ShardedQueue<T> {
    pub fn new(cells: usize) -> Self {
        assert!(cells > 0, "a sharded queue needs at least one cell");
        Self {
            cells: (0..cells).map(|_| EventQueue::new()).collect(),
            mins: vec![None; cells],
            len: 0,
        }
    }

    /// Empty every wheel and re-size the bank to `cells`, keeping existing
    /// wheel allocations — the scratch-reuse half (wheels are recycled
    /// across trials; growing the bank allocates only the new cells).
    pub fn reset(&mut self, cells: usize) {
        assert!(cells > 0, "a sharded queue needs at least one cell");
        for q in &mut self.cells {
            q.clear();
        }
        if self.cells.len() > cells {
            self.cells.truncate(cells);
        } else {
            self.cells.resize_with(cells, EventQueue::new);
        }
        self.mins.clear();
        self.mins.resize(cells, None);
        self.len = 0;
    }

    pub fn cells(&self) -> usize {
        self.cells.len()
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn push(&mut self, cell: usize, key: u128, item: T) {
        self.cells[cell].push(key, item);
        self.mins[cell] = Some(match self.mins[cell] {
            Some(m) => m.min(key),
            None => key,
        });
        self.len += 1;
    }

    /// The globally minimum pending key across all cells.
    pub fn min_key(&self) -> Option<u128> {
        self.mins.iter().flatten().copied().min()
    }

    /// Remove and return the globally minimum entry as `(cell, key, item)`.
    /// Keys are unique, so the argmin cell is unambiguous.
    pub fn pop_min(&mut self) -> Option<(usize, u128, T)> {
        let (cell, _) = self
            .mins
            .iter()
            .enumerate()
            .filter_map(|(c, m)| m.map(|k| (c, k)))
            .min_by_key(|&(_, k)| k)?;
        let (key, item) = self.cells[cell].pop().expect("cached min for empty cell");
        self.mins[cell] = self.cells[cell].peek_key();
        self.len -= 1;
        Some((cell, key, item))
    }
}

/// Collects the messages an actor emits while handling a delivery.
///
/// The staging buffer is owned by the engine and reused across dispatches
/// (perf: avoids one Vec allocation per event — see EXPERIMENTS.md §Perf).
pub struct Outbox<'e, M> {
    now: SimTime,
    staged: &'e mut Vec<(SimTime, ActorId, M)>,
    /// Set to request a simulation stop after this dispatch completes.
    pub stop: bool,
}

impl<M> Outbox<'_, M> {
    /// Deliver `msg` to `target` after `delay` of virtual time.
    pub fn send_in(&mut self, delay: SimTime, target: ActorId, msg: M) {
        self.staged.push((self.now + delay, target, msg));
    }

    /// Deliver at an absolute virtual time.
    ///
    /// Scheduling into the past is clamped to `now` — in **every** build
    /// profile. (An earlier revision `debug_assert!`ed here while release
    /// builds clamped silently, so a protocol bug could make debug and
    /// release traces diverge; the clamp is now the documented contract and
    /// is tested in `send_at_past_clamps_to_now`.)
    pub fn send_at(&mut self, at: SimTime, target: ActorId, msg: M) {
        self.staged.push((at.max(self.now), target, msg));
    }

    /// Current virtual time of the dispatch.
    pub fn now(&self) -> SimTime {
        self.now
    }
}

/// A compact trace of dispatches for determinism checks: (time, target, tag).
pub type EventLog = Vec<(SimTime, usize, u64)>;

/// The engine. Generic over the message type `M`; protocols define their
/// own message enums and dispatch to their own state in the run closure.
pub struct Engine<M> {
    queue: EventQueue<(ActorId, M)>,
    now: SimTime,
    seq: u64,
    dispatched: u64,
    /// Optional tagger for event-log capture (used by determinism tests).
    tagger: Option<fn(&M) -> u64>,
    log: EventLog,
    /// Reused staging buffer for actor outboxes (perf).
    staging: Vec<(SimTime, ActorId, M)>,
}

impl<M> Default for Engine<M> {
    fn default() -> Self {
        Self::new()
    }
}

impl<M> Engine<M> {
    pub fn new() -> Self {
        Self {
            queue: EventQueue::new(),
            now: SimTime::ZERO,
            seq: 0,
            dispatched: 0,
            tagger: None,
            log: Vec::new(),
            staging: Vec::new(),
        }
    }

    /// Reset the engine to its initial state while keeping the queue and
    /// staging allocations — the engine half of
    /// [`TrialScratch`](crate::sim::harness::TrialScratch) reuse: a
    /// recycled engine runs a fresh trial without allocating. (The log
    /// buffer is only retained if the previous run didn't [`take_log`]
    /// it; log-capturing runs hand their buffer to the caller.)
    ///
    /// [`take_log`]: Engine::take_log
    pub fn recycle(&mut self) {
        self.queue.clear();
        self.now = SimTime::ZERO;
        self.seq = 0;
        self.dispatched = 0;
        self.tagger = None;
        self.log.clear();
        self.staging.clear();
    }

    /// Enable event-log capture; `tagger` maps a message to a stable tag.
    pub fn capture_log(&mut self, tagger: fn(&M) -> u64) {
        self.tagger = Some(tagger);
    }

    pub fn log(&self) -> &EventLog {
        &self.log
    }

    /// Take the captured event log out of the engine (no copy), leaving an
    /// empty log behind. The cheap way to extract the trace when the run is
    /// over and the engine is headed for recycling or drop.
    pub fn take_log(&mut self) -> EventLog {
        std::mem::take(&mut self.log)
    }

    /// Schedule an initial event.
    pub fn schedule(&mut self, at: SimTime, target: ActorId, msg: M) {
        self.queue.push(pack_key(at, self.seq), (target, msg));
        self.seq += 1;
    }

    pub fn now(&self) -> SimTime {
        self.now
    }

    pub fn dispatched(&self) -> u64 {
        self.dispatched
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Run until the queue drains, the dispatch closure requests a stop, or
    /// virtual time would exceed `horizon` (events past the horizon stay
    /// undelivered). `dispatch` is handed each due event in (time, seq)
    /// order; it routes the message to the caller's own actor state.
    /// Returns the final virtual time.
    pub fn run_until<F>(&mut self, horizon: SimTime, mut dispatch: F) -> SimTime
    where
        F: FnMut(ActorId, M, &mut Outbox<'_, M>),
    {
        while let Some(key) = self.queue.peek_key() {
            let at = SimTime((key >> 64) as u64);
            if at > horizon {
                // Past the horizon: clamp the clock and stop (the event
                // stays queued).
                self.now = horizon;
                break;
            }
            let (_, (target, msg)) = self.queue.pop().expect("peeked event");
            debug_assert!(at >= self.now, "time went backwards");
            self.now = at;
            self.dispatched += 1;
            if let Some(tag) = self.tagger {
                self.log.push((at, target.0, tag(&msg)));
            }
            let mut out = Outbox { now: at, staged: &mut self.staging, stop: false };
            dispatch(target, msg, &mut out);
            let stop = out.stop;
            for (t, target, msg) in self.staging.drain(..) {
                self.queue.push(pack_key(t, self.seq), (target, msg));
                self.seq += 1;
            }
            if stop {
                break;
            }
        }
        self.now
    }

    /// Run to quiescence (no horizon).
    pub fn run<F>(&mut self, dispatch: F) -> SimTime
    where
        F: FnMut(ActorId, M, &mut Outbox<'_, M>),
    {
        self.run_until(SimTime(u64::MAX), dispatch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[derive(Debug, Clone)]
    enum Msg {
        Ping(u32),
        Pong(u32),
    }

    #[test]
    fn simtime_conversions_roundtrip() {
        assert_eq!(SimTime::from_secs(1.5).0, 1_500_000_000);
        assert_eq!(SimTime::from_millis(2.0).0, 2_000_000);
        assert_eq!(SimTime::from_micros(3.0).0, 3_000);
        assert!((SimTime::from_secs(0.47).as_secs() - 0.47).abs() < 1e-12);
    }

    #[test]
    fn packed_key_orders_time_then_seq() {
        assert!(pack_key(SimTime(1), u64::MAX) < pack_key(SimTime(2), 0));
        assert!(pack_key(SimTime(5), 3) < pack_key(SimTime(5), 4));
    }

    #[test]
    fn queue_pops_in_key_order_across_levels_and_overflow() {
        // one entry per regime: same granule, level 0..3, and far enough
        // out to overflow (> ~4.9 h)
        let times_s =
            [0.0, 0.000_5, 0.01, 1.0, 60.0, 3600.0, 5.0 * 3600.0, 100.0 * 3600.0];
        let mut q: EventQueue<usize> = EventQueue::new();
        // push in reverse so order is the queue's doing, not insertion's
        for (i, &s) in times_s.iter().enumerate().rev() {
            q.push(pack_key(SimTime::from_secs(s), i as u64), i);
        }
        assert_eq!(q.len(), times_s.len());
        let mut got = Vec::new();
        let mut last = 0u128;
        while let Some((key, item)) = q.pop() {
            assert!(key >= last, "keys must pop in ascending order");
            last = key;
            got.push(item);
        }
        assert_eq!(got, (0..times_s.len()).collect::<Vec<_>>());
        assert!(q.is_empty());
    }

    #[test]
    fn queue_breaks_equal_time_ties_by_seq() {
        let mut q: EventQueue<u64> = EventQueue::new();
        let t = SimTime::from_secs(2.0);
        for seq in [5u64, 1, 3, 0, 4, 2] {
            q.push(pack_key(t, seq), seq);
        }
        let mut got = Vec::new();
        while let Some((_, s)) = q.pop() {
            got.push(s);
        }
        assert_eq!(got, vec![0, 1, 2, 3, 4, 5]);
    }

    #[test]
    fn queue_interleaves_pushes_with_rotation() {
        // pushes after the cursor has rotated must land correctly, both
        // into the granule being drained and into future slots
        let mut q: EventQueue<u32> = EventQueue::new();
        q.push(pack_key(SimTime::from_secs(10.0), 0), 0);
        q.push(pack_key(SimTime::from_secs(30.0), 1), 1);
        assert_eq!(q.pop().map(|(_, i)| i), Some(0));
        // the cursor now sits at t=10's granule
        q.push(pack_key(SimTime::from_secs(20.0), 2), 2);
        q.push(pack_key(SimTime(10_000_000_100), 3), 3); // same granule as the cursor
        assert_eq!(q.pop().map(|(_, i)| i), Some(3));
        assert_eq!(q.pop().map(|(_, i)| i), Some(2));
        assert_eq!(q.pop().map(|(_, i)| i), Some(1));
        assert!(q.pop().is_none());
    }

    #[test]
    fn queue_peek_matches_pop_and_clear_resets() {
        let mut q: EventQueue<u8> = EventQueue::new();
        q.push(pack_key(SimTime::from_secs(7.0), 0), 7);
        q.push(pack_key(SimTime::from_secs(3.0), 1), 3);
        assert_eq!(q.peek_key(), Some(pack_key(SimTime::from_secs(3.0), 1)));
        assert_eq!(q.pop().map(|(_, i)| i), Some(3));
        q.clear();
        assert!(q.is_empty());
        assert_eq!(q.peek_key(), None);
        // reusable after clear, including the far-future path
        q.push(pack_key(SimTime::from_secs(50.0 * 3600.0), 0), 1);
        q.push(pack_key(SimTime::from_secs(1.0), 1), 2);
        assert_eq!(q.pop().map(|(_, i)| i), Some(2));
        assert_eq!(q.pop().map(|(_, i)| i), Some(1));
    }

    #[test]
    fn sharded_queue_matches_single_wheel_for_any_cell_count() {
        // The load-bearing identity: with globally unique keys, a sharded
        // bank pops the exact sequence of one wheel holding every entry —
        // regardless of cell count or of how entries are routed to cells.
        let mut items: Vec<(u128, usize)> = Vec::new();
        let mut state = 0x9E37_79B9u64;
        for seq in 0..500u64 {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            // times collide often (mod 16 granule seconds) to stress seq ties
            let t = SimTime::from_secs((state >> 56) as f64);
            items.push((pack_key(t, seq), seq as usize));
        }
        let mut reference: EventQueue<usize> = EventQueue::new();
        for &(k, v) in &items {
            reference.push(k, v);
        }
        let mut expect = Vec::new();
        while let Some((k, v)) = reference.pop() {
            expect.push((k, v));
        }
        for cells in [1usize, 2, 7, 64] {
            let mut sq: ShardedQueue<usize> = ShardedQueue::new(cells);
            for &(k, v) in &items {
                sq.push(v % cells, k, v);
            }
            assert_eq!(sq.len(), items.len());
            let mut got = Vec::new();
            while let Some(min) = sq.min_key() {
                let (_, k, v) = sq.pop_min().expect("non-empty");
                assert_eq!(k, min);
                got.push((k, v));
            }
            assert_eq!(got, expect, "cells={cells}");
            assert!(sq.is_empty());
        }
    }

    #[test]
    fn sharded_queue_reset_recycles_and_resizes() {
        let mut sq: ShardedQueue<u32> = ShardedQueue::new(4);
        for i in 0..16u64 {
            sq.push((i % 4) as usize, pack_key(SimTime::from_secs(i as f64), i), i as u32);
        }
        sq.reset(2);
        assert_eq!(sq.cells(), 2);
        assert!(sq.is_empty());
        assert_eq!(sq.min_key(), None);
        // interleave pushes with pops so cached mins re-peek correctly
        sq.push(1, pack_key(SimTime::from_secs(5.0), 0), 50);
        sq.push(0, pack_key(SimTime::from_secs(1.0), 1), 10);
        assert_eq!(sq.pop_min().map(|(c, _, v)| (c, v)), Some((0, 10)));
        sq.push(0, pack_key(SimTime::from_secs(9.0), 2), 90);
        assert_eq!(sq.pop_min().map(|(c, _, v)| (c, v)), Some((1, 50)));
        assert_eq!(sq.pop_min().map(|(c, _, v)| (c, v)), Some((0, 90)));
        assert_eq!(sq.pop_min().map(|(_, _, v)| v), None);
    }

    #[test]
    fn events_dispatch_in_time_order() {
        let mut seen: Vec<u32> = Vec::new();
        let mut eng: Engine<Msg> = Engine::new();
        let a = ActorId(0);
        eng.schedule(SimTime::from_secs(3.0), a, Msg::Ping(3));
        eng.schedule(SimTime::from_secs(1.0), a, Msg::Ping(1));
        eng.schedule(SimTime::from_secs(2.0), a, Msg::Ping(2));
        eng.run(|_me, msg, _out| {
            if let Msg::Ping(i) = msg {
                seen.push(i);
            }
        });
        assert_eq!(seen, vec![1, 2, 3]);
    }

    #[test]
    fn ties_break_by_insertion_order() {
        let mut seen: Vec<u32> = Vec::new();
        let mut eng: Engine<Msg> = Engine::new();
        for i in 0..10 {
            eng.schedule(SimTime::from_secs(1.0), ActorId(0), Msg::Ping(i));
        }
        eng.run(|_me, msg, _out| {
            if let Msg::Ping(i) = msg {
                seen.push(i);
            }
        });
        assert_eq!(seen, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn ping_pong_terminates_and_advances_clock() {
        // Actor 0 pings actor 1; actor 1 pongs back until a count runs out.
        let mut remaining = [5u32, 5u32];
        let mut eng: Engine<Msg> = Engine::new();
        eng.schedule(SimTime::ZERO, ActorId(0), Msg::Ping(0));
        let end = eng.run(|me, msg, out| {
            let peer = ActorId(1 - me.0);
            match msg {
                Msg::Ping(i) if remaining[me.0] > 0 => {
                    remaining[me.0] -= 1;
                    out.send_in(SimTime::from_millis(10.0), peer, Msg::Pong(i));
                }
                Msg::Pong(i) if remaining[me.0] > 0 => {
                    remaining[me.0] -= 1;
                    out.send_in(SimTime::from_millis(10.0), peer, Msg::Ping(i + 1));
                }
                _ => {}
            }
        });
        // 10 hops of 10ms each (5+5 remaining), minus the initial dispatch at t=0.
        assert_eq!(end, SimTime::from_millis(100.0));
        assert_eq!(eng.dispatched(), 11); // initial + 10 relayed
    }

    #[test]
    fn horizon_stops_early() {
        let mut eng: Engine<Msg> = Engine::new();
        eng.schedule(SimTime::ZERO, ActorId(0), Msg::Ping(0));
        let end = eng.run_until(SimTime::from_secs(10.5), |_me, _msg, out| {
            // re-arm forever
            let t = out.now();
            out.send_at(t + SimTime::from_secs(1.0), ActorId(0), Msg::Ping(0));
        });
        assert_eq!(end, SimTime::from_secs(10.5));
        assert_eq!(eng.dispatched(), 11); // t=0..10 inclusive
        assert_eq!(eng.pending(), 1); // the t=11 event remains queued
    }

    #[test]
    fn stop_flag_halts_dispatch() {
        let mut eng: Engine<Msg> = Engine::new();
        eng.schedule(SimTime::ZERO, ActorId(0), Msg::Ping(0));
        eng.schedule(SimTime::from_secs(100.0), ActorId(0), Msg::Ping(99));
        eng.run(|_me, msg, out| {
            if let Msg::Ping(i) = msg {
                if i >= 3 {
                    out.stop = true;
                } else {
                    out.send_in(SimTime::from_secs(1.0), ActorId(0), Msg::Ping(i + 1));
                }
            }
        });
        assert_eq!(eng.now(), SimTime::from_secs(3.0));
        assert_eq!(eng.pending(), 1);
    }

    #[test]
    fn send_at_past_clamps_to_now() {
        // The documented contract: an absolute send into the past delivers
        // at the current dispatch time (identically in debug and release).
        let mut seen: Vec<(u64, u32)> = Vec::new();
        let mut eng: Engine<Msg> = Engine::new();
        eng.schedule(SimTime::from_secs(1.0), ActorId(0), Msg::Ping(0));
        eng.run(|_me, msg, out| {
            if let Msg::Ping(i) = msg {
                seen.push((out.now().0, i));
                if i == 0 {
                    // deliberately schedule one second into the past
                    out.send_at(SimTime::ZERO, ActorId(0), Msg::Ping(1));
                }
            }
        });
        assert_eq!(seen.len(), 2);
        // the clamped event is delivered at the time of the dispatch that
        // staged it, not at the requested (past) time
        assert_eq!(seen[1], (SimTime::from_secs(1.0).0, 1));
    }

    #[test]
    fn log_captures_trace_and_take_log_empties_it() {
        let mut eng: Engine<Msg> = Engine::new();
        eng.capture_log(|m| match m {
            Msg::Ping(i) => *i as u64,
            Msg::Pong(i) => 1000 + *i as u64,
        });
        eng.schedule(SimTime::from_secs(1.0), ActorId(0), Msg::Ping(7));
        eng.schedule(SimTime::from_secs(2.0), ActorId(0), Msg::Pong(8));
        eng.run(|_me, _msg, _out| {});
        assert_eq!(eng.log().len(), 2);
        assert_eq!(eng.log()[0].2, 7);
        assert_eq!(eng.log()[1].2, 1008);
        let log = eng.take_log();
        assert_eq!(log.len(), 2);
        assert!(eng.log().is_empty());
    }

    #[test]
    fn recycled_engine_replays_identically() {
        let run = |eng: &mut Engine<Msg>| {
            eng.capture_log(|m| match m {
                Msg::Ping(i) => *i as u64,
                Msg::Pong(i) => 1000 + *i as u64,
            });
            eng.schedule(SimTime::ZERO, ActorId(0), Msg::Ping(0));
            eng.run(|_me, msg, out| {
                if let Msg::Ping(i) = msg {
                    if i < 20 {
                        out.send_in(SimTime::from_millis(1.0), ActorId(0), Msg::Ping(i + 1));
                    }
                }
            });
            (eng.take_log(), eng.dispatched(), eng.now())
        };
        let mut eng: Engine<Msg> = Engine::new();
        let first = run(&mut eng);
        eng.recycle();
        assert_eq!(eng.pending(), 0);
        let second = run(&mut eng);
        assert_eq!(first, second);
    }

    #[test]
    fn recycled_engine_replays_across_overflow_horizons() {
        // churn repair timers live hours out: the recycle contract must
        // hold through the overflow path too
        let run = |eng: &mut Engine<Msg>| {
            eng.capture_log(|m| match m {
                Msg::Ping(i) => *i as u64,
                Msg::Pong(i) => 1000 + *i as u64,
            });
            for i in 0..8 {
                eng.schedule(
                    SimTime::from_secs(i as f64 * 3.0 * 3600.0),
                    ActorId(0),
                    Msg::Ping(i),
                );
            }
            eng.run(|_me, _msg, _out| {});
            (eng.take_log(), eng.dispatched(), eng.now())
        };
        let mut eng: Engine<Msg> = Engine::new();
        let first = run(&mut eng);
        eng.recycle();
        let second = run(&mut eng);
        assert_eq!(first, second);
        assert_eq!(first.0.len(), 8);
    }
}
