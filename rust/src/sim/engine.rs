//! The discrete-event engine: a virtual clock, an ordered event queue, and
//! closure dispatch.
//!
//! Determinism contract: two runs with the same dispatch function, same
//! initial events and same RNG seeds produce *identical* event traces. Ties
//! in delivery time are broken by a monotone sequence number, so insertion
//! order is part of the contract (tested in `testkit` property tests).
//!
//! ## Hot-path design (see DESIGN.md §Hot path)
//!
//! The engine owns no actors: [`Engine::run_until`] takes a *dispatch
//! closure* and hands it each due event. Callers (notably
//! [`crate::sim::harness`]) keep their actor state in a plain `Vec` and
//! index it with the delivered [`ActorId`] — no `Box<dyn>` virtual call, no
//! `Rc<RefCell<…>>` borrow, no allocation on the per-event path. The heap
//! key is packed as `(time, seq)` into one `u128`, so the `BinaryHeap`
//! sift compares are single integer compares.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// Virtual time in integer nanoseconds (u64 ⇒ ~584 years of range).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub struct SimTime(pub u64);

impl SimTime {
    pub const ZERO: SimTime = SimTime(0);

    pub fn from_secs(s: f64) -> Self {
        debug_assert!(s >= 0.0, "negative sim time {s}");
        SimTime((s * 1e9).round() as u64)
    }

    pub fn from_millis(ms: f64) -> Self {
        Self::from_secs(ms * 1e-3)
    }

    pub fn from_micros(us: f64) -> Self {
        Self::from_secs(us * 1e-6)
    }

    pub fn as_secs(self) -> f64 {
        self.0 as f64 * 1e-9
    }

    pub fn saturating_sub(self, other: SimTime) -> SimTime {
        SimTime(self.0.saturating_sub(other.0))
    }
}

impl std::ops::Add for SimTime {
    type Output = SimTime;
    fn add(self, rhs: SimTime) -> SimTime {
        SimTime(self.0 + rhs.0)
    }
}

impl std::ops::AddAssign for SimTime {
    fn add_assign(&mut self, rhs: SimTime) {
        self.0 += rhs.0;
    }
}

impl std::fmt::Display for SimTime {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{:.6}s", self.as_secs())
    }
}

/// Identifies an actor; an index into whatever state store the dispatch
/// closure consults (the harness uses a plain `Vec` of scenario states).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct ActorId(pub usize);

/// A scheduled delivery. The heap key packs `(time, seq)` into one `u128`
/// — `time` in the high 64 bits, `seq` in the low — so ordering is a
/// single integer compare instead of a lexicographic tuple compare.
struct Event<M> {
    key: u128,
    target: ActorId,
    msg: M,
}

#[inline]
fn pack_key(at: SimTime, seq: u64) -> u128 {
    ((at.0 as u128) << 64) | seq as u128
}

impl<M> Event<M> {
    #[inline]
    fn at(&self) -> SimTime {
        SimTime((self.key >> 64) as u64)
    }
}

// Order by the packed (time, seq) key — BinaryHeap is a max-heap so the
// engine wraps events in Reverse.
impl<M> PartialEq for Event<M> {
    fn eq(&self, other: &Self) -> bool {
        self.key == other.key
    }
}
impl<M> Eq for Event<M> {}
impl<M> PartialOrd for Event<M> {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl<M> Ord for Event<M> {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.key.cmp(&other.key)
    }
}

/// Collects the messages an actor emits while handling a delivery.
///
/// The staging buffer is owned by the engine and reused across dispatches
/// (perf: avoids one Vec allocation per event — see EXPERIMENTS.md §Perf).
pub struct Outbox<'e, M> {
    now: SimTime,
    staged: &'e mut Vec<(SimTime, ActorId, M)>,
    /// Set to request a simulation stop after this dispatch completes.
    pub stop: bool,
}

impl<M> Outbox<'_, M> {
    /// Deliver `msg` to `target` after `delay` of virtual time.
    pub fn send_in(&mut self, delay: SimTime, target: ActorId, msg: M) {
        self.staged.push((self.now + delay, target, msg));
    }

    /// Deliver at an absolute virtual time.
    ///
    /// Scheduling into the past is clamped to `now` — in **every** build
    /// profile. (An earlier revision `debug_assert!`ed here while release
    /// builds clamped silently, so a protocol bug could make debug and
    /// release traces diverge; the clamp is now the documented contract and
    /// is tested in `send_at_past_clamps_to_now`.)
    pub fn send_at(&mut self, at: SimTime, target: ActorId, msg: M) {
        self.staged.push((at.max(self.now), target, msg));
    }

    /// Current virtual time of the dispatch.
    pub fn now(&self) -> SimTime {
        self.now
    }
}

/// A compact trace of dispatches for determinism checks: (time, target, tag).
pub type EventLog = Vec<(SimTime, usize, u64)>;

/// The engine. Generic over the message type `M`; protocols define their
/// own message enums and dispatch to their own state in the run closure.
pub struct Engine<M> {
    queue: BinaryHeap<Reverse<Event<M>>>,
    now: SimTime,
    seq: u64,
    dispatched: u64,
    /// Optional tagger for event-log capture (used by determinism tests).
    tagger: Option<fn(&M) -> u64>,
    log: EventLog,
    /// Reused staging buffer for actor outboxes (perf).
    staging: Vec<(SimTime, ActorId, M)>,
}

impl<M> Default for Engine<M> {
    fn default() -> Self {
        Self::new()
    }
}

impl<M> Engine<M> {
    pub fn new() -> Self {
        Self {
            queue: BinaryHeap::new(),
            now: SimTime::ZERO,
            seq: 0,
            dispatched: 0,
            tagger: None,
            log: Vec::new(),
            staging: Vec::new(),
        }
    }

    /// Reset the engine to its initial state while keeping the queue and
    /// staging allocations — the engine half of
    /// [`TrialScratch`](crate::sim::harness::TrialScratch) reuse: a
    /// recycled engine runs a fresh trial without allocating. (The log
    /// buffer is only retained if the previous run didn't [`take_log`]
    /// it; log-capturing runs hand their buffer to the caller.)
    ///
    /// [`take_log`]: Engine::take_log
    pub fn recycle(&mut self) {
        self.queue.clear();
        self.now = SimTime::ZERO;
        self.seq = 0;
        self.dispatched = 0;
        self.tagger = None;
        self.log.clear();
        self.staging.clear();
    }

    /// Enable event-log capture; `tagger` maps a message to a stable tag.
    pub fn capture_log(&mut self, tagger: fn(&M) -> u64) {
        self.tagger = Some(tagger);
    }

    pub fn log(&self) -> &EventLog {
        &self.log
    }

    /// Take the captured event log out of the engine (no copy), leaving an
    /// empty log behind. The cheap way to extract the trace when the run is
    /// over and the engine is headed for recycling or drop.
    pub fn take_log(&mut self) -> EventLog {
        std::mem::take(&mut self.log)
    }

    /// Schedule an initial event.
    pub fn schedule(&mut self, at: SimTime, target: ActorId, msg: M) {
        let ev = Event { key: pack_key(at, self.seq), target, msg };
        self.seq += 1;
        self.queue.push(Reverse(ev));
    }

    pub fn now(&self) -> SimTime {
        self.now
    }

    pub fn dispatched(&self) -> u64 {
        self.dispatched
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Run until the queue drains, the dispatch closure requests a stop, or
    /// virtual time would exceed `horizon` (events past the horizon stay
    /// undelivered). `dispatch` is handed each due event in (time, seq)
    /// order; it routes the message to the caller's own actor state.
    /// Returns the final virtual time.
    pub fn run_until<F>(&mut self, horizon: SimTime, mut dispatch: F) -> SimTime
    where
        F: FnMut(ActorId, M, &mut Outbox<'_, M>),
    {
        while let Some(Reverse(ev)) = self.queue.pop() {
            let at = ev.at();
            if at > horizon {
                // Past the horizon: clamp the clock and stop.
                self.now = horizon;
                self.queue.push(Reverse(ev));
                break;
            }
            debug_assert!(at >= self.now, "time went backwards");
            self.now = at;
            self.dispatched += 1;
            if let Some(tag) = self.tagger {
                self.log.push((at, ev.target.0, tag(&ev.msg)));
            }
            let mut out = Outbox { now: at, staged: &mut self.staging, stop: false };
            dispatch(ev.target, ev.msg, &mut out);
            let stop = out.stop;
            for (t, target, msg) in self.staging.drain(..) {
                let e = Event { key: pack_key(t, self.seq), target, msg };
                self.seq += 1;
                self.queue.push(Reverse(e));
            }
            if stop {
                break;
            }
        }
        self.now
    }

    /// Run to quiescence (no horizon).
    pub fn run<F>(&mut self, dispatch: F) -> SimTime
    where
        F: FnMut(ActorId, M, &mut Outbox<'_, M>),
    {
        self.run_until(SimTime(u64::MAX), dispatch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[derive(Debug, Clone)]
    enum Msg {
        Ping(u32),
        Pong(u32),
    }

    #[test]
    fn simtime_conversions_roundtrip() {
        assert_eq!(SimTime::from_secs(1.5).0, 1_500_000_000);
        assert_eq!(SimTime::from_millis(2.0).0, 2_000_000);
        assert_eq!(SimTime::from_micros(3.0).0, 3_000);
        assert!((SimTime::from_secs(0.47).as_secs() - 0.47).abs() < 1e-12);
    }

    #[test]
    fn packed_key_orders_time_then_seq() {
        assert!(pack_key(SimTime(1), u64::MAX) < pack_key(SimTime(2), 0));
        assert!(pack_key(SimTime(5), 3) < pack_key(SimTime(5), 4));
        assert_eq!(
            Event::<u32> { key: pack_key(SimTime(7), 9), target: ActorId(0), msg: 0 }.at(),
            SimTime(7)
        );
    }

    #[test]
    fn events_dispatch_in_time_order() {
        let mut seen: Vec<u32> = Vec::new();
        let mut eng: Engine<Msg> = Engine::new();
        let a = ActorId(0);
        eng.schedule(SimTime::from_secs(3.0), a, Msg::Ping(3));
        eng.schedule(SimTime::from_secs(1.0), a, Msg::Ping(1));
        eng.schedule(SimTime::from_secs(2.0), a, Msg::Ping(2));
        eng.run(|_me, msg, _out| {
            if let Msg::Ping(i) = msg {
                seen.push(i);
            }
        });
        assert_eq!(seen, vec![1, 2, 3]);
    }

    #[test]
    fn ties_break_by_insertion_order() {
        let mut seen: Vec<u32> = Vec::new();
        let mut eng: Engine<Msg> = Engine::new();
        for i in 0..10 {
            eng.schedule(SimTime::from_secs(1.0), ActorId(0), Msg::Ping(i));
        }
        eng.run(|_me, msg, _out| {
            if let Msg::Ping(i) = msg {
                seen.push(i);
            }
        });
        assert_eq!(seen, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn ping_pong_terminates_and_advances_clock() {
        // Actor 0 pings actor 1; actor 1 pongs back until a count runs out.
        let mut remaining = [5u32, 5u32];
        let mut eng: Engine<Msg> = Engine::new();
        eng.schedule(SimTime::ZERO, ActorId(0), Msg::Ping(0));
        let end = eng.run(|me, msg, out| {
            let peer = ActorId(1 - me.0);
            match msg {
                Msg::Ping(i) if remaining[me.0] > 0 => {
                    remaining[me.0] -= 1;
                    out.send_in(SimTime::from_millis(10.0), peer, Msg::Pong(i));
                }
                Msg::Pong(i) if remaining[me.0] > 0 => {
                    remaining[me.0] -= 1;
                    out.send_in(SimTime::from_millis(10.0), peer, Msg::Ping(i + 1));
                }
                _ => {}
            }
        });
        // 10 hops of 10ms each (5+5 remaining), minus the initial dispatch at t=0.
        assert_eq!(end, SimTime::from_millis(100.0));
        assert_eq!(eng.dispatched(), 11); // initial + 10 relayed
    }

    #[test]
    fn horizon_stops_early() {
        let mut eng: Engine<Msg> = Engine::new();
        eng.schedule(SimTime::ZERO, ActorId(0), Msg::Ping(0));
        let end = eng.run_until(SimTime::from_secs(10.5), |_me, _msg, out| {
            // re-arm forever
            let t = out.now();
            out.send_at(t + SimTime::from_secs(1.0), ActorId(0), Msg::Ping(0));
        });
        assert_eq!(end, SimTime::from_secs(10.5));
        assert_eq!(eng.dispatched(), 11); // t=0..10 inclusive
        assert_eq!(eng.pending(), 1); // the t=11 event remains queued
    }

    #[test]
    fn stop_flag_halts_dispatch() {
        let mut eng: Engine<Msg> = Engine::new();
        eng.schedule(SimTime::ZERO, ActorId(0), Msg::Ping(0));
        eng.schedule(SimTime::from_secs(100.0), ActorId(0), Msg::Ping(99));
        eng.run(|_me, msg, out| {
            if let Msg::Ping(i) = msg {
                if i >= 3 {
                    out.stop = true;
                } else {
                    out.send_in(SimTime::from_secs(1.0), ActorId(0), Msg::Ping(i + 1));
                }
            }
        });
        assert_eq!(eng.now(), SimTime::from_secs(3.0));
        assert_eq!(eng.pending(), 1);
    }

    #[test]
    fn send_at_past_clamps_to_now() {
        // The documented contract: an absolute send into the past delivers
        // at the current dispatch time (identically in debug and release).
        let mut seen: Vec<(u64, u32)> = Vec::new();
        let mut eng: Engine<Msg> = Engine::new();
        eng.schedule(SimTime::from_secs(1.0), ActorId(0), Msg::Ping(0));
        eng.run(|_me, msg, out| {
            if let Msg::Ping(i) = msg {
                seen.push((out.now().0, i));
                if i == 0 {
                    // deliberately schedule one second into the past
                    out.send_at(SimTime::ZERO, ActorId(0), Msg::Ping(1));
                }
            }
        });
        assert_eq!(seen.len(), 2);
        // the clamped event is delivered at the time of the dispatch that
        // staged it, not at the requested (past) time
        assert_eq!(seen[1], (SimTime::from_secs(1.0).0, 1));
    }

    #[test]
    fn log_captures_trace_and_take_log_empties_it() {
        let mut eng: Engine<Msg> = Engine::new();
        eng.capture_log(|m| match m {
            Msg::Ping(i) => *i as u64,
            Msg::Pong(i) => 1000 + *i as u64,
        });
        eng.schedule(SimTime::from_secs(1.0), ActorId(0), Msg::Ping(7));
        eng.schedule(SimTime::from_secs(2.0), ActorId(0), Msg::Pong(8));
        eng.run(|_me, _msg, _out| {});
        assert_eq!(eng.log().len(), 2);
        assert_eq!(eng.log()[0].2, 7);
        assert_eq!(eng.log()[1].2, 1008);
        let log = eng.take_log();
        assert_eq!(log.len(), 2);
        assert!(eng.log().is_empty());
    }

    #[test]
    fn recycled_engine_replays_identically() {
        let run = |eng: &mut Engine<Msg>| {
            eng.capture_log(|m| match m {
                Msg::Ping(i) => *i as u64,
                Msg::Pong(i) => 1000 + *i as u64,
            });
            eng.schedule(SimTime::ZERO, ActorId(0), Msg::Ping(0));
            eng.run(|_me, msg, out| {
                if let Msg::Ping(i) = msg {
                    if i < 20 {
                        out.send_in(SimTime::from_millis(1.0), ActorId(0), Msg::Ping(i + 1));
                    }
                }
            });
            (eng.take_log(), eng.dispatched(), eng.now())
        };
        let mut eng: Engine<Msg> = Engine::new();
        let first = run(&mut eng);
        eng.recycle();
        assert_eq!(eng.pending(), 0);
        let second = run(&mut eng);
        assert_eq!(first, second);
    }
}
