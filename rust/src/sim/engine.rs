//! The discrete-event engine: a virtual clock, an ordered event queue, and
//! actor dispatch.
//!
//! Determinism contract: two runs with the same actor set, same initial
//! events and same RNG seeds produce *identical* event traces. Ties in
//! delivery time are broken by a monotone sequence number, so insertion
//! order is part of the contract (tested in `testkit` property tests).

use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// Virtual time in integer nanoseconds (u64 ⇒ ~584 years of range).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub struct SimTime(pub u64);

impl SimTime {
    pub const ZERO: SimTime = SimTime(0);

    pub fn from_secs(s: f64) -> Self {
        debug_assert!(s >= 0.0, "negative sim time {s}");
        SimTime((s * 1e9).round() as u64)
    }

    pub fn from_millis(ms: f64) -> Self {
        Self::from_secs(ms * 1e-3)
    }

    pub fn from_micros(us: f64) -> Self {
        Self::from_secs(us * 1e-6)
    }

    pub fn as_secs(self) -> f64 {
        self.0 as f64 * 1e-9
    }

    pub fn saturating_sub(self, other: SimTime) -> SimTime {
        SimTime(self.0.saturating_sub(other.0))
    }
}

impl std::ops::Add for SimTime {
    type Output = SimTime;
    fn add(self, rhs: SimTime) -> SimTime {
        SimTime(self.0 + rhs.0)
    }
}

impl std::ops::AddAssign for SimTime {
    fn add_assign(&mut self, rhs: SimTime) {
        self.0 += rhs.0;
    }
}

impl std::fmt::Display for SimTime {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{:.6}s", self.as_secs())
    }
}

/// Identifies an actor registered with the engine.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct ActorId(pub usize);

/// A scheduled delivery.
#[derive(Debug, Clone)]
struct Event<M> {
    at: SimTime,
    seq: u64,
    target: ActorId,
    msg: M,
}

// Order by (time, seq) — BinaryHeap is a max-heap so we wrap in Reverse.
impl<M> PartialEq for Event<M> {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}
impl<M> Eq for Event<M> {}
impl<M> PartialOrd for Event<M> {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl<M> Ord for Event<M> {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (self.at, self.seq).cmp(&(other.at, other.seq))
    }
}

/// Collects the messages an actor emits while handling a delivery.
///
/// The staging buffer is owned by the engine and reused across dispatches
/// (perf: avoids one Vec allocation per event — see EXPERIMENTS.md §Perf).
pub struct Outbox<'e, M> {
    now: SimTime,
    staged: &'e mut Vec<(SimTime, ActorId, M)>,
    /// Set to request a simulation stop after this dispatch completes.
    pub stop: bool,
}

impl<M> Outbox<'_, M> {
    /// Deliver `msg` to `target` after `delay` of virtual time.
    pub fn send_in(&mut self, delay: SimTime, target: ActorId, msg: M) {
        self.staged.push((self.now + delay, target, msg));
    }

    /// Deliver at an absolute virtual time.
    ///
    /// Scheduling into the past is clamped to `now` — in **every** build
    /// profile. (An earlier revision `debug_assert!`ed here while release
    /// builds clamped silently, so a protocol bug could make debug and
    /// release traces diverge; the clamp is now the documented contract and
    /// is tested in `send_at_past_clamps_to_now`.)
    pub fn send_at(&mut self, at: SimTime, target: ActorId, msg: M) {
        self.staged.push((at.max(self.now), target, msg));
    }

    /// Current virtual time of the dispatch.
    pub fn now(&self) -> SimTime {
        self.now
    }
}

/// Actor behaviour: react to a delivered message, optionally emitting more.
pub trait Actor<M> {
    fn on_msg(&mut self, me: ActorId, msg: M, out: &mut Outbox<'_, M>);
}

/// Blanket impl so plain closures can be used as actors in tests.
impl<M, F: FnMut(ActorId, M, &mut Outbox<'_, M>)> Actor<M> for F {
    fn on_msg(&mut self, me: ActorId, msg: M, out: &mut Outbox<'_, M>) {
        self(me, msg, out)
    }
}

/// A compact trace of dispatches for determinism checks: (time, target, tag).
pub type EventLog = Vec<(SimTime, usize, u64)>;

/// The engine. Generic over the message type `M`; protocols define their own
/// message enums and register actors.
pub struct Engine<M> {
    queue: BinaryHeap<Reverse<Event<M>>>,
    actors: Vec<Box<dyn Actor<M>>>,
    now: SimTime,
    seq: u64,
    dispatched: u64,
    /// Optional tagger for event-log capture (used by determinism tests).
    tagger: Option<fn(&M) -> u64>,
    log: EventLog,
    /// Reused staging buffer for actor outboxes (perf).
    staging: Vec<(SimTime, ActorId, M)>,
}

impl<M> Default for Engine<M> {
    fn default() -> Self {
        Self::new()
    }
}

impl<M> Engine<M> {
    pub fn new() -> Self {
        Self {
            queue: BinaryHeap::new(),
            actors: Vec::new(),
            now: SimTime::ZERO,
            seq: 0,
            dispatched: 0,
            tagger: None,
            log: Vec::new(),
            staging: Vec::new(),
        }
    }

    /// Register an actor; returns its id.
    pub fn add_actor(&mut self, actor: Box<dyn Actor<M>>) -> ActorId {
        self.actors.push(actor);
        ActorId(self.actors.len() - 1)
    }

    /// Enable event-log capture; `tagger` maps a message to a stable tag.
    pub fn capture_log(&mut self, tagger: fn(&M) -> u64) {
        self.tagger = Some(tagger);
    }

    pub fn log(&self) -> &EventLog {
        &self.log
    }

    /// Schedule an initial event.
    pub fn schedule(&mut self, at: SimTime, target: ActorId, msg: M) {
        let ev = Event { at, seq: self.seq, target, msg };
        self.seq += 1;
        self.queue.push(Reverse(ev));
    }

    pub fn now(&self) -> SimTime {
        self.now
    }

    pub fn dispatched(&self) -> u64 {
        self.dispatched
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Run until the queue drains, an actor requests a stop, or virtual time
    /// would exceed `horizon` (events past the horizon stay undelivered).
    /// Returns the final virtual time.
    pub fn run_until(&mut self, horizon: SimTime) -> SimTime {
        while let Some(Reverse(ev)) = self.queue.pop() {
            if ev.at > horizon {
                // Past the horizon: clamp the clock and stop.
                self.now = horizon;
                self.queue.push(Reverse(ev));
                break;
            }
            debug_assert!(ev.at >= self.now, "time went backwards");
            self.now = ev.at;
            self.dispatched += 1;
            if let Some(tag) = self.tagger {
                self.log.push((ev.at, ev.target.0, tag(&ev.msg)));
            }
            let mut staging = std::mem::take(&mut self.staging);
            let mut out = Outbox { now: self.now, staged: &mut staging, stop: false };
            self.actors[ev.target.0].on_msg(ev.target, ev.msg, &mut out);
            let stop = out.stop;
            for (at, target, msg) in staging.drain(..) {
                let e = Event { at, seq: self.seq, target, msg };
                self.seq += 1;
                self.queue.push(Reverse(e));
            }
            self.staging = staging;
            if stop {
                break;
            }
        }
        self.now
    }

    /// Run to quiescence (no horizon).
    pub fn run(&mut self) -> SimTime {
        self.run_until(SimTime(u64::MAX))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::cell::RefCell;
    use std::rc::Rc;

    #[derive(Debug, Clone)]
    enum Msg {
        Ping(u32),
        Pong(u32),
    }

    #[test]
    fn simtime_conversions_roundtrip() {
        assert_eq!(SimTime::from_secs(1.5).0, 1_500_000_000);
        assert_eq!(SimTime::from_millis(2.0).0, 2_000_000);
        assert_eq!(SimTime::from_micros(3.0).0, 3_000);
        assert!((SimTime::from_secs(0.47).as_secs() - 0.47).abs() < 1e-12);
    }

    #[test]
    fn events_dispatch_in_time_order() {
        let seen: Rc<RefCell<Vec<u32>>> = Rc::default();
        let s = seen.clone();
        let mut eng: Engine<Msg> = Engine::new();
        let a = eng.add_actor(Box::new(move |_me, msg: Msg, _out: &mut Outbox<'_, Msg>| {
            if let Msg::Ping(i) = msg {
                s.borrow_mut().push(i);
            }
        }));
        eng.schedule(SimTime::from_secs(3.0), a, Msg::Ping(3));
        eng.schedule(SimTime::from_secs(1.0), a, Msg::Ping(1));
        eng.schedule(SimTime::from_secs(2.0), a, Msg::Ping(2));
        eng.run();
        assert_eq!(*seen.borrow(), vec![1, 2, 3]);
    }

    #[test]
    fn ties_break_by_insertion_order() {
        let seen: Rc<RefCell<Vec<u32>>> = Rc::default();
        let s = seen.clone();
        let mut eng: Engine<Msg> = Engine::new();
        let a = eng.add_actor(Box::new(move |_me, msg: Msg, _out: &mut Outbox<'_, Msg>| {
            if let Msg::Ping(i) = msg {
                s.borrow_mut().push(i);
            }
        }));
        for i in 0..10 {
            eng.schedule(SimTime::from_secs(1.0), a, Msg::Ping(i));
        }
        eng.run();
        assert_eq!(*seen.borrow(), (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn ping_pong_terminates_and_advances_clock() {
        // Actor 0 pings actor 1; actor 1 pongs back until a count runs out.
        struct PingPong {
            peer: usize,
            remaining: u32,
        }
        impl Actor<Msg> for PingPong {
            fn on_msg(&mut self, _me: ActorId, msg: Msg, out: &mut Outbox<'_, Msg>) {
                match msg {
                    Msg::Ping(i) if self.remaining > 0 => {
                        self.remaining -= 1;
                        out.send_in(SimTime::from_millis(10.0), ActorId(self.peer), Msg::Pong(i));
                    }
                    Msg::Pong(i) if self.remaining > 0 => {
                        self.remaining -= 1;
                        out.send_in(SimTime::from_millis(10.0), ActorId(self.peer), Msg::Ping(i + 1));
                    }
                    _ => {}
                }
            }
        }
        let mut eng: Engine<Msg> = Engine::new();
        let a = eng.add_actor(Box::new(PingPong { peer: 1, remaining: 5 }));
        let _b = eng.add_actor(Box::new(PingPong { peer: 0, remaining: 5 }));
        eng.schedule(SimTime::ZERO, a, Msg::Ping(0));
        let end = eng.run();
        // 10 hops of 10ms each (5+5 remaining), minus the initial dispatch at t=0.
        assert_eq!(end, SimTime::from_millis(100.0));
        assert_eq!(eng.dispatched(), 11); // initial + 10 relayed
    }

    #[test]
    fn horizon_stops_early() {
        let mut eng: Engine<Msg> = Engine::new();
        let a = eng.add_actor(Box::new(|_me, _msg: Msg, out: &mut Outbox<'_, Msg>| {
            // re-arm forever
            let t = out.now();
            out.send_at(t + SimTime::from_secs(1.0), ActorId(0), Msg::Ping(0));
        }));
        eng.schedule(SimTime::ZERO, a, Msg::Ping(0));
        let end = eng.run_until(SimTime::from_secs(10.5));
        assert_eq!(end, SimTime::from_secs(10.5));
        assert_eq!(eng.dispatched(), 11); // t=0..10 inclusive
        assert_eq!(eng.pending(), 1); // the t=11 event remains queued
    }

    #[test]
    fn stop_flag_halts_dispatch() {
        let mut eng: Engine<Msg> = Engine::new();
        let a = eng.add_actor(Box::new(|_me, msg: Msg, out: &mut Outbox<'_, Msg>| {
            if let Msg::Ping(i) = msg {
                if i >= 3 {
                    out.stop = true;
                } else {
                    out.send_in(SimTime::from_secs(1.0), ActorId(0), Msg::Ping(i + 1));
                }
            }
        }));
        eng.schedule(SimTime::ZERO, a, Msg::Ping(0));
        eng.schedule(SimTime::from_secs(100.0), a, Msg::Ping(99));
        eng.run();
        assert_eq!(eng.now(), SimTime::from_secs(3.0));
        assert_eq!(eng.pending(), 1);
    }

    #[test]
    fn send_at_past_clamps_to_now() {
        // The documented contract: an absolute send into the past delivers
        // at the current dispatch time (identically in debug and release).
        let seen: Rc<RefCell<Vec<(u64, u32)>>> = Rc::default();
        let s = seen.clone();
        let mut eng: Engine<Msg> = Engine::new();
        let a = eng.add_actor(Box::new(move |_me, msg: Msg, out: &mut Outbox<'_, Msg>| {
            if let Msg::Ping(i) = msg {
                s.borrow_mut().push((out.now().0, i));
                if i == 0 {
                    // deliberately schedule one second into the past
                    out.send_at(SimTime::ZERO, ActorId(0), Msg::Ping(1));
                }
            }
        }));
        eng.schedule(SimTime::from_secs(1.0), a, Msg::Ping(0));
        eng.run();
        let got = seen.borrow().clone();
        assert_eq!(got.len(), 2);
        // the clamped event is delivered at the time of the dispatch that
        // staged it, not at the requested (past) time
        assert_eq!(got[1], (SimTime::from_secs(1.0).0, 1));
    }

    #[test]
    fn log_captures_trace() {
        let mut eng: Engine<Msg> = Engine::new();
        let a = eng.add_actor(Box::new(|_me, _msg: Msg, _out: &mut Outbox<'_, Msg>| {}));
        eng.capture_log(|m| match m {
            Msg::Ping(i) => *i as u64,
            Msg::Pong(i) => 1000 + *i as u64,
        });
        eng.schedule(SimTime::from_secs(1.0), a, Msg::Ping(7));
        eng.schedule(SimTime::from_secs(2.0), a, Msg::Pong(8));
        eng.run();
        assert_eq!(eng.log().len(), 2);
        assert_eq!(eng.log()[0].2, 7);
        assert_eq!(eng.log()[1].2, 1008);
    }
}
