//! Deterministic pseudo-random number generator + distributions.
//!
//! xoshiro256** seeded via SplitMix64 — no external crates, identical streams
//! on every platform. All stochastic behaviour in the simulator (failure
//! times, trial noise, genome synthesis) flows through this type so a seed
//! fully determines an experiment.

/// xoshiro256** PRNG.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed the generator; any u64 (including 0) is a valid seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Self {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    /// Derive an independent child stream (for per-trial / per-node streams).
    pub fn fork(&mut self, salt: u64) -> Rng {
        Rng::new(self.next_u64() ^ salt.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    /// Draw the raw fork key for a deferred child stream. Storing the key (a
    /// plain `u64`) instead of the child keeps per-node setup O(1) memory and
    /// lets the child be materialized later, position-independently:
    /// `fork(salt)` ≡ `Rng::from_fork(fork_key(), salt)`.
    pub fn fork_key(&mut self) -> u64 {
        self.next_u64()
    }

    /// Materialize the child stream for a key drawn earlier via [`fork_key`].
    ///
    /// [`fork_key`]: Rng::fork_key
    pub fn from_fork(key: u64, salt: u64) -> Rng {
        Rng::new(key ^ salt.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [lo, hi) — panics if the range is empty.
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo < hi, "empty range {lo}..{hi}");
        let span = hi - lo;
        // Lemire-style rejection-free-enough multiply-shift.
        lo + (((self.next_u64() as u128 * span as u128) >> 64) as u64)
    }

    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        self.range_u64(lo as u64, hi as u64) as usize
    }

    /// Uniform f64 in [lo, hi).
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Exponential with mean `mean`.
    pub fn exponential(&mut self, mean: f64) -> f64 {
        let u = 1.0 - self.f64(); // in (0, 1]
        -mean * u.ln()
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self, mean: f64, std: f64) -> f64 {
        let u1 = 1.0 - self.f64();
        let u2 = self.f64();
        let z = (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos();
        mean + std * z
    }

    /// Multiplicative lognormal jitter with median 1 and shape `sigma` —
    /// used for trial-to-trial variation of measured protocol times.
    pub fn jitter(&mut self, sigma: f64) -> f64 {
        self.normal(0.0, sigma).exp()
    }

    /// Bernoulli trial.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Pick an element uniformly.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.range_usize(0, xs.len())]
    }

    /// In-place Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.range_usize(0, i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..100).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut root = Rng::new(7);
        let mut c1 = root.fork(1);
        let mut c2 = root.fork(2);
        let same = (0..100).filter(|_| c1.next_u64() == c2.next_u64()).count();
        assert!(same < 3);
    }

    #[test]
    fn fork_key_matches_fork() {
        let mut a = Rng::new(77);
        let mut b = Rng::new(77);
        for salt in [0u64, 1, 2, 1403, u64::MAX] {
            let mut eager = a.fork(salt);
            let mut lazy = Rng::from_fork(b.fork_key(), salt);
            for _ in 0..64 {
                assert_eq!(eager.next_u64(), lazy.next_u64());
            }
        }
        // Parent streams advanced identically too.
        assert_eq!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniform_mean_reasonable() {
        let mut r = Rng::new(4);
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| r.uniform(2.0, 4.0)).sum::<f64>() / n as f64;
        assert!((mean - 3.0).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn range_bounds_respected() {
        let mut r = Rng::new(5);
        let mut seen_lo = false;
        let mut seen_hi = false;
        for _ in 0..10_000 {
            let v = r.range_u64(10, 14);
            assert!((10..14).contains(&v));
            seen_lo |= v == 10;
            seen_hi |= v == 13;
        }
        assert!(seen_lo && seen_hi);
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(6);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.exponential(5.0)).sum::<f64>() / n as f64;
        assert!((mean - 5.0).abs() < 0.1, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(7);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal(1.0, 2.0)).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!((mean - 1.0).abs() < 0.05, "mean={mean}");
        assert!((var - 4.0).abs() < 0.15, "var={var}");
    }

    #[test]
    fn jitter_median_near_one() {
        let mut r = Rng::new(8);
        let mut xs: Vec<f64> = (0..10_001).map(|_| r.jitter(0.05)).collect();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = xs[5_000];
        assert!((median - 1.0).abs() < 0.01, "median={median}");
        assert!(xs.iter().all(|&x| x > 0.0));
    }

    #[test]
    fn chance_extremes() {
        let mut r = Rng::new(9);
        assert!((0..100).all(|_| !r.chance(0.0)));
        assert!((0..100).all(|_| r.chance(1.0)));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(10);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>()); // astronomically unlikely
    }
}
