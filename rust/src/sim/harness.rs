//! The generic scenario runtime: actor registration, shared jitter / trace /
//! RNG plumbing, stop conditions and deterministic seeding on top of the
//! bare [`Engine`](super::Engine).
//!
//! Before this module existed the episode protocols (`agentft::migration`,
//! `coreft::migration`) and the live full-system simulation
//! (`coordinator::livesim`) each hand-rolled the same scaffolding: an
//! `Rc<RefCell<…>>` result slot, a message enum, jitter handling and trace
//! collection. The harness centralises that plumbing once:
//!
//! * a [`Scenario`] is plain owned state plus an `on_msg` handler — no
//!   shared-ownership cells in protocol code;
//! * the [`Ctx`] handed to the handler exposes the virtual clock, message
//!   scheduling, the harness RNG (one deterministic stream per run), step
//!   tracing and the stop/finish conditions;
//! * [`Harness::run`] drives the engine and hands the scenario state back
//!   *by value* together with the collected trace, so results are read off
//!   plain fields instead of `Rc<RefCell>` slots.
//!
//! ## Hot-path design (see DESIGN.md §Hot path)
//!
//! The harness owns a plain `Vec<S>` of scenario states and dispatches
//! `&mut scenarios[id.0]` through the engine's run closure — there is no
//! `Box<dyn Actor>`, no `Rc<RefCell<Plumbing>>` double borrow, and no
//! `Rc::try_unwrap` unwind at the end of a run; states and plumbing are
//! plain owned fields moved into [`Finished`]. Because nothing is
//! type-erased, [`Scenario`] needs no `'static` bound: scenario state may
//! borrow its configuration (the live simulation borrows its `LiveCfg` and
//! `Topology` instead of cloning them per trial).
//!
//! [`TrialScratch`] carries the engine's queue and staging allocations from
//! one run to the next: `scenario::batch` threads hold one scratch each, so
//! steady-state trials allocate nothing on the event path.
//!
//! Determinism contract: a harness seeded with the same RNG, the same
//! scenario state and the same initial events produces a byte-identical
//! event trace (property-tested in `tests/harness_properties.rs`) — with or
//! without scratch reuse.

use super::engine::{ActorId, Engine, EventLog, Outbox};
use super::{Rng, SimTime};

/// One recorded protocol step (name, start, duration). Shared by the
/// Fig. 3 / Fig. 5 episode protocols and any future scenario that wants a
/// step-by-step account of itself.
#[derive(Debug, Clone, PartialEq)]
pub struct StepTrace {
    pub step: &'static str,
    pub start_s: f64,
    pub dur_s: f64,
}

/// Scenario behaviour: owned state reacting to messages of its own type.
///
/// Implementations hold plain fields (counters, hosts, outcomes); the
/// harness returns the state by value after the run, which is how results
/// leave the simulation. State may borrow long-lived configuration — no
/// `'static` bound — since the harness never type-erases it.
pub trait Scenario: Sized {
    type Msg;

    fn on_msg(&mut self, ctx: &mut Ctx<'_, '_, Self::Msg>, msg: Self::Msg);
}

/// The plumbing every actor of a harness shares.
struct Plumbing {
    rng: Rng,
    trace: Vec<StepTrace>,
    finished_at: Option<SimTime>,
}

/// Per-dispatch context handed to [`Scenario::on_msg`].
pub struct Ctx<'a, 'e, M> {
    me: ActorId,
    out: &'a mut Outbox<'e, M>,
    pb: &'a mut Plumbing,
}

impl<M> Ctx<'_, '_, M> {
    /// Current virtual time of the dispatch.
    pub fn now(&self) -> SimTime {
        self.out.now()
    }

    /// The actor id the message was delivered to.
    pub fn me(&self) -> ActorId {
        self.me
    }

    /// Deliver `msg` to `target` after `delay` of virtual time.
    pub fn send_in(&mut self, delay: SimTime, target: ActorId, msg: M) {
        self.out.send_in(delay, target, msg);
    }

    /// Deliver at an absolute virtual time (clamped to now, see
    /// [`Outbox::send_at`]).
    pub fn send_at(&mut self, at: SimTime, target: ActorId, msg: M) {
        self.out.send_at(at, target, msg);
    }

    /// Deliver `msg` back to this actor after `delay_s` seconds of virtual
    /// time — the common move of the episode state machines.
    pub fn send_self_in_s(&mut self, delay_s: f64, msg: M) {
        let me = self.me;
        self.out.send_in(SimTime::from_secs(delay_s), me, msg);
    }

    /// The harness RNG: one deterministic stream per run, shared by every
    /// actor in dispatch order.
    pub fn rng(&mut self) -> &mut Rng {
        &mut self.pb.rng
    }

    /// Multiplicative lognormal trial jitter; `sigma <= 0` draws nothing
    /// and returns exactly 1.0 (so noiseless runs match closed forms).
    pub fn jitter(&mut self, sigma: f64) -> f64 {
        if sigma > 0.0 {
            self.pb.rng.jitter(sigma)
        } else {
            1.0
        }
    }

    /// Record a protocol step starting now.
    pub fn record(&mut self, step: &'static str, dur_s: f64) {
        let start_s = self.out.now().as_secs();
        self.pb.trace.push(StepTrace { step, start_s, dur_s });
    }

    /// Mark the scenario finished at the current virtual time and stop the
    /// run after this dispatch.
    pub fn finish(&mut self) {
        self.pb.finished_at = Some(self.out.now());
        self.out.stop = true;
    }

    /// Stop the run after this dispatch without marking a finish time.
    pub fn stop(&mut self) {
        self.out.stop = true;
    }
}

/// Everything a finished run hands back: the scenario states by value, the
/// shared step trace, the finish time (if [`Ctx::finish`] was called), the
/// dispatch count and the final clock.
pub struct Finished<S: Scenario> {
    /// Scenario states in registration order.
    pub scenarios: Vec<S>,
    pub trace: Vec<StepTrace>,
    pub finished_at: Option<SimTime>,
    /// Total dispatched events (determinism fingerprint).
    pub events: u64,
    /// Final virtual time.
    pub end: SimTime,
    /// Captured event log (empty unless [`Harness::capture_log`] was used).
    pub log: EventLog,
}

impl<S: Scenario> Finished<S> {
    /// Consume a single-actor run, returning its scenario state.
    pub fn into_scenario(mut self) -> S {
        assert_eq!(self.scenarios.len(), 1, "into_scenario on a multi-actor harness");
        self.scenarios.pop().expect("one scenario")
    }
}

/// Reusable per-trial allocations: the engine's event queue and outbox
/// staging buffer. A batch worker holds one scratch and threads it through
/// consecutive trials via [`Harness::from_scratch`] /
/// [`Harness::run_until_reclaim`]; a recycled scratch behaves exactly like
/// a fresh one (tested in `tests/harness_properties.rs`), it just skips
/// the allocations. Note the event log and step trace move *out* with
/// [`Finished`] (callers own their results), so runs that capture a log or
/// record steps still allocate those — the hot batch path does neither.
pub struct TrialScratch<M> {
    eng: Engine<M>,
    trace: Vec<StepTrace>,
}

impl<M> TrialScratch<M> {
    pub fn new() -> Self {
        Self { eng: Engine::new(), trace: Vec::new() }
    }
}

impl<M> Default for TrialScratch<M> {
    fn default() -> Self {
        Self::new()
    }
}

/// The scenario runtime. Owns the engine, the shared plumbing and the
/// registered scenario states — all as plain fields.
pub struct Harness<S: Scenario> {
    eng: Engine<S::Msg>,
    pb: Plumbing,
    scenarios: Vec<S>,
}

impl<S: Scenario> Harness<S> {
    /// Build a harness whose shared RNG is `rng` (deterministic seeding:
    /// the caller decides exactly which stream the run consumes).
    pub fn new(rng: Rng) -> Self {
        Self::from_scratch(rng, TrialScratch::new())
    }

    /// Convenience: a harness seeded directly from a `u64`.
    pub fn with_seed(seed: u64) -> Self {
        Self::new(Rng::new(seed))
    }

    /// Build a harness on recycled trial allocations. Behaviour is
    /// identical to [`Harness::new`]; only the allocations differ.
    pub fn from_scratch(rng: Rng, scratch: TrialScratch<S::Msg>) -> Self {
        let TrialScratch { mut eng, mut trace } = scratch;
        eng.recycle();
        trace.clear();
        Self { eng, pb: Plumbing { rng, trace, finished_at: None }, scenarios: Vec::new() }
    }

    /// Register a scenario actor; returns its engine id.
    pub fn add(&mut self, scenario: S) -> ActorId {
        self.scenarios.push(scenario);
        ActorId(self.scenarios.len() - 1)
    }

    /// Schedule an initial event.
    pub fn schedule(&mut self, at: SimTime, target: ActorId, msg: S::Msg) {
        self.eng.schedule(at, target, msg);
    }

    /// Enable event-log capture (determinism checks).
    pub fn capture_log(&mut self, tagger: fn(&S::Msg) -> u64) {
        self.eng.capture_log(tagger);
    }

    /// Run to quiescence or until a stop condition fires.
    pub fn run(self) -> Finished<S> {
        self.run_until(SimTime(u64::MAX))
    }

    /// Run until `horizon`, a stop condition, or quiescence.
    pub fn run_until(self, horizon: SimTime) -> Finished<S> {
        self.run_until_reclaim(horizon).0
    }

    /// Run like [`Harness::run_until`] and additionally hand the trial
    /// allocations back for reuse. (The step trace moves into [`Finished`]
    /// — callers own their results — so the returned scratch carries a
    /// fresh trace buffer; scenarios that record no steps never allocate
    /// one.)
    pub fn run_until_reclaim(self, horizon: SimTime) -> (Finished<S>, TrialScratch<S::Msg>) {
        let Harness { mut eng, mut pb, mut scenarios } = self;
        let end = eng.run_until(horizon, |me, msg, out| {
            let mut ctx = Ctx { me, out, pb: &mut pb };
            scenarios[me.0].on_msg(&mut ctx, msg);
        });
        let events = eng.dispatched();
        let log = eng.take_log();
        let trace = std::mem::take(&mut pb.trace);
        let fin = Finished { scenarios, trace, finished_at: pb.finished_at, events, end, log };
        (fin, TrialScratch { eng, trace: Vec::new() })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A counter that re-arms itself with jittered delays until done.
    struct Countdown {
        remaining: u32,
        sigma: f64,
        seen: Vec<u32>,
    }

    impl Scenario for Countdown {
        type Msg = u32;
        fn on_msg(&mut self, ctx: &mut Ctx<'_, '_, u32>, msg: u32) {
            self.seen.push(msg);
            if self.remaining == 0 {
                ctx.finish();
                return;
            }
            self.remaining -= 1;
            ctx.record("tick", 0.001);
            let j = ctx.jitter(self.sigma);
            ctx.send_self_in_s(0.001 * j, msg + 1);
        }
    }

    #[test]
    fn state_returned_by_value_with_trace() {
        let mut h: Harness<Countdown> = Harness::with_seed(1);
        let id = h.add(Countdown { remaining: 5, sigma: 0.0, seen: Vec::new() });
        h.schedule(SimTime::ZERO, id, 0);
        let fin = h.run();
        let s = fin.scenarios.into_iter().next().unwrap();
        assert_eq!(s.seen, vec![0, 1, 2, 3, 4, 5]);
        assert_eq!(fin.trace.len(), 5);
        assert!(fin.finished_at.is_some());
        assert_eq!(fin.events, 6);
    }

    #[test]
    fn noiseless_jitter_is_exactly_one() {
        let mut h: Harness<Countdown> = Harness::with_seed(2);
        let id = h.add(Countdown { remaining: 3, sigma: 0.0, seen: Vec::new() });
        h.schedule(SimTime::ZERO, id, 0);
        let fin = h.run();
        // three re-arms of exactly 1 ms each
        assert_eq!(fin.finished_at.unwrap(), SimTime::from_millis(3.0));
    }

    #[test]
    fn same_seed_identical_trace() {
        let run = |seed: u64| {
            let mut h: Harness<Countdown> = Harness::with_seed(seed);
            h.capture_log(|m| *m as u64);
            let id = h.add(Countdown { remaining: 40, sigma: 0.05, seen: Vec::new() });
            h.schedule(SimTime::ZERO, id, 0);
            let fin = h.run();
            (fin.log, fin.finished_at)
        };
        assert_eq!(run(7), run(7));
        assert_ne!(run(7).1, run(8).1);
    }

    #[test]
    fn multi_actor_states_in_registration_order() {
        struct Echo {
            tag: u32,
            got: u32,
        }
        impl Scenario for Echo {
            type Msg = u32;
            fn on_msg(&mut self, ctx: &mut Ctx<'_, '_, u32>, msg: u32) {
                self.got = msg + self.tag;
                ctx.stop();
            }
        }
        let mut h: Harness<Echo> = Harness::with_seed(3);
        let a = h.add(Echo { tag: 10, got: 0 });
        let b = h.add(Echo { tag: 20, got: 0 });
        h.schedule(SimTime::ZERO, a, 1);
        h.schedule(SimTime::from_secs(1.0), b, 2);
        // first run stops after actor a's dispatch; re-drive manually: the
        // stop flag only halts remaining deliveries, so schedule both at the
        // same time to observe both.
        let fin = h.run();
        assert_eq!(fin.scenarios[0].got, 11);
        assert_eq!(fin.scenarios[1].got, 0); // stopped before b's event
    }

    #[test]
    fn into_scenario_unwraps_single_actor() {
        let mut h: Harness<Countdown> = Harness::with_seed(4);
        let id = h.add(Countdown { remaining: 1, sigma: 0.0, seen: Vec::new() });
        h.schedule(SimTime::ZERO, id, 9);
        let s = h.run().into_scenario();
        assert_eq!(s.seen, vec![9, 10]);
    }

    #[test]
    fn scenario_state_may_borrow_config() {
        // the redesign drops the `'static` bound: scenario state can borrow
        // long-lived configuration instead of cloning it per trial
        struct Borrowing<'a> {
            weights: &'a [f64],
            acc: f64,
        }
        impl Scenario for Borrowing<'_> {
            type Msg = usize;
            fn on_msg(&mut self, ctx: &mut Ctx<'_, '_, usize>, msg: usize) {
                self.acc += self.weights[msg];
                if msg + 1 < self.weights.len() {
                    ctx.send_self_in_s(0.001, msg + 1);
                } else {
                    ctx.finish();
                }
            }
        }
        let weights = vec![1.0, 2.0, 4.0];
        let mut h: Harness<Borrowing<'_>> = Harness::with_seed(5);
        let id = h.add(Borrowing { weights: &weights, acc: 0.0 });
        h.schedule(SimTime::ZERO, id, 0);
        let s = h.run().into_scenario();
        assert_eq!(s.acc, 7.0);
    }

    #[test]
    fn scratch_reuse_replays_identically() {
        let run = |scratch: TrialScratch<u32>| {
            let mut h: Harness<Countdown> = Harness::from_scratch(Rng::new(11), scratch);
            h.capture_log(|m| *m as u64);
            let id = h.add(Countdown { remaining: 30, sigma: 0.05, seen: Vec::new() });
            h.schedule(SimTime::ZERO, id, 0);
            let (fin, scratch) = h.run_until_reclaim(SimTime(u64::MAX));
            ((fin.log, fin.finished_at, fin.events, fin.trace.len()), scratch)
        };
        let (first, scratch) = run(TrialScratch::new());
        let (second, _) = run(scratch);
        assert_eq!(first, second);
    }
}
