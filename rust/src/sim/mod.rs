//! Deterministic discrete-event simulation core.
//!
//! Every experiment in the paper is a *time* measurement over a cluster; we
//! reproduce them on a virtual-time engine so five-hour jobs run in
//! milliseconds of wall clock and every trial is exactly reproducible from
//! its seed (a property the test suite leans on heavily).
//!
//! [`engine`] is the bare event loop; [`harness`] is the scenario runtime
//! that every protocol simulation (episodes, live runs, multi-failure
//! scenarios) is built on.

pub mod engine;
pub mod harness;
pub mod rng;

pub use engine::{Engine, EventLog, ShardedQueue, SimTime};
pub use harness::{Ctx, Finished, Harness, Scenario, StepTrace, TrialScratch};
pub use rng::Rng;
