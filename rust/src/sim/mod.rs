//! Deterministic discrete-event simulation core.
//!
//! Every experiment in the paper is a *time* measurement over a cluster; we
//! reproduce them on a virtual-time engine so five-hour jobs run in
//! milliseconds of wall clock and every trial is exactly reproducible from
//! its seed (a property the test suite leans on heavily).

pub mod engine;
pub mod rng;

pub use engine::{Engine, EventLog, SimTime};
pub use rng::Rng;
