//! Validation of the decision rules with the genome-searching job
//! (Results, "Genome Searching using Multi-Agent approaches"):
//!
//! * Z = 4  vs Z = 12 at S_d = 2^19 KB — validates Rule 1 (core wins small
//!   Z, comparable at Z = 12);
//! * S_d = 2^19 vs 2^25 KB — validates Rule 2 (agent wins small data,
//!   comparable large);
//! * S_p sweep — validates Rule 3.

use crate::cluster::{preset, ClusterPreset};
use crate::coordinator::ftmanager::Strategy;
use crate::coordinator::run::ExperimentCfg;
use crate::metrics::Table;
use crate::scenario::{run_sweep, CellSpec, SweepSpec};
use crate::util::fmt::hms_ms;

/// One validation scenario and its measurements.
#[derive(Debug, Clone)]
pub struct RuleCheck {
    pub label: String,
    pub z: usize,
    pub data_kb: u64,
    pub proc_kb: u64,
    pub agent_s: f64,
    pub core_s: f64,
    /// Which rule this scenario probes.
    pub rule: &'static str,
    /// Expected relation: -1 core wins, +1 agent wins, 0 comparable.
    pub expected: i8,
}

impl RuleCheck {
    /// Does the measurement satisfy the expected relation (5 % comparability
    /// band)?
    pub fn holds(&self) -> bool {
        let rel = (self.agent_s - self.core_s) / self.core_s;
        match self.expected {
            -1 => self.core_s <= self.agent_s,
            1 => self.agent_s <= self.core_s,
            _ => rel.abs() < 0.30, // "the times are comparable"
        }
    }
}

/// Run all the genome-job validation scenarios — every (scenario ×
/// approach) pair is one cell of a single fused sweep, with the same
/// seeds (`seed ^ i<<8` for agent, `… ^ 0xc0fe` for core) the historical
/// per-scenario loop used.
pub fn run(seed: u64) -> Vec<RuleCheck> {
    let kb19 = 1u64 << 19;
    let kb25 = 1u64 << 25;
    let scenarios: Vec<(String, usize, u64, u64, &'static str, i8)> = vec![
        // Rule 1: three searchers + combiner (Z=4) vs eleven + one (Z=12)
        ("genome search, Z=4, S_d=2^19".into(), 4, kb19, kb19, "Rule 1", -1),
        ("genome search, Z=12, S_d=2^19".into(), 12, kb19, kb19, "Rule 1", 0),
        // Rule 2: small vs large data at Z=12 (rule region requires Z>10)
        ("genome search, Z=12, S_d=2^19 (small data)".into(), 12, kb19, kb19, "Rule 2", 1),
        ("genome search, Z=12, S_d=2^25 (large data)".into(), 12, kb25, kb25, "Rule 2", 0),
        // Rule 3: small vs large process image
        ("genome search, Z=12, S_p=2^19 (small proc)".into(), 12, kb19, kb19, "Rule 3", 1),
        ("genome search, Z=12, S_p=2^25 (large proc)".into(), 12, kb19, kb25, "Rule 3", 0),
    ];
    let cells: Vec<CellSpec> = scenarios
        .iter()
        .enumerate()
        .flat_map(|(i, &(_, z, d, p, _, _))| {
            let cfg = ExperimentCfg {
                z,
                data_kb: d,
                proc_kb: p,
                ..ExperimentCfg::table1(preset(ClusterPreset::Placentia))
            };
            let s = seed ^ ((i as u64) << 8);
            [
                CellSpec::reinstate(Strategy::Agent, cfg.clone(), s),
                CellSpec::reinstate(Strategy::Core, cfg, s ^ 0xc0fe),
            ]
        })
        .collect();
    let sums = run_sweep(&SweepSpec::new(cells, 30));
    scenarios
        .into_iter()
        .enumerate()
        .map(|(i, (label, z, d, p, rule, expected))| RuleCheck {
            label,
            z,
            data_kb: d,
            proc_kb: p,
            agent_s: sums[2 * i].mean,
            core_s: sums[2 * i + 1].mean,
            rule,
            expected,
        })
        .collect()
}

/// Render as a table.
pub fn render(checks: &[RuleCheck]) -> String {
    let mut t = Table::new(
        "Decision-rule validation (genome searching job, Placentia)",
        &["scenario", "rule", "agent reinstate", "core reinstate", "expected", "holds"],
    );
    for c in checks {
        t.row(&[
            c.label.clone(),
            c.rule.to_string(),
            hms_ms(c.agent_s),
            hms_ms(c.core_s),
            match c.expected {
                -1 => "core wins".into(),
                1 => "agent wins".into(),
                _ => "comparable".into(),
            },
            if c.holds() { "yes".into() } else { "NO".into() },
        ]);
    }
    t.render()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_rules_hold() {
        let checks = run(2014);
        for c in &checks {
            assert!(
                c.holds(),
                "{} ({}): agent {:.3} core {:.3} expected {}",
                c.label,
                c.rule,
                c.agent_s,
                c.core_s,
                c.expected
            );
        }
    }

    #[test]
    fn genome_anchors_reproduced() {
        let checks = run(99);
        let z4 = &checks[0];
        // paper: agent 0.47 s, core 0.38 s
        assert!((z4.agent_s - 0.47).abs() < 0.02, "{}", z4.agent_s);
        assert!((z4.core_s - 0.38).abs() < 0.02, "{}", z4.core_s);
        // Z=12: paper reports ~0.54 s, "times are comparable"
        let z12 = &checks[1];
        assert!((0.45..0.60).contains(&z12.agent_s), "{}", z12.agent_s);
        assert!((z12.agent_s - z12.core_s).abs() / z12.core_s < 0.3);
    }

    #[test]
    fn render_flags_holds() {
        let r = render(&run(5));
        assert!(r.contains("yes"));
        assert!(!r.contains(" NO "));
    }
}
