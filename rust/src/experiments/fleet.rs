//! The fleet experiment family: continuous multi-job cluster lifetimes
//! through [`scenario::fleet`] and the fused sweep executor (EXPERIMENTS.md
//! §Fleet).
//!
//! * `fleet` — mean job slowdown vs arrival rate, one series per
//!   fault-tolerance strategy: the headline 90 %-vs-10 % separation
//!   re-emerges at fleet scale and widens as the cluster fills;
//! * `fleet_contention` — slowdown vs churn as checkpoint recoveries
//!   contend for the shared checkpoint server (1 stream vs 8 streams vs
//!   the hybrid strategy, which never queues on the server);
//! * `fleet_churn` — goodput vs per-node churn rate under fail → repair →
//!   rejoin, one series per strategy;
//! * `fleet_scale` — goodput vs cluster size at a fixed ~90 % offered
//!   load ([`FleetSpec::scale_fleet`] sizing): the scale ladder that the
//!   timer-wheel event queue, indexed placement and arena job storage
//!   exist for, and the small sibling of the 10k-node / 1M-arrival bench
//!   target in `benches/fleet.rs`.
//!
//! Every grid runs chunk-parallel through [`run_sweep`]; cells are
//! trial-seeded, so each figure is byte-identical at any thread count.
//!
//! [`scenario::fleet`]: crate::scenario::fleet

use crate::checkpoint::CheckpointStrategy;
use crate::coordinator::ftmanager::Strategy;
use crate::metrics::Series;
use crate::scenario::{run_sweep, CellSpec, FleetMetric, FleetSpec, SweepSpec};

/// Cluster size shared by the fleet figures (ring of 48 nodes × 2 slots).
const NODES: usize = 48;

/// One line of a fleet figure: a label plus the spec builder for an
/// x-axis value. Shared with the `netfault` family.
pub(crate) type Variant<'a> = (&'a str, Box<dyn Fn(f64) -> FleetSpec>);

/// The checkpoint baseline of the fleet figures: central single-server
/// checkpointing is reactive only (no prediction-driven migration), so its
/// `predictable_frac` is forced to zero.
fn checkpoint_fleet(arrival_per_h: f64, churn_per_node_h: f64, streams: usize) -> FleetSpec {
    let mut spec = FleetSpec::placentia_fleet(
        Strategy::Checkpoint(CheckpointStrategy::CentralSingle),
        NODES,
        arrival_per_h,
        churn_per_node_h,
    );
    spec.job.predictable_frac = 0.0;
    spec.ckpt_streams = streams;
    spec
}

/// The shared scaffold of every fleet figure: one sweep cell per
/// (variant × x-point), all run as one fused grid, one series per
/// variant. Per-point seeds are spaced 2³² apart — far beyond any
/// realistic trial count, so neighbouring x-points never share trial
/// seeds — while variants share seeds deliberately (common random
/// numbers: every strategy faces the same arrival/churn stories).
pub(crate) fn fleet_series(
    title: &str,
    x_label: &str,
    y_label: &str,
    xs: &[f64],
    variants: &[Variant<'_>],
    metric: FleetMetric,
    trials: usize,
    seed: u64,
) -> Series {
    let cells: Vec<CellSpec> = variants
        .iter()
        .flat_map(|(_, mk)| {
            xs.iter().enumerate().map(move |(i, &x)| {
                CellSpec::fleet(mk(x), metric, seed ^ ((i as u64) << 32))
            })
        })
        .collect();
    let y: Vec<f64> = run_sweep(&SweepSpec::new(cells, trials.max(1)))
        .iter()
        .map(|s| s.mean)
        .collect();
    let mut s = Series::new(title, x_label, y_label, xs.to_vec());
    for (vi, (label, _)) in variants.iter().enumerate() {
        s.push(label, y[vi * xs.len()..(vi + 1) * xs.len()].to_vec());
    }
    s
}

/// Mean job slowdown vs arrival rate, per strategy.
pub fn fleet(trials: usize, seed: u64) -> Series {
    let churn = 0.5;
    let variants: Vec<Variant<'_>> = vec![
        (
            "hybrid intelligence",
            Box::new(move |r| FleetSpec::placentia_fleet(Strategy::Hybrid, NODES, r, churn)),
        ),
        (
            "agent intelligence",
            Box::new(move |r| FleetSpec::placentia_fleet(Strategy::Agent, NODES, r, churn)),
        ),
        (
            "checkpoint (central, 2 streams)",
            Box::new(move |r| checkpoint_fleet(r, churn, 2)),
        ),
    ];
    fleet_series(
        "Fleet: mean job slowdown vs arrival rate (48 nodes, churn 0.5/node/h)",
        "job arrivals per hour",
        "mean slowdown (completion / nominal)",
        &[2.0, 4.0, 8.0, 16.0],
        &variants,
        FleetMetric::MeanSlowdown,
        trials,
        seed,
    )
}

/// Mean job slowdown vs churn rate as checkpoint recoveries contend for
/// the shared checkpoint server.
pub fn fleet_contention(trials: usize, seed: u64) -> Series {
    let arrival = 6.0;
    let variants: Vec<Variant<'_>> = vec![
        (
            "checkpoint, 1 server stream",
            Box::new(move |c| checkpoint_fleet(arrival, c, 1)),
        ),
        (
            "checkpoint, 8 server streams",
            Box::new(move |c| checkpoint_fleet(arrival, c, 8)),
        ),
        (
            "hybrid intelligence (no server queueing)",
            Box::new(move |c| FleetSpec::placentia_fleet(Strategy::Hybrid, NODES, arrival, c)),
        ),
    ];
    fleet_series(
        "Fleet: checkpoint-server contention (48 nodes, 6 jobs/h)",
        "node failures per node-hour",
        "mean slowdown (completion / nominal)",
        &[0.25, 0.5, 1.0, 2.0],
        &variants,
        FleetMetric::MeanSlowdown,
        trials,
        seed,
    )
}

/// Goodput vs per-node churn rate under fail → repair → rejoin.
pub fn fleet_churn(trials: usize, seed: u64) -> Series {
    let arrival = 8.0;
    let variants: Vec<Variant<'_>> = vec![
        (
            "hybrid intelligence",
            Box::new(move |c| FleetSpec::placentia_fleet(Strategy::Hybrid, NODES, arrival, c)),
        ),
        (
            "core intelligence",
            Box::new(move |c| FleetSpec::placentia_fleet(Strategy::Core, NODES, arrival, c)),
        ),
        (
            "checkpoint (central, 2 streams)",
            Box::new(move |c| checkpoint_fleet(arrival, c, 2)),
        ),
    ];
    fleet_series(
        "Fleet: goodput under node churn with repair (48 nodes, 8 jobs/h)",
        "node failures per node-hour",
        "goodput (completed compute / cluster slot-seconds)",
        &[0.0, 0.5, 1.0, 2.0, 4.0],
        &variants,
        FleetMetric::Goodput,
        trials,
        seed,
    )
}

/// Goodput vs cluster size at a fixed ~90 % offered load. Every x-point
/// is a [`FleetSpec::scale_fleet`] lifetime — the arrival count grows
/// with the ring (6 jobs per node), so the horizon stays ~13 h while the
/// event volume scales linearly with the cluster. The paper's headline
/// separation must survive scale: the hybrid line holds its goodput as
/// the ring grows, while the checkpoint line keeps paying rollbacks into
/// the shared server.
pub fn fleet_scale(trials: usize, seed: u64) -> Series {
    let churn = 0.25;
    let arrivals_per_node = 6;
    let variants: Vec<Variant<'_>> = vec![
        (
            "hybrid intelligence",
            Box::new(move |n| {
                FleetSpec::scale_fleet(
                    Strategy::Hybrid,
                    n as usize,
                    arrivals_per_node * n as usize,
                    churn,
                )
            }),
        ),
        (
            "checkpoint (central, 2 streams)",
            Box::new(move |n| {
                let mut spec = FleetSpec::scale_fleet(
                    Strategy::Checkpoint(CheckpointStrategy::CentralSingle),
                    n as usize,
                    arrivals_per_node * n as usize,
                    churn,
                );
                spec.job.predictable_frac = 0.0;
                spec.ckpt_streams = 2;
                spec
            }),
        ),
    ];
    fleet_series(
        "Fleet scale: goodput vs cluster size (~90% load, churn 0.25/node/h)",
        "cluster nodes (ring of 2, 2 slots/node)",
        "goodput (completed compute / cluster slot-seconds)",
        &[64.0, 128.0, 256.0],
        &variants,
        FleetMetric::Goodput,
        trials,
        seed,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fleet_series_shape_and_determinism() {
        let a = fleet(2, 9);
        assert_eq!(a.series.len(), 3);
        assert_eq!(a.x.len(), 4);
        for (name, y) in &a.series {
            assert_eq!(y.len(), 4, "{name}");
        }
        let b = fleet(2, 9);
        assert_eq!(a.to_csv(), b.to_csv());
    }

    #[test]
    fn fleet_scale_shape_and_finite_goodput() {
        let s = fleet_scale(1, 12);
        assert_eq!(s.series.len(), 2);
        assert_eq!(s.x, vec![64.0, 128.0, 256.0]);
        for (name, y) in &s.series {
            assert_eq!(y.len(), 3, "{name}");
            // goodput is defined (0) even for empty lifetimes, never NaN
            assert!(y.iter().all(|v| v.is_finite()), "{name}: {y:?}");
        }
    }

    #[test]
    fn contention_starved_server_is_never_cheaper_in_aggregate() {
        let s = fleet_contention(3, 5);
        let one = &s.series[0].1;
        let eight = &s.series[1].1;
        let sum1: f64 = one.iter().filter(|v| v.is_finite()).sum();
        let sum8: f64 = eight.iter().filter(|v| v.is_finite()).sum();
        assert!(
            sum1 >= sum8 - 1e-9,
            "1-stream slowdowns {sum1} must not beat 8-stream {sum8}"
        );
    }

    #[test]
    fn churn_goodput_declines_for_every_strategy() {
        let s = fleet_churn(3, 4);
        for (name, y) in &s.series {
            assert!(y.iter().all(|v| v.is_finite()), "{name}: goodput is never NaN");
            assert!(
                y[0] >= *y.last().unwrap() - 1e-9,
                "{name}: churn-free goodput {} should be at least the heavy-churn one {}",
                y[0],
                y.last().unwrap()
            );
        }
    }
}
