//! The network-fault experiment: what the deterministic fault plane
//! ([`crate::net::faults`]) costs each recovery strategy (EXPERIMENTS.md
//! §Network faults).
//!
//! * `netfault` — goodput vs per-message loss rate, loss × detector
//!   accuracy: every migration handshake and checkpoint-server exchange
//!   pays timeouts, retransmissions and exponential backoff out of the
//!   same [`RetryPolicy`](crate::net::RetryPolicy), and an exhausted
//!   exchange degrades gracefully (migration falls back to reactive
//!   checkpoint recovery; a severed restore pays the cold-restore factor)
//!   instead of losing the job. The figure shows the proactive lines
//!   eroding toward the reactive baseline as loss climbs — lost
//!   negotiation/handshake traffic converts predicted failures back into
//!   rollbacks — while an accurate detector keeps a margin at every loss
//!   rate.
//!
//! Both link classes (peer and checkpoint-server) share the swept loss
//! probability, so the checkpoint baseline is not given a free perfect
//! network. Seeds follow the fleet-family convention: common random
//! numbers across variants, 2³²-spaced per x-point.

use super::fleet::{fleet_series, Variant};
use crate::checkpoint::CheckpointStrategy;
use crate::coordinator::ftmanager::Strategy;
use crate::metrics::Series;
use crate::scenario::{FleetMetric, FleetSpec};

/// Cluster size of the netfault figure (ring of 32 nodes × 2 slots).
const NODES: usize = 32;

/// Apply a symmetric loss rate to both link classes of the spec's fault
/// plane. Duplication/delay stay off so the x-axis isolates loss; the
/// retry policy stays at its calibrated default.
fn faulted(mut spec: FleetSpec, loss_p: f64) -> FleetSpec {
    spec.faults.peer.loss_p = loss_p;
    spec.faults.ckpt.loss_p = loss_p;
    spec
}

/// Goodput vs per-message loss rate: loss × detector accuracy.
pub fn netfault(trials: usize, seed: u64) -> Series {
    let arrival = 6.0;
    let churn = 1.0;
    let variants: Vec<Variant<'_>> = vec![
        (
            "hybrid, accurate detector (90% predicted)",
            Box::new(move |l| {
                faulted(FleetSpec::placentia_fleet(Strategy::Hybrid, NODES, arrival, churn), l)
            }),
        ),
        (
            "hybrid, weak detector (50% predicted)",
            Box::new(move |l| {
                let mut s = FleetSpec::placentia_fleet(Strategy::Hybrid, NODES, arrival, churn);
                s.job.predictable_frac = 0.5;
                faulted(s, l)
            }),
        ),
        (
            "checkpoint (central, 2 streams, reactive)",
            Box::new(move |l| {
                let mut s = FleetSpec::placentia_fleet(
                    Strategy::Checkpoint(CheckpointStrategy::CentralSingle),
                    NODES,
                    arrival,
                    churn,
                );
                s.job.predictable_frac = 0.0;
                faulted(s, l)
            }),
        ),
    ];
    fleet_series(
        "Netfault: goodput vs message loss rate (32 nodes, 6 jobs/h, churn 1/node/h)",
        "per-message loss probability (both link classes)",
        "goodput (completed compute / cluster slot-seconds)",
        &[0.0, 0.02, 0.05, 0.1, 0.2],
        &variants,
        FleetMetric::Goodput,
        trials,
        seed,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn netfault_shape_and_determinism() {
        let a = netfault(2, 9);
        assert_eq!(a.series.len(), 3);
        assert_eq!(a.x, vec![0.0, 0.02, 0.05, 0.1, 0.2]);
        for (name, y) in &a.series {
            assert_eq!(y.len(), 5, "{name}");
            assert!(y.iter().all(|v| v.is_finite()), "{name}: goodput is never NaN");
        }
        let b = netfault(2, 9);
        assert_eq!(a.to_csv(), b.to_csv());
    }

    #[test]
    fn lossless_point_matches_the_unfaulted_fleet() {
        // At loss 0.0 the plane is off and the cell must be byte-identical
        // to a spec that never mentions faults at all.
        let spec = faulted(
            FleetSpec::placentia_fleet(Strategy::Hybrid, NODES, 6.0, 1.0),
            0.0,
        );
        assert!(spec.faults.is_off());
        let clean = FleetSpec::placentia_fleet(Strategy::Hybrid, NODES, 6.0, 1.0);
        let a = crate::scenario::fleet::run_fleet(&spec, 42);
        let b = crate::scenario::fleet::run_fleet(&clean, 42);
        assert_eq!(a.events, b.events);
        assert_eq!(a.jobs_completed, b.jobs_completed);
        assert_eq!(a.net_retries, 0);
        assert_eq!(a.fallbacks, 0);
    }

    #[test]
    fn loss_never_raises_goodput_for_the_accurate_detector() {
        let s = netfault(3, 5);
        let (name, y) = &s.series[0];
        assert!(
            y[0] >= *y.last().unwrap() - 1e-9,
            "{name}: lossless goodput {} should be at least the 20%-loss one {}",
            y[0],
            y.last().unwrap()
        );
    }
}
