//! Figs. 8-13: reinstate time vs dependencies / data size / process size,
//! one series per cluster, mean of 30 DES trials per point.
//!
//! Every figure's grid — all (preset × parameter point) cells — runs as
//! **one** fused [`run_sweep`] task list, so the whole figure parallelises
//! even though each cell is only 30 trials (the per-point loop never
//! crossed the serial threshold). Cell seeds and draw streams are exactly
//! the historical per-point loop's, so outputs are byte-identical to it at
//! any thread count (`tests/sweep_properties.rs`).

use crate::cluster::{preset, ClusterPreset};
use crate::coordinator::ftmanager::Strategy;
use crate::coordinator::run::ExperimentCfg;
use crate::metrics::{Series, Summary};
use crate::scenario::{run_sweep, CellSpec, SweepSpec};

/// The paper's dependency sweep: Z from 3 to 63.
pub fn z_values() -> Vec<usize> {
    let mut v = vec![3, 5, 7, 10, 15, 20, 25, 30, 35, 40, 45, 50, 55, 60, 63];
    v.dedup();
    v
}

/// The paper's size sweep: `2^n KB` for n = 19, 19.5, …, 31.
pub fn size_exponents() -> Vec<f64> {
    let mut v = Vec::new();
    let mut n = 19.0;
    while n <= 31.0 + 1e-9 {
        v.push(n);
        n += 0.5;
    }
    v
}

fn kb_of(n: f64) -> u64 {
    2f64.powf(n).round() as u64
}

/// One grid cell: the same `ExperimentCfg` + seed the historical
/// per-point `measure` built, as a sweep cell.
fn cell(
    strategy: Strategy,
    p: ClusterPreset,
    z: usize,
    data_kb: u64,
    proc_kb: u64,
    seed: u64,
) -> CellSpec {
    let cfg = ExperimentCfg { z, data_kb, proc_kb, ..ExperimentCfg::table1(preset(p)) };
    CellSpec::reinstate(strategy, cfg, seed)
}

/// Run a preset-major grid as one fused sweep and fold the per-cell means
/// back into one series column per preset.
fn grid_series(
    title: &str,
    x_label: &str,
    x: Vec<f64>,
    cells: Vec<CellSpec>,
    trials: usize,
) -> Series {
    let points = x.len();
    let sums: Vec<Summary> = run_sweep(&SweepSpec::new(cells, trials.max(1)));
    let mut s = Series::new(title, x_label, "reinstate time (s)", x);
    for (pi, p) in ClusterPreset::all().into_iter().enumerate() {
        let y: Vec<f64> = sums[pi * points..(pi + 1) * points].iter().map(|c| c.mean).collect();
        s.push(p.name(), y);
    }
    s
}

fn sweep_z(strategy: Strategy, title: &str, trials: usize, seed: u64) -> Series {
    let zs = z_values();
    let cells: Vec<CellSpec> = ClusterPreset::all()
        .into_iter()
        .flat_map(|p| {
            zs.iter()
                .map(move |&z| cell(strategy, p, z, 1 << 24, 1 << 24, seed ^ z as u64))
                .collect::<Vec<_>>()
        })
        .collect();
    let x = zs.iter().map(|&z| z as f64).collect();
    grid_series(title, "dependencies Z", x, cells, trials)
}

fn sweep_size(strategy: Strategy, title: &str, vary_data: bool, trials: usize, seed: u64) -> Series {
    let ns = size_exponents();
    let cells: Vec<CellSpec> = ClusterPreset::all()
        .into_iter()
        .flat_map(|p| {
            ns.iter()
                .map(move |&n| {
                    let kb = kb_of(n);
                    let (d, pr) = if vary_data { (kb, 1 << 19) } else { (1 << 19, kb) };
                    cell(strategy, p, 10, d, pr, seed ^ n.to_bits())
                })
                .collect::<Vec<_>>()
        })
        .collect();
    grid_series(title, "size 2^n KB (n)", ns, cells, trials)
}

/// Fig. 8 — Z vs reinstate, agent intelligence (S_d = 2^24 KB).
pub fn fig8(trials: usize, seed: u64) -> Series {
    sweep_z(Strategy::Agent, "Fig 8: dependencies vs reinstate (agent intelligence)", trials, seed)
}

/// Fig. 9 — Z vs reinstate, core intelligence.
pub fn fig9(trials: usize, seed: u64) -> Series {
    sweep_z(Strategy::Core, "Fig 9: dependencies vs reinstate (core intelligence)", trials, seed)
}

/// Fig. 10 — S_d vs reinstate, agent intelligence (Z = 10).
pub fn fig10(trials: usize, seed: u64) -> Series {
    sweep_size(Strategy::Agent, "Fig 10: data size vs reinstate (agent intelligence)", true, trials, seed)
}

/// Fig. 11 — S_d vs reinstate, core intelligence.
pub fn fig11(trials: usize, seed: u64) -> Series {
    sweep_size(Strategy::Core, "Fig 11: data size vs reinstate (core intelligence)", true, trials, seed)
}

/// Fig. 12 — S_p vs reinstate, agent intelligence.
pub fn fig12(trials: usize, seed: u64) -> Series {
    sweep_size(Strategy::Agent, "Fig 12: process size vs reinstate (agent intelligence)", false, trials, seed)
}

/// Fig. 13 — S_p vs reinstate, core intelligence.
pub fn fig13(trials: usize, seed: u64) -> Series {
    sweep_size(Strategy::Core, "Fig 13: process size vs reinstate (core intelligence)", false, trials, seed)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn col<'a>(s: &'a Series, name: &str) -> &'a [f64] {
        &s.series.iter().find(|(n, _)| n == name).unwrap().1
    }

    #[test]
    fn fig8_orderings() {
        let s = fig8(8, 1);
        assert_eq!(s.series.len(), 4);
        let acet = col(&s, "acet");
        let plac = col(&s, "placentia");
        // ACET slowest, Placentia fastest, everywhere
        for i in 0..s.x.len() {
            assert!(acet[i] > plac[i], "x={}", s.x[i]);
        }
        // steep rise until Z=10 then shallow (placentia)
        let i3 = s.x.iter().position(|&x| x == 3.0).unwrap();
        let i10 = s.x.iter().position(|&x| x == 10.0).unwrap();
        let i25 = s.x.iter().position(|&x| x == 25.0).unwrap();
        let early_slope = (plac[i10] - plac[i3]) / 7.0;
        let late_slope = (plac[i25] - plac[i10]) / 15.0;
        assert!(early_slope > 2.0 * late_slope, "early {early_slope} late {late_slope}");
        // ACET rises again after Z=25 (congestion)
        let acet25 = acet[i25];
        let acet_last = acet[s.x.len() - 1];
        assert!(acet_last - acet25 > 0.1, "{acet25} -> {acet_last}");
        // sub-second everywhere on placentia
        assert!(plac.iter().all(|&v| v < 0.6));
    }

    #[test]
    fn fig9_uniform_then_divergent() {
        let s = fig9(8, 2);
        let i5 = s.x.iter().position(|&x| x == 5.0).unwrap();
        let at = |i: usize| -> Vec<f64> { s.series.iter().map(|(_, y)| y[i]).collect() };
        let spread = |v: &[f64]| {
            v.iter().cloned().fold(f64::MIN, f64::max) - v.iter().cloned().fold(f64::MAX, f64::min)
        };
        let last = s.x.len() - 1;
        assert!(spread(&at(i5)) < 0.08, "spread at Z=5: {:?}", at(i5));
        assert!(spread(&at(last)) > 2.0 * spread(&at(i5)));
    }

    #[test]
    fn rule1_visible_in_fig8_vs_fig9() {
        let f8 = fig8(8, 3);
        let f9 = fig9(8, 3);
        // core below agent for Z <= 10 on every cluster (S_d = 2^24)
        for (name, _) in &f8.series {
            let a = col(&f8, name);
            let c = col(&f9, name);
            for (i, &z) in f8.x.iter().enumerate() {
                if z <= 10.0 {
                    assert!(c[i] < a[i] + 0.02, "{name} z={z}: core {} agent {}", c[i], a[i]);
                }
            }
        }
    }

    #[test]
    fn rule2_visible_in_fig10_vs_fig11() {
        let f10 = fig10(8, 4);
        let f11 = fig11(8, 4);
        let a = col(&f10, "placentia");
        let c = col(&f11, "placentia");
        for (i, &n) in f10.x.iter().enumerate() {
            if n <= 24.0 {
                assert!(a[i] <= c[i] + 0.02, "n={n}: agent {} core {}", a[i], c[i]);
            }
        }
    }

    #[test]
    fn fig11_acet_worse_past_2p24() {
        let f11 = fig11(8, 5);
        let acet = col(&f11, "acet");
        let plac = col(&f11, "placentia");
        let i22 = f11.x.iter().position(|&x| x == 22.0).unwrap();
        let i30 = f11.x.iter().position(|&x| x == 30.0).unwrap();
        let gap22 = acet[i22] - plac[i22];
        let gap30 = acet[i30] - plac[i30];
        assert!(gap30 > gap22 + 0.05, "gap22 {gap22} gap30 {gap30}");
    }

    #[test]
    fn fig12_13_similar_to_fig10_11() {
        // paper: "The second scenario performs similar to the first"
        let f10 = fig10(8, 6);
        let f12 = fig12(8, 6);
        let a10 = col(&f10, "glooscap");
        let a12 = col(&f12, "glooscap");
        for i in 0..f10.x.len() {
            assert!((a10[i] - a12[i]).abs() < 0.05, "i={i}");
        }
    }

    #[test]
    fn sweeps_deterministic() {
        let a = fig10(4, 9).to_csv();
        let b = fig10(4, 9).to_csv();
        assert_eq!(a, b);
    }
}
